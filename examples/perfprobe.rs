use ptxasw::sim::run;
use ptxasw::suite::{by_name, workload, generate};
use ptxasw::emu::emulate;
use ptxasw::shuffle::{detect, DetectOpts};
use std::time::Instant;

fn main() {
    // simulator throughput on tricubic (largest kernel)
    let b = by_name("tricubic").unwrap();
    let w = workload(&b, 64, 16, 12, 1);
    let t0 = Instant::now();
    let r = run(&w.kernel, &w.cfg, w.mem).unwrap();
    let dt = t0.elapsed().as_secs_f64();
    println!("sim: {} warp-instr in {:.3}s = {:.2} M warp-instr/s ({:.2} M thread-instr/s)",
        r.stats.warp_instructions, dt,
        r.stats.warp_instructions as f64 / dt / 1e6,
        r.stats.thread_instructions as f64 / dt / 1e6);
    // analysis throughput across whole suite
    let t1 = Instant::now();
    let mut total_terms = 0usize;
    for b in ptxasw::suite::suite() {
        let k = generate(&b);
        let res = emulate(&k).unwrap();
        total_terms += res.pool.len();
        let _ = detect(&k, &res, DetectOpts::default());
    }
    println!("analysis: full 16-benchmark suite in {:.1}ms ({} terms interned)",
        t1.elapsed().as_secs_f64()*1e3, total_terms);
}
