//! Performance probe: simulator throughput, analysis throughput through
//! the staged pipeline, the artifact cache's cold→warm behaviour, and the
//! on-disk store's cross-process warm path.
//!
//!     cargo run --release --example perfprobe [--stats]

use ptxasw::coordinator::{report, run_suite_on, PipelineConfig};
use ptxasw::pipeline::{DiskStore, Pipeline, Stage};
use ptxasw::shuffle::DetectOpts;
use ptxasw::sim::run;
use ptxasw::suite::{by_name, generate, workload};
use std::time::Instant;

fn main() {
    let want_stats = std::env::args().any(|a| a == "--stats");

    // simulator throughput on tricubic (largest kernel)
    let b = by_name("tricubic").unwrap();
    let w = workload(&b, 64, 16, 12, 1);
    let t0 = Instant::now();
    let r = run(&w.kernel, &w.cfg, w.mem).unwrap();
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "sim: {} warp-instr in {:.3}s = {:.2} M warp-instr/s ({:.2} M thread-instr/s)",
        r.stats.warp_instructions,
        dt,
        r.stats.warp_instructions as f64 / dt / 1e6,
        r.stats.thread_instructions as f64 / dt / 1e6
    );

    // analysis throughput across the whole suite, via the pass manager.
    // Hash once via intake so the timed loops measure emulate/detect and
    // cache service, not repeated fingerprinting.
    let p = Pipeline::new();
    let parsed: Vec<_> = ptxasw::suite::suite()
        .iter()
        .map(|b| p.intake(generate(b)))
        .collect();
    let t1 = Instant::now();
    let mut total_terms = 0usize;
    for pa in &parsed {
        let emu = p.emulated_hashed(&pa.kernel, pa.hash).unwrap();
        total_terms += emu.result.pool.len();
        let _ = p
            .detected_hashed(&pa.kernel, pa.hash, DetectOpts::default())
            .unwrap();
    }
    let cold = t1.elapsed();
    println!(
        "analysis (cold): full 16-benchmark suite in {:.1}ms ({} terms interned)",
        cold.as_secs_f64() * 1e3,
        total_terms
    );

    // warm pass: every artifact is served from the content-addressed cache
    let before = p.stats().cache;
    let t2 = Instant::now();
    for pa in &parsed {
        let _ = p
            .detected_hashed(&pa.kernel, pa.hash, DetectOpts::default())
            .unwrap();
    }
    let warm = t2.elapsed();
    let after = p.stats().cache;
    let (hits, misses) = (
        after.hits() - before.hits(),
        after.misses() - before.misses(),
    );
    println!(
        "analysis (warm): {:.3}ms — {hits}/{} lookups served from cache",
        warm.as_secs_f64() * 1e3,
        hits + misses
    );

    // on-disk persistence: a second pipeline over the same cache
    // directory (stand-in for a fresh process) must serve the whole
    // benchmark — simulation included — from disk
    let dir = std::env::temp_dir().join(format!("ptxasw-perfprobe-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let bench = [by_name("jacobi").unwrap()];
    let cfg = PipelineConfig::default();

    let cold_p = Pipeline::new().with_disk(DiskStore::open_default(&dir).unwrap());
    let t3 = Instant::now();
    assert!(run_suite_on(&cold_p, &bench, &cfg).iter().all(|r| r.is_ok()));
    let disk_cold = t3.elapsed();

    let warm_p = Pipeline::new().with_disk(DiskStore::open_default(&dir).unwrap());
    let t4 = Instant::now();
    assert!(run_suite_on(&warm_p, &bench, &cfg).iter().all(|r| r.is_ok()));
    let disk_warm = t4.elapsed();
    let ws = warm_p.stats();
    println!(
        "disk store (jacobi): cold {:.1}ms → warm {:.1}ms \
         ({} disk hits; {} emulations, {} simulations on the warm run)",
        disk_cold.as_secs_f64() * 1e3,
        disk_warm.as_secs_f64() * 1e3,
        ws.disk.hits,
        ws.stage_count(Stage::Emulate),
        ws.stage_count(Stage::Validate),
    );
    let _ = std::fs::remove_dir_all(&dir);

    if want_stats {
        println!("{}", report::pipeline_stats(&p.stats()));
        println!("{}", report::pipeline_stats(&ws));
    }
}
