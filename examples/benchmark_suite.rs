//! Full KernelGen sweep — the paper's evaluation in one binary.
//!
//! Runs the complete pipeline over all 16 benchmarks on the coordinator's
//! thread pool and prints Table 2, Figure 2 (all four architectures), and
//! the Figure 3 stall breakdown for a chosen benchmark.
//!
//!     cargo run --release --example benchmark_suite [fig3-bench]

use ptxasw::coordinator::{report, run_suite, PipelineConfig};
use ptxasw::shuffle::Variant;
use ptxasw::suite::suite;
use std::time::Instant;

fn main() {
    let fig3_pick = std::env::args().nth(1).unwrap_or_else(|| "gaussblur".into());
    let cfg = PipelineConfig {
        variants: vec![Variant::NoLoad, Variant::NoCorner, Variant::Full],
        ..PipelineConfig::default()
    };
    let benches = suite();
    let t0 = Instant::now();
    let results = run_suite(&benches, &cfg);
    let wall = t0.elapsed();

    let ok: Vec<_> = results
        .iter()
        .map(|r| r.as_ref().expect("pipeline"))
        .collect();

    println!("=== Table 2 ===");
    println!("{}", report::table2(&ok));
    println!("=== Figure 2 (speed-up vs Original; occupancy of PTXASW) ===");
    println!("{}", report::figure2(&ok, &cfg.archs, &cfg.variants));
    if let Some(r) = ok.iter().find(|r| r.name == fig3_pick) {
        println!("=== Figure 3 (stall breakdown: {fig3_pick}) ===");
        println!("{}", report::figure3(r, &cfg.archs));
    }

    // validity audit: PTXASW must be bit-exact everywhere
    let mut valid = 0;
    let mut invalid_probes = 0;
    for r in &ok {
        for (v, o) in &r.variants {
            match (v, o.valid) {
                (Variant::Full, Some(true)) => valid += 1,
                (Variant::Full, Some(false)) => panic!("{}: PTXASW corrupted output!", r.name),
                (_, Some(false)) => invalid_probes += 1,
                _ => {}
            }
        }
    }
    println!(
        "PTXASW bit-exact on {valid}/16 benchmarks; \
         {invalid_probes} perf-probe variants invalid as expected; \
         full sweep in {wall:.2?}"
    );
}
