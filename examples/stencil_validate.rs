//! End-to-end three-layer driver (DESIGN.md "validation ladder" rung 5).
//!
//! The same Jacobi stencil runs three ways on a real small workload:
//!   (a) the AOT Pallas/JAX artifact executed by the Rust PJRT runtime
//!       (L1+L2, built once by `make artifacts`),
//!   (b) the warp simulator executing the NVHPC-shaped original PTX (L3),
//!   (c) the simulator executing the shuffle-synthesized PTX.
//! Requirements: (b) == (c) bit-exactly, and (a) ≈ (b) to float tolerance
//! (different fma association). Also reports the modelled speed-up of (c)
//! on every GPU generation — the headline metric.
//!
//!     make artifacts && cargo run --release --example stencil_validate

use ptxasw::coordinator::{run_benchmark, PipelineConfig};
use ptxasw::runtime::Runtime;
use ptxasw::shuffle::Variant;
use ptxasw::sim::run;
use ptxasw::suite::{by_name, generate, workload};
use std::time::Instant;

fn main() {
    // --- layer 1+2: PJRT executes the Pallas/JAX artifact ---
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let mut rt = match Runtime::open(&dir) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("{e}\nrun `make artifacts` first");
            std::process::exit(1);
        }
    };
    println!("PJRT platform: {}", rt.platform());
    let dims = rt.spec("jacobi").expect("jacobi artifact").args[0]
        .dims
        .clone();
    let (ny, nx) = (dims[0], dims[1]);
    println!("workload: jacobi {ny}x{nx} f32");

    let bench = by_name("jacobi").unwrap();
    let w = workload(&bench, nx, ny, 1, 31337);
    let input = w.mem.read_f32s(w.cfg.params[1], nx * ny).unwrap();

    let t0 = Instant::now();
    let pjrt_out = rt.run_f32("jacobi", &[&input]).expect("pjrt exec");
    let t_pjrt = t0.elapsed();

    // --- layer 3: simulate original and synthesized PTX ---
    let kernel = generate(&bench);
    let t1 = Instant::now();
    let base = run(&kernel, &w.cfg, w.mem).expect("sim original");
    let t_sim = t1.elapsed();
    let sim_out = base.mem.read_f32s(w.out_ptr, w.out_len).unwrap();

    let cfg = PipelineConfig::default();
    let result = run_benchmark(&bench, &cfg).expect("pipeline");
    let full = result
        .variants
        .iter()
        .find(|(v, _)| *v == Variant::Full)
        .unwrap();
    assert_eq!(full.1.valid, Some(true), "synthesized PTX must be bit-exact");

    // --- cross-layer numerics ---
    let mut max_err = 0f32;
    for (a, b) in pjrt_out.iter().zip(&sim_out) {
        max_err = max_err.max((a - b).abs());
    }
    assert!(max_err < 1e-5, "PJRT vs simulator: max err {max_err}");
    println!(
        "cross-check: PJRT(Pallas) vs simulated PTX max abs err = {max_err:.2e}  ✓"
    );
    println!(
        "timings: PJRT exec {t_pjrt:?}; warp-sim ({} warp-instrs) {t_sim:?}",
        base.stats.warp_instructions
    );

    // --- headline metric: modelled speed-up per architecture ---
    println!("\nmodelled PTXASW speed-up (jacobi, {} shuffles):", result.detection.shuffle_count());
    for (ai, arch) in cfg.archs.iter().enumerate() {
        let s = result.speedup(Variant::Full, ai).unwrap();
        let occ = full.1.reports[ai].occupancy;
        println!("  {:<8} {:>6.3}x  (occupancy {:.2})", arch.name, s, occ);
    }
    println!("\nstencil_validate OK — all three layers agree");
}
