//! Quickstart: the paper's Jacobi example end to end, in-process.
//!
//! Generates NVHPC-shaped PTX for the Jacobi kernel (Listing 4), runs the
//! symbolic emulator, prints the memory trace (Listing 5), detects shuffle
//! candidates, synthesizes `shfl.sync` code (Listing 6), and verifies on
//! the warp simulator that the rewritten kernel is bit-exact.
//!
//!     cargo run --release --example quickstart

use ptxasw::emu::emulate;
use ptxasw::ptx::print_kernel;
use ptxasw::shuffle::{detect, synthesize, DetectOpts, Variant};
use ptxasw::sim::run;
use ptxasw::suite::{by_name, generate, workload};

fn main() {
    let bench = by_name("jacobi").expect("jacobi benchmark");
    let kernel = generate(&bench);
    println!("=== Generated PTX (the NVHPC stand-in) ===");
    println!("{}", print_kernel(&kernel));

    // Symbolic emulation (paper §4)
    let res = emulate(&kernel).expect("emulation");
    println!(
        "=== Emulation: {} flows, {} steps, {} loads traced ===",
        res.stats.flows_finished, res.stats.steps, res.stats.loads
    );
    let hot = res
        .flows
        .iter()
        .max_by_key(|f| f.trace.loads.len())
        .unwrap();
    println!("--- global-memory trace of the hottest flow (Listing 5) ---");
    for l in hot.trace.loads.iter().take(12) {
        println!(
            "  stmt {:>3} seg {:>2} {:>5}: addr term #{}",
            l.stmt,
            l.segment,
            if l.nc { "nc" } else { "plain" },
            l.addr.0
        );
    }

    // Detection (§5.1)
    let det = detect(&kernel, &res, DetectOpts::default());
    println!(
        "\n=== Detection: {}/{} loads covered, avg delta {:?} ===",
        det.shuffle_count(),
        det.total_global_loads,
        det.avg_delta()
    );
    for c in &det.chosen {
        let dir = if c.delta < 0 { "up" } else { "down" };
        println!(
            "  load@{:>3} <- load@{:>3}  shfl.sync.{dir} |N|={}",
            c.dst_stmt,
            c.src_stmt,
            c.delta.abs()
        );
    }

    // Synthesis (§5.2)
    let synth = synthesize(&kernel, &det, Variant::Full);
    println!("\n=== Synthesized PTX (Listing 6 structure) ===");
    println!("{}", print_kernel(&synth));

    // Validation on the warp simulator
    let w = workload(&bench, 96, 8, 1, 2024);
    let base = run(&kernel, &w.cfg, w.mem).expect("baseline sim");
    let w2 = workload(&bench, 96, 8, 1, 2024);
    let shfl = run(&synth, &w2.cfg, w2.mem).expect("synth sim");
    let a = base.mem.read_f32s(w.out_ptr, w.out_len).unwrap();
    let b = shfl.mem.read_f32s(w.out_ptr, w.out_len).unwrap();
    assert_eq!(a, b, "synthesized kernel must be bit-exact");
    println!(
        "=== Simulator check: {} outputs bit-exact; {} shuffles executed ===",
        a.len(),
        shfl.stats.shfls
    );
}
