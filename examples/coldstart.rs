//! `coldstart` — cold-vs-warm emulation timing probe (BENCH_4).
//!
//! Measures what the `emulated/` + `decoded/` disk artifacts buy a fresh
//! process: every suite kernel is symbolically emulated and decoded once
//! into a cold cache directory, then a second pipeline (the stand-in for
//! a fresh process) resolves the same artifacts from disk. The warm pass
//! must perform **zero** emulations and **zero** decodes — the run fails
//! otherwise — and `BENCH_4.json` records the wall-time ratio.
//!
//!     cargo run --release --example coldstart -- [--out FILE] [--repeat N]

use ptxasw::cli::Args;
use ptxasw::pipeline::{DiskStore, Pipeline, Stage, DEFAULT_MAX_BYTES};
use ptxasw::suite;
use std::fmt::Write as _;
use std::time::Instant;

fn main() {
    let args = Args::parse(std::env::args().skip(1)).unwrap_or_default();
    let out_path = args.opt("out").unwrap_or("BENCH_4.json").to_string();
    let repeat = args.opt_usize("repeat", 3).unwrap_or(3).max(1);

    let dir = std::env::temp_dir().join(format!("ptxasw-coldstart-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);

    let benches = suite::suite();
    let kernels: Vec<_> = benches.iter().map(suite::generate).collect();

    // cold: emulate + decode everything once, persisting as we go
    let p_cold = Pipeline::new().with_disk(DiskStore::open(&dir, DEFAULT_MAX_BYTES).unwrap());
    let t0 = Instant::now();
    let mut unique = std::collections::HashSet::new();
    for k in &kernels {
        let parsed = p_cold.intake(k.clone());
        unique.insert(parsed.hash);
        p_cold
            .emulated_hashed(&parsed.kernel, parsed.hash)
            .expect("cold emulation");
        p_cold.decoded(&parsed.kernel, parsed.hash).expect("cold decode");
    }
    let cold_s = t0.elapsed().as_secs_f64();
    let cold_stats = p_cold.stats();
    assert_eq!(
        cold_stats.cache.emulate_misses as usize,
        unique.len(),
        "cold pass computes every unique emulation"
    );

    // warm: fresh pipeline + fresh store over the same directory — best
    // of N to keep the tiny numbers stable
    let mut warm_s = f64::INFINITY;
    let mut last = None;
    for _ in 0..repeat {
        let p_warm =
            Pipeline::new().with_disk(DiskStore::open(&dir, DEFAULT_MAX_BYTES).unwrap());
        let t0 = Instant::now();
        for k in &kernels {
            let parsed = p_warm.intake(k.clone());
            p_warm
                .emulated_hashed(&parsed.kernel, parsed.hash)
                .expect("warm load");
            p_warm.decoded(&parsed.kernel, parsed.hash).expect("warm decode load");
        }
        warm_s = warm_s.min(t0.elapsed().as_secs_f64());
        last = Some(p_warm.stats());
    }
    let warm_stats = last.unwrap();

    // correctness gate: the warm pass must never emulate or decode
    assert_eq!(warm_stats.stage_count(Stage::Emulate), 0, "warm pass re-emulated");
    assert_eq!(warm_stats.stage_count(Stage::Decode), 0, "warm pass re-decoded");
    assert_eq!(
        warm_stats.cache.emulate_disk_hits as usize,
        unique.len(),
        "every emulation must come from disk"
    );
    assert_eq!(
        warm_stats.cache.decode_disk_hits as usize,
        unique.len(),
        "every decoded kernel must come from disk"
    );

    let speedup = cold_s / warm_s.max(1e-9);
    let mut j = String::new();
    writeln!(j, "{{").unwrap();
    writeln!(j, "  \"bench\": \"coldstart\",").unwrap();
    writeln!(j, "  \"kernels\": {},", kernels.len()).unwrap();
    writeln!(j, "  \"cold_emulate_decode_s\": {cold_s:.6},").unwrap();
    writeln!(j, "  \"warm_disk_load_s\": {warm_s:.6},").unwrap();
    writeln!(j, "  \"cold_over_warm\": {speedup:.3},").unwrap();
    writeln!(
        j,
        "  \"emulate_disk_hits\": {},",
        warm_stats.cache.emulate_disk_hits
    )
    .unwrap();
    writeln!(
        j,
        "  \"decode_disk_hits\": {}",
        warm_stats.cache.decode_disk_hits
    )
    .unwrap();
    writeln!(j, "}}").unwrap();

    std::fs::write(&out_path, &j).expect("write BENCH_4.json");
    eprintln!(
        "coldstart: {} kernels — cold {:.3}s, warm {:.3}s ({speedup:.2}x) -> {out_path}",
        kernels.len(),
        cold_s,
        warm_s
    );
    let _ = std::fs::remove_dir_all(&dir);
}
