//! §8 guidelines as a tool: given a kernel and a target GPU generation,
//! report whether shuffle synthesis is advisable and why — the paper's
//! per-architecture analysis (execution-dependency vs texture-stall vs
//! cache-efficiency trade-offs) distilled into a decision procedure.
//!
//!     cargo run --release --example shuffle_advisor [bench ...]

use ptxasw::coordinator::{run_benchmark, PipelineConfig};
use ptxasw::perf::Stall;
use ptxasw::shuffle::Variant;
use ptxasw::suite::{by_name, suite};

fn main() {
    let names: Vec<String> = std::env::args().skip(1).collect();
    let benches = if names.is_empty() {
        suite()
    } else {
        names
            .iter()
            .map(|n| by_name(n).unwrap_or_else(|| panic!("unknown benchmark `{n}`")))
            .collect()
    };

    let cfg = PipelineConfig::default();
    println!(
        "{:<12} {:<8} {:>8} {:>7} {:>9}  advice",
        "benchmark", "arch", "speedup", "Δocc", "tex-stall"
    );
    for b in benches {
        let r = match run_benchmark(&b, &cfg) {
            Ok(r) => r,
            Err(e) => {
                eprintln!("{e}");
                continue;
            }
        };
        if r.detection.shuffle_count() == 0 {
            println!("{:<12} {:<8} {:>8} {:>7} {:>9}  no shuffle opportunities", r.name, "-", "-", "-", "-");
            continue;
        }
        for (ai, arch) in cfg.archs.iter().enumerate() {
            let speedup = r.speedup(Variant::Full, ai).unwrap();
            let base = &r.baseline.reports[ai];
            let full = &r
                .variants
                .iter()
                .find(|(v, _)| *v == Variant::Full)
                .unwrap()
                .1
                .reports[ai];
            let docc = full.occupancy - base.occupancy;
            let tex = base
                .stall_fractions()
                .iter()
                .find(|(n, _)| *n == Stall::Texture.name())
                .map(|(_, f)| *f)
                .unwrap_or(0.0);
            // §8 decision procedure
            let advice = if speedup >= 1.05 && tex > 0.10 {
                "APPLY — texture stalls replaced by shuffles"
            } else if speedup >= 1.05 {
                "APPLY — memory traffic reduction wins"
            } else if speedup >= 0.98 {
                "NEUTRAL — within noise; prefer original for simplicity"
            } else if arch.name == "Volta" {
                "AVOID — cache already hides loads; corner cases cost occupancy"
            } else if docc < -0.05 {
                "AVOID — register pressure drops occupancy"
            } else {
                "AVOID — corner-case overhead exceeds latency savings"
            };
            println!(
                "{:<12} {:<8} {:>7.3}x {:>+7.2} {:>8.1}%  {advice}",
                r.name,
                arch.name,
                speedup,
                docc,
                tex * 100.0
            );
        }
    }
}
