//! `servebench` — serve-mode throughput + concurrency + resume +
//! tracing-cost probe (BENCH_10).
//!
//! Drives in-process [`ServeSession`]s (the same object `ptxasw serve`
//! wraps around stdin or a socket) through the full suite as JSON-lines
//! request batches and records `BENCH_10.json`:
//!
//! 1. **cold vs warm throughput** — the batch against a fresh cache dir,
//!    then again from a fresh session over the warmed dir (the stand-in
//!    for a second process); the warm pass must report disk hits;
//! 2. **requests/sec vs threads** — the warm batch through
//!    [`serve_pooled`] at `--serve-threads 1` and `4`. The run
//!    **hard-fails** unless the pooled output is byte-identical to the
//!    serial run and the 4-thread warm throughput is strictly above
//!    serial;
//! 3. **widened-retry resume** — a flow-blowup kernel that trips the
//!    tight budget and fits the wide one, once over a store (the wide
//!    retry must *resume* the tight run's persisted frontier image) and
//!    once without (the cold-retry baseline latency column);
//! 4. **poisoned batch** — parse-error, flow-blowup and panicking
//!    requests interleaved with healthy kernels. The run **hard-fails**
//!    unless every healthy kernel's rewritten PTX is bit-exact with a
//!    clean serial run and every pathological request produced its typed
//!    error record (`ParseError` / `EmuError` / `Panicked`);
//! 5. **tracing cost** — the disabled-tracer cost per span site is
//!    measured directly and projected onto a warm request's span count;
//!    the run **hard-fails** if that overhead exceeds 2% of a warm
//!    request, if a `"trace": true` request is not bit-exact with its
//!    untraced twin, or if `--trace-sample` perturbs response bytes;
//! 6. **index audit** — after all of the above churned the store, its
//!    sharded index must agree with a full `verify` directory walk.
//!
//!     cargo run --release --example servebench -- [--out FILE]

use ptxasw::cli::Args;
use ptxasw::obs::Tracer;
use ptxasw::pipeline::{
    serve_pooled, DiskStore, Pipeline, ServeOpts, ServeSession, DEFAULT_MAX_BYTES,
};
use ptxasw::ptx::{ast::Module, print_module};
use ptxasw::shuffle::{DetectOpts, ElimOpts, Variant};
use ptxasw::suite;
use ptxasw::util::Json;
use std::fmt::Write as _;
use std::sync::Arc;
use std::time::Instant;

/// One flow-explosion kernel: `bits` tid-dependent branches whose sides
/// leave distinct accumulator values, so 2^bits distinct environments
/// defeat memoization. 13 bits = 8192 flows — over even the default wide
/// budget (4096): a guaranteed typed `EmuError` after the widen retry.
/// 10 bits = 1024 flows — over tight (512), under wide: the resume path.
fn blowup_ptx(bits: usize) -> String {
    let mut body = String::new();
    for i in 0..bits {
        body.push_str(&format!(
            "and.b32 %r10, %r1, {};\nsetp.eq.s32 %p{p}, %r10, 0;\n\
             @%p{p} bra $S{i};\nadd.s32 %r2, %r2, {};\n$S{i}:\n",
            1u32 << i,
            100 + i,
            p = i + 1,
        ));
    }
    format!(
        ".version 7.6\n.target sm_70\n.address_size 64\n\
         .visible .entry forky(.param .u64 out){{\n\
         .reg .pred %p<{}>; .reg .b32 %r<12>; .reg .b64 %rd<3>;\n\
         ld.param.u64 %rd1, [out];\ncvta.to.global.u64 %rd2, %rd1;\n\
         mov.u32 %r1, %tid.x;\nmov.u32 %r2, 0;\n{body}\
         st.global.u32 [%rd2], %r2;\nret;\n}}\n",
        bits + 2,
    )
}

fn asm_req(id: u64, ptx: &str) -> String {
    Json::obj(vec![
        ("id", Json::num(id as f64)),
        ("cmd", Json::str("asm")),
        ("ptx", Json::str(ptx)),
    ])
    .render()
}

/// Serve a batch through the pooled entry point (`threads == 1` is the
/// serial loop) and return the raw response bytes plus parsed lines.
fn run_pooled(
    session: &mut ServeSession,
    lines: &[String],
    threads: usize,
) -> (String, Vec<Json>) {
    let mut out = Vec::new();
    serve_pooled(
        session,
        std::io::Cursor::new(lines.join("\n")),
        &mut out,
        threads,
    )
    .expect("in-memory serve IO");
    let raw = String::from_utf8(out).unwrap();
    let parsed = raw
        .lines()
        .map(|l| Json::parse(l).expect("valid response line"))
        .collect();
    (raw, parsed)
}

fn run_batch(session: &mut ServeSession, lines: &[String]) -> Vec<Json> {
    run_pooled(session, lines, 1).1
}

/// The serial ground truth: what `ptxasw asm` (defaults) prints for `src`.
fn expected_asm(src: &str) -> String {
    let p = Pipeline::new();
    let mut module = ptxasw::ptx::parse(src).unwrap();
    let opts = DetectOpts {
        max_abs_delta: 31,
        ..DetectOpts::default()
    };
    let elim = ElimOpts {
        enabled: true,
        block: 32,
    };
    for k in module.kernels.iter_mut() {
        let parsed = p.intake(k.clone());
        let s = p
            .synthesized_hashed(&parsed.kernel, parsed.hash, opts, Variant::Full, elim)
            .unwrap();
        *k = (*s.kernel).clone();
    }
    print_module(&module)
}

/// Best-of-3 cost of one *disabled* span site: `begin()` + `span()` with
/// a lazy arg thunk, which the contract says must cost one relaxed atomic
/// load each and never evaluate the thunk.
fn disabled_ns_per_span() -> f64 {
    let t = Tracer::disabled();
    const ITERS: u64 = 2_000_000;
    let mut best = f64::INFINITY;
    for _ in 0..3 {
        let t0 = Instant::now();
        for i in 0..ITERS {
            let s = t.begin();
            std::hint::black_box(i);
            t.span("bench", "bench.noop", s, Vec::new);
        }
        best = best.min(t0.elapsed().as_nanos() as f64 / ITERS as f64);
    }
    assert!(t.is_empty(), "a disabled tracer must record nothing");
    best
}

fn main() {
    let args = Args::parse(std::env::args().skip(1)).unwrap_or_default();
    let out_path = args.opt("out").unwrap_or("BENCH_10.json").to_string();

    let dir = std::env::temp_dir().join(format!("ptxasw-servebench-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);

    // every suite kernel as PTX text — the request corpus
    let sources: Vec<String> = suite::suite()
        .iter()
        .map(|b| print_module(&Module::single(suite::generate(b))))
        .collect();
    let batch: Vec<String> = sources
        .iter()
        .enumerate()
        .map(|(i, s)| asm_req(i as u64, s))
        .collect();

    // -- 1. cold vs warm batch throughput ----------------------------------
    let store = Arc::new(DiskStore::open(&dir, DEFAULT_MAX_BYTES).unwrap());
    let mut cold = ServeSession::new(ServeOpts::default(), Some(store));
    let t0 = Instant::now();
    let cold_rs = run_batch(&mut cold, &batch);
    let cold_s = t0.elapsed().as_secs_f64();
    assert!(
        cold_rs.iter().all(|r| r.get("ok").unwrap().as_bool() == Some(true)),
        "every suite kernel must serve cleanly"
    );

    let store2 = Arc::new(DiskStore::open(&dir, DEFAULT_MAX_BYTES).unwrap());
    let mut warm = ServeSession::new(ServeOpts::default(), Some(store2));
    let t0 = Instant::now();
    let warm_rs = run_batch(&mut warm, &batch);
    let warm_s = t0.elapsed().as_secs_f64();
    let warm_hits = warm.pipeline().stats().disk.hits;
    assert!(warm_hits > 0, "the warm session must be served from disk");
    for (c, w) in cold_rs.iter().zip(&warm_rs) {
        assert_eq!(
            c.get("ptx").unwrap().as_str(),
            w.get("ptx").unwrap().as_str(),
            "warm response diverged from cold"
        );
    }

    // -- 2. requests/sec vs --serve-threads --------------------------------
    // the warm batch, repeated with distinct ids so the multiplexed run
    // has enough work to overlap; serial (threads=1) output is the
    // byte-exactness ground truth for the pooled runs
    let big: Vec<String> = (0..3)
        .flat_map(|rep| {
            sources
                .iter()
                .enumerate()
                .map(move |(i, s)| asm_req((rep * 1000 + i) as u64, s))
                .collect::<Vec<_>>()
        })
        .collect();
    let mut rps = Vec::new();
    let mut serial_bytes = String::new();
    for &threads in &[1usize, 4] {
        let st = Arc::new(DiskStore::open(&dir, DEFAULT_MAX_BYTES).unwrap());
        let mut s = ServeSession::new(ServeOpts::default(), Some(st));
        let t0 = Instant::now();
        let (raw, rs) = run_pooled(&mut s, &big, threads);
        let dt = t0.elapsed().as_secs_f64();
        assert!(
            rs.iter().all(|r| r.get("ok").unwrap().as_bool() == Some(true)),
            "warm pooled batch must serve cleanly at {threads} thread(s)"
        );
        if threads == 1 {
            serial_bytes = raw;
        } else {
            assert_eq!(
                raw, serial_bytes,
                "pooled responses at {threads} threads must be byte-identical \
                 to the serial run"
            );
        }
        rps.push((threads, big.len() as f64 / dt.max(1e-9), dt));
    }
    let (serial_rps, pooled_rps) = (rps[0].1, rps[1].1);
    assert!(
        pooled_rps > serial_rps,
        "4-thread warm throughput ({pooled_rps:.1} req/s) must be strictly \
         above serial ({serial_rps:.1} req/s)"
    );

    // -- 3. widened-retry latency: frontier resume vs cold re-emulation ----
    let resume_src = blowup_ptx(10); // 1024 flows: over tight, under wide
    let rdir = std::env::temp_dir().join(format!("ptxasw-servebench-resume-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&rdir);
    let rstore = Arc::new(DiskStore::open(&rdir, DEFAULT_MAX_BYTES).unwrap());
    let mut with_store = ServeSession::new(ServeOpts::default(), Some(rstore));
    let t0 = Instant::now();
    let rr = run_batch(&mut with_store, &[asm_req(0, &resume_src)]);
    let widened_resume_s = t0.elapsed().as_secs_f64();
    assert_eq!(rr[0].get("widened").and_then(Json::as_bool), Some(true));
    let frontier_resumes = with_store.pipeline().stats().frontier_resumes;
    assert_eq!(
        frontier_resumes, 1,
        "the wide retry over a store must resume the tight frontier image"
    );

    let mut no_store = ServeSession::new(ServeOpts::default(), None);
    let t0 = Instant::now();
    let cr = run_batch(&mut no_store, &[asm_req(0, &resume_src)]);
    let widened_cold_s = t0.elapsed().as_secs_f64();
    assert_eq!(cr[0].get("widened").and_then(Json::as_bool), Some(true));
    assert_eq!(
        cr[0].get("ptx").and_then(|p| p.as_str()),
        rr[0].get("ptx").and_then(|p| p.as_str()),
        "resumed retry must produce the cold retry's exact PTX"
    );
    let _ = std::fs::remove_dir_all(&rdir);

    // -- 4. poisoned batch --------------------------------------------------
    let healthy: Vec<&String> = sources.iter().take(4).collect();
    let expect: Vec<String> = healthy.iter().map(|s| expected_asm(s)).collect();
    let blow = blowup_ptx(13);
    let lines = vec![
        asm_req(0, healthy[0]),
        r#"{"id":100,"cmd":"asm","ptx":"this is not ptx at all"}"#.to_string(),
        asm_req(1, healthy[1]),
        asm_req(200, &blow),
        asm_req(2, healthy[2]),
        r#"{"id":300,"cmd":"__panic"}"#.to_string(),
        asm_req(3, healthy[3]),
    ];
    let store3 = Arc::new(DiskStore::open(&dir, DEFAULT_MAX_BYTES).unwrap());
    let mut poisoned = ServeSession::new(
        ServeOpts {
            allow_test_faults: true,
            ..ServeOpts::default()
        },
        Some(store3),
    );
    let rs = run_batch(&mut poisoned, &lines);
    assert_eq!(rs.len(), lines.len(), "one response per request, always");

    let kind_of = |r: &Json| {
        r.get("error")
            .and_then(|e| e.get("kind"))
            .and_then(|k| k.as_str())
            .map(str::to_string)
    };
    // the hard gate: pathological requests degrade to typed records...
    assert_eq!(kind_of(&rs[1]).as_deref(), Some("ParseError"), "{:?}", rs[1]);
    assert_eq!(kind_of(&rs[3]).as_deref(), Some("EmuError"), "{:?}", rs[3]);
    assert_eq!(kind_of(&rs[5]).as_deref(), Some("Panicked"), "{:?}", rs[5]);
    // ...while every healthy kernel — before and after the panic — is
    // bit-exact with the clean serial run
    for (hi, ri) in [(0usize, 0usize), (1, 2), (2, 4), (3, 6)] {
        assert_eq!(
            rs[ri].get("ptx").and_then(|p| p.as_str()),
            Some(expect[hi].as_str()),
            "healthy kernel {hi} diverged from the serial run in the poisoned batch"
        );
    }
    let pstats = poisoned.stats();
    assert_eq!(pstats.panicked, 1);
    assert_eq!(pstats.errors, 3);
    assert_eq!(pstats.ok, 4);

    // and the same poison under 4-way multiplexing stays byte-identical
    let store3b = Arc::new(DiskStore::open(&dir, DEFAULT_MAX_BYTES).unwrap());
    let mut poisoned_pooled = ServeSession::new(
        ServeOpts {
            allow_test_faults: true,
            ..ServeOpts::default()
        },
        Some(store3b),
    );
    let store3c = Arc::new(DiskStore::open(&dir, DEFAULT_MAX_BYTES).unwrap());
    let mut poisoned_serial = ServeSession::new(
        ServeOpts {
            allow_test_faults: true,
            ..ServeOpts::default()
        },
        Some(store3c),
    );
    let (praw_serial, _) = run_pooled(&mut poisoned_serial, &lines, 1);
    let (praw_pooled, _) = run_pooled(&mut poisoned_pooled, &lines, 4);
    assert_eq!(
        praw_pooled, praw_serial,
        "poisoned pooled batch must be byte-identical to the serial run"
    );

    // -- 5. tracing cost ----------------------------------------------------
    // (a) a traced request over the warmed dir is bit-exact with its
    // untraced twin and reports its span events + trace id
    let store4 = Arc::new(DiskStore::open(&dir, DEFAULT_MAX_BYTES).unwrap());
    let mut traced = ServeSession::new(ServeOpts::default(), Some(store4));
    let treq = Json::obj(vec![
        ("id", Json::num(0.0)),
        ("cmd", Json::str("asm")),
        ("ptx", Json::str(sources[0].as_str())),
        ("trace", Json::Bool(true)),
    ])
    .render();
    let trs = run_batch(&mut traced, &[treq]);
    assert_eq!(
        trs[0].get("ptx").and_then(|p| p.as_str()),
        warm_rs[0].get("ptx").and_then(|p| p.as_str()),
        "a traced request must be bit-exact with its untraced twin"
    );
    let spans_per_req = trs[0]
        .get("trace")
        .and_then(|t| t.as_arr())
        .map(|a| a.len())
        .expect("traced response carries its span events");
    assert!(spans_per_req >= 1, "at least the serve.request span");

    // (b) span sampling records into the ring but never touches the wire
    let store5 = Arc::new(DiskStore::open(&dir, DEFAULT_MAX_BYTES).unwrap());
    let mut sampled = ServeSession::new(
        ServeOpts {
            trace_sample: 1,
            ..ServeOpts::default()
        },
        Some(store5),
    );
    let srs = run_batch(&mut sampled, &[asm_req(0, &sources[0])]);
    assert!(
        srs[0].get("trace").is_none() && srs[0].get("trace_id").is_none(),
        "--trace-sample must not attach spans to responses"
    );
    assert_eq!(
        srs[0].get("ptx").and_then(|p| p.as_str()),
        warm_rs[0].get("ptx").and_then(|p| p.as_str()),
        "a sampled request must be bit-exact with an unsampled one"
    );
    assert!(
        !sampled.tracer().is_empty(),
        "the sampled request's spans must land in the session ring"
    );

    // (c) the disabled-tracer overhead projected onto a warm request must
    // stay under 2% — the hard regression gate for the span plumbing
    let disabled_ns = disabled_ns_per_span();
    let warm_req_ns = warm_s.max(1e-9) * 1e9 / batch.len() as f64;
    let traced_overhead_pct = spans_per_req as f64 * disabled_ns / warm_req_ns * 100.0;
    assert!(
        traced_overhead_pct < 2.0,
        "tracing-disabled overhead {traced_overhead_pct:.4}% of a warm request \
         ({spans_per_req} spans x {disabled_ns:.1}ns vs {warm_req_ns:.0}ns) breaches the 2% gate"
    );

    // -- 6. index audit ------------------------------------------------------
    // after every session above churned the store, the O(changed) sharded
    // index must still agree with the ground truth of a full verify walk
    let audit = DiskStore::open(&dir, DEFAULT_MAX_BYTES).unwrap();
    let check = audit.verify(false);
    assert_eq!(check.bad, 0, "no artifact may decode-fail: {:?}", check.bad_paths);
    assert!(
        check.index_mismatch.is_empty(),
        "sharded index disagrees with the verify scan: {:?}",
        check.index_mismatch
    );

    // -- report -------------------------------------------------------------
    let n = batch.len() as f64;
    let mut j = String::new();
    writeln!(j, "{{").unwrap();
    writeln!(j, "  \"bench\": \"servebench\",").unwrap();
    writeln!(j, "  \"kernels\": {},", batch.len()).unwrap();
    writeln!(j, "  \"cold_batch_s\": {cold_s:.6},").unwrap();
    writeln!(j, "  \"warm_batch_s\": {warm_s:.6},").unwrap();
    writeln!(j, "  \"cold_req_per_s\": {:.2},", n / cold_s.max(1e-9)).unwrap();
    writeln!(j, "  \"warm_req_per_s\": {:.2},", n / warm_s.max(1e-9)).unwrap();
    writeln!(j, "  \"warm_disk_hits\": {warm_hits},").unwrap();
    writeln!(j, "  \"threads\": [").unwrap();
    for (i, (t, r, dt)) in rps.iter().enumerate() {
        let comma = if i + 1 < rps.len() { "," } else { "" };
        writeln!(
            j,
            "    {{\"serve_threads\": {t}, \"warm_req_per_s\": {r:.2}, \
             \"warm_batch_s\": {dt:.6}}}{comma}"
        )
        .unwrap();
    }
    writeln!(j, "  ],").unwrap();
    writeln!(j, "  \"pooled_speedup\": {:.3},", pooled_rps / serial_rps).unwrap();
    writeln!(j, "  \"pooled_bit_exact\": true,").unwrap();
    writeln!(j, "  \"widened_retry\": {{").unwrap();
    writeln!(j, "    \"resume_s\": {widened_resume_s:.6},").unwrap();
    writeln!(j, "    \"cold_s\": {widened_cold_s:.6},").unwrap();
    writeln!(j, "    \"frontier_resumes\": {frontier_resumes}").unwrap();
    writeln!(j, "  }},").unwrap();
    writeln!(j, "  \"poisoned\": {{").unwrap();
    writeln!(j, "    \"requests\": {},", pstats.requests).unwrap();
    writeln!(j, "    \"ok\": {},", pstats.ok).unwrap();
    writeln!(j, "    \"errors\": {},", pstats.errors).unwrap();
    writeln!(j, "    \"panicked\": {},", pstats.panicked).unwrap();
    writeln!(j, "    \"widened\": {},", pstats.widened).unwrap();
    writeln!(j, "    \"healthy_bit_exact\": true").unwrap();
    writeln!(j, "  }},").unwrap();
    writeln!(j, "  \"tracing\": {{").unwrap();
    writeln!(j, "    \"disabled_ns_per_span\": {disabled_ns:.3},").unwrap();
    writeln!(j, "    \"spans_per_warm_request\": {spans_per_req},").unwrap();
    writeln!(j, "    \"warm_request_ns\": {warm_req_ns:.0},").unwrap();
    writeln!(j, "    \"traced_overhead_pct\": {traced_overhead_pct:.5},").unwrap();
    writeln!(j, "    \"traced_matches_untraced\": true").unwrap();
    writeln!(j, "  }},").unwrap();
    writeln!(j, "  \"index_agrees\": true").unwrap();
    writeln!(j, "}}").unwrap();

    std::fs::write(&out_path, &j).expect("write BENCH_10.json");
    eprintln!(
        "servebench: {} kernels — cold {:.3}s, warm {:.3}s ({} disk hits); \
         pooled x{:.2} at 4 threads, bit-exact; widened retry {:.3}s resumed \
         vs {:.3}s cold; poisoned batch: {} ok / {} typed errors; tracing: \
         {:.1}ns/span disabled, {:.4}% of a warm request; index agrees -> {out_path}",
        batch.len(),
        cold_s,
        warm_s,
        warm_hits,
        pooled_rps / serial_rps,
        widened_resume_s,
        widened_cold_s,
        pstats.ok,
        pstats.errors,
        disabled_ns,
        traced_overhead_pct,
    );
    let _ = std::fs::remove_dir_all(&dir);
}
