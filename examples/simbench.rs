//! `simbench` — simulator throughput benchmark (warp-steps/sec).
//!
//! Runs a benchmark family through the three simulator configurations —
//! the reference AST walker, the decoded micro-op engine serial, and the
//! decoded engine with one worker per CPU — measuring the best-of-N wall
//! time each, and emits a JSON report with per-benchmark numbers and
//! aggregates. The headline metric is warp-level instruction issues per
//! second (`warp-steps/sec`).
//!
//! Families: `--family table2` (default) is the classic KernelGen suite
//! (`BENCH_3.json`, barrier-free — the cooperative scheduler degenerates
//! to the old serialized warp order here, so this doubles as its
//! no-regression gate); `--family shared` is the shared-memory/barrier
//! family opened by the cooperative scheduler (`BENCH_5.json` — every
//! run exercises real `bar.sync` suspend/resume); `--family all` runs
//! both.
//!
//! The run doubles as a correctness gate: every engine's output image is
//! compared bit-for-bit before a timing is accepted, and the shared
//! family additionally asserts barrier phases actually happened.
//!
//!     cargo run --release --example simbench -- [--family table2|shared|all]
//!                                               [--out FILE] [--repeat N]
//!                                               [--sim-threads N]

use ptxasw::cli::Args;
use ptxasw::coordinator::sim_sizes;
use ptxasw::sim::{decode, run_decoded, run_reference, SimResult};
use ptxasw::suite;
use std::fmt::Write as _;
use std::time::Instant;

struct Row {
    name: &'static str,
    warp_steps: u64,
    blocks: u32,
    decode_us: f64,
    reference_s: f64,
    decoded_s: f64,
    parallel_s: f64,
}

fn best_of<T>(repeat: usize, mut f: impl FnMut() -> T) -> (f64, T) {
    let mut best = f64::INFINITY;
    let mut out = None;
    for _ in 0..repeat.max(1) {
        let t0 = Instant::now();
        let r = f();
        best = best.min(t0.elapsed().as_secs_f64());
        out = Some(r);
    }
    (best, out.unwrap())
}

fn main() {
    let args = Args::parse(std::env::args().skip(1)).unwrap_or_default();
    let family = args.opt("family").unwrap_or("table2").to_string();
    let (benches, bench_id, default_out) = match family.as_str() {
        "table2" => (suite::suite(), "BENCH_3", "BENCH_3.json"),
        "shared" => (suite::shared_suite(), "BENCH_5", "BENCH_5.json"),
        "all" => {
            let mut v = suite::suite();
            v.extend(suite::shared_suite());
            (v, "BENCH_3+5", "BENCH_ALL.json")
        }
        other => {
            eprintln!("simbench: unknown --family `{other}` (table2|shared|all)");
            std::process::exit(2);
        }
    };
    let out_path = args.opt("out").unwrap_or(default_out).to_string();
    let repeat = args.opt_usize("repeat", 3).unwrap_or(3);
    let par_threads = args
        .opt_usize(
            "sim-threads",
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
        )
        .unwrap_or(4)
        .max(2);

    let mut rows = Vec::new();
    for b in benches {
        let (nx, ny, nz) = sim_sizes(&b);
        let w = suite::workload(&b, nx, ny, nz, 42);
        let cfg = w.cfg.clone(); // no trace: measure the pure interpreter
        let barrier_family = matches!(
            b.pattern,
            suite::Pattern::TiledReduce { .. } | suite::Pattern::SharedStencil { .. }
        );

        let t0 = Instant::now();
        let dk = decode(&w.kernel).expect("decode");
        let decode_us = t0.elapsed().as_secs_f64() * 1e6;

        let mut c1 = cfg.clone();
        c1.sim_threads = 1;
        let mut cn = cfg.clone();
        cn.sim_threads = par_threads;
        let (reference_s, r_ref) =
            best_of(repeat, || run_reference(&w.kernel, &cfg, w.mem.clone()).expect("reference"));
        let (decoded_s, r_dec) =
            best_of(repeat, || run_decoded(&dk, &c1, w.mem.clone()).expect("decoded"));
        let (parallel_s, r_par) =
            best_of(repeat, || run_decoded(&dk, &cn, w.mem.clone()).expect("parallel"));

        check_agree(b.name, &r_ref, &r_dec, "decoded");
        check_agree(b.name, &r_ref, &r_par, "parallel");
        if barrier_family {
            assert!(
                r_ref.stats.barrier_phases > 0,
                "{}: the barrier family must cross barrier phases",
                b.name
            );
        }
        let out = r_ref.mem.read_f32s(w.out_ptr, w.out_len).expect("read output");
        assert!(
            out.iter().zip(&w.expected).all(|(a, e)| a.to_bits() == e.to_bits()),
            "{}: output diverged from the CPU reference",
            b.name
        );

        rows.push(Row {
            name: b.name,
            warp_steps: r_ref.stats.warp_instructions,
            blocks: cfg.grid.0 * cfg.grid.1 * cfg.grid.2,
            decode_us,
            reference_s,
            decoded_s,
            parallel_s,
        });
    }

    let total_steps: u64 = rows.iter().map(|r| r.warp_steps).sum();
    let total_ref: f64 = rows.iter().map(|r| r.reference_s).sum();
    let total_dec: f64 = rows.iter().map(|r| r.decoded_s).sum();
    let total_par: f64 = rows.iter().map(|r| r.parallel_s).sum();
    let geomean = |f: &dyn Fn(&Row) -> f64| -> f64 {
        (rows.iter().map(|r| f(r).ln()).sum::<f64>() / rows.len() as f64).exp()
    };
    let gm_dec = geomean(&|r| r.reference_s / r.decoded_s);
    let gm_par = geomean(&|r| r.reference_s / r.parallel_s);

    let mut json = String::new();
    writeln!(json, "{{").unwrap();
    writeln!(json, "  \"bench_id\": \"{bench_id}\",").unwrap();
    writeln!(json, "  \"family\": \"{family}\",").unwrap();
    writeln!(json, "  \"unit\": \"warp-steps/sec\",").unwrap();
    writeln!(json, "  \"repeat\": {repeat},").unwrap();
    writeln!(json, "  \"parallel_threads\": {par_threads},").unwrap();
    writeln!(json, "  \"benchmarks\": [").unwrap();
    for (i, r) in rows.iter().enumerate() {
        let comma = if i + 1 == rows.len() { "" } else { "," };
        writeln!(
            json,
            "    {{\"name\": \"{}\", \"warp_steps\": {}, \"blocks\": {}, \
             \"decode_us\": {:.1}, \
             \"reference_s\": {:.6}, \"decoded_s\": {:.6}, \"parallel_s\": {:.6}, \
             \"reference_wsps\": {:.0}, \"decoded_wsps\": {:.0}, \"parallel_wsps\": {:.0}, \
             \"speedup_decoded\": {:.3}, \"speedup_parallel\": {:.3}}}{comma}",
            r.name,
            r.warp_steps,
            r.blocks,
            r.decode_us,
            r.reference_s,
            r.decoded_s,
            r.parallel_s,
            r.warp_steps as f64 / r.reference_s,
            r.warp_steps as f64 / r.decoded_s,
            r.warp_steps as f64 / r.parallel_s,
            r.reference_s / r.decoded_s,
            r.reference_s / r.parallel_s,
        )
        .unwrap();
    }
    writeln!(json, "  ],").unwrap();
    writeln!(json, "  \"total_warp_steps\": {total_steps},").unwrap();
    writeln!(json, "  \"reference_wsps\": {:.0},", total_steps as f64 / total_ref).unwrap();
    writeln!(json, "  \"decoded_wsps\": {:.0},", total_steps as f64 / total_dec).unwrap();
    writeln!(json, "  \"parallel_wsps\": {:.0},", total_steps as f64 / total_par).unwrap();
    writeln!(
        json,
        "  \"speedup_decoded_vs_reference\": {:.3},",
        total_ref / total_dec
    )
    .unwrap();
    writeln!(
        json,
        "  \"speedup_parallel_vs_reference\": {:.3},",
        total_ref / total_par
    )
    .unwrap();
    writeln!(json, "  \"geomean_speedup_decoded\": {gm_dec:.3},").unwrap();
    writeln!(json, "  \"geomean_speedup_parallel\": {gm_par:.3}").unwrap();
    writeln!(json, "}}").unwrap();

    std::fs::write(&out_path, &json).expect("write benchmark json");
    eprintln!(
        "simbench [{family}]: {} benchmarks, {total_steps} warp-steps",
        rows.len()
    );
    eprintln!(
        "  reference {:>12.0} warp-steps/s",
        total_steps as f64 / total_ref
    );
    eprintln!(
        "  decoded   {:>12.0} warp-steps/s  ({:.2}x, geomean {:.2}x)",
        total_steps as f64 / total_dec,
        total_ref / total_dec,
        gm_dec
    );
    eprintln!(
        "  parallel  {:>12.0} warp-steps/s  ({:.2}x, geomean {:.2}x, {par_threads} threads)",
        total_steps as f64 / total_par,
        total_ref / total_par,
        gm_par
    );
    eprintln!("  wrote {out_path}");
}

fn check_agree(name: &str, a: &SimResult, b: &SimResult, tag: &str) {
    assert_eq!(a.mem, b.mem, "{name}: {tag} memory image diverged");
    assert_eq!(a.stats, b.stats, "{name}: {tag} stats diverged");
}
