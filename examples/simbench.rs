//! `simbench` — simulator throughput benchmark (warp-steps/sec).
//!
//! Runs a benchmark family through the reference AST walker and the
//! decoded micro-op engine in every execution-path configuration —
//! scalar (per-uop per-lane, the PR 3 engine), superblock (fused
//! straight-line runs), vector (lane-vectorized ALU kernels; inert
//! without the `simd` cargo feature), fused (superblock + vector), and
//! fused with one worker per CPU — measuring the best-of-N wall time
//! each, and emits a JSON report with per-benchmark numbers and
//! aggregates. The headline metric is warp-level instruction issues per
//! second (`warp-steps/sec`).
//!
//! Families: `--family table2` (default) is the classic KernelGen suite
//! (`BENCH_3.json`, barrier-free — the cooperative scheduler degenerates
//! to the old serialized warp order here, so this doubles as its
//! no-regression gate); `--family shared` is the shared-memory/barrier
//! family opened by the cooperative scheduler (`BENCH_5.json` — every
//! run exercises real `bar.sync` suspend/resume); `--family all` runs
//! both and is the engine-matrix artifact (`BENCH_6.json`);
//! `--family elim` runs the shared family through the phase-liveness
//! dead-store/barrier elimination pass and emits pre/post perf-model
//! columns (`BENCH_7.json`). The elim run is a hard correctness gate:
//! eliminated kernels must match the reference simulation and the CPU
//! reference bit-for-bit, `tiledreduce`/`sharedstencil` must actually
//! lose their staging stores and at least one `bar.sync`, the
//! data-dependent `sharedgather` must keep both, and the post perf score
//! must be strictly better than the pre score.
//!
//! The run doubles as a correctness gate: every engine's output image is
//! compared bit-for-bit before a timing is accepted, and the shared
//! family additionally asserts barrier phases actually happened.
//!
//!     cargo run --release --example simbench -- [--family table2|shared|all]
//!                                               [--engine both|scalar|superblock|vector]
//!                                               [--out FILE] [--repeat N]
//!                                               [--sim-threads N]

use ptxasw::cli::Args;
use ptxasw::coordinator::sim_sizes;
use ptxasw::sim::{decode, run_decoded, run_reference, SimConfig, SimResult};
use ptxasw::suite;
use std::fmt::Write as _;
use std::time::Instant;

/// The decoded engine's execution-path columns: (superblocks, vector).
/// `scalar` is the PR 3 per-uop per-lane engine and the speedup baseline.
const ENGINES: [(&str, bool, bool); 4] = [
    ("scalar", false, false),
    ("superblock", true, false),
    ("vector", false, true),
    ("fused", true, true),
];

struct Row {
    name: &'static str,
    warp_steps: u64,
    blocks: u32,
    decode_us: f64,
    reference_s: f64,
    /// Serial timing per engine column, in `ENGINES` order; `None` for
    /// columns excluded by `--engine`.
    engine_s: [Option<f64>; 4],
    parallel_s: f64,
}

fn best_of<T>(repeat: usize, mut f: impl FnMut() -> T) -> (f64, T) {
    let mut best = f64::INFINITY;
    let mut out = None;
    for _ in 0..repeat.max(1) {
        let t0 = Instant::now();
        let r = f();
        best = best.min(t0.elapsed().as_secs_f64());
        out = Some(r);
    }
    (best, out.unwrap())
}

/// The tracing contract's cost gate: a *disabled* tracer's span site is
/// one relaxed atomic load (plus a dead `SpanStart`), so it must stay far
/// cheaper than an enabled site that reads the clock twice and takes the
/// ring lock. Run on every simbench invocation so an accidental always-on
/// cost in the hot pipeline plumbing shows up as a hard benchmark failure.
fn assert_disabled_tracer_is_free() {
    use ptxasw::obs::Tracer;
    const ITERS: u64 = 2_000_000;
    let ns_per_span = |t: &Tracer| -> f64 {
        let mut best = f64::INFINITY;
        for _ in 0..3 {
            let t0 = Instant::now();
            for i in 0..ITERS {
                let s = t.begin();
                std::hint::black_box(i);
                t.span("bench", "bench.noop", s, Vec::new);
            }
            best = best.min(t0.elapsed().as_nanos() as f64 / ITERS as f64);
        }
        best
    };
    let off = Tracer::disabled();
    let disabled_ns = ns_per_span(&off);
    assert!(off.is_empty(), "a disabled tracer must record nothing");
    let on = Tracer::with_capacity(1024);
    let enabled_ns = ns_per_span(&on);
    assert!(
        disabled_ns < 250.0,
        "disabled span site costs {disabled_ns:.1}ns (gate: 250ns)"
    );
    assert!(
        disabled_ns * 4.0 < enabled_ns,
        "disabled span site ({disabled_ns:.1}ns) must be far cheaper than \
         an enabled one ({enabled_ns:.1}ns)"
    );
    eprintln!("simbench: tracer span site {disabled_ns:.1}ns disabled / {enabled_ns:.1}ns enabled");
}

fn main() {
    let args = Args::parse(std::env::args().skip(1)).unwrap_or_default();
    assert_disabled_tracer_is_free();
    let family = args.opt("family").unwrap_or("table2").to_string();
    let (benches, bench_id, default_out) = match family.as_str() {
        "table2" => (suite::suite(), "BENCH_3", "BENCH_3.json"),
        "shared" => (suite::shared_suite(), "BENCH_5", "BENCH_5.json"),
        "all" => {
            let mut v = suite::suite();
            v.extend(suite::shared_suite());
            (v, "BENCH_6", "BENCH_6.json")
        }
        "elim" => (suite::shared_suite(), "BENCH_7", "BENCH_7.json"),
        other => {
            eprintln!("simbench: unknown --family `{other}` (table2|shared|all|elim)");
            std::process::exit(2);
        }
    };
    if family == "elim" {
        let out_path = args.opt("out").unwrap_or(default_out).to_string();
        let repeat = args.opt_usize("repeat", 3).unwrap_or(3);
        run_elim(benches, bench_id, &out_path, repeat);
        return;
    }
    // `--engine both` (default) measures every column; a single engine
    // name restricts the serial columns to scalar + that engine (scalar
    // is always kept: it is the baseline every speedup is quoted against)
    let engine = args.opt("engine").unwrap_or("both").to_string();
    let measured: Vec<bool> = match engine.as_str() {
        "both" | "all" | "fused" => vec![true; ENGINES.len()],
        name if ENGINES.iter().any(|(n, ..)| *n == name) => ENGINES
            .iter()
            .map(|(n, ..)| *n == name || *n == "scalar")
            .collect(),
        other => {
            eprintln!("simbench: unknown --engine `{other}` (both|scalar|superblock|vector)");
            std::process::exit(2);
        }
    };
    let out_path = args.opt("out").unwrap_or(default_out).to_string();
    let repeat = args.opt_usize("repeat", 3).unwrap_or(3);
    let par_threads = args
        .opt_usize(
            "sim-threads",
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
        )
        .unwrap_or(4)
        .max(2);

    let mut rows = Vec::new();
    for b in benches {
        let (nx, ny, nz) = sim_sizes(&b);
        let w = suite::workload(&b, nx, ny, nz, 42);
        let cfg = w.cfg.clone(); // no trace: measure the pure interpreter
        let barrier_family = matches!(
            b.pattern,
            suite::Pattern::TiledReduce { .. } | suite::Pattern::SharedStencil { .. }
        );

        let t0 = Instant::now();
        let dk = decode(&w.kernel).expect("decode");
        let decode_us = t0.elapsed().as_secs_f64() * 1e6;

        let engine_cfg = |superblocks: bool, vector: bool, threads: usize| -> SimConfig {
            let mut c = cfg.clone();
            c.sim_threads = threads;
            c.superblocks = superblocks;
            c.vector = vector;
            c
        };

        let (reference_s, r_ref) =
            best_of(repeat, || run_reference(&w.kernel, &cfg, w.mem.clone()).expect("reference"));

        let mut engine_s = [None; 4];
        for (i, (name, superblocks, vector)) in ENGINES.iter().enumerate() {
            if !measured[i] {
                continue;
            }
            let c = engine_cfg(*superblocks, *vector, 1);
            let (t, r) = best_of(repeat, || run_decoded(&dk, &c, w.mem.clone()).expect(name));
            check_agree(b.name, &r_ref, &r, name);
            engine_s[i] = Some(t);
        }

        let cn = engine_cfg(true, true, par_threads);
        let (parallel_s, r_par) =
            best_of(repeat, || run_decoded(&dk, &cn, w.mem.clone()).expect("parallel"));
        check_agree(b.name, &r_ref, &r_par, "parallel");

        if barrier_family {
            assert!(
                r_ref.stats.barrier_phases > 0,
                "{}: the barrier family must cross barrier phases",
                b.name
            );
        }
        let out = r_ref.mem.read_f32s(w.out_ptr, w.out_len).expect("read output");
        assert!(
            out.iter().zip(&w.expected).all(|(a, e)| a.to_bits() == e.to_bits()),
            "{}: output diverged from the CPU reference",
            b.name
        );

        rows.push(Row {
            name: b.name,
            warp_steps: r_ref.stats.warp_instructions,
            blocks: cfg.grid.0 * cfg.grid.1 * cfg.grid.2,
            decode_us,
            reference_s,
            engine_s,
            parallel_s,
        });
    }

    let total_steps: u64 = rows.iter().map(|r| r.warp_steps).sum();
    let total_ref: f64 = rows.iter().map(|r| r.reference_s).sum();
    let total_par: f64 = rows.iter().map(|r| r.parallel_s).sum();
    let total_engine: Vec<Option<f64>> = (0..ENGINES.len())
        .map(|i| {
            measured[i].then(|| rows.iter().map(|r| r.engine_s[i].unwrap()).sum::<f64>())
        })
        .collect();
    let geomean = |f: &dyn Fn(&Row) -> f64| -> f64 {
        (rows.iter().map(|r| f(r).ln()).sum::<f64>() / rows.len() as f64).exp()
    };

    let mut json = String::new();
    writeln!(json, "{{").unwrap();
    writeln!(json, "  \"bench_id\": \"{bench_id}\",").unwrap();
    writeln!(json, "  \"family\": \"{family}\",").unwrap();
    writeln!(json, "  \"engine\": \"{engine}\",").unwrap();
    writeln!(json, "  \"simd_feature\": {},", cfg!(feature = "simd")).unwrap();
    writeln!(json, "  \"unit\": \"warp-steps/sec\",").unwrap();
    writeln!(json, "  \"repeat\": {repeat},").unwrap();
    writeln!(json, "  \"parallel_threads\": {par_threads},").unwrap();
    writeln!(json, "  \"benchmarks\": [").unwrap();
    for (i, r) in rows.iter().enumerate() {
        let comma = if i + 1 == rows.len() { "" } else { "," };
        write!(
            json,
            "    {{\"name\": \"{}\", \"warp_steps\": {}, \"blocks\": {}, \
             \"decode_us\": {:.1}, \
             \"reference_s\": {:.6}, \"reference_wsps\": {:.0}",
            r.name,
            r.warp_steps,
            r.blocks,
            r.decode_us,
            r.reference_s,
            r.warp_steps as f64 / r.reference_s,
        )
        .unwrap();
        for (j, (name, ..)) in ENGINES.iter().enumerate() {
            if let Some(t) = r.engine_s[j] {
                write!(
                    json,
                    ", \"{name}_s\": {:.6}, \"{name}_wsps\": {:.0}",
                    t,
                    r.warp_steps as f64 / t
                )
                .unwrap();
            }
        }
        if let Some(scalar) = r.engine_s[0] {
            for (j, (name, ..)) in ENGINES.iter().enumerate().skip(1) {
                if let Some(t) = r.engine_s[j] {
                    write!(json, ", \"speedup_{name}_vs_scalar\": {:.3}", scalar / t).unwrap();
                }
            }
        }
        writeln!(
            json,
            ", \"parallel_s\": {:.6}, \"parallel_wsps\": {:.0}, \
             \"speedup_parallel_vs_reference\": {:.3}}}{comma}",
            r.parallel_s,
            r.warp_steps as f64 / r.parallel_s,
            r.reference_s / r.parallel_s,
        )
        .unwrap();
    }
    writeln!(json, "  ],").unwrap();
    writeln!(json, "  \"total_warp_steps\": {total_steps},").unwrap();
    writeln!(json, "  \"reference_wsps\": {:.0},", total_steps as f64 / total_ref).unwrap();
    for (j, (name, ..)) in ENGINES.iter().enumerate() {
        if let Some(t) = total_engine[j] {
            writeln!(json, "  \"{name}_wsps\": {:.0},", total_steps as f64 / t).unwrap();
        }
    }
    if let Some(scalar) = total_engine[0] {
        for (j, (name, ..)) in ENGINES.iter().enumerate().skip(1) {
            if let Some(t) = total_engine[j] {
                writeln!(json, "  \"speedup_{name}_vs_scalar\": {:.3},", scalar / t).unwrap();
                let gm = geomean(&|r| r.engine_s[0].unwrap() / r.engine_s[j].unwrap());
                writeln!(json, "  \"geomean_speedup_{name}_vs_scalar\": {gm:.3},").unwrap();
            }
        }
    }
    writeln!(json, "  \"parallel_wsps\": {:.0},", total_steps as f64 / total_par).unwrap();
    writeln!(
        json,
        "  \"speedup_parallel_vs_reference\": {:.3},",
        total_ref / total_par
    )
    .unwrap();
    let gm_par = geomean(&|r| r.reference_s / r.parallel_s);
    writeln!(json, "  \"geomean_speedup_parallel\": {gm_par:.3}").unwrap();
    writeln!(json, "}}").unwrap();

    std::fs::write(&out_path, &json).expect("write benchmark json");
    eprintln!(
        "simbench [{family}]: {} benchmarks, {total_steps} warp-steps",
        rows.len()
    );
    eprintln!(
        "  reference  {:>12.0} warp-steps/s",
        total_steps as f64 / total_ref
    );
    let scalar_total = total_engine[0];
    for (j, (name, ..)) in ENGINES.iter().enumerate() {
        if let Some(t) = total_engine[j] {
            let vs = match scalar_total {
                Some(s) if j > 0 => format!("  ({:.2}x vs scalar)", s / t),
                _ => String::new(),
            };
            eprintln!(
                "  {name:<10} {:>12.0} warp-steps/s{vs}",
                total_steps as f64 / t
            );
        }
    }
    eprintln!(
        "  parallel   {:>12.0} warp-steps/s  ({:.2}x vs reference, geomean {:.2}x, {par_threads} threads)",
        total_steps as f64 / total_par,
        total_ref / total_par,
        gm_par
    );
    eprintln!("  wrote {out_path}");
}

fn check_agree(name: &str, a: &SimResult, b: &SimResult, tag: &str) {
    assert_eq!(a.mem, b.mem, "{name}: {tag} memory image diverged");
    assert_eq!(a.stats, b.stats, "{name}: {tag} stats diverged");
}

/// `--family elim` (`BENCH_7`): run the shared family through the
/// phase-liveness dead-store/barrier elimination pass and emit pre/post
/// perf-model columns. Every assert here is a CI gate: a bail, a lost
/// elimination, an output mismatch, or a non-improving perf score aborts
/// the benchmark run.
fn run_elim(benches: Vec<suite::Benchmark>, bench_id: &str, out_path: &str, repeat: usize) {
    use ptxasw::emu::emulate;
    use ptxasw::perf::{model, Stall, PASCAL, STALL_KINDS};
    use ptxasw::shuffle::{eliminate, ElimOpts};

    let sync = STALL_KINDS
        .iter()
        .position(|&s| s == Stall::Synchronization)
        .unwrap();

    struct ERow {
        name: &'static str,
        stores_deleted: usize,
        stores_seen: usize,
        barriers_elided: usize,
        barriers_seen: usize,
        forwarded_loads: u32,
        dce_stmts: u32,
        pre_serial: f64,
        post_serial: f64,
        pre_sync: f64,
        post_sync: f64,
        pre_s: f64,
        post_s: f64,
    }

    let mut arch_name = "";
    let mut rows: Vec<ERow> = Vec::new();
    for b in benches {
        let (nx, ny, nz) = sim_sizes(&b);
        let w = suite::workload(&b, nx, ny, nz, 42);
        let emu = emulate(&w.kernel).expect("emulate");
        let opts = ElimOpts { enabled: true, block: w.cfg.block.0 };
        let (elim, report) = eliminate(&w.kernel, &w.kernel, &emu, opts);
        if let Some(why) = &report.bail {
            panic!("{}: elimination pass bailed: {why}", b.name);
        }

        // Wall-clock columns (best-of-N, untraced) for both kernels.
        let (pre_s, r_pre) =
            best_of(repeat, || run_reference(&w.kernel, &w.cfg, w.mem.clone()).expect("pre"));
        let (post_s, r_post) =
            best_of(repeat, || run_reference(&elim, &w.cfg, w.mem.clone()).expect("post"));

        // Correctness gate: the eliminated kernel must reproduce the
        // original's output image bit-for-bit, and both must match the
        // CPU reference image computed by the workload builder.
        let pre_out = r_pre.mem.read_f32s(w.out_ptr, w.out_len).expect("pre out");
        let post_out = r_post.mem.read_f32s(w.out_ptr, w.out_len).expect("post out");
        assert!(
            pre_out.iter().zip(&post_out).all(|(a, c)| a.to_bits() == c.to_bits()),
            "{}: eliminated kernel diverged from the original",
            b.name
        );
        assert!(
            post_out.iter().zip(&w.expected).all(|(a, e)| a.to_bits() == e.to_bits()),
            "{}: eliminated kernel diverged from the CPU reference",
            b.name
        );

        // Traced runs feed the perf model (the Figure 3 stall columns).
        let mut tcfg = w.cfg.clone();
        tcfg.record_trace = true;
        let t_pre = run_reference(&w.kernel, &tcfg, w.mem.clone()).expect("pre trace");
        let t_post = run_reference(&elim, &tcfg, w.mem.clone()).expect("post trace");
        let m_pre = model(&w.kernel, &t_pre.trace, &PASCAL);
        let m_post = model(&elim, &t_post.trace, &PASCAL);
        arch_name = m_pre.arch;

        if matches!(b.pattern, suite::Pattern::SharedGather { .. }) {
            // Adversarial column: the gather's staging store feeds a
            // data-dependent load, so the pass must keep the store AND
            // the barrier (unknown address => conservatively live).
            assert_eq!(
                report.deleted_stores(),
                0,
                "{}: the data-dependent gather must keep its staging store",
                b.name
            );
            assert_eq!(
                report.elided_barriers(),
                0,
                "{}: the data-dependent gather must keep its barrier",
                b.name
            );
            assert_eq!(
                r_post.stats.barriers, r_pre.stats.barriers,
                "{}: barrier count changed on the adversarial kernel",
                b.name
            );
        } else {
            // tiledreduce / sharedstencil: fully-shuffled tiles must lose
            // every staging store and at least one bar.sync, and the perf
            // model must score the eliminated kernel strictly better.
            assert!(
                report.deleted_stores() > 0,
                "{}: zero store eliminations fired on a fully-shuffled kernel",
                b.name
            );
            assert!(
                report.elided_barriers() >= 1,
                "{}: no bar.sync was elided on a fully-shuffled kernel",
                b.name
            );
            assert_eq!(
                r_post.stats.shared_loads, 0,
                "{}: .shared loads survived elimination",
                b.name
            );
            assert!(
                r_post.stats.barriers < r_pre.stats.barriers,
                "{}: executed barrier count did not drop",
                b.name
            );
            assert!(
                m_post.serial_cycles < m_pre.serial_cycles,
                "{}: perf score did not improve ({:.0} -> {:.0} serial cycles)",
                b.name,
                m_pre.serial_cycles,
                m_post.serial_cycles
            );
            assert!(
                m_post.stalls[sync] < m_pre.stalls[sync],
                "{}: sync stall cycles did not drop ({:.0} -> {:.0})",
                b.name,
                m_pre.stalls[sync],
                m_post.stalls[sync]
            );
        }

        rows.push(ERow {
            name: b.name,
            stores_deleted: report.deleted_stores(),
            stores_seen: report.stores.len(),
            barriers_elided: report.elided_barriers(),
            barriers_seen: report.barriers.len(),
            forwarded_loads: report.forwarded_loads,
            dce_stmts: report.dce_stmts,
            pre_serial: m_pre.serial_cycles,
            post_serial: m_post.serial_cycles,
            pre_sync: m_pre.stalls[sync],
            post_sync: m_post.stalls[sync],
            pre_s,
            post_s,
        });
    }

    let total_deleted: usize = rows.iter().map(|r| r.stores_deleted).sum();
    let total_elided: usize = rows.iter().map(|r| r.barriers_elided).sum();
    let geomean_speedup = (rows
        .iter()
        .map(|r| (r.pre_serial / r.post_serial).ln())
        .sum::<f64>()
        / rows.len() as f64)
        .exp();

    let mut json = String::new();
    writeln!(json, "{{").unwrap();
    writeln!(json, "  \"bench_id\": \"{bench_id}\",").unwrap();
    writeln!(json, "  \"family\": \"elim\",").unwrap();
    writeln!(json, "  \"arch\": \"{arch_name}\",").unwrap();
    writeln!(json, "  \"unit\": \"perf-model serial cycles (pre/post elimination)\",").unwrap();
    writeln!(json, "  \"repeat\": {repeat},").unwrap();
    writeln!(json, "  \"benchmarks\": [").unwrap();
    for (i, r) in rows.iter().enumerate() {
        let comma = if i + 1 == rows.len() { "" } else { "," };
        writeln!(
            json,
            "    {{\"name\": \"{}\", \
             \"stores_deleted\": {}, \"stores_seen\": {}, \
             \"barriers_elided\": {}, \"barriers_seen\": {}, \
             \"loads_forwarded\": {}, \"dce_stmts\": {}, \
             \"pre_serial_cycles\": {:.1}, \"post_serial_cycles\": {:.1}, \
             \"model_speedup\": {:.3}, \
             \"pre_sync_stall_cycles\": {:.1}, \"post_sync_stall_cycles\": {:.1}, \
             \"pre_reference_s\": {:.6}, \"post_reference_s\": {:.6}}}{comma}",
            r.name,
            r.stores_deleted,
            r.stores_seen,
            r.barriers_elided,
            r.barriers_seen,
            r.forwarded_loads,
            r.dce_stmts,
            r.pre_serial,
            r.post_serial,
            r.pre_serial / r.post_serial,
            r.pre_sync,
            r.post_sync,
            r.pre_s,
            r.post_s,
        )
        .unwrap();
    }
    writeln!(json, "  ],").unwrap();
    writeln!(json, "  \"total_stores_deleted\": {total_deleted},").unwrap();
    writeln!(json, "  \"total_barriers_elided\": {total_elided},").unwrap();
    writeln!(json, "  \"geomean_model_speedup\": {geomean_speedup:.3}").unwrap();
    writeln!(json, "}}").unwrap();

    std::fs::write(out_path, &json).expect("write benchmark json");
    eprintln!(
        "simbench [elim]: {} benchmarks, {total_deleted} stores deleted, {total_elided} barriers elided",
        rows.len()
    );
    for r in &rows {
        eprintln!(
            "  {:<14} {:>3} stores deleted, {:>2} barriers elided, {:>3} loads forwarded, \
             serial {:>9.1} -> {:>9.1} cycles ({:.2}x)",
            r.name,
            r.stores_deleted,
            r.barriers_elided,
            r.forwarded_loads,
            r.pre_serial,
            r.post_serial,
            r.pre_serial / r.post_serial,
        );
    }
    eprintln!("  geomean model speedup {geomean_speedup:.2}x");
    eprintln!("  wrote {out_path}");
}
