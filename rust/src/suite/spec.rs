//! Benchmark specifications: the stencil footprints of the KernelGen suite
//! (Table 2) and the §8.5 application kernels, described abstractly so the
//! code generator (`codegen.rs`) can emit NVHPC-shaped PTX and the harness
//! can compute reference results.

/// Source language of the original benchmark (Table 2 metadata).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Lang {
    C,
    Fortran,
}

impl Lang {
    pub fn short(self) -> &'static str {
        match self {
            Lang::C => "C",
            Lang::Fortran => "F",
        }
    }
}

/// Optional unary function applied to a loaded value (sincos benchmark).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TapFunc {
    None,
    Sin,
    Cos,
}

/// One load tap: input array index + per-dimension element offsets
/// (leading/thread dimension first) + combining coefficient.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Tap {
    pub array: u32,
    pub di: i64,
    pub dj: i64,
    pub dk: i64,
    pub coef: f32,
    pub func: TapFunc,
}

impl Tap {
    pub const fn new(array: u32, di: i64, dj: i64, dk: i64, coef: f32) -> Tap {
        Tap {
            array,
            di,
            dj,
            dk,
            coef,
            func: TapFunc::None,
        }
    }

    pub const fn with_func(mut self, f: TapFunc) -> Tap {
        self.func = f;
        self
    }
}

/// Computational pattern of a benchmark.
#[derive(Debug, Clone, PartialEq)]
pub enum Pattern {
    /// `out[idx] = Σ coefₜ · inₜ[idx + offₜ]` — the stencil family.
    Stencil { taps: Vec<Tap> },
    /// Dense `C = A·B` with an unrolled inner k-loop (no shuffles possible).
    MatMul { unroll: u32 },
    /// `y = A·x` with an unrolled inner loop (no shuffles possible).
    MatVec { unroll: u32 },
    /// `out[i] = sin(a[i]) + cos(b[i])`.
    SinCos,
    /// `c[i] = a[i] + b[i]`.
    VecAdd,
    /// Per-block tree reduction communicated through `.shared` with a
    /// `bar.sync` between every round: `out[blk] = Σ a[blk·block ..
    /// (blk+1)·block]`. `block` is the (fixed, power-of-two, multiple of
    /// 32) thread-block size the unrolled tree is generated for.
    TiledReduce { block: u32 },
    /// 1D `2·radius+1`-point uniform stencil whose tile (plus halo,
    /// clamped at the grid edges) is staged into `.shared` by the block,
    /// with one `bar.sync` between staging and use.
    SharedStencil { radius: i64, block: u32 },
    /// Data-dependent gather through `.shared`: each thread stages one
    /// element, then reads its own slot *and* a slot picked by a runtime
    /// index array (`in1[i] & (block-1)`). The second tap's address is
    /// unknowable statically — the adversarial case for the phase-liveness
    /// pass, which must keep the staging store and the barrier.
    SharedGather { block: u32 },
}

/// Tap coefficient of the shared-staged stencil (uniform averaging) —
/// single source of truth for the code generator AND the CPU reference,
/// so the fma chains stay bit-identical.
pub fn shared_stencil_coef(radius: i64) -> f32 {
    1.0f32 / (2 * radius + 1) as f32
}

/// A benchmark of the suite.
#[derive(Debug, Clone)]
pub struct Benchmark {
    pub name: &'static str,
    pub lang: Lang,
    /// 2 or 3 dimensions (Table 2: 2D vs 3D benchmarks).
    pub dims: u32,
    pub pattern: Pattern,
    /// `divergence`-style data-dependent guard load (Listing 1).
    pub divergent: bool,
    /// Expected Table 2 row (shuffles, loads, avg |delta|) for validation.
    pub expect_shuffles: usize,
    pub expect_loads: usize,
    pub expect_delta: Option<f64>,
}

impl Benchmark {
    /// Number of distinct input arrays the pattern reads.
    pub fn input_arrays(&self) -> u32 {
        match &self.pattern {
            Pattern::Stencil { taps } => taps.iter().map(|t| t.array).max().unwrap_or(0) + 1,
            Pattern::MatMul { .. } => 2,
            Pattern::MatVec { .. } => 2,
            Pattern::SinCos => 2,
            Pattern::VecAdd => 2,
            Pattern::TiledReduce { .. } | Pattern::SharedStencil { .. } => 1,
            Pattern::SharedGather { .. } => 2,
        }
    }

    /// Halo (guard margin) on the leading dimension.
    pub fn halo_i(&self) -> i64 {
        match &self.pattern {
            Pattern::Stencil { taps } => taps
                .iter()
                .map(|t| t.di.abs())
                .max()
                .unwrap_or(0),
            _ => 0,
        }
    }

    pub fn halo_j(&self) -> i64 {
        match &self.pattern {
            Pattern::Stencil { taps } => taps.iter().map(|t| t.dj.abs()).max().unwrap_or(0),
            _ => 0,
        }
    }

    pub fn halo_k(&self) -> i64 {
        match &self.pattern {
            Pattern::Stencil { taps } => taps.iter().map(|t| t.dk.abs()).max().unwrap_or(0),
            _ => 0,
        }
    }
}

/// Build a row of taps along the leading dimension: offsets `lo..=hi`
/// (inclusive) at fixed `(dj, dk)` of `array`.
pub fn irow(array: u32, lo: i64, hi: i64, dj: i64, dk: i64, coef: f32) -> Vec<Tap> {
    (lo..=hi)
        .map(|di| Tap::new(array, di, dj, dk, coef))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn irow_builds_inclusive_range() {
        let r = irow(0, -2, 2, 0, 0, 1.0);
        assert_eq!(r.len(), 5);
        assert_eq!(r[0].di, -2);
        assert_eq!(r[4].di, 2);
    }

    #[test]
    fn halo_is_max_abs_offset() {
        let b = Benchmark {
            name: "t",
            lang: Lang::C,
            dims: 3,
            pattern: Pattern::Stencil {
                taps: vec![Tap::new(0, -1, 2, -3, 1.0), Tap::new(1, 2, 0, 0, 1.0)],
            },
            divergent: false,
            expect_shuffles: 0,
            expect_loads: 2,
            expect_delta: None,
        };
        assert_eq!(b.halo_i(), 2);
        assert_eq!(b.halo_j(), 2);
        assert_eq!(b.halo_k(), 3);
        assert_eq!(b.input_arrays(), 2);
    }
}
