//! The KernelGen benchmark suite (Table 2), reconstructed from the
//! published stencil footprints.
//!
//! Each benchmark's tap set is chosen to reproduce the paper's Table 2 row
//! exactly: number of global loads, number of synthesized shuffles, and
//! average shuffle delta (see DESIGN.md). `workload` builds a simulator
//! launch plus deterministic input data, and `reference` computes the
//! expected output on the CPU with the same fma ordering, so validation is
//! bit-exact.

use super::codegen::{generate, param_names};
use super::spec::{irow, Benchmark, Lang, Pattern, Tap, TapFunc};
use crate::ptx::ast::Kernel;
use crate::sim::{Allocator, GlobalMem, SimConfig};
use crate::util::Rng;

/// All 16 benchmarks in Table 2 order.
pub fn suite() -> Vec<Benchmark> {
    vec![
        divergence(),
        gameoflife(),
        gaussblur(),
        gradient(),
        jacobi(),
        lapgsrb(),
        laplacian(),
        matmul(),
        matvec(),
        sincos(),
        tricubic(),
        tricubic2(),
        uxx1(),
        vecadd(),
        wave13pt(),
        whispering(),
    ]
}

pub fn by_name(name: &str) -> Option<Benchmark> {
    suite()
        .into_iter()
        .chain(shared_suite())
        .find(|b| b.name == name)
}

/// The shared-memory-communicating benchmark family: kernels that stage
/// data through `.shared` and synchronize warps with `bar.sync` — the
/// class the cooperative warp scheduler opened up. Kept separate from
/// [`suite`] (the paper's Table 2 is exactly 16 rows); reachable by name
/// from the CLI and run by `simbench --family shared` (`BENCH_5.json`).
pub fn shared_suite() -> Vec<Benchmark> {
    vec![tiledreduce(), sharedstencil(), sharedgather()]
}

fn tiledreduce() -> Benchmark {
    Benchmark {
        name: "tiledreduce",
        lang: Lang::C,
        dims: 1,
        // single-warp blocks: the phase-liveness pass only forwards
        // store→load traffic it can replace with warp shuffles
        pattern: Pattern::TiledReduce { block: 32 },
        divergent: false,
        // one global load; the tree communicates through .shared, which
        // the default detection options exclude (and the tree loads are
        // predicated anyway)
        expect_shuffles: 0,
        expect_loads: 1,
        expect_delta: None,
    }
}

fn sharedstencil() -> Benchmark {
    Benchmark {
        name: "sharedstencil",
        lang: Lang::C,
        dims: 1,
        pattern: Pattern::SharedStencil {
            radius: 1,
            block: 32,
        },
        divergent: false,
        // center + two predicated halo loads; the taps read .shared
        // across a barrier, so nothing may be shuffled
        expect_shuffles: 0,
        expect_loads: 3,
        expect_delta: None,
    }
}

fn sharedgather() -> Benchmark {
    Benchmark {
        name: "sharedgather",
        lang: Lang::C,
        dims: 1,
        pattern: Pattern::SharedGather { block: 32 },
        divergent: false,
        // the staged value + the index array; the data-dependent tap keeps
        // the staging store and barrier alive (adversarial for elimination)
        expect_shuffles: 0,
        expect_loads: 2,
        expect_delta: None,
    }
}

fn divergence() -> Benchmark {
    // flag guard load + {i±1}, {j±1} of a, center of b: 6 loads, 1 shuffle (N=2)
    let taps = vec![
        Tap::new(0, -1, 0, 0, 0.25),
        Tap::new(0, 1, 0, 0, 0.25),
        Tap::new(0, 0, -1, 0, 0.25),
        Tap::new(0, 0, 1, 0, 0.25),
        Tap::new(1, 0, 0, 0, 1.0),
    ];
    Benchmark {
        name: "divergence",
        lang: Lang::C,
        dims: 3,
        pattern: Pattern::Stencil { taps },
        divergent: true,
        expect_shuffles: 1,
        expect_loads: 6,
        expect_delta: Some(2.0),
    }
}

fn gameoflife() -> Benchmark {
    let mut taps = Vec::new();
    for dj in -1..=1 {
        taps.extend(irow(0, -1, 1, dj, 0, if dj == 0 { 0.5 } else { 0.125 }));
    }
    Benchmark {
        name: "gameoflife",
        lang: Lang::C,
        dims: 2,
        pattern: Pattern::Stencil { taps },
        divergent: false,
        expect_shuffles: 6,
        expect_loads: 9,
        expect_delta: Some(1.5),
    }
}

fn gaussblur() -> Benchmark {
    let w = [0.054, 0.244, 0.403, 0.244, 0.054f32];
    let mut taps = Vec::new();
    for (jw, dj) in (-2..=2).enumerate().map(|(n, d)| (w[n], d)) {
        for (iw, di) in (-2..=2).enumerate().map(|(n, d)| (w[n], d)) {
            taps.push(Tap::new(0, di, dj, 0, iw * jw));
        }
    }
    Benchmark {
        name: "gaussblur",
        lang: Lang::C,
        dims: 2,
        pattern: Pattern::Stencil { taps },
        divergent: false,
        expect_shuffles: 20,
        expect_loads: 25,
        expect_delta: Some(2.5),
    }
}

fn gradient() -> Benchmark {
    let taps = vec![
        Tap::new(0, -1, 0, 0, -0.5),
        Tap::new(0, 1, 0, 0, 0.5),
        Tap::new(0, 0, -1, 0, -0.5),
        Tap::new(0, 0, 1, 0, 0.5),
        Tap::new(0, 0, 0, -1, -0.5),
        Tap::new(0, 0, 0, 1, 0.5),
    ];
    Benchmark {
        name: "gradient",
        lang: Lang::C,
        dims: 3,
        pattern: Pattern::Stencil { taps },
        divergent: false,
        expect_shuffles: 1,
        expect_loads: 6,
        expect_delta: Some(2.0),
    }
}

fn jacobi() -> Benchmark {
    // Listing 4: c0*center + c1*(edge neighbors) + c2*(corner neighbors)
    let (c0, c1, c2) = (0.5f32, 0.1f32, 0.025f32);
    let mut taps = Vec::new();
    for dj in -1..=1i64 {
        for di in -1..=1i64 {
            let c = match di.abs() + dj.abs() {
                0 => c0,
                1 => c1,
                _ => c2,
            };
            taps.push(Tap::new(0, di, dj, 0, c));
        }
    }
    Benchmark {
        name: "jacobi",
        lang: Lang::Fortran,
        dims: 2,
        pattern: Pattern::Stencil { taps },
        divergent: false,
        expect_shuffles: 6,
        expect_loads: 9,
        expect_delta: Some(1.5),
    }
}

fn lapgsrb() -> Benchmark {
    // 5-wide leading row + four 3-wide rows + 8 single taps:
    // 25 loads, 12 shuffles, avg delta (10 + 4*3)/12 = 1.83
    let mut taps = Vec::new();
    taps.extend(irow(0, -2, 2, 0, 0, 0.05));
    for (dj, dk) in [(-1, 0), (1, 0), (0, -1), (0, 1)] {
        taps.extend(irow(0, -1, 1, dj, dk, 0.03));
    }
    for (dj, dk) in [
        (2, 0),
        (-2, 0),
        (0, 2),
        (0, -2),
        (1, 1),
        (-1, 1),
        (1, -1),
        (-1, -1),
    ] {
        taps.push(Tap::new(0, 0, dj, dk, 0.01));
    }
    Benchmark {
        name: "lapgsrb",
        lang: Lang::C,
        dims: 3,
        pattern: Pattern::Stencil { taps },
        divergent: false,
        expect_shuffles: 12,
        expect_loads: 25,
        expect_delta: Some(22.0 / 12.0),
    }
}

fn laplacian() -> Benchmark {
    let taps = vec![
        Tap::new(0, -1, 0, 0, 1.0),
        Tap::new(0, 0, 0, 0, -6.0),
        Tap::new(0, 1, 0, 0, 1.0),
        Tap::new(0, 0, -1, 0, 1.0),
        Tap::new(0, 0, 1, 0, 1.0),
        Tap::new(0, 0, 0, -1, 1.0),
        Tap::new(0, 0, 0, 1, 1.0),
    ];
    Benchmark {
        name: "laplacian",
        lang: Lang::C,
        dims: 3,
        pattern: Pattern::Stencil { taps },
        divergent: false,
        expect_shuffles: 2,
        expect_loads: 7,
        expect_delta: Some(1.5),
    }
}

fn matmul() -> Benchmark {
    Benchmark {
        name: "matmul",
        lang: Lang::Fortran,
        dims: 2,
        pattern: Pattern::MatMul { unroll: 4 },
        divergent: false,
        expect_shuffles: 0,
        expect_loads: 8,
        expect_delta: None,
    }
}

fn matvec() -> Benchmark {
    Benchmark {
        name: "matvec",
        lang: Lang::C,
        dims: 2,
        pattern: Pattern::MatVec { unroll: 3 },
        divergent: false,
        expect_shuffles: 0,
        expect_loads: 7,
        expect_delta: None,
    }
}

fn sincos() -> Benchmark {
    Benchmark {
        name: "sincos",
        lang: Lang::Fortran,
        dims: 3,
        pattern: Pattern::SinCos,
        divergent: false,
        expect_shuffles: 0,
        expect_loads: 2,
        expect_delta: None,
    }
}

fn tricubic_taps(coef: f32) -> Vec<Tap> {
    // 4x4x4 interpolation neighborhood + 3 coordinate loads
    let mut taps = Vec::new();
    for dk in -1..=2 {
        for dj in -1..=2 {
            taps.extend(irow(0, -1, 2, dj, dk, coef));
        }
    }
    taps.push(Tap::new(1, 0, 0, 0, 1.0));
    taps.push(Tap::new(2, 0, 0, 0, 1.0));
    taps.push(Tap::new(3, 0, 0, 0, 1.0));
    taps
}

fn tricubic() -> Benchmark {
    Benchmark {
        name: "tricubic",
        lang: Lang::C,
        dims: 3,
        pattern: Pattern::Stencil {
            taps: tricubic_taps(1.0 / 64.0),
        },
        divergent: false,
        expect_shuffles: 48,
        expect_loads: 67,
        expect_delta: Some(2.0),
    }
}

fn tricubic2() -> Benchmark {
    Benchmark {
        name: "tricubic2",
        lang: Lang::C,
        dims: 3,
        pattern: Pattern::Stencil {
            taps: tricubic_taps(1.0 / 32.0),
        },
        divergent: false,
        expect_shuffles: 48,
        expect_loads: 67,
        expect_delta: Some(2.0),
    }
}

fn uxx1() -> Benchmark {
    // three {i-1, i+1} pairs on different arrays + 11 single taps
    let mut taps = Vec::new();
    for a in 0..3 {
        taps.push(Tap::new(a, -1, 0, 0, 0.5));
        taps.push(Tap::new(a, 1, 0, 0, 0.5));
    }
    for (a, dj, dk) in [
        (0, 1, 0),
        (0, -1, 0),
        (0, 0, 1),
        (0, 0, -1),
        (1, 1, 0),
        (1, -1, 0),
        (2, 0, 1),
        (2, 0, -1),
        (3, 0, 0),
        (3, 1, 0),
        (3, 0, 1),
    ] {
        taps.push(Tap::new(a, 0, dj, dk, 0.1));
    }
    Benchmark {
        name: "uxx1",
        lang: Lang::C,
        dims: 3,
        pattern: Pattern::Stencil { taps },
        divergent: false,
        expect_shuffles: 3,
        expect_loads: 17,
        expect_delta: Some(2.0),
    }
}

fn vecadd() -> Benchmark {
    Benchmark {
        name: "vecadd",
        lang: Lang::C,
        dims: 3,
        pattern: Pattern::VecAdd,
        divergent: false,
        expect_shuffles: 0,
        expect_loads: 2,
        expect_delta: None,
    }
}

fn wave13pt() -> Benchmark {
    let mut taps = Vec::new();
    taps.extend(irow(0, -2, 2, 0, 0, 0.1));
    for dj in [-2i64, -1, 1, 2] {
        taps.push(Tap::new(0, 0, dj, 0, 0.05));
    }
    for dk in [-2i64, -1, 1, 2] {
        taps.push(Tap::new(0, 0, 0, dk, 0.05));
    }
    taps.push(Tap::new(1, 0, 0, 0, -1.0)); // previous time step
    Benchmark {
        name: "wave13pt",
        lang: Lang::C,
        dims: 3,
        pattern: Pattern::Stencil { taps },
        divergent: false,
        expect_shuffles: 4,
        expect_loads: 14,
        expect_delta: Some(2.5),
    }
}

fn whispering() -> Benchmark {
    // deltas {1,2,2,0,0,0} → avg 0.83; 19 loads over 5 arrays
    let mut taps = Vec::new();
    taps.extend(irow(0, -1, 1, 0, 0, 0.2)); // 2 shuffles (1,2)
    taps.push(Tap::new(1, -1, 0, 0, 0.3)); // pair → 1 shuffle (2)
    taps.push(Tap::new(1, 1, 0, 0, 0.3));
    for a in 2..5 {
        // duplicated load → N=0 shuffle each
        taps.push(Tap::new(a, 0, 0, 0, 0.1));
        taps.push(Tap::new(a, 0, 0, 0, 0.1));
    }
    for (a, dj) in [(0, -1), (0, 1), (1, -1), (1, 1), (2, -1), (2, 1), (3, -1), (4, 1)] {
        taps.push(Tap::new(a, 0, dj, 0, 0.05));
    }
    Benchmark {
        name: "whispering",
        lang: Lang::C,
        dims: 2,
        pattern: Pattern::Stencil { taps },
        divergent: false,
        expect_shuffles: 6,
        expect_loads: 19,
        expect_delta: Some(5.0 / 6.0),
    }
}

// ---------------------------------------------------------------------------
// Workload construction + CPU reference
// ---------------------------------------------------------------------------

/// Bump when the *meaning* of the workload inputs changes (input data
/// distribution, layout, launch shape): stale fingerprints must not alias
/// freshly generated workloads.
pub const WORKLOAD_SPEC_VERSION: u32 = 1;

/// Stable 128-bit fingerprint of a simulator workload: the benchmark's
/// input-generation spec (pattern, dims, divergence), the grid sizes and
/// the RNG seed. Combined with `ptx::kernel_fingerprint` it keys the
/// `Validated`/`Scored` pipeline artifacts, so re-runs of an identical
/// workload skip simulation entirely.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct WorkloadFingerprint(pub u64, pub u64);

impl std::fmt::Display for WorkloadFingerprint {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{:016x}{:016x}", self.0, self.1)
    }
}

/// Fingerprint the workload [`workload`] would build for these inputs.
///
/// The canonical form is a text rendering of every input that shapes the
/// generated data, keyed with the shared [`crate::util::Fnv128`] scheme,
/// so the key is reproducible run-to-run and process-to-process — never
/// `DefaultHasher`.
pub fn workload_fingerprint(
    b: &Benchmark,
    nx: usize,
    ny: usize,
    nz: usize,
    seed: u64,
) -> WorkloadFingerprint {
    use std::fmt::Write;
    let mut text = String::with_capacity(256);
    write!(
        text,
        "v{WORKLOAD_SPEC_VERSION};{};{:?};dims={};div={};{:?};{nx}x{ny}x{nz};seed={seed}",
        b.name, b.lang, b.dims, b.divergent, b.pattern
    )
    .unwrap();
    let mut h = crate::util::Fnv128::new();
    h.write(text.as_bytes());
    let (w0, w1) = h.finish();
    WorkloadFingerprint(w0, w1)
}

/// A ready-to-run simulator workload.
#[derive(Debug)]
pub struct Workload {
    pub kernel: Kernel,
    pub cfg: SimConfig,
    pub mem: GlobalMem,
    pub out_ptr: u64,
    pub out_len: usize,
    /// Expected output, computed on the CPU with matching fma order.
    pub expected: Vec<f32>,
}

/// Deterministic input array.
fn input_data(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.f32() * 4.0 - 2.0).collect()
}

/// Build a simulator workload (+ bit-exact CPU reference) for a benchmark.
pub fn workload(b: &Benchmark, nx: usize, ny: usize, nz: usize, seed: u64) -> Workload {
    let kernel = generate(b);
    let mut rng = Rng::new(seed ^ 0xBEEF);
    let n = nx * ny * nz;

    let mem_size = (n * 4 * 8 + (1 << 16)).next_power_of_two();
    let mut mem = GlobalMem::new(mem_size);
    let mut alloc = Allocator::new(&mem);

    match &b.pattern {
        Pattern::Stencil { taps } => {
            stencil_workload(b, kernel, taps.clone(), nx, ny, nz, &mut rng, mem, alloc)
        }
        Pattern::SinCos => {
            let taps = vec![
                Tap::new(0, 0, 0, 0, 1.0).with_func(TapFunc::Sin),
                Tap::new(1, 0, 0, 0, 1.0).with_func(TapFunc::Cos),
            ];
            stencil_workload(b, kernel, taps, nx, ny, nz, &mut rng, mem, alloc)
        }
        Pattern::VecAdd => {
            let taps = vec![Tap::new(0, 0, 0, 0, 1.0), Tap::new(1, 0, 0, 0, 1.0)];
            stencil_workload(b, kernel, taps, nx, ny, nz, &mut rng, mem, alloc)
        }
        Pattern::MatMul { unroll } => {
            // C[ny×nx] = A[ny×nk] · B[nk×nx], nk = nz rounded to the unroll
            let nk = nz.div_ceil(*unroll as usize) * *unroll as usize;
            let c = alloc.alloc((nx * ny * 4) as u64);
            let a = alloc.alloc((ny * nk * 4) as u64);
            let bb = alloc.alloc((nk * nx * 4) as u64);
            let av = input_data(&mut rng, ny * nk);
            let bv = input_data(&mut rng, nk * nx);
            mem.write_f32s(a, &av).unwrap();
            mem.write_f32s(bb, &bv).unwrap();
            let block = 32u32;
            let mut cfg = SimConfig::new(ny as u32, block, vec![
                c,
                a,
                bb,
                nx as u64,
                ny as u64,
                nk as u64,
            ]);
            cfg.grid = (ny as u32, (nx as u32).div_ceil(block), 1);
            let mut expected = vec![0f32; nx * ny];
            for j in 0..ny {
                for i in 0..nx {
                    let mut acc = 0f32;
                    for k in 0..nk {
                        acc = av[j * nk + k].mul_add(bv[k * nx + i], acc);
                    }
                    expected[j * nx + i] = acc;
                }
            }
            Workload {
                kernel,
                cfg,
                mem,
                out_ptr: c,
                out_len: nx * ny,
                expected,
            }
        }
        Pattern::TiledReduce { block } => {
            let bs = *block as usize;
            let nblocks = nx.max(1);
            let total = nblocks * bs;
            let out = alloc.alloc((nblocks * 4) as u64);
            let a = alloc.alloc((total * 4) as u64);
            let av = input_data(&mut rng, total);
            mem.write_f32s(a, &av).unwrap();
            let cfg = SimConfig::new(nblocks as u32, *block, vec![out, a]);
            // CPU reference replays the kernel's tree exactly: round `s`
            // does sh[t] = sh[t] + sh[t+s] for t < s (reads are disjoint
            // from the round's writes, so sequential order is the tree)
            let mut expected = vec![0f32; nblocks];
            for (blk, e) in expected.iter_mut().enumerate() {
                let mut sh: Vec<f32> = av[blk * bs..(blk + 1) * bs].to_vec();
                let mut s = bs / 2;
                while s >= 1 {
                    for t in 0..s {
                        sh[t] += sh[t + s];
                    }
                    s /= 2;
                }
                *e = sh[0];
            }
            Workload {
                kernel,
                cfg,
                mem,
                out_ptr: out,
                out_len: nblocks,
                expected,
            }
        }
        Pattern::SharedStencil { radius, block } => {
            let (r, bs) = (*radius as usize, *block as usize);
            let nblocks = nx.max(1);
            let total = nblocks * bs;
            let out = alloc.alloc((total * 4) as u64);
            let a = alloc.alloc((total * 4) as u64);
            let av = input_data(&mut rng, total);
            mem.write_f32s(a, &av).unwrap();
            let cfg = SimConfig::new(nblocks as u32, *block, vec![out, a, total as u64]);
            // CPU reference stages the tile + clamped halo like the
            // kernel, then combines with the identical fma chain
            let coef = crate::suite::spec::shared_stencil_coef(*radius);
            let mut expected = vec![0f32; total];
            for blk in 0..nblocks {
                let mut sh = vec![0f32; bs + 2 * r];
                for t in 0..bs {
                    let i = blk * bs + t;
                    sh[t + r] = av[i];
                    if t < r {
                        sh[t] = av[i.saturating_sub(r)];
                    }
                    if t >= bs - r {
                        sh[t + 2 * r] = av[(i + r).min(total - 1)];
                    }
                }
                for t in 0..bs {
                    let mut acc = 0f32;
                    for k in 0..=2 * r {
                        acc = coef.mul_add(sh[t + k], acc);
                    }
                    expected[blk * bs + t] = acc;
                }
            }
            Workload {
                kernel,
                cfg,
                mem,
                out_ptr: out,
                out_len: total,
                expected,
            }
        }
        Pattern::SharedGather { block } => {
            let bs = *block as usize;
            let nblocks = nx.max(1);
            let total = nblocks * bs;
            let out = alloc.alloc((total * 4) as u64);
            let a = alloc.alloc((total * 4) as u64);
            let ip = alloc.alloc((total * 4) as u64);
            let av = input_data(&mut rng, total);
            mem.write_f32s(a, &av).unwrap();
            let iv: Vec<u32> = (0..total).map(|_| rng.next_u32()).collect();
            mem.write_u32s(ip, &iv).unwrap();
            let cfg = SimConfig::new(nblocks as u32, *block, vec![out, a, ip]);
            // out[i] = tile[t] + tile[idx[i] & (bs-1)] over the block's tile
            let mut expected = vec![0f32; total];
            for blk in 0..nblocks {
                for t in 0..bs {
                    let i = blk * bs + t;
                    let j = (iv[i] as usize) & (bs - 1);
                    expected[i] = av[i] + av[blk * bs + j];
                }
            }
            Workload {
                kernel,
                cfg,
                mem,
                out_ptr: out,
                out_len: total,
                expected,
            }
        }
        Pattern::MatVec { unroll } => {
            let nk = nz.max(1).div_ceil(*unroll as usize) * *unroll as usize * 4;
            let y = alloc.alloc((nx * 4) as u64);
            let a = alloc.alloc((nx * nk * 4) as u64);
            let x = alloc.alloc((nk * 4) as u64);
            let yv = input_data(&mut rng, nx);
            let av = input_data(&mut rng, nx * nk);
            let xv = input_data(&mut rng, nk);
            mem.write_f32s(y, &yv).unwrap();
            mem.write_f32s(a, &av).unwrap();
            mem.write_f32s(x, &xv).unwrap();
            let block = 32u32;
            let cfg = SimConfig::new(
                (nx as u32).div_ceil(block),
                block,
                vec![y, a, x, nx as u64, nk as u64],
            );
            let mut expected = yv.clone();
            for i in 0..nx {
                let mut acc = yv[i];
                for k in 0..nk {
                    acc = av[i * nk + k].mul_add(xv[k], acc);
                }
                expected[i] = acc;
            }
            Workload {
                kernel,
                cfg,
                mem,
                out_ptr: y,
                out_len: nx,
                expected,
            }
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn stencil_workload(
    b: &Benchmark,
    kernel: Kernel,
    taps: Vec<Tap>,
    nx: usize,
    ny: usize,
    nz: usize,
    rng: &mut Rng,
    mut mem: GlobalMem,
    mut alloc: Allocator,
) -> Workload {
    let n = nx * ny * nz;
    let narr = taps.iter().map(|t| t.array).max().unwrap_or(0) as usize + 1;
    let out = alloc.alloc((n * 4) as u64);
    let mut ins = Vec::new();
    let mut in_data = Vec::new();
    for _ in 0..narr {
        let p = alloc.alloc((n * 4) as u64);
        let d = input_data(rng, n);
        mem.write_f32s(p, &d).unwrap();
        ins.push(p);
        in_data.push(d);
    }
    let flags: Option<(u64, Vec<u32>)> = if b.divergent {
        let p = alloc.alloc((n * 4) as u64);
        let d: Vec<u32> = (0..n).map(|_| (rng.below(4) != 0) as u32).collect();
        mem.write_u32s(p, &d).unwrap();
        Some((p, d))
    } else {
        None
    };

    let (hi, hj, hk) = (b.halo_i(), b.halo_j(), b.halo_k());
    let mut params = vec![out];
    params.extend(&ins);
    if let Some((p, _)) = &flags {
        params.push(*p);
    }
    params.push(nx as u64);
    if b.dims >= 2 {
        params.push(ny as u64);
    }
    if b.dims >= 3 {
        params.push(nz as u64);
    }
    debug_assert_eq!(params.len(), param_names(b).len());

    let cfg = if b.dims == 3 {
        // gang over k (+halo range), vector covers i with one block
        let grid = ((nz as i64 - 2 * hk).max(1)) as u32;
        SimConfig::new(grid, nx.min(512) as u32, params)
    } else {
        let grid = ((ny as i64 - 2 * hj).max(1)) as u32;
        SimConfig::new(grid, 64.min(nx as u32), params)
    };

    // CPU reference with identical fma ordering
    let mut expected = vec![0f32; n];
    let (zlo, zhi) = if b.dims == 3 {
        (hk, nz as i64 - hk)
    } else {
        (0, 1)
    };
    for k in zlo..zhi {
        for j in hj..(ny as i64 - hj) {
            for i in hi..(nx as i64 - hi) {
                let idx = ((k * ny as i64 + j) * nx as i64 + i) as usize;
                if let Some((_, f)) = &flags {
                    if f[idx] == 0 {
                        continue;
                    }
                }
                let mut acc = 0f32;
                for t in &taps {
                    let tidx = ((k + t.dk) * ny as i64 + (j + t.dj)) * nx as i64 + (i + t.di);
                    let mut v = in_data[t.array as usize][tidx as usize];
                    v = match t.func {
                        TapFunc::None => v,
                        TapFunc::Sin => v.sin(),
                        TapFunc::Cos => v.cos(),
                    };
                    acc = t.coef.mul_add(v, acc);
                }
                expected[idx] = acc;
            }
        }
    }

    Workload {
        kernel,
        cfg,
        mem,
        out_ptr: out,
        out_len: n,
        expected,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suite_has_16_benchmarks() {
        let s = suite();
        assert_eq!(s.len(), 16);
        let names: Vec<&str> = s.iter().map(|b| b.name).collect();
        assert_eq!(
            names,
            vec![
                "divergence",
                "gameoflife",
                "gaussblur",
                "gradient",
                "jacobi",
                "lapgsrb",
                "laplacian",
                "matmul",
                "matvec",
                "sincos",
                "tricubic",
                "tricubic2",
                "uxx1",
                "vecadd",
                "wave13pt",
                "whispering"
            ]
        );
    }

    #[test]
    fn static_load_counts_match_table2() {
        for b in suite() {
            let k = generate(&b);
            assert_eq!(
                k.global_loads() - b.divergent as usize,
                b.expect_loads - b.divergent as usize,
                "{}: generated loads (incl flag) = {}",
                b.name,
                k.global_loads()
            );
            assert_eq!(k.global_loads(), b.expect_loads, "{}", b.name);
        }
    }

    #[test]
    fn workload_fingerprints_are_stable_and_distinct() {
        let b = by_name("jacobi").unwrap();
        let a = workload_fingerprint(&b, 8, 8, 1, 42);
        assert_eq!(a, workload_fingerprint(&b, 8, 8, 1, 42), "must be deterministic");
        assert_ne!(a, workload_fingerprint(&b, 8, 8, 1, 43), "seed is part of the key");
        assert_ne!(a, workload_fingerprint(&b, 16, 8, 1, 42), "sizes are part of the key");
        let other = by_name("gradient").unwrap();
        assert_ne!(
            a,
            workload_fingerprint(&other, 8, 8, 1, 42),
            "the input-generation spec is part of the key"
        );
    }

    #[test]
    fn langs_match_table2() {
        let fortran: Vec<&str> = suite()
            .iter()
            .filter(|b| b.lang == Lang::Fortran)
            .map(|b| b.name)
            .collect();
        assert_eq!(fortran, vec!["jacobi", "matmul", "sincos"]);
    }

    #[test]
    fn dims_match_paper() {
        for b in suite() {
            let expect_2d = matches!(
                b.name,
                "gameoflife" | "gaussblur" | "jacobi" | "matmul" | "matvec" | "whispering"
            );
            assert_eq!(b.dims == 2, expect_2d, "{}", b.name);
        }
    }
}
