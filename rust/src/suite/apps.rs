//! §8.5 application kernels: complex 3D stencils from [27] (Rawat et al.).
//!
//! `hypterm` (compressible Navier-Stokes mini-app, 3 kernels over 13
//! arrays), `rhs4th3fort` and `derivative` (SW4 seismic-wave stencils over
//! 7 / 10 arrays). Footprints regenerate the paper's per-kernel access
//! counts (152/179/166 elements) and its |N| ≤ 1 shuffle yields
//! (12/48 on the leading-dim hypterm kernel, 44/179, 52/166).

use super::spec::{Benchmark, Lang, Pattern, Tap};

/// Build `pairs` adjacent {di-1, di} pairs plus `singles` lone taps, spread
/// over `arrays` arrays at distinct (dj, dk) rows.
fn paired_footprint(arrays: u32, pairs: usize, singles: usize) -> Vec<Tap> {
    // distinct (dj, dk) rows in a deterministic spiral, allocated per array
    let mut rows = Vec::new();
    for r in 0i64..6 {
        for dj in -r..=r {
            for dk in -r..=r {
                if dj.abs().max(dk.abs()) == r {
                    rows.push((dj, dk));
                }
            }
        }
    }
    let mut next_row = vec![0usize; arrays as usize];
    let mut take = |a: u32| {
        let (dj, dk) = rows[next_row[a as usize]];
        next_row[a as usize] += 1;
        (dj, dk)
    };
    let mut taps = Vec::new();
    for p in 0..pairs {
        let a = (p as u32) % arrays;
        let (dj, dk) = take(a);
        taps.push(Tap::new(a, -1, dj, dk, 0.25));
        taps.push(Tap::new(a, 0, dj, dk, 0.25));
    }
    for s in 0..singles {
        let a = (s as u32) % arrays;
        let (dj, dk) = take(a);
        taps.push(Tap::new(a, 0, dj, dk, 0.125));
    }
    taps
}

/// Rows along a non-leading dimension only (the hypterm y/z kernels: no
/// leading-dimension neighbors → no shuffles).
fn crosswise_footprint(arrays: u32, count: usize, use_k: bool) -> Vec<Tap> {
    let mut taps = Vec::new();
    for c in 0..count {
        let a = (c as u32) % arrays;
        let off = (c as i64 % 9) - 4;
        let (dj, dk) = if use_k { (0, off) } else { (off, 0) };
        taps.push(Tap::new(a, 0, dj, dk, 0.1));
    }
    taps
}

fn app(name: &'static str, arrays_hint: u32, taps: Vec<Tap>, shuffles: usize, delta: Option<f64>) -> Benchmark {
    let loads = taps.len();
    let _ = arrays_hint;
    Benchmark {
        name,
        lang: Lang::C,
        dims: 3,
        pattern: Pattern::Stencil { taps },
        divergent: false,
        expect_shuffles: shuffles,
        expect_loads: loads,
        expect_delta: delta,
    }
}

/// hypterm kernel for the leading (x) dimension: 48 loads over 8 of the 13
/// arrays; 12 adjacent pairs → 12 shuffles at |N| = 1.
pub fn hypterm_x() -> Benchmark {
    app("hypterm_x", 8, paired_footprint(8, 12, 24), 12, Some(1.0))
}

/// hypterm y-direction kernel: no leading-dim neighbors → 0 shuffles.
pub fn hypterm_y() -> Benchmark {
    app("hypterm_y", 8, crosswise_footprint(8, 52, false), 0, None)
}

/// hypterm z-direction kernel: no leading-dim neighbors → 0 shuffles.
pub fn hypterm_z() -> Benchmark {
    app("hypterm_z", 8, crosswise_footprint(8, 52, true), 0, None)
}

/// rhs4th3fort: 179 loads over 7 arrays, 44 pairs → 44 shuffles.
pub fn rhs4th3fort() -> Benchmark {
    app("rhs4th3fort", 7, paired_footprint(7, 44, 91), 44, Some(1.0))
}

/// derivative: 166 loads over 10 arrays, 52 pairs → 52 shuffles.
pub fn derivative() -> Benchmark {
    app("derivative", 10, paired_footprint(10, 52, 62), 52, Some(1.0))
}

/// The §8.5 kernels in paper order.
pub fn apps() -> Vec<Benchmark> {
    vec![
        hypterm_x(),
        hypterm_y(),
        hypterm_z(),
        rhs4th3fort(),
        derivative(),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn footprints_match_section85() {
        assert_eq!(hypterm_x().expect_loads, 48);
        assert_eq!(rhs4th3fort().expect_loads, 179);
        assert_eq!(derivative().expect_loads, 166);
        // hypterm total across 3 kernels ≈ the paper's 152 elements/thread
        let total: usize = [hypterm_x(), hypterm_y(), hypterm_z()]
            .iter()
            .map(|b| b.expect_loads)
            .sum();
        assert_eq!(total, 152);
    }

    #[test]
    fn pair_rows_are_distinct() {
        // no two taps of the same array may share (di, dj, dk)
        for b in apps() {
            let Pattern::Stencil { taps } = &b.pattern else {
                unreachable!()
            };
            let mut seen = std::collections::HashSet::new();
            for t in taps {
                assert!(
                    seen.insert((t.array, t.di, t.dj, t.dk)),
                    "{}: duplicate tap {:?}",
                    b.name,
                    t
                );
            }
        }
    }
}
