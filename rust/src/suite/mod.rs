//! The benchmark substrate: KernelGen suite (Table 2) + §8.5 app kernels,
//! generated as NVHPC-shaped PTX, with simulator workloads and bit-exact
//! CPU references.

pub mod apps;
pub mod codegen;
pub mod kernelgen;
pub mod spec;

pub use apps::apps;
pub use codegen::{generate, param_names};
pub use kernelgen::{
    by_name, shared_suite, suite, workload, workload_fingerprint, Workload,
    WorkloadFingerprint, WORKLOAD_SPEC_VERSION,
};
pub use spec::{irow, shared_stencil_coef, Benchmark, Lang, Pattern, Tap, TapFunc};
