//! The benchmark substrate: KernelGen suite (Table 2) + §8.5 app kernels,
//! generated as NVHPC-shaped PTX, with simulator workloads and bit-exact
//! CPU references.

pub mod apps;
pub mod codegen;
pub mod kernelgen;
pub mod spec;

pub use apps::apps;
pub use codegen::{generate, param_names};
pub use kernelgen::{
    by_name, suite, workload, workload_fingerprint, Workload, WorkloadFingerprint,
    WORKLOAD_SPEC_VERSION,
};
pub use spec::{irow, Benchmark, Lang, Pattern, Tap, TapFunc};
