//! NVHPC-shaped PTX code generation from benchmark specs.
//!
//! Plays the role of the NVHPC OpenACC compiler in the paper's pipeline
//! (DESIGN.md substitution table) and reproduces its idioms: gang
//! parallelism on the outermost loop (`%ctaid.x`), vector parallelism on
//! the innermost (`%tid.x`), a strip-mine loop stepping by `%ntid.x` for 2D
//! kernels, a sequential middle loop for 3D kernels, per-(array,row) base
//! registers, `ld.global.nc` for `independent`-annotated read arrays, and
//! `fma.rn` accumulation chains.

use super::spec::{shared_stencil_coef, Benchmark, Pattern, Tap, TapFunc};
use crate::ptx::ast::*;
use std::collections::BTreeMap;

/// Tiny register allocator + statement buffer.
struct B {
    body: Vec<Statement>,
    shared: Vec<SharedDecl>,
    nr: u32,
    nrd: u32,
    nf: u32,
    np: u32,
}

impl B {
    fn new() -> B {
        B {
            body: Vec::new(),
            shared: Vec::new(),
            nr: 0,
            nrd: 0,
            nf: 0,
            np: 0,
        }
    }
    fn r(&mut self) -> Reg {
        self.nr += 1;
        Reg::new(format!("%r{}", self.nr))
    }
    fn rd(&mut self) -> Reg {
        self.nrd += 1;
        Reg::new(format!("%rd{}", self.nrd))
    }
    fn f(&mut self) -> Reg {
        self.nf += 1;
        Reg::new(format!("%f{}", self.nf))
    }
    fn p(&mut self) -> Reg {
        self.np += 1;
        Reg::new(format!("%p{}", self.np))
    }
    fn push(&mut self, op: Op) {
        self.body.push(Statement::instr(op));
    }
    fn guarded(&mut self, pred: &Reg, op: Op) {
        self.body.push(Statement::guarded(&pred.0, false, op));
    }
    fn label(&mut self, l: &str) {
        self.body.push(Statement::Label(l.to_string()));
    }

    // -- common emission helpers ------------------------------------------

    fn ld_param_u64(&mut self, name: &str) -> Reg {
        let d = self.rd();
        self.push(Op::Ld {
            space: Space::Param,
            nc: false,
            ty: Type::U64,
            dst: d.clone(),
            addr: Address {
                base: Operand::Var(name.to_string()),
                offset: 0,
            },
        });
        d
    }

    fn ld_param_u32(&mut self, name: &str) -> Reg {
        let d = self.r();
        self.push(Op::Ld {
            space: Space::Param,
            nc: false,
            ty: Type::U32,
            dst: d.clone(),
            addr: Address {
                base: Operand::Var(name.to_string()),
                offset: 0,
            },
        });
        d
    }

    fn cvta(&mut self, src: &Reg) -> Reg {
        let d = self.rd();
        self.push(Op::Cvta {
            to_global: true,
            dst: d.clone(),
            src: Operand::Reg(src.clone()),
        });
        d
    }

    fn mov_special(&mut self, sp: Special) -> Reg {
        let d = self.r();
        self.push(Op::Mov {
            ty: Type::U32,
            dst: d.clone(),
            src: Operand::Special(sp),
        });
        d
    }

    fn addi(&mut self, a: &Reg, imm: i64) -> Reg {
        if imm == 0 {
            return a.clone();
        }
        let d = self.r();
        self.push(Op::IntBin {
            op: IntBinOp::Add,
            ty: Type::S32,
            dst: d.clone(),
            a: Operand::Reg(a.clone()),
            b: Operand::ImmInt(imm as i128),
        });
        d
    }

    /// `dst = a*b + c` (s32).
    fn mad(&mut self, a: Operand, bo: Operand, c: Operand) -> Reg {
        let d = self.r();
        self.push(Op::Mad {
            wide: false,
            ty: Type::S32,
            dst: d.clone(),
            a,
            b: bo,
            c,
        });
        d
    }

    /// Byte address of `base + 4*sext(idx)`.
    fn elem_addr(&mut self, base: &Reg, idx: &Reg) -> Reg {
        let off = self.rd();
        self.push(Op::IntBin {
            op: IntBinOp::MulWide,
            ty: Type::S32,
            dst: off.clone(),
            a: Operand::Reg(idx.clone()),
            b: Operand::ImmInt(4),
        });
        let d = self.rd();
        self.push(Op::IntBin {
            op: IntBinOp::Add,
            ty: Type::S64,
            dst: d.clone(),
            a: Operand::Reg(base.clone()),
            b: Operand::Reg(off.clone()),
        });
        d
    }

    fn ld_f32(&mut self, addr: &Reg, byte_off: i64, nc: bool) -> Reg {
        let d = self.f();
        self.push(Op::Ld {
            space: Space::Global,
            nc,
            ty: Type::F32,
            dst: d.clone(),
            addr: Address {
                base: Operand::Reg(addr.clone()),
                offset: byte_off,
            },
        });
        d
    }

    // -- shared-memory / barrier helpers ----------------------------------

    /// Declare a `.shared .align 4 .b8 name[bytes]` window.
    fn shared_decl(&mut self, name: &str, bytes: u64) {
        self.shared.push(SharedDecl {
            name: name.to_string(),
            align: 4,
            bytes,
        });
    }

    /// `mov.u64 %rd, name` — the shared window's base address.
    fn mov_var_u64(&mut self, name: &str) -> Reg {
        let d = self.rd();
        self.push(Op::Mov {
            ty: Type::U64,
            dst: d.clone(),
            src: Operand::Var(name.to_string()),
        });
        d
    }

    fn bar_sync(&mut self, id: u32) {
        self.push(Op::BarSync { id, cnt: None });
    }

    /// `setp.<cmp>.s32 %p, a, imm`.
    fn setp_imm(&mut self, cmp: CmpOp, a: &Reg, imm: i64) -> Reg {
        let d = self.p();
        self.push(Op::Setp {
            cmp,
            ty: Type::S32,
            dst: d.clone(),
            a: Operand::Reg(a.clone()),
            b: Operand::ImmInt(imm as i128),
        });
        d
    }

    fn ld_shared_f32(&mut self, guard: Option<&Reg>, addr: &Reg, byte_off: i64) -> Reg {
        let d = self.f();
        let op = Op::Ld {
            space: Space::Shared,
            nc: false,
            ty: Type::F32,
            dst: d.clone(),
            addr: Address {
                base: Operand::Reg(addr.clone()),
                offset: byte_off,
            },
        };
        match guard {
            None => self.push(op),
            Some(p) => self.guarded(p, op),
        }
        d
    }

    fn st_shared_f32(&mut self, guard: Option<&Reg>, addr: &Reg, byte_off: i64, src: &Reg) {
        let op = Op::St {
            space: Space::Shared,
            ty: Type::F32,
            addr: Address {
                base: Operand::Reg(addr.clone()),
                offset: byte_off,
            },
            src: Operand::Reg(src.clone()),
        };
        match guard {
            None => self.push(op),
            Some(p) => self.guarded(p, op),
        }
    }
}

/// Parameter names of a benchmark kernel, in declaration order.
pub fn param_names(b: &Benchmark) -> Vec<String> {
    let mut v = vec!["out".to_string()];
    match &b.pattern {
        Pattern::Stencil { .. } => {
            for a in 0..b.input_arrays() {
                v.push(format!("in{a}"));
            }
            if b.divergent {
                v.push("flags".into());
            }
            v.push("nx".into());
            if b.dims >= 2 {
                v.push("ny".into());
            }
            if b.dims >= 3 {
                v.push("nz".into());
            }
        }
        Pattern::MatMul { .. } => {
            v.extend(["a".into(), "b".into(), "nx".into(), "ny".into(), "nk".into()]);
        }
        Pattern::MatVec { .. } => {
            v.extend(["a".into(), "x".into(), "nx".into(), "nk".into()]);
        }
        Pattern::SinCos | Pattern::VecAdd => {
            v.extend(["in0".into(), "in1".into(), "nx".into(), "ny".into(), "nz".into()]);
        }
        Pattern::TiledReduce { .. } => {
            v.push("in0".into());
        }
        Pattern::SharedStencil { .. } => {
            v.extend(["in0".into(), "nx".into()]);
        }
        Pattern::SharedGather { .. } => {
            v.extend(["in0".into(), "in1".into()]);
        }
    }
    v
}

/// Generate the PTX kernel for a benchmark.
pub fn generate(bench: &Benchmark) -> Kernel {
    let mut b = B::new();
    match &bench.pattern {
        Pattern::Stencil { taps } => gen_stencil(&mut b, bench, taps),
        Pattern::MatMul { unroll } => gen_matmul(&mut b, *unroll),
        Pattern::MatVec { unroll } => gen_matvec(&mut b, *unroll),
        Pattern::SinCos => {
            let taps = vec![
                Tap::new(0, 0, 0, 0, 1.0).with_func(TapFunc::Sin),
                Tap::new(1, 0, 0, 0, 1.0).with_func(TapFunc::Cos),
            ];
            gen_stencil(&mut b, bench, &taps)
        }
        Pattern::VecAdd => {
            let taps = vec![Tap::new(0, 0, 0, 0, 1.0), Tap::new(1, 0, 0, 0, 1.0)];
            gen_stencil(&mut b, bench, &taps)
        }
        Pattern::TiledReduce { block } => gen_tiledreduce(&mut b, *block),
        Pattern::SharedStencil { radius, block } => {
            gen_sharedstencil(&mut b, *radius, *block)
        }
        Pattern::SharedGather { block } => gen_sharedgather(&mut b, *block),
    }

    let params = param_names(bench)
        .into_iter()
        .map(|name| Param {
            ty: if name.starts_with('n') { Type::U32 } else { Type::U64 },
            name,
        })
        .collect();

    Kernel {
        name: bench.name.replace('-', "_"),
        params,
        regs: vec![
            RegDecl {
                ty: Type::Pred,
                prefix: "%p".into(),
                count: b.np + 1,
            },
            RegDecl {
                ty: Type::B32,
                prefix: "%r".into(),
                count: b.nr + 1,
            },
            RegDecl {
                ty: Type::F32,
                prefix: "%f".into(),
                count: b.nf + 1,
            },
            RegDecl {
                ty: Type::B64,
                prefix: "%rd".into(),
                count: b.nrd + 1,
            },
        ],
        shared: b.shared,
        body: b.body,
    }
}

/// Per-block tree reduction through `.shared`: every thread stages one
/// element, then `log2(block)` guarded halving rounds with a `bar.sync`
/// between each — the canonical shared-memory-communicating kernel. The
/// tree is fully unrolled for the fixed `block` size (predication only,
/// no control flow), so every warp reaches every barrier with all lanes.
fn gen_tiledreduce(b: &mut B, block: u32) {
    assert!(
        block.is_power_of_two() && block % 32 == 0 && block <= 1024,
        "tiled reduction needs a power-of-two block of whole warps"
    );
    b.shared_decl("sdata", block as u64 * 4);
    let pout = b.ld_param_u64("out");
    let out_base = b.cvta(&pout);
    let pin = b.ld_param_u64("in0");
    let in_base = b.cvta(&pin);
    let tid = b.mov_special(Special::TidX);
    let ntid = b.mov_special(Special::NtidX);
    let cta = b.mov_special(Special::CtaidX);
    let i = b.mad(
        Operand::Reg(cta.clone()),
        Operand::Reg(ntid),
        Operand::Reg(tid.clone()),
    );
    // stage a[i] into sdata[tid]
    let iaddr = b.elem_addr(&in_base, &i);
    let v = b.ld_f32(&iaddr, 0, true);
    let sbase = b.mov_var_u64("sdata");
    let saddr = b.elem_addr(&sbase, &tid);
    b.st_shared_f32(None, &saddr, 0, &v);
    b.bar_sync(0);
    // unrolled tree: sdata[tid] += sdata[tid + s] for tid < s
    let mut s = block / 2;
    while s >= 1 {
        let p = b.setp_imm(CmpOp::Lt, &tid, s as i64);
        let fa = b.ld_shared_f32(Some(&p), &saddr, 0);
        let fb = b.ld_shared_f32(Some(&p), &saddr, s as i64 * 4);
        let fc = b.f();
        b.guarded(
            &p,
            Op::FltBin {
                op: FltBinOp::Add,
                ty: Type::F32,
                dst: fc.clone(),
                a: Operand::Reg(fa),
                b: Operand::Reg(fb),
            },
        );
        b.st_shared_f32(Some(&p), &saddr, 0, &fc);
        b.bar_sync(0);
        s /= 2;
    }
    // thread 0 publishes the block sum
    let pz = b.setp_imm(CmpOp::Eq, &tid, 0);
    let fo = b.ld_shared_f32(Some(&pz), &sbase, 0);
    let oaddr = b.elem_addr(&out_base, &cta);
    b.guarded(
        &pz,
        Op::St {
            space: Space::Global,
            ty: Type::F32,
            addr: Address {
                base: Operand::Reg(oaddr),
                offset: 0,
            },
            src: Operand::Reg(fo),
        },
    );
    b.push(Op::Ret);
}

/// 1D uniform stencil staged through `.shared`: the block stages its tile
/// plus a clamped halo (predicated edge lanes), one `bar.sync`, then every
/// thread combines `2·radius+1` shared taps with an fma chain.
fn gen_sharedstencil(b: &mut B, radius: i64, block: u32) {
    assert!(radius >= 1 && block % 32 == 0 && block as i64 > 2 * radius);
    b.shared_decl("stile", (block as i64 + 2 * radius) as u64 * 4);
    let pout = b.ld_param_u64("out");
    let out_base = b.cvta(&pout);
    let pin = b.ld_param_u64("in0");
    let in_base = b.cvta(&pin);
    let nx = b.ld_param_u32("nx");
    let tid = b.mov_special(Special::TidX);
    let ntid = b.mov_special(Special::NtidX);
    let cta = b.mov_special(Special::CtaidX);
    let i = b.mad(
        Operand::Reg(cta),
        Operand::Reg(ntid),
        Operand::Reg(tid.clone()),
    );
    // center: stile[tid + radius] = a[i]
    let iaddr = b.elem_addr(&in_base, &i);
    let v = b.ld_f32(&iaddr, 0, true);
    let sbase = b.mov_var_u64("stile");
    let stid = b.elem_addr(&sbase, &tid);
    b.st_shared_f32(None, &stid, radius * 4, &v);
    // left halo (lanes tid < radius): stile[tid] = a[max(i - radius, 0)]
    let pl = b.setp_imm(CmpOp::Lt, &tid, radius);
    let il = b.addi(&i, -radius);
    let il2 = b.r();
    b.push(Op::IntBin {
        op: IntBinOp::Max,
        ty: Type::S32,
        dst: il2.clone(),
        a: Operand::Reg(il),
        b: Operand::ImmInt(0),
    });
    let laddr = b.elem_addr(&in_base, &il2);
    let fl = b.f();
    b.guarded(
        &pl,
        Op::Ld {
            space: Space::Global,
            nc: true,
            ty: Type::F32,
            dst: fl.clone(),
            addr: Address {
                base: Operand::Reg(laddr),
                offset: 0,
            },
        },
    );
    b.st_shared_f32(Some(&pl), &stid, 0, &fl);
    // right halo (lanes tid ≥ block - radius):
    // stile[tid + 2·radius] = a[min(i + radius, nx - 1)]
    let pr = b.setp_imm(CmpOp::Ge, &tid, block as i64 - radius);
    let ir = b.addi(&i, radius);
    let nm1 = b.addi(&nx, -1);
    let ir2 = b.r();
    b.push(Op::IntBin {
        op: IntBinOp::Min,
        ty: Type::S32,
        dst: ir2.clone(),
        a: Operand::Reg(ir),
        b: Operand::Reg(nm1),
    });
    let raddr = b.elem_addr(&in_base, &ir2);
    let fr = b.f();
    b.guarded(
        &pr,
        Op::Ld {
            space: Space::Global,
            nc: true,
            ty: Type::F32,
            dst: fr.clone(),
            addr: Address {
                base: Operand::Reg(raddr),
                offset: 0,
            },
        },
    );
    b.st_shared_f32(Some(&pr), &stid, 2 * radius * 4, &fr);
    b.bar_sync(0);
    // combine the 2·radius+1 shared taps (uniform coefficients)
    let coef = shared_stencil_coef(radius);
    let acc = b.f();
    b.push(Op::Mov {
        ty: Type::F32,
        dst: acc.clone(),
        src: Operand::ImmF32(0),
    });
    for di in -radius..=radius {
        let t = b.ld_shared_f32(None, &stid, (radius + di) * 4);
        let nacc = b.f();
        b.push(Op::Fma {
            ty: Type::F32,
            dst: nacc.clone(),
            a: Operand::ImmF32(coef.to_bits()),
            b: Operand::Reg(t),
            c: Operand::Reg(acc.clone()),
        });
        b.push(Op::Mov {
            ty: Type::F32,
            dst: acc.clone(),
            src: Operand::Reg(nacc),
        });
    }
    let oaddr = b.elem_addr(&out_base, &i);
    b.push(Op::St {
        space: Space::Global,
        ty: Type::F32,
        addr: Address {
            base: Operand::Reg(oaddr),
            offset: 0,
        },
        src: Operand::Reg(acc),
    });
    b.push(Op::Ret);
}

/// Data-dependent gather through `.shared`: every thread stages one
/// element, one `bar.sync`, then reads its own slot plus the slot named by
/// a runtime index (`in1[i] & (block-1)`). The second tap's address comes
/// from loaded data, so no static analysis can prove who wrote it — the
/// adversarial fixture pinning the phase-liveness pass's conservatism.
fn gen_sharedgather(b: &mut B, block: u32) {
    assert!(block.is_power_of_two() && block % 32 == 0);
    b.shared_decl("sg", block as u64 * 4);
    let pout = b.ld_param_u64("out");
    let out_base = b.cvta(&pout);
    let pin = b.ld_param_u64("in0");
    let in_base = b.cvta(&pin);
    let pix = b.ld_param_u64("in1");
    let ix_base = b.cvta(&pix);
    let tid = b.mov_special(Special::TidX);
    let ntid = b.mov_special(Special::NtidX);
    let cta = b.mov_special(Special::CtaidX);
    let i = b.mad(
        Operand::Reg(cta),
        Operand::Reg(ntid),
        Operand::Reg(tid.clone()),
    );
    // stage a[i] into sg[tid]
    let iaddr = b.elem_addr(&in_base, &i);
    let v = b.ld_f32(&iaddr, 0, true);
    let sbase = b.mov_var_u64("sg");
    let saddr = b.elem_addr(&sbase, &tid);
    b.st_shared_f32(None, &saddr, 0, &v);
    b.bar_sync(0);
    // own slot
    let tap0 = b.ld_shared_f32(None, &saddr, 0);
    // data-dependent slot: sg[in1[i] & (block-1)]
    let jaddr = b.elem_addr(&ix_base, &i);
    let idx = b.r();
    b.push(Op::Ld {
        space: Space::Global,
        nc: false,
        ty: Type::U32,
        dst: idx.clone(),
        addr: Address {
            base: Operand::Reg(jaddr),
            offset: 0,
        },
    });
    let m = b.r();
    b.push(Op::IntBin {
        op: IntBinOp::And,
        ty: Type::B32,
        dst: m.clone(),
        a: Operand::Reg(idx),
        b: Operand::ImmInt(block as i128 - 1),
    });
    let gaddr = b.elem_addr(&sbase, &m);
    let tap1 = b.ld_shared_f32(None, &gaddr, 0);
    let sum = b.f();
    b.push(Op::FltBin {
        op: FltBinOp::Add,
        ty: Type::F32,
        dst: sum.clone(),
        a: Operand::Reg(tap0),
        b: Operand::Reg(tap1),
    });
    let oaddr = b.elem_addr(&out_base, &i);
    b.push(Op::St {
        space: Space::Global,
        ty: Type::F32,
        addr: Address {
            base: Operand::Reg(oaddr),
            offset: 0,
        },
        src: Operand::Reg(sum),
    });
    b.push(Op::Ret);
}

/// Shared stencil scaffolding for 2D (strip-mine i loop) and 3D
/// (sequential middle j loop) kernels; sincos/vecadd run as degenerate
/// single-tap stencils over the same scaffolding.
fn gen_stencil(b: &mut B, bench: &Benchmark, taps: &[Tap]) {
    let dims = bench.dims;
    let (hi, hj, hk) = (
        taps.iter().map(|t| t.di.abs()).max().unwrap_or(0),
        taps.iter().map(|t| t.dj.abs()).max().unwrap_or(0),
        taps.iter().map(|t| t.dk.abs()).max().unwrap_or(0),
    );

    // prologue: params
    let pout = b.ld_param_u64("out");
    let out_base = b.cvta(&pout);
    let narr = taps.iter().map(|t| t.array).max().unwrap_or(0) + 1;
    let mut in_bases = Vec::new();
    for a in 0..narr {
        let p = b.ld_param_u64(&format!("in{a}"));
        in_bases.push(b.cvta(&p));
    }
    let flags_base = if bench.divergent {
        let p = b.ld_param_u64("flags");
        Some(b.cvta(&p))
    } else {
        None
    };
    let nx = b.ld_param_u32("nx");
    let ny = if dims >= 2 { Some(b.ld_param_u32("ny")) } else { None };
    let nz = if dims >= 3 { Some(b.ld_param_u32("nz")) } else { None };

    let exit = "$L_EXIT";

    if dims == 3 {
        // k = ctaid.x + hk ; guard k >= nz - hk
        let ctaid = b.mov_special(Special::CtaidX);
        let k = b.addi(&ctaid, hk);
        let klim = b.addi(nz.as_ref().unwrap(), -hk);
        let pk = b.p();
        b.push(Op::Setp {
            cmp: CmpOp::Ge,
            ty: Type::S32,
            dst: pk.clone(),
            a: Operand::Reg(k.clone()),
            b: Operand::Reg(klim),
        });
        b.guarded(&pk, Op::Bra { uni: false, target: exit.into() });

        // i = tid.x + hi ; guard i >= nx - hi
        let tid = b.mov_special(Special::TidX);
        let i = b.addi(&tid, hi);
        let ilim = b.addi(&nx, -hi);
        let pi = b.p();
        b.push(Op::Setp {
            cmp: CmpOp::Ge,
            ty: Type::S32,
            dst: pi.clone(),
            a: Operand::Reg(i.clone()),
            b: Operand::Reg(ilim),
        });
        b.guarded(&pi, Op::Bra { uni: false, target: exit.into() });

        // j sequential loop
        let j = b.r();
        b.push(Op::Mov {
            ty: Type::U32,
            dst: j.clone(),
            src: Operand::ImmInt(hj as i128),
        });
        let jlim = b.addi(ny.as_ref().unwrap(), -hj);
        b.label("$L_JLOOP");
        let pj = b.p();
        b.push(Op::Setp {
            cmp: CmpOp::Ge,
            ty: Type::S32,
            dst: pj.clone(),
            a: Operand::Reg(j.clone()),
            b: Operand::Reg(jlim.clone()),
        });
        b.guarded(&pj, Op::Bra { uni: false, target: exit.into() });

        // idx = (k*ny + j)*nx + i
        let kny = b.mad(
            Operand::Reg(k.clone()),
            Operand::Reg(ny.clone().unwrap()),
            Operand::Reg(j.clone()),
        );
        let idx = b.mad(
            Operand::Reg(kny.clone()),
            Operand::Reg(nx.clone()),
            Operand::Reg(i.clone()),
        );

        stencil_body(
            b,
            bench,
            taps,
            &idx,
            &nx,
            ny.as_ref(),
            &in_bases,
            flags_base.as_ref(),
            &out_base,
            "$L_JLATCH",
        );

        b.label("$L_JLATCH");
        b.push(Op::IntBin {
            op: IntBinOp::Add,
            ty: Type::S32,
            dst: j.clone(),
            a: Operand::Reg(j.clone()),
            b: Operand::ImmInt(1),
        });
        b.push(Op::Bra {
            uni: false,
            target: "$L_JLOOP".into(),
        });
    } else {
        // 2D: j = ctaid.x + hj ; guard j >= ny - hj
        let ctaid = b.mov_special(Special::CtaidX);
        let j = b.addi(&ctaid, hj);
        let jlim = b.addi(ny.as_ref().unwrap(), -hj);
        let pj = b.p();
        b.push(Op::Setp {
            cmp: CmpOp::Ge,
            ty: Type::S32,
            dst: pj.clone(),
            a: Operand::Reg(j.clone()),
            b: Operand::Reg(jlim),
        });
        b.guarded(&pj, Op::Bra { uni: false, target: exit.into() });

        // strip-mine: i from tid.x + hi, step ntid.x
        let tid = b.mov_special(Special::TidX);
        let ntid = b.mov_special(Special::NtidX);
        let i = b.r();
        let i0 = b.addi(&tid, hi);
        b.push(Op::Mov {
            ty: Type::U32,
            dst: i.clone(),
            src: Operand::Reg(i0),
        });
        let ilim = b.addi(&nx, -hi);
        b.label("$L_ILOOP");
        let pi = b.p();
        b.push(Op::Setp {
            cmp: CmpOp::Ge,
            ty: Type::S32,
            dst: pi.clone(),
            a: Operand::Reg(i.clone()),
            b: Operand::Reg(ilim.clone()),
        });
        b.guarded(&pi, Op::Bra { uni: false, target: exit.into() });

        // idx = j*nx + i
        let idx = b.mad(
            Operand::Reg(j.clone()),
            Operand::Reg(nx.clone()),
            Operand::Reg(i.clone()),
        );

        stencil_body(
            b,
            bench,
            taps,
            &idx,
            &nx,
            ny.as_ref(),
            &in_bases,
            flags_base.as_ref(),
            &out_base,
            "$L_ILATCH",
        );

        b.label("$L_ILATCH");
        b.push(Op::IntBin {
            op: IntBinOp::Add,
            ty: Type::S32,
            dst: i.clone(),
            a: Operand::Reg(i.clone()),
            b: Operand::Reg(ntid.clone()),
        });
        b.push(Op::Bra {
            uni: false,
            target: "$L_ILOOP".into(),
        });
    }

    b.label(exit);
    b.push(Op::Ret);
}

/// The per-point body: optional divergence guard, row-base computation,
/// loads, fma combine, store. Jumps to `skip_label` when the flag is unset.
#[allow(clippy::too_many_arguments)]
fn stencil_body(
    b: &mut B,
    bench: &Benchmark,
    taps: &[Tap],
    idx: &Reg,
    nx: &Reg,
    ny: Option<&Reg>,
    in_bases: &[Reg],
    flags_base: Option<&Reg>,
    out_base: &Reg,
    skip_label: &str,
) {
    // data-dependent guard (Listing 1: `if (f[i])`)
    if let Some(fb) = flags_base {
        let fa = b.elem_addr(fb, idx);
        let fv = b.r();
        b.push(Op::Ld {
            space: Space::Global,
            nc: false,
            ty: Type::U32,
            dst: fv.clone(),
            addr: Address {
                base: Operand::Reg(fa),
                offset: 0,
            },
        });
        let pz = b.p();
        b.push(Op::Setp {
            cmp: CmpOp::Eq,
            ty: Type::S32,
            dst: pz.clone(),
            a: Operand::Reg(fv),
            b: Operand::ImmInt(0),
        });
        b.guarded(&pz, Op::Bra { uni: false, target: skip_label.into() });
    }

    // plane stride for 3D rows: nxny = nx*ny
    let needs_plane = taps.iter().any(|t| t.dk != 0);
    let nxny = if needs_plane {
        let r = b.r();
        b.push(Op::IntBin {
            op: IntBinOp::MulLo,
            ty: Type::S32,
            dst: r.clone(),
            a: Operand::Reg(nx.clone()),
            b: Operand::Reg(ny.expect("3D taps need ny").clone()),
        });
        Some(r)
    } else {
        None
    };

    // row base addresses per distinct (array, dj, dk)
    let mut rows: BTreeMap<(u32, i64, i64), Reg> = BTreeMap::new();
    for t in taps {
        rows.entry((t.array, t.dj, t.dk)).or_insert_with(|| {
            // row_idx = idx + dj*nx + dk*nx*ny
            let mut cur = idx.clone();
            if t.dj != 0 {
                cur = b.mad(
                    Operand::Reg(nx.clone()),
                    Operand::ImmInt(t.dj as i128),
                    Operand::Reg(cur),
                );
            }
            if t.dk != 0 {
                cur = b.mad(
                    Operand::Reg(nxny.clone().expect("plane stride")),
                    Operand::ImmInt(t.dk as i128),
                    Operand::Reg(cur),
                );
            }
            b.elem_addr(&in_bases[t.array as usize], &cur)
        });
    }

    // loads in tap order, then fma combine in the same order
    let mut loaded: Vec<(Reg, &Tap)> = Vec::new();
    for t in taps {
        let row = rows[&(t.array, t.dj, t.dk)].clone();
        let v = b.ld_f32(&row, t.di * 4, true);
        loaded.push((v, t));
    }
    let acc = b.f();
    b.push(Op::Mov {
        ty: Type::F32,
        dst: acc.clone(),
        src: Operand::ImmF32(0),
    });
    for (v, t) in loaded {
        let v = match t.func {
            TapFunc::None => v,
            TapFunc::Sin => {
                let d = b.f();
                b.push(Op::FltUn {
                    op: FltUnOp::Sin,
                    ty: Type::F32,
                    dst: d.clone(),
                    a: Operand::Reg(v),
                });
                d
            }
            TapFunc::Cos => {
                let d = b.f();
                b.push(Op::FltUn {
                    op: FltUnOp::Cos,
                    ty: Type::F32,
                    dst: d.clone(),
                    a: Operand::Reg(v),
                });
                d
            }
        };
        let nacc = b.f();
        b.push(Op::Fma {
            ty: Type::F32,
            dst: nacc.clone(),
            a: Operand::ImmF32(t.coef.to_bits()),
            b: Operand::Reg(v),
            c: Operand::Reg(acc.clone()),
        });
        // keep the accumulator a single register chain
        b.push(Op::Mov {
            ty: Type::F32,
            dst: acc.clone(),
            src: Operand::Reg(nacc),
        });
    }

    let oa = b.elem_addr(out_base, idx);
    b.push(Op::St {
        space: Space::Global,
        ty: Type::F32,
        addr: Address {
            base: Operand::Reg(oa),
            offset: 0,
        },
        src: Operand::Reg(acc),
    });
    let _ = bench;
}

/// C[j,i] = Σ_k A[j,k]·B[k,i], inner loop unrolled. No shuffle chances:
/// A-loads are tid-invariant, B-loads differ by a symbolic row stride.
fn gen_matmul(b: &mut B, unroll: u32) {
    let pc = b.ld_param_u64("out");
    let cbase = b.cvta(&pc);
    let pa = b.ld_param_u64("a");
    let abase = b.cvta(&pa);
    let pb = b.ld_param_u64("b");
    let bbase = b.cvta(&pb);
    let nx = b.ld_param_u32("nx");
    let ny = b.ld_param_u32("ny");
    let nk = b.ld_param_u32("nk");

    let exit = "$L_EXIT";
    let j = b.mov_special(Special::CtaidX);
    let pj = b.p();
    b.push(Op::Setp {
        cmp: CmpOp::Ge,
        ty: Type::S32,
        dst: pj.clone(),
        a: Operand::Reg(j.clone()),
        b: Operand::Reg(ny.clone()),
    });
    b.guarded(&pj, Op::Bra { uni: false, target: exit.into() });
    let tid = b.mov_special(Special::TidX);
    let ntid = b.mov_special(Special::NtidX);
    let ctay = b.mov_special(Special::CtaidY);
    let i = b.mad(
        Operand::Reg(ctay),
        Operand::Reg(ntid),
        Operand::Reg(tid),
    );
    let pi = b.p();
    b.push(Op::Setp {
        cmp: CmpOp::Ge,
        ty: Type::S32,
        dst: pi.clone(),
        a: Operand::Reg(i.clone()),
        b: Operand::Reg(nx.clone()),
    });
    b.guarded(&pi, Op::Bra { uni: false, target: exit.into() });

    let acc = b.f();
    b.push(Op::Mov {
        ty: Type::F32,
        dst: acc.clone(),
        src: Operand::ImmF32(0),
    });
    let kreg = b.r();
    b.push(Op::Mov {
        ty: Type::U32,
        dst: kreg.clone(),
        src: Operand::ImmInt(0),
    });

    b.label("$L_KLOOP");
    // a_row = j*nk + k ; four consecutive a loads
    let arow = b.mad(
        Operand::Reg(j.clone()),
        Operand::Reg(nk.clone()),
        Operand::Reg(kreg.clone()),
    );
    let aaddr = b.elem_addr(&abase, &arow);
    // b_idx = k*nx + i ; B loads stride by nx between unrolled steps
    let bidx = b.mad(
        Operand::Reg(kreg.clone()),
        Operand::Reg(nx.clone()),
        Operand::Reg(i.clone()),
    );
    let baddr0 = b.elem_addr(&bbase, &bidx);
    let nx4 = b.rd();
    b.push(Op::IntBin {
        op: IntBinOp::MulWide,
        ty: Type::S32,
        dst: nx4.clone(),
        a: Operand::Reg(nx.clone()),
        b: Operand::ImmInt(4),
    });
    let mut baddr = baddr0;
    for u in 0..unroll {
        let av = b.ld_f32(&aaddr, (u as i64) * 4, true);
        let bv = b.ld_f32(&baddr, 0, true);
        let nacc = b.f();
        b.push(Op::Fma {
            ty: Type::F32,
            dst: nacc.clone(),
            a: Operand::Reg(av),
            b: Operand::Reg(bv),
            c: Operand::Reg(acc.clone()),
        });
        b.push(Op::Mov {
            ty: Type::F32,
            dst: acc.clone(),
            src: Operand::Reg(nacc),
        });
        if u + 1 < unroll {
            let next = b.rd();
            b.push(Op::IntBin {
                op: IntBinOp::Add,
                ty: Type::S64,
                dst: next.clone(),
                a: Operand::Reg(baddr.clone()),
                b: Operand::Reg(nx4.clone()),
            });
            baddr = next;
        }
    }
    b.push(Op::IntBin {
        op: IntBinOp::Add,
        ty: Type::S32,
        dst: kreg.clone(),
        a: Operand::Reg(kreg.clone()),
        b: Operand::ImmInt(unroll as i128),
    });
    let pk = b.p();
    b.push(Op::Setp {
        cmp: CmpOp::Lt,
        ty: Type::S32,
        dst: pk.clone(),
        a: Operand::Reg(kreg.clone()),
        b: Operand::Reg(nk.clone()),
    });
    b.guarded(&pk, Op::Bra { uni: false, target: "$L_KLOOP".into() });

    // C[j,i]
    let cidx = b.mad(
        Operand::Reg(j.clone()),
        Operand::Reg(nx.clone()),
        Operand::Reg(i.clone()),
    );
    let caddr = b.elem_addr(&cbase, &cidx);
    b.push(Op::St {
        space: Space::Global,
        ty: Type::F32,
        addr: Address {
            base: Operand::Reg(caddr),
            offset: 0,
        },
        src: Operand::Reg(acc),
    });
    b.label(exit);
    b.push(Op::Ret);
}

/// y[i] += Σ_k A[i,k]·x[k]: 2·unroll + 1 loads; A-loads stride by the
/// symbolic row pitch w.r.t. tid, so nothing shuffles.
fn gen_matvec(b: &mut B, unroll: u32) {
    let py = b.ld_param_u64("out");
    let ybase = b.cvta(&py);
    let pa = b.ld_param_u64("a");
    let abase = b.cvta(&pa);
    let px = b.ld_param_u64("x");
    let xbase = b.cvta(&px);
    let nx = b.ld_param_u32("nx");
    let nk = b.ld_param_u32("nk");

    let exit = "$L_EXIT";
    let tid = b.mov_special(Special::TidX);
    let ntid = b.mov_special(Special::NtidX);
    let cta = b.mov_special(Special::CtaidX);
    let i = b.mad(Operand::Reg(cta), Operand::Reg(ntid), Operand::Reg(tid));
    let pi = b.p();
    b.push(Op::Setp {
        cmp: CmpOp::Ge,
        ty: Type::S32,
        dst: pi.clone(),
        a: Operand::Reg(i.clone()),
        b: Operand::Reg(nx.clone()),
    });
    b.guarded(&pi, Op::Bra { uni: false, target: exit.into() });

    // y[i] read-modify-write (the +1 load)
    let yidx = b.elem_addr(&ybase, &i);
    let acc = b.ld_f32(&yidx, 0, false);

    let kreg = b.r();
    b.push(Op::Mov {
        ty: Type::U32,
        dst: kreg.clone(),
        src: Operand::ImmInt(0),
    });
    b.label("$L_KLOOP");
    let arow = b.mad(
        Operand::Reg(i.clone()),
        Operand::Reg(nk.clone()),
        Operand::Reg(kreg.clone()),
    );
    let aaddr = b.elem_addr(&abase, &arow);
    let xaddr = b.elem_addr(&xbase, &kreg);
    for u in 0..unroll {
        let av = b.ld_f32(&aaddr, (u as i64) * 4, true);
        let xv = b.ld_f32(&xaddr, (u as i64) * 4, true);
        let nacc = b.f();
        b.push(Op::Fma {
            ty: Type::F32,
            dst: nacc.clone(),
            a: Operand::Reg(av),
            b: Operand::Reg(xv),
            c: Operand::Reg(acc.clone()),
        });
        b.push(Op::Mov {
            ty: Type::F32,
            dst: acc.clone(),
            src: Operand::Reg(nacc),
        });
    }
    b.push(Op::IntBin {
        op: IntBinOp::Add,
        ty: Type::S32,
        dst: kreg.clone(),
        a: Operand::Reg(kreg.clone()),
        b: Operand::ImmInt(unroll as i128),
    });
    let pk = b.p();
    b.push(Op::Setp {
        cmp: CmpOp::Lt,
        ty: Type::S32,
        dst: pk.clone(),
        a: Operand::Reg(kreg.clone()),
        b: Operand::Reg(nk.clone()),
    });
    b.guarded(&pk, Op::Bra { uni: false, target: "$L_KLOOP".into() });

    b.push(Op::St {
        space: Space::Global,
        ty: Type::F32,
        addr: Address {
            base: Operand::Reg(yidx),
            offset: 0,
        },
        src: Operand::Reg(acc),
    });
    b.label(exit);
    b.push(Op::Ret);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ptx::printer::print_kernel;
    use crate::ptx::parser::parse;
    use crate::suite::spec::{irow, Lang};

    fn jacobi_like() -> Benchmark {
        let mut taps = Vec::new();
        for dj in -1..=1 {
            taps.extend(irow(0, -1, 1, dj, 0, 0.1));
        }
        Benchmark {
            name: "jacobi_like",
            lang: Lang::Fortran,
            dims: 2,
            pattern: Pattern::Stencil { taps },
            divergent: false,
            expect_shuffles: 6,
            expect_loads: 9,
            expect_delta: Some(1.5),
        }
    }

    #[test]
    fn generated_kernel_roundtrips() {
        let k = generate(&jacobi_like());
        assert_eq!(k.global_loads(), 9);
        let text = format!(
            ".version 7.6\n.target sm_70\n.address_size 64\n{}",
            print_kernel(&k)
        );
        let re = parse(&text).unwrap_or_else(|e| panic!("{e}\n{text}"));
        assert_eq!(re.kernels[0], k);
    }

    #[test]
    fn generated_2d_has_strip_mine_loop() {
        let k = generate(&jacobi_like());
        // backward branch to $L_ILOOP exists
        let has_loop = k.body.iter().any(|s| {
            matches!(s, Statement::Instr { op: Op::Bra { target, .. }, .. } if target == "$L_ILOOP")
        });
        assert!(has_loop);
    }

    #[test]
    fn generated_3d_has_middle_loop() {
        let b = Benchmark {
            name: "lap3d",
            lang: Lang::C,
            dims: 3,
            pattern: Pattern::Stencil {
                taps: vec![
                    Tap::new(0, -1, 0, 0, 1.0),
                    Tap::new(0, 0, 0, 0, -6.0),
                    Tap::new(0, 1, 0, 0, 1.0),
                    Tap::new(0, 0, -1, 0, 1.0),
                    Tap::new(0, 0, 1, 0, 1.0),
                    Tap::new(0, 0, 0, -1, 1.0),
                    Tap::new(0, 0, 0, 1, 1.0),
                ],
            },
            divergent: false,
            expect_shuffles: 2,
            expect_loads: 7,
            expect_delta: Some(1.5),
        };
        let k = generate(&b);
        assert_eq!(k.global_loads(), 7);
        assert!(k.body.iter().any(|s| matches!(
            s,
            Statement::Instr { op: Op::Bra { target, .. }, .. } if target == "$L_JLOOP"
        )));
    }

    #[test]
    fn matmul_has_expected_loads() {
        let k = generate(&Benchmark {
            name: "matmul",
            lang: Lang::Fortran,
            dims: 2,
            pattern: Pattern::MatMul { unroll: 4 },
            divergent: false,
            expect_shuffles: 0,
            expect_loads: 8,
            expect_delta: None,
        });
        assert_eq!(k.global_loads(), 8);
    }
}
