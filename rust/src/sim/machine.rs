//! Concrete warp-level PTX interpreter — the *reference* engine.
//!
//! Plays the GPU in this testbed (DESIGN.md substitution table): 32-thread
//! warps in lock-step SIMT with lowest-pc-first reconvergence, per-lane
//! predication, full `shfl.sync` semantics (PTX ISA: out-of-range sources
//! return the lane's own value with a false predicate), `activemask`, and a
//! flat global memory. Used to check bit-exact semantics preservation of
//! the synthesized kernels and to produce the dynamic instruction trace the
//! performance model replays.
//!
//! This module walks the AST directly, interning register names on the
//! fly; it is deliberately simple and serves as the semantic oracle the
//! pre-decoded micro-op engine ([`crate::sim::exec`]) is differential-
//! tested against. Production callers go through [`crate::sim::run`],
//! which lowers the kernel once ([`crate::sim::decode`]) and executes the
//! flat form. The two engines share the arithmetic helpers at the bottom
//! of this file so a value can never be computed two different ways.
//!
//! # Cooperative warp scheduling
//!
//! Warps of a block advance in *phases* separated by `bar.sync`: the
//! scheduler runs warps in warp-index order, each until it retires or
//! reaches a block-wide barrier with every live lane converged, then —
//! once no warp is runnable — releases them all into the next phase. For
//! barrier-free kernels this degenerates to exactly the old serialized
//! execution (warp 0 to completion, then warp 1, …), so every observable
//! is unchanged for the OpenACC-style kernel class; kernels that stage
//! data through `.shared` and synchronize with `bar.sync` now execute
//! with real exchange semantics. Violations are hard errors
//! ([`SimError::BarrierDivergence`]): a warp retiring while siblings
//! wait, divergent lanes reaching a barrier, mismatched barrier ids
//! across warps, and `bar.sync id, cnt` counts that do not name the full
//! block.

use super::memory::{GlobalMem, MemError, GLOBAL_BASE, SHARED_BASE};
use crate::emu::env::RegInterner;
use crate::ptx::ast::*;
use crate::sym::term::{eval_bin, eval_cmp, to_signed, BvOp, CmpKind};
use std::collections::HashMap;

/// Launch configuration.
#[derive(Debug, Clone)]
pub struct SimConfig {
    pub grid: (u32, u32, u32),
    pub block: (u32, u32, u32),
    /// Kernel parameter values in declaration order (pointers or scalars).
    pub params: Vec<u64>,
    /// Record the issue trace of block (0,0,0) for the perf model.
    pub record_trace: bool,
    pub max_warp_steps: u64,
    /// Worker threads for the decoded engine's grid execution (blocks are
    /// split across workers; results are bit-identical for any value).
    /// The reference engine ignores it. `1` = run on the calling thread.
    pub sim_threads: usize,
    /// Opt-in cross-block read-after-write diagnostic: a global load of
    /// bytes an *earlier* (launch-order) block wrote is a hard
    /// [`SimError::CrossBlockRace`]. Serial engines only — the decoded
    /// engine forces one worker while this is set, since snapshot
    /// isolation hides exactly the reads this shadow is looking for.
    pub detect_races: bool,
    /// Decoded engine only: execute decode-time-fused straight-line
    /// *superblocks*, skipping per-uop scheduling and step bookkeeping in
    /// the interior. Automatically falls back to the per-uop path for any
    /// block that records a [`WarpEvent`] trace and whenever
    /// `detect_races` is set (see `sim::exec`). The reference engine
    /// ignores it. Observables are bit-identical either way.
    pub superblocks: bool,
    /// Decoded engine only: dispatch hot ALU / `setp` / `selp` micro-ops
    /// to the full-warp lane-vectorized kernels. Only effective when the
    /// crate is built with the `simd` cargo feature — the default stable
    /// build always runs the per-lane scalar path, which is also the
    /// differential oracle. Observables are bit-identical either way.
    pub vector: bool,
}

impl SimConfig {
    pub fn new(grid_x: u32, block_x: u32, params: Vec<u64>) -> SimConfig {
        SimConfig {
            grid: (grid_x, 1, 1),
            block: (block_x, 1, 1),
            params,
            record_trace: false,
            max_warp_steps: 50_000_000,
            sim_threads: 1,
            detect_races: false,
            superblocks: true,
            vector: true,
        }
    }

    pub fn threads_per_block(&self) -> u32 {
        self.block.0 * self.block.1 * self.block.2
    }
}

/// One warp-issue event (for the perf model).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WarpEvent {
    /// Kernel body statement index.
    pub stmt: u32,
    /// Bitmask of lanes that arrived at the instruction together.
    pub active: u32,
    /// Bitmask of lanes that actually executed it (guard-passing).
    pub exec: u32,
    /// For global/shared loads & stores: byte address of the lowest
    /// executing lane (the perf model dedups 32-byte sectors with it).
    pub addr: u64,
}

#[derive(Debug, Clone, Copy, Default)]
pub struct SimStats {
    /// Warp-level instruction issues.
    pub warp_instructions: u64,
    /// Thread-level instruction executions (sum of active lanes).
    pub thread_instructions: u64,
    pub global_loads: u64,
    pub nc_loads: u64,
    pub shared_loads: u64,
    pub stores: u64,
    pub shfls: u64,
    pub branches: u64,
    pub divergent_branches: u64,
    pub uninit_reads: u64,
    /// Global-memory stores whose bytes were also written by a *different*
    /// block (write-after-write across blocks). The serial simulator used
    /// to hide this by quietly applying blocks in launch order; it is now
    /// counted — identically by every engine — because such kernels are
    /// scheduling-dependent on real hardware.
    pub cross_block_write_conflicts: u64,
    /// Warp-level `bar.sync` arrivals (one per warp per barrier executed).
    pub barriers: u64,
    /// Block-wide barrier releases: the number of phase boundaries the
    /// cooperative scheduler crossed, summed over all blocks.
    pub barrier_phases: u64,
    /// Engine telemetry: superblock fast-path entries in the decoded
    /// executor (0 on the reference engine and on every per-uop fallback).
    /// Excluded from equality — see the [`PartialEq`] impl below.
    pub superblocks_entered: u64,
    /// Engine telemetry: warp-level issues executed by the lane-vectorized
    /// wide kernels (0 without the `simd` feature or with
    /// [`SimConfig::vector`] off). Excluded from equality.
    pub vector_warp_steps: u64,
}

/// Equality is over the *semantic* counters only. The two engine-telemetry
/// fields (`superblocks_entered`, `vector_warp_steps`) describe which fast
/// path executed the kernel, not what the kernel did — they differ across
/// engine configurations by design, while the differential guarantee
/// ("bit-identical stats on every engine, any thread count") is over
/// everything else. The destructuring keeps this impl exhaustive: adding a
/// `SimStats` field without deciding its equality class is a compile error.
impl PartialEq for SimStats {
    fn eq(&self, other: &SimStats) -> bool {
        let SimStats {
            warp_instructions,
            thread_instructions,
            global_loads,
            nc_loads,
            shared_loads,
            stores,
            shfls,
            branches,
            divergent_branches,
            uninit_reads,
            cross_block_write_conflicts,
            barriers,
            barrier_phases,
            superblocks_entered: _,
            vector_warp_steps: _,
        } = *self;
        (
            warp_instructions,
            thread_instructions,
            global_loads,
            nc_loads,
            shared_loads,
            stores,
            shfls,
            branches,
            divergent_branches,
            uninit_reads,
            cross_block_write_conflicts,
            barriers,
            barrier_phases,
        ) == (
            other.warp_instructions,
            other.thread_instructions,
            other.global_loads,
            other.nc_loads,
            other.shared_loads,
            other.stores,
            other.shfls,
            other.branches,
            other.divergent_branches,
            other.uninit_reads,
            other.cross_block_write_conflicts,
            other.barriers,
            other.barrier_phases,
        )
    }
}

impl Eq for SimStats {}

#[derive(Debug)]
pub struct SimResult {
    pub mem: GlobalMem,
    pub stats: SimStats,
    /// Issue trace of block (0,0,0) when requested: one stream per warp.
    pub trace: Vec<Vec<WarpEvent>>,
}

#[derive(Debug, Clone)]
pub enum SimError {
    Mem(MemError),
    UnknownLabel(String),
    UnknownParam(String),
    /// A shared-variable name used as an operand with no matching
    /// `.shared` declaration (formerly misreported as `UnknownParam`).
    UnknownVar(String),
    StepLimit(u64),
    /// `detect_races` diagnostic: a global load observed bytes written by
    /// an earlier (launch-order) block — the kernel's result is
    /// scheduling-dependent on real hardware.
    CrossBlockRace {
        addr: u64,
        bytes: u32,
        writer_block: u32,
        reader_block: u32,
    },
    /// Cooperative barrier semantics violated inside a block (see
    /// [`BarrierCause`] for the taxonomy). Hard error on every engine: on
    /// real hardware these kernels deadlock or have undefined exchanges.
    BarrierDivergence {
        block: u32,
        /// Barrier id of the (first) waiting warp.
        id: u32,
        cause: BarrierCause,
    },
    /// `detect_races` diagnostic: a shared/global load observed bytes a
    /// *different warp of the same block* wrote in the *same barrier
    /// phase* — no happens-before edge orders the write before the read,
    /// so the exchange is scheduling-dependent on real hardware. A
    /// missing `bar.sync` between staging and use is the classic cause.
    IntraBlockRace {
        addr: u64,
        bytes: u32,
        block: u32,
        phase: u32,
        writer_warp: u32,
        reader_warp: u32,
        /// The racing bytes live in the block's `.shared` window.
        shared: bool,
    },
}

/// Why a [`SimError::BarrierDivergence`] fired.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BarrierCause {
    /// A warp ran to completion while sibling warps wait at a barrier
    /// (the waiters can never be released — deadlock on hardware).
    Exit,
    /// A warp reached `bar.sync` with only part of its live lanes (the
    /// barrier must be executed by every non-exited lane of the warp).
    Divergence,
    /// Two warps of one block wait at barriers with different ids.
    IdMismatch { other: u32 },
    /// `bar.sync id, cnt` whose thread count is not the launched block
    /// size — partial-block barriers are outside the supported class.
    PartialCount { cnt: u32, tpb: u32 },
}

impl std::fmt::Display for BarrierCause {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            BarrierCause::Exit => write!(f, "a warp exited while others wait"),
            BarrierCause::Divergence => write!(f, "divergent lanes reached the barrier"),
            BarrierCause::IdMismatch { other } => {
                write!(f, "another warp waits at barrier id {other}")
            }
            BarrierCause::PartialCount { cnt, tpb } => write!(
                f,
                "thread count {cnt} does not name the full block of {tpb} threads"
            ),
        }
    }
}

impl std::fmt::Display for SimError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SimError::Mem(e) => write!(f, "{e}"),
            SimError::UnknownLabel(l) => write!(f, "unknown branch target `{l}`"),
            SimError::UnknownParam(p) => write!(f, "unknown parameter `{p}`"),
            SimError::UnknownVar(v) => write!(f, "unknown shared variable `{v}`"),
            SimError::StepLimit(n) => write!(f, "warp exceeded {n} steps (livelock?)"),
            SimError::CrossBlockRace {
                addr,
                bytes,
                writer_block,
                reader_block,
            } => write!(
                f,
                "cross-block read-after-write: block {reader_block} loads {bytes} bytes at \
                 {addr:#x} written by block {writer_block} (scheduling-dependent on hardware)"
            ),
            SimError::BarrierDivergence { block, id, cause } => write!(
                f,
                "barrier divergence in block {block} at bar.sync {id}: {cause}"
            ),
            SimError::IntraBlockRace {
                addr,
                bytes,
                block,
                phase,
                writer_warp,
                reader_warp,
                shared,
            } => write!(
                f,
                "intra-block race in block {block}: warp {reader_warp} loads {bytes} \
                 {} bytes at {addr:#x} written by warp {writer_warp} in the same \
                 barrier phase {phase} (missing bar.sync?)",
                if *shared { "shared" } else { "global" }
            ),
        }
    }
}

impl std::error::Error for SimError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            SimError::Mem(e) => Some(e),
            _ => None,
        }
    }
}

impl From<MemError> for SimError {
    fn from(e: MemError) -> SimError {
        SimError::Mem(e)
    }
}

const WARP: usize = 32;

struct Lane {
    regs: Vec<u64>,
    written: Vec<bool>,
    pc: usize,
    done: bool,
    tid: (u32, u32, u32),
}

/// Compute the per-block shared-memory window layout: `(name → virtual
/// base, total window bytes)`. Shared by both engines so the address map
/// can never drift.
pub(super) fn shared_layout(kernel: &Kernel) -> (HashMap<&str, u64>, u64) {
    let mut shared_bases: HashMap<&str, u64> = HashMap::new();
    let mut shared_size = 0u64;
    for sh in &kernel.shared {
        let a = sh.align.max(1) as u64;
        shared_size = (shared_size + a - 1) / a * a;
        shared_bases.insert(sh.name.as_str(), SHARED_BASE + shared_size);
        shared_size += sh.bytes;
    }
    (shared_bases, shared_size)
}

/// Run a kernel to completion over the whole grid, walking the AST.
///
/// This is the reference engine: every observable ([`GlobalMem`],
/// [`SimStats`], the block-(0,0,0) trace) is bit-identical to
/// [`crate::sim::run`] for kernels that do not read another block's
/// global writes (cross-block read-after-write is scheduling-dependent
/// on hardware and unsupported by the parallel engine).
pub fn run_reference(
    kernel: &Kernel,
    cfg: &SimConfig,
    mem: GlobalMem,
) -> Result<SimResult, SimError> {
    let mut regs = RegInterner::from_kernel(kernel);
    // intern guard regs too (already covered by from_kernel)
    let mut labels: HashMap<&str, usize> = HashMap::new();
    for (i, st) in kernel.body.iter().enumerate() {
        if let Statement::Label(l) = st {
            labels.insert(l.as_str(), i);
        }
    }
    let mut params: HashMap<&str, u64> = HashMap::new();
    for (i, p) in kernel.params.iter().enumerate() {
        params.insert(
            p.name.as_str(),
            cfg.params.get(i).copied().ok_or_else(|| {
                SimError::UnknownParam(format!("{} (no value supplied)", p.name))
            })?,
        );
    }
    // shared-variable window layout (computed once, outside the block loop)
    let (shared_bases, shared_size) = shared_layout(kernel);

    // conflicts are impossible on a single-block grid — skip the shadow
    let nblocks = cfg.grid.0 as u64 * cfg.grid.1 as u64 * cfg.grid.2 as u64;
    let written_by = (nblocks > 1).then(|| WriteShadow::new(&mem));
    let phase_shadow = cfg.detect_races.then(|| PhaseShadow::new(&mem));
    let mut m = Machine {
        kernel,
        regs: &mut regs,
        labels,
        params,
        shared_bases,
        mem,
        shared: Vec::new(),
        stats: SimStats::default(),
        trace: Vec::new(),
        cfg,
        written_by,
        phase_shadow,
        cur_block: 0,
        cur_warp: 0,
        cur_phase: 0,
    };

    let tpb = cfg.threads_per_block();
    for bz in 0..cfg.grid.2 {
        for by in 0..cfg.grid.1 {
            for bx in 0..cfg.grid.0 {
                // block-local scratch: fully re-zeroed per block (buffer
                // reused; clear + resize zero-fills every element)
                m.shared.clear();
                m.shared.resize(shared_size as usize, 0);
                let record = cfg.record_trace && (bx, by, bz) == (0, 0, 0);
                m.run_block((bx, by, bz), tpb, record)?;
                m.cur_block += 1;
            }
        }
    }

    Ok(SimResult {
        mem: m.mem,
        stats: m.stats,
        trace: m.trace,
    })
}

struct Machine<'a> {
    kernel: &'a Kernel,
    regs: &'a mut RegInterner,
    labels: HashMap<&'a str, usize>,
    params: HashMap<&'a str, u64>,
    shared_bases: HashMap<&'a str, u64>,
    mem: GlobalMem,
    shared: Vec<u8>,
    stats: SimStats,
    trace: Vec<Vec<WarpEvent>>,
    cfg: &'a SimConfig,
    /// Last-writer shadow for `cross_block_write_conflicts` (`None` on
    /// single-block grids, where conflicts are impossible).
    written_by: Option<WriteShadow>,
    /// `detect_races` only: intra-block happens-before shadow (who wrote
    /// each byte, in which warp and phase).
    phase_shadow: Option<PhaseShadow>,
    cur_block: u32,
    /// Warp index the scheduler is currently advancing (for the shadow).
    cur_warp: u32,
    /// Barrier phase the current block is in (0 before any release).
    cur_phase: u32,
}

/// Why a warp's scheduling slice ended (shared by both engines'
/// cooperative schedulers).
pub(super) enum WarpHalt {
    /// Every lane retired (`ret`/`exit` or fell off the end).
    Finished,
    /// All live lanes converged on `bar.sync id`; pc NOT yet advanced —
    /// the scheduler advances it when the whole block releases.
    Barrier { id: u32 },
}

#[derive(Clone, Copy, PartialEq)]
pub(super) enum WarpStatus {
    Running,
    AtBarrier(u32),
    Finished,
}

/// Decide a block's fate once no warp is runnable: `Ok(None)` = every
/// warp retired (block done), `Ok(Some(id))` = all warps wait at barrier
/// `id` (release into the next phase), `Err` = the barrier contract is
/// violated. Shared by both engines so the error taxonomy — and which
/// violation wins when several apply — can never drift between them.
pub(super) fn barrier_release(
    status: impl Iterator<Item = WarpStatus> + Clone,
    block: u32,
) -> Result<Option<u32>, SimError> {
    let Some(id) = status.clone().find_map(|s| match s {
        WarpStatus::AtBarrier(id) => Some(id),
        _ => None,
    }) else {
        return Ok(None);
    };
    for s in status {
        match s {
            WarpStatus::Finished => {
                return Err(SimError::BarrierDivergence {
                    block,
                    id,
                    cause: BarrierCause::Exit,
                })
            }
            WarpStatus::AtBarrier(other) if other != id => {
                return Err(SimError::BarrierDivergence {
                    block,
                    id,
                    cause: BarrierCause::IdMismatch { other },
                })
            }
            _ => {}
        }
    }
    Ok(Some(id))
}

impl<'a> Machine<'a> {
    /// Run one block to completion under the cooperative scheduler: warps
    /// advance in warp-index order, each until it retires or arrives at a
    /// block-wide barrier; when no warp is runnable, either every warp
    /// finished (block done) or the waiting set is validated and released
    /// into the next phase. Barrier-free kernels therefore execute warp 0
    /// to completion, then warp 1, … — exactly the old serialized order.
    fn run_block(
        &mut self,
        ctaid: (u32, u32, u32),
        tpb: u32,
        record: bool,
    ) -> Result<(), SimError> {
        let nregs = self.regs.len();
        let warps = tpb.div_ceil(32) as usize;
        let mut lanes: Vec<Vec<Lane>> = (0..warps as u32)
            .map(|w| {
                (0..WARP as u32)
                    .map(|l| {
                        let t = w * 32 + l;
                        let tid = linear_to_tid(t, self.cfg.block);
                        Lane {
                            regs: vec![0; nregs],
                            written: vec![false; nregs],
                            pc: 0,
                            done: t >= tpb, // fractional warp: extra lanes inactive
                            tid,
                        }
                    })
                    .collect()
            })
            .collect();
        // one trace stream per warp, in warp order (same as serialized)
        let tbase = self.trace.len();
        if record {
            for _ in 0..warps {
                self.trace.push(Vec::new());
            }
        }
        let mut status = vec![WarpStatus::Running; warps];
        let mut steps = vec![0u64; warps];
        self.cur_phase = 0;
        if let Some(sh) = &mut self.phase_shadow {
            sh.begin_block(self.shared.len());
        }

        loop {
            for w in 0..warps {
                if status[w] != WarpStatus::Running {
                    continue;
                }
                self.cur_warp = w as u32;
                let ti = record.then_some(tbase + w);
                status[w] = match self.run_warp(&mut lanes[w], ctaid, ti, &mut steps[w], tpb)? {
                    WarpHalt::Finished => WarpStatus::Finished,
                    WarpHalt::Barrier { id } => WarpStatus::AtBarrier(id),
                };
            }
            // no warp is runnable: all finished, or a barrier release
            if barrier_release(status.iter().copied(), self.cur_block)?.is_none() {
                return Ok(()); // every warp retired
            }
            // release: step every live lane past the barrier statement
            for wl in lanes.iter_mut() {
                for l in wl.iter_mut().filter(|l| !l.done) {
                    l.pc += 1;
                }
            }
            status.fill(WarpStatus::Running);
            self.stats.barrier_phases += 1;
            self.cur_phase += 1;
        }
    }

    /// Advance one warp until it retires or converges on a barrier.
    fn run_warp(
        &mut self,
        lanes: &mut [Lane],
        ctaid: (u32, u32, u32),
        trace_idx: Option<usize>,
        steps: &mut u64,
        tpb: u32,
    ) -> Result<WarpHalt, SimError> {
        let body_len = self.kernel.body.len();
        loop {
            // lowest-pc-first reconvergence
            let pc = match lanes.iter().filter(|l| !l.done).map(|l| l.pc).min() {
                None => return Ok(WarpHalt::Finished),
                Some(p) => p,
            };
            if pc >= body_len {
                for l in lanes.iter_mut().filter(|l| !l.done && l.pc >= body_len) {
                    l.done = true;
                }
                continue;
            }
            *steps += 1;
            if *steps > self.cfg.max_warp_steps {
                return Err(SimError::StepLimit(self.cfg.max_warp_steps));
            }
            let active: Vec<usize> = lanes
                .iter()
                .enumerate()
                .filter(|(_, l)| !l.done && l.pc == pc)
                .map(|(i, _)| i)
                .collect();
            let mask: u32 = active.iter().fold(0, |m, &i| m | (1 << i));

            match &self.kernel.body[pc] {
                Statement::Label(_) => {
                    for &i in &active {
                        lanes[i].pc += 1;
                    }
                    continue;
                }
                Statement::Instr { guard, op } => {
                    self.stats.warp_instructions += 1;
                    // per-lane guard evaluation
                    let exec: Vec<usize> = match guard {
                        None => active.clone(),
                        Some(g) => {
                            let gid = self.regs.intern(&g.reg);
                            active
                                .iter()
                                .copied()
                                .filter(|&i| {
                                    let v = lanes[i].regs[gid as usize] & 1 == 1;
                                    v != g.negated
                                })
                                .collect()
                        }
                    };
                    self.stats.thread_instructions += exec.len() as u64;
                    if let Some(ti) = trace_idx {
                        let exec_mask: u32 = exec.iter().fold(0, |m, &i| m | (1 << i));
                        // address of the first executing lane for memory ops
                        let addr = match op {
                            Op::Ld { space, addr, .. } | Op::St { space, addr, .. }
                                if *space != Space::Param =>
                            {
                                match exec.first() {
                                    Some(&l) => self.addr_value(&mut lanes[l], addr, ctaid)?,
                                    None => 0,
                                }
                            }
                            _ => 0,
                        };
                        self.trace[ti].push(WarpEvent {
                            stmt: pc as u32,
                            active: mask,
                            exec: exec_mask,
                            addr,
                        });
                    }
                    if let Op::BarSync { id, cnt } = op {
                        // uniformly-skipped barrier (guard false on every
                        // active lane): a plain no-op, step past it
                        if exec.is_empty() {
                            for &i in &active {
                                lanes[i].pc += 1;
                            }
                            continue;
                        }
                        if let Some(c) = cnt {
                            if *c != tpb {
                                return Err(SimError::BarrierDivergence {
                                    block: self.cur_block,
                                    id: *id,
                                    cause: BarrierCause::PartialCount { cnt: *c, tpb },
                                });
                            }
                        }
                        let live = lanes.iter().filter(|l| !l.done).count();
                        if exec.len() != live {
                            return Err(SimError::BarrierDivergence {
                                block: self.cur_block,
                                id: *id,
                                cause: BarrierCause::Divergence,
                            });
                        }
                        self.stats.barriers += 1;
                        // suspend WITHOUT advancing pc: the scheduler steps
                        // every live lane past the barrier at release
                        return Ok(WarpHalt::Barrier { id: *id });
                    }
                    self.exec(op, lanes, &active, &exec, mask, ctaid)?;
                }
            }
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn exec(
        &mut self,
        op: &Op,
        lanes: &mut [Lane],
        active: &[usize],
        exec: &[usize],
        maskbits: u32,
        ctaid: (u32, u32, u32),
    ) -> Result<(), SimError> {
        match op {
            Op::Bra { target, .. } => {
                self.stats.branches += 1;
                let t = *self
                    .labels
                    .get(target.as_str())
                    .ok_or_else(|| SimError::UnknownLabel(target.clone()))?;
                let mut taken = 0usize;
                for &i in active {
                    if exec.contains(&i) {
                        lanes[i].pc = t;
                        taken += 1;
                    } else {
                        lanes[i].pc += 1;
                    }
                }
                if taken != 0 && taken != active.len() {
                    self.stats.divergent_branches += 1;
                }
                return Ok(());
            }
            Op::Ret | Op::Exit => {
                for &i in active {
                    if exec.contains(&i) {
                        lanes[i].done = true;
                    } else {
                        lanes[i].pc += 1;
                    }
                }
                return Ok(());
            }
            Op::Shfl {
                mode,
                dst,
                pred_out,
                src,
                b,
                c,
                mask,
                ..
            } => {
                self.stats.shfls += 1;
                let did = self.regs.intern(dst) as usize;
                let pid = pred_out.as_ref().map(|p| self.regs.intern(p) as usize);
                // gather source values first (exchange is simultaneous)
                let mut srcv = [0u64; WARP];
                for &i in exec {
                    srcv[i] = self.read_operand(&mut lanes[i], src, 32, ctaid)?;
                }
                let exec_mask: u32 = exec.iter().fold(0, |m, &i| m | (1 << i));
                for &i in exec {
                    let bv = self.read_operand(&mut lanes[i], b, 32, ctaid)? as u32;
                    let cv = self.read_operand(&mut lanes[i], c, 32, ctaid)? as u32;
                    let mv = self.read_operand(&mut lanes[i], mask, 32, ctaid)? as u32;
                    let lane = i as u32;
                    // PTX ISA `c`-operand encoding — see `shfl_source_lane`
                    let (src_lane, pval) = shfl_source_lane(*mode, lane, bv, cv);
                    let valid = pval
                        && (mv >> src_lane) & 1 == 1
                        && (exec_mask >> src_lane) & 1 == 1;
                    let val = if valid { srcv[src_lane as usize] } else { srcv[i] };
                    lanes[i].regs[did] = val & 0xFFFF_FFFF;
                    lanes[i].written[did] = true;
                    if let Some(p) = pid {
                        lanes[i].regs[p] = valid as u64;
                        lanes[i].written[p] = true;
                    }
                }
            }
            Op::Activemask { dst } => {
                let did = self.regs.intern(dst) as usize;
                for &i in exec {
                    lanes[i].regs[did] = maskbits as u64;
                    lanes[i].written[did] = true;
                }
            }
            Op::BarSync { .. } => unreachable!("handled by the warp scheduler"),
            _ => {
                for &i in exec {
                    self.exec_lane(op, &mut lanes[i], ctaid)?;
                }
            }
        }
        for &i in active {
            if !matches!(op, Op::Bra { .. } | Op::Ret | Op::Exit) {
                lanes[i].pc += 1;
            }
        }
        Ok(())
    }

    fn write(&mut self, lane: &mut Lane, r: &Reg, v: u64) {
        let id = self.regs.intern(r) as usize;
        lane.regs[id] = v;
        lane.written[id] = true;
    }

    fn read_reg(&mut self, lane: &mut Lane, r: &Reg) -> u64 {
        let id = self.regs.intern(r) as usize;
        if !lane.written[id] {
            self.stats.uninit_reads += 1;
        }
        lane.regs[id]
    }

    fn read_operand(
        &mut self,
        lane: &mut Lane,
        o: &Operand,
        width: u32,
        ctaid: (u32, u32, u32),
    ) -> Result<u64, SimError> {
        let m = width_mask(width);
        Ok(match o {
            Operand::Reg(r) => self.read_reg(lane, r) & m,
            Operand::ImmInt(v) => (*v as u64) & m,
            Operand::ImmF32(b) => *b as u64,
            Operand::ImmF64(b) => *b,
            Operand::Special(sp) => {
                special_value(*sp, lane.tid, self.cfg.block, self.cfg.grid, ctaid) & m
            }
            Operand::Var(v) => self
                .shared_bases
                .get(v.as_str())
                .copied()
                .ok_or_else(|| SimError::UnknownVar(v.clone()))?,
        })
    }

    fn addr_value(
        &mut self,
        lane: &mut Lane,
        addr: &Address,
        ctaid: (u32, u32, u32),
    ) -> Result<u64, SimError> {
        let base = self.read_operand(lane, &addr.base, 64, ctaid)?;
        Ok(base.wrapping_add(addr.offset as u64))
    }

    fn load_mem(&mut self, space: Space, addr: u64, bytes: u32) -> Result<u64, SimError> {
        match shared_window_offset(self.shared.len() as u64, space, addr, bytes, "shared load")? {
            Some(o) => {
                if let Some(sh) = &self.phase_shadow {
                    if let Some(w) = sh.check_shared(o, bytes, self.cur_warp, self.cur_phase) {
                        return Err(SimError::IntraBlockRace {
                            addr,
                            bytes,
                            block: self.cur_block,
                            phase: self.cur_phase,
                            writer_warp: w,
                            reader_warp: self.cur_warp,
                            shared: true,
                        });
                    }
                }
                let mut v = 0u64;
                for k in 0..bytes as usize {
                    v |= (self.shared[o + k] as u64) << (8 * k);
                }
                Ok(v)
            }
            None => {
                let v = self.mem.load(addr, bytes)?;
                if self.cfg.detect_races {
                    if let Some(sh) = &self.written_by {
                        if let Some(w) = sh.foreign_writer(addr, bytes, self.cur_block) {
                            return Err(SimError::CrossBlockRace {
                                addr,
                                bytes,
                                writer_block: w,
                                reader_block: self.cur_block,
                            });
                        }
                    }
                    if let Some(sh) = &self.phase_shadow {
                        if let Some(w) = sh.check_global(
                            addr,
                            bytes,
                            self.cur_block,
                            self.cur_warp,
                            self.cur_phase,
                        ) {
                            return Err(SimError::IntraBlockRace {
                                addr,
                                bytes,
                                block: self.cur_block,
                                phase: self.cur_phase,
                                writer_warp: w,
                                reader_warp: self.cur_warp,
                                shared: false,
                            });
                        }
                    }
                }
                Ok(v)
            }
        }
    }

    fn store_mem(
        &mut self,
        space: Space,
        addr: u64,
        bytes: u32,
        v: u64,
    ) -> Result<(), SimError> {
        match shared_window_offset(self.shared.len() as u64, space, addr, bytes, "shared store")? {
            Some(o) => {
                if let Some(sh) = &mut self.phase_shadow {
                    sh.note_shared(o, bytes, self.cur_warp, self.cur_phase);
                }
                for k in 0..bytes as usize {
                    self.shared[o + k] = (v >> (8 * k)) as u8;
                }
                Ok(())
            }
            None => {
                self.mem.store(addr, bytes, v)?;
                if let Some(sh) = &mut self.written_by {
                    if sh.note(addr, bytes, self.cur_block) {
                        self.stats.cross_block_write_conflicts += 1;
                    }
                }
                if let Some(sh) = &mut self.phase_shadow {
                    sh.note_global(addr, bytes, self.cur_block, self.cur_warp, self.cur_phase);
                }
                Ok(())
            }
        }
    }

    fn exec_lane(
        &mut self,
        op: &Op,
        lane: &mut Lane,
        ctaid: (u32, u32, u32),
    ) -> Result<(), SimError> {
        match op {
            Op::Ld {
                space,
                nc,
                ty,
                dst,
                addr,
            } => {
                let v = if *space == Space::Param {
                    let name = match &addr.base {
                        Operand::Var(n) => n.as_str(),
                        _ => "?",
                    };
                    let base = *self
                        .params
                        .get(name)
                        .ok_or_else(|| SimError::UnknownParam(name.to_string()))?;
                    // scalar param: the value itself (offset addressing into
                    // multi-word params is not needed for our kernels)
                    let _ = addr.offset;
                    base & width_mask(ty.bits())
                } else {
                    let a = self.addr_value(lane, addr, ctaid)?;
                    match space {
                        Space::Global | Space::Const | Space::Local => {
                            self.stats.global_loads += 1;
                            if *nc {
                                self.stats.nc_loads += 1;
                            }
                        }
                        Space::Shared => self.stats.shared_loads += 1,
                        Space::Param => unreachable!(),
                    }
                    self.load_mem(*space, a, ty.bytes() as u32)?
                };
                self.write(lane, dst, v);
            }
            Op::St { space, ty, addr, src } => {
                let a = self.addr_value(lane, addr, ctaid)?;
                let v = self.read_operand(lane, src, ty.bits().max(8), ctaid)?;
                self.stats.stores += 1;
                self.store_mem(*space, a, ty.bytes() as u32, v)?;
            }
            Op::Mov { ty, dst, src } => {
                let v = self.read_operand(lane, src, ty.bits().max(8), ctaid)?;
                self.write(lane, dst, v);
            }
            Op::Cvta { dst, src, .. } => {
                let v = self.read_operand(lane, src, 64, ctaid)?;
                self.write(lane, dst, v);
            }
            Op::IntBin { op: bop, ty, dst, a, b } => {
                let w = ty.bits().max(1);
                let signed = ty.is_signed();
                let av = self.read_operand(lane, a, w, ctaid)?;
                let bv = self.read_operand(lane, b, w, ctaid)?;
                let v = match bop {
                    IntBinOp::MulWide => mul_full(signed, w, av, bv) & width_mask(w * 2),
                    IntBinOp::MulHi => mul_hi(signed, w, av, bv),
                    _ => {
                        let bv2 = match bop {
                            IntBinOp::Shl | IntBinOp::Shr => bv, // shift counts
                            _ => bv,
                        };
                        eval_bin(int_bvop(*bop, signed), av, bv2, w)
                    }
                };
                self.write(lane, dst, v);
            }
            Op::Mad { wide, ty, dst, a, b, c } => {
                let w = ty.bits();
                let signed = ty.is_signed();
                let av = self.read_operand(lane, a, w, ctaid)?;
                let bv = self.read_operand(lane, b, w, ctaid)?;
                let v = if *wide {
                    let cv = self.read_operand(lane, c, w * 2, ctaid)?;
                    mul_full(signed, w, av, bv).wrapping_add(cv) & width_mask(w * 2)
                } else {
                    let cv = self.read_operand(lane, c, w, ctaid)?;
                    av.wrapping_mul(bv).wrapping_add(cv) & width_mask(w)
                };
                self.write(lane, dst, v);
            }
            Op::Not { ty, dst, a } => {
                let w = ty.bits().max(1);
                let av = self.read_operand(lane, a, w, ctaid)?;
                self.write(lane, dst, !av & width_mask(w));
            }
            Op::Neg { ty, dst, a } => {
                let w = ty.bits();
                let av = self.read_operand(lane, a, w, ctaid)?;
                self.write(lane, dst, av.wrapping_neg() & width_mask(w));
            }
            Op::FltBin { op: fop, ty, dst, a, b } => {
                let w = ty.bits();
                let av = self.read_operand(lane, a, w, ctaid)?;
                let bv = self.read_operand(lane, b, w, ctaid)?;
                let v = if *ty == Type::F32 {
                    let (x, y) = (f32::from_bits(av as u32), f32::from_bits(bv as u32));
                    f32_bin(*fop, x, y).to_bits() as u64
                } else {
                    let (x, y) = (f64::from_bits(av), f64::from_bits(bv));
                    f64_bin(*fop, x, y).to_bits()
                };
                self.write(lane, dst, v);
            }
            Op::Fma { ty, dst, a, b, c } => {
                let w = ty.bits();
                let av = self.read_operand(lane, a, w, ctaid)?;
                let bv = self.read_operand(lane, b, w, ctaid)?;
                let cv = self.read_operand(lane, c, w, ctaid)?;
                let v = if *ty == Type::F32 {
                    f32::from_bits(av as u32)
                        .mul_add(f32::from_bits(bv as u32), f32::from_bits(cv as u32))
                        .to_bits() as u64
                } else {
                    f64::from_bits(av)
                        .mul_add(f64::from_bits(bv), f64::from_bits(cv))
                        .to_bits()
                };
                self.write(lane, dst, v);
            }
            Op::FltUn { op: fop, ty, dst, a } => {
                let w = ty.bits();
                let av = self.read_operand(lane, a, w, ctaid)?;
                let v = if *ty == Type::F32 {
                    f32_un(*fop, f32::from_bits(av as u32)).to_bits() as u64
                } else {
                    f64_un(*fop, f64::from_bits(av)).to_bits()
                };
                self.write(lane, dst, v);
            }
            Op::Setp { cmp, ty, dst, a, b } => {
                let w = ty.bits();
                let av = self.read_operand(lane, a, w, ctaid)?;
                let bv = self.read_operand(lane, b, w, ctaid)?;
                let r = if ty.is_float() {
                    flt_cmp(*cmp, *ty != Type::F32, av, bv)
                } else {
                    let signed = !matches!(ty, Type::U8 | Type::U16 | Type::U32 | Type::U64);
                    eval_cmp(cmp_kind(*cmp, signed), av, bv, w)
                };
                self.write(lane, dst, r as u64);
            }
            Op::Selp { ty, dst, a, b, p } => {
                let w = ty.bits();
                let av = self.read_operand(lane, a, w, ctaid)?;
                let bv = self.read_operand(lane, b, w, ctaid)?;
                let pv = self.read_operand(lane, p, 1, ctaid)?;
                self.write(lane, dst, if pv & 1 == 1 { av } else { bv });
            }
            Op::Cvt { dty, sty, dst, src } => {
                let sv = self.read_operand(lane, src, sty.bits(), ctaid)?;
                let v = convert(sv, *sty, *dty);
                self.write(lane, dst, v);
            }
            Op::Shfl { .. }
            | Op::Activemask { .. }
            | Op::BarSync { .. }
            | Op::Bra { .. }
            | Op::Ret
            | Op::Exit => unreachable!("handled at warp level"),
        }
        Ok(())
    }
}

/// Resolve an address into the per-block shared window, bounds-checked.
///
/// `.shared` accesses accept window-relative addresses (offsets below
/// the window size, as PTX shared-state-space addressing starts at 0)
/// or generic addresses at `SHARED_BASE`; anything else — including a
/// below-base address that is not a valid window offset — is an
/// out-of-bounds error, never a silent alias onto global memory.
/// Returns `None` when the access belongs to global memory.
pub(super) fn shared_window_offset(
    window: u64,
    space: Space,
    addr: u64,
    bytes: u32,
    kind: &'static str,
) -> Result<Option<usize>, SimError> {
    let o = if addr >= SHARED_BASE {
        addr - SHARED_BASE
    } else if space == Space::Shared {
        addr // window-relative
    } else {
        return Ok(None);
    };
    let in_bounds = o
        .checked_add(bytes as u64)
        .map(|end| end <= window)
        .unwrap_or(false);
    if !in_bounds {
        return Err(SimError::Mem(MemError::OutOfBounds {
            kind,
            addr,
            bytes: bytes as u64,
            size: window,
        }));
    }
    Ok(Some(o as usize))
}

/// Last-writer shadow of global memory for cross-block conflict
/// detection: one slot per global byte (`u32::MAX` = never written), flat
/// like [`GlobalMem`] itself — far cheaper than a per-byte map. Both
/// engines note stores in launch block order, so the resulting
/// `cross_block_write_conflicts` count is identical serial vs parallel.
pub(super) struct WriteShadow {
    slots: Vec<u32>,
}

impl WriteShadow {
    pub(super) fn new(mem: &GlobalMem) -> WriteShadow {
        WriteShadow {
            slots: vec![u32::MAX; mem.size()],
        }
    }

    /// Mark `bytes` at `addr` as written by `block`; returns `true` when
    /// any of them was previously written by a *different* block (one
    /// conflicting store, however many bytes overlap). `addr` must be a
    /// bounds-checked global address (the caller just stored through it).
    pub(super) fn note(&mut self, addr: u64, bytes: u32, block: u32) -> bool {
        let o = (addr - GLOBAL_BASE) as usize;
        let mut conflict = false;
        for s in &mut self.slots[o..o + bytes as usize] {
            conflict |= *s != u32::MAX && *s != block;
            *s = block;
        }
        conflict
    }

    /// The `detect_races` load-side probe: which *other* block (if any)
    /// last wrote one of `bytes` at `addr`? `addr` must be a
    /// bounds-checked global address (the caller just loaded through it).
    pub(super) fn foreign_writer(&self, addr: u64, bytes: u32, block: u32) -> Option<u32> {
        let o = (addr - GLOBAL_BASE) as usize;
        self.slots[o..o + bytes as usize]
            .iter()
            .find(|&&s| s != u32::MAX && s != block)
            .copied()
    }
}

/// Intra-block happens-before shadow for the `detect_races` diagnostic,
/// shared by both serial engines. Tracks, per byte, which (warp, phase)
/// last wrote it; a load by a *different* warp in the *same* phase has no
/// happens-before edge from the write (barriers are the only intra-block
/// ordering), so it is a diagnosable race. Writes from earlier phases are
/// ordered by the intervening `bar.sync`; same-warp accesses are ordered
/// by program order (warp-synchronous lanes included).
///
/// Global bytes carry a block tag so stale entries from earlier blocks
/// need no clearing; the shared-window shadow is reset per block.
pub(super) struct PhaseShadow {
    g_block: Vec<u32>,
    g_warp: Vec<u32>,
    g_phase: Vec<u32>,
    s_warp: Vec<u32>,
    s_phase: Vec<u32>,
}

impl PhaseShadow {
    pub(super) fn new(mem: &GlobalMem) -> PhaseShadow {
        PhaseShadow {
            g_block: vec![u32::MAX; mem.size()],
            g_warp: vec![0; mem.size()],
            g_phase: vec![0; mem.size()],
            s_warp: Vec::new(),
            s_phase: Vec::new(),
        }
    }

    /// Reset the shared-window shadow for a new block.
    pub(super) fn begin_block(&mut self, shared_bytes: usize) {
        self.s_warp.clear();
        self.s_warp.resize(shared_bytes, u32::MAX);
        self.s_phase.clear();
        self.s_phase.resize(shared_bytes, 0);
    }

    pub(super) fn note_global(&mut self, addr: u64, bytes: u32, block: u32, warp: u32, phase: u32) {
        let o = (addr - GLOBAL_BASE) as usize;
        for k in o..o + bytes as usize {
            self.g_block[k] = block;
            self.g_warp[k] = warp;
            self.g_phase[k] = phase;
        }
    }

    /// Same-block, same-phase, different-warp writer of any of the bytes.
    pub(super) fn check_global(
        &self,
        addr: u64,
        bytes: u32,
        block: u32,
        warp: u32,
        phase: u32,
    ) -> Option<u32> {
        let o = (addr - GLOBAL_BASE) as usize;
        (o..o + bytes as usize).find_map(|k| {
            (self.g_block[k] == block && self.g_warp[k] != warp && self.g_phase[k] == phase)
                .then_some(self.g_warp[k])
        })
    }

    pub(super) fn note_shared(&mut self, off: usize, bytes: u32, warp: u32, phase: u32) {
        for k in off..off + bytes as usize {
            self.s_warp[k] = warp;
            self.s_phase[k] = phase;
        }
    }

    pub(super) fn check_shared(
        &self,
        off: usize,
        bytes: u32,
        warp: u32,
        phase: u32,
    ) -> Option<u32> {
        (off..off + bytes as usize).find_map(|k| {
            (self.s_warp[k] != u32::MAX && self.s_warp[k] != warp && self.s_phase[k] == phase)
                .then_some(self.s_warp[k])
        })
    }
}

/// PTX ISA `shfl.sync` source-lane computation, shared by both engines
/// so the subtle `c`-operand semantics can never drift between them.
///
/// `c` encodes the clamp value in bits 0–4 and the segment mask in bits
/// 8–12. Lanes are bounded to their segment:
///   maxLane = (lane & segmask) | (cval & ~segmask)
///   minLane =  lane & segmask
/// maxLane is the upper bound for Down/Bfly/Idx and the *lower* bound for
/// Up (where the conventional clamp value is 0, making it the segment
/// base). The source index is signed: Up can go below the segment base
/// (even negative), Down/Bfly above the clamp. Returns the resolved
/// source lane (the lane itself when out of segment — always < 32) and
/// the in-segment predicate.
pub(super) fn shfl_source_lane(mode: ShflMode, lane: u32, bv: u32, cv: u32) -> (u32, bool) {
    let bval = bv & 0x1f;
    let cval = cv & 0x1f;
    let segmask = (cv >> 8) & 0x1f;
    let max_lane = (lane & segmask) | (cval & !segmask & 0x1f);
    let min_lane = lane & segmask;
    let (j, pval) = match mode {
        ShflMode::Up => {
            let j = lane as i32 - bval as i32;
            (j, j >= max_lane as i32)
        }
        ShflMode::Down => {
            let j = (lane + bval) as i32;
            (j, j <= max_lane as i32)
        }
        ShflMode::Bfly => {
            let j = (lane ^ bval) as i32;
            (j, j <= max_lane as i32)
        }
        ShflMode::Idx => {
            let j = (min_lane | (bval & !segmask & 0x1f)) as i32;
            (j, j <= max_lane as i32)
        }
    };
    (if pval { j as u32 } else { lane }, pval)
}

/// Full-width product of two `w`-bit values (low 64 bits, unmasked) —
/// the `mul.wide` / wide-`mad` kernel, shared by both engines.
pub(super) fn mul_full(signed: bool, w: u32, av: u64, bv: u64) -> u64 {
    if signed {
        (to_signed(av, w) * to_signed(bv, w)) as u64
    } else {
        (av as u128 * bv as u128) as u64
    }
}

/// `mul.hi` semantics, shared by both engines (including its asymmetric
/// signed/unsigned shift placement).
pub(super) fn mul_hi(signed: bool, w: u32, av: u64, bv: u64) -> u64 {
    let full = if signed {
        (to_signed(av, w) * to_signed(bv, w)) as u64
    } else {
        ((av as u128 * bv as u128) >> w) as u64
    };
    if signed {
        ((full as u128) >> w) as u64 & width_mask(w)
    } else {
        full & width_mask(w)
    }
}

/// Float `setp` comparison, shared by both engines (f32 operands are
/// widened to f64 before comparing).
pub(super) fn flt_cmp(cmp: CmpOp, wide: bool, av: u64, bv: u64) -> bool {
    let (x, y) = if wide {
        (f64::from_bits(av), f64::from_bits(bv))
    } else {
        (
            f32::from_bits(av as u32) as f64,
            f32::from_bits(bv as u32) as f64,
        )
    };
    match cmp {
        CmpOp::Eq => x == y,
        CmpOp::Ne => x != y,
        CmpOp::Lt => x < y,
        CmpOp::Le => x <= y,
        CmpOp::Gt => x > y,
        CmpOp::Ge => x >= y,
    }
}

/// Value of a special (pre-defined) register for one thread.
pub(super) fn special_value(
    sp: Special,
    tid: (u32, u32, u32),
    block: (u32, u32, u32),
    grid: (u32, u32, u32),
    ctaid: (u32, u32, u32),
) -> u64 {
    (match sp {
        Special::TidX => tid.0,
        Special::TidY => tid.1,
        Special::TidZ => tid.2,
        Special::NtidX => block.0,
        Special::NtidY => block.1,
        Special::NtidZ => block.2,
        Special::CtaidX => ctaid.0,
        Special::CtaidY => ctaid.1,
        Special::CtaidZ => ctaid.2,
        Special::NctaidX => grid.0,
        Special::NctaidY => grid.1,
        Special::NctaidZ => grid.2,
        Special::LaneId => (tid.0 + tid.1 * block.0 + tid.2 * block.0 * block.1) % 32,
        Special::WarpSize => 32,
    }) as u64
}

pub(super) fn width_mask(w: u32) -> u64 {
    if w >= 64 {
        u64::MAX
    } else {
        (1u64 << w) - 1
    }
}

pub(super) fn linear_to_tid(t: u32, block: (u32, u32, u32)) -> (u32, u32, u32) {
    let x = t % block.0;
    let y = (t / block.0) % block.1;
    let z = t / (block.0 * block.1);
    (x, y, z)
}

pub(super) fn int_bvop(op: IntBinOp, signed: bool) -> BvOp {
    match op {
        IntBinOp::Add => BvOp::Add,
        IntBinOp::Sub => BvOp::Sub,
        IntBinOp::MulLo => BvOp::Mul,
        IntBinOp::Div => {
            if signed {
                BvOp::SDiv
            } else {
                BvOp::UDiv
            }
        }
        IntBinOp::Rem => {
            if signed {
                BvOp::SRem
            } else {
                BvOp::URem
            }
        }
        IntBinOp::Min => {
            if signed {
                BvOp::SMin
            } else {
                BvOp::UMin
            }
        }
        IntBinOp::Max => {
            if signed {
                BvOp::SMax
            } else {
                BvOp::UMax
            }
        }
        IntBinOp::And => BvOp::And,
        IntBinOp::Or => BvOp::Or,
        IntBinOp::Xor => BvOp::Xor,
        IntBinOp::Shl => BvOp::Shl,
        IntBinOp::Shr => {
            if signed {
                BvOp::AShr
            } else {
                BvOp::LShr
            }
        }
        IntBinOp::MulWide | IntBinOp::MulHi => unreachable!(),
    }
}

pub(super) fn cmp_kind(c: CmpOp, signed: bool) -> CmpKind {
    match (c, signed) {
        (CmpOp::Eq, _) => CmpKind::Eq,
        (CmpOp::Ne, _) => CmpKind::Ne,
        (CmpOp::Lt, true) => CmpKind::Slt,
        (CmpOp::Le, true) => CmpKind::Sle,
        (CmpOp::Gt, true) => CmpKind::Sgt,
        (CmpOp::Ge, true) => CmpKind::Sge,
        (CmpOp::Lt, false) => CmpKind::Ult,
        (CmpOp::Le, false) => CmpKind::Ule,
        (CmpOp::Gt, false) => CmpKind::Ugt,
        (CmpOp::Ge, false) => CmpKind::Uge,
    }
}

pub(super) fn f32_bin(op: FltBinOp, x: f32, y: f32) -> f32 {
    match op {
        FltBinOp::Add => x + y,
        FltBinOp::Sub => x - y,
        FltBinOp::Mul => x * y,
        FltBinOp::Div => x / y,
        FltBinOp::Min => x.min(y),
        FltBinOp::Max => x.max(y),
    }
}

pub(super) fn f64_bin(op: FltBinOp, x: f64, y: f64) -> f64 {
    match op {
        FltBinOp::Add => x + y,
        FltBinOp::Sub => x - y,
        FltBinOp::Mul => x * y,
        FltBinOp::Div => x / y,
        FltBinOp::Min => x.min(y),
        FltBinOp::Max => x.max(y),
    }
}

pub(super) fn f32_un(op: FltUnOp, x: f32) -> f32 {
    match op {
        FltUnOp::Neg => -x,
        FltUnOp::Abs => x.abs(),
        FltUnOp::Sqrt => x.sqrt(),
        FltUnOp::Rsqrt => 1.0 / x.sqrt(),
        FltUnOp::Rcp => 1.0 / x,
        FltUnOp::Sin => x.sin(),
        FltUnOp::Cos => x.cos(),
        FltUnOp::Ex2 => x.exp2(),
        FltUnOp::Lg2 => x.log2(),
    }
}

pub(super) fn f64_un(op: FltUnOp, x: f64) -> f64 {
    match op {
        FltUnOp::Neg => -x,
        FltUnOp::Abs => x.abs(),
        FltUnOp::Sqrt => x.sqrt(),
        FltUnOp::Rsqrt => 1.0 / x.sqrt(),
        FltUnOp::Rcp => 1.0 / x,
        FltUnOp::Sin => x.sin(),
        FltUnOp::Cos => x.cos(),
        FltUnOp::Ex2 => x.exp2(),
        FltUnOp::Lg2 => x.log2(),
    }
}

pub(super) fn convert(v: u64, sty: Type, dty: Type) -> u64 {
    use Type::*;
    let as_f64 = |v: u64, t: Type| -> f64 {
        match t {
            F32 => f32::from_bits(v as u32) as f64,
            F64 => f64::from_bits(v),
            _ => {
                if t.is_signed() {
                    to_signed(v, t.bits()) as f64
                } else {
                    (v & width_mask(t.bits())) as f64
                }
            }
        }
    };
    match (sty.is_float(), dty.is_float()) {
        (false, false) => {
            // int → int: sign- or zero-extend / truncate
            let x = if sty.is_signed() {
                to_signed(v, sty.bits()) as u64
            } else {
                v & width_mask(sty.bits())
            };
            x & width_mask(dty.bits())
        }
        (_, true) => {
            let f = as_f64(v, sty);
            if dty == F32 {
                (f as f32).to_bits() as u64
            } else {
                f.to_bits()
            }
        }
        (true, false) => {
            let f = as_f64(v, sty);
            // cvt.rzi semantics: round toward zero, saturate
            let x = f.trunc();
            if dty.is_signed() {
                (x as i64 as u64) & width_mask(dty.bits())
            } else {
                (x as u64) & width_mask(dty.bits())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ptx::parser::parse_kernel;
    use crate::sim::memory::{Allocator, GlobalMem};

    /// Differential harness shadowing the old entry point: every test in
    /// this module now runs the reference walker, the decoded engine
    /// (serial) and the decoded engine on two workers, asserts their
    /// memory / stats / traces are bit-identical, and returns the decoded
    /// result the assertions below inspect.
    fn run(k: &Kernel, cfg: &SimConfig, mem: GlobalMem) -> Result<SimResult, SimError> {
        let r_ref = run_reference(k, cfg, mem.clone());
        let r_dec = crate::sim::run(k, cfg, mem.clone());
        let mut cfg_par = cfg.clone();
        cfg_par.sim_threads = 2;
        let r_par = crate::sim::run(k, &cfg_par, mem);
        match (&r_ref, &r_dec, &r_par) {
            (Ok(a), Ok(b), Ok(c)) => {
                for (tag, other) in [("decoded", b), ("parallel", c)] {
                    assert_eq!(a.mem, other.mem, "{tag}: GlobalMem diverged");
                    assert_eq!(a.stats, other.stats, "{tag}: stats diverged");
                    assert_eq!(a.trace, other.trace, "{tag}: trace diverged");
                }
            }
            (Err(_), Err(_), Err(_)) => {}
            (a, b, c) => panic!("engines disagree on success: {a:?} / {b:?} / {c:?}"),
        }
        r_dec
    }

    /// Pins *exactly* which fields the manual [`PartialEq`] on `SimStats`
    /// excludes: the two engine-telemetry counters and nothing else. If a
    /// semantic counter ever slips into the excluded set, the loop below
    /// fails; if telemetry starts breaking equality, the first assertion
    /// fails.
    #[test]
    fn simstats_equality_excludes_exactly_the_engine_telemetry() {
        let base = SimStats {
            warp_instructions: 1,
            thread_instructions: 2,
            global_loads: 3,
            nc_loads: 4,
            shared_loads: 5,
            stores: 6,
            shfls: 7,
            branches: 8,
            divergent_branches: 9,
            uninit_reads: 10,
            cross_block_write_conflicts: 11,
            barriers: 12,
            barrier_phases: 13,
            superblocks_entered: 14,
            vector_warp_steps: 15,
        };

        // telemetry-only differences must NOT break equality
        let mut telemetry = base;
        telemetry.superblocks_entered += 100;
        telemetry.vector_warp_steps += 100;
        assert_eq!(base, telemetry, "engine telemetry must be excluded");

        // every semantic counter MUST break equality when bumped
        type Bump = (&'static str, fn(&mut SimStats));
        let bumps: [Bump; 13] = [
            ("warp_instructions", |s| s.warp_instructions += 1),
            ("thread_instructions", |s| s.thread_instructions += 1),
            ("global_loads", |s| s.global_loads += 1),
            ("nc_loads", |s| s.nc_loads += 1),
            ("shared_loads", |s| s.shared_loads += 1),
            ("stores", |s| s.stores += 1),
            ("shfls", |s| s.shfls += 1),
            ("branches", |s| s.branches += 1),
            ("divergent_branches", |s| s.divergent_branches += 1),
            ("uninit_reads", |s| s.uninit_reads += 1),
            ("cross_block_write_conflicts", |s| {
                s.cross_block_write_conflicts += 1;
            }),
            ("barriers", |s| s.barriers += 1),
            ("barrier_phases", |s| s.barrier_phases += 1),
        ];
        for (name, bump) in bumps {
            let mut changed = base;
            bump(&mut changed);
            assert_ne!(base, changed, "{name} must participate in equality");
        }
    }

    /// c[i] = a[i] + b[i] over one block of 64 threads.
    #[test]
    fn vecadd_runs() {
        let k = parse_kernel(
            r#"
.visible .entry vadd(.param .u64 c, .param .u64 a, .param .u64 b){
.reg .b32 %r<6>; .reg .b64 %rd<10>; .reg .f32 %f<4>;
ld.param.u64 %rd1, [c];
ld.param.u64 %rd2, [a];
ld.param.u64 %rd3, [b];
cvta.to.global.u64 %rd4, %rd2;
cvta.to.global.u64 %rd5, %rd3;
cvta.to.global.u64 %rd6, %rd1;
mov.u32 %r2, %ntid.x;
mov.u32 %r3, %ctaid.x;
mov.u32 %r4, %tid.x;
mad.lo.s32 %r1, %r3, %r2, %r4;
mul.wide.s32 %rd7, %r1, 4;
add.s64 %rd8, %rd4, %rd7;
add.s64 %rd9, %rd5, %rd7;
ld.global.nc.f32 %f1, [%rd8];
ld.global.nc.f32 %f2, [%rd9];
add.f32 %f3, %f1, %f2;
add.s64 %rd8, %rd6, %rd7;
st.global.f32 [%rd8], %f3;
ret;
}
"#,
        )
        .unwrap();
        let n = 64usize;
        let mut mem = GlobalMem::new(1 << 16);
        let mut alloc = Allocator::new(&mem);
        let (c, a, b) = (
            alloc.alloc(4 * n as u64),
            alloc.alloc(4 * n as u64),
            alloc.alloc(4 * n as u64),
        );
        let av: Vec<f32> = (0..n).map(|i| i as f32).collect();
        let bv: Vec<f32> = (0..n).map(|i| 2.0 * i as f32).collect();
        mem.write_f32s(a, &av).unwrap();
        mem.write_f32s(b, &bv).unwrap();
        let cfg = SimConfig::new(2, 32, vec![c, a, b]);
        let r = run(&k, &cfg, mem).unwrap();
        let cv = r.mem.read_f32s(c, n).unwrap();
        for i in 0..n {
            assert_eq!(cv[i], 3.0 * i as f32);
        }
        assert_eq!(r.stats.stores, 64);
        assert_eq!(r.stats.uninit_reads, 0);
    }

    /// Guarded early-exit: threads ≥ n skip the store (fractional warp).
    #[test]
    fn guard_divergence_and_fractional_warp() {
        let k = parse_kernel(
            r#"
.visible .entry g(.param .u64 out, .param .u32 n){
.reg .b32 %r<6>; .reg .b64 %rd<6>; .reg .pred %p<2>;
ld.param.u64 %rd1, [out];
ld.param.u32 %r5, [n];
cvta.to.global.u64 %rd2, %rd1;
mov.u32 %r4, %tid.x;
setp.ge.s32 %p1, %r4, %r5;
@%p1 bra $EXIT;
mul.wide.s32 %rd3, %r4, 4;
add.s64 %rd4, %rd2, %rd3;
st.global.b32 [%rd4], %r4;
$EXIT: ret;
}
"#,
        )
        .unwrap();
        let mut mem = GlobalMem::new(1 << 12);
        let mut alloc = Allocator::new(&mem);
        let out = alloc.alloc(4 * 48);
        mem.write_u32s(out, &vec![7777; 48]).unwrap();
        // block of 40 threads (fractional second warp), n = 20
        let mut cfg = SimConfig::new(1, 40, vec![out, 20]);
        cfg.record_trace = true;
        let r = run(&k, &cfg, mem).unwrap();
        let vals = r.mem.read_u32s(out, 48).unwrap();
        for (i, v) in vals.iter().enumerate() {
            if i < 20 {
                assert_eq!(*v, i as u32);
            } else {
                assert_eq!(*v, 7777, "thread {i} must not store");
            }
        }
        assert!(r.stats.divergent_branches >= 1);
        assert!(!r.trace.is_empty());
    }

    /// shfl.sync.up/down semantics incl. out-of-range lanes and pred out.
    #[test]
    fn shfl_semantics() {
        let k = parse_kernel(
            r#"
.visible .entry s(.param .u64 up, .param .u64 dn, .param .u64 pu){
.reg .b32 %r<8>; .reg .b64 %rd<8>; .reg .pred %p<2>;
ld.param.u64 %rd1, [up];
ld.param.u64 %rd2, [dn];
ld.param.u64 %rd3, [pu];
cvta.to.global.u64 %rd1, %rd1;
cvta.to.global.u64 %rd2, %rd2;
cvta.to.global.u64 %rd3, %rd3;
mov.u32 %r1, %tid.x;
activemask.b32 %r2;
shfl.sync.up.b32 %r3|%p1, %r1, 2, 0, %r2;
shfl.sync.down.b32 %r4, %r1, 3, 31, %r2;
mul.wide.s32 %rd4, %r1, 4;
add.s64 %rd5, %rd1, %rd4;
st.global.b32 [%rd5], %r3;
add.s64 %rd6, %rd2, %rd4;
st.global.b32 [%rd6], %r4;
selp.b32 %r5, 1, 0, %p1;
add.s64 %rd7, %rd3, %rd4;
st.global.b32 [%rd7], %r5;
ret;
}
"#,
        )
        .unwrap();
        let mut mem = GlobalMem::new(1 << 12);
        let mut alloc = Allocator::new(&mem);
        let (up, dn, pu) = (alloc.alloc(128), alloc.alloc(128), alloc.alloc(128));
        let cfg = SimConfig::new(1, 32, vec![up, dn, pu]);
        let r = run(&k, &cfg, mem).unwrap();
        let upv = r.mem.read_u32s(up, 32).unwrap();
        let dnv = r.mem.read_u32s(dn, 32).unwrap();
        let puv = r.mem.read_u32s(pu, 32).unwrap();
        for i in 0..32u32 {
            // up by 2: lane i gets lane i-2's tid; lanes 0,1 keep own value
            let expect_up = if i >= 2 { i - 2 } else { i };
            assert_eq!(upv[i as usize], expect_up, "up lane {i}");
            assert_eq!(puv[i as usize], (i >= 2) as u32, "pred lane {i}");
            // down by 3: lane i gets lane i+3's tid; lanes 29..31 keep own
            let expect_dn = if i + 3 <= 31 { i + 3 } else { i };
            assert_eq!(dnv[i as usize], expect_dn, "down lane {i}");
        }
        assert_eq!(r.stats.shfls, 2);
    }

    /// A loop with a trip count from a parameter.
    #[test]
    fn loop_sums() {
        let k = parse_kernel(
            r#"
.visible .entry l(.param .u64 out, .param .u64 a, .param .u32 n){
.reg .b32 %r<8>; .reg .b64 %rd<8>; .reg .f32 %f<4>; .reg .pred %p<2>;
ld.param.u64 %rd1, [out];
ld.param.u64 %rd2, [a];
ld.param.u32 %r5, [n];
cvta.to.global.u64 %rd3, %rd2;
cvta.to.global.u64 %rd4, %rd1;
mov.u32 %r1, 0;
mov.f32 %f1, 0f00000000;
mov.b64 %rd5, %rd3;
$LOOP:
ld.global.f32 %f2, [%rd5];
add.f32 %f1, %f1, %f2;
add.s64 %rd5, %rd5, 4;
add.s32 %r1, %r1, 1;
setp.lt.s32 %p1, %r1, %r5;
@%p1 bra $LOOP;
mov.u32 %r4, %tid.x;
mul.wide.s32 %rd6, %r4, 4;
add.s64 %rd7, %rd4, %rd6;
st.global.f32 [%rd7], %f1;
ret;
}
"#,
        )
        .unwrap();
        let mut mem = GlobalMem::new(1 << 12);
        let mut alloc = Allocator::new(&mem);
        let (out, a) = (alloc.alloc(4), alloc.alloc(64));
        mem.write_f32s(a, &[1.0, 2.0, 3.0, 4.0, 5.0, 0.0, 0.0, 0.0])
            .unwrap();
        let cfg = SimConfig::new(1, 1, vec![out, a, 5]);
        let r = run(&k, &cfg, mem).unwrap();
        assert_eq!(r.mem.read_f32s(out, 1).unwrap()[0], 15.0);
    }

    /// Run one full-warp `shfl.sync.<mode>` with immediate `b`/`c`
    /// operands; returns (result, predicate) per lane. Lane values are
    /// the lane ids, so the result *is* the source-lane table.
    fn run_shfl(mode: &str, b: u32, c: u32) -> (Vec<u32>, Vec<u32>) {
        let src = format!(
            r#"
.visible .entry sh(.param .u64 dst, .param .u64 prd){{
.reg .b32 %r<8>; .reg .b64 %rd<8>; .reg .pred %p<2>;
ld.param.u64 %rd1, [dst];
ld.param.u64 %rd2, [prd];
cvta.to.global.u64 %rd1, %rd1;
cvta.to.global.u64 %rd2, %rd2;
mov.u32 %r1, %tid.x;
activemask.b32 %r2;
shfl.sync.{mode}.b32 %r3|%p1, %r1, {b}, {c}, %r2;
selp.b32 %r4, 1, 0, %p1;
mul.wide.s32 %rd3, %r1, 4;
add.s64 %rd4, %rd1, %rd3;
st.global.b32 [%rd4], %r3;
add.s64 %rd5, %rd2, %rd3;
st.global.b32 [%rd5], %r4;
ret;
}}
"#
        );
        let k = parse_kernel(&src).unwrap();
        let mem = GlobalMem::new(1 << 12);
        let mut alloc = Allocator::new(&mem);
        let (dst, prd) = (alloc.alloc(128), alloc.alloc(128));
        let cfg = SimConfig::new(1, 32, vec![dst, prd]);
        let r = run(&k, &cfg, mem).unwrap();
        (
            r.mem.read_u32s(dst, 32).unwrap(),
            r.mem.read_u32s(prd, 32).unwrap(),
        )
    }

    /// PTX ISA `c` encoding: clamp in bits 0–4, segment mask in bits
    /// 8–12. `c = 0x181f` splits the warp into 8-lane segments with the
    /// clamp at each segment's end: down-by-2 shifts within the segment
    /// and the last two lanes of each segment fall out of range.
    #[test]
    fn shfl_down_segmented_clamp_table() {
        let (vals, preds) = run_shfl("down", 2, 0x181f);
        #[rustfmt::skip]
        let expect = [
             2,  3,  4,  5,  6,  7,  6,  7,
            10, 11, 12, 13, 14, 15, 14, 15,
            18, 19, 20, 21, 22, 23, 22, 23,
            26, 27, 28, 29, 30, 31, 30, 31,
        ];
        let expect_p = [1, 1, 1, 1, 1, 1, 0, 0].repeat(4);
        assert_eq!(vals, expect, "down source lanes");
        assert_eq!(preds, expect_p, "down predicates");
    }

    /// Up-by-3 over 8-lane segments (`c = 0x1800`, clamp 0): the lower
    /// bound is the *lane's segment base* (lane & segmask), not the raw
    /// segment-mask bits — the first three lanes of every segment keep
    /// their own value with a false predicate.
    #[test]
    fn shfl_up_segmented_base_table() {
        let (vals, preds) = run_shfl("up", 3, 0x1800);
        #[rustfmt::skip]
        let expect = [
             0,  1,  2,  0,  1,  2,  3,  4,
             8,  9, 10,  8,  9, 10, 11, 12,
            16, 17, 18, 16, 17, 18, 19, 20,
            24, 25, 26, 24, 25, 26, 27, 28,
        ];
        let expect_p = [0, 0, 0, 1, 1, 1, 1, 1].repeat(4);
        assert_eq!(vals, expect, "up source lanes");
        assert_eq!(preds, expect_p, "up predicates");
    }

    /// Up with a nonzero clamp (`c = 4`, no segments): per the ISA,
    /// maxLane is Up's *lower* bound, so lanes whose source index
    /// `lane - 1` falls below 4 keep their own value with a false
    /// predicate — even though the index itself is ≥ 0.
    #[test]
    fn shfl_up_nonzero_clamp_table() {
        let (vals, preds) = run_shfl("up", 1, 4);
        let expect: Vec<u32> = (0..32u32).map(|l| if l >= 5 { l - 1 } else { l }).collect();
        let expect_p: Vec<u32> = (0..32u32).map(|l| (l >= 5) as u32).collect();
        assert_eq!(vals, expect, "up source lanes");
        assert_eq!(preds, expect_p, "up predicates");
    }

    /// Butterfly with the full-warp clamp (`c = 0x1f`): every lane pairs
    /// with `lane ^ 1`, always in range.
    #[test]
    fn shfl_bfly_xor_table() {
        let (vals, preds) = run_shfl("bfly", 1, 0x1f);
        let expect: Vec<u32> = (0..32u32).map(|l| l ^ 1).collect();
        assert_eq!(vals, expect, "bfly source lanes");
        assert_eq!(preds, vec![1; 32], "bfly predicates");
    }

    /// Idx must honour the segment mask: `j = (lane & segmask) |
    /// (b & ~segmask)`. With 8-lane segments and b = 9, every lane reads
    /// its segment's lane 1 (9 & ~0x18 = 1) — not global lane 9.
    #[test]
    fn shfl_idx_segmented_table() {
        let (vals, preds) = run_shfl("idx", 9, 0x181f);
        #[rustfmt::skip]
        let expect = [
             1,  1,  1,  1,  1,  1,  1,  1,
             9,  9,  9,  9,  9,  9,  9,  9,
            17, 17, 17, 17, 17, 17, 17, 17,
            25, 25, 25, 25, 25, 25, 25, 25,
        ];
        assert_eq!(vals, expect, "idx source lanes");
        assert_eq!(preds, vec![1; 32], "idx predicates");
    }

    /// A `.shared` access outside the block's shared window must be an
    /// out-of-bounds error — never a silent alias onto itself or global
    /// memory (the old `checked_sub(SHARED_BASE).unwrap_or(addr)` bug).
    #[test]
    fn shared_out_of_window_is_an_error() {
        let k = parse_kernel(
            r#"
.visible .entry bad(.param .u64 out){
.reg .b32 %r<4>; .reg .b64 %rd<4>;
.shared .align 4 .b8 win[64];
mov.u32 %r1, 7;
st.shared.b32 [4096], %r1;
ret;
}
"#,
        )
        .unwrap();
        let mem = GlobalMem::new(1 << 14);
        let mut alloc = Allocator::new(&mem);
        let out = alloc.alloc(4);
        let cfg = SimConfig::new(1, 1, vec![out]);
        let err = run(&k, &cfg, mem).unwrap_err();
        assert!(
            matches!(err, SimError::Mem(MemError::OutOfBounds { .. })),
            "expected OutOfBounds, got {err:?}"
        );
    }

    /// Window-relative `.shared` addressing (offsets below the window
    /// size) keeps working and stays bounds-checked at the window edge.
    #[test]
    fn shared_window_relative_roundtrips_and_is_bounded() {
        let k = parse_kernel(
            r#"
.visible .entry ok(.param .u64 out){
.reg .b32 %r<4>; .reg .b64 %rd<4>;
.shared .align 4 .b8 win[64];
ld.param.u64 %rd1, [out];
cvta.to.global.u64 %rd1, %rd1;
mov.u32 %r1, 1234;
st.shared.b32 [8], %r1;
ld.shared.b32 %r2, [8];
st.global.b32 [%rd1], %r2;
ret;
}
"#,
        )
        .unwrap();
        let mem = GlobalMem::new(1 << 12);
        let mut alloc = Allocator::new(&mem);
        let out = alloc.alloc(4);
        let cfg = SimConfig::new(1, 1, vec![out]);
        let r = run(&k, &cfg, mem).unwrap();
        assert_eq!(r.mem.read_u32s(out, 1).unwrap()[0], 1234);

        // one byte past the window edge errors
        let k2 = parse_kernel(
            r#"
.visible .entry edge(.param .u64 out){
.reg .b32 %r<4>; .reg .b64 %rd<4>;
.shared .align 4 .b8 win[64];
mov.u32 %r1, 7;
st.shared.b32 [61], %r1;
ret;
}
"#,
        )
        .unwrap();
        let mem2 = GlobalMem::new(1 << 12);
        let cfg2 = SimConfig::new(1, 1, vec![0x1000]);
        assert!(run(&k2, &cfg2, mem2).is_err(), "store crossing the window edge");
    }

    /// The step budget counts *statements* on every engine. The loop body
    /// revisits its label each iteration: mov(1) + 8 × [label + add +
    /// setp + bra](4) + ret(1) = 34 statements. The exact budget passes,
    /// one below trips — and the differential shim asserts the reference
    /// and decoded engines agree at both boundaries (the decoded engine
    /// used to count micro-ops, where labels are free, and tripped 8
    /// statements later).
    #[test]
    fn step_limit_grazes_identically_on_both_engines() {
        let k = parse_kernel(
            r#"
.visible .entry graze(.param .u64 out){
.reg .b32 %r<4>; .reg .b64 %rd<4>; .reg .pred %p<2>;
mov.u32 %r1, 0;
$LOOP:
add.s32 %r1, %r1, 1;
setp.lt.s32 %p1, %r1, 8;
@%p1 bra $LOOP;
ret;
}
"#,
        )
        .unwrap();
        let mem = GlobalMem::new(1 << 12);
        let mut cfg = SimConfig::new(1, 1, vec![0x1000]);
        cfg.max_warp_steps = 34;
        run(&k, &cfg, mem.clone()).expect("exact statement budget must pass");
        cfg.max_warp_steps = 33;
        let err = run(&k, &cfg, mem).unwrap_err();
        assert!(
            matches!(err, SimError::StepLimit(33)),
            "one below the budget must trip, got {err:?}"
        );
    }

    /// `detect_races`: a block loading global bytes an earlier block
    /// wrote is a hard error on every engine; the same kernel passes with
    /// the diagnostic off, and a same-block read-after-write never trips.
    #[test]
    fn detect_races_flags_cross_block_raw() {
        // every block stores out[ctaid] then reads out[0]: block 0 reads
        // its own write (fine), block 1 reads block 0's write (race)
        let k = parse_kernel(
            r#"
.visible .entry race(.param .u64 out){
.reg .b32 %r<4>; .reg .b64 %rd<6>;
ld.param.u64 %rd1, [out];
cvta.to.global.u64 %rd2, %rd1;
mov.u32 %r1, %ctaid.x;
mul.wide.s32 %rd3, %r1, 4;
add.s64 %rd4, %rd2, %rd3;
st.global.b32 [%rd4], %r1;
ld.global.b32 %r2, [%rd2];
ret;
}
"#,
        )
        .unwrap();
        let mem = GlobalMem::new(1 << 12);
        let mut alloc = Allocator::new(&mem);
        let out = alloc.alloc(64);
        let mut cfg = SimConfig::new(2, 1, vec![out]);

        // off: undetected, identical results on every engine
        let r = run(&k, &cfg, mem.clone()).expect("diagnostic off must not fail");
        assert_eq!(r.stats.cross_block_write_conflicts, 0);

        // on: hard error naming the offending blocks (the shim asserts
        // the reference and both decoded paths agree on failure)
        cfg.detect_races = true;
        let err = run(&k, &cfg, mem.clone()).unwrap_err();
        match err {
            SimError::CrossBlockRace {
                writer_block,
                reader_block,
                bytes,
                ..
            } => {
                assert_eq!((writer_block, reader_block, bytes), (0, 1, 4));
            }
            other => panic!("expected CrossBlockRace, got {other:?}"),
        }

        // a single-block launch of the same kernel is race-free
        let cfg1 = {
            let mut c = SimConfig::new(1, 1, vec![out]);
            c.detect_races = true;
            c
        };
        run(&k, &cfg1, mem).expect("same-block RAW must not trip the diagnostic");
    }

    #[test]
    fn cvt_f32_s32_roundtrip() {
        assert_eq!(
            convert((-7i64) as u64 & 0xFFFF_FFFF, Type::S32, Type::F32),
            (-7.0f32).to_bits() as u64
        );
        assert_eq!(
            convert((-7.9f32).to_bits() as u64, Type::F32, Type::S32),
            (-7i64 as u64) & 0xFFFF_FFFF
        );
        assert_eq!(convert(300, Type::U32, Type::U8), 300 & 0xFF);
    }
}
