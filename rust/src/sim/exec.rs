//! Executor for the pre-decoded micro-op form, with parallel grid
//! execution.
//!
//! # Why it is fast
//!
//! Per warp step the reference engine hashes register-name strings,
//! chases label `HashMap`s and re-derives width masks; this engine walks
//! a flat [`Uop`] array whose operands are already slot indices and
//! pre-masked immediates. Warp state is struct-of-arrays — one
//! `regs[slot * 32 + lane]` array per warp plus a packed `written`
//! bitmask per slot — so the 32-lane inner loops touch contiguous
//! memory.
//!
//! # Grid execution semantics
//!
//! With one worker (the default) blocks execute directly on the result
//! image in launch order, exactly like the reference engine — no
//! snapshots, no logs. With `sim_threads > 1`, blocks are split into
//! contiguous index ranges on the coordinator's work-stealing
//! [`WorkQueue`] and every block runs under *snapshot isolation*: it
//! observes the launch-time memory image plus its own stores (each
//! worker owns a private copy of global memory; a per-block store log is
//! applied during the block and undone after), and the logs are merged
//! into the result image **in launch block order**. The outcome is
//! therefore bit-identical for any worker count — including the
//! reference engine's serial order — for every kernel that does not read
//! another block's global writes (which is scheduling-dependent on real
//! hardware and out of scope for all engines). Overlapping writes from
//! different blocks are deterministic (last block in launch order wins,
//! as in the serial engine) and are surfaced in
//! [`SimStats::cross_block_write_conflicts`].
//!
//! # Cooperative warp scheduling
//!
//! Within a block, warps advance in barrier-delimited *phases* (see
//! [`super::machine`] for the semantics): the scheduler swaps each
//! warp's struct-of-arrays state in and out of the worker's hot-loop
//! fields (`Vec` swaps are pointer moves) and runs it until it retires
//! or converges on a `bar.sync`, suspending and resuming at micro-op
//! granularity. Barrier-free kernels degenerate to the old serialized
//! warp order, so nothing changes for them; barrier violations are hard
//! [`SimError::BarrierDivergence`] errors with the same taxonomy and
//! schedule as the reference engine.
//!
//! # Fidelity
//!
//! Observable behaviour — final [`GlobalMem`], [`SimStats`], and the
//! block-(0,0,0) [`WarpEvent`] trace — is bit-identical to
//! [`super::machine::run_reference`]; the differential tests in
//! `tests/integration_sim.rs` and the unit tests in `machine.rs` hold the
//! two engines (serial and parallel) to that. The only intentional
//! deviation: static name/label errors surface at decode time rather
//! than first execution. The `max_warp_steps` budget counts kernel-body
//! *statements* (labels included), tracked as a per-lane statement
//! position: a lane's position restarts at the statement after each
//! issued instruction, or at the *targeted label* on a taken branch, and
//! each issue charges the gap from the issuing group's earliest position
//! to the instruction — exactly the label visits the reference engine
//! pays, including branches into the middle of consecutive-label runs
//! and trailing labels at body end (charged at retire). The accounting
//! is exact for **every** program, so the two engines trip the limit on
//! identical step counts always.
//!
//! With [`SimConfig::detect_races`] set, grid execution is forced serial
//! and every global load probes the last-writer shadow: a block reading
//! bytes an earlier block wrote is a hard [`SimError::CrossBlockRace`]
//! (snapshot isolation in the parallel path hides exactly those reads,
//! which is why the diagnostic pins the serial engine).
//!
//! # Superblock fast path
//!
//! With [`SimConfig::superblocks`] set (the default), a warp group whose
//! current micro-op starts a decode-time-fused straight-line run
//! ([`DecodedKernel::sb_end`]) executes the whole run at once: the step
//! budget is charged in bulk at entry (the telescoped sum of the per-uop
//! charges, `last.stmt + 1 - min(stmt_pos)`, via the statement side
//! table), and the interior skips the per-uop scheduling loop entirely —
//! no min-pc reconvergence scans, no per-uop budget checks, no trace
//! hooks, no per-uop `pc`/`stmt_pos` writes. The run is clamped to the
//! earliest *other* live lane's pc, so divergent groups merge exactly
//! where the per-uop path merges them, and a run that could trip
//! `max_warp_steps` mid-block falls back to the per-uop path so the
//! `StepLimit`-vs-memory-error interleaving never changes. The fast path
//! is disabled per block while that block records a [`WarpEvent`] trace,
//! and globally under `detect_races` — those paths need per-uop hooks.
//! Scheduler handoff is untouched: `bar.sync` always terminates a run
//! (barriers are control ops, so no superblock contains one), and the
//! suspend/release protocol is exactly the per-uop path's.
//!
//! # Lane-vectorized kernels (`simd` feature)
//!
//! With the `simd` cargo feature and [`SimConfig::vector`] set, hot
//! register-to-register micro-ops (integer/float ALU, `setp`, `selp`,
//! moves) dispatch once per warp to full-width kernels over the
//! struct-of-arrays state (`sim::lanes`): operands are gathered into
//! 32-lane buffers with the same masks the scalar path uses, computed
//! branchlessly for all lanes, and blended back under the exec mask —
//! shapes the compiler maps onto SIMD units. The per-lane scalar path is
//! retained verbatim as the differential oracle (and is the only path on
//! the default stable build); uninitialized-read accounting is preserved
//! exactly (`popcount(exec & !written)` per operand equals the scalar
//! per-lane count).

use super::decode::{Daddr, DecodedKernel, Dop, Uop};
use super::machine::{
    barrier_release, convert, f32_bin, f32_un, f64_bin, f64_un, flt_cmp, linear_to_tid,
    mul_full, mul_hi, shared_window_offset, shfl_source_lane, special_value, width_mask,
    BarrierCause, PhaseShadow, SimConfig, SimError, SimResult, SimStats, WarpEvent, WarpHalt,
    WarpStatus, WriteShadow,
};
use super::memory::GlobalMem;
use crate::coordinator::queue::WorkQueue;
use crate::ptx::ast::Space;
use crate::sym::term::{eval_bin, eval_cmp};
use std::sync::Mutex;

const WARP: usize = 32;

/// One logged global-memory store: what was written and what it replaced
/// (for the post-block undo that keeps the worker's image pristine).
struct StoreRec {
    addr: u64,
    bytes: u32,
    val: u64,
    old: u64,
}

/// Everything one block produced: its ordered store log, its stats, and
/// (block 0 only) its issue trace.
struct BlockRun {
    log: Vec<StoreRec>,
    stats: SimStats,
    trace: Vec<Vec<WarpEvent>>,
}

/// A block's result slot, filled by whichever worker ran it.
type BlockSlot = Mutex<Option<Result<BlockRun, SimError>>>;

/// Why the superblock fast path would fall back to per-uop execution for
/// a run under `cfg` — `None` when the fast path is eligible. Mirrors the
/// per-block gate in the executor (`fast = superblocks && !record &&
/// !detect_races`; trace recording only covers block (0,0,0), so
/// `"trace-hooks-block0"` means *that block* runs per-uop while the rest
/// of the grid stays fast). Exposed for the `sim.engine` trace event.
pub fn engine_fallback_reason(cfg: &SimConfig) -> Option<&'static str> {
    if !cfg.superblocks {
        Some("superblocks-disabled")
    } else if cfg.detect_races {
        Some("race-shadow")
    } else if cfg.record_trace {
        Some("trace-hooks-block0")
    } else {
        None
    }
}

/// Run a decoded kernel over the whole grid.
///
/// `cfg.sim_threads` workers execute contiguous block ranges; results are
/// bit-identical for any thread count (see the module docs for the
/// snapshot-isolation semantics that make this true).
pub fn run_decoded(
    dk: &DecodedKernel,
    cfg: &SimConfig,
    mem: GlobalMem,
) -> Result<SimResult, SimError> {
    // Launch-time parameter check, same order/message as the reference
    // engine's eager map construction.
    if cfg.params.len() < dk.param_names.len() {
        return Err(SimError::UnknownParam(format!(
            "{} (no value supplied)",
            dk.param_names[cfg.params.len()]
        )));
    }
    let (gx, gy, gz) = cfg.grid;
    let nblocks = gx as usize * gy as usize * gz as usize;
    if nblocks == 0 {
        return Ok(SimResult {
            mem,
            stats: SimStats::default(),
            trace: Vec::new(),
        });
    }
    let tpb = cfg.threads_per_block();
    // the race diagnostic needs the serial last-writer order; snapshot
    // isolation would hide exactly the cross-block reads it looks for
    let workers = if cfg.detect_races {
        1
    } else {
        cfg.sim_threads.max(1).min(nblocks)
    };

    if workers == 1 {
        // Direct serial path: execute on the result image itself, with
        // inline conflict accounting — no snapshot, log, undo or merge.
        // Identical results to the parallel path for every supported
        // kernel (cross-block RAW is out of scope for all engines).
        let mut wk = Worker::new(dk, cfg, mem);
        wk.direct = true;
        // conflicts are impossible on a single-block grid — skip the shadow
        wk.shadow = (nblocks > 1).then(|| WriteShadow::new(&wk.mem));
        wk.phase_shadow = cfg.detect_races.then(|| PhaseShadow::new(&wk.mem));
        let mut stats = SimStats::default();
        let mut trace = Vec::new();
        for b in 0..nblocks {
            let blk = wk.run_block(b, tpb)?;
            accumulate(&mut stats, &blk.stats);
            if b == 0 {
                trace = blk.trace;
            }
        }
        return Ok(SimResult {
            mem: wk.mem,
            stats,
            trace,
        });
    }

    let mut runs: Vec<Option<Result<BlockRun, SimError>>> = {
        let queue: WorkQueue<(usize, usize)> = WorkQueue::new(workers);
        // ~4 ranges per worker: coarse enough to amortize queue traffic,
        // fine enough for stealing to balance skewed blocks
        let chunk = nblocks.div_ceil(workers * 4).max(1);
        let mut start = 0;
        while start < nblocks {
            let end = (start + chunk).min(nblocks);
            queue.push((start, end));
            start = end;
        }
        let cells: Vec<BlockSlot> = (0..nblocks).map(|_| Mutex::new(None)).collect();
        let (qr, cr, mr) = (&queue, &cells, &mem);
        std::thread::scope(|s| {
            for w in 0..qr.workers() {
                s.spawn(move || {
                    let mut wk = Worker::new(dk, cfg, mr.clone());
                    while let Some((lo, hi)) = qr.pop(w) {
                        for b in lo..hi {
                            let r = wk.run_block(b, tpb);
                            *cr[b].lock().unwrap() = Some(r);
                        }
                        qr.retire();
                    }
                });
            }
        });
        cells.into_iter().map(|c| c.into_inner().unwrap()).collect()
    };

    // Deterministic merge in launch block order: first error wins; store
    // logs replay onto the master image with cross-block conflict
    // detection (identical to the serial paths' inline accounting).
    let mut master = mem;
    let mut stats = SimStats::default();
    let mut trace = Vec::new();
    let mut written_by = WriteShadow::new(&master);
    for (b, run) in runs.iter_mut().enumerate() {
        let blk = run.take().expect("block executed")?;
        accumulate(&mut stats, &blk.stats);
        for rec in &blk.log {
            master
                .store(rec.addr, rec.bytes, rec.val)
                .expect("logged store was bounds-checked during execution");
            if written_by.note(rec.addr, rec.bytes, b as u32) {
                stats.cross_block_write_conflicts += 1;
            }
        }
        if b == 0 {
            trace = blk.trace;
        }
    }
    Ok(SimResult {
        mem: master,
        stats,
        trace,
    })
}

/// Field-exhaustive stats accumulation (destructuring makes adding a
/// `SimStats` field without updating the merge a compile error).
fn accumulate(dst: &mut SimStats, s: &SimStats) {
    let SimStats {
        warp_instructions,
        thread_instructions,
        global_loads,
        nc_loads,
        shared_loads,
        stores,
        shfls,
        branches,
        divergent_branches,
        uninit_reads,
        cross_block_write_conflicts,
        barriers,
        barrier_phases,
        superblocks_entered,
        vector_warp_steps,
    } = *s;
    dst.warp_instructions += warp_instructions;
    dst.thread_instructions += thread_instructions;
    dst.global_loads += global_loads;
    dst.nc_loads += nc_loads;
    dst.shared_loads += shared_loads;
    dst.stores += stores;
    dst.shfls += shfls;
    dst.branches += branches;
    dst.divergent_branches += divergent_branches;
    dst.uninit_reads += uninit_reads;
    dst.cross_block_write_conflicts += cross_block_write_conflicts;
    dst.barriers += barriers;
    dst.barrier_phases += barrier_phases;
    dst.superblocks_entered += superblocks_entered;
    dst.vector_warp_steps += vector_warp_steps;
}

/// Serial launch order: `bx` fastest, then `by`, then `bz`.
fn block_coord(idx: usize, grid: (u32, u32, u32)) -> (u32, u32, u32) {
    let (gx, gy) = (grid.0 as usize, grid.1 as usize);
    (
        (idx % gx) as u32,
        ((idx / gx) % gy) as u32,
        (idx / (gx * gy)) as u32,
    )
}

/// Suspended state of one warp of the current block. The scheduler swaps
/// a slot into the worker's hot-loop fields for each scheduling slice
/// (`Vec` swaps are pointer moves, the arrays are 32 elements), so the
/// executor's inner loops are untouched by cooperative scheduling.
struct WarpSlot {
    regs: Vec<u64>,
    written: Vec<u32>,
    pc: [u32; WARP],
    stmt_pos: [u32; WARP],
    done: u32,
    tids: [(u32, u32, u32); WARP],
    steps: u64,
    status: WarpStatus,
}

impl Default for WarpSlot {
    fn default() -> WarpSlot {
        WarpSlot {
            regs: Vec::new(),
            written: Vec::new(),
            pc: [0; WARP],
            stmt_pos: [0; WARP],
            done: 0,
            tids: [(0, 0, 0); WARP],
            steps: 0,
            status: WarpStatus::Finished,
        }
    }
}

/// One worker: a global-memory image, block-local shared scratch, and
/// reusable struct-of-arrays warp state (hot-loop fields for the warp
/// currently scheduled; suspended warps live in `warps`).
///
/// In the parallel path `mem` is a private copy kept pristine between
/// blocks (stores are logged and undone); in the direct serial path
/// (`direct` set) `mem` IS the result image — stores apply in place with
/// inline conflict accounting, and no log is kept.
struct Worker<'a> {
    dk: &'a DecodedKernel,
    cfg: &'a SimConfig,
    mem: GlobalMem,
    /// Direct serial mode: stores apply in place, nothing is logged.
    direct: bool,
    /// Inline last-writer shadow (direct mode, multi-block grids only).
    shadow: Option<WriteShadow>,
    /// `detect_races` only: intra-block happens-before shadow.
    phase_shadow: Option<PhaseShadow>,
    cur_block: u32,
    cur_warp: u32,
    cur_phase: u32,
    shared: Vec<u8>,
    /// Lane registers, slot-major: `regs[slot * 32 + lane]`.
    regs: Vec<u64>,
    /// Written bitmask per slot (bit = lane).
    written: Vec<u32>,
    pc: [u32; WARP],
    /// Per-lane kernel-body *statement* position matching `pc` — the
    /// statement the lane entered its current straight-line stretch at
    /// (fallthrough: previous statement + 1; branch: the targeted label).
    /// The step budget charges `uop.stmt - min(stmt_pos) + 1` per issue,
    /// which is exactly the label visits the reference engine pays.
    stmt_pos: [u32; WARP],
    done: u32,
    tids: [(u32, u32, u32); WARP],
    /// Statement steps of the warp currently swapped in.
    steps: u64,
    /// Suspended per-warp state of the current block.
    warps: Vec<WarpSlot>,
    log: Vec<StoreRec>,
    stats: SimStats,
    trace: Vec<Vec<WarpEvent>>,
}

impl<'a> Worker<'a> {
    fn new(dk: &'a DecodedKernel, cfg: &'a SimConfig, mem: GlobalMem) -> Worker<'a> {
        Worker {
            dk,
            cfg,
            mem,
            direct: false,
            shadow: None,
            phase_shadow: None,
            cur_block: 0,
            cur_warp: 0,
            cur_phase: 0,
            shared: Vec::new(),
            regs: vec![0; dk.nregs as usize * WARP],
            written: vec![0; dk.nregs as usize],
            pc: [0; WARP],
            stmt_pos: [0; WARP],
            done: 0,
            tids: [(0, 0, 0); WARP],
            steps: 0,
            warps: Vec::new(),
            log: Vec::new(),
            stats: SimStats::default(),
            trace: Vec::new(),
        }
    }

    /// Exchange the worker's hot-loop warp state with slot `w`.
    fn swap_warp(&mut self, w: usize) {
        let mut s = std::mem::take(&mut self.warps[w]);
        std::mem::swap(&mut self.regs, &mut s.regs);
        std::mem::swap(&mut self.written, &mut s.written);
        std::mem::swap(&mut self.pc, &mut s.pc);
        std::mem::swap(&mut self.stmt_pos, &mut s.stmt_pos);
        std::mem::swap(&mut self.done, &mut s.done);
        std::mem::swap(&mut self.tids, &mut s.tids);
        std::mem::swap(&mut self.steps, &mut s.steps);
        self.warps[w] = s;
    }

    /// Run one block under the cooperative scheduler: warps advance in
    /// warp-index order, each until it retires or converges on a block
    /// barrier; with no barriers this is exactly serialized execution.
    fn run_block(&mut self, bidx: usize, tpb: u32) -> Result<BlockRun, SimError> {
        let ctaid = block_coord(bidx, self.cfg.grid);
        self.cur_block = bidx as u32;
        // block-local scratch: fresh zeroed window, no sharing with other
        // blocks (clear + resize zero-fills, keeping the allocation)
        self.shared.clear();
        self.shared.resize(self.dk.shared_size as usize, 0);
        self.stats = SimStats::default();
        self.log.clear();
        self.trace.clear();
        let record = self.cfg.record_trace && bidx == 0;
        // superblock fast path needs no per-uop trace hooks or race
        // probes; fall back to per-uop whenever either is requested
        let fast = self.cfg.superblocks && !record && !self.cfg.detect_races;
        let nwarps = tpb.div_ceil(32) as usize;
        let nregs = self.dk.nregs as usize;
        while self.warps.len() < nwarps {
            self.warps.push(WarpSlot::default());
        }
        for (w, slot) in self.warps.iter_mut().enumerate().take(nwarps) {
            slot.regs.clear();
            slot.regs.resize(nregs * WARP, 0);
            slot.written.clear();
            slot.written.resize(nregs, 0);
            slot.pc = [0; WARP];
            slot.stmt_pos = [0; WARP];
            slot.done = 0;
            slot.steps = 0;
            slot.status = WarpStatus::Running;
            for l in 0..WARP as u32 {
                let t = w as u32 * 32 + l;
                slot.tids[l as usize] = linear_to_tid(t, self.cfg.block);
                if t >= tpb {
                    slot.done |= 1 << l; // fractional warp: extra lanes inactive
                }
            }
        }
        if record {
            for _ in 0..nwarps {
                self.trace.push(Vec::new());
            }
        }
        self.cur_phase = 0;
        let shared_len = self.shared.len();
        if let Some(sh) = &mut self.phase_shadow {
            sh.begin_block(shared_len);
        }

        let mut result = Ok(());
        'sched: loop {
            for w in 0..nwarps {
                if self.warps[w].status != WarpStatus::Running {
                    continue;
                }
                self.cur_warp = w as u32;
                self.swap_warp(w);
                let halt = self.run_warp(ctaid, record, fast, tpb);
                self.swap_warp(w);
                match halt {
                    Ok(WarpHalt::Finished) => self.warps[w].status = WarpStatus::Finished,
                    Ok(WarpHalt::Barrier { id }) => {
                        self.warps[w].status = WarpStatus::AtBarrier(id)
                    }
                    Err(e) => {
                        result = Err(e);
                        break 'sched;
                    }
                }
            }
            // no warp is runnable: all finished, or a barrier release
            // (the validation helper is shared with the reference engine
            // so the violation taxonomy/ordering can never drift)
            let statuses = self.warps[..nwarps].iter().map(|s| s.status);
            match barrier_release(statuses, self.cur_block) {
                Ok(None) => break, // every warp retired
                Ok(Some(_)) => {}
                Err(e) => {
                    result = Err(e);
                    break 'sched;
                }
            }
            // release: step every live lane past its barrier micro-op
            for slot in self.warps[..nwarps].iter_mut() {
                let live = !slot.done;
                let mut m = live;
                while m != 0 {
                    let l = m.trailing_zeros() as usize;
                    m &= m - 1;
                    let stmt = self.dk.uops[slot.pc[l] as usize].stmt;
                    slot.pc[l] += 1;
                    slot.stmt_pos[l] = stmt + 1;
                }
                slot.status = WarpStatus::Running;
            }
            self.stats.barrier_phases += 1;
            self.cur_phase += 1;
        }
        // snapshot isolation (parallel path only): undo in reverse so the
        // private image is the launch image again
        if !self.direct {
            for rec in self.log.iter().rev() {
                self.mem
                    .store(rec.addr, rec.bytes, rec.old)
                    .expect("undo of an executed store is in bounds");
            }
        }
        result.map(|()| BlockRun {
            log: std::mem::take(&mut self.log),
            stats: self.stats,
            trace: std::mem::take(&mut self.trace),
        })
    }

    /// Read a decoded operand for `lane`, masked with `m` (immediates are
    /// pre-masked at decode time and pass through).
    #[inline]
    fn read(&mut self, lane: usize, d: Dop, m: u64, ctaid: (u32, u32, u32)) -> u64 {
        match d {
            Dop::Imm(v) => v,
            Dop::Slot(s) => {
                let s = s as usize;
                if self.written[s] & (1 << lane) == 0 {
                    self.stats.uninit_reads += 1;
                }
                self.regs[s * WARP + lane] & m
            }
            Dop::Special(sp) => {
                special_value(sp, self.tids[lane], self.cfg.block, self.cfg.grid, ctaid) & m
            }
        }
    }

    #[inline]
    fn write(&mut self, lane: usize, slot: u32, v: u64) {
        let s = slot as usize;
        self.regs[s * WARP + lane] = v;
        self.written[s] |= 1 << lane;
    }

    #[inline]
    fn addr_value(&mut self, lane: usize, a: &Daddr, ctaid: (u32, u32, u32)) -> u64 {
        self.read(lane, a.base, u64::MAX, ctaid).wrapping_add(a.offset)
    }

    fn load_mem(&mut self, space: Space, addr: u64, bytes: u32) -> Result<u64, SimError> {
        match shared_window_offset(self.shared.len() as u64, space, addr, bytes, "shared load")? {
            Some(o) => {
                if let Some(sh) = &self.phase_shadow {
                    if let Some(w) = sh.check_shared(o, bytes, self.cur_warp, self.cur_phase) {
                        return Err(SimError::IntraBlockRace {
                            addr,
                            bytes,
                            block: self.cur_block,
                            phase: self.cur_phase,
                            writer_warp: w,
                            reader_warp: self.cur_warp,
                            shared: true,
                        });
                    }
                }
                let mut v = 0u64;
                for k in 0..bytes as usize {
                    v |= (self.shared[o + k] as u64) << (8 * k);
                }
                Ok(v)
            }
            None => {
                let v = self.mem.load(addr, bytes)?;
                if self.cfg.detect_races {
                    // direct serial mode only: the shadow exists exactly
                    // when cross-block races are possible
                    if let Some(sh) = &self.shadow {
                        if let Some(w) = sh.foreign_writer(addr, bytes, self.cur_block) {
                            return Err(SimError::CrossBlockRace {
                                addr,
                                bytes,
                                writer_block: w,
                                reader_block: self.cur_block,
                            });
                        }
                    }
                    if let Some(sh) = &self.phase_shadow {
                        if let Some(w) = sh.check_global(
                            addr,
                            bytes,
                            self.cur_block,
                            self.cur_warp,
                            self.cur_phase,
                        ) {
                            return Err(SimError::IntraBlockRace {
                                addr,
                                bytes,
                                block: self.cur_block,
                                phase: self.cur_phase,
                                writer_warp: w,
                                reader_warp: self.cur_warp,
                                shared: false,
                            });
                        }
                    }
                }
                Ok(v)
            }
        }
    }

    fn store_mem(&mut self, space: Space, addr: u64, bytes: u32, v: u64) -> Result<(), SimError> {
        match shared_window_offset(self.shared.len() as u64, space, addr, bytes, "shared store")? {
            Some(o) => {
                if let Some(sh) = &mut self.phase_shadow {
                    sh.note_shared(o, bytes, self.cur_warp, self.cur_phase);
                }
                for k in 0..bytes as usize {
                    self.shared[o + k] = (v >> (8 * k)) as u8;
                }
                Ok(())
            }
            None => {
                if self.direct {
                    // direct serial path: store in place, count conflicts
                    // inline (same order as the reference engine)
                    self.mem.store(addr, bytes, v)?;
                    if let Some(sh) = &mut self.shadow {
                        if sh.note(addr, bytes, self.cur_block) {
                            self.stats.cross_block_write_conflicts += 1;
                        }
                    }
                    if let Some(sh) = &mut self.phase_shadow {
                        sh.note_global(addr, bytes, self.cur_block, self.cur_warp, self.cur_phase);
                    }
                } else {
                    let old = self.mem.exchange(addr, bytes, v)?;
                    self.log.push(StoreRec {
                        addr,
                        bytes,
                        val: v,
                        old,
                    });
                }
                Ok(())
            }
        }
    }

    fn run_warp(
        &mut self,
        ctaid: (u32, u32, u32),
        record: bool,
        fast: bool,
        tpb: u32,
    ) -> Result<WarpHalt, SimError> {
        let dk = self.dk;
        let nuops = dk.uops.len() as u32;
        loop {
            // lowest-pc-first reconvergence over live lanes; also track
            // the second-distinct-lowest pc, which bounds how far the
            // superblock fast path may run before another group could
            // merge in
            let live = !self.done;
            if live == 0 {
                return Ok(WarpHalt::Finished);
            }
            let mut pc = u32::MAX;
            let mut next_pc = u32::MAX;
            let mut m = live;
            while m != 0 {
                let l = m.trailing_zeros() as usize;
                m &= m - 1;
                let p = self.pc[l];
                if p < pc {
                    next_pc = pc;
                    pc = p;
                } else if p > pc {
                    next_pc = next_pc.min(p);
                }
            }
            if pc >= nuops {
                // min pc past the end ⇒ every live lane is retiring.
                // Charge the trailing-label statements the reference
                // engine still visits (body end minus the earliest lane
                // entry point into the trailing run).
                let mut min_sp = u32::MAX;
                let mut m = live;
                while m != 0 {
                    let l = m.trailing_zeros() as usize;
                    m &= m - 1;
                    min_sp = min_sp.min(self.stmt_pos[l]);
                    self.done |= 1 << l;
                }
                self.steps += dk.nstmts.saturating_sub(min_sp) as u64;
                if self.steps > self.cfg.max_warp_steps {
                    return Err(SimError::StepLimit(self.cfg.max_warp_steps));
                }
                continue;
            }
            let mut active = 0u32;
            let mut min_sp = u32::MAX;
            let mut m = live;
            while m != 0 {
                let l = m.trailing_zeros() as usize;
                m &= m - 1;
                if self.pc[l] == pc {
                    active |= 1 << l;
                    min_sp = min_sp.min(self.stmt_pos[l]);
                }
            }
            // Superblock fast path: run the whole decode-time-fused
            // straight-line run starting at `pc` in one scheduling slice.
            // The run is clamped to the earliest *other* live lane's pc
            // (no lane can merge strictly inside the clamped run, so the
            // per-uop path would keep this exact group active throughout)
            // and only taken when the bulk step charge — the telescoped
            // sum of the per-uop charges; every lane at `pc` has
            // `stmt_pos <= entry.stmt`, so no intermediate charge
            // saturates — fits the remaining budget, keeping the
            // StepLimit/memory-error interleaving per-uop-exact.
            if fast {
                let end = dk.sb_end[pc as usize].min(next_pc);
                if end >= pc + 2 {
                    let last_stmt = dk.uops[end as usize - 1].stmt;
                    let bulk = (last_stmt + 1).saturating_sub(min_sp) as u64;
                    if self.steps + bulk <= self.cfg.max_warp_steps {
                        self.steps += bulk;
                        self.stats.superblocks_entered += 1;
                        self.run_superblock(pc, end, active, ctaid)?;
                        let mut m = active;
                        while m != 0 {
                            let l = m.trailing_zeros() as usize;
                            m &= m - 1;
                            self.pc[l] = end;
                            self.stmt_pos[l] = last_stmt + 1;
                        }
                        continue;
                    }
                }
            }
            // The step budget counts *statements*, like the reference
            // engine: each issue is charged the instruction itself plus
            // the labels between the issuing group's entry point into the
            // current straight-line stretch (`stmt_pos`, maintained per
            // lane — fallthrough: previous statement + 1; branch: the
            // targeted label's statement) and the instruction. Merged
            // lane groups charge from the *earliest* entry, which is
            // exactly the label visits the reference engine pays — exact
            // for every program, label runs and trailing labels included.
            let entry = &dk.uops[pc as usize];
            self.steps += (entry.stmt + 1).saturating_sub(min_sp) as u64;
            if self.steps > self.cfg.max_warp_steps {
                return Err(SimError::StepLimit(self.cfg.max_warp_steps));
            }

            self.stats.warp_instructions += 1;
            let exec = self.guard_mask(entry.guard, active);
            self.stats.thread_instructions += exec.count_ones() as u64;
            if record {
                // address of the first executing lane for memory ops
                // (this extra base read counts toward uninit_reads, as in
                // the reference engine's traced path)
                let addr = match &entry.op {
                    Uop::Ld { addr, .. } | Uop::St { addr, .. } => {
                        let a = *addr;
                        match exec.trailing_zeros() {
                            32 => 0,
                            l => self.addr_value(l as usize, &a, ctaid),
                        }
                    }
                    _ => 0,
                };
                let ti = self.cur_warp as usize;
                self.trace[ti].push(WarpEvent {
                    stmt: entry.stmt,
                    active,
                    exec,
                    addr,
                });
            }
            if let Uop::BarSync { id, cnt } = entry.op {
                // uniformly-skipped barrier (guard false on every active
                // lane): a plain no-op, step past it
                if exec == 0 {
                    let stmt = entry.stmt;
                    let mut m = active;
                    while m != 0 {
                        let l = m.trailing_zeros() as usize;
                        m &= m - 1;
                        self.pc[l] += 1;
                        self.stmt_pos[l] = stmt + 1;
                    }
                    continue;
                }
                if cnt != 0 && cnt != tpb {
                    return Err(SimError::BarrierDivergence {
                        block: self.cur_block,
                        id,
                        cause: BarrierCause::PartialCount { cnt, tpb },
                    });
                }
                if exec.count_ones() != live.count_ones() {
                    return Err(SimError::BarrierDivergence {
                        block: self.cur_block,
                        id,
                        cause: BarrierCause::Divergence,
                    });
                }
                self.stats.barriers += 1;
                // suspend WITHOUT advancing pc: the scheduler steps every
                // live lane past the barrier at release
                return Ok(WarpHalt::Barrier { id });
            }
            self.exec_uop(pc as usize, active, exec, ctaid)?;
        }
    }

    fn exec_uop(
        &mut self,
        pc: usize,
        active: u32,
        exec: u32,
        ctaid: (u32, u32, u32),
    ) -> Result<(), SimError> {
        let dk = self.dk;
        let stmt = dk.uops[pc].stmt;
        let op = &dk.uops[pc].op;
        match op {
            Uop::Bra {
                target,
                target_stmt,
            } => {
                self.stats.branches += 1;
                let (t, ts, mut taken) = (*target, *target_stmt, 0u32);
                let mut m = active;
                while m != 0 {
                    let l = m.trailing_zeros() as usize;
                    m &= m - 1;
                    if exec & (1 << l) != 0 {
                        self.pc[l] = t;
                        self.stmt_pos[l] = ts;
                        taken += 1;
                    } else {
                        self.pc[l] += 1;
                        self.stmt_pos[l] = stmt + 1;
                    }
                }
                if taken != 0 && taken != active.count_ones() {
                    self.stats.divergent_branches += 1;
                }
                return Ok(());
            }
            Uop::Ret => {
                let mut m = active;
                while m != 0 {
                    let l = m.trailing_zeros() as usize;
                    m &= m - 1;
                    if exec & (1 << l) != 0 {
                        self.done |= 1 << l;
                    } else {
                        self.pc[l] += 1;
                        self.stmt_pos[l] = stmt + 1;
                    }
                }
                return Ok(());
            }
            _ => self.exec_op(pc, active, exec, ctaid)?,
        }
        let mut m = active;
        while m != 0 {
            let l = m.trailing_zeros() as usize;
            m &= m - 1;
            self.pc[l] += 1;
            self.stmt_pos[l] = stmt + 1;
        }
        Ok(())
    }

    /// Per-lane guard evaluation (plain register read, no
    /// uninitialized-read accounting — as in the reference engine).
    #[inline]
    fn guard_mask(&self, guard: Option<(u32, bool)>, active: u32) -> u32 {
        match guard {
            None => active,
            Some((g, negated)) => {
                let g = g as usize;
                let mut e = 0u32;
                let mut m = active;
                while m != 0 {
                    let l = m.trailing_zeros() as usize;
                    m &= m - 1;
                    if (self.regs[g * WARP + l] & 1 == 1) != negated {
                        e |= 1 << l;
                    }
                }
                e
            }
        }
    }

    /// Execute the fused straight-line run `start..end` for the `active`
    /// group (every lane of which sits at `start`). The caller has
    /// already charged the step budget in bulk and updates
    /// `pc`/`stmt_pos` once at exit; the interior is exactly the per-uop
    /// semantic sequence — guard, instruction counters, dispatch — minus
    /// the scheduler's per-uop bookkeeping. Memory errors abort the whole
    /// simulation here just as they do per-uop, and in the same order,
    /// because the budget pre-check guaranteed no interior StepLimit.
    fn run_superblock(
        &mut self,
        start: u32,
        end: u32,
        active: u32,
        ctaid: (u32, u32, u32),
    ) -> Result<(), SimError> {
        let dk = self.dk;
        // one slice bounds check for the whole run
        let run = &dk.uops[start as usize..end as usize];
        for (k, entry) in run.iter().enumerate() {
            self.stats.warp_instructions += 1;
            let exec = self.guard_mask(entry.guard, active);
            self.stats.thread_instructions += exec.count_ones() as u64;
            self.exec_op(start as usize + k, active, exec, ctaid)?;
        }
        Ok(())
    }

    /// Execute one non-control micro-op for the `exec` lanes — shared by
    /// the per-uop path (via [`Self::exec_uop`]) and the superblock
    /// interior. Does not touch `pc`/`stmt_pos`.
    fn exec_op(
        &mut self,
        pc: usize,
        active: u32,
        exec: u32,
        ctaid: (u32, u32, u32),
    ) -> Result<(), SimError> {
        let dk = self.dk;
        match &dk.uops[pc].op {
            Uop::Shfl { mode, dst, pred_out, src, b, c, mask } => {
                self.stats.shfls += 1;
                let (mode, dst, pred_out) = (*mode, *dst, *pred_out);
                let (src, b, c, mask) = (*src, *b, *c, *mask);
                // gather source values first (exchange is simultaneous)
                let mut srcv = [0u64; WARP];
                let mut m = exec;
                while m != 0 {
                    let i = m.trailing_zeros() as usize;
                    m &= m - 1;
                    srcv[i] = self.read(i, src, 0xFFFF_FFFF, ctaid);
                }
                let mut m = exec;
                while m != 0 {
                    let i = m.trailing_zeros() as usize;
                    m &= m - 1;
                    let bv = self.read(i, b, 0xFFFF_FFFF, ctaid) as u32;
                    let cv = self.read(i, c, 0xFFFF_FFFF, ctaid) as u32;
                    let mv = self.read(i, mask, 0xFFFF_FFFF, ctaid) as u32;
                    let lane = i as u32;
                    // PTX ISA `c`-operand encoding — shared helper, so the
                    // clamp/segment semantics can never drift per engine
                    let (src_lane, pval) = shfl_source_lane(mode, lane, bv, cv);
                    let valid = pval
                        && (mv >> src_lane) & 1 == 1
                        && (exec >> src_lane) & 1 == 1;
                    let val = if valid { srcv[src_lane as usize] } else { srcv[i] };
                    self.write(i, dst, val & 0xFFFF_FFFF);
                    if let Some(p) = pred_out {
                        self.write(i, p, valid as u64);
                    }
                }
            }
            Uop::Activemask { dst } => {
                let dst = *dst;
                let mut m = exec;
                while m != 0 {
                    let i = m.trailing_zeros() as usize;
                    m &= m - 1;
                    self.write(i, dst, active as u64);
                }
            }
            Uop::Bra { .. } | Uop::Ret | Uop::BarSync { .. } => {
                unreachable!("control ops are handled by the warp scheduler")
            }
            _ => {
                #[cfg(feature = "simd")]
                if self.cfg.vector && self.exec_wide(pc, exec, ctaid) {
                    return Ok(());
                }
                let mut m = exec;
                while m != 0 {
                    let i = m.trailing_zeros() as usize;
                    m &= m - 1;
                    self.exec_lane(pc, i, ctaid)?;
                }
            }
        }
        Ok(())
    }

    fn exec_lane(
        &mut self,
        pc: usize,
        lane: usize,
        ctaid: (u32, u32, u32),
    ) -> Result<(), SimError> {
        let dk = self.dk;
        match &dk.uops[pc].op {
            Uop::LdParam { dst, index, mask } => {
                let v = self.cfg.params[*index as usize] & mask;
                self.write(lane, *dst, v);
            }
            Uop::Ld { space, nc, bytes, dst, addr } => {
                let (space, nc, bytes, dst, addr) = (*space, *nc, *bytes, *dst, *addr);
                let a = self.addr_value(lane, &addr, ctaid);
                match space {
                    Space::Global | Space::Const | Space::Local => {
                        self.stats.global_loads += 1;
                        if nc {
                            self.stats.nc_loads += 1;
                        }
                    }
                    Space::Shared => self.stats.shared_loads += 1,
                    Space::Param => unreachable!("lowered to LdParam"),
                }
                let v = self.load_mem(space, a, bytes)?;
                self.write(lane, dst, v);
            }
            Uop::St { space, bytes, smask, src, addr } => {
                let (space, bytes, smask, src, addr) = (*space, *bytes, *smask, *src, *addr);
                let a = self.addr_value(lane, &addr, ctaid);
                let v = self.read(lane, src, smask, ctaid);
                self.stats.stores += 1;
                self.store_mem(space, a, bytes, v)?;
            }
            Uop::Mov { dst, src, mask } => {
                let v = self.read(lane, *src, *mask, ctaid);
                self.write(lane, *dst, v);
            }
            Uop::Cvta { dst, src } => {
                let v = self.read(lane, *src, u64::MAX, ctaid);
                self.write(lane, *dst, v);
            }
            Uop::IntBin { op, w, mask, dst, a, b } => {
                let av = self.read(lane, *a, *mask, ctaid);
                let bv = self.read(lane, *b, *mask, ctaid);
                self.write(lane, *dst, eval_bin(*op, av, bv, *w));
            }
            Uop::MulWide { signed, w, dst, a, b } => {
                let (w, m) = (*w, width_mask(*w));
                let av = self.read(lane, *a, m, ctaid);
                let bv = self.read(lane, *b, m, ctaid);
                let v = mul_full(*signed, w, av, bv) & width_mask(w * 2);
                self.write(lane, *dst, v);
            }
            Uop::MulHi { signed, w, dst, a, b } => {
                let (w, m) = (*w, width_mask(*w));
                let av = self.read(lane, *a, m, ctaid);
                let bv = self.read(lane, *b, m, ctaid);
                self.write(lane, *dst, mul_hi(*signed, w, av, bv));
            }
            Uop::Mad { wide, signed, w, dst, a, b, c } => {
                let (w, m) = (*w, width_mask(*w));
                let av = self.read(lane, *a, m, ctaid);
                let bv = self.read(lane, *b, m, ctaid);
                let v = if *wide {
                    let cv = self.read(lane, *c, width_mask(w * 2), ctaid);
                    mul_full(*signed, w, av, bv).wrapping_add(cv) & width_mask(w * 2)
                } else {
                    let cv = self.read(lane, *c, m, ctaid);
                    av.wrapping_mul(bv).wrapping_add(cv) & m
                };
                self.write(lane, *dst, v);
            }
            Uop::Not { w, dst, a } => {
                let m = width_mask(*w);
                let av = self.read(lane, *a, m, ctaid);
                self.write(lane, *dst, !av & m);
            }
            Uop::Neg { w, dst, a } => {
                let m = width_mask(*w);
                let av = self.read(lane, *a, m, ctaid);
                self.write(lane, *dst, av.wrapping_neg() & m);
            }
            Uop::FltBin { op, wide, dst, a, b } => {
                let m = if *wide { u64::MAX } else { 0xFFFF_FFFF };
                let av = self.read(lane, *a, m, ctaid);
                let bv = self.read(lane, *b, m, ctaid);
                let v = if !*wide {
                    let (x, y) = (f32::from_bits(av as u32), f32::from_bits(bv as u32));
                    f32_bin(*op, x, y).to_bits() as u64
                } else {
                    let (x, y) = (f64::from_bits(av), f64::from_bits(bv));
                    f64_bin(*op, x, y).to_bits()
                };
                self.write(lane, *dst, v);
            }
            Uop::Fma { wide, dst, a, b, c } => {
                let m = if *wide { u64::MAX } else { 0xFFFF_FFFF };
                let av = self.read(lane, *a, m, ctaid);
                let bv = self.read(lane, *b, m, ctaid);
                let cv = self.read(lane, *c, m, ctaid);
                let v = if !*wide {
                    f32::from_bits(av as u32)
                        .mul_add(f32::from_bits(bv as u32), f32::from_bits(cv as u32))
                        .to_bits() as u64
                } else {
                    f64::from_bits(av)
                        .mul_add(f64::from_bits(bv), f64::from_bits(cv))
                        .to_bits()
                };
                self.write(lane, *dst, v);
            }
            Uop::FltUn { op, wide, dst, a } => {
                let m = if *wide { u64::MAX } else { 0xFFFF_FFFF };
                let av = self.read(lane, *a, m, ctaid);
                let v = if !*wide {
                    f32_un(*op, f32::from_bits(av as u32)).to_bits() as u64
                } else {
                    f64_un(*op, f64::from_bits(av)).to_bits()
                };
                self.write(lane, *dst, v);
            }
            Uop::SetpF { cmp, wide, dst, a, b } => {
                let m = if *wide { u64::MAX } else { 0xFFFF_FFFF };
                let av = self.read(lane, *a, m, ctaid);
                let bv = self.read(lane, *b, m, ctaid);
                let r = flt_cmp(*cmp, *wide, av, bv);
                self.write(lane, *dst, r as u64);
            }
            Uop::SetpI { kind, w, dst, a, b } => {
                let m = width_mask(*w);
                let av = self.read(lane, *a, m, ctaid);
                let bv = self.read(lane, *b, m, ctaid);
                let r = eval_cmp(*kind, av, bv, *w);
                self.write(lane, *dst, r as u64);
            }
            Uop::Selp { w, dst, a, b, p } => {
                let m = width_mask(*w);
                let av = self.read(lane, *a, m, ctaid);
                let bv = self.read(lane, *b, m, ctaid);
                let pv = self.read(lane, *p, 1, ctaid);
                self.write(lane, *dst, if pv & 1 == 1 { av } else { bv });
            }
            Uop::Cvt { dty, sty, dst, src } => {
                let sv = self.read(lane, *src, width_mask(sty.bits()), ctaid);
                self.write(lane, *dst, convert(sv, *sty, *dty));
            }
            Uop::Bra { .. }
            | Uop::Ret
            | Uop::Shfl { .. }
            | Uop::Activemask { .. }
            | Uop::BarSync { .. } => {
                unreachable!("handled at warp level")
            }
        }
        Ok(())
    }
}

/// Lane-vectorized dispatch (`simd` feature): gather 32 lanes into a
/// fixed buffer, run a full-width elementwise kernel from
/// [`super::lanes`], blend results back under the exec mask. Bit-exact
/// with the per-lane scalar path by construction: operands use the same
/// masks, only exec lanes are written, uninitialized-read accounting is
/// the same popcount the scalar path sums one lane at a time, and the
/// kernels are property-tested against the shared scalar helpers.
/// Non-exec garbage lanes are safe to compute on — every kernel is total
/// (shifts clamped, division-by-zero defined, floats non-trapping).
#[cfg(feature = "simd")]
impl Worker<'_> {
    /// Read a decoded operand for all 32 lanes, masked with `m`;
    /// uninitialized reads are counted for `exec` lanes only (the
    /// counter is a sum, so the total matches the scalar path's
    /// lane-at-a-time accounting exactly).
    fn gather(&mut self, d: Dop, m: u64, exec: u32, ctaid: (u32, u32, u32)) -> [u64; WARP] {
        let mut out = [0u64; WARP];
        match d {
            Dop::Imm(v) => out = [v; WARP],
            Dop::Slot(s) => {
                let s = s as usize;
                self.stats.uninit_reads += (exec & !self.written[s]).count_ones() as u64;
                let regs = &self.regs[s * WARP..(s + 1) * WARP];
                for (o, &r) in out.iter_mut().zip(regs) {
                    *o = r & m;
                }
            }
            Dop::Special(sp) => {
                for (o, &t) in out.iter_mut().zip(&self.tids) {
                    *o = special_value(sp, t, self.cfg.block, self.cfg.grid, ctaid) & m;
                }
            }
        }
        out
    }

    /// Blend `vals` into slot `dst` under the exec mask and mark the
    /// exec lanes written — the same bits the scalar path sets one
    /// `write` at a time.
    fn scatter(&mut self, dst: u32, exec: u32, vals: &[u64; WARP]) {
        let s = dst as usize;
        let regs = &mut self.regs[s * WARP..(s + 1) * WARP];
        for (l, (r, &v)) in regs.iter_mut().zip(vals).enumerate() {
            if exec & (1 << l) != 0 {
                *r = v;
            }
        }
        self.written[s] |= exec;
    }

    /// Full-warp dispatch for the hot register-to-register micro-ops.
    /// Returns `false` when the op is not wide-eligible (memory,
    /// transcendental and convert ops keep the per-lane scalar path).
    fn exec_wide(&mut self, pc: usize, exec: u32, ctaid: (u32, u32, u32)) -> bool {
        use super::lanes;
        let dk = self.dk;
        match &dk.uops[pc].op {
            Uop::Mov { dst, src, mask } => {
                let v = self.gather(*src, *mask, exec, ctaid);
                self.scatter(*dst, exec, &v);
            }
            Uop::Cvta { dst, src } => {
                let v = self.gather(*src, u64::MAX, exec, ctaid);
                self.scatter(*dst, exec, &v);
            }
            Uop::IntBin { op, w, mask, dst, a, b } => {
                let av = self.gather(*a, *mask, exec, ctaid);
                let bv = self.gather(*b, *mask, exec, ctaid);
                self.scatter(*dst, exec, &lanes::int_bin(*op, *w, &av, &bv));
            }
            Uop::MulWide { signed, w, dst, a, b } => {
                let m = width_mask(*w);
                let av = self.gather(*a, m, exec, ctaid);
                let bv = self.gather(*b, m, exec, ctaid);
                self.scatter(*dst, exec, &lanes::mul_wide(*signed, *w, &av, &bv));
            }
            Uop::MulHi { signed, w, dst, a, b } => {
                let m = width_mask(*w);
                let av = self.gather(*a, m, exec, ctaid);
                let bv = self.gather(*b, m, exec, ctaid);
                self.scatter(*dst, exec, &lanes::mul_hi_v(*signed, *w, &av, &bv));
            }
            Uop::Mad { wide, signed, w, dst, a, b, c } => {
                let m = width_mask(*w);
                let av = self.gather(*a, m, exec, ctaid);
                let bv = self.gather(*b, m, exec, ctaid);
                let cm = if *wide { width_mask(*w * 2) } else { m };
                let cv = self.gather(*c, cm, exec, ctaid);
                self.scatter(*dst, exec, &lanes::mad(*wide, *signed, *w, &av, &bv, &cv));
            }
            Uop::Not { w, dst, a } => {
                let av = self.gather(*a, width_mask(*w), exec, ctaid);
                self.scatter(*dst, exec, &lanes::not_v(*w, &av));
            }
            Uop::Neg { w, dst, a } => {
                let av = self.gather(*a, width_mask(*w), exec, ctaid);
                self.scatter(*dst, exec, &lanes::neg_v(*w, &av));
            }
            Uop::FltBin { op, wide, dst, a, b } => {
                let m = if *wide { u64::MAX } else { 0xFFFF_FFFF };
                let av = self.gather(*a, m, exec, ctaid);
                let bv = self.gather(*b, m, exec, ctaid);
                self.scatter(*dst, exec, &lanes::flt_bin(*op, *wide, &av, &bv));
            }
            Uop::Fma { wide, dst, a, b, c } => {
                let m = if *wide { u64::MAX } else { 0xFFFF_FFFF };
                let av = self.gather(*a, m, exec, ctaid);
                let bv = self.gather(*b, m, exec, ctaid);
                let cv = self.gather(*c, m, exec, ctaid);
                self.scatter(*dst, exec, &lanes::fma(*wide, &av, &bv, &cv));
            }
            Uop::SetpF { cmp, wide, dst, a, b } => {
                let m = if *wide { u64::MAX } else { 0xFFFF_FFFF };
                let av = self.gather(*a, m, exec, ctaid);
                let bv = self.gather(*b, m, exec, ctaid);
                self.scatter(*dst, exec, &lanes::setp_f(*cmp, *wide, &av, &bv));
            }
            Uop::SetpI { kind, w, dst, a, b } => {
                let m = width_mask(*w);
                let av = self.gather(*a, m, exec, ctaid);
                let bv = self.gather(*b, m, exec, ctaid);
                self.scatter(*dst, exec, &lanes::setp_i(*kind, *w, &av, &bv));
            }
            Uop::Selp { w, dst, a, b, p } => {
                let m = width_mask(*w);
                let av = self.gather(*a, m, exec, ctaid);
                let bv = self.gather(*b, m, exec, ctaid);
                let pv = self.gather(*p, 1, exec, ctaid);
                self.scatter(*dst, exec, &lanes::selp(&av, &bv, &pv));
            }
            _ => return false,
        }
        self.stats.vector_warp_steps += 1;
        true
    }
}
