//! Full-warp elementwise kernels for the lane-vectorized executor
//! (`simd` feature).
//!
//! Each kernel computes all 32 lanes of one micro-op over fixed
//! `[u64; 32]` buffers in a single tight loop — the operator `match`
//! happens once per warp instead of once per lane, the loop bodies are
//! branch-free (shift counts clamped, selects instead of conditions),
//! and the fixed trip count over contiguous buffers is the shape the
//! compiler's auto-vectorizer maps onto SIMD units. Non-exec lanes hold
//! garbage and are computed anyway (the caller blends under the exec
//! mask), so every kernel must be total: division by zero is defined,
//! shifts never exceed the type width, floats don't trap.
//!
//! Semantics are pinned to the scalar oracle — [`eval_bin`]/[`eval_cmp`]
//! and the [`super::machine`] float/multiply helpers. Kernels either
//! call those helpers per element (where the helper is already
//! branch-light) or replicate them in clamped form; the property tests
//! at the bottom hold every kernel to the oracle on random inputs across
//! all widths.

use super::machine::{f32_bin, f64_bin, flt_cmp, mul_full, mul_hi, width_mask};
use crate::ptx::ast::{CmpOp, FltBinOp};
use crate::sym::term::{eval_bin, BvOp, CmpKind};

const WARP: usize = 32;

/// Sign-extend the low `w` bits of `v` (matches `term::to_signed`
/// numerically for every `w ∈ 1..=64`; i64 arithmetic suffices because
/// no kernel widens past 64 bits here).
#[inline]
fn sx(v: u64, w: u32) -> i64 {
    if w >= 64 {
        v as i64
    } else {
        ((v << (64 - w)) as i64) >> (64 - w)
    }
}

/// `w`-bit modular binary op over all lanes ([`eval_bin`] semantics).
pub(crate) fn int_bin(op: BvOp, w: u32, a: &[u64; WARP], b: &[u64; WARP]) -> [u64; WARP] {
    let m = width_mask(w);
    let wu = w as u64;
    let mut r = [0u64; WARP];
    match op {
        BvOp::Add => {
            for ((r, &a), &b) in r.iter_mut().zip(a).zip(b) {
                *r = a.wrapping_add(b) & m;
            }
        }
        BvOp::Sub => {
            for ((r, &a), &b) in r.iter_mut().zip(a).zip(b) {
                *r = a.wrapping_sub(b) & m;
            }
        }
        BvOp::Mul => {
            for ((r, &a), &b) in r.iter_mut().zip(a).zip(b) {
                *r = a.wrapping_mul(b) & m;
            }
        }
        BvOp::And => {
            for ((r, &a), &b) in r.iter_mut().zip(a).zip(b) {
                *r = a & b & m;
            }
        }
        BvOp::Or => {
            for ((r, &a), &b) in r.iter_mut().zip(a).zip(b) {
                *r = (a | b) & m;
            }
        }
        BvOp::Xor => {
            for ((r, &a), &b) in r.iter_mut().zip(a).zip(b) {
                *r = (a ^ b) & m;
            }
        }
        BvOp::Shl => {
            // clamp keeps the shift in-range for garbage lanes; the
            // select reproduces eval_bin's `b >= w ⇒ 0`
            for ((r, &a), &b) in r.iter_mut().zip(a).zip(b) {
                let b = b & m;
                let v = ((a & m) << b.min(63)) & m;
                *r = if b >= wu { 0 } else { v };
            }
        }
        BvOp::LShr => {
            for ((r, &a), &b) in r.iter_mut().zip(a).zip(b) {
                let b = b & m;
                let v = ((a & m) >> b.min(63)) & m;
                *r = if b >= wu { 0 } else { v };
            }
        }
        BvOp::AShr => {
            for ((r, &a), &b) in r.iter_mut().zip(a).zip(b) {
                let sh = (b & m).min(wu - 1);
                *r = (sx(a & m, w) >> sh) as u64 & m;
            }
        }
        BvOp::UMin => {
            for ((r, &a), &b) in r.iter_mut().zip(a).zip(b) {
                *r = (a & m).min(b & m);
            }
        }
        BvOp::UMax => {
            for ((r, &a), &b) in r.iter_mut().zip(a).zip(b) {
                *r = (a & m).max(b & m);
            }
        }
        BvOp::SMin => {
            for ((r, &a), &b) in r.iter_mut().zip(a).zip(b) {
                let (a, b) = (a & m, b & m);
                *r = if sx(a, w) <= sx(b, w) { a } else { b };
            }
        }
        BvOp::SMax => {
            for ((r, &a), &b) in r.iter_mut().zip(a).zip(b) {
                let (a, b) = (a & m, b & m);
                *r = if sx(a, w) >= sx(b, w) { a } else { b };
            }
        }
        // division has a hardware-divide per element either way; the
        // shared scalar helper keeps the zero-divisor cases pinned
        BvOp::UDiv | BvOp::SDiv | BvOp::URem | BvOp::SRem => {
            for ((r, &a), &b) in r.iter_mut().zip(a).zip(b) {
                *r = eval_bin(op, a, b, w);
            }
        }
    }
    r
}

/// `w`-bit integer comparison over all lanes (0/1 results,
/// [`eval_cmp`] semantics).
pub(crate) fn setp_i(kind: CmpKind, w: u32, a: &[u64; WARP], b: &[u64; WARP]) -> [u64; WARP] {
    let m = width_mask(w);
    let mut r = [0u64; WARP];
    macro_rules! cmp {
        (|$x:ident, $y:ident| $e:expr) => {
            for ((r, &a), &b) in r.iter_mut().zip(a).zip(b) {
                let ($x, $y) = (a & m, b & m);
                *r = ($e) as u64;
            }
        };
    }
    match kind {
        CmpKind::Eq => cmp!(|x, y| x == y),
        CmpKind::Ne => cmp!(|x, y| x != y),
        CmpKind::Ult => cmp!(|x, y| x < y),
        CmpKind::Ule => cmp!(|x, y| x <= y),
        CmpKind::Ugt => cmp!(|x, y| x > y),
        CmpKind::Uge => cmp!(|x, y| x >= y),
        CmpKind::Slt => cmp!(|x, y| sx(x, w) < sx(y, w)),
        CmpKind::Sle => cmp!(|x, y| sx(x, w) <= sx(y, w)),
        CmpKind::Sgt => cmp!(|x, y| sx(x, w) > sx(y, w)),
        CmpKind::Sge => cmp!(|x, y| sx(x, w) >= sx(y, w)),
    }
    r
}

/// Float comparison over all lanes (0/1 results, [`flt_cmp`] semantics —
/// f32 operands widen to f64 before comparing).
pub(crate) fn setp_f(cmp: CmpOp, wide: bool, a: &[u64; WARP], b: &[u64; WARP]) -> [u64; WARP] {
    let mut r = [0u64; WARP];
    for ((r, &a), &b) in r.iter_mut().zip(a).zip(b) {
        *r = flt_cmp(cmp, wide, a, b) as u64;
    }
    r
}

/// Predicated select over all lanes (`p` gathered with mask 1).
pub(crate) fn selp(a: &[u64; WARP], b: &[u64; WARP], p: &[u64; WARP]) -> [u64; WARP] {
    let mut r = [0u64; WARP];
    for (((r, &a), &b), &p) in r.iter_mut().zip(a).zip(b).zip(p) {
        *r = if p & 1 == 1 { a } else { b };
    }
    r
}

/// Float binary op over all lanes (bit-level [`f32_bin`]/[`f64_bin`]
/// semantics, including the `min`/`max` NaN behaviour).
pub(crate) fn flt_bin(op: FltBinOp, wide: bool, a: &[u64; WARP], b: &[u64; WARP]) -> [u64; WARP] {
    let mut r = [0u64; WARP];
    if wide {
        for ((r, &a), &b) in r.iter_mut().zip(a).zip(b) {
            *r = f64_bin(op, f64::from_bits(a), f64::from_bits(b)).to_bits();
        }
    } else {
        for ((r, &a), &b) in r.iter_mut().zip(a).zip(b) {
            *r = f32_bin(op, f32::from_bits(a as u32), f32::from_bits(b as u32)).to_bits() as u64;
        }
    }
    r
}

/// Fused multiply-add over all lanes (`mul_add`, one rounding).
pub(crate) fn fma(wide: bool, a: &[u64; WARP], b: &[u64; WARP], c: &[u64; WARP]) -> [u64; WARP] {
    let mut r = [0u64; WARP];
    if wide {
        for (((r, &a), &b), &c) in r.iter_mut().zip(a).zip(b).zip(c) {
            *r = f64::from_bits(a)
                .mul_add(f64::from_bits(b), f64::from_bits(c))
                .to_bits();
        }
    } else {
        for (((r, &a), &b), &c) in r.iter_mut().zip(a).zip(b).zip(c) {
            *r = f32::from_bits(a as u32)
                .mul_add(f32::from_bits(b as u32), f32::from_bits(c as u32))
                .to_bits() as u64;
        }
    }
    r
}

/// `w`-bit × `w`-bit → `2w`-bit multiply over all lanes.
pub(crate) fn mul_wide(signed: bool, w: u32, a: &[u64; WARP], b: &[u64; WARP]) -> [u64; WARP] {
    let m2 = width_mask(w * 2);
    let mut r = [0u64; WARP];
    for ((r, &a), &b) in r.iter_mut().zip(a).zip(b) {
        *r = mul_full(signed, w, a, b) & m2;
    }
    r
}

/// High `w` bits of the `2w`-bit product over all lanes.
pub(crate) fn mul_hi_v(signed: bool, w: u32, a: &[u64; WARP], b: &[u64; WARP]) -> [u64; WARP] {
    let mut r = [0u64; WARP];
    for ((r, &a), &b) in r.iter_mut().zip(a).zip(b) {
        *r = mul_hi(signed, w, a, b);
    }
    r
}

/// Multiply-add over all lanes (`wide`: `2w`-bit accumulate).
pub(crate) fn mad(
    wide: bool,
    signed: bool,
    w: u32,
    a: &[u64; WARP],
    b: &[u64; WARP],
    c: &[u64; WARP],
) -> [u64; WARP] {
    let mut r = [0u64; WARP];
    if wide {
        let m2 = width_mask(w * 2);
        for (((r, &a), &b), &c) in r.iter_mut().zip(a).zip(b).zip(c) {
            *r = mul_full(signed, w, a, b).wrapping_add(c) & m2;
        }
    } else {
        let m = width_mask(w);
        for (((r, &a), &b), &c) in r.iter_mut().zip(a).zip(b).zip(c) {
            *r = a.wrapping_mul(b).wrapping_add(c) & m;
        }
    }
    r
}

/// `w`-bit bitwise complement over all lanes.
pub(crate) fn not_v(w: u32, a: &[u64; WARP]) -> [u64; WARP] {
    let m = width_mask(w);
    let mut r = [0u64; WARP];
    for (r, &a) in r.iter_mut().zip(a) {
        *r = !a & m;
    }
    r
}

/// `w`-bit two's-complement negation over all lanes.
pub(crate) fn neg_v(w: u32, a: &[u64; WARP]) -> [u64; WARP] {
    let m = width_mask(w);
    let mut r = [0u64; WARP];
    for (r, &a) in r.iter_mut().zip(a) {
        *r = a.wrapping_neg() & m;
    }
    r
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sym::term::eval_cmp;
    use crate::util::Rng;

    const WIDTHS: [u32; 6] = [1, 8, 16, 24, 32, 64];

    /// Adversarial lane values: all-ones, zero, sign-boundary patterns
    /// and raw randoms — unmasked on purpose (the kernels must mask).
    fn lanes(rng: &mut Rng) -> [u64; WARP] {
        let mut v = [0u64; WARP];
        for (i, x) in v.iter_mut().enumerate() {
            *x = match i % 4 {
                0 => rng.next_u64(),
                1 => u64::MAX,
                2 => rng.next_u64() & 0x8000_0000_0000_00FF,
                _ => rng.next_u64() % 67, // small values near shift widths
            };
        }
        v
    }

    #[test]
    fn int_bin_matches_eval_bin_for_every_op_and_width() {
        use BvOp::*;
        let ops = [
            Add, Sub, Mul, UDiv, SDiv, URem, SRem, And, Or, Xor, Shl, LShr, AShr, UMin, UMax,
            SMin, SMax,
        ];
        let mut rng = Rng::new(0x1a4e5);
        for &w in &WIDTHS {
            for &op in &ops {
                for round in 0..16 {
                    let (a, b) = (lanes(&mut rng), lanes(&mut rng));
                    let r = int_bin(op, w, &a, &b);
                    for l in 0..WARP {
                        assert_eq!(
                            r[l],
                            eval_bin(op, a[l], b[l], w),
                            "{op:?} w={w} round={round} lane={l} a={:#x} b={:#x}",
                            a[l],
                            b[l]
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn setp_i_matches_eval_cmp_for_every_kind_and_width() {
        use CmpKind::*;
        let kinds = [Eq, Ne, Ult, Ule, Ugt, Uge, Slt, Sle, Sgt, Sge];
        let mut rng = Rng::new(0x5e7b);
        for &w in &WIDTHS {
            for &k in &kinds {
                for _ in 0..16 {
                    let (mut a, b) = (lanes(&mut rng), lanes(&mut rng));
                    // force some equal pairs so Eq/Ule/Sge get both arms
                    a[7] = b[7];
                    a[21] = b[21];
                    let r = setp_i(k, w, &a, &b);
                    for l in 0..WARP {
                        assert_eq!(
                            r[l],
                            eval_cmp(k, a[l], b[l], w) as u64,
                            "{k:?} w={w} lane={l} a={:#x} b={:#x}",
                            a[l],
                            b[l]
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn float_kernels_match_scalar_helpers_bitwise() {
        let mut rng = Rng::new(0xf10a7);
        // include NaN/∞/-0.0 payloads: raw random bit patterns cover
        // them, plus pinned specials in fixed lanes
        for wide in [false, true] {
            for _ in 0..16 {
                let (mut a, mut b, c) = (lanes(&mut rng), lanes(&mut rng), lanes(&mut rng));
                if wide {
                    a[3] = f64::NAN.to_bits();
                    b[5] = f64::NEG_INFINITY.to_bits();
                    a[9] = (-0.0f64).to_bits();
                } else {
                    a[3] = f32::NAN.to_bits() as u64;
                    b[5] = f32::NEG_INFINITY.to_bits() as u64;
                    a[9] = (-0.0f32).to_bits() as u64;
                }
                for op in [
                    FltBinOp::Add,
                    FltBinOp::Sub,
                    FltBinOp::Mul,
                    FltBinOp::Div,
                    FltBinOp::Min,
                    FltBinOp::Max,
                ] {
                    let r = flt_bin(op, wide, &a, &b);
                    for l in 0..WARP {
                        let want = if wide {
                            f64_bin(op, f64::from_bits(a[l]), f64::from_bits(b[l])).to_bits()
                        } else {
                            f32_bin(op, f32::from_bits(a[l] as u32), f32::from_bits(b[l] as u32))
                                .to_bits() as u64
                        };
                        assert_eq!(r[l], want, "{op:?} wide={wide} lane={l}");
                    }
                }
                for cmp in [CmpOp::Eq, CmpOp::Ne, CmpOp::Lt, CmpOp::Le, CmpOp::Gt, CmpOp::Ge] {
                    let r = setp_f(cmp, wide, &a, &b);
                    for l in 0..WARP {
                        assert_eq!(r[l], flt_cmp(cmp, wide, a[l], b[l]) as u64);
                    }
                }
                let r = fma(wide, &a, &b, &c);
                for l in 0..WARP {
                    let want = if wide {
                        f64::from_bits(a[l])
                            .mul_add(f64::from_bits(b[l]), f64::from_bits(c[l]))
                            .to_bits()
                    } else {
                        f32::from_bits(a[l] as u32)
                            .mul_add(f32::from_bits(b[l] as u32), f32::from_bits(c[l] as u32))
                            .to_bits() as u64
                    };
                    assert_eq!(r[l], want, "fma wide={wide} lane={l}");
                }
            }
        }
    }

    #[test]
    fn multiply_select_and_unary_kernels_match_scalar_forms() {
        let mut rng = Rng::new(0x9dc3);
        for &w in &[8u32, 16, 24, 32] {
            let m = width_mask(w);
            for _ in 0..16 {
                let (ra, rb, rc) = (lanes(&mut rng), lanes(&mut rng), lanes(&mut rng));
                // gather pre-masks operands; mirror that here
                let mut a = [0u64; WARP];
                let mut b = [0u64; WARP];
                for l in 0..WARP {
                    a[l] = ra[l] & m;
                    b[l] = rb[l] & m;
                }
                for signed in [false, true] {
                    let rw = mul_wide(signed, w, &a, &b);
                    let rh = mul_hi_v(signed, w, &a, &b);
                    let rm = mad(true, signed, w, &a, &b, &rc);
                    let rn = mad(false, signed, w, &a, &b, &rc);
                    for l in 0..WARP {
                        assert_eq!(rw[l], mul_full(signed, w, a[l], b[l]) & width_mask(w * 2));
                        assert_eq!(rh[l], mul_hi(signed, w, a[l], b[l]));
                        assert_eq!(
                            rm[l],
                            mul_full(signed, w, a[l], b[l]).wrapping_add(rc[l]) & width_mask(w * 2)
                        );
                        assert_eq!(rn[l], a[l].wrapping_mul(b[l]).wrapping_add(rc[l]) & m);
                    }
                }
                let p = lanes(&mut rng);
                let rs = selp(&a, &b, &p);
                let rnot = not_v(w, &a);
                let rneg = neg_v(w, &a);
                for l in 0..WARP {
                    assert_eq!(rs[l], if p[l] & 1 == 1 { a[l] } else { b[l] });
                    assert_eq!(rnot[l], !a[l] & m);
                    assert_eq!(rneg[l], a[l].wrapping_neg() & m);
                }
            }
        }
    }
}
