//! Concrete warp-level GPU simulator (the testbed's "GPU").

pub mod machine;
pub mod memory;

pub use machine::{run, SimConfig, SimError, SimResult, SimStats, WarpEvent};
pub use memory::{Allocator, GlobalMem, MemError, GLOBAL_BASE, SHARED_BASE};
