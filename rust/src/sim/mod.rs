//! Concrete warp-level GPU simulator (the testbed's "GPU").
//!
//! Two engines share one set of semantics:
//!
//! * [`machine`] — the reference AST walker, the semantic oracle;
//! * [`decode`] + [`exec`] — a one-time lowering to flat micro-ops
//!   executed with struct-of-arrays warp state and (optionally) parallel
//!   block execution. This is what [`run`] uses.
//!
//! Both produce bit-identical [`GlobalMem`], [`SimStats`] and
//! block-(0,0,0) traces for any `sim_threads` value (differential-tested;
//! see `tests/integration_sim.rs`).

pub mod decode;
pub mod exec;
#[cfg(feature = "simd")]
pub(crate) mod lanes;
pub mod machine;
pub mod memory;

pub use decode::{decode, DecodedKernel};
pub use exec::run_decoded;
pub use machine::{
    run_reference, BarrierCause, SimConfig, SimError, SimResult, SimStats, WarpEvent,
};
pub use memory::{Allocator, GlobalMem, MemError, GLOBAL_BASE, SHARED_BASE};

use crate::ptx::ast::Kernel;

/// Run a kernel to completion over the whole grid: decode once, then
/// execute the micro-op form (`cfg.sim_threads` workers). Callers that
/// run one kernel many times should decode once via [`decode`] (or the
/// pipeline's cached `Decoded` artifact) and call [`run_decoded`].
pub fn run(kernel: &Kernel, cfg: &SimConfig, mem: GlobalMem) -> Result<SimResult, SimError> {
    let dk = decode::decode(kernel)?;
    exec::run_decoded(&dk, cfg, mem)
}
