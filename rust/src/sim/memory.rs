//! Byte-addressable memory for the warp simulator.
//!
//! Device pointers are plain offsets into one flat global buffer (offset 0
//! is kept unmapped to catch null derefs). Shared memory lives per block in
//! a separate window at `SHARED_BASE`.

/// Base virtual address of the per-block shared-memory window.
pub const SHARED_BASE: u64 = 1 << 47;
/// First valid global address (null guard page).
pub const GLOBAL_BASE: u64 = 0x1000;

#[derive(Debug, Clone)]
pub enum MemError {
    OutOfBounds {
        kind: &'static str,
        addr: u64,
        bytes: u64,
        size: u64,
    },
}

impl std::fmt::Display for MemError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let MemError::OutOfBounds {
            kind,
            addr,
            bytes,
            size,
        } = self;
        write!(
            f,
            "out-of-bounds {kind} of {bytes} bytes at {addr:#x} (global size {size:#x})"
        )
    }
}

impl std::error::Error for MemError {}

/// Flat global memory.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct GlobalMem {
    bytes: Vec<u8>,
}

impl GlobalMem {
    /// Allocate `size` data bytes (addresses `GLOBAL_BASE..GLOBAL_BASE+size`).
    pub fn new(size: usize) -> GlobalMem {
        GlobalMem {
            bytes: vec![0; size],
        }
    }

    pub fn size(&self) -> usize {
        self.bytes.len()
    }

    /// The raw backing bytes (for whole-image comparisons in differential
    /// tests and benchmarks).
    pub fn as_bytes(&self) -> &[u8] {
        &self.bytes
    }

    fn offset(&self, addr: u64, n: u64, kind: &'static str) -> Result<usize, MemError> {
        let end = addr.wrapping_add(n);
        if addr < GLOBAL_BASE || end > GLOBAL_BASE + self.bytes.len() as u64 || end < addr {
            return Err(MemError::OutOfBounds {
                kind,
                addr,
                bytes: n,
                size: self.bytes.len() as u64,
            });
        }
        Ok((addr - GLOBAL_BASE) as usize)
    }

    pub fn load(&self, addr: u64, bytes: u32) -> Result<u64, MemError> {
        let o = self.offset(addr, bytes as u64, "load")?;
        let mut v: u64 = 0;
        for i in 0..bytes as usize {
            v |= (self.bytes[o + i] as u64) << (8 * i);
        }
        Ok(v)
    }

    pub fn store(&mut self, addr: u64, bytes: u32, val: u64) -> Result<(), MemError> {
        let o = self.offset(addr, bytes as u64, "store")?;
        for i in 0..bytes as usize {
            self.bytes[o + i] = (val >> (8 * i)) as u8;
        }
        Ok(())
    }

    /// Store `val` and return the bytes it replaced — the decoded engine's
    /// per-block store logs need the old value for their undo pass, and a
    /// failing exchange must report a *store* bounds error.
    pub fn exchange(&mut self, addr: u64, bytes: u32, val: u64) -> Result<u64, MemError> {
        let o = self.offset(addr, bytes as u64, "store")?;
        let mut old = 0u64;
        for i in 0..bytes as usize {
            old |= (self.bytes[o + i] as u64) << (8 * i);
            self.bytes[o + i] = (val >> (8 * i)) as u8;
        }
        Ok(old)
    }

    /// Write an `f32` slice starting at a device pointer.
    pub fn write_f32s(&mut self, addr: u64, xs: &[f32]) -> Result<(), MemError> {
        for (i, &x) in xs.iter().enumerate() {
            self.store(addr + 4 * i as u64, 4, x.to_bits() as u64)?;
        }
        Ok(())
    }

    pub fn read_f32s(&self, addr: u64, n: usize) -> Result<Vec<f32>, MemError> {
        (0..n)
            .map(|i| Ok(f32::from_bits(self.load(addr + 4 * i as u64, 4)? as u32)))
            .collect()
    }

    pub fn write_u32s(&mut self, addr: u64, xs: &[u32]) -> Result<(), MemError> {
        for (i, &x) in xs.iter().enumerate() {
            self.store(addr + 4 * i as u64, 4, x as u64)?;
        }
        Ok(())
    }

    pub fn read_u32s(&self, addr: u64, n: usize) -> Result<Vec<u32>, MemError> {
        (0..n)
            .map(|i| Ok(self.load(addr + 4 * i as u64, 4)? as u32))
            .collect()
    }
}

/// A simple bump allocator for laying out arrays in global memory.
#[derive(Debug)]
pub struct Allocator {
    next: u64,
    limit: u64,
}

impl Allocator {
    pub fn new(mem: &GlobalMem) -> Allocator {
        Allocator {
            next: GLOBAL_BASE,
            limit: GLOBAL_BASE + mem.size() as u64,
        }
    }

    /// Allocate `bytes` with 256-byte alignment (GPU-like); returns the
    /// device pointer.
    pub fn alloc(&mut self, bytes: u64) -> u64 {
        let p = (self.next + 255) & !255;
        assert!(
            p + bytes <= self.limit,
            "simulator global memory exhausted ({} requested, {} available)",
            bytes,
            self.limit - p
        );
        self.next = p + bytes;
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_various_widths() {
        let mut m = GlobalMem::new(64);
        m.store(GLOBAL_BASE, 4, 0xDEADBEEF).unwrap();
        assert_eq!(m.load(GLOBAL_BASE, 4).unwrap(), 0xDEADBEEF);
        m.store(GLOBAL_BASE + 8, 8, u64::MAX - 5).unwrap();
        assert_eq!(m.load(GLOBAL_BASE + 8, 8).unwrap(), u64::MAX - 5);
        m.store(GLOBAL_BASE + 16, 1, 0x7F).unwrap();
        assert_eq!(m.load(GLOBAL_BASE + 16, 1).unwrap(), 0x7F);
    }

    #[test]
    fn bounds_checked() {
        let m = GlobalMem::new(16);
        assert!(m.load(0, 4).is_err()); // null page
        assert!(m.load(GLOBAL_BASE + 13, 4).is_err()); // crosses the end
        assert!(m.load(GLOBAL_BASE + 12, 4).is_ok());
    }

    #[test]
    fn f32_helpers() {
        let mut m = GlobalMem::new(64);
        m.write_f32s(GLOBAL_BASE, &[1.5, -2.25, 0.0]).unwrap();
        assert_eq!(m.read_f32s(GLOBAL_BASE, 3).unwrap(), vec![1.5, -2.25, 0.0]);
    }

    #[test]
    fn allocator_aligns() {
        let m = GlobalMem::new(4096);
        let mut a = Allocator::new(&m);
        let p1 = a.alloc(10);
        let p2 = a.alloc(10);
        assert_eq!(p1 % 256, 0);
        assert_eq!(p2 % 256, 0);
        assert!(p2 >= p1 + 10);
    }

    #[test]
    #[should_panic(expected = "exhausted")]
    fn allocator_panics_when_full() {
        let m = GlobalMem::new(128);
        let mut a = Allocator::new(&m);
        a.alloc(4096);
    }
}
