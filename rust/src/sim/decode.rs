//! One-time lowering of a [`Kernel`] into a flat micro-op form.
//!
//! The reference interpreter pays per *executed* instruction for work that
//! only depends on the *static* kernel: interning register names
//! (`regs.intern(r)` hashes the register string on every operand read),
//! resolving branch labels through a `HashMap`, looking up parameter and
//! shared-variable names, and recomputing width masks. `decode` does all
//! of that exactly once and produces a [`DecodedKernel`]:
//!
//! * registers are pre-interned to dense **slot indices** (the same
//!   [`RegInterner`] numbering the reference engine uses);
//! * labels are erased and branch targets resolved to **micro-op
//!   indices**;
//! * shared-variable bases are baked as immediate addresses, parameter
//!   reads carry the parameter's **index** into `SimConfig::params`
//!   (values stay launch-time, so the decoded form is reusable across
//!   workloads and content-addressed by the kernel fingerprint alone);
//! * integer immediates are pre-masked with the width mask of the reading
//!   site, and every mask/width an op needs is stored in the op;
//! * a `stmt` side table maps each micro-op back to its kernel-body
//!   statement index, so [`super::WarpEvent`] traces — and through them
//!   the perf model — are unchanged.
//!
//! Decoding is *eager* about static errors: an unknown branch target,
//! parameter or shared variable fails `decode` even if the instruction
//! would never execute (the reference engine only errors when the
//! offending instruction is reached). The arithmetic itself is not
//! reinterpreted here — the executor reuses the reference engine's helper
//! functions, so both engines compute every value with the same code.

use super::machine::{int_bvop, shared_layout, width_mask};
use super::SimError;
use crate::emu::env::RegInterner;
use crate::emu::memtrace::{space_from_tag, space_tag, type_from_tag, type_tag};
use crate::ptx::ast::*;
use crate::sym::persist::{bvop_from_tag, bvop_tag, cmp_from_tag, cmp_tag};
use crate::sym::term::{BvOp, CmpKind};
use crate::util::{Dec, Enc};

/// A decoded operand: everything a read needs, with names resolved away.
#[derive(Debug, Clone, Copy)]
pub enum Dop {
    /// Pre-interned register slot; masked with the site's width mask at
    /// read time (and checked against the written bitmap for the
    /// uninitialized-read counter).
    Slot(u32),
    /// Immediate, pre-masked at decode time exactly as the reference
    /// engine masks it at read time (float immediates are raw bits).
    Imm(u64),
    /// Special register; evaluated per lane, masked at read time.
    Special(Special),
}

/// `[base+offset]` with a decoded base.
#[derive(Debug, Clone, Copy)]
pub struct Daddr {
    pub base: Dop,
    pub offset: u64,
}

/// One micro-op. Field names mirror the AST op they were lowered from;
/// `w`/`mask` fields are the pre-resolved operand widths/masks.
#[derive(Debug, Clone)]
pub enum Uop {
    /// `bra` with the target resolved to a micro-op index. `target_stmt`
    /// is the statement index of the targeted *label* — the executor's
    /// per-lane statement positions (exact `max_warp_steps` accounting)
    /// restart there, so a branch into the middle of a label run charges
    /// only the labels actually traversed.
    Bra { target: u32, target_stmt: u32 },
    /// `ret` / `exit`.
    Ret,
    /// `bar.sync id [, cnt]` — suspends the warp until every warp of the
    /// block arrives (cooperative scheduler). `cnt` 0 = no explicit count.
    BarSync { id: u32, cnt: u32 },
    Shfl {
        mode: ShflMode,
        dst: u32,
        pred_out: Option<u32>,
        src: Dop,
        b: Dop,
        c: Dop,
        mask: Dop,
    },
    Activemask { dst: u32 },
    /// `ld.param`: the parameter index into `SimConfig::params` plus the
    /// result mask (`width_mask(ty.bits())`).
    LdParam { dst: u32, index: u32, mask: u64 },
    /// Non-param load; `bytes = ty.bytes()`.
    Ld {
        space: Space,
        nc: bool,
        bytes: u32,
        dst: u32,
        addr: Daddr,
    },
    /// Store; `src` is read with `smask` (`width_mask(ty.bits().max(8))`).
    St {
        space: Space,
        bytes: u32,
        smask: u64,
        src: Dop,
        addr: Daddr,
    },
    Mov { dst: u32, src: Dop, mask: u64 },
    Cvta { dst: u32, src: Dop },
    /// Generic integer binary op on the shared `eval_bin` path.
    IntBin {
        op: BvOp,
        w: u32,
        mask: u64,
        dst: u32,
        a: Dop,
        b: Dop,
    },
    MulWide {
        signed: bool,
        w: u32,
        dst: u32,
        a: Dop,
        b: Dop,
    },
    MulHi {
        signed: bool,
        w: u32,
        dst: u32,
        a: Dop,
        b: Dop,
    },
    Mad {
        wide: bool,
        signed: bool,
        w: u32,
        dst: u32,
        a: Dop,
        b: Dop,
        c: Dop,
    },
    Not { w: u32, dst: u32, a: Dop },
    Neg { w: u32, dst: u32, a: Dop },
    FltBin {
        op: FltBinOp,
        wide: bool,
        dst: u32,
        a: Dop,
        b: Dop,
    },
    Fma {
        wide: bool,
        dst: u32,
        a: Dop,
        b: Dop,
        c: Dop,
    },
    FltUn {
        op: FltUnOp,
        wide: bool,
        dst: u32,
        a: Dop,
    },
    /// Float compare (operands widened to f64 for F32, as the reference
    /// engine does).
    SetpF {
        cmp: CmpOp,
        wide: bool,
        dst: u32,
        a: Dop,
        b: Dop,
    },
    /// Integer compare with the signedness pre-resolved to a [`CmpKind`].
    SetpI {
        kind: CmpKind,
        w: u32,
        dst: u32,
        a: Dop,
        b: Dop,
    },
    Selp {
        w: u32,
        dst: u32,
        a: Dop,
        b: Dop,
        p: Dop,
    },
    Cvt {
        dty: Type,
        sty: Type,
        dst: u32,
        src: Dop,
    },
}

/// One decoded instruction: micro-op + guard + origin statement.
#[derive(Debug, Clone)]
pub struct UopEntry {
    /// Kernel-body statement index this micro-op was lowered from (the
    /// trace/perf-model side table).
    pub stmt: u32,
    /// Guard predicate as `(slot, negated)`.
    pub guard: Option<(u32, bool)>,
    pub op: Uop,
}

/// A kernel lowered to flat micro-ops; immutable and shareable across
/// threads and workloads (pipeline artifact keyed by the kernel
/// fingerprint).
#[derive(Debug, Clone)]
pub struct DecodedKernel {
    /// Register-file slots per lane (the [`RegInterner`] universe).
    pub nregs: u32,
    /// Per-block shared-memory window size in bytes.
    pub shared_size: u64,
    /// Kernel-body statement count (labels included) — the upper bound of
    /// the statement side table, used to charge trailing-label visits in
    /// the executor's step accounting.
    pub nstmts: u32,
    /// Parameter names in declaration order, for launch-time
    /// missing-value errors.
    pub param_names: Vec<String>,
    pub uops: Vec<UopEntry>,
    /// Superblock table, one entry per micro-op: `sb_end[i]` is the
    /// exclusive end of the maximal *straight-line* run starting at `i` —
    /// no control flow (`bra`/`ret`/`bar.sync`) anywhere in
    /// `i..sb_end[i]`; for a control op itself `sb_end[i] == i`. Indexing
    /// by the run's *start* means a branch into the middle of a run needs
    /// no split: the entry at the landing uop is exactly the remaining
    /// suffix. The executor's fast path runs a whole (possibly clamped)
    /// run with one bulk step charge and no per-uop bookkeeping; see
    /// `sim::exec`.
    pub sb_end: Vec<u32>,
}

/// Compute the superblock table for a micro-op stream (decode-time; also
/// the ground truth [`DecodedKernel::from_bytes`] revalidates a persisted
/// table against — derived data is never trusted from disk).
fn superblock_ends(uops: &[UopEntry]) -> Vec<u32> {
    let mut ends = vec![0u32; uops.len()];
    let mut end = uops.len() as u32;
    for (i, u) in uops.iter().enumerate().rev() {
        if matches!(u.op, Uop::Bra { .. } | Uop::Ret | Uop::BarSync { .. }) {
            ends[i] = i as u32;
            end = i as u32;
        } else {
            ends[i] = end;
        }
    }
    ends
}

impl DecodedKernel {
    /// Micro-ops per kernel-body *instruction* executed once (diagnostic).
    pub fn len(&self) -> usize {
        self.uops.len()
    }

    pub fn is_empty(&self) -> bool {
        self.uops.is_empty()
    }

    /// Serialize for the on-disk artifact store (`decoded/` kind). The
    /// decoded form is a pure function of the kernel, so this is a plain
    /// field-by-field codec on the shared [`crate::util::codec`]
    /// primitives — no relocation needed (slots and targets are already
    /// kernel-local indices).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut e = Enc::default();
        e.u32(self.nregs);
        e.u64(self.shared_size);
        e.u32(self.nstmts);
        e.u64(self.param_names.len() as u64);
        for p in &self.param_names {
            e.str(p);
        }
        e.u64(self.uops.len() as u64);
        for u in &self.uops {
            e.u32(u.stmt);
            match u.guard {
                None => e.u8(0),
                Some((slot, neg)) => {
                    e.u8(1);
                    e.u32(slot);
                    e.bool(neg);
                }
            }
            enc_uop(&mut e, &u.op);
        }
        for &end in &self.sb_end {
            e.u32(end);
        }
        e.buf
    }

    /// Decode a [`DecodedKernel::to_bytes`] image. Fully validated: every
    /// register slot must be `< nregs`, every branch target `≤ #uops`,
    /// every `ld.param` index in range, and the statement side table
    /// strictly increasing (the executor's statement-step accounting and
    /// flat register file index through these without further checks).
    pub fn from_bytes(bytes: &[u8]) -> Option<DecodedKernel> {
        let mut d = Dec::new(bytes);
        let nregs = d.u32()?;
        let shared_size = d.u64()?;
        let nstmts = d.u32()?;
        let nparams = d.len()?;
        let mut param_names = Vec::with_capacity(nparams);
        for _ in 0..nparams {
            param_names.push(d.str()?.to_string());
        }
        let nuops = d.len()?;
        let mut uops = Vec::with_capacity(nuops);
        for i in 0..nuops {
            let stmt = d.u32()?;
            let guard = match d.u8()? {
                0 => None,
                1 => {
                    let slot = d.u32()?;
                    (slot < nregs).then_some(())?;
                    Some((slot, d.bool()?))
                }
                _ => return None,
            };
            let op = dec_uop(&mut d)?;
            if i > 0 {
                let prev: &UopEntry = &uops[i - 1];
                (stmt > prev.stmt).then_some(())?;
            }
            uops.push(UopEntry { stmt, guard, op });
        }
        let mut sb_end = Vec::with_capacity(nuops);
        for _ in 0..nuops {
            sb_end.push(d.u32()?);
        }
        let dk = DecodedKernel {
            nregs,
            shared_size,
            nstmts,
            param_names,
            uops,
            sb_end,
        };
        (d.done() && dk.validate()).then_some(dk)
    }

    /// Structural invariants the executor relies on (indexes without
    /// bounds checks). The statement side table must fit `nstmts` and
    /// branch statement positions must not exceed the targeted micro-op's
    /// statement — the step accounting computes `stmt - stmt_pos` gaps
    /// without underflow checks.
    fn validate(&self) -> bool {
        let nuops = self.uops.len() as u32;
        let slot_ok = |s: u32| s < self.nregs;
        let dop_ok = |o: &Dop| match o {
            Dop::Slot(s) => slot_ok(*s),
            Dop::Imm(_) | Dop::Special(_) => true,
        };
        let addr_ok = |a: &Daddr| dop_ok(&a.base);
        let bytes_ok = |b: u32| (1..=8).contains(&b);
        if self.uops.last().map(|u| u.stmt >= self.nstmts).unwrap_or(false) {
            return false;
        }
        // The superblock table is derived data: revalidate by exact
        // recomputation (the fast path runs interiors with no per-uop
        // checks, so a stale or tampered table must never load).
        if self.sb_end != superblock_ends(&self.uops) {
            return false;
        }
        self.uops.iter().all(|u| {
            u.guard.map(|(s, _)| slot_ok(s)).unwrap_or(true)
                && match &u.op {
                    Uop::Bra { target, target_stmt } => {
                        let bound = if *target < nuops {
                            self.uops[*target as usize].stmt
                        } else {
                            self.nstmts
                        };
                        *target <= nuops && *target_stmt <= bound
                    }
                    Uop::Ret | Uop::BarSync { .. } => true,
                    Uop::Shfl { dst, pred_out, src, b, c, mask, .. } => {
                        slot_ok(*dst)
                            && pred_out.map(slot_ok).unwrap_or(true)
                            && [src, b, c, mask].into_iter().all(dop_ok)
                    }
                    Uop::Activemask { dst } => slot_ok(*dst),
                    Uop::LdParam { dst, index, .. } => {
                        slot_ok(*dst) && (*index as usize) < self.param_names.len()
                    }
                    Uop::Ld { bytes, dst, addr, space, .. } => {
                        slot_ok(*dst)
                            && addr_ok(addr)
                            && bytes_ok(*bytes)
                            && *space != Space::Param
                    }
                    Uop::St { bytes, src, addr, space, .. } => {
                        dop_ok(src)
                            && addr_ok(addr)
                            && bytes_ok(*bytes)
                            && *space != Space::Param
                    }
                    Uop::Mov { dst, src, .. } => slot_ok(*dst) && dop_ok(src),
                    Uop::Cvta { dst, src } => slot_ok(*dst) && dop_ok(src),
                    Uop::IntBin { dst, a, b, .. }
                    | Uop::MulWide { dst, a, b, .. }
                    | Uop::MulHi { dst, a, b, .. }
                    | Uop::FltBin { dst, a, b, .. }
                    | Uop::SetpF { dst, a, b, .. }
                    | Uop::SetpI { dst, a, b, .. } => {
                        slot_ok(*dst) && dop_ok(a) && dop_ok(b)
                    }
                    Uop::Mad { dst, a, b, c, .. } | Uop::Fma { dst, a, b, c, .. } => {
                        slot_ok(*dst) && dop_ok(a) && dop_ok(b) && dop_ok(c)
                    }
                    Uop::Not { dst, a, .. }
                    | Uop::Neg { dst, a, .. }
                    | Uop::FltUn { dst, a, .. } => slot_ok(*dst) && dop_ok(a),
                    Uop::Selp { dst, a, b, p, .. } => {
                        slot_ok(*dst) && dop_ok(a) && dop_ok(b) && dop_ok(p)
                    }
                    Uop::Cvt { dst, src, .. } => slot_ok(*dst) && dop_ok(src),
                }
        })
    }
}

fn special_tag(s: Special) -> u8 {
    match s {
        Special::TidX => 0,
        Special::TidY => 1,
        Special::TidZ => 2,
        Special::NtidX => 3,
        Special::NtidY => 4,
        Special::NtidZ => 5,
        Special::CtaidX => 6,
        Special::CtaidY => 7,
        Special::CtaidZ => 8,
        Special::NctaidX => 9,
        Special::NctaidY => 10,
        Special::NctaidZ => 11,
        Special::LaneId => 12,
        Special::WarpSize => 13,
    }
}

fn special_from_tag(tag: u8) -> Option<Special> {
    Some(match tag {
        0 => Special::TidX,
        1 => Special::TidY,
        2 => Special::TidZ,
        3 => Special::NtidX,
        4 => Special::NtidY,
        5 => Special::NtidZ,
        6 => Special::CtaidX,
        7 => Special::CtaidY,
        8 => Special::CtaidZ,
        9 => Special::NctaidX,
        10 => Special::NctaidY,
        11 => Special::NctaidZ,
        12 => Special::LaneId,
        13 => Special::WarpSize,
        _ => return None,
    })
}

fn shfl_tag(m: ShflMode) -> u8 {
    match m {
        ShflMode::Up => 0,
        ShflMode::Down => 1,
        ShflMode::Bfly => 2,
        ShflMode::Idx => 3,
    }
}

fn shfl_from_tag(tag: u8) -> Option<ShflMode> {
    Some(match tag {
        0 => ShflMode::Up,
        1 => ShflMode::Down,
        2 => ShflMode::Bfly,
        3 => ShflMode::Idx,
        _ => return None,
    })
}

fn fltbin_tag(o: FltBinOp) -> u8 {
    match o {
        FltBinOp::Add => 0,
        FltBinOp::Sub => 1,
        FltBinOp::Mul => 2,
        FltBinOp::Div => 3,
        FltBinOp::Min => 4,
        FltBinOp::Max => 5,
    }
}

fn fltbin_from_tag(tag: u8) -> Option<FltBinOp> {
    Some(match tag {
        0 => FltBinOp::Add,
        1 => FltBinOp::Sub,
        2 => FltBinOp::Mul,
        3 => FltBinOp::Div,
        4 => FltBinOp::Min,
        5 => FltBinOp::Max,
        _ => return None,
    })
}

fn fltun_tag(o: FltUnOp) -> u8 {
    match o {
        FltUnOp::Neg => 0,
        FltUnOp::Abs => 1,
        FltUnOp::Sqrt => 2,
        FltUnOp::Rsqrt => 3,
        FltUnOp::Rcp => 4,
        FltUnOp::Sin => 5,
        FltUnOp::Cos => 6,
        FltUnOp::Ex2 => 7,
        FltUnOp::Lg2 => 8,
    }
}

fn fltun_from_tag(tag: u8) -> Option<FltUnOp> {
    Some(match tag {
        0 => FltUnOp::Neg,
        1 => FltUnOp::Abs,
        2 => FltUnOp::Sqrt,
        3 => FltUnOp::Rsqrt,
        4 => FltUnOp::Rcp,
        5 => FltUnOp::Sin,
        6 => FltUnOp::Cos,
        7 => FltUnOp::Ex2,
        8 => FltUnOp::Lg2,
        _ => return None,
    })
}

fn cmpop_tag(o: CmpOp) -> u8 {
    match o {
        CmpOp::Eq => 0,
        CmpOp::Ne => 1,
        CmpOp::Lt => 2,
        CmpOp::Le => 3,
        CmpOp::Gt => 4,
        CmpOp::Ge => 5,
    }
}

fn cmpop_from_tag(tag: u8) -> Option<CmpOp> {
    Some(match tag {
        0 => CmpOp::Eq,
        1 => CmpOp::Ne,
        2 => CmpOp::Lt,
        3 => CmpOp::Le,
        4 => CmpOp::Gt,
        5 => CmpOp::Ge,
        _ => return None,
    })
}

fn enc_dop(e: &mut Enc, d: &Dop) {
    match d {
        Dop::Slot(s) => {
            e.u8(0);
            e.u32(*s);
        }
        Dop::Imm(v) => {
            e.u8(1);
            e.u64(*v);
        }
        Dop::Special(sp) => {
            e.u8(2);
            e.u8(special_tag(*sp));
        }
    }
}

fn dec_dop(d: &mut Dec) -> Option<Dop> {
    Some(match d.u8()? {
        0 => Dop::Slot(d.u32()?),
        1 => Dop::Imm(d.u64()?),
        2 => Dop::Special(special_from_tag(d.u8()?)?),
        _ => return None,
    })
}

fn enc_addr(e: &mut Enc, a: &Daddr) {
    enc_dop(e, &a.base);
    e.u64(a.offset);
}

fn dec_addr(d: &mut Dec) -> Option<Daddr> {
    Some(Daddr {
        base: dec_dop(d)?,
        offset: d.u64()?,
    })
}

fn enc_uop(e: &mut Enc, op: &Uop) {
    match op {
        Uop::Bra { target, target_stmt } => {
            e.u8(0);
            e.u32(*target);
            e.u32(*target_stmt);
        }
        Uop::Ret => e.u8(1),
        Uop::BarSync { id, cnt } => {
            e.u8(2);
            e.u32(*id);
            e.u32(*cnt);
        }
        Uop::Shfl { mode, dst, pred_out, src, b, c, mask } => {
            e.u8(3);
            e.u8(shfl_tag(*mode));
            e.u32(*dst);
            match pred_out {
                None => e.u8(0),
                Some(p) => {
                    e.u8(1);
                    e.u32(*p);
                }
            }
            for o in [src, b, c, mask] {
                enc_dop(e, o);
            }
        }
        Uop::Activemask { dst } => {
            e.u8(4);
            e.u32(*dst);
        }
        Uop::LdParam { dst, index, mask } => {
            e.u8(5);
            e.u32(*dst);
            e.u32(*index);
            e.u64(*mask);
        }
        Uop::Ld { space, nc, bytes, dst, addr } => {
            e.u8(6);
            e.u8(space_tag(*space));
            e.bool(*nc);
            e.u32(*bytes);
            e.u32(*dst);
            enc_addr(e, addr);
        }
        Uop::St { space, bytes, smask, src, addr } => {
            e.u8(7);
            e.u8(space_tag(*space));
            e.u32(*bytes);
            e.u64(*smask);
            enc_dop(e, src);
            enc_addr(e, addr);
        }
        Uop::Mov { dst, src, mask } => {
            e.u8(8);
            e.u32(*dst);
            enc_dop(e, src);
            e.u64(*mask);
        }
        Uop::Cvta { dst, src } => {
            e.u8(9);
            e.u32(*dst);
            enc_dop(e, src);
        }
        Uop::IntBin { op, w, mask, dst, a, b } => {
            e.u8(10);
            e.u8(bvop_tag(*op));
            e.u32(*w);
            e.u64(*mask);
            e.u32(*dst);
            enc_dop(e, a);
            enc_dop(e, b);
        }
        Uop::MulWide { signed, w, dst, a, b } => {
            e.u8(11);
            e.bool(*signed);
            e.u32(*w);
            e.u32(*dst);
            enc_dop(e, a);
            enc_dop(e, b);
        }
        Uop::MulHi { signed, w, dst, a, b } => {
            e.u8(12);
            e.bool(*signed);
            e.u32(*w);
            e.u32(*dst);
            enc_dop(e, a);
            enc_dop(e, b);
        }
        Uop::Mad { wide, signed, w, dst, a, b, c } => {
            e.u8(13);
            e.bool(*wide);
            e.bool(*signed);
            e.u32(*w);
            e.u32(*dst);
            enc_dop(e, a);
            enc_dop(e, b);
            enc_dop(e, c);
        }
        Uop::Not { w, dst, a } => {
            e.u8(14);
            e.u32(*w);
            e.u32(*dst);
            enc_dop(e, a);
        }
        Uop::Neg { w, dst, a } => {
            e.u8(15);
            e.u32(*w);
            e.u32(*dst);
            enc_dop(e, a);
        }
        Uop::FltBin { op, wide, dst, a, b } => {
            e.u8(16);
            e.u8(fltbin_tag(*op));
            e.bool(*wide);
            e.u32(*dst);
            enc_dop(e, a);
            enc_dop(e, b);
        }
        Uop::Fma { wide, dst, a, b, c } => {
            e.u8(17);
            e.bool(*wide);
            e.u32(*dst);
            enc_dop(e, a);
            enc_dop(e, b);
            enc_dop(e, c);
        }
        Uop::FltUn { op, wide, dst, a } => {
            e.u8(18);
            e.u8(fltun_tag(*op));
            e.bool(*wide);
            e.u32(*dst);
            enc_dop(e, a);
        }
        Uop::SetpF { cmp, wide, dst, a, b } => {
            e.u8(19);
            e.u8(cmpop_tag(*cmp));
            e.bool(*wide);
            e.u32(*dst);
            enc_dop(e, a);
            enc_dop(e, b);
        }
        Uop::SetpI { kind, w, dst, a, b } => {
            e.u8(20);
            e.u8(cmp_tag(*kind));
            e.u32(*w);
            e.u32(*dst);
            enc_dop(e, a);
            enc_dop(e, b);
        }
        Uop::Selp { w, dst, a, b, p } => {
            e.u8(21);
            e.u32(*w);
            e.u32(*dst);
            enc_dop(e, a);
            enc_dop(e, b);
            enc_dop(e, p);
        }
        Uop::Cvt { dty, sty, dst, src } => {
            e.u8(22);
            e.u8(type_tag(*dty));
            e.u8(type_tag(*sty));
            e.u32(*dst);
            enc_dop(e, src);
        }
    }
}

fn dec_uop(d: &mut Dec) -> Option<Uop> {
    Some(match d.u8()? {
        0 => Uop::Bra {
            target: d.u32()?,
            target_stmt: d.u32()?,
        },
        1 => Uop::Ret,
        2 => Uop::BarSync {
            id: d.u32()?,
            cnt: d.u32()?,
        },
        3 => {
            let mode = shfl_from_tag(d.u8()?)?;
            let dst = d.u32()?;
            let pred_out = match d.u8()? {
                0 => None,
                1 => Some(d.u32()?),
                _ => return None,
            };
            Uop::Shfl {
                mode,
                dst,
                pred_out,
                src: dec_dop(d)?,
                b: dec_dop(d)?,
                c: dec_dop(d)?,
                mask: dec_dop(d)?,
            }
        }
        4 => Uop::Activemask { dst: d.u32()? },
        5 => Uop::LdParam {
            dst: d.u32()?,
            index: d.u32()?,
            mask: d.u64()?,
        },
        6 => Uop::Ld {
            space: space_from_tag(d.u8()?)?,
            nc: d.bool()?,
            bytes: d.u32()?,
            dst: d.u32()?,
            addr: dec_addr(d)?,
        },
        7 => Uop::St {
            space: space_from_tag(d.u8()?)?,
            bytes: d.u32()?,
            smask: d.u64()?,
            src: dec_dop(d)?,
            addr: dec_addr(d)?,
        },
        8 => Uop::Mov {
            dst: d.u32()?,
            src: dec_dop(d)?,
            mask: d.u64()?,
        },
        9 => Uop::Cvta {
            dst: d.u32()?,
            src: dec_dop(d)?,
        },
        10 => Uop::IntBin {
            op: bvop_from_tag(d.u8()?)?,
            w: d.u32()?,
            mask: d.u64()?,
            dst: d.u32()?,
            a: dec_dop(d)?,
            b: dec_dop(d)?,
        },
        11 => Uop::MulWide {
            signed: d.bool()?,
            w: d.u32()?,
            dst: d.u32()?,
            a: dec_dop(d)?,
            b: dec_dop(d)?,
        },
        12 => Uop::MulHi {
            signed: d.bool()?,
            w: d.u32()?,
            dst: d.u32()?,
            a: dec_dop(d)?,
            b: dec_dop(d)?,
        },
        13 => Uop::Mad {
            wide: d.bool()?,
            signed: d.bool()?,
            w: d.u32()?,
            dst: d.u32()?,
            a: dec_dop(d)?,
            b: dec_dop(d)?,
            c: dec_dop(d)?,
        },
        14 => Uop::Not {
            w: d.u32()?,
            dst: d.u32()?,
            a: dec_dop(d)?,
        },
        15 => Uop::Neg {
            w: d.u32()?,
            dst: d.u32()?,
            a: dec_dop(d)?,
        },
        16 => Uop::FltBin {
            op: fltbin_from_tag(d.u8()?)?,
            wide: d.bool()?,
            dst: d.u32()?,
            a: dec_dop(d)?,
            b: dec_dop(d)?,
        },
        17 => Uop::Fma {
            wide: d.bool()?,
            dst: d.u32()?,
            a: dec_dop(d)?,
            b: dec_dop(d)?,
            c: dec_dop(d)?,
        },
        18 => Uop::FltUn {
            op: fltun_from_tag(d.u8()?)?,
            wide: d.bool()?,
            dst: d.u32()?,
            a: dec_dop(d)?,
        },
        19 => Uop::SetpF {
            cmp: cmpop_from_tag(d.u8()?)?,
            wide: d.bool()?,
            dst: d.u32()?,
            a: dec_dop(d)?,
            b: dec_dop(d)?,
        },
        20 => Uop::SetpI {
            kind: cmp_from_tag(d.u8()?)?,
            w: d.u32()?,
            dst: d.u32()?,
            a: dec_dop(d)?,
            b: dec_dop(d)?,
        },
        21 => Uop::Selp {
            w: d.u32()?,
            dst: d.u32()?,
            a: dec_dop(d)?,
            b: dec_dop(d)?,
            p: dec_dop(d)?,
        },
        22 => Uop::Cvt {
            dty: type_from_tag(d.u8()?)?,
            sty: type_from_tag(d.u8()?)?,
            dst: d.u32()?,
            src: dec_dop(d)?,
        },
        _ => return None,
    })
}

struct Decoder<'a> {
    kernel: &'a Kernel,
    regs: RegInterner,
    shared_bases: std::collections::HashMap<&'a str, u64>,
}

impl<'a> Decoder<'a> {
    fn slot(&mut self, r: &Reg) -> u32 {
        self.regs.intern(r)
    }

    /// Decode an operand read at `width` bits, replicating the reference
    /// engine's `read_operand` masking: integer immediates are masked,
    /// float immediates are raw bits, shared bases are unmasked
    /// addresses, registers and specials are masked at read time.
    fn operand(&mut self, o: &Operand, width: u32) -> Result<Dop, SimError> {
        let m = width_mask(width);
        Ok(match o {
            Operand::Reg(r) => Dop::Slot(self.slot(r)),
            Operand::ImmInt(v) => Dop::Imm((*v as u64) & m),
            Operand::ImmF32(b) => Dop::Imm(*b as u64),
            Operand::ImmF64(b) => Dop::Imm(*b),
            Operand::Special(sp) => Dop::Special(*sp),
            Operand::Var(v) => Dop::Imm(
                self.shared_bases
                    .get(v.as_str())
                    .copied()
                    .ok_or_else(|| SimError::UnknownVar(v.clone()))?,
            ),
        })
    }

    /// Address bases are read at 64 bits.
    fn address(&mut self, a: &Address) -> Result<Daddr, SimError> {
        Ok(Daddr {
            base: self.operand(&a.base, 64)?,
            offset: a.offset as u64,
        })
    }

    fn param_index(&self, addr: &Address) -> Result<u32, SimError> {
        let name = match &addr.base {
            Operand::Var(n) => n.as_str(),
            _ => "?",
        };
        self.kernel
            .params
            .iter()
            .position(|p| p.name == name)
            .map(|i| i as u32)
            .ok_or_else(|| SimError::UnknownParam(name.to_string()))
        // scalar param: the value itself; offset addressing into
        // multi-word params is not needed for our kernels
    }

    fn op(
        &mut self,
        op: &Op,
        branch_target: impl Fn(&str) -> Option<(u32, u32)>,
    ) -> Result<Uop, SimError> {
        Ok(match op {
            Op::Bra { target, .. } => {
                let (target, target_stmt) = branch_target(target)
                    .ok_or_else(|| SimError::UnknownLabel(target.clone()))?;
                Uop::Bra {
                    target,
                    target_stmt,
                }
            }
            Op::Ret | Op::Exit => Uop::Ret,
            Op::BarSync { id, cnt } => Uop::BarSync {
                id: *id,
                cnt: cnt.unwrap_or(0),
            },
            Op::Shfl { mode, dst, pred_out, src, b, c, mask } => Uop::Shfl {
                mode: *mode,
                dst: self.slot(dst),
                pred_out: pred_out.as_ref().map(|p| self.slot(p)),
                src: self.operand(src, 32)?,
                b: self.operand(b, 32)?,
                c: self.operand(c, 32)?,
                mask: self.operand(mask, 32)?,
            },
            Op::Activemask { dst } => Uop::Activemask {
                dst: self.slot(dst),
            },
            Op::Ld { space, nc, ty, dst, addr } => {
                if *space == Space::Param {
                    Uop::LdParam {
                        dst: self.slot(dst),
                        index: self.param_index(addr)?,
                        mask: width_mask(ty.bits()),
                    }
                } else {
                    Uop::Ld {
                        space: *space,
                        nc: *nc,
                        bytes: ty.bytes() as u32,
                        dst: self.slot(dst),
                        addr: self.address(addr)?,
                    }
                }
            }
            Op::St { space, ty, addr, src } => Uop::St {
                space: *space,
                bytes: ty.bytes() as u32,
                smask: width_mask(ty.bits().max(8)),
                src: self.operand(src, ty.bits().max(8))?,
                addr: self.address(addr)?,
            },
            Op::Mov { ty, dst, src } => Uop::Mov {
                dst: self.slot(dst),
                src: self.operand(src, ty.bits().max(8))?,
                mask: width_mask(ty.bits().max(8)),
            },
            Op::Cvta { dst, src, .. } => Uop::Cvta {
                dst: self.slot(dst),
                src: self.operand(src, 64)?,
            },
            Op::IntBin { op: bop, ty, dst, a, b } => {
                let w = ty.bits().max(1);
                let signed = ty.is_signed();
                let (dst, a, b) =
                    (self.slot(dst), self.operand(a, w)?, self.operand(b, w)?);
                match bop {
                    IntBinOp::MulWide => Uop::MulWide { signed, w, dst, a, b },
                    IntBinOp::MulHi => Uop::MulHi { signed, w, dst, a, b },
                    _ => Uop::IntBin {
                        op: int_bvop(*bop, signed),
                        w,
                        mask: width_mask(w),
                        dst,
                        a,
                        b,
                    },
                }
            }
            Op::Mad { wide, ty, dst, a, b, c } => {
                let w = ty.bits();
                let cw = if *wide { w * 2 } else { w };
                Uop::Mad {
                    wide: *wide,
                    signed: ty.is_signed(),
                    w,
                    dst: self.slot(dst),
                    a: self.operand(a, w)?,
                    b: self.operand(b, w)?,
                    c: self.operand(c, cw)?,
                }
            }
            Op::Not { ty, dst, a } => {
                let w = ty.bits().max(1);
                Uop::Not {
                    w,
                    dst: self.slot(dst),
                    a: self.operand(a, w)?,
                }
            }
            Op::Neg { ty, dst, a } => {
                let w = ty.bits();
                Uop::Neg {
                    w,
                    dst: self.slot(dst),
                    a: self.operand(a, w)?,
                }
            }
            Op::FltBin { op: fop, ty, dst, a, b } => {
                let w = ty.bits();
                Uop::FltBin {
                    op: *fop,
                    wide: *ty != Type::F32,
                    dst: self.slot(dst),
                    a: self.operand(a, w)?,
                    b: self.operand(b, w)?,
                }
            }
            Op::Fma { ty, dst, a, b, c } => {
                let w = ty.bits();
                Uop::Fma {
                    wide: *ty != Type::F32,
                    dst: self.slot(dst),
                    a: self.operand(a, w)?,
                    b: self.operand(b, w)?,
                    c: self.operand(c, w)?,
                }
            }
            Op::FltUn { op: fop, ty, dst, a } => Uop::FltUn {
                op: *fop,
                wide: *ty != Type::F32,
                dst: self.slot(dst),
                a: self.operand(a, ty.bits())?,
            },
            Op::Setp { cmp, ty, dst, a, b } => {
                let w = ty.bits();
                let (dst, a, b) =
                    (self.slot(dst), self.operand(a, w)?, self.operand(b, w)?);
                if ty.is_float() {
                    Uop::SetpF {
                        cmp: *cmp,
                        wide: *ty != Type::F32,
                        dst,
                        a,
                        b,
                    }
                } else {
                    let signed =
                        !matches!(ty, Type::U8 | Type::U16 | Type::U32 | Type::U64);
                    Uop::SetpI {
                        kind: super::machine::cmp_kind(*cmp, signed),
                        w,
                        dst,
                        a,
                        b,
                    }
                }
            }
            Op::Selp { ty, dst, a, b, p } => {
                let w = ty.bits();
                Uop::Selp {
                    w,
                    dst: self.slot(dst),
                    a: self.operand(a, w)?,
                    b: self.operand(b, w)?,
                    p: self.operand(p, 1)?,
                }
            }
            Op::Cvt { dty, sty, dst, src } => Uop::Cvt {
                dty: *dty,
                sty: *sty,
                dst: self.slot(dst),
                src: self.operand(src, sty.bits())?,
            },
        })
    }
}

/// Lower a kernel into its flat micro-op form.
pub fn decode(kernel: &Kernel) -> Result<DecodedKernel, SimError> {
    // Same slot numbering as the reference engine (it pre-interns the
    // whole kernel too), so register files are layout-compatible.
    let regs = RegInterner::from_kernel(kernel);
    let (shared_bases, shared_size) = shared_layout(kernel);

    // Statement index → micro-op index of the first instruction at or
    // after it (labels lower to nothing; a lane stepping past a label in
    // the reference engine lands on exactly this instruction).
    let mut stmt_to_uop = Vec::with_capacity(kernel.body.len() + 1);
    let mut n = 0u32;
    for st in &kernel.body {
        stmt_to_uop.push(n);
        if matches!(st, Statement::Instr { .. }) {
            n += 1;
        }
    }
    stmt_to_uop.push(n); // branch past the end = retire

    // label → (first micro-op at or after it, the label's own statement
    // index). The statement index is where a branching lane's statement
    // position restarts, so label runs are charged from the *targeted*
    // label, not the run's first label.
    let mut labels: std::collections::HashMap<&str, (u32, u32)> =
        std::collections::HashMap::new();
    for (i, st) in kernel.body.iter().enumerate() {
        if let Statement::Label(l) = st {
            labels.insert(l.as_str(), (stmt_to_uop[i], i as u32));
        }
    }

    let mut d = Decoder {
        kernel,
        regs,
        shared_bases,
    };
    let mut uops = Vec::with_capacity(n as usize);
    for (i, st) in kernel.body.iter().enumerate() {
        let Statement::Instr { guard, op } = st else {
            continue;
        };
        let guard = guard.as_ref().map(|g| (d.regs.intern(&g.reg), g.negated));
        let op = d.op(op, |l| labels.get(l).copied())?;
        uops.push(UopEntry {
            stmt: i as u32,
            guard,
            op,
        });
    }

    let sb_end = superblock_ends(&uops);
    Ok(DecodedKernel {
        nregs: d.regs.len() as u32,
        shared_size,
        nstmts: kernel.body.len() as u32,
        param_names: kernel.params.iter().map(|p| p.name.clone()).collect(),
        uops,
        sb_end,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ptx::parser::parse_kernel;

    const K: &str = r#"
.visible .entry d(.param .u64 out, .param .u32 n){
.reg .b32 %r<6>; .reg .b64 %rd<6>; .reg .pred %p<2>;
ld.param.u64 %rd1, [out];
ld.param.u32 %r5, [n];
cvta.to.global.u64 %rd2, %rd1;
mov.u32 %r4, %tid.x;
setp.ge.s32 %p1, %r4, %r5;
@%p1 bra $EXIT;
mul.wide.s32 %rd3, %r4, 4;
add.s64 %rd4, %rd2, %rd3;
st.global.b32 [%rd4], %r4;
$EXIT: ret;
}
"#;

    #[test]
    fn labels_erase_and_targets_resolve() {
        let k = parse_kernel(K).unwrap();
        let dk = decode(&k).unwrap();
        // 11 body statements, one of which is the `$EXIT` label
        assert_eq!(dk.uops.len(), 10);
        // the guarded bra is uop 5 and must target the final ret (uop 9);
        // its statement position restarts at the `$EXIT` label (stmt 9)
        let Uop::Bra {
            target,
            target_stmt,
        } = &dk.uops[5].op
        else {
            panic!("uop 5 is {:?}", dk.uops[5].op)
        };
        assert_eq!((*target, *target_stmt), (9, 9));
        assert_eq!(dk.nstmts, 11);
        // the guard predicate is pre-interned, non-negated
        let (gslot, negated) = dk.uops[5].guard.expect("bra is guarded");
        assert!(!negated);
        // the setp producing it writes the same slot
        let Uop::SetpI { dst, .. } = &dk.uops[4].op else { panic!() };
        assert_eq!(*dst, gslot);
        // the side table points past the label: the ret is body stmt 10
        assert_eq!(dk.uops[9].stmt, 10);
        assert!(matches!(dk.uops[9].op, Uop::Ret));
        assert_eq!(dk.param_names, vec!["out", "n"]);
    }

    #[test]
    fn param_loads_carry_indices_and_masks() {
        let k = parse_kernel(K).unwrap();
        let dk = decode(&k).unwrap();
        let Uop::LdParam { index, mask, .. } = &dk.uops[0].op else {
            panic!()
        };
        assert_eq!((*index, *mask), (0, u64::MAX));
        let Uop::LdParam { index, mask, .. } = &dk.uops[1].op else {
            panic!()
        };
        assert_eq!((*index, *mask), (1, 0xFFFF_FFFF));
    }

    #[test]
    fn superblock_table_splits_at_control_ops_only() {
        let k = parse_kernel(K).unwrap();
        let dk = decode(&k).unwrap();
        // uop 5 is the guarded bra, uop 9 the ret; runs are [0,5) and
        // [6,9), and every interior index points at its run's suffix end
        assert_eq!(dk.sb_end.len(), dk.uops.len());
        for i in 0..5 {
            assert_eq!(dk.sb_end[i], 5, "prefix run at uop {i}");
        }
        assert_eq!(dk.sb_end[5], 5, "control op has an empty run");
        for i in 6..9 {
            assert_eq!(dk.sb_end[i], 9, "body run at uop {i}");
        }
        assert_eq!(dk.sb_end[9], 9, "ret has an empty run");
    }

    #[test]
    fn codec_roundtrips_and_rejects_a_tampered_superblock_table() {
        let k = parse_kernel(K).unwrap();
        let dk = decode(&k).unwrap();
        let bytes = dk.to_bytes();
        let back = DecodedKernel::from_bytes(&bytes).expect("roundtrip");
        assert_eq!(back.sb_end, dk.sb_end);
        assert_eq!(back.uops.len(), dk.uops.len());
        // the table is the trailing section: flipping its last entry must
        // fail the recomputation check, not load a bogus fast path
        let mut bad = bytes.clone();
        let n = bad.len();
        bad[n - 4] ^= 1;
        assert!(DecodedKernel::from_bytes(&bad).is_none());
    }

    #[test]
    fn unknown_label_is_an_eager_decode_error() {
        let k = parse_kernel(
            r#"
.visible .entry bad(.param .u64 out){
.reg .b32 %r<4>; .reg .pred %p<2>;
mov.u32 %r1, 0;
setp.eq.s32 %p1, %r1, 1;
@%p1 bra $NOWHERE;
ret;
}
"#,
        )
        .unwrap();
        assert!(matches!(decode(&k), Err(SimError::UnknownLabel(l)) if l == "$NOWHERE"));
    }

    #[test]
    fn unknown_shared_var_is_an_unknown_var_error() {
        let k = parse_kernel(
            r#"
.visible .entry sv(.param .u64 out){
.reg .b32 %r<4>; .reg .b64 %rd<4>;
mov.u64 %rd1, ghost;
ret;
}
"#,
        )
        .unwrap();
        assert!(matches!(decode(&k), Err(SimError::UnknownVar(v)) if v == "ghost"));
    }
}
