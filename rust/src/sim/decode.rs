//! One-time lowering of a [`Kernel`] into a flat micro-op form.
//!
//! The reference interpreter pays per *executed* instruction for work that
//! only depends on the *static* kernel: interning register names
//! (`regs.intern(r)` hashes the register string on every operand read),
//! resolving branch labels through a `HashMap`, looking up parameter and
//! shared-variable names, and recomputing width masks. `decode` does all
//! of that exactly once and produces a [`DecodedKernel`]:
//!
//! * registers are pre-interned to dense **slot indices** (the same
//!   [`RegInterner`] numbering the reference engine uses);
//! * labels are erased and branch targets resolved to **micro-op
//!   indices**;
//! * shared-variable bases are baked as immediate addresses, parameter
//!   reads carry the parameter's **index** into `SimConfig::params`
//!   (values stay launch-time, so the decoded form is reusable across
//!   workloads and content-addressed by the kernel fingerprint alone);
//! * integer immediates are pre-masked with the width mask of the reading
//!   site, and every mask/width an op needs is stored in the op;
//! * a `stmt` side table maps each micro-op back to its kernel-body
//!   statement index, so [`super::WarpEvent`] traces — and through them
//!   the perf model — are unchanged.
//!
//! Decoding is *eager* about static errors: an unknown branch target,
//! parameter or shared variable fails `decode` even if the instruction
//! would never execute (the reference engine only errors when the
//! offending instruction is reached). The arithmetic itself is not
//! reinterpreted here — the executor reuses the reference engine's helper
//! functions, so both engines compute every value with the same code.

use super::machine::{int_bvop, shared_layout, width_mask};
use super::SimError;
use crate::emu::env::RegInterner;
use crate::ptx::ast::*;
use crate::sym::term::{BvOp, CmpKind};

/// A decoded operand: everything a read needs, with names resolved away.
#[derive(Debug, Clone, Copy)]
pub enum Dop {
    /// Pre-interned register slot; masked with the site's width mask at
    /// read time (and checked against the written bitmap for the
    /// uninitialized-read counter).
    Slot(u32),
    /// Immediate, pre-masked at decode time exactly as the reference
    /// engine masks it at read time (float immediates are raw bits).
    Imm(u64),
    /// Special register; evaluated per lane, masked at read time.
    Special(Special),
}

/// `[base+offset]` with a decoded base.
#[derive(Debug, Clone, Copy)]
pub struct Daddr {
    pub base: Dop,
    pub offset: u64,
}

/// One micro-op. Field names mirror the AST op they were lowered from;
/// `w`/`mask` fields are the pre-resolved operand widths/masks.
#[derive(Debug, Clone)]
pub enum Uop {
    /// `bra` with the target resolved to a micro-op index.
    Bra { target: u32 },
    /// `ret` / `exit`.
    Ret,
    /// `bar.sync` — warps run serialized, still a no-op.
    BarSync,
    Shfl {
        mode: ShflMode,
        dst: u32,
        pred_out: Option<u32>,
        src: Dop,
        b: Dop,
        c: Dop,
        mask: Dop,
    },
    Activemask { dst: u32 },
    /// `ld.param`: the parameter index into `SimConfig::params` plus the
    /// result mask (`width_mask(ty.bits())`).
    LdParam { dst: u32, index: u32, mask: u64 },
    /// Non-param load; `bytes = ty.bytes()`.
    Ld {
        space: Space,
        nc: bool,
        bytes: u32,
        dst: u32,
        addr: Daddr,
    },
    /// Store; `src` is read with `smask` (`width_mask(ty.bits().max(8))`).
    St {
        space: Space,
        bytes: u32,
        smask: u64,
        src: Dop,
        addr: Daddr,
    },
    Mov { dst: u32, src: Dop, mask: u64 },
    Cvta { dst: u32, src: Dop },
    /// Generic integer binary op on the shared `eval_bin` path.
    IntBin {
        op: BvOp,
        w: u32,
        mask: u64,
        dst: u32,
        a: Dop,
        b: Dop,
    },
    MulWide {
        signed: bool,
        w: u32,
        dst: u32,
        a: Dop,
        b: Dop,
    },
    MulHi {
        signed: bool,
        w: u32,
        dst: u32,
        a: Dop,
        b: Dop,
    },
    Mad {
        wide: bool,
        signed: bool,
        w: u32,
        dst: u32,
        a: Dop,
        b: Dop,
        c: Dop,
    },
    Not { w: u32, dst: u32, a: Dop },
    Neg { w: u32, dst: u32, a: Dop },
    FltBin {
        op: FltBinOp,
        wide: bool,
        dst: u32,
        a: Dop,
        b: Dop,
    },
    Fma {
        wide: bool,
        dst: u32,
        a: Dop,
        b: Dop,
        c: Dop,
    },
    FltUn {
        op: FltUnOp,
        wide: bool,
        dst: u32,
        a: Dop,
    },
    /// Float compare (operands widened to f64 for F32, as the reference
    /// engine does).
    SetpF {
        cmp: CmpOp,
        wide: bool,
        dst: u32,
        a: Dop,
        b: Dop,
    },
    /// Integer compare with the signedness pre-resolved to a [`CmpKind`].
    SetpI {
        kind: CmpKind,
        w: u32,
        dst: u32,
        a: Dop,
        b: Dop,
    },
    Selp {
        w: u32,
        dst: u32,
        a: Dop,
        b: Dop,
        p: Dop,
    },
    Cvt {
        dty: Type,
        sty: Type,
        dst: u32,
        src: Dop,
    },
}

/// One decoded instruction: micro-op + guard + origin statement.
#[derive(Debug, Clone)]
pub struct UopEntry {
    /// Kernel-body statement index this micro-op was lowered from (the
    /// trace/perf-model side table).
    pub stmt: u32,
    /// Guard predicate as `(slot, negated)`.
    pub guard: Option<(u32, bool)>,
    pub op: Uop,
}

/// A kernel lowered to flat micro-ops; immutable and shareable across
/// threads and workloads (pipeline artifact keyed by the kernel
/// fingerprint).
#[derive(Debug, Clone)]
pub struct DecodedKernel {
    /// Register-file slots per lane (the [`RegInterner`] universe).
    pub nregs: u32,
    /// Per-block shared-memory window size in bytes.
    pub shared_size: u64,
    /// Parameter names in declaration order, for launch-time
    /// missing-value errors.
    pub param_names: Vec<String>,
    pub uops: Vec<UopEntry>,
}

impl DecodedKernel {
    /// Micro-ops per kernel-body *instruction* executed once (diagnostic).
    pub fn len(&self) -> usize {
        self.uops.len()
    }

    pub fn is_empty(&self) -> bool {
        self.uops.is_empty()
    }
}

struct Decoder<'a> {
    kernel: &'a Kernel,
    regs: RegInterner,
    shared_bases: std::collections::HashMap<&'a str, u64>,
}

impl<'a> Decoder<'a> {
    fn slot(&mut self, r: &Reg) -> u32 {
        self.regs.intern(r)
    }

    /// Decode an operand read at `width` bits, replicating the reference
    /// engine's `read_operand` masking: integer immediates are masked,
    /// float immediates are raw bits, shared bases are unmasked
    /// addresses, registers and specials are masked at read time.
    fn operand(&mut self, o: &Operand, width: u32) -> Result<Dop, SimError> {
        let m = width_mask(width);
        Ok(match o {
            Operand::Reg(r) => Dop::Slot(self.slot(r)),
            Operand::ImmInt(v) => Dop::Imm((*v as u64) & m),
            Operand::ImmF32(b) => Dop::Imm(*b as u64),
            Operand::ImmF64(b) => Dop::Imm(*b),
            Operand::Special(sp) => Dop::Special(*sp),
            Operand::Var(v) => Dop::Imm(
                self.shared_bases
                    .get(v.as_str())
                    .copied()
                    .ok_or_else(|| SimError::UnknownVar(v.clone()))?,
            ),
        })
    }

    /// Address bases are read at 64 bits.
    fn address(&mut self, a: &Address) -> Result<Daddr, SimError> {
        Ok(Daddr {
            base: self.operand(&a.base, 64)?,
            offset: a.offset as u64,
        })
    }

    fn param_index(&self, addr: &Address) -> Result<u32, SimError> {
        let name = match &addr.base {
            Operand::Var(n) => n.as_str(),
            _ => "?",
        };
        self.kernel
            .params
            .iter()
            .position(|p| p.name == name)
            .map(|i| i as u32)
            .ok_or_else(|| SimError::UnknownParam(name.to_string()))
        // scalar param: the value itself; offset addressing into
        // multi-word params is not needed for our kernels
    }

    fn op(
        &mut self,
        op: &Op,
        branch_target: impl Fn(&str) -> Option<u32>,
    ) -> Result<Uop, SimError> {
        Ok(match op {
            Op::Bra { target, .. } => Uop::Bra {
                target: branch_target(target)
                    .ok_or_else(|| SimError::UnknownLabel(target.clone()))?,
            },
            Op::Ret | Op::Exit => Uop::Ret,
            Op::BarSync { .. } => Uop::BarSync,
            Op::Shfl { mode, dst, pred_out, src, b, c, mask } => Uop::Shfl {
                mode: *mode,
                dst: self.slot(dst),
                pred_out: pred_out.as_ref().map(|p| self.slot(p)),
                src: self.operand(src, 32)?,
                b: self.operand(b, 32)?,
                c: self.operand(c, 32)?,
                mask: self.operand(mask, 32)?,
            },
            Op::Activemask { dst } => Uop::Activemask {
                dst: self.slot(dst),
            },
            Op::Ld { space, nc, ty, dst, addr } => {
                if *space == Space::Param {
                    Uop::LdParam {
                        dst: self.slot(dst),
                        index: self.param_index(addr)?,
                        mask: width_mask(ty.bits()),
                    }
                } else {
                    Uop::Ld {
                        space: *space,
                        nc: *nc,
                        bytes: ty.bytes() as u32,
                        dst: self.slot(dst),
                        addr: self.address(addr)?,
                    }
                }
            }
            Op::St { space, ty, addr, src } => Uop::St {
                space: *space,
                bytes: ty.bytes() as u32,
                smask: width_mask(ty.bits().max(8)),
                src: self.operand(src, ty.bits().max(8))?,
                addr: self.address(addr)?,
            },
            Op::Mov { ty, dst, src } => Uop::Mov {
                dst: self.slot(dst),
                src: self.operand(src, ty.bits().max(8))?,
                mask: width_mask(ty.bits().max(8)),
            },
            Op::Cvta { dst, src, .. } => Uop::Cvta {
                dst: self.slot(dst),
                src: self.operand(src, 64)?,
            },
            Op::IntBin { op: bop, ty, dst, a, b } => {
                let w = ty.bits().max(1);
                let signed = ty.is_signed();
                let (dst, a, b) =
                    (self.slot(dst), self.operand(a, w)?, self.operand(b, w)?);
                match bop {
                    IntBinOp::MulWide => Uop::MulWide { signed, w, dst, a, b },
                    IntBinOp::MulHi => Uop::MulHi { signed, w, dst, a, b },
                    _ => Uop::IntBin {
                        op: int_bvop(*bop, signed),
                        w,
                        mask: width_mask(w),
                        dst,
                        a,
                        b,
                    },
                }
            }
            Op::Mad { wide, ty, dst, a, b, c } => {
                let w = ty.bits();
                let cw = if *wide { w * 2 } else { w };
                Uop::Mad {
                    wide: *wide,
                    signed: ty.is_signed(),
                    w,
                    dst: self.slot(dst),
                    a: self.operand(a, w)?,
                    b: self.operand(b, w)?,
                    c: self.operand(c, cw)?,
                }
            }
            Op::Not { ty, dst, a } => {
                let w = ty.bits().max(1);
                Uop::Not {
                    w,
                    dst: self.slot(dst),
                    a: self.operand(a, w)?,
                }
            }
            Op::Neg { ty, dst, a } => {
                let w = ty.bits();
                Uop::Neg {
                    w,
                    dst: self.slot(dst),
                    a: self.operand(a, w)?,
                }
            }
            Op::FltBin { op: fop, ty, dst, a, b } => {
                let w = ty.bits();
                Uop::FltBin {
                    op: *fop,
                    wide: *ty != Type::F32,
                    dst: self.slot(dst),
                    a: self.operand(a, w)?,
                    b: self.operand(b, w)?,
                }
            }
            Op::Fma { ty, dst, a, b, c } => {
                let w = ty.bits();
                Uop::Fma {
                    wide: *ty != Type::F32,
                    dst: self.slot(dst),
                    a: self.operand(a, w)?,
                    b: self.operand(b, w)?,
                    c: self.operand(c, w)?,
                }
            }
            Op::FltUn { op: fop, ty, dst, a } => Uop::FltUn {
                op: *fop,
                wide: *ty != Type::F32,
                dst: self.slot(dst),
                a: self.operand(a, ty.bits())?,
            },
            Op::Setp { cmp, ty, dst, a, b } => {
                let w = ty.bits();
                let (dst, a, b) =
                    (self.slot(dst), self.operand(a, w)?, self.operand(b, w)?);
                if ty.is_float() {
                    Uop::SetpF {
                        cmp: *cmp,
                        wide: *ty != Type::F32,
                        dst,
                        a,
                        b,
                    }
                } else {
                    let signed =
                        !matches!(ty, Type::U8 | Type::U16 | Type::U32 | Type::U64);
                    Uop::SetpI {
                        kind: super::machine::cmp_kind(*cmp, signed),
                        w,
                        dst,
                        a,
                        b,
                    }
                }
            }
            Op::Selp { ty, dst, a, b, p } => {
                let w = ty.bits();
                Uop::Selp {
                    w,
                    dst: self.slot(dst),
                    a: self.operand(a, w)?,
                    b: self.operand(b, w)?,
                    p: self.operand(p, 1)?,
                }
            }
            Op::Cvt { dty, sty, dst, src } => Uop::Cvt {
                dty: *dty,
                sty: *sty,
                dst: self.slot(dst),
                src: self.operand(src, sty.bits())?,
            },
        })
    }
}

/// Lower a kernel into its flat micro-op form.
pub fn decode(kernel: &Kernel) -> Result<DecodedKernel, SimError> {
    // Same slot numbering as the reference engine (it pre-interns the
    // whole kernel too), so register files are layout-compatible.
    let regs = RegInterner::from_kernel(kernel);
    let (shared_bases, shared_size) = shared_layout(kernel);

    // Statement index → micro-op index of the first instruction at or
    // after it (labels lower to nothing; a lane stepping past a label in
    // the reference engine lands on exactly this instruction).
    let mut stmt_to_uop = Vec::with_capacity(kernel.body.len() + 1);
    let mut n = 0u32;
    for st in &kernel.body {
        stmt_to_uop.push(n);
        if matches!(st, Statement::Instr { .. }) {
            n += 1;
        }
    }
    stmt_to_uop.push(n); // branch past the end = retire

    let mut labels: std::collections::HashMap<&str, u32> = std::collections::HashMap::new();
    for (i, st) in kernel.body.iter().enumerate() {
        if let Statement::Label(l) = st {
            labels.insert(l.as_str(), stmt_to_uop[i]);
        }
    }

    let mut d = Decoder {
        kernel,
        regs,
        shared_bases,
    };
    let mut uops = Vec::with_capacity(n as usize);
    for (i, st) in kernel.body.iter().enumerate() {
        let Statement::Instr { guard, op } = st else {
            continue;
        };
        let guard = guard.as_ref().map(|g| (d.regs.intern(&g.reg), g.negated));
        let op = d.op(op, |l| labels.get(l).copied())?;
        uops.push(UopEntry {
            stmt: i as u32,
            guard,
            op,
        });
    }

    Ok(DecodedKernel {
        nregs: d.regs.len() as u32,
        shared_size,
        param_names: kernel.params.iter().map(|p| p.name.clone()).collect(),
        uops,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ptx::parser::parse_kernel;

    const K: &str = r#"
.visible .entry d(.param .u64 out, .param .u32 n){
.reg .b32 %r<6>; .reg .b64 %rd<6>; .reg .pred %p<2>;
ld.param.u64 %rd1, [out];
ld.param.u32 %r5, [n];
cvta.to.global.u64 %rd2, %rd1;
mov.u32 %r4, %tid.x;
setp.ge.s32 %p1, %r4, %r5;
@%p1 bra $EXIT;
mul.wide.s32 %rd3, %r4, 4;
add.s64 %rd4, %rd2, %rd3;
st.global.b32 [%rd4], %r4;
$EXIT: ret;
}
"#;

    #[test]
    fn labels_erase_and_targets_resolve() {
        let k = parse_kernel(K).unwrap();
        let dk = decode(&k).unwrap();
        // 11 body statements, one of which is the `$EXIT` label
        assert_eq!(dk.uops.len(), 10);
        // the guarded bra is uop 5 and must target the final ret (uop 9)
        let Uop::Bra { target } = &dk.uops[5].op else {
            panic!("uop 5 is {:?}", dk.uops[5].op)
        };
        assert_eq!(*target, 9);
        // the guard predicate is pre-interned, non-negated
        let (gslot, negated) = dk.uops[5].guard.expect("bra is guarded");
        assert!(!negated);
        // the setp producing it writes the same slot
        let Uop::SetpI { dst, .. } = &dk.uops[4].op else { panic!() };
        assert_eq!(*dst, gslot);
        // the side table points past the label: the ret is body stmt 10
        assert_eq!(dk.uops[9].stmt, 10);
        assert!(matches!(dk.uops[9].op, Uop::Ret));
        assert_eq!(dk.param_names, vec!["out", "n"]);
    }

    #[test]
    fn param_loads_carry_indices_and_masks() {
        let k = parse_kernel(K).unwrap();
        let dk = decode(&k).unwrap();
        let Uop::LdParam { index, mask, .. } = &dk.uops[0].op else {
            panic!()
        };
        assert_eq!((*index, *mask), (0, u64::MAX));
        let Uop::LdParam { index, mask, .. } = &dk.uops[1].op else {
            panic!()
        };
        assert_eq!((*index, *mask), (1, 0xFFFF_FFFF));
    }

    #[test]
    fn unknown_label_is_an_eager_decode_error() {
        let k = parse_kernel(
            r#"
.visible .entry bad(.param .u64 out){
.reg .b32 %r<4>; .reg .pred %p<2>;
mov.u32 %r1, 0;
setp.eq.s32 %p1, %r1, 1;
@%p1 bra $NOWHERE;
ret;
}
"#,
        )
        .unwrap();
        assert!(matches!(decode(&k), Err(SimError::UnknownLabel(l)) if l == "$NOWHERE"));
    }

    #[test]
    fn unknown_shared_var_is_an_unknown_var_error() {
        let k = parse_kernel(
            r#"
.visible .entry sv(.param .u64 out){
.reg .b32 %r<4>; .reg .b64 %rd<4>;
mov.u64 %rd1, ghost;
ret;
}
"#,
        )
        .unwrap();
        assert!(matches!(decode(&k), Err(SimError::UnknownVar(v)) if v == "ghost"));
    }
}
