//! Content-addressed artifacts and the thread-safe cache that stores them.
//!
//! Every artifact is keyed by the [`ContentHash`] of the kernel it was
//! derived from (plus the options that shaped it), so the three synthesis
//! variants of one benchmark share a single emulation and identical
//! kernels across suite runs are computed once. The two workload-dependent
//! stages at the tail (`Validated`, `Scored`) add a
//! [`WorkloadFingerprint`] to the key — sizes, RNG seed and the
//! input-generation spec — so repeated simulation of the same (kernel,
//! workload) pair is also served from cache. Slots are
//! `Arc<OnceLock<…>>`: the map mutex is held only for the entry lookup,
//! concurrent requests for the *same* key block on the slot (exactly one
//! computes), and requests for different keys proceed in parallel.

use crate::emu::{EmuError, EmulationResult};
use crate::ptx::ast::Kernel;
use crate::ptx::printer::ContentHash;
use crate::shuffle::{DetectOpts, Detection, ElimOpts, ElimReport, Variant};
use crate::sim::SimError;
use crate::suite::{Workload, WorkloadFingerprint};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Duration;

/// Stage 1 artifact: a kernel admitted into the pipeline, with its
/// content address.
#[derive(Debug, Clone)]
pub struct Parsed {
    pub kernel: Arc<Kernel>,
    pub hash: ContentHash,
}

/// Workload artifact: one benchmark's simulator launch + deterministic
/// input data + CPU reference, keyed by its [`WorkloadFingerprint`].
/// Generated once per (spec, sizes, seed) and shared by the baseline and
/// every synthesis variant.
#[derive(Debug)]
pub struct WorkloadArt {
    pub workload: Workload,
    pub fingerprint: WorkloadFingerprint,
}

/// Stage 2 artifact: one symbolic emulation of a kernel.
#[derive(Debug)]
pub struct Emulated {
    pub kernel: Arc<Kernel>,
    pub hash: ContentHash,
    pub result: EmulationResult,
    /// Wall time of the original (cache-missing) emulation.
    pub elapsed: Duration,
}

/// Stage 3 artifact: shuffle detection over an emulation.
///
/// The wall times are properties of the *original* computation and
/// travel with the artifact: an artifact served from the on-disk store
/// reports the analysis cost measured when it was first built (possibly
/// in another process), not this session's near-zero lookup time — the
/// `--stats` stage table is the per-session view (0 runs on a warm hit).
#[derive(Debug)]
pub struct Detected {
    pub detection: Detection,
    /// Wall time of the detection pass alone.
    pub elapsed: Duration,
    /// Wall time of the emulation this detection consumed.
    pub emu_elapsed: Duration,
}

impl Detected {
    /// The paper's Table 2 "Analysis" quantity: emulate + detect — the
    /// original computation's cost, historical on cache hits (see the
    /// struct docs).
    pub fn analysis_time(&self) -> Duration {
        self.emu_elapsed + self.elapsed
    }
}

/// Stage 4 artifact: a synthesized kernel variant, after the
/// phase-liveness elimination pass has run over it (the pass is an
/// identity transform when disabled or when nothing is provable).
#[derive(Debug)]
pub struct Synthesized {
    pub kernel: Arc<Kernel>,
    pub variant: Variant,
    /// Content address of the *source* kernel the variant was derived from.
    pub source: ContentHash,
    /// Content address of the synthesized kernel itself (keys the
    /// downstream `Validated`/`Scored` artifacts).
    pub hash: ContentHash,
    /// What the dead-store / barrier elimination pass did (or why it
    /// declined to act).
    pub elim: ElimReport,
}

/// Which artifact family a cache event belongs to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArtifactKind {
    Workload,
    Decoded,
    Emulated,
    Detected,
    Synthesized,
    Validated,
    Scored,
}

impl ArtifactKind {
    /// The family's instant-event name in the trace taxonomy
    /// (`artifact.<family>`).
    pub fn span_name(self) -> &'static str {
        match self {
            ArtifactKind::Workload => "artifact.workload",
            ArtifactKind::Decoded => "artifact.decoded",
            ArtifactKind::Emulated => "artifact.emulated",
            ArtifactKind::Detected => "artifact.detected",
            ArtifactKind::Synthesized => "artifact.synthesized",
            ArtifactKind::Validated => "artifact.validated",
            ArtifactKind::Scored => "artifact.scored",
        }
    }
}

/// How a cache lookup was served.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CacheEvent {
    /// Served from the in-memory slot.
    Hit,
    /// Recovered from the on-disk store (no recompute).
    DiskHit,
    /// Computed fresh.
    Miss,
}

impl CacheEvent {
    /// Stable provenance label used in trace event args.
    pub fn name(self) -> &'static str {
        match self {
            CacheEvent::Hit => "hit",
            CacheEvent::DiskHit => "disk_hit",
            CacheEvent::Miss => "miss",
        }
    }
}

/// Monotonic hit/disk-hit/miss counters, one triple per artifact family.
#[derive(Debug, Default)]
pub struct CacheCounters {
    workload_hits: AtomicU64,
    workload_misses: AtomicU64,
    decode_hits: AtomicU64,
    decode_disk_hits: AtomicU64,
    decode_misses: AtomicU64,
    emulate_hits: AtomicU64,
    emulate_disk_hits: AtomicU64,
    emulate_misses: AtomicU64,
    detect_hits: AtomicU64,
    detect_disk_hits: AtomicU64,
    detect_misses: AtomicU64,
    synth_hits: AtomicU64,
    synth_disk_hits: AtomicU64,
    synth_misses: AtomicU64,
    validate_hits: AtomicU64,
    validate_disk_hits: AtomicU64,
    validate_misses: AtomicU64,
    score_hits: AtomicU64,
    score_disk_hits: AtomicU64,
    score_misses: AtomicU64,
}

impl CacheCounters {
    pub fn record(&self, kind: ArtifactKind, event: CacheEvent) {
        use ArtifactKind::*;
        use CacheEvent::*;
        let cell = match (kind, event) {
            (Workload, Hit) => &self.workload_hits,
            // workloads are never disk-persisted (cheap regeneration)
            (Workload, DiskHit | Miss) => &self.workload_misses,
            (Decoded, Hit) => &self.decode_hits,
            (Decoded, DiskHit) => &self.decode_disk_hits,
            (Decoded, Miss) => &self.decode_misses,
            (Emulated, Hit) => &self.emulate_hits,
            (Emulated, DiskHit) => &self.emulate_disk_hits,
            (Emulated, Miss) => &self.emulate_misses,
            (Detected, Hit) => &self.detect_hits,
            (Detected, DiskHit) => &self.detect_disk_hits,
            (Detected, Miss) => &self.detect_misses,
            (Synthesized, Hit) => &self.synth_hits,
            (Synthesized, DiskHit) => &self.synth_disk_hits,
            (Synthesized, Miss) => &self.synth_misses,
            (Validated, Hit) => &self.validate_hits,
            (Validated, DiskHit) => &self.validate_disk_hits,
            (Validated, Miss) => &self.validate_misses,
            (Scored, Hit) => &self.score_hits,
            (Scored, DiskHit) => &self.score_disk_hits,
            (Scored, Miss) => &self.score_misses,
        };
        cell.fetch_add(1, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> CacheSnapshot {
        CacheSnapshot {
            workload_hits: self.workload_hits.load(Ordering::Relaxed),
            workload_misses: self.workload_misses.load(Ordering::Relaxed),
            decode_hits: self.decode_hits.load(Ordering::Relaxed),
            decode_disk_hits: self.decode_disk_hits.load(Ordering::Relaxed),
            decode_misses: self.decode_misses.load(Ordering::Relaxed),
            emulate_hits: self.emulate_hits.load(Ordering::Relaxed),
            emulate_disk_hits: self.emulate_disk_hits.load(Ordering::Relaxed),
            emulate_misses: self.emulate_misses.load(Ordering::Relaxed),
            detect_hits: self.detect_hits.load(Ordering::Relaxed),
            detect_disk_hits: self.detect_disk_hits.load(Ordering::Relaxed),
            detect_misses: self.detect_misses.load(Ordering::Relaxed),
            synth_hits: self.synth_hits.load(Ordering::Relaxed),
            synth_disk_hits: self.synth_disk_hits.load(Ordering::Relaxed),
            synth_misses: self.synth_misses.load(Ordering::Relaxed),
            validate_hits: self.validate_hits.load(Ordering::Relaxed),
            validate_disk_hits: self.validate_disk_hits.load(Ordering::Relaxed),
            validate_misses: self.validate_misses.load(Ordering::Relaxed),
            score_hits: self.score_hits.load(Ordering::Relaxed),
            score_disk_hits: self.score_disk_hits.load(Ordering::Relaxed),
            score_misses: self.score_misses.load(Ordering::Relaxed),
        }
    }
}

/// Point-in-time copy of the cache counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheSnapshot {
    pub workload_hits: u64,
    pub workload_misses: u64,
    pub decode_hits: u64,
    pub decode_disk_hits: u64,
    pub decode_misses: u64,
    pub emulate_hits: u64,
    pub emulate_disk_hits: u64,
    pub emulate_misses: u64,
    pub detect_hits: u64,
    pub detect_disk_hits: u64,
    pub detect_misses: u64,
    pub synth_hits: u64,
    pub synth_disk_hits: u64,
    pub synth_misses: u64,
    pub validate_hits: u64,
    pub validate_disk_hits: u64,
    pub validate_misses: u64,
    pub score_hits: u64,
    pub score_disk_hits: u64,
    pub score_misses: u64,
}

impl CacheSnapshot {
    /// Field-wise sum of another snapshot into this one (serve mode folds
    /// its tight + wide pipelines into one report).
    pub fn absorb(&mut self, o: &CacheSnapshot) {
        self.workload_hits += o.workload_hits;
        self.workload_misses += o.workload_misses;
        self.decode_hits += o.decode_hits;
        self.decode_disk_hits += o.decode_disk_hits;
        self.decode_misses += o.decode_misses;
        self.emulate_hits += o.emulate_hits;
        self.emulate_disk_hits += o.emulate_disk_hits;
        self.emulate_misses += o.emulate_misses;
        self.detect_hits += o.detect_hits;
        self.detect_disk_hits += o.detect_disk_hits;
        self.detect_misses += o.detect_misses;
        self.synth_hits += o.synth_hits;
        self.synth_disk_hits += o.synth_disk_hits;
        self.synth_misses += o.synth_misses;
        self.validate_hits += o.validate_hits;
        self.validate_disk_hits += o.validate_disk_hits;
        self.validate_misses += o.validate_misses;
        self.score_hits += o.score_hits;
        self.score_disk_hits += o.score_disk_hits;
        self.score_misses += o.score_misses;
    }

    /// In-memory hits across every family.
    pub fn hits(&self) -> u64 {
        self.workload_hits
            + self.decode_hits
            + self.emulate_hits
            + self.detect_hits
            + self.synth_hits
            + self.validate_hits
            + self.score_hits
    }

    /// Artifacts recovered from the on-disk store (no recompute).
    pub fn disk_hits(&self) -> u64 {
        self.decode_disk_hits
            + self.emulate_disk_hits
            + self.detect_disk_hits
            + self.synth_disk_hits
            + self.validate_disk_hits
            + self.score_disk_hits
    }

    /// Artifacts computed fresh.
    pub fn misses(&self) -> u64 {
        self.workload_misses
            + self.decode_misses
            + self.emulate_misses
            + self.detect_misses
            + self.synth_misses
            + self.validate_misses
            + self.score_misses
    }

    /// Fraction of lookups served without recompute (memory or disk).
    pub fn hit_rate(&self) -> f64 {
        let served = self.hits() + self.disk_hits();
        let total = served + self.misses();
        if total == 0 {
            0.0
        } else {
            served as f64 / total as f64
        }
    }
}

/// One cache slot: exactly one thread computes, everyone else blocks on
/// the `OnceLock` and clones the finished value (or the error).
pub type CacheSlot<T, E = EmuError> = Arc<OnceLock<Result<Arc<T>, E>>>;

type SlotMap<K, T, E = EmuError> = Mutex<HashMap<K, CacheSlot<T, E>>>;

/// Infallible slot (workload generation cannot fail).
pub type PlainSlot<T> = Arc<OnceLock<Arc<T>>>;
type PlainMap<K, T> = Mutex<HashMap<K, PlainSlot<T>>>;

/// Detection key: kernel + the full [`DetectOpts`] that shaped it.
pub type DetectKey = (ContentHash, DetectOpts);
/// Synthesis key: detection key + variant + the elimination options that
/// shaped the post-synthesis cleanup.
pub type SynthKey = (ContentHash, DetectOpts, Variant, ElimOpts);
/// Validation key: kernel version + workload + (for variants) the
/// baseline kernel whose output the bit-exactness verdict is against.
pub type ValidateKey = (ContentHash, WorkloadFingerprint, Option<ContentHash>);
/// Scoring key: kernel version + workload + architecture.
pub type ScoreKey = (ContentHash, WorkloadFingerprint, &'static str);

/// Thread-safe, content-addressed artifact store.
#[derive(Debug, Default)]
pub struct ArtifactCache {
    workloads: PlainMap<WorkloadFingerprint, WorkloadArt>,
    /// Decoded micro-op kernels, keyed by the kernel fingerprint alone
    /// (the decoded form is workload-independent); shared by every
    /// validation — and any future consumer — of one kernel version.
    decoded: SlotMap<ContentHash, crate::sim::DecodedKernel, SimError>,
    emulated: SlotMap<ContentHash, Emulated>,
    detected: SlotMap<DetectKey, Detected>,
    synthesized: SlotMap<SynthKey, Synthesized>,
    validated: SlotMap<ValidateKey, super::stages::Validated, SimError>,
    scored: PlainMap<ScoreKey, super::stages::Scored>,
    pub counters: CacheCounters,
}

impl ArtifactCache {
    pub fn workload_slot(&self, key: WorkloadFingerprint) -> PlainSlot<WorkloadArt> {
        self.workloads.lock().unwrap().entry(key).or_default().clone()
    }

    pub fn decode_slot(
        &self,
        key: ContentHash,
    ) -> CacheSlot<crate::sim::DecodedKernel, SimError> {
        self.decoded.lock().unwrap().entry(key).or_default().clone()
    }

    pub fn emu_slot(&self, key: ContentHash) -> CacheSlot<Emulated> {
        self.emulated.lock().unwrap().entry(key).or_default().clone()
    }

    pub fn detect_slot(&self, key: DetectKey) -> CacheSlot<Detected> {
        self.detected.lock().unwrap().entry(key).or_default().clone()
    }

    pub fn synth_slot(&self, key: SynthKey) -> CacheSlot<Synthesized> {
        self.synthesized
            .lock()
            .unwrap()
            .entry(key)
            .or_default()
            .clone()
    }

    pub fn validate_slot(&self, key: ValidateKey) -> CacheSlot<super::stages::Validated, SimError> {
        self.validated.lock().unwrap().entry(key).or_default().clone()
    }

    pub fn score_slot(&self, key: ScoreKey) -> PlainSlot<super::stages::Scored> {
        self.scored.lock().unwrap().entry(key).or_default().clone()
    }

    /// Number of emulation artifacts resident in the cache.
    pub fn emulated_len(&self) -> usize {
        self.emulated.lock().unwrap().len()
    }

    /// Number of validated (simulated) artifacts resident in the cache.
    pub fn validated_len(&self) -> usize {
        self.validated.lock().unwrap().len()
    }

    /// Number of decoded micro-op kernels resident in the cache.
    pub fn decoded_len(&self) -> usize {
        self.decoded.lock().unwrap().len()
    }
}
