//! Content-addressed artifacts and the thread-safe cache that stores them.
//!
//! Every artifact is keyed by the [`ContentHash`] of the kernel it was
//! derived from (plus the options that shaped it), so the three synthesis
//! variants of one benchmark share a single emulation and identical
//! kernels across suite runs are computed once. Slots are
//! `Arc<OnceLock<…>>`: the map mutex is held only for the entry lookup,
//! concurrent requests for the *same* key block on the slot (exactly one
//! computes), and requests for different keys proceed in parallel.

use crate::emu::{EmuError, EmulationResult};
use crate::ptx::ast::Kernel;
use crate::ptx::printer::ContentHash;
use crate::shuffle::{DetectOpts, Detection, Variant};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Duration;

/// Stage 1 artifact: a kernel admitted into the pipeline, with its
/// content address.
#[derive(Debug, Clone)]
pub struct Parsed {
    pub kernel: Arc<Kernel>,
    pub hash: ContentHash,
}

/// Stage 2 artifact: one symbolic emulation of a kernel.
#[derive(Debug)]
pub struct Emulated {
    pub kernel: Arc<Kernel>,
    pub hash: ContentHash,
    pub result: EmulationResult,
    /// Wall time of the original (cache-missing) emulation.
    pub elapsed: Duration,
}

/// Stage 3 artifact: shuffle detection over an emulation.
#[derive(Debug)]
pub struct Detected {
    pub detection: Detection,
    /// Wall time of the detection pass alone.
    pub elapsed: Duration,
    /// Wall time of the emulation this detection consumed.
    pub emu_elapsed: Duration,
}

impl Detected {
    /// The paper's Table 2 "Analysis" quantity: emulate + detect.
    pub fn analysis_time(&self) -> Duration {
        self.emu_elapsed + self.elapsed
    }
}

/// Stage 4 artifact: a synthesized kernel variant.
#[derive(Debug)]
pub struct Synthesized {
    pub kernel: Arc<Kernel>,
    pub variant: Variant,
    /// Content address of the *source* kernel the variant was derived from.
    pub source: ContentHash,
}

/// Which artifact family a cache event belongs to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArtifactKind {
    Emulated,
    Detected,
    Synthesized,
}

/// Monotonic hit/miss counters, one pair per artifact family.
#[derive(Debug, Default)]
pub struct CacheCounters {
    emulate_hits: AtomicU64,
    emulate_misses: AtomicU64,
    detect_hits: AtomicU64,
    detect_misses: AtomicU64,
    synth_hits: AtomicU64,
    synth_misses: AtomicU64,
}

impl CacheCounters {
    pub fn record(&self, kind: ArtifactKind, computed: bool) {
        let cell = match (kind, computed) {
            (ArtifactKind::Emulated, false) => &self.emulate_hits,
            (ArtifactKind::Emulated, true) => &self.emulate_misses,
            (ArtifactKind::Detected, false) => &self.detect_hits,
            (ArtifactKind::Detected, true) => &self.detect_misses,
            (ArtifactKind::Synthesized, false) => &self.synth_hits,
            (ArtifactKind::Synthesized, true) => &self.synth_misses,
        };
        cell.fetch_add(1, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> CacheSnapshot {
        CacheSnapshot {
            emulate_hits: self.emulate_hits.load(Ordering::Relaxed),
            emulate_misses: self.emulate_misses.load(Ordering::Relaxed),
            detect_hits: self.detect_hits.load(Ordering::Relaxed),
            detect_misses: self.detect_misses.load(Ordering::Relaxed),
            synth_hits: self.synth_hits.load(Ordering::Relaxed),
            synth_misses: self.synth_misses.load(Ordering::Relaxed),
        }
    }
}

/// Point-in-time copy of the cache counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheSnapshot {
    pub emulate_hits: u64,
    pub emulate_misses: u64,
    pub detect_hits: u64,
    pub detect_misses: u64,
    pub synth_hits: u64,
    pub synth_misses: u64,
}

impl CacheSnapshot {
    pub fn hits(&self) -> u64 {
        self.emulate_hits + self.detect_hits + self.synth_hits
    }

    pub fn misses(&self) -> u64 {
        self.emulate_misses + self.detect_misses + self.synth_misses
    }

    pub fn hit_rate(&self) -> f64 {
        let total = self.hits() + self.misses();
        if total == 0 {
            0.0
        } else {
            self.hits() as f64 / total as f64
        }
    }
}

/// One cache slot: exactly one thread computes, everyone else blocks on
/// the `OnceLock` and clones the finished value (or the error).
pub type CacheSlot<T> = Arc<OnceLock<Result<Arc<T>, EmuError>>>;

type SlotMap<K, T> = Mutex<HashMap<K, CacheSlot<T>>>;

/// Detection key: kernel + the full [`DetectOpts`] that shaped it.
pub type DetectKey = (ContentHash, DetectOpts);
/// Synthesis key: detection key + variant.
pub type SynthKey = (ContentHash, DetectOpts, Variant);

/// Thread-safe, content-addressed artifact store.
#[derive(Debug, Default)]
pub struct ArtifactCache {
    emulated: SlotMap<ContentHash, Emulated>,
    detected: SlotMap<DetectKey, Detected>,
    synthesized: SlotMap<SynthKey, Synthesized>,
    pub counters: CacheCounters,
}

impl ArtifactCache {
    pub fn emu_slot(&self, key: ContentHash) -> CacheSlot<Emulated> {
        self.emulated.lock().unwrap().entry(key).or_default().clone()
    }

    pub fn detect_slot(&self, key: DetectKey) -> CacheSlot<Detected> {
        self.detected.lock().unwrap().entry(key).or_default().clone()
    }

    pub fn synth_slot(&self, key: SynthKey) -> CacheSlot<Synthesized> {
        self.synthesized
            .lock()
            .unwrap()
            .entry(key)
            .or_default()
            .clone()
    }

    /// Number of emulation artifacts resident in the cache.
    pub fn emulated_len(&self) -> usize {
        self.emulated.lock().unwrap().len()
    }
}
