//! `serve` mode: a long-running analysis daemon on one persistent
//! [`Pipeline`] session.
//!
//! The paper's tool is invoked once per compile; production traffic (the
//! ACC-Saturator / JACC deployments ROADMAP item 2 describes) is a warm
//! fleet of daemons fed batches of kernels by many concurrent compiler
//! invocations. This module is that tier: JSON-lines requests over stdin
//! or a Unix socket (framing hand-rolled on [`crate::util::json`] — the
//! crate stays zero-dep), multiplexed onto persistent pipelines over one
//! shared [`DiskStore`].
//!
//! **Isolation.** One adversarial kernel must degrade to an error record
//! while the rest of the batch streams results:
//!
//! - every request runs under `catch_unwind`; a panic yields a `Panicked`
//!   record and the session *rebuilds* its pipelines (the old in-memory
//!   caches and interner may hold poisoned locks — the disk store, which
//!   is poison-tolerant by construction, carries the warm state across),
//! - analysis runs **tight-limits-first**: a cheap emulation budget
//!   catches step-limit blowups in milliseconds; only a request whose
//!   tight run errored or truncated flows is retried once on the
//!   wide-limits pipeline (`widened: true` in the response),
//! - a per-request **deadline** is checked cooperatively at kernel
//!   boundaries; past it the request fails with `Timeout` (a
//!   `deadline_ms` of 0 times out deterministically — the tests pin
//!   that),
//! - every failure is a typed record from the taxonomy
//!   `ParseError | EmuError | SimError | Timeout | Panicked | BadRequest`
//!   so callers can machine-route retries.
//!
//! ## Protocol
//!
//! One JSON object per line in, one per line out (`id` echoed verbatim):
//!
//! ```text
//! → {"id":1,"cmd":"asm","ptx":".visible .entry k(...){...}",
//!    "variant":"full","max_delta":31,"block":32,"elim":true,
//!    "deadline_ms":2000}
//! ← {"id":1,"ok":true,"widened":false,"cmd":"asm",
//!    "kernels":[{"name":"k","shuffles":2,"elim_deleted_stores":0,
//!    "elim_elided_barriers":0}],"ptx":"..."}
//!
//! → {"id":2,"cmd":"bench","bench":"vecadd"}
//! ← {"id":2,"ok":true,"cmd":"bench","bench":"vecadd","shuffles":2,
//!    "variants":[{"variant":"noload","valid":true}, ...]}
//!
//! → {"id":3,"cmd":"asm","ptx":"not ptx at all"}
//! ← {"id":3,"ok":false,"error":{"kind":"ParseError","message":"..."}}
//!
//! → {"cmd":"ping"}          ← {"id":null,"ok":true,"cmd":"pong"}
//! → {"cmd":"stats"}         ← serve + disk counters
//! → {"cmd":"shutdown"}      ← {"ok":true,"cmd":"shutdown"} and exit 0
//! ```

use crate::coordinator::queue::WorkQueue;
use crate::coordinator::{run_benchmark_on, PipelineConfig, PipelineError};
use crate::emu::{FlowEnd, Limits};
use crate::obs::{ArgVal, Histogram, Tracer};
use crate::pipeline::{metrics_snapshot, DiskStore, Pipeline};
use crate::ptx::{parse, print_module};
use crate::shuffle::{DetectOpts, ElimOpts, Variant};
use crate::util::Json;
use std::io::{BufRead, Write};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Tight first-pass emulation budget: two orders of magnitude under the
/// defaults — enough for every suite kernel, cheap enough that a blowup
/// kernel fails in milliseconds instead of pinning the daemon.
pub fn tight_limits() -> Limits {
    Limits {
        max_flows: 512,
        max_steps_per_flow: 20_000,
        max_total_steps: 500_000,
    }
}

/// Serve-session configuration.
#[derive(Debug, Clone, Copy)]
pub struct ServeOpts {
    /// First-pass emulation budget (see [`tight_limits`]).
    pub tight: Limits,
    /// Retry budget for requests the tight pass errored or truncated.
    pub wide: Limits,
    /// Default per-request deadline; `None` = no deadline. A request's
    /// own `deadline_ms` field overrides this.
    pub deadline_ms: Option<u64>,
    /// Honor the `__panic` test command (CLI `--test-faults`). Off in
    /// production: a panic can then only come from a real bug, but the
    /// isolation path stays testable end-to-end.
    pub allow_test_faults: bool,
    /// Worker threads per simulation (`Pipeline::with_sim_threads`).
    pub sim_threads: usize,
    /// Decoded-engine paths (`Pipeline::with_engine`).
    pub engine: (bool, bool),
    /// Worker threads for batch (stdin) serving: [`serve_pooled`] fans
    /// the request batch across this many per-worker sessions over the
    /// shared disk store. `1` keeps the serial path.
    pub serve_threads: usize,
    /// Span-sample every Nth request even without `"trace": true`
    /// (`0` = off). Sampled spans stay in the session ring for export;
    /// they never ride back on responses, so sampling cannot perturb
    /// the wire bytes.
    pub trace_sample: u64,
}

impl Default for ServeOpts {
    fn default() -> ServeOpts {
        ServeOpts {
            tight: tight_limits(),
            wide: Limits::default(),
            deadline_ms: None,
            allow_test_faults: false,
            sim_threads: 1,
            engine: (true, true),
            serve_threads: 1,
            trace_sample: 0,
        }
    }
}

/// Serve-loop counters (also exposed over the `stats` command).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ServeStats {
    pub requests: u64,
    pub ok: u64,
    pub errors: u64,
    /// Requests retried on the wide-limits pipeline.
    pub widened: u64,
    /// Requests that panicked (each one rebuilt the pipelines).
    pub panicked: u64,
}

impl ServeStats {
    /// Fold a worker session's counters into this one (the pooled and
    /// socket paths merge per-worker stats back into the root session).
    pub fn absorb(&mut self, o: &ServeStats) {
        self.requests += o.requests;
        self.ok += o.ok;
        self.errors += o.errors;
        self.widened += o.widened;
        self.panicked += o.panicked;
    }
}

/// Typed failure record — the `error.kind` strings of the protocol.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeErrorKind {
    ParseError,
    EmuError,
    SimError,
    Timeout,
    Panicked,
    BadRequest,
}

impl ServeErrorKind {
    pub fn name(&self) -> &'static str {
        match self {
            ServeErrorKind::ParseError => "ParseError",
            ServeErrorKind::EmuError => "EmuError",
            ServeErrorKind::SimError => "SimError",
            ServeErrorKind::Timeout => "Timeout",
            ServeErrorKind::Panicked => "Panicked",
            ServeErrorKind::BadRequest => "BadRequest",
        }
    }
}

#[derive(Debug, Clone)]
struct ServeError {
    kind: ServeErrorKind,
    message: String,
}

impl ServeError {
    fn new(kind: ServeErrorKind, message: impl Into<String>) -> ServeError {
        ServeError {
            kind,
            message: message.into(),
        }
    }
}

/// One long-lived serving session: a tight- and a wide-limits [`Pipeline`]
/// over one optional shared [`DiskStore`].
#[derive(Debug)]
pub struct ServeSession {
    opts: ServeOpts,
    store: Option<Arc<DiskStore>>,
    tight: Pipeline,
    wide: Pipeline,
    stats: ServeStats,
    /// Span recorder shared by both pipelines (and, when the caller wired
    /// it, the disk store). Disabled until a request asks for tracing via
    /// `"trace": true` — its request id becomes the trace id echoed back.
    tracer: Arc<Tracer>,
    /// End-to-end request latency (dispatch + retry), all commands.
    request_hist: Histogram,
}

impl ServeSession {
    pub fn new(opts: ServeOpts, store: Option<Arc<DiskStore>>) -> ServeSession {
        ServeSession::with_tracer(opts, store, Arc::new(Tracer::disabled()))
    }

    /// A session recording into an explicit shared tracer (the CLI hands
    /// the same handle to the [`DiskStore`], so `store.*` events land in
    /// per-request traces too).
    pub fn with_tracer(
        opts: ServeOpts,
        store: Option<Arc<DiskStore>>,
        tracer: Arc<Tracer>,
    ) -> ServeSession {
        // the wide retry pipeline resumes widened requests from the
        // frontier image the tight pass persisted at its budget trip —
        // flow zero is never re-emulated on the retry path
        let tight = build_pipeline(&opts, opts.tight, &store, &tracer);
        let wide =
            build_pipeline(&opts, opts.wide, &store, &tracer).with_resume_from(opts.tight);
        ServeSession {
            opts,
            store,
            tight,
            wide,
            stats: ServeStats::default(),
            tracer,
            request_hist: Histogram::new(),
        }
    }

    pub fn stats(&self) -> ServeStats {
        self.stats
    }

    /// The wide-limits pipeline (its counters cover the common path).
    pub fn pipeline(&self) -> &Pipeline {
        &self.wide
    }

    /// The session's span tracer.
    pub fn tracer(&self) -> &Arc<Tracer> {
        &self.tracer
    }

    /// Discard both pipelines after a panic: their in-memory caches and
    /// interner may hold poisoned locks mid-update. The shared disk store
    /// survives (its own locks are poison-tolerant), so warm artifacts
    /// carry across the rebuild.
    fn rebuild(&mut self) {
        self.tight = build_pipeline(&self.opts, self.opts.tight, &self.store, &self.tracer);
        self.wide = build_pipeline(&self.opts, self.opts.wide, &self.store, &self.tracer)
            .with_resume_from(self.opts.tight);
    }

    /// Serve one connection: read JSON-lines from `reader`, stream one
    /// response line per request to `writer` (flushed per line). Returns
    /// `true` when a `shutdown` command ended the session (the socket
    /// accept-loop uses this to stop listening).
    pub fn serve(
        &mut self,
        reader: impl BufRead,
        mut writer: impl Write,
    ) -> std::io::Result<bool> {
        for line in reader.lines() {
            let line = line?;
            if line.trim().is_empty() {
                continue;
            }
            self.stats.requests += 1;
            let (response, shutdown) = self.handle_line(&line);
            writer.write_all(response.render().as_bytes())?;
            writer.write_all(b"\n")?;
            writer.flush()?;
            if shutdown {
                return Ok(true);
            }
        }
        Ok(false)
    }

    /// Process one request line; never panics (panics inside become
    /// `Panicked` records and rebuild the session's pipelines).
    fn handle_line(&mut self, line: &str) -> (Json, bool) {
        let Some(req) = Json::parse(line) else {
            self.stats.errors += 1;
            return (
                error_response(
                    Json::Null,
                    &ServeError::new(ServeErrorKind::BadRequest, "request is not valid JSON"),
                ),
                false,
            );
        };
        let id = req.get("id").cloned().unwrap_or(Json::Null);
        let cmd = req
            .get("cmd")
            .and_then(|c| c.as_str())
            .unwrap_or("")
            .to_string();
        if cmd == "shutdown" {
            self.stats.ok += 1;
            return (
                Json::obj(vec![
                    ("id", id),
                    ("ok", Json::Bool(true)),
                    ("cmd", Json::str("shutdown")),
                ]),
                true,
            );
        }

        // Per-request tracing: `"trace": true` flips the shared tracer on
        // for the duration of this request (no pipeline rebuild) and the
        // events recorded past `mark` ride back on the response, keyed by
        // the request id as the trace id.
        let want_trace = req.get("trace").and_then(|t| t.as_bool()).unwrap_or(false);
        // `--trace-sample N` records every Nth request's spans into the
        // session ring (for `--trace-out` export) without attaching them
        // to the response — the wire bytes stay identical to an
        // unsampled run.
        let sampled = !want_trace
            && self.opts.trace_sample > 0
            && self.stats.requests % self.opts.trace_sample == 0;
        let was_enabled = self.tracer.is_enabled();
        if want_trace || sampled {
            self.tracer.set_enabled(true);
        }
        let mark = self.tracer.mark();
        let trace_id = want_trace.then(|| id.clone());
        let span = self.tracer.begin();
        let t0 = Instant::now();

        let outcome = catch_unwind(AssertUnwindSafe(|| self.dispatch(&cmd, &req)));
        self.request_hist.observe(t0.elapsed());
        let (ok, widened, err_kind) = match &outcome {
            Ok(Ok((_, w))) => (true, *w, None),
            Ok(Err(e)) => (false, false, Some(e.kind.name())),
            Err(_) => (false, false, Some(ServeErrorKind::Panicked.name())),
        };
        self.tracer.span("serve", "serve.request", span, || {
            vec![
                ("id", ArgVal::Str(id.render())),
                ("cmd", ArgVal::Str(cmd.clone())),
                ("ok", ArgVal::Bool(ok)),
                ("widened", ArgVal::Bool(widened)),
                (
                    "error_kind",
                    ArgVal::Str(err_kind.unwrap_or("none").to_string()),
                ),
            ]
        });

        let mut response = match outcome {
            Ok(Ok((mut fields, widened))) => {
                self.stats.ok += 1;
                if widened {
                    self.stats.widened += 1;
                }
                let mut kvs = vec![("id".to_string(), id)];
                kvs.push(("ok".to_string(), Json::Bool(true)));
                kvs.push(("widened".to_string(), Json::Bool(widened)));
                match &mut fields {
                    Json::Obj(inner) => kvs.append(inner),
                    other => kvs.push(("result".to_string(), other.clone())),
                }
                Json::Obj(kvs)
            }
            Ok(Err(e)) => {
                self.stats.errors += 1;
                error_response(id, &e)
            }
            Err(panic) => {
                self.stats.errors += 1;
                self.stats.panicked += 1;
                self.rebuild();
                let msg = panic
                    .downcast_ref::<&str>()
                    .map(|s| s.to_string())
                    .or_else(|| panic.downcast_ref::<String>().cloned())
                    .unwrap_or_else(|| "panic with non-string payload".into());
                error_response(
                    id,
                    &ServeError::new(
                        ServeErrorKind::Panicked,
                        format!("request panicked (pipelines rebuilt): {msg}"),
                    ),
                )
            }
        };
        if want_trace {
            let events: Vec<Json> = self
                .tracer
                .events_since(mark)
                .iter()
                .map(crate::obs::TraceEvent::to_json)
                .collect();
            if !was_enabled {
                self.tracer.set_enabled(false);
            }
            if let Json::Obj(kvs) = &mut response {
                kvs.push((
                    "trace_id".to_string(),
                    trace_id.unwrap_or(Json::Null),
                ));
                kvs.push(("trace".to_string(), Json::Arr(events)));
            }
        } else if sampled && !was_enabled {
            self.tracer.set_enabled(false);
        }
        (response, false)
    }

    /// Route a command. Returns the response body (merged into the
    /// envelope) plus whether the wide retry ran.
    fn dispatch(&mut self, cmd: &str, req: &Json) -> Result<(Json, bool), ServeError> {
        let deadline = req
            .get("deadline_ms")
            .and_then(|d| d.as_u64())
            .or(self.opts.deadline_ms)
            .map(Deadline::after_ms);
        match cmd {
            "ping" => Ok((Json::obj(vec![("cmd", Json::str("pong"))]), false)),
            "stats" => Ok((self.stats_body(), false)),
            "metrics" => Ok((self.metrics_body(), false)),
            "asm" => self.handle_asm(req, deadline.as_ref()),
            "bench" => self.handle_bench(req, deadline.as_ref()).map(|j| (j, false)),
            "__panic" if self.opts.allow_test_faults => {
                panic!("injected test panic (--test-faults)")
            }
            "" => Err(ServeError::new(
                ServeErrorKind::BadRequest,
                "missing `cmd` field",
            )),
            other => Err(ServeError::new(
                ServeErrorKind::BadRequest,
                format!("unknown cmd `{other}`"),
            )),
        }
    }

    fn stats_body(&self) -> Json {
        let s = self.stats;
        let disk = self.wide.stats().disk;
        Json::obj(vec![
            ("cmd", Json::str("stats")),
            ("requests", Json::num(s.requests as f64)),
            ("ok_count", Json::num(s.ok as f64)),
            ("errors", Json::num(s.errors as f64)),
            ("widened", Json::num(s.widened as f64)),
            ("panicked", Json::num(s.panicked as f64)),
            ("disk_hits", Json::num(disk.hits as f64)),
            ("disk_stores", Json::num(disk.stores as f64)),
            ("disk_resident_bytes", Json::num(disk.resident_bytes as f64)),
            // store-coordination churn a fleet operator watches without
            // shelling into the cache dir
            ("disk_evictions", Json::num(disk.evictions as f64)),
            ("disk_generation", Json::num(disk.generation as f64)),
            ("disk_lock_skips", Json::num(disk.lock_skips as f64)),
            ("disk_resyncs", Json::num(disk.resyncs as f64)),
            ("disk_swept_tmp", Json::num(disk.swept_tmp as f64)),
        ])
    }

    /// The `metrics` command: the unified [`crate::obs::MetricsSnapshot`]
    /// over both pipelines (tight + wide folded; the shared disk store
    /// counted once) plus the serve-loop counters and request latency.
    fn metrics_body(&self) -> Json {
        let mut stats = self.wide.stats();
        stats.absorb(&self.tight.stats());
        let mut m = metrics_snapshot(&stats);
        let s = self.stats;
        m.counter("serve.requests", s.requests);
        m.counter("serve.ok", s.ok);
        m.counter("serve.errors", s.errors);
        m.counter("serve.widened", s.widened);
        m.counter("serve.panicked", s.panicked);
        m.counter("trace.events", self.tracer.len() as u64);
        m.counter("trace.dropped", self.tracer.dropped());
        m.histogram("serve.request.latency", self.request_hist.snapshot());
        let mut body = m.to_json();
        if let Json::Obj(kvs) = &mut body {
            kvs.insert(0, ("cmd".to_string(), Json::str("metrics")));
        }
        body
    }

    /// The `asm` command: tight-limits first, one widened retry when the
    /// tight pass errors out or truncates flows.
    fn handle_asm(
        &self,
        req: &Json,
        deadline: Option<&Deadline>,
    ) -> Result<(Json, bool), ServeError> {
        match asm_on(&self.tight, req, deadline) {
            Ok(body) => Ok((body, false)),
            // a tight-budget blowup or truncation is exactly what the
            // wide retry exists for; anything else is final
            Err(e)
                if e.kind == ServeErrorKind::EmuError
                    && deadline.map(|d| !d.passed()).unwrap_or(true) =>
            {
                asm_on(&self.wide, req, deadline).map(|body| (body, true))
            }
            Err(e) => Err(e),
        }
    }

    /// The `bench` command: run one named suite benchmark end-to-end on
    /// the wide pipeline (detection → synthesis → validation → scoring).
    fn handle_bench(
        &self,
        req: &Json,
        deadline: Option<&Deadline>,
    ) -> Result<Json, ServeError> {
        check_deadline(deadline)?;
        let name = req
            .get("bench")
            .and_then(|b| b.as_str())
            .ok_or_else(|| {
                ServeError::new(ServeErrorKind::BadRequest, "bench: missing `bench` field")
            })?;
        let bench = crate::suite::by_name(name)
            .or_else(|| {
                crate::suite::shared_suite()
                    .into_iter()
                    .find(|b| b.name == name)
            })
            .ok_or_else(|| {
                ServeError::new(
                    ServeErrorKind::BadRequest,
                    format!("unknown benchmark `{name}`"),
                )
            })?;
        let cfg = PipelineConfig {
            threads: 1,
            ..PipelineConfig::default()
        };
        let r = run_benchmark_on(&self.wide, &bench, &cfg).map_err(|e| match e {
            PipelineError::Emu(n, err) => {
                ServeError::new(ServeErrorKind::EmuError, format!("{n}: {err}"))
            }
            PipelineError::Sim(n, err) => {
                ServeError::new(ServeErrorKind::SimError, format!("{n}: {err}"))
            }
        })?;
        let variants = r
            .variants
            .iter()
            .map(|(v, o)| {
                Json::obj(vec![
                    ("variant", Json::str(variant_key(*v))),
                    (
                        "valid",
                        o.valid.map(Json::Bool).unwrap_or(Json::Null),
                    ),
                ])
            })
            .collect();
        Ok(Json::obj(vec![
            ("cmd", Json::str("bench")),
            ("bench", Json::str(name)),
            ("shuffles", Json::num(r.detection.shuffle_count() as f64)),
            ("variants", Json::Arr(variants)),
        ]))
    }
}

/// Protocol-stable variant keys — the same strings the `asm` request's
/// `variant` field accepts (the display names `Variant::name` renders in
/// reports are not part of the wire protocol).
fn variant_key(v: Variant) -> &'static str {
    match v {
        Variant::Full => "full",
        Variant::NoLoad => "noload",
        Variant::NoCorner => "nocorner",
        Variant::UniformBranch => "uniform",
    }
}

fn build_pipeline(
    opts: &ServeOpts,
    limits: Limits,
    store: &Option<Arc<DiskStore>>,
    tracer: &Arc<Tracer>,
) -> Pipeline {
    let mut p = Pipeline::with_limits(limits)
        .with_sim_threads(opts.sim_threads)
        .with_engine(opts.engine.0, opts.engine.1)
        .with_tracer(tracer.clone());
    if let Some(s) = store {
        p = p.with_disk_shared(s.clone());
    }
    p
}

/// Cooperative per-request deadline, checked at kernel boundaries.
#[derive(Debug)]
struct Deadline {
    at: Instant,
    /// A zero-millisecond deadline must trip *deterministically* (the
    /// tests rely on it), not race `Instant::now` resolution.
    zero: bool,
}

impl Deadline {
    fn after_ms(ms: u64) -> Deadline {
        // clamp to a day: Instant + huge Duration panics on overflow, and
        // an adversarial request must not get to pick the panic path
        Deadline {
            at: Instant::now() + Duration::from_millis(ms.min(86_400_000)),
            zero: ms == 0,
        }
    }

    fn passed(&self) -> bool {
        self.zero || Instant::now() >= self.at
    }
}

fn check_deadline(deadline: Option<&Deadline>) -> Result<(), ServeError> {
    match deadline {
        Some(d) if d.passed() => Err(ServeError::new(
            ServeErrorKind::Timeout,
            "request deadline exceeded",
        )),
        _ => Ok(()),
    }
}

/// Run the `asm` request on one pipeline. Errors with `EmuError` both on
/// a hard emulation error *and* on per-flow truncation (`FlowEnd::
/// StepLimit` ends a flow silently — results computed from a truncated
/// trace are valid but incomplete, so the tight pass treats them as
/// retry-worthy rather than serving them).
fn asm_on(
    p: &Pipeline,
    req: &Json,
    deadline: Option<&Deadline>,
) -> Result<Json, ServeError> {
    check_deadline(deadline)?;
    let src = req
        .get("ptx")
        .and_then(|s| s.as_str())
        .ok_or_else(|| ServeError::new(ServeErrorKind::BadRequest, "asm: missing `ptx` field"))?;
    let variant = match req.get("variant").and_then(|v| v.as_str()).unwrap_or("full") {
        "full" => Variant::Full,
        "noload" => Variant::NoLoad,
        "nocorner" => Variant::NoCorner,
        "uniform" => Variant::UniformBranch,
        other => {
            return Err(ServeError::new(
                ServeErrorKind::BadRequest,
                format!("unknown variant `{other}`"),
            ))
        }
    };
    let opts = DetectOpts {
        max_abs_delta: req
            .get("max_delta")
            .and_then(|d| d.as_u64())
            .unwrap_or(31) as i64,
        ..DetectOpts::default()
    };
    let block = req.get("block").and_then(|b| b.as_u64()).unwrap_or(32) as u32;
    if block == 0 || block > 1024 {
        return Err(ServeError::new(
            ServeErrorKind::BadRequest,
            format!("block size {block} out of range (1..=1024)"),
        ));
    }
    let elim = ElimOpts {
        enabled: req
            .get("elim")
            .and_then(|e| e.as_bool())
            .unwrap_or(true),
        block,
    };

    let mut module =
        parse(src).map_err(|e| ServeError::new(ServeErrorKind::ParseError, e.to_string()))?;
    let mut kernels = Vec::new();
    for k in module.kernels.iter_mut() {
        check_deadline(deadline)?;
        let parsed = p.intake(k.clone());
        let det = p
            .detected_hashed(&parsed.kernel, parsed.hash, opts)
            .map_err(|e| {
                ServeError::new(ServeErrorKind::EmuError, format!("{}: {e}", k.name))
            })?;
        // the emulation is in cache now (the detection consumed it);
        // check for silent per-flow truncation
        let emu = p.emulated_hashed(&parsed.kernel, parsed.hash).map_err(|e| {
            ServeError::new(ServeErrorKind::EmuError, format!("{}: {e}", k.name))
        })?;
        if emu
            .result
            .flows
            .iter()
            .any(|f| f.end == FlowEnd::StepLimit)
        {
            return Err(ServeError::new(
                ServeErrorKind::EmuError,
                format!("{}: emulation truncated by the step budget", k.name),
            ));
        }
        let synth = p
            .synthesized_hashed(&parsed.kernel, parsed.hash, opts, variant, elim)
            .map_err(|e| {
                ServeError::new(ServeErrorKind::EmuError, format!("{}: {e}", k.name))
            })?;
        kernels.push(Json::obj(vec![
            ("name", Json::str(k.name.clone())),
            (
                "shuffles",
                Json::num(det.detection.shuffle_count() as f64),
            ),
            (
                "elim_deleted_stores",
                Json::num(synth.elim.deleted_stores() as f64),
            ),
            (
                "elim_elided_barriers",
                Json::num(synth.elim.elided_barriers() as f64),
            ),
        ]));
        *k = (*synth.kernel).clone();
    }
    check_deadline(deadline)?;
    Ok(Json::obj(vec![
        ("cmd", Json::str("asm")),
        ("kernels", Json::Arr(kernels)),
        ("ptx", Json::str(print_module(&module))),
    ]))
}

fn error_response(id: Json, e: &ServeError) -> Json {
    Json::obj(vec![
        ("id", id),
        ("ok", Json::Bool(false)),
        (
            "error",
            Json::obj(vec![
                ("kind", Json::str(e.kind.name())),
                ("message", Json::str(e.message.clone())),
            ]),
        ),
    ])
}

/// Serve one batch of request lines across a pool of worker sessions.
///
/// `threads <= 1` delegates to the serial [`ServeSession::serve`] loop.
/// Otherwise the batch is read up front (truncated after the first
/// `shutdown` line — serial semantics), the line indices are dispatched
/// onto a work-stealing [`WorkQueue`], and each worker processes
/// requests on its **own** `ServeSession` (own pipelines, own interner,
/// own child tracer) over the root session's shared disk store. Per-
/// request isolation is untouched: a worker panic degrades to a
/// `Panicked` record and rebuilds only that worker's pipelines.
///
/// Responses are written in **input order**, so healthy output is
/// byte-identical to a serial run of the same batch. Worker stats,
/// latency histograms, and trace rings are folded back into `session`
/// before returning.
pub fn serve_pooled(
    session: &mut ServeSession,
    reader: impl BufRead,
    mut writer: impl Write,
    threads: usize,
) -> std::io::Result<bool> {
    if threads <= 1 {
        return session.serve(reader, writer);
    }
    let mut lines = Vec::new();
    let mut saw_shutdown = false;
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let is_shutdown = Json::parse(&line)
            .map(|j| j.get("cmd").and_then(|c| c.as_str()) == Some("shutdown"))
            .unwrap_or(false);
        lines.push(line);
        if is_shutdown {
            // nothing after a shutdown line is processed — exactly the
            // serial loop's contract
            saw_shutdown = true;
            break;
        }
    }

    let slots: Vec<Mutex<Option<Json>>> = lines.iter().map(|_| Mutex::new(None)).collect();
    let queue = WorkQueue::new(threads);
    for i in 0..lines.len() {
        queue.push(i);
    }
    let merged = Mutex::new(ServeStats::default());
    let merged_hist = Histogram::new();
    let opts = session.opts;
    std::thread::scope(|scope| {
        for w in 0..threads {
            let queue = &queue;
            let slots = &slots;
            let lines = &lines;
            let merged = &merged;
            let merged_hist = &merged_hist;
            let parent = &session.tracer;
            let store = session.store.clone();
            scope.spawn(move || {
                // worker session ids start at 2; the root session keeps
                // pid 1 in merged Perfetto exports
                let tracer = Arc::new(parent.child(w as u64 + 2));
                let mut ws = ServeSession::with_tracer(opts, store, tracer.clone());
                while let Some(i) = queue.pop(w) {
                    ws.stats.requests += 1;
                    let (response, _) = ws.handle_line(&lines[i]);
                    *slots[i].lock().unwrap() = Some(response);
                    queue.retire();
                }
                parent.absorb(&tracer);
                merged.lock().unwrap().absorb(&ws.stats);
                merged_hist.absorb(&ws.request_hist.snapshot());
            });
        }
    });
    session.stats.absorb(&merged.into_inner().unwrap());
    session.request_hist.absorb(&merged_hist.snapshot());

    for slot in &slots {
        if let Some(response) = slot.lock().unwrap().take() {
            writer.write_all(response.render().as_bytes())?;
            writer.write_all(b"\n")?;
            writer.flush()?;
        }
    }
    Ok(saw_shutdown)
}

/// Accept loop over a Unix socket: each connection is served **con-
/// currently** on its own worker session (own pipelines, own child
/// tracer with a distinct session id) over the root session's shared
/// disk store, so one slow or adversarial client cannot stall the
/// others. A `shutdown` command on any connection stops the listener
/// after in-flight connections drain. The socket file is replaced if it
/// already exists. Worker stats and trace rings fold back into
/// `session` before returning.
#[cfg(unix)]
pub fn serve_unix(session: &mut ServeSession, path: &std::path::Path) -> std::io::Result<()> {
    use std::os::unix::net::UnixListener;
    use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
    let _ = std::fs::remove_file(path);
    let listener = UnixListener::bind(path)?;
    // nonblocking accept: the loop can observe the shutdown flag set by
    // a worker thread instead of parking in accept(2) forever
    listener.set_nonblocking(true)?;
    let shutdown = AtomicBool::new(false);
    let next_sid = AtomicU64::new(2);
    let merged = Mutex::new(ServeStats::default());
    let merged_hist = Histogram::new();
    let opts = session.opts;
    let result = std::thread::scope(|scope| -> std::io::Result<()> {
        loop {
            if shutdown.load(Ordering::SeqCst) {
                return Ok(());
            }
            match listener.accept() {
                Ok((stream, _)) => {
                    let sid = next_sid.fetch_add(1, Ordering::Relaxed);
                    let store = session.store.clone();
                    let parent = &session.tracer;
                    let shutdown = &shutdown;
                    let merged = &merged;
                    let merged_hist = &merged_hist;
                    scope.spawn(move || {
                        // accepted streams must block: the per-line
                        // reader loop owns this connection's pacing
                        let _ = stream.set_nonblocking(false);
                        let tracer = Arc::new(parent.child(sid));
                        let mut ws = ServeSession::with_tracer(opts, store, tracer.clone());
                        if let Ok(rs) = stream.try_clone() {
                            // a broken connection ends its own worker
                            // only; the listener keeps serving
                            if let Ok(true) = ws.serve(std::io::BufReader::new(rs), &stream) {
                                shutdown.store(true, Ordering::SeqCst);
                            }
                        }
                        parent.absorb(&tracer);
                        merged.lock().unwrap().absorb(&ws.stats);
                        merged_hist.absorb(&ws.request_hist.snapshot());
                    });
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(5));
                }
                Err(e) => return Err(e),
            }
        }
    });
    session.stats.absorb(&merged.into_inner().unwrap());
    session.request_hist.absorb(&merged_hist.snapshot());
    let _ = std::fs::remove_file(path);
    result
}

#[cfg(test)]
mod tests {
    use super::*;

    const K: &str = r#"
.version 7.6
.target sm_70
.address_size 64
.visible .entry servek(.param .u64 out, .param .u64 a){
.reg .b32 %r<6>; .reg .b64 %rd<8>; .reg .f32 %f<6>;
ld.param.u64 %rd1, [out];
ld.param.u64 %rd2, [a];
cvta.to.global.u64 %rd3, %rd2;
cvta.to.global.u64 %rd4, %rd1;
mov.u32 %r4, %tid.x;
mul.wide.s32 %rd5, %r4, 4;
add.s64 %rd6, %rd3, %rd5;
ld.global.nc.f32 %f1, [%rd6];
ld.global.nc.f32 %f2, [%rd6+4];
add.f32 %f4, %f1, %f2;
add.s64 %rd7, %rd4, %rd5;
st.global.f32 [%rd7], %f4;
ret;
}
"#;

    fn run_lines(session: &mut ServeSession, lines: &[String]) -> Vec<Json> {
        let input = lines.join("\n");
        let mut out = Vec::new();
        session
            .serve(std::io::Cursor::new(input), &mut out)
            .unwrap();
        String::from_utf8(out)
            .unwrap()
            .lines()
            .map(|l| Json::parse(l).expect("every response line is valid JSON"))
            .collect()
    }

    fn asm_req(id: u64, ptx: &str) -> String {
        Json::obj(vec![
            ("id", Json::num(id as f64)),
            ("cmd", Json::str("asm")),
            ("ptx", Json::str(ptx)),
        ])
        .render()
    }

    #[test]
    fn healthy_request_roundtrips_and_streams_ptx() {
        let mut s = ServeSession::new(ServeOpts::default(), None);
        let responses = run_lines(&mut s, &[asm_req(1, K)]);
        assert_eq!(responses.len(), 1);
        let r = &responses[0];
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(r.get("id").unwrap().as_u64(), Some(1));
        let kernels = r.get("kernels").unwrap().as_arr().unwrap();
        assert_eq!(kernels[0].get("name").unwrap().as_str(), Some("servek"));
        assert!(kernels[0].get("shuffles").unwrap().as_u64().unwrap() >= 1);
        assert!(r.get("ptx").unwrap().as_str().unwrap().contains("shfl.sync"));
    }

    #[test]
    fn poisoned_batch_degrades_per_request_not_per_session() {
        let mut s = ServeSession::new(
            ServeOpts {
                allow_test_faults: true,
                ..ServeOpts::default()
            },
            None,
        );
        let lines = vec![
            asm_req(1, K),
            r#"{"id":2,"cmd":"asm","ptx":"this is not ptx"}"#.to_string(),
            "this is not even json".to_string(),
            r#"{"id":4,"cmd":"__panic"}"#.to_string(),
            asm_req(5, K),
        ];
        let responses = run_lines(&mut s, &lines);
        assert_eq!(responses.len(), 5);
        let kind = |i: usize| {
            responses[i]
                .get("error")
                .and_then(|e| e.get("kind"))
                .and_then(|k| k.as_str())
                .map(str::to_string)
        };
        assert_eq!(responses[0].get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(kind(1).as_deref(), Some("ParseError"));
        assert_eq!(kind(2).as_deref(), Some("BadRequest"));
        assert_eq!(kind(3).as_deref(), Some("Panicked"));
        // the kernel after the panic still succeeds, bit-identically
        assert_eq!(responses[4].get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(
            responses[4].get("ptx").unwrap().as_str(),
            responses[0].get("ptx").unwrap().as_str(),
            "post-panic result must match the pre-panic one"
        );
        let stats = s.stats();
        assert_eq!(stats.requests, 5);
        assert_eq!(stats.panicked, 1);
        assert_eq!(stats.errors, 3);
    }

    #[test]
    fn zero_deadline_times_out_deterministically() {
        let mut s = ServeSession::new(ServeOpts::default(), None);
        let req = Json::obj(vec![
            ("id", Json::num(9.0)),
            ("cmd", Json::str("asm")),
            ("ptx", Json::str(K)),
            ("deadline_ms", Json::num(0.0)),
        ])
        .render();
        let responses = run_lines(&mut s, &[req]);
        assert_eq!(
            responses[0]
                .get("error")
                .and_then(|e| e.get("kind"))
                .and_then(|k| k.as_str()),
            Some("Timeout")
        );
    }

    /// `bits` tid-dependent branches, each adding a distinct constant to
    /// the accumulator on its taken side: every one of the 2^bits paths
    /// carries a distinct register environment, so neither loop
    /// abstraction nor label memoization collapses the explosion — the
    /// flow count is the real cost.
    fn forky(bits: usize) -> String {
        let mut body = String::new();
        for i in 0..bits {
            body.push_str(&format!(
                "and.b32 %r10, %r1, {};\nsetp.eq.s32 %p{p}, %r10, 0;\n\
                 @%p{p} bra $S{i};\nadd.s32 %r2, %r2, {};\n$S{i}:\n",
                1u32 << i,
                100 + i,
                p = i + 1,
            ));
        }
        format!(
            ".version 7.6\n.target sm_70\n.address_size 64\n\
             .visible .entry forky(.param .u64 out){{\n\
             .reg .pred %p<{}>; .reg .b32 %r<12>; .reg .b64 %rd<3>;\n\
             ld.param.u64 %rd1, [out];\ncvta.to.global.u64 %rd2, %rd1;\n\
             mov.u32 %r1, %tid.x;\nmov.u32 %r2, 0;\n{body}\
             st.global.u32 [%rd2], %r2;\nret;\n}}\n",
            bits + 2,
        )
    }

    #[test]
    fn flow_blowup_widens_once_then_reports_emu_error() {
        // 2^10 = 1024 flows: over the tight budget (512) and over this
        // session's deliberately small "wide" budget too — the request
        // must retry once, then fail with a typed EmuError
        let mut s = ServeSession::new(
            ServeOpts {
                wide: Limits {
                    max_flows: 64,
                    max_steps_per_flow: 50_000,
                    max_total_steps: 2_000_000,
                },
                ..ServeOpts::default()
            },
            None,
        );
        let responses = run_lines(&mut s, &[asm_req(1, &forky(10))]);
        assert_eq!(
            responses[0]
                .get("error")
                .and_then(|e| e.get("kind"))
                .and_then(|k| k.as_str()),
            Some("EmuError"),
            "flow-blowup kernel must yield a typed EmuError, got {:?}",
            responses[0]
        );
        assert_eq!(s.stats().errors, 1);
    }

    #[test]
    fn tight_overflow_with_wide_headroom_widens_and_succeeds() {
        // 1024 flows: over tight (512), comfortably under the default
        // wide budget (4096) — the retry must succeed and be flagged
        let mut s = ServeSession::new(ServeOpts::default(), None);
        let responses = run_lines(&mut s, &[asm_req(1, &forky(10))]);
        assert_eq!(
            responses[0].get("ok").unwrap().as_bool(),
            Some(true),
            "got {:?}",
            responses[0]
        );
        assert_eq!(responses[0].get("widened").unwrap().as_bool(), Some(true));
        assert_eq!(s.stats().widened, 1);
    }

    #[test]
    fn ping_stats_shutdown_protocol() {
        let mut s = ServeSession::new(ServeOpts::default(), None);
        let lines = vec![
            r#"{"cmd":"ping"}"#.to_string(),
            r#"{"cmd":"stats"}"#.to_string(),
            r#"{"id":"bye","cmd":"shutdown"}"#.to_string(),
            // anything after shutdown is not processed
            asm_req(99, K),
        ];
        let responses = run_lines(&mut s, &lines);
        assert_eq!(responses.len(), 3, "shutdown stops the loop");
        assert_eq!(responses[0].get("cmd").unwrap().as_str(), Some("pong"));
        assert!(responses[1].get("requests").unwrap().as_u64().unwrap() >= 2);
        assert_eq!(responses[2].get("id").unwrap().as_str(), Some("bye"));
    }

    #[test]
    fn stats_surfaces_disk_coordination_fields() {
        let mut s = ServeSession::new(ServeOpts::default(), None);
        let responses = run_lines(&mut s, &[r#"{"cmd":"stats"}"#.to_string()]);
        let r = &responses[0];
        // no disk store attached: the gauges exist and read zero
        for field in [
            "disk_evictions",
            "disk_generation",
            "disk_lock_skips",
            "disk_resyncs",
            "disk_swept_tmp",
        ] {
            assert_eq!(r.get(field).and_then(Json::as_u64), Some(0), "{field}");
        }
    }

    #[test]
    fn metrics_command_returns_the_unified_snapshot() {
        let mut s = ServeSession::new(ServeOpts::default(), None);
        let lines = vec![asm_req(1, K), r#"{"id":2,"cmd":"metrics"}"#.to_string()];
        let responses = run_lines(&mut s, &lines);
        let m = &responses[1];
        assert_eq!(m.get("cmd").unwrap().as_str(), Some("metrics"));
        assert_eq!(m.get("metrics_version").and_then(Json::as_u64), Some(1));
        let counters = m.get("counters").expect("counters object");
        // the asm request ran emulation + detection through the pipelines
        assert_eq!(counters.get("serve.requests").and_then(Json::as_u64), Some(2));
        assert_eq!(counters.get("serve.ok").and_then(Json::as_u64), Some(1));
        assert!(counters.get("cache.emulate.misses").and_then(Json::as_u64).unwrap() >= 1);
        let hists = m.get("histograms").expect("histograms object");
        let lat = hists.get("serve.request.latency").expect("request latency");
        assert!(lat.get("count").and_then(Json::as_u64).unwrap() >= 1);
    }

    #[test]
    fn per_request_trace_rides_back_on_the_response() {
        let mut s = ServeSession::new(ServeOpts::default(), None);
        let traced = Json::obj(vec![
            ("id", Json::str("req-7")),
            ("cmd", Json::str("asm")),
            ("ptx", Json::str(K)),
            ("trace", Json::Bool(true)),
        ])
        .render();
        let lines = vec![asm_req(1, K), traced, asm_req(3, K)];
        let responses = run_lines(&mut s, &lines);
        // untraced requests carry no trace keys
        assert!(responses[0].get("trace").is_none());
        assert!(responses[2].get("trace").is_none());
        let r = &responses[1];
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(true));
        // the request id is echoed as the trace id
        assert_eq!(
            r.get("trace_id").unwrap().as_str(),
            Some("req-7"),
            "got {:?}",
            r.get("trace_id")
        );
        let events = r.get("trace").unwrap().as_arr().unwrap();
        assert!(!events.is_empty(), "traced request returns span events");
        // the request-level span is present and marks this id + cmd
        let req_span = events
            .iter()
            .find(|e| e.get("name").and_then(|n| n.as_str()) == Some("serve.request"))
            .expect("serve.request span in the per-request trace");
        assert_eq!(req_span.get("ph").unwrap().as_str(), Some("X"));
        let args = req_span.get("args").expect("span args");
        assert_eq!(args.get("cmd").unwrap().as_str(), Some("asm"));
        assert_eq!(args.get("ok").unwrap().as_bool(), Some(true));
        // the session tracer is disabled again after the traced request
        assert!(!s.tracer().is_enabled());
    }

    #[test]
    fn widened_retry_resumes_from_the_tight_frontier_over_the_store() {
        let dir = std::env::temp_dir().join(format!(
            "ptxasw-serve-resume-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let store = Arc::new(DiskStore::open(&dir, 1 << 30).unwrap());
        let mut s = ServeSession::new(ServeOpts::default(), Some(store));
        // 1024 flows: trips the tight budget (512), fits wide (4096)
        let responses = run_lines(&mut s, &[asm_req(1, &forky(10))]);
        assert_eq!(
            responses[0].get("ok").unwrap().as_bool(),
            Some(true),
            "got {:?}",
            responses[0]
        );
        assert_eq!(responses[0].get("widened").unwrap().as_bool(), Some(true));
        // the tight pass persisted its budget-trip frontier image...
        assert_eq!(s.tight.stats().frontier_stores, 1);
        // ...and the wide retry resumed it instead of re-emulating the
        // tight run's finished flows
        assert_eq!(s.wide.stats().frontier_resumes, 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn pooled_batch_matches_serial_run_byte_for_byte() {
        // a poisoned batch — parse error, garbage framing, injected
        // panic, flow blowup — interleaved with healthy kernels: the
        // pooled run must emit the exact bytes of the serial run
        let opts = ServeOpts {
            allow_test_faults: true,
            ..ServeOpts::default()
        };
        let lines = vec![
            asm_req(1, K),
            r#"{"id":2,"cmd":"asm","ptx":"this is not ptx"}"#.to_string(),
            "this is not even json".to_string(),
            r#"{"id":4,"cmd":"__panic"}"#.to_string(),
            asm_req(5, &forky(10)),
            asm_req(6, K),
        ];
        let input = lines.join("\n");
        let mut serial = ServeSession::new(opts, None);
        let mut serial_out = Vec::new();
        serial
            .serve(std::io::Cursor::new(input.clone()), &mut serial_out)
            .unwrap();
        let mut pooled = ServeSession::new(opts, None);
        let mut pooled_out = Vec::new();
        let shutdown =
            serve_pooled(&mut pooled, std::io::Cursor::new(input), &mut pooled_out, 3).unwrap();
        assert!(!shutdown);
        assert_eq!(
            String::from_utf8(pooled_out).unwrap(),
            String::from_utf8(serial_out).unwrap(),
            "pooled responses must be byte-identical to the serial run"
        );
        // worker counters folded back into the root session
        let s = pooled.stats();
        assert_eq!(s.requests, 6);
        assert_eq!(s.panicked, 1);
        assert_eq!(s.widened, 1);
        assert_eq!(s.errors, 3);
    }

    #[test]
    fn pooled_shutdown_truncates_like_the_serial_loop() {
        let mut s = ServeSession::new(ServeOpts::default(), None);
        let lines = vec![
            r#"{"cmd":"ping"}"#.to_string(),
            r#"{"id":"bye","cmd":"shutdown"}"#.to_string(),
            asm_req(99, K),
        ];
        let mut out = Vec::new();
        let shutdown = serve_pooled(
            &mut s,
            std::io::Cursor::new(lines.join("\n")),
            &mut out,
            2,
        )
        .unwrap();
        assert!(shutdown, "the shutdown line must surface to the caller");
        let responses: Vec<Json> = String::from_utf8(out)
            .unwrap()
            .lines()
            .map(|l| Json::parse(l).unwrap())
            .collect();
        assert_eq!(responses.len(), 2, "nothing after shutdown is processed");
        assert_eq!(responses[0].get("cmd").unwrap().as_str(), Some("pong"));
        assert_eq!(responses[1].get("id").unwrap().as_str(), Some("bye"));
    }

    #[test]
    fn trace_sampling_records_spans_without_touching_responses() {
        let mut s = ServeSession::new(
            ServeOpts {
                trace_sample: 2,
                ..ServeOpts::default()
            },
            None,
        );
        let lines = vec![asm_req(1, K), asm_req(2, K), asm_req(3, K), asm_req(4, K)];
        let responses = run_lines(&mut s, &lines);
        for r in &responses {
            assert_eq!(r.get("ok").unwrap().as_bool(), Some(true));
            assert!(
                r.get("trace").is_none() && r.get("trace_id").is_none(),
                "sampled spans never ride back on responses"
            );
        }
        // requests 2 and 4 hit the sample gate: their serve.request
        // spans sit in the session ring for --trace-out export
        let spans: Vec<_> = s
            .tracer()
            .events()
            .into_iter()
            .filter(|e| e.name == "serve.request")
            .collect();
        assert_eq!(spans.len(), 2, "every 2nd of 4 requests is sampled");
        // and the tracer is back off between requests
        assert!(!s.tracer().is_enabled());
    }
}
