//! The staged pass manager for the PTXASW pipeline.
//!
//! The paper's tool is a tail-of-pipeline phase; production traffic needs
//! the phases to be explicit, cacheable and batched. This module stages
//! the end-to-end flow as typed artifacts
//!
//! ```text
//! Parsed → Emulated → Detected → Synthesized → Validated → Scored
//! ```
//!
//! with **every** stage content-addressed: the analysis stages by a stable
//! hash of the kernel ([`crate::ptx::kernel_fingerprint`]), the two
//! workload-dependent tail stages by that hash combined with a
//! [`crate::suite::WorkloadFingerprint`] (sizes, RNG seed,
//! input-generation spec). Benchmark input generation is itself a cached
//! stage ([`Pipeline::workload_art`]), so the baseline and every synthesis
//! variant share one generated workload.
//!
//! Artifacts live in a thread-safe in-memory [`ArtifactCache`], optionally
//! backed by an on-disk [`DiskStore`] ([`Pipeline::with_disk`]): emulated,
//! decoded, detected, synthesized, validated and scored artifacts persist
//! across processes — emulations through the relocatable term-graph codec
//! ([`crate::sym::persist`]) — so a warm re-run skips symbolic emulation,
//! micro-op decoding *and* simulation entirely, even for workloads or
//! detection options the cache has never seen. A
//! [`Pipeline`] owns one [`SessionInterner`] shared by every emulation it
//! runs, so symbol and UF names (`%tid.x`, params, `load.global.*`) are
//! interned once per session instead of once per kernel. Per-stage wall
//! time and cache hit/miss counters (memory and disk) are exposed through
//! [`Pipeline::stats`] for the CLI `--stats` flag and the coordinator's
//! suite report.

pub mod artifact;
pub mod serve;
pub mod stages;
pub mod store;

pub use artifact::{
    ArtifactCache, ArtifactKind, CacheEvent, CacheSnapshot, Detected, Emulated, Parsed,
    Synthesized, WorkloadArt,
};
pub use serve::{serve_pooled, ServeOpts, ServeSession, ServeStats};
pub use stages::{score, validate, Scored, Validated};
pub use store::{
    default_dir, DiskSnapshot, DiskStore, KeyBuilder, KindCheck, Manifest, StoreCheck, StoreKind,
    DEFAULT_MAX_BYTES, STORE_KINDS,
};

use crate::emu::{emulate_outcome, resume_outcome, EmuError, EmuOutcome, FlowEnd, Limits};
use crate::obs::{ArgVal, HistSnapshot, Histogram, MetricsSnapshot, Tracer};
use crate::perf::Arch;
use crate::ptx::ast::Kernel;
use crate::ptx::parser::{parse, ParseError};
use crate::ptx::printer::{kernel_fingerprint, ContentHash};
use crate::shuffle::{detect, eliminate, synthesize, DetectOpts, ElimOpts, ElimReport, Variant};
use crate::sim::SimError;
use crate::suite::{Benchmark, WorkloadFingerprint};
use crate::sym::SessionInterner;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// The pipeline's stages, in execution order. `Workload` is the cached
/// input-generation stage feeding `Validate`; `Decode` is the cached
/// micro-op lowering the simulator executes (kernel-keyed, so one
/// decoding serves every workload of a kernel version).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stage {
    Parse,
    Workload,
    Decode,
    Emulate,
    Detect,
    Synthesize,
    Validate,
    Score,
}

/// All stages in execution order (for reports).
pub const STAGES: [Stage; 8] = [
    Stage::Parse,
    Stage::Workload,
    Stage::Decode,
    Stage::Emulate,
    Stage::Detect,
    Stage::Synthesize,
    Stage::Validate,
    Stage::Score,
];

impl Stage {
    pub fn name(self) -> &'static str {
        match self {
            Stage::Parse => "parse",
            Stage::Workload => "workload",
            Stage::Decode => "decode",
            Stage::Emulate => "emulate",
            Stage::Detect => "detect",
            Stage::Synthesize => "synthesize",
            Stage::Validate => "validate",
            Stage::Score => "score",
        }
    }

    pub fn index(self) -> usize {
        match self {
            Stage::Parse => 0,
            Stage::Workload => 1,
            Stage::Decode => 2,
            Stage::Emulate => 3,
            Stage::Detect => 4,
            Stage::Synthesize => 5,
            Stage::Validate => 6,
            Stage::Score => 7,
        }
    }

    /// The stage's span name in the trace taxonomy (`stage.<name>`).
    pub fn span_name(self) -> &'static str {
        match self {
            Stage::Parse => "stage.parse",
            Stage::Workload => "stage.workload",
            Stage::Decode => "stage.decode",
            Stage::Emulate => "stage.emulate",
            Stage::Detect => "stage.detect",
            Stage::Synthesize => "stage.synthesize",
            Stage::Validate => "stage.validate",
            Stage::Score => "stage.score",
        }
    }
}

/// Accumulated wall time, invocation counts and latency distribution per
/// stage.
#[derive(Debug, Default)]
struct StageTimings {
    nanos: [AtomicU64; STAGES.len()],
    runs: [AtomicU64; STAGES.len()],
    hist: [Histogram; STAGES.len()],
}

impl StageTimings {
    fn record(&self, stage: Stage, elapsed: Duration) {
        let i = stage.index();
        self.nanos[i].fetch_add(elapsed.as_nanos() as u64, Ordering::Relaxed);
        self.runs[i].fetch_add(1, Ordering::Relaxed);
        self.hist[i].observe(elapsed);
    }
}

/// Point-in-time view of the pipeline's counters.
#[derive(Debug, Clone, Copy, Default)]
pub struct PipelineStats {
    pub cache: CacheSnapshot,
    pub disk: DiskSnapshot,
    pub stage_nanos: [u64; STAGES.len()],
    pub stage_runs: [u64; STAGES.len()],
    /// Per-stage latency distributions (same fixed bucket layout as the
    /// metrics registry, so snapshots merge bucket-by-bucket).
    pub stage_hist: [HistSnapshot; STAGES.len()],
    /// Decoded-engine telemetry summed over every simulation this
    /// pipeline ran: straight-line runs taken in one scheduling slice.
    pub superblocks_entered: u64,
    /// Warp micro-ops dispatched through the lane-vectorized kernels
    /// (always 0 without the `simd` feature or with `--engine` scalar).
    pub vector_warp_steps: u64,
    /// Budget-tripped emulations that left a resumable frontier image in
    /// the disk store.
    pub frontier_stores: u64,
    /// Emulations completed by *resuming* a tighter run's frontier image
    /// instead of re-emulating from flow zero (serve mode's widened
    /// retry path).
    pub frontier_resumes: u64,
}

impl PipelineStats {
    pub fn stage_time(&self, stage: Stage) -> Duration {
        Duration::from_nanos(self.stage_nanos[stage.index()])
    }

    pub fn stage_count(&self, stage: Stage) -> u64 {
        self.stage_runs[stage.index()]
    }

    /// Fold another pipeline's counters into this snapshot (serve mode
    /// reports its tight + wide pipelines as one). The `disk` snapshot is
    /// deliberately *not* summed: the pipelines being folded share one
    /// [`DiskStore`], so its counters appear identically in both
    /// snapshots and summing would double-count.
    pub fn absorb(&mut self, o: &PipelineStats) {
        self.cache.absorb(&o.cache);
        for i in 0..STAGES.len() {
            self.stage_nanos[i] += o.stage_nanos[i];
            self.stage_runs[i] += o.stage_runs[i];
            self.stage_hist[i] = self.stage_hist[i].merged(&o.stage_hist[i]);
        }
        self.superblocks_entered += o.superblocks_entered;
        self.vector_warp_steps += o.vector_warp_steps;
        self.frontier_stores += o.frontier_stores;
        self.frontier_resumes += o.frontier_resumes;
    }
}

/// Collect a [`PipelineStats`] snapshot into the unified metrics registry
/// view: stable dotted names, one versioned [`MetricsSnapshot`]. This is
/// the single place the five specialized stat structs are folded together,
/// shared by `--stats`, the serve `metrics` request and `ptxasw metrics`.
pub fn metrics_snapshot(s: &PipelineStats) -> MetricsSnapshot {
    let mut m = MetricsSnapshot::new();
    let c = &s.cache;
    let families: [(&str, u64, u64, u64); 7] = [
        ("workload", c.workload_hits, 0, c.workload_misses),
        ("decode", c.decode_hits, c.decode_disk_hits, c.decode_misses),
        ("emulate", c.emulate_hits, c.emulate_disk_hits, c.emulate_misses),
        ("detect", c.detect_hits, c.detect_disk_hits, c.detect_misses),
        ("synthesize", c.synth_hits, c.synth_disk_hits, c.synth_misses),
        (
            "validate",
            c.validate_hits,
            c.validate_disk_hits,
            c.validate_misses,
        ),
        ("score", c.score_hits, c.score_disk_hits, c.score_misses),
    ];
    for (name, hits, disk_hits, misses) in families {
        m.counter(format!("cache.{name}.hits"), hits);
        m.counter(format!("cache.{name}.disk_hits"), disk_hits);
        m.counter(format!("cache.{name}.misses"), misses);
    }
    for stage in STAGES {
        let i = stage.index();
        m.counter(format!("stage.{}.runs", stage.name()), s.stage_runs[i]);
        m.counter(format!("stage.{}.nanos", stage.name()), s.stage_nanos[i]);
    }
    m.counter("engine.superblocks_entered", s.superblocks_entered);
    m.counter("engine.vector_warp_steps", s.vector_warp_steps);
    m.counter("emulate.frontier_stores", s.frontier_stores);
    m.counter("emulate.frontier_resumes", s.frontier_resumes);
    let d = &s.disk;
    m.counter("store.enabled", u64::from(d.enabled));
    m.counter("store.hits", d.hits);
    m.counter("store.misses", d.misses);
    m.counter("store.stores", d.stores);
    m.counter("store.evictions", d.evictions);
    m.counter("store.corrupt", d.corrupt);
    m.counter("store.resident_bytes", d.resident_bytes);
    m.counter("store.generation", d.generation);
    m.counter("store.lock_skips", d.lock_skips);
    m.counter("store.resyncs", d.resyncs);
    m.counter("store.swept_tmp", d.swept_tmp);
    m.counter("store.index_rebuilds", d.index_rebuilds);
    for stage in STAGES {
        m.histogram(
            format!("stage.{}.latency", stage.name()),
            s.stage_hist[stage.index()],
        );
    }
    m
}

/// The pass manager: shared interner session + artifact cache + counters,
/// with optional on-disk persistence.
///
/// One `Pipeline` per logical session; `run_suite`-style drivers create a
/// fresh one per call unless handed an existing pipeline to share the
/// cache across runs. Two pipelines (or processes) opened over the same
/// cache directory share artifacts through the [`DiskStore`].
#[derive(Debug)]
pub struct Pipeline {
    session: Arc<SessionInterner>,
    limits: Limits,
    cache: ArtifactCache,
    timings: StageTimings,
    store: Option<Arc<DiskStore>>,
    /// Worker threads per simulation (`0`/`1` = serial). Results are
    /// bit-identical for any value on the simulator's supported domain
    /// (kernels that never read another block's global writes — such
    /// reads are scheduling-dependent on real hardware and undefined for
    /// every engine; see `sim::exec`), so it is *not* part of any cache
    /// key. Cross-block write-after-write *is* detected
    /// (`SimStats::cross_block_write_conflicts`); read-after-write is an
    /// opt-in hard-error diagnostic ([`Pipeline::with_detect_races`]).
    sim_threads: usize,
    /// Opt-in cross-block read-after-write diagnostic (`--detect-races`):
    /// simulations run serial with a load-side shadow and an offending
    /// kernel is a hard `SimError`. Diagnostic runs bypass the *disk*
    /// store for `Validated` artifacts entirely — a verdict computed
    /// without the shadow must never satisfy a diagnostic query, and a
    /// diagnostic result must never leak into normal runs.
    detect_races: bool,
    /// Superblock fast path in the decoded engine (`--engine`). Not part
    /// of any cache key: results are bit-identical either way.
    superblocks: bool,
    /// Lane-vectorized kernels in the decoded engine (`--engine`; inert
    /// without the `simd` cargo feature). Not part of any cache key.
    vector: bool,
    /// The limits of a *tighter* pipeline whose frontier images this one
    /// may resume from ([`Pipeline::with_resume_from`]). `None` = cold
    /// re-emulation on every budget-tripped retry.
    resume_from: Option<Limits>,
    /// Decoded-engine telemetry summed across this pipeline's runs.
    superblocks_entered: AtomicU64,
    vector_warp_steps: AtomicU64,
    /// Frontier images written (budget trips) and consumed (resumes).
    frontier_stores: AtomicU64,
    frontier_resumes: AtomicU64,
    /// Span recorder threaded through every stage. Disabled by default —
    /// one relaxed atomic load per span site; see [`crate::obs`].
    tracer: Arc<Tracer>,
}

impl Default for Pipeline {
    fn default() -> Pipeline {
        Pipeline {
            session: Arc::default(),
            limits: Limits::default(),
            cache: ArtifactCache::default(),
            timings: StageTimings::default(),
            store: None,
            sim_threads: 0,
            detect_races: false,
            // both engine paths are on by default (bit-identical results)
            superblocks: true,
            vector: true,
            resume_from: None,
            superblocks_entered: AtomicU64::new(0),
            vector_warp_steps: AtomicU64::new(0),
            frontier_stores: AtomicU64::new(0),
            frontier_resumes: AtomicU64::new(0),
            tracer: Arc::new(Tracer::disabled()),
        }
    }
}

impl Pipeline {
    pub fn new() -> Pipeline {
        Pipeline::default()
    }

    /// A pipeline with non-default emulation limits.
    pub fn with_limits(limits: Limits) -> Pipeline {
        Pipeline {
            limits,
            ..Pipeline::default()
        }
    }

    /// Use `n` worker threads inside each simulation (the CLI
    /// `--sim-threads` flag). Orthogonal to the coordinator's task-level
    /// parallelism; useful when a few large kernels dominate wall time.
    pub fn with_sim_threads(mut self, n: usize) -> Pipeline {
        self.sim_threads = n;
        self
    }

    /// Worker threads each simulation runs with.
    pub fn sim_threads(&self) -> usize {
        self.sim_threads.max(1)
    }

    /// Enable the cross-block read-after-write diagnostic (the CLI
    /// `--detect-races` flag): every simulation runs on the serial engine
    /// with a load-side shadow, and a kernel whose block reads bytes an
    /// earlier block wrote fails hard with
    /// [`SimError::CrossBlockRace`](crate::sim::SimError). Never cached
    /// on disk (see the field docs).
    pub fn with_detect_races(mut self, on: bool) -> Pipeline {
        self.detect_races = on;
        self
    }

    /// Whether the cross-block read-after-write diagnostic is on.
    pub fn detect_races(&self) -> bool {
        self.detect_races
    }

    /// Select the decoded engine's execution paths (the CLI `--engine`
    /// flag): the superblock fast path and the lane-vectorized kernels.
    /// Both default to on. Results are bit-identical in every
    /// combination (differential-tested), so neither is part of any
    /// cache key — the flags only matter for throughput and telemetry.
    pub fn with_engine(mut self, superblocks: bool, vector: bool) -> Pipeline {
        self.superblocks = superblocks;
        self.vector = vector;
        self
    }

    /// The decoded-engine path selection as `(superblocks, vector)`.
    pub fn engine(&self) -> (bool, bool) {
        (self.superblocks, self.vector)
    }

    /// Resume budget-tripped emulations from the frontier images a
    /// *tighter* pipeline left in the shared disk store (serve mode's
    /// widened retry: the tight pass persists its exploration frontier;
    /// this pipeline picks up the worklist there instead of re-emulating
    /// from flow zero). `tight` must be dominated by this pipeline's own
    /// limits on every axis or the frontier is ignored.
    pub fn with_resume_from(mut self, tight: Limits) -> Pipeline {
        self.resume_from = Some(tight);
        self
    }

    /// The tight-limits key family this pipeline resumes from, if any.
    pub fn resume_from(&self) -> Option<Limits> {
        self.resume_from
    }

    /// Fold one simulation's engine telemetry into the pipeline-wide
    /// counters (the validate stage calls this after every run).
    pub(crate) fn note_engine_stats(&self, s: &crate::sim::SimStats) {
        self.superblocks_entered
            .fetch_add(s.superblocks_entered, Ordering::Relaxed);
        self.vector_warp_steps
            .fetch_add(s.vector_warp_steps, Ordering::Relaxed);
    }

    /// Attach a span tracer (shared, so serve mode and the attached
    /// [`DiskStore`] can record into the same ring).
    pub fn with_tracer(mut self, tracer: Arc<Tracer>) -> Pipeline {
        self.tracer = tracer;
        self
    }

    /// The span tracer every stage of this pipeline records into.
    pub fn tracer(&self) -> &Tracer {
        &self.tracer
    }

    /// The tracer as a shareable handle.
    pub fn tracer_shared(&self) -> Arc<Tracer> {
        self.tracer.clone()
    }

    /// Attach an on-disk artifact store; detected/synthesized/validated/
    /// scored artifacts persist across pipelines and processes.
    pub fn with_disk(self, store: DiskStore) -> Pipeline {
        self.with_disk_shared(Arc::new(store))
    }

    /// Attach an *already shared* disk store — serve mode runs a tight-
    /// and a wide-limits pipeline over one store (one set of counters,
    /// one eviction lock holder per process).
    pub fn with_disk_shared(mut self, store: Arc<DiskStore>) -> Pipeline {
        self.store = Some(store);
        self
    }

    /// The attached disk store, if any.
    pub fn disk(&self) -> Option<&DiskStore> {
        self.store.as_deref()
    }

    /// The attached disk store as a shareable handle, if any.
    pub fn disk_shared(&self) -> Option<Arc<DiskStore>> {
        self.store.clone()
    }

    /// The emulation limits this pipeline runs (and keys artifacts) under.
    pub fn limits(&self) -> Limits {
        self.limits
    }

    /// The interner session every emulation of this pipeline shares.
    pub fn session(&self) -> &Arc<SessionInterner> {
        &self.session
    }

    /// The underlying artifact store (counters, residency).
    pub fn cache(&self) -> &ArtifactCache {
        &self.cache
    }

    /// Time a closure against a stage's wall-time counters and record a
    /// `stage.<name>` span.
    pub fn time<T>(&self, stage: Stage, f: impl FnOnce() -> T) -> T {
        let span = self.tracer.begin();
        let t0 = Instant::now();
        let out = f();
        self.timings.record(stage, t0.elapsed());
        self.tracer.span("stage", stage.span_name(), span, Vec::new);
        out
    }

    /// Record an artifact cache lookup's provenance (hit / disk_hit /
    /// miss) as an instant event.
    fn trace_artifact(&self, kind: ArtifactKind, key: ContentHash, event: CacheEvent) {
        self.tracer.instant("artifact", kind.span_name(), || {
            vec![
                ("key", ArgVal::Str(key.to_string())),
                ("provenance", ArgVal::Str(event.name().to_string())),
            ]
        });
    }

    fn disk_load<T>(
        &self,
        kind: StoreKind,
        key: ContentHash,
        decode: impl FnOnce(&[u8]) -> Option<T>,
    ) -> Option<T> {
        self.store.as_ref()?.load_decoded(kind, key, decode)
    }

    fn disk_store(&self, kind: StoreKind, key: ContentHash, payload: Vec<u8>) {
        if let Some(s) = &self.store {
            s.store(kind, key, &payload);
        }
    }

    /// Admit an already-built kernel (e.g. from the suite generator),
    /// computing its content address.
    pub fn intake(&self, kernel: Kernel) -> Parsed {
        self.time(Stage::Parse, || {
            let kernel = Arc::new(kernel);
            let hash = kernel_fingerprint(&kernel);
            Parsed { kernel, hash }
        })
    }

    /// Parse PTX source into per-kernel [`Parsed`] artifacts.
    pub fn parse_source(&self, src: &str) -> Result<Vec<Parsed>, ParseError> {
        self.time(Stage::Parse, || {
            let module = parse(src)?;
            Ok(module
                .kernels
                .into_iter()
                .map(|k| {
                    let kernel = Arc::new(k);
                    let hash = kernel_fingerprint(&kernel);
                    Parsed { kernel, hash }
                })
                .collect())
        })
    }

    /// Workload artifact: simulator launch + deterministic inputs + CPU
    /// reference for a benchmark, generated once per
    /// [`WorkloadFingerprint`] and shared by the baseline and every
    /// variant. In-memory only — regeneration is cheap and deterministic;
    /// the expensive artifacts *derived* from it persist to disk.
    pub fn workload_art(
        &self,
        b: &Benchmark,
        (nx, ny, nz): (usize, usize, usize),
        seed: u64,
    ) -> Arc<WorkloadArt> {
        let fingerprint = crate::suite::workload_fingerprint(b, nx, ny, nz, seed);
        let slot = self.cache.workload_slot(fingerprint);
        let mut event = CacheEvent::Hit;
        let out = slot
            .get_or_init(|| {
                event = CacheEvent::Miss;
                self.time(Stage::Workload, || {
                    Arc::new(WorkloadArt {
                        workload: crate::suite::workload(b, nx, ny, nz, seed),
                        fingerprint,
                    })
                })
            })
            .clone();
        self.cache.counters.record(ArtifactKind::Workload, event);
        self.tracer
            .instant("artifact", ArtifactKind::Workload.span_name(), || {
                vec![
                    ("key", ArgVal::Str(fingerprint.to_string())),
                    ("provenance", ArgVal::Str(event.name().to_string())),
                ]
            });
        out
    }

    fn decode_disk_key(hash: ContentHash) -> ContentHash {
        KeyBuilder::new("decoded").hash(hash).finish()
    }

    /// Decoded micro-op artifact for a kernel version: the one-time
    /// lowering the concrete simulator executes, keyed by the kernel
    /// fingerprint alone (workload-independent) and persisted in the
    /// disk store's `decoded/` kind, so a fresh process on a warm cache
    /// dir performs zero decodes for previously seen kernels. The hash
    /// must be `kernel_fingerprint(kernel)`.
    pub fn decoded(
        &self,
        kernel: &Arc<Kernel>,
        hash: ContentHash,
    ) -> Result<Arc<crate::sim::DecodedKernel>, SimError> {
        let slot = self.cache.decode_slot(hash);
        let mut event = CacheEvent::Hit;
        let out = slot
            .get_or_init(|| {
                let dkey = Pipeline::decode_disk_key(hash);
                if let Some(dk) = self.disk_load(StoreKind::Decoded, dkey, store::decode_decoded)
                {
                    event = CacheEvent::DiskHit;
                    return Ok(Arc::new(dk));
                }
                event = CacheEvent::Miss;
                let dk = self.time(Stage::Decode, || {
                    crate::sim::decode(kernel).map(Arc::new)
                })?;
                self.disk_store(StoreKind::Decoded, dkey, store::encode_decoded(&dk));
                Ok(dk)
            })
            .clone();
        self.cache.counters.record(ArtifactKind::Decoded, event);
        self.trace_artifact(ArtifactKind::Decoded, hash, event);
        out
    }

    /// Emulation artifact for a kernel (computing the hash here).
    pub fn emulated(&self, kernel: &Arc<Kernel>) -> Result<Arc<Emulated>, EmuError> {
        self.emulated_hashed(kernel, kernel_fingerprint(kernel))
    }

    /// Disk key of an emulation: the kernel fingerprint *plus the
    /// emulation limits* — two processes sharing one cache dir with
    /// different limits must not exchange results (a tighter limit can
    /// change which flows finish).
    fn emulate_disk_key(hash: ContentHash, limits: Limits) -> ContentHash {
        KeyBuilder::new("emulated").hash(hash).limits(limits).finish()
    }

    /// Disk key of the resumable frontier a budget-tripped emulation
    /// leaves behind: the kernel fingerprint plus the limits that ran
    /// out. A separate key family from complete images so a wider reader
    /// can probe for a tight run's frontier without colliding with its
    /// own results.
    fn frontier_disk_key(hash: ContentHash, limits: Limits) -> ContentHash {
        KeyBuilder::new("emulated.frontier")
            .hash(hash)
            .limits(limits)
            .finish()
    }

    /// Load a tighter run's frontier image for this kernel, if resume is
    /// configured, the store holds one, and this pipeline's limits
    /// dominate the tight ones on every axis (a narrower "resume" could
    /// not reproduce the serial result and is never attempted).
    fn load_frontier(
        &self,
        kernel: &Arc<Kernel>,
        hash: ContentHash,
    ) -> Option<crate::emu::PartialEmulation> {
        let tight = self.resume_from?;
        if self.limits.max_flows < tight.max_flows
            || self.limits.max_steps_per_flow < tight.max_steps_per_flow
            || self.limits.max_total_steps < tight.max_total_steps
        {
            return None;
        }
        let key = Pipeline::frontier_disk_key(hash, tight);
        let nregs = crate::emu::env::RegInterner::from_kernel(kernel).len();
        self.disk_load(StoreKind::Emulated, key, |b| {
            store::decode_frontier(b, &self.session, Some(nregs)).map(|(_, p)| p)
        })
    }

    /// Emulation artifact when the caller already knows the content hash.
    /// The hash must be `kernel_fingerprint(kernel)`. Served in order
    /// from the in-memory slot, the disk store's `emulated/` kind (the
    /// relocatable term-graph image decodes into this pipeline's
    /// session — zero symbolic emulations on a warm cache dir), and only
    /// then computed fresh.
    pub fn emulated_hashed(
        &self,
        kernel: &Arc<Kernel>,
        hash: ContentHash,
    ) -> Result<Arc<Emulated>, EmuError> {
        let slot = self.cache.emu_slot(hash);
        let mut event = CacheEvent::Hit;
        let out = slot
            .get_or_init(|| {
                let dkey = Pipeline::emulate_disk_key(hash, self.limits);
                if let Some(art) = self.disk_load(StoreKind::Emulated, dkey, |b| {
                    store::decode_emulated(b, kernel, hash, &self.session)
                }) {
                    event = CacheEvent::DiskHit;
                    return Ok(Arc::new(art));
                }
                event = CacheEvent::Miss;
                let span = self.tracer.begin();
                let t0 = Instant::now();
                // a tighter run may have left a resumable frontier: pick
                // the exploration up at its worklist instead of
                // re-emulating from flow zero
                let frontier = self.load_frontier(kernel, hash);
                let resumed = frontier.is_some();
                let outcome = match frontier {
                    Some(part) => {
                        resume_outcome(kernel, self.limits, part, Some(self.tracer.clone()))
                    }
                    None => emulate_outcome(
                        kernel,
                        self.limits,
                        self.session.clone(),
                        Some(self.tracer.clone()),
                    ),
                };
                let elapsed = t0.elapsed();
                let result = match outcome {
                    EmuOutcome::Complete(r) => {
                        if resumed {
                            self.frontier_resumes.fetch_add(1, Ordering::Relaxed);
                        }
                        r
                    }
                    EmuOutcome::Partial(part) => {
                        // leave the frontier behind so a wider retry can
                        // resume where this budget ran out
                        self.frontier_stores.fetch_add(1, Ordering::Relaxed);
                        self.disk_store(
                            StoreKind::Emulated,
                            Pipeline::frontier_disk_key(hash, self.limits),
                            store::encode_frontier(elapsed, &part),
                        );
                        let e = part.error;
                        // budget exhaustion is the span worth having:
                        // record which limit the kernel ran into
                        self.tracer.span("stage", "stage.emulate", span, || {
                            vec![
                                ("key", ArgVal::Str(hash.to_string())),
                                ("error", ArgVal::Str(e.to_string())),
                                ("resumed", ArgVal::U64(u64::from(resumed))),
                                ("max_flows", ArgVal::U64(self.limits.max_flows as u64)),
                                ("max_total_steps", ArgVal::U64(self.limits.max_total_steps)),
                            ]
                        });
                        return Err(e);
                    }
                    EmuOutcome::Failed(e) => {
                        self.tracer.span("stage", "stage.emulate", span, || {
                            vec![
                                ("key", ArgVal::Str(hash.to_string())),
                                ("error", ArgVal::Str(e.to_string())),
                                ("resumed", ArgVal::U64(u64::from(resumed))),
                                ("max_flows", ArgVal::U64(self.limits.max_flows as u64)),
                                ("max_total_steps", ArgVal::U64(self.limits.max_total_steps)),
                            ]
                        });
                        return Err(e);
                    }
                };
                self.timings.record(Stage::Emulate, elapsed);
                let (flows_started, flows_finished, steps) = (
                    result.stats.flows_started,
                    result.stats.flows_finished,
                    result.stats.steps,
                );
                let truncated = result
                    .flows
                    .iter()
                    .filter(|f| f.end == FlowEnd::StepLimit)
                    .count() as u64;
                self.tracer.span("stage", "stage.emulate", span, || {
                    vec![
                        ("key", ArgVal::Str(hash.to_string())),
                        ("flows_started", ArgVal::U64(flows_started)),
                        ("flows_finished", ArgVal::U64(flows_finished)),
                        ("steps", ArgVal::U64(steps)),
                        ("truncated_flows", ArgVal::U64(truncated)),
                        ("resumed", ArgVal::U64(u64::from(resumed))),
                        ("max_flows", ArgVal::U64(self.limits.max_flows as u64)),
                        ("max_total_steps", ArgVal::U64(self.limits.max_total_steps)),
                    ]
                });
                let art = Emulated {
                    kernel: kernel.clone(),
                    hash,
                    result,
                    elapsed,
                };
                self.disk_store(StoreKind::Emulated, dkey, store::encode_emulated(&art));
                Ok(Arc::new(art))
            })
            .clone();
        self.cache.counters.record(ArtifactKind::Emulated, event);
        self.trace_artifact(ArtifactKind::Emulated, hash, event);
        out
    }

    /// Detection/synthesis disk keys carry the emulation limits too: both
    /// are derived from the emulation, so a result computed under a tight
    /// budget must never satisfy a reader running a wider one (serve mode
    /// deliberately runs both over one cache dir).
    fn detect_disk_key(hash: ContentHash, opts: DetectOpts, limits: Limits) -> ContentHash {
        KeyBuilder::new("detected")
            .hash(hash)
            .opts(opts)
            .limits(limits)
            .finish()
    }

    /// Detection artifact; consumes the cached [`Emulated`] artifact —
    /// `detect` itself never emulates, and a disk-served detection skips
    /// the emulation too.
    pub fn detected(
        &self,
        kernel: &Arc<Kernel>,
        opts: DetectOpts,
    ) -> Result<Arc<Detected>, EmuError> {
        self.detected_hashed(kernel, kernel_fingerprint(kernel), opts)
    }

    pub fn detected_hashed(
        &self,
        kernel: &Arc<Kernel>,
        hash: ContentHash,
        opts: DetectOpts,
    ) -> Result<Arc<Detected>, EmuError> {
        let key = (hash, opts);
        let slot = self.cache.detect_slot(key);
        let mut event = CacheEvent::Hit;
        let out = slot
            .get_or_init(|| {
                let dkey = Pipeline::detect_disk_key(hash, opts, self.limits);
                if let Some(art) = self.disk_load(StoreKind::Detected, dkey, store::decode_detected)
                {
                    event = CacheEvent::DiskHit;
                    return Ok(Arc::new(art));
                }
                event = CacheEvent::Miss;
                let emu = self.emulated_hashed(kernel, hash)?;
                let span = self.tracer.begin();
                let t0 = Instant::now();
                let detection = detect(kernel, &emu.result, opts);
                let elapsed = t0.elapsed();
                self.timings.record(Stage::Detect, elapsed);
                let (chosen, total_loads) = (
                    detection.chosen.len() as u64,
                    detection.total_global_loads as u64,
                );
                self.tracer.span("stage", "stage.detect", span, || {
                    vec![
                        ("key", ArgVal::Str(hash.to_string())),
                        ("shuffles_chosen", ArgVal::U64(chosen)),
                        ("total_global_loads", ArgVal::U64(total_loads)),
                    ]
                });
                let art = Detected {
                    detection,
                    elapsed,
                    emu_elapsed: emu.elapsed,
                };
                self.disk_store(StoreKind::Detected, dkey, store::encode_detected(&art));
                Ok(Arc::new(art))
            })
            .clone();
        self.cache.counters.record(ArtifactKind::Detected, event);
        self.trace_artifact(ArtifactKind::Detected, hash, event);
        out
    }

    fn synth_disk_key(
        hash: ContentHash,
        opts: DetectOpts,
        variant: Variant,
        elim: ElimOpts,
        limits: Limits,
    ) -> ContentHash {
        KeyBuilder::new("synthesized")
            .hash(hash)
            .opts(opts)
            .u64(store::variant_key_byte(variant))
            .elim(elim)
            .limits(limits)
            .finish()
    }

    /// Synthesized-variant artifact; reuses the cached detection (and
    /// through it the single emulation). After synthesis the
    /// phase-liveness elimination pass ([`crate::shuffle::eliminate`])
    /// runs with `elim` — an identity transform when disabled or when
    /// nothing is provable; its verdicts travel in the artifact.
    pub fn synthesized(
        &self,
        kernel: &Arc<Kernel>,
        opts: DetectOpts,
        variant: Variant,
        elim: ElimOpts,
    ) -> Result<Arc<Synthesized>, EmuError> {
        self.synthesized_hashed(kernel, kernel_fingerprint(kernel), opts, variant, elim)
    }

    pub fn synthesized_hashed(
        &self,
        kernel: &Arc<Kernel>,
        hash: ContentHash,
        opts: DetectOpts,
        variant: Variant,
        elim: ElimOpts,
    ) -> Result<Arc<Synthesized>, EmuError> {
        let key = (hash, opts, variant, elim);
        let slot = self.cache.synth_slot(key);
        let mut event = CacheEvent::Hit;
        let out = slot
            .get_or_init(|| {
                let dkey = Pipeline::synth_disk_key(hash, opts, variant, elim, self.limits);
                if let Some(art) =
                    self.disk_load(StoreKind::Synthesized, dkey, store::decode_synthesized)
                {
                    event = CacheEvent::DiskHit;
                    return Ok(Arc::new(art));
                }
                event = CacheEvent::Miss;
                let det = self.detected_hashed(kernel, hash, opts)?;
                // the elimination pass re-reads the source kernel's
                // symbolic trace; served from the in-memory slot the
                // detection above already filled (or from disk)
                let emu = if elim.enabled {
                    Some(self.emulated_hashed(kernel, hash)?)
                } else {
                    None
                };
                let span = self.tracer.begin();
                let t0 = Instant::now();
                let synthesized = synthesize(kernel, &det.detection, variant);
                let (final_kernel, elim_report) = match &emu {
                    Some(emu) => eliminate(&synthesized, kernel, &emu.result, elim),
                    None => (synthesized, ElimReport::disabled()),
                };
                self.timings.record(Stage::Synthesize, t0.elapsed());
                let (deleted, elided) = (
                    elim_report.deleted_stores() as u64,
                    elim_report.elided_barriers() as u64,
                );
                self.tracer.span("stage", "stage.synthesize", span, || {
                    vec![
                        ("key", ArgVal::Str(hash.to_string())),
                        ("variant", ArgVal::Str(variant.name().to_string())),
                        ("elim_deleted_stores", ArgVal::U64(deleted)),
                        ("elim_elided_barriers", ArgVal::U64(elided)),
                    ]
                });
                crate::shuffle::elim::trace_report(&self.tracer, hash, &elim_report);
                let art = Synthesized {
                    hash: kernel_fingerprint(&final_kernel),
                    kernel: Arc::new(final_kernel),
                    variant,
                    source: hash,
                    elim: elim_report,
                };
                self.disk_store(StoreKind::Synthesized, dkey, store::encode_synthesized(&art));
                Ok(Arc::new(art))
            })
            .clone();
        self.cache
            .counters
            .record(ArtifactKind::Synthesized, event);
        self.trace_artifact(ArtifactKind::Synthesized, hash, event);
        out
    }

    fn validate_disk_key(
        hash: ContentHash,
        wfp: WorkloadFingerprint,
        baseline: Option<ContentHash>,
    ) -> ContentHash {
        let mut k = KeyBuilder::new("validated");
        k.hash(hash).u64(wfp.0).u64(wfp.1);
        match baseline {
            None => k.u64(0),
            Some(b) => k.u64(1).hash(b),
        };
        k.finish()
    }

    /// Validated artifact: one simulator execution of a kernel version
    /// over a workload, keyed by (kernel, workload, baseline). `baseline`
    /// carries the content hash of the baseline kernel plus its output —
    /// the bit-exactness verdict is part of the artifact.
    pub fn validated(
        &self,
        kernel: &Arc<Kernel>,
        hash: ContentHash,
        w: &WorkloadArt,
        baseline: Option<(ContentHash, &[f32])>,
    ) -> Result<Arc<Validated>, SimError> {
        let key = (hash, w.fingerprint, baseline.map(|(h, _)| h));
        let slot = self.cache.validate_slot(key);
        let mut event = CacheEvent::Hit;
        let out = slot
            .get_or_init(|| {
                // diagnostic runs never touch the disk store: a verdict
                // simulated without the race shadow must not satisfy a
                // `--detect-races` query, and vice versa
                let dkey =
                    Pipeline::validate_disk_key(hash, w.fingerprint, baseline.map(|(h, _)| h));
                if !self.detect_races {
                    if let Some(art) =
                        self.disk_load(StoreKind::Validated, dkey, store::decode_validated)
                    {
                        event = CacheEvent::DiskHit;
                        return Ok(Arc::new(art));
                    }
                }
                event = CacheEvent::Miss;
                let v =
                    stages::validate(self, kernel, hash, &w.workload, baseline.map(|(_, o)| o))?;
                if !self.detect_races {
                    self.disk_store(StoreKind::Validated, dkey, store::encode_validated(&v));
                }
                Ok(Arc::new(v))
            })
            .clone();
        self.cache.counters.record(ArtifactKind::Validated, event);
        self.trace_artifact(ArtifactKind::Validated, hash, event);
        out
    }

    fn score_disk_key(hash: ContentHash, wfp: WorkloadFingerprint, arch: &str) -> ContentHash {
        KeyBuilder::new("scored")
            .hash(hash)
            .u64(wfp.0)
            .u64(wfp.1)
            .bytes(arch.as_bytes())
            .finish()
    }

    /// Scored artifact: the latency-model report for one (kernel,
    /// workload, architecture) triple.
    pub fn scored(
        &self,
        kernel: &Arc<Kernel>,
        hash: ContentHash,
        wfp: WorkloadFingerprint,
        v: &Validated,
        arch: &'static Arch,
    ) -> Arc<Scored> {
        let key = (hash, wfp, arch.name);
        let slot = self.cache.score_slot(key);
        let mut event = CacheEvent::Hit;
        let out = slot
            .get_or_init(|| {
                let dkey = Pipeline::score_disk_key(hash, wfp, arch.name);
                if let Some(art) = self.disk_load(StoreKind::Scored, dkey, store::decode_scored) {
                    event = CacheEvent::DiskHit;
                    return Arc::new(art);
                }
                event = CacheEvent::Miss;
                let s = stages::score(self, kernel, v, arch);
                self.disk_store(StoreKind::Scored, dkey, store::encode_scored(&s));
                Arc::new(s)
            })
            .clone();
        self.cache.counters.record(ArtifactKind::Scored, event);
        self.trace_artifact(ArtifactKind::Scored, hash, event);
        out
    }

    /// Snapshot of cache counters (memory + disk) and per-stage timings.
    pub fn stats(&self) -> PipelineStats {
        let mut s = PipelineStats {
            cache: self.cache.counters.snapshot(),
            disk: self
                .store
                .as_ref()
                .map(|d| d.snapshot())
                .unwrap_or_default(),
            ..Default::default()
        };
        for stage in STAGES {
            let i = stage.index();
            s.stage_nanos[i] = self.timings.nanos[i].load(Ordering::Relaxed);
            s.stage_runs[i] = self.timings.runs[i].load(Ordering::Relaxed);
            s.stage_hist[i] = self.timings.hist[i].snapshot();
        }
        s.superblocks_entered = self.superblocks_entered.load(Ordering::Relaxed);
        s.vector_warp_steps = self.vector_warp_steps.load(Ordering::Relaxed);
        s.frontier_stores = self.frontier_stores.load(Ordering::Relaxed);
        s.frontier_resumes = self.frontier_resumes.load(Ordering::Relaxed);
        s
    }

    /// The unified metrics view of this pipeline (see [`metrics_snapshot`])
    /// plus the tracer's own gauges.
    pub fn metrics(&self) -> MetricsSnapshot {
        let mut m = metrics_snapshot(&self.stats());
        m.counter("trace.events", self.tracer.len() as u64);
        m.counter("trace.dropped", self.tracer.dropped());
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ptx::parser::parse_kernel;

    const K: &str = r#"
.visible .entry s3(.param .u64 out, .param .u64 a){
.reg .b32 %r<6>; .reg .b64 %rd<8>; .reg .f32 %f<6>;
ld.param.u64 %rd1, [out];
ld.param.u64 %rd2, [a];
cvta.to.global.u64 %rd3, %rd2;
cvta.to.global.u64 %rd4, %rd1;
mov.u32 %r4, %tid.x;
mul.wide.s32 %rd5, %r4, 4;
add.s64 %rd6, %rd3, %rd5;
ld.global.nc.f32 %f1, [%rd6];
ld.global.nc.f32 %f2, [%rd6+4];
ld.global.nc.f32 %f3, [%rd6+8];
add.f32 %f4, %f1, %f2;
add.f32 %f5, %f4, %f3;
add.s64 %rd7, %rd4, %rd5;
st.global.f32 [%rd7], %f5;
ret;
}
"#;

    #[test]
    fn emulation_is_computed_once_per_content_hash() {
        let p = Pipeline::new();
        let k = Arc::new(parse_kernel(K).unwrap());
        let e1 = p.emulated(&k).unwrap();
        // a *separately parsed* but identical kernel hits the same slot
        let k2 = Arc::new(parse_kernel(K).unwrap());
        let e2 = p.emulated(&k2).unwrap();
        assert!(Arc::ptr_eq(&e1, &e2), "identical kernels must share the artifact");
        let s = p.stats().cache;
        assert_eq!(s.emulate_misses, 1);
        assert_eq!(s.emulate_hits, 1);
        assert_eq!(p.cache().emulated_len(), 1);
    }

    #[test]
    fn variants_share_one_emulation() {
        let p = Pipeline::new();
        let k = Arc::new(parse_kernel(K).unwrap());
        let opts = DetectOpts::default();
        for v in [Variant::NoLoad, Variant::NoCorner, Variant::Full] {
            let s = p.synthesized(&k, opts, v, ElimOpts::default()).unwrap();
            assert_eq!(s.variant, v);
        }
        let s = p.stats();
        assert_eq!(s.cache.emulate_misses, 1, "exactly one emulation");
        // each synth miss re-reads the emulation for the elimination
        // pass — from the in-memory slot detection already filled
        assert_eq!(s.cache.emulate_hits, 3);
        assert_eq!(s.cache.detect_misses, 1, "exactly one detection");
        // each variant after the first found the detection in the cache
        assert_eq!(s.cache.detect_hits, 2);
        assert_eq!(s.cache.synth_misses, 3);
        // stage counters saw one emulate pass and three synthesize passes
        assert_eq!(s.stage_count(Stage::Emulate), 1);
        assert_eq!(s.stage_count(Stage::Synthesize), 3);
    }

    #[test]
    fn mutated_kernel_gets_its_own_artifact() {
        let p = Pipeline::new();
        let k = Arc::new(parse_kernel(K).unwrap());
        let mutated = Arc::new(parse_kernel(&K.replace("[%rd6+8]", "[%rd6+12]")).unwrap());
        p.emulated(&k).unwrap();
        p.emulated(&mutated).unwrap();
        let s = p.stats().cache;
        assert_eq!(s.emulate_misses, 2);
        assert_eq!(s.emulate_hits, 0);
        assert_eq!(p.cache().emulated_len(), 2);
    }

    #[test]
    fn parse_source_assigns_content_addresses() {
        let p = Pipeline::new();
        let src = format!(".version 7.6\n.target sm_70\n.address_size 64\n{K}");
        let parsed = p.parse_source(&src).unwrap();
        assert_eq!(parsed.len(), 1);
        let again = p.intake((*parsed[0].kernel).clone());
        assert_eq!(parsed[0].hash, again.hash);
    }

    #[test]
    fn workload_is_generated_once_per_fingerprint() {
        let p = Pipeline::new();
        let b = crate::suite::by_name("vecadd").unwrap();
        let w1 = p.workload_art(&b, (16, 2, 1), 42);
        let w2 = p.workload_art(&b, (16, 2, 1), 42);
        assert!(Arc::ptr_eq(&w1, &w2), "same inputs must share the artifact");
        // different seed → different artifact
        let w3 = p.workload_art(&b, (16, 2, 1), 43);
        assert_ne!(w1.fingerprint, w3.fingerprint);
        let s = p.stats().cache;
        assert_eq!(s.workload_misses, 2);
        assert_eq!(s.workload_hits, 1);
        assert_eq!(s.stage_count(Stage::Workload), 2);
    }

    #[test]
    fn widened_pipeline_resumes_a_tight_runs_frontier_image() {
        // four flows of fan-out: a tight 2-flow budget trips mid-way
        let forky = r#"
.visible .entry fk(.param .u64 out){
.reg .b32 %r<8>; .reg .b64 %rd<4>; .reg .pred %p<4>;
ld.param.u64 %rd1, [out];
cvta.to.global.u64 %rd2, %rd1;
mov.u32 %r1, %tid.x;
and.b32 %r2, %r1, 1;
setp.eq.s32 %p1, %r2, 0;
@%p1 bra $A;
add.s32 %r1, %r1, 7;
$A:
and.b32 %r3, %r1, 2;
setp.eq.s32 %p2, %r3, 0;
@%p2 bra $B;
add.s32 %r1, %r1, 9;
$B:
st.global.u32 [%rd2], %r1;
ret;
}
"#;
        let dir = std::env::temp_dir().join(format!(
            "ptxasw-pipe-resume-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let store = Arc::new(DiskStore::open(&dir, 1 << 20).unwrap());
        let k = Arc::new(parse_kernel(forky).unwrap());

        let tight = Limits {
            max_flows: 2,
            ..Limits::default()
        };
        let tp = Pipeline::with_limits(tight).with_disk_shared(store.clone());
        let err = tp.emulated(&k).unwrap_err();
        assert!(matches!(err, EmuError::FlowLimit(2)), "{err:?}");
        assert_eq!(tp.stats().frontier_stores, 1, "trip must persist a frontier");

        // the widened pipeline resumes the image instead of re-emulating
        let wp = Pipeline::with_limits(Limits::default())
            .with_disk_shared(store.clone())
            .with_resume_from(tight);
        let warm = wp.emulated(&k).unwrap();
        assert_eq!(
            wp.stats().frontier_resumes,
            1,
            "wide retry must resume the tight frontier, not start cold"
        );

        // and the resumed artifact is indistinguishable from a cold run
        let cold = Pipeline::with_limits(Limits::default()).emulated(&k).unwrap();
        assert_eq!(warm.result.stats.to_words(), cold.result.stats.to_words());
        assert_eq!(warm.result.flows.len(), cold.result.flows.len());
        for (a, b) in warm.result.flows.iter().zip(&cold.result.flows) {
            assert_eq!((a.id, a.end), (b.id, b.end));
            assert_eq!(a.trace.loads.len(), b.trace.loads.len());
            assert_eq!(a.trace.stores.len(), b.trace.stores.len());
        }

        // a pipeline with *no* resume configured starts cold and still
        // agrees (fresh store-less pipeline, fresh session)
        let np = Pipeline::with_limits(Limits::default());
        let n = np.emulated(&k).unwrap();
        assert_eq!(np.stats().frontier_resumes, 0);
        assert_eq!(n.result.stats.to_words(), cold.result.stats.to_words());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn validated_and_scored_are_workload_keyed() {
        let p = Pipeline::new();
        let b = crate::suite::by_name("vecadd").unwrap();
        let w = p.workload_art(&b, (16, 2, 2), 7);
        let parsed = p.intake(w.workload.kernel.clone());
        let arch = crate::perf::by_name("Pascal").unwrap();

        let v1 = p.validated(&parsed.kernel, parsed.hash, &w, None).unwrap();
        let v2 = p.validated(&parsed.kernel, parsed.hash, &w, None).unwrap();
        assert!(Arc::ptr_eq(&v1, &v2), "second validation must be a cache hit");
        let s1 = p.scored(&parsed.kernel, parsed.hash, w.fingerprint, &v1, arch);
        let s2 = p.scored(&parsed.kernel, parsed.hash, w.fingerprint, &v1, arch);
        assert!(Arc::ptr_eq(&s1, &s2));

        let s = p.stats();
        assert_eq!(s.cache.validate_misses, 1);
        assert_eq!(s.cache.validate_hits, 1);
        assert_eq!(s.cache.score_misses, 1);
        assert_eq!(s.cache.score_hits, 1);
        assert_eq!(s.stage_count(Stage::Validate), 1, "one simulation");
        assert_eq!(s.stage_count(Stage::Score), 1);

        // a different workload re-simulates
        let w2 = p.workload_art(&b, (16, 2, 2), 8);
        p.validated(&parsed.kernel, parsed.hash, &w2, None).unwrap();
        assert_eq!(p.stats().cache.validate_misses, 2);
    }
}
