//! The staged pass manager for the PTXASW pipeline.
//!
//! The paper's tool is a tail-of-pipeline phase; production traffic needs
//! the phases to be explicit, cacheable and batched. This module stages
//! the end-to-end flow as typed artifacts
//!
//! ```text
//! Parsed → Emulated → Detected → Synthesized → Validated → Scored
//! ```
//!
//! with the first four stages content-addressed by a stable hash of the
//! kernel ([`crate::ptx::kernel_fingerprint`]) and stored in a
//! thread-safe [`ArtifactCache`]. A [`Pipeline`] owns one
//! [`SessionInterner`] shared by every emulation it runs, so symbol and
//! UF names (`%tid.x`, params, `load.global.*`) are interned once per
//! session instead of once per kernel. Per-stage wall time and cache
//! hit/miss counters are exposed through [`Pipeline::stats`] for the CLI
//! `--stats` flag and the coordinator's suite report.

pub mod artifact;
pub mod stages;

pub use artifact::{
    ArtifactCache, ArtifactKind, CacheSnapshot, Detected, Emulated, Parsed, Synthesized,
};
pub use stages::{score, validate, Scored, Validated};

use crate::emu::{emulate_in_session, EmuError, Limits};
use crate::ptx::ast::Kernel;
use crate::ptx::parser::{parse, ParseError};
use crate::ptx::printer::{kernel_fingerprint, ContentHash};
use crate::shuffle::{detect, synthesize, DetectOpts, Variant};
use crate::sym::SessionInterner;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// The pipeline's stages, in execution order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stage {
    Parse,
    Emulate,
    Detect,
    Synthesize,
    Validate,
    Score,
}

/// All stages in execution order (for reports).
pub const STAGES: [Stage; 6] = [
    Stage::Parse,
    Stage::Emulate,
    Stage::Detect,
    Stage::Synthesize,
    Stage::Validate,
    Stage::Score,
];

impl Stage {
    pub fn name(self) -> &'static str {
        match self {
            Stage::Parse => "parse",
            Stage::Emulate => "emulate",
            Stage::Detect => "detect",
            Stage::Synthesize => "synthesize",
            Stage::Validate => "validate",
            Stage::Score => "score",
        }
    }

    pub fn index(self) -> usize {
        match self {
            Stage::Parse => 0,
            Stage::Emulate => 1,
            Stage::Detect => 2,
            Stage::Synthesize => 3,
            Stage::Validate => 4,
            Stage::Score => 5,
        }
    }
}

/// Accumulated wall time and invocation counts per stage.
#[derive(Debug, Default)]
struct StageTimings {
    nanos: [AtomicU64; STAGES.len()],
    runs: [AtomicU64; STAGES.len()],
}

impl StageTimings {
    fn record(&self, stage: Stage, elapsed: Duration) {
        let i = stage.index();
        self.nanos[i].fetch_add(elapsed.as_nanos() as u64, Ordering::Relaxed);
        self.runs[i].fetch_add(1, Ordering::Relaxed);
    }
}

/// Point-in-time view of the pipeline's counters.
#[derive(Debug, Clone, Copy, Default)]
pub struct PipelineStats {
    pub cache: CacheSnapshot,
    pub stage_nanos: [u64; STAGES.len()],
    pub stage_runs: [u64; STAGES.len()],
}

impl PipelineStats {
    pub fn stage_time(&self, stage: Stage) -> Duration {
        Duration::from_nanos(self.stage_nanos[stage.index()])
    }

    pub fn stage_count(&self, stage: Stage) -> u64 {
        self.stage_runs[stage.index()]
    }
}

/// The pass manager: shared interner session + artifact cache + counters.
///
/// One `Pipeline` per logical session; `run_suite`-style drivers create a
/// fresh one per call unless handed an existing pipeline to share the
/// cache across runs.
#[derive(Debug, Default)]
pub struct Pipeline {
    session: Arc<SessionInterner>,
    limits: Limits,
    cache: ArtifactCache,
    timings: StageTimings,
}

impl Pipeline {
    pub fn new() -> Pipeline {
        Pipeline::default()
    }

    /// A pipeline with non-default emulation limits.
    pub fn with_limits(limits: Limits) -> Pipeline {
        Pipeline {
            limits,
            ..Pipeline::default()
        }
    }

    /// The interner session every emulation of this pipeline shares.
    pub fn session(&self) -> &Arc<SessionInterner> {
        &self.session
    }

    /// The underlying artifact store (counters, residency).
    pub fn cache(&self) -> &ArtifactCache {
        &self.cache
    }

    /// Time a closure against a stage's wall-time counters.
    pub fn time<T>(&self, stage: Stage, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.timings.record(stage, t0.elapsed());
        out
    }

    /// Admit an already-built kernel (e.g. from the suite generator),
    /// computing its content address.
    pub fn intake(&self, kernel: Kernel) -> Parsed {
        self.time(Stage::Parse, || {
            let kernel = Arc::new(kernel);
            let hash = kernel_fingerprint(&kernel);
            Parsed { kernel, hash }
        })
    }

    /// Parse PTX source into per-kernel [`Parsed`] artifacts.
    pub fn parse_source(&self, src: &str) -> Result<Vec<Parsed>, ParseError> {
        self.time(Stage::Parse, || {
            let module = parse(src)?;
            Ok(module
                .kernels
                .into_iter()
                .map(|k| {
                    let kernel = Arc::new(k);
                    let hash = kernel_fingerprint(&kernel);
                    Parsed { kernel, hash }
                })
                .collect())
        })
    }

    /// Emulation artifact for a kernel (computing the hash here).
    pub fn emulated(&self, kernel: &Arc<Kernel>) -> Result<Arc<Emulated>, EmuError> {
        self.emulated_hashed(kernel, kernel_fingerprint(kernel))
    }

    /// Emulation artifact when the caller already knows the content hash.
    /// The hash must be `kernel_fingerprint(kernel)`.
    pub fn emulated_hashed(
        &self,
        kernel: &Arc<Kernel>,
        hash: ContentHash,
    ) -> Result<Arc<Emulated>, EmuError> {
        let slot = self.cache.emu_slot(hash);
        let mut computed = false;
        let out = slot
            .get_or_init(|| {
                computed = true;
                let t0 = Instant::now();
                let result = emulate_in_session(kernel, self.limits, self.session.clone())?;
                let elapsed = t0.elapsed();
                self.timings.record(Stage::Emulate, elapsed);
                Ok(Arc::new(Emulated {
                    kernel: kernel.clone(),
                    hash,
                    result,
                    elapsed,
                }))
            })
            .clone();
        self.cache.counters.record(ArtifactKind::Emulated, computed);
        out
    }

    /// Detection artifact; consumes the cached [`Emulated`] artifact —
    /// `detect` itself never emulates.
    pub fn detected(
        &self,
        kernel: &Arc<Kernel>,
        opts: DetectOpts,
    ) -> Result<Arc<Detected>, EmuError> {
        self.detected_hashed(kernel, kernel_fingerprint(kernel), opts)
    }

    pub fn detected_hashed(
        &self,
        kernel: &Arc<Kernel>,
        hash: ContentHash,
        opts: DetectOpts,
    ) -> Result<Arc<Detected>, EmuError> {
        let key = (hash, opts);
        let slot = self.cache.detect_slot(key);
        let mut computed = false;
        let out = slot
            .get_or_init(|| {
                computed = true;
                let emu = self.emulated_hashed(kernel, hash)?;
                let t0 = Instant::now();
                let detection = detect(kernel, &emu.result, opts);
                let elapsed = t0.elapsed();
                self.timings.record(Stage::Detect, elapsed);
                Ok(Arc::new(Detected {
                    detection,
                    elapsed,
                    emu_elapsed: emu.elapsed,
                }))
            })
            .clone();
        self.cache.counters.record(ArtifactKind::Detected, computed);
        out
    }

    /// Synthesized-variant artifact; reuses the cached detection (and
    /// through it the single emulation).
    pub fn synthesized(
        &self,
        kernel: &Arc<Kernel>,
        opts: DetectOpts,
        variant: Variant,
    ) -> Result<Arc<Synthesized>, EmuError> {
        self.synthesized_hashed(kernel, kernel_fingerprint(kernel), opts, variant)
    }

    pub fn synthesized_hashed(
        &self,
        kernel: &Arc<Kernel>,
        hash: ContentHash,
        opts: DetectOpts,
        variant: Variant,
    ) -> Result<Arc<Synthesized>, EmuError> {
        let key = (hash, opts, variant);
        let slot = self.cache.synth_slot(key);
        let mut computed = false;
        let out = slot
            .get_or_init(|| {
                computed = true;
                let det = self.detected_hashed(kernel, hash, opts)?;
                let t0 = Instant::now();
                let synthesized = synthesize(kernel, &det.detection, variant);
                self.timings.record(Stage::Synthesize, t0.elapsed());
                Ok(Arc::new(Synthesized {
                    kernel: Arc::new(synthesized),
                    variant,
                    source: hash,
                }))
            })
            .clone();
        self.cache
            .counters
            .record(ArtifactKind::Synthesized, computed);
        out
    }

    /// Snapshot of cache counters and per-stage timings.
    pub fn stats(&self) -> PipelineStats {
        let mut s = PipelineStats {
            cache: self.cache.counters.snapshot(),
            ..Default::default()
        };
        for stage in STAGES {
            let i = stage.index();
            s.stage_nanos[i] = self.timings.nanos[i].load(Ordering::Relaxed);
            s.stage_runs[i] = self.timings.runs[i].load(Ordering::Relaxed);
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ptx::parser::parse_kernel;

    const K: &str = r#"
.visible .entry s3(.param .u64 out, .param .u64 a){
.reg .b32 %r<6>; .reg .b64 %rd<8>; .reg .f32 %f<6>;
ld.param.u64 %rd1, [out];
ld.param.u64 %rd2, [a];
cvta.to.global.u64 %rd3, %rd2;
cvta.to.global.u64 %rd4, %rd1;
mov.u32 %r4, %tid.x;
mul.wide.s32 %rd5, %r4, 4;
add.s64 %rd6, %rd3, %rd5;
ld.global.nc.f32 %f1, [%rd6];
ld.global.nc.f32 %f2, [%rd6+4];
ld.global.nc.f32 %f3, [%rd6+8];
add.f32 %f4, %f1, %f2;
add.f32 %f5, %f4, %f3;
add.s64 %rd7, %rd4, %rd5;
st.global.f32 [%rd7], %f5;
ret;
}
"#;

    #[test]
    fn emulation_is_computed_once_per_content_hash() {
        let p = Pipeline::new();
        let k = Arc::new(parse_kernel(K).unwrap());
        let e1 = p.emulated(&k).unwrap();
        // a *separately parsed* but identical kernel hits the same slot
        let k2 = Arc::new(parse_kernel(K).unwrap());
        let e2 = p.emulated(&k2).unwrap();
        assert!(Arc::ptr_eq(&e1, &e2), "identical kernels must share the artifact");
        let s = p.stats().cache;
        assert_eq!(s.emulate_misses, 1);
        assert_eq!(s.emulate_hits, 1);
        assert_eq!(p.cache().emulated_len(), 1);
    }

    #[test]
    fn variants_share_one_emulation() {
        let p = Pipeline::new();
        let k = Arc::new(parse_kernel(K).unwrap());
        let opts = DetectOpts::default();
        for v in [Variant::NoLoad, Variant::NoCorner, Variant::Full] {
            let s = p.synthesized(&k, opts, v).unwrap();
            assert_eq!(s.variant, v);
        }
        let s = p.stats();
        assert_eq!(s.cache.emulate_misses, 1, "exactly one emulation");
        assert_eq!(s.cache.detect_misses, 1, "exactly one detection");
        // each variant after the first found the detection in the cache
        assert_eq!(s.cache.detect_hits, 2);
        assert_eq!(s.cache.synth_misses, 3);
        // stage counters saw one emulate pass and three synthesize passes
        assert_eq!(s.stage_count(Stage::Emulate), 1);
        assert_eq!(s.stage_count(Stage::Synthesize), 3);
    }

    #[test]
    fn mutated_kernel_gets_its_own_artifact() {
        let p = Pipeline::new();
        let k = Arc::new(parse_kernel(K).unwrap());
        let mutated = Arc::new(parse_kernel(&K.replace("[%rd6+8]", "[%rd6+12]")).unwrap());
        p.emulated(&k).unwrap();
        p.emulated(&mutated).unwrap();
        let s = p.stats().cache;
        assert_eq!(s.emulate_misses, 2);
        assert_eq!(s.emulate_hits, 0);
        assert_eq!(p.cache().emulated_len(), 2);
    }

    #[test]
    fn parse_source_assigns_content_addresses() {
        let p = Pipeline::new();
        let src = format!(".version 7.6\n.target sm_70\n.address_size 64\n{K}");
        let parsed = p.parse_source(&src).unwrap();
        assert_eq!(parsed.len(), 1);
        let again = p.intake((*parsed[0].kernel).clone());
        assert_eq!(parsed[0].hash, again.hash);
    }
}
