//! The two workload-dependent stages at the tail of the typed chain
//! `Parsed → Emulated → Detected → Synthesized → Validated → Scored`.
//!
//! Unlike the first four stages, validation and scoring depend on a
//! concrete simulator workload (grid sizes, input data, seed), so they are
//! not content-addressed — the coordinator drives them as tasks and the
//! pass manager only accounts their wall time.

use crate::perf::{model, Arch, PerfReport};
use crate::pipeline::{Pipeline, Stage};
use crate::ptx::ast::Kernel;
use crate::sim::{run, SimError, SimStats, WarpEvent};
use crate::suite::Workload;

/// Stage 5 artifact: one simulator execution of a kernel version, with
/// the bit-exactness verdict against the baseline output.
#[derive(Debug)]
pub struct Validated {
    pub out: Vec<f32>,
    pub stats: SimStats,
    pub trace: Vec<Vec<WarpEvent>>,
    /// `Some(true)` iff the output matched the baseline bit-exactly
    /// (`None` for the baseline itself).
    pub valid: Option<bool>,
}

/// Stage 6 artifact: the per-architecture reports for one kernel
/// version, assembled by the coordinator once every [`score`] task for a
/// slot has retired.
#[derive(Debug)]
pub struct Scored {
    pub reports: Vec<PerfReport>,
}

/// Run a kernel version on the warp simulator and compare against the
/// baseline output (when given).
pub fn validate(
    p: &Pipeline,
    kernel: &Kernel,
    w: Workload,
    baseline_out: Option<&[f32]>,
) -> Result<Validated, SimError> {
    p.time(Stage::Validate, || {
        let Workload {
            mut cfg,
            mem,
            out_ptr,
            out_len,
            ..
        } = w;
        cfg.record_trace = true;
        let r = run(kernel, &cfg, mem)?;
        let out = r.mem.read_f32s(out_ptr, out_len)?;
        let valid = baseline_out.map(|base| {
            base.len() == out.len()
                && base
                    .iter()
                    .zip(&out)
                    .all(|(a, b)| a.to_bits() == b.to_bits())
        });
        Ok(Validated {
            out,
            stats: r.stats,
            trace: r.trace,
            valid,
        })
    })
}

/// Score one validated kernel version on one architecture.
pub fn score(p: &Pipeline, kernel: &Kernel, v: &Validated, arch: &Arch) -> PerfReport {
    p.time(Stage::Score, || model(kernel, &v.trace, arch))
}
