//! The two workload-dependent stages at the tail of the typed chain
//! `Parsed → Emulated → Detected → Synthesized → Validated → Scored`.
//!
//! Validation and scoring depend on a concrete simulator workload (grid
//! sizes, input data, seed), so they are keyed by
//! ([`crate::ptx::kernel_fingerprint`], [`crate::suite::WorkloadFingerprint`])
//! instead of the kernel hash alone — see [`crate::pipeline::Pipeline::validated`]
//! and [`crate::pipeline::Pipeline::scored`] for the cached entry points.
//! The free functions here are the *compute* path those entry points fall
//! back to on a cache miss.

use crate::perf::{model, Arch, PerfReport};
use crate::pipeline::{Pipeline, Stage};
use crate::ptx::ast::Kernel;
use crate::ptx::printer::ContentHash;
use crate::sim::{run_decoded, SimError, SimStats, WarpEvent};
use crate::suite::Workload;
use std::sync::Arc;

/// Stage 5 artifact: one simulator execution of a kernel version, with
/// the bit-exactness verdict against the baseline output.
#[derive(Debug)]
pub struct Validated {
    pub out: Vec<f32>,
    pub stats: SimStats,
    pub trace: Vec<Vec<WarpEvent>>,
    /// `Some(true)` iff the output matched the baseline bit-exactly
    /// (`None` for the baseline itself).
    pub valid: Option<bool>,
}

/// Stage 6 artifact: the latency-model report for one kernel version on
/// one architecture.
#[derive(Debug)]
pub struct Scored {
    pub report: PerfReport,
}

/// Run a kernel version on the warp simulator and compare against the
/// baseline output (when given). The workload is borrowed — its memory
/// image is cloned so the cached artifact stays pristine. Simulation
/// goes through the cached [`crate::sim::DecodedKernel`] artifact
/// (`hash` must be the kernel's fingerprint), so the micro-op lowering
/// happens once per kernel version no matter how many workloads,
/// variants or re-runs consume it.
pub fn validate(
    p: &Pipeline,
    kernel: &Arc<Kernel>,
    hash: ContentHash,
    w: &Workload,
    baseline_out: Option<&[f32]>,
) -> Result<Validated, SimError> {
    let decoded = p.decoded(kernel, hash)?;
    p.time(Stage::Validate, || {
        let mut cfg = w.cfg.clone();
        cfg.record_trace = true;
        cfg.sim_threads = p.sim_threads();
        // diagnostic mode: serial engine + load-side race shadow (the
        // executor forces one worker itself; see `sim::exec`)
        cfg.detect_races = p.detect_races();
        // engine path selection (`--engine`): affects throughput and the
        // telemetry counters only — results are bit-identical, so the
        // artifact (and its cache key) are engine-independent
        (cfg.superblocks, cfg.vector) = p.engine();
        let r = run_decoded(&decoded, &cfg, w.mem.clone())?;
        p.note_engine_stats(&r.stats);
        // record which engine paths this simulation actually ran with,
        // and why the superblock fast path would fall back per-uop
        p.tracer().instant("sim", "sim.engine", || {
            use crate::obs::ArgVal;
            vec![
                ("key", ArgVal::Str(hash.to_string())),
                ("superblocks", ArgVal::Bool(cfg.superblocks)),
                ("vector", ArgVal::Bool(cfg.vector)),
                ("sim_threads", ArgVal::U64(cfg.sim_threads as u64)),
                (
                    "fallback",
                    ArgVal::Str(
                        crate::sim::exec::engine_fallback_reason(&cfg)
                            .unwrap_or("none")
                            .to_string(),
                    ),
                ),
                ("superblocks_entered", ArgVal::U64(r.stats.superblocks_entered)),
                ("vector_warp_steps", ArgVal::U64(r.stats.vector_warp_steps)),
            ]
        });
        let out = r.mem.read_f32s(w.out_ptr, w.out_len)?;
        let valid = baseline_out.map(|base| {
            base.len() == out.len()
                && base
                    .iter()
                    .zip(&out)
                    .all(|(a, b)| a.to_bits() == b.to_bits())
        });
        Ok(Validated {
            out,
            stats: r.stats,
            trace: r.trace,
            valid,
        })
    })
}

/// Score one validated kernel version on one architecture.
pub fn score(p: &Pipeline, kernel: &Kernel, v: &Validated, arch: &Arch) -> Scored {
    p.time(Stage::Score, || Scored {
        report: model(kernel, &v.trace, arch),
    })
}
