//! On-disk persistence for pipeline artifacts.
//!
//! The in-memory [`crate::pipeline::ArtifactCache`] makes repeated lookups
//! free *within* a process; this module extends the content addressing
//! across processes, so a recompile-heavy workflow (the ACC-Saturator /
//! JACC use case: the same kernels re-analyzed on every build) pays for
//! emulation, simulation and scoring once per machine instead of once per
//! run.
//!
//! Layout (one file per artifact, under a format-version directory):
//!
//! ```text
//! <cache-dir>/v8/<kind>/<32-hex-key>.art   artifact (header + payload)
//! <cache-dir>/v8/<kind>/<32-hex-key>.lru   empty touch marker (last use)
//! <cache-dir>/v8/<kind>/.index             sharded per-kind index (see below)
//! <cache-dir>/v8/manifest                  generation manifest (see below)
//! <cache-dir>/v8/.evict.lock               cross-process eviction lock
//! ```
//!
//! `<kind>` is one of `emulated`, `decoded`, `detected`, `synthesized`,
//! `validated`, `scored`. Emulations persist through the relocatable
//! term-graph codec ([`crate::sym::persist`]) — the image spells symbol/UF
//! names out and the loader re-interns them through its own session, so
//! the interner-relative ids never touch disk. Decoded micro-op kernels
//! persist as plain field images ([`crate::sim::DecodedKernel::to_bytes`]).
//! Workloads are *not* persisted: they are cheap to regenerate from their
//! fingerprint inputs.
//!
//! Every file is `MAGIC ∥ version ∥ kind ∥ payload ∥ fnv64(payload)`.
//! Loads are corruption-tolerant: any header/checksum/decode mismatch
//! deletes the file, counts it, and falls back to recompute. Writes go
//! through a temp file + rename so readers never observe a torn artifact.
//! The store is LRU size-bounded: after each write the store evicts
//! least-recently-used artifacts (by touch-marker mtime) until the
//! resident set fits `max_bytes`.
//!
//! **Fault model.** Every filesystem operation routes through the
//! [`crate::util::Vfs`] seam, so the fault-injection suites
//! (`tests/fault_store.rs`) can drive the whole pipeline through torn
//! writes, crash-point truncation, simulated ENOSPC, and flat IO errors.
//! The invariant under any injected failure: the store degrades to
//! recompute with bit-exact results — never a panic, never an
//! accepted-corrupt artifact, and a later no-fault run heals the dir
//! (stale temp files are swept on `open`, corrupt entries are deleted on
//! load or by [`DiskStore::verify`]).
//!
//! **Cross-process coordination.** N processes on one cache dir behave
//! like one. Writers already coordinate through atomic tmp+rename (last
//! writer of a content-addressed key wins with identical bytes). Evictors
//! coordinate through `.evict.lock` — acquired with `O_EXCL` carrying
//! `pid ∥ unix-millis`, with stale-lock takeover after
//! [`STALE_LOCK_MS`] — so at most one process pays the eviction scan and
//! the rest skip (counted in [`DiskSnapshot::lock_skips`]). Each
//! completed eviction bumps the `manifest` generation (tmp+rename, so
//! the bump is atomic); writers that observe a foreign generation — or
//! every [`RESYNC_EVERY`]th store — resynchronize their resident-bytes
//! counter from a directory scan instead of trusting local increments
//! (counted in [`DiskSnapshot::resyncs`]). A file evicted under a
//! concurrent reader just recomputes; a file already deleted by a racing
//! evictor is treated as evicted, not as an error.
//!
//! **Sharded index.** Every kind dir carries a `.index` file (`RPIX` ∥
//! store version ∥ manifest generation ∥ count ∥ byte total ∥ entries ∥
//! `fnv64`) mirroring the in-memory entry table the store maintains as it
//! writes. A clean open whose index generations all match the manifest
//! seeds the resident counter and the eviction candidate set from those
//! files — and every subsequent store/evict updates the index
//! incrementally, so the steady-state write path performs **zero
//! directory scans**: eviction and `snapshot()` are O(changed) rather
//! than O(entries). Any mismatch (foreign generation, missing/corrupt
//! index, every [`RESYNC_EVERY`]th store) falls back to one full scan
//! that rebuilds the index (counted in [`DiskSnapshot::index_rebuilds`]).
//! The index is advisory exactly like the resident counter: foreign
//! writers this process has not observed yet surface at the next rebuild,
//! and [`DiskStore::verify`] cross-checks the index against a raw
//! directory walk (the CI gate).

use crate::emu::EmuStats;
use crate::obs::{ArgVal, Tracer};
use crate::perf::PerfReport;
use crate::pipeline::artifact::{Detected, Emulated, Synthesized};
use crate::pipeline::stages::{Scored, Validated};
use crate::ptx::ast::Kernel;
use crate::ptx::parser::parse_kernel;
use crate::ptx::printer::{print_kernel, ContentHash};
use crate::shuffle::{Candidate, DetectOpts, Detection, ElimOpts, ElimReport, Variant};
use crate::sim::{DecodedKernel, SimStats, WarpEvent};
use crate::sym::SessionInterner;
use crate::util::vfs::{RealFs, Vfs};
use crate::util::{fnv64, Dec, Enc};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, SystemTime, UNIX_EPOCH};

/// Bump when the artifact encoding changes; old `v<N>` trees are simply
/// ignored (and eventually reclaimed by the user, not by us).
/// v2: `SimStats` grew `cross_block_write_conflicts`.
/// v3: new `emulated/` (relocatable term-graph images) and `decoded/`
/// (micro-op kernel) artifact kinds.
/// v4: `SimStats` grew the barrier counters, memory-trace records carry
/// the barrier phase, and the decoded form carries branch statement
/// positions (`nstmts`, `Bra::target_stmt`, `BarSync` id/cnt).
/// v5: `SimStats` grew the engine telemetry counters
/// (`superblocks_entered`, `vector_warp_steps`) and the decoded form
/// carries the superblock table (`sb_end`).
/// v6: `synthesized/` artifacts carry the phase-liveness [`ElimReport`]
/// (dead-store / barrier-elision verdicts) and their disk key includes
/// the [`ElimOpts`] fingerprint.
/// v7: `detected/` and `synthesized/` disk keys include the emulation
/// [`crate::emu::Limits`] fingerprint (serve mode runs tight and wide
/// budgets over one cache dir; a detection computed under a tight budget
/// must never satisfy a default-budget reader), and the version root
/// gains the generation `manifest` + `.evict.lock` coordination files.
/// v8: the `emulated/` kind additionally stores resumable *frontier*
/// images (persist-v3 partial emulations under `emulated.frontier` keys),
/// and every kind dir gains a sharded `.index` file so eviction and
/// resync are O(changed) instead of O(entries).
pub const STORE_VERSION: u32 = 8;
const MAGIC: [u8; 4] = *b"RPST";
/// Generation-manifest magic (distinct from artifact files on purpose).
const MANIFEST_MAGIC: [u8; 4] = *b"RPMF";
/// Sharded per-kind index magic.
const INDEX_MAGIC: [u8; 4] = *b"RPIX";
/// Default resident-set bound: 256 MiB.
pub const DEFAULT_MAX_BYTES: u64 = 256 * 1024 * 1024;
/// An eviction lock older than this is presumed abandoned (holder
/// crashed mid-eviction) and taken over.
pub const STALE_LOCK_MS: u64 = 30_000;
/// Resynchronize the resident-bytes counter from a directory scan every
/// this-many stores, even when no foreign manifest generation is seen.
const RESYNC_EVERY: u64 = 32;

/// Artifact families the store persists.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StoreKind {
    Emulated,
    Decoded,
    Detected,
    Synthesized,
    Validated,
    Scored,
}

pub const STORE_KINDS: [StoreKind; 6] = [
    StoreKind::Emulated,
    StoreKind::Decoded,
    StoreKind::Detected,
    StoreKind::Synthesized,
    StoreKind::Validated,
    StoreKind::Scored,
];

impl StoreKind {
    pub fn dir(self) -> &'static str {
        match self {
            StoreKind::Emulated => "emulated",
            StoreKind::Decoded => "decoded",
            StoreKind::Detected => "detected",
            StoreKind::Synthesized => "synthesized",
            StoreKind::Validated => "validated",
            StoreKind::Scored => "scored",
        }
    }

    fn tag(self) -> u8 {
        match self {
            StoreKind::Detected => 1,
            StoreKind::Synthesized => 2,
            StoreKind::Validated => 3,
            StoreKind::Scored => 4,
            StoreKind::Emulated => 5,
            StoreKind::Decoded => 6,
        }
    }
}

/// Point-in-time view of the store's counters (all zero when no store is
/// attached to the pipeline).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct DiskSnapshot {
    pub enabled: bool,
    pub hits: u64,
    pub misses: u64,
    pub stores: u64,
    pub evictions: u64,
    /// Corrupt / truncated / undecodable files discarded on load.
    pub corrupt: u64,
    pub resident_bytes: u64,
    /// Last manifest generation this store observed (0 = none yet).
    pub generation: u64,
    /// Evictions skipped because another process held `.evict.lock`.
    pub lock_skips: u64,
    /// Resident-counter resynchronizations from a directory scan.
    pub resyncs: u64,
    /// Stale temp files swept at `open` (crash debris from prior runs).
    pub swept_tmp: u64,
    /// Sharded-index rebuilds from a full directory scan (open mismatch,
    /// foreign generation, or the periodic resync).
    pub index_rebuilds: u64,
}

/// The persistent artifact store. One per cache directory; safe to share
/// across threads (and, best-effort, across processes: writes are atomic
/// renames, and a file evicted under a concurrent reader just recomputes).
#[derive(Debug)]
pub struct DiskStore {
    vfs: Arc<dyn Vfs>,
    root: PathBuf,
    max_bytes: u64,
    evict_lock: Mutex<()>,
    hits: AtomicU64,
    misses: AtomicU64,
    stores: AtomicU64,
    evictions: AtomicU64,
    corrupt: AtomicU64,
    resident: AtomicU64,
    /// Manifest generation last seen (0 until a manifest is observed).
    last_gen: AtomicU64,
    lock_skips: AtomicU64,
    resyncs: AtomicU64,
    swept_tmp: AtomicU64,
    index_rebuilds: AtomicU64,
    /// In-memory mirror of the per-kind `.index` files: every resident
    /// artifact's size and last-use clock, keyed by file stem. Updated on
    /// every store/load/evict this process performs; rebuilt from a scan
    /// only on a generation mismatch or the periodic resync.
    index: Mutex<IndexState>,
    /// Span recorder for store ops (`store.*` in the trace taxonomy).
    /// Disabled by default; [`DiskStore::set_tracer`] attaches a shared
    /// one before the store is wrapped in an `Arc`. Sits above the [`Vfs`]
    /// seam, so fault-injection tests observe spans for injected failures.
    tracer: Arc<Tracer>,
}

/// One indexed artifact: container size on disk plus the last-use clock
/// (unix millis — what the `.lru` markers encode as mtimes).
#[derive(Debug, Clone, Copy)]
struct IdxEntry {
    size: u64,
    touched_ms: u64,
}

/// In-memory sharded index: one entry table per kind, keyed by the
/// artifact's file stem (the 32-hex key).
#[derive(Debug, Default)]
struct IndexState {
    kinds: [crate::util::FnvMap<String, IdxEntry>; STORE_KINDS.len()],
}

impl IndexState {
    fn kind(&self, k: StoreKind) -> &crate::util::FnvMap<String, IdxEntry> {
        &self.kinds[k.tag() as usize - 1]
    }

    fn kind_mut(&mut self, k: StoreKind) -> &mut crate::util::FnvMap<String, IdxEntry> {
        &mut self.kinds[k.tag() as usize - 1]
    }

    /// Per-kind `(count, bytes)` in [`STORE_KINDS`] order — the manifest
    /// summary, computed without touching the directory.
    fn totals(&self) -> [(u64, u64); STORE_KINDS.len()] {
        let mut out = [(0u64, 0u64); STORE_KINDS.len()];
        for (slot, kind) in out.iter_mut().zip(STORE_KINDS) {
            let m = self.kind(kind);
            *slot = (m.len() as u64, m.values().map(|e| e.size).sum());
        }
        out
    }

    fn total_bytes(&self) -> u64 {
        self.totals().iter().map(|&(_, b)| b).sum()
    }
}

fn millis_of(t: SystemTime) -> u64 {
    t.duration_since(UNIX_EPOCH)
        .map(|d| d.as_millis() as u64)
        .unwrap_or(0)
}

/// The default cache directory: `$RUST_PALLAS_CACHE_DIR`, else
/// `$HOME/.cache/rust_pallas`, else `None` (disk cache disabled).
pub fn default_dir() -> Option<PathBuf> {
    if let Some(d) = std::env::var_os("RUST_PALLAS_CACHE_DIR") {
        return Some(PathBuf::from(d));
    }
    std::env::var_os("HOME").map(|h| PathBuf::from(h).join(".cache").join("rust_pallas"))
}

impl DiskStore {
    /// Open (creating if needed) a store rooted at `dir`, bounded to
    /// `max_bytes` of resident artifacts, on the real filesystem.
    pub fn open(dir: &Path, max_bytes: u64) -> std::io::Result<DiskStore> {
        DiskStore::open_on(Arc::new(RealFs), dir, max_bytes)
    }

    /// Open on an explicit [`Vfs`] — the seam the fault-injection suites
    /// use to drive the store through every IO failure class.
    pub fn open_on(
        vfs: Arc<dyn Vfs>,
        dir: &Path,
        max_bytes: u64,
    ) -> std::io::Result<DiskStore> {
        let root = dir.join(format!("v{STORE_VERSION}"));
        for kind in STORE_KINDS {
            vfs.create_dir_all(&root.join(kind.dir()))?;
        }
        let store = DiskStore {
            vfs,
            root,
            max_bytes,
            evict_lock: Mutex::new(()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            stores: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            corrupt: AtomicU64::new(0),
            resident: AtomicU64::new(0),
            last_gen: AtomicU64::new(0),
            lock_skips: AtomicU64::new(0),
            resyncs: AtomicU64::new(0),
            swept_tmp: AtomicU64::new(0),
            index_rebuilds: AtomicU64::new(0),
            index: Mutex::new(IndexState::default()),
            tracer: Arc::new(Tracer::disabled()),
        };
        store.sweep_tmp();
        let generation = store.read_manifest().map(|m| m.generation).unwrap_or(0);
        store.last_gen.store(generation, Ordering::Relaxed);
        match store.load_index_files(generation) {
            Some(seeded) => {
                let total = seeded.total_bytes();
                *store.index_lock() = seeded;
                store.resident.store(total, Ordering::Relaxed);
            }
            None => store.rebuild_index(),
        }
        Ok(store)
    }

    /// Open with the default size bound.
    pub fn open_default(dir: &Path) -> std::io::Result<DiskStore> {
        DiskStore::open(dir, DEFAULT_MAX_BYTES)
    }

    /// The configured resident-set bound.
    pub fn max_bytes(&self) -> u64 {
        self.max_bytes
    }

    /// Attach a shared span tracer (call before wrapping in an `Arc`).
    pub fn set_tracer(&mut self, tracer: Arc<Tracer>) {
        self.tracer = tracer;
    }

    /// Record one store operation's outcome as an instant event.
    fn trace_op(
        &self,
        name: &'static str,
        kind: StoreKind,
        key: ContentHash,
        outcome: &'static str,
    ) {
        self.tracer.instant("store", name, || {
            vec![
                ("kind", ArgVal::Str(kind.dir().to_string())),
                ("key", ArgVal::Str(key.to_string())),
                ("outcome", ArgVal::Str(outcome.to_string())),
            ]
        });
    }

    pub fn snapshot(&self) -> DiskSnapshot {
        DiskSnapshot {
            enabled: true,
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            stores: self.stores.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            corrupt: self.corrupt.load(Ordering::Relaxed),
            resident_bytes: self.resident.load(Ordering::Relaxed),
            generation: self.last_gen.load(Ordering::Relaxed),
            lock_skips: self.lock_skips.load(Ordering::Relaxed),
            resyncs: self.resyncs.load(Ordering::Relaxed),
            swept_tmp: self.swept_tmp.load(Ordering::Relaxed),
            index_rebuilds: self.index_rebuilds.load(Ordering::Relaxed),
        }
    }

    /// Delete temp files abandoned by crashed writers (`<key>.tmp<pid>-<n>`
    /// never renamed into place). Run once at `open`; failures are
    /// harmless — the debris is retried next open.
    fn sweep_tmp(&self) {
        let dirs = STORE_KINDS
            .iter()
            .map(|k| self.root.join(k.dir()))
            .chain(std::iter::once(self.root.clone()));
        for dir in dirs {
            let Ok(entries) = self.vfs.read_dir(&dir) else {
                continue;
            };
            for (path, _) in entries {
                let is_tmp = path
                    .extension()
                    .and_then(|x| x.to_str())
                    .is_some_and(|x| x.starts_with("tmp"));
                if is_tmp && self.vfs.remove_file(&path).is_ok() {
                    self.swept_tmp.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
    }

    fn art_path(&self, kind: StoreKind, key: ContentHash) -> PathBuf {
        self.root.join(kind.dir()).join(format!("{key}.art"))
    }

    // -- sharded per-kind index --------------------------------------------

    fn index_lock(&self) -> std::sync::MutexGuard<'_, IndexState> {
        // a panicking pipeline thread must not wedge the index
        self.index.lock().unwrap_or_else(|e| e.into_inner())
    }

    fn index_path(&self, kind: StoreKind) -> PathBuf {
        self.root.join(kind.dir()).join(".index")
    }

    /// Try to seed the whole index from the per-kind `.index` files. All
    /// six must parse and carry `generation` — any missing, corrupt or
    /// foreign-generation shard invalidates the lot (the caller rescans).
    fn load_index_files(&self, generation: u64) -> Option<IndexState> {
        let mut state = IndexState::default();
        for kind in STORE_KINDS {
            let bytes = self.vfs.read(&self.index_path(kind)).ok()?;
            if bytes.len() < 12 || bytes[0..4] != INDEX_MAGIC {
                return None;
            }
            let payload = &bytes[4..bytes.len() - 8];
            let want = u64::from_le_bytes(bytes[bytes.len() - 8..].try_into().ok()?);
            if fnv64(payload) != want {
                return None;
            }
            let mut d = Dec::new(payload);
            if d.u32()? != STORE_VERSION || d.u64()? != generation {
                return None;
            }
            let count = d.len()?;
            let claimed_bytes = d.u64()?;
            let map = state.kind_mut(kind);
            for _ in 0..count {
                let stem = d.str()?.to_string();
                let size = d.u64()?;
                let touched_ms = d.u64()?;
                map.insert(stem, IdxEntry { size, touched_ms });
            }
            if !d.done()
                || map.len() != count
                || map.values().map(|e| e.size).sum::<u64>() != claimed_bytes
            {
                return None;
            }
        }
        Some(state)
    }

    /// Write one kind's `.index` shard (tmp+rename; failures swallowed —
    /// a stale shard is detected by the generation/total check and
    /// rebuilt, exactly like a stale resident counter).
    fn write_kind_index(&self, kind: StoreKind, state: &IndexState, generation: u64) {
        let map = state.kind(kind);
        let mut stems: Vec<&String> = map.keys().collect();
        stems.sort_unstable(); // deterministic bytes
        let mut e = Enc::default();
        e.u32(STORE_VERSION);
        e.u64(generation);
        e.u64(map.len() as u64);
        e.u64(map.values().map(|x| x.size).sum());
        for stem in stems {
            let entry = map[stem];
            e.str(stem);
            e.u64(entry.size);
            e.u64(entry.touched_ms);
        }
        let mut bytes = Vec::with_capacity(e.buf.len() + 12);
        bytes.extend_from_slice(&INDEX_MAGIC);
        bytes.extend_from_slice(&e.buf);
        bytes.extend_from_slice(&fnv64(&e.buf).to_le_bytes());
        let path = self.index_path(kind);
        let tmp = path.with_extension(format!("tmp{}", std::process::id()));
        if !(self.vfs.write(&tmp, &bytes).is_ok() && self.vfs.rename(&tmp, &path).is_ok()) {
            let _ = self.vfs.remove_file(&tmp);
        }
    }

    /// Rebuild the whole index from one directory scan, reset the
    /// resident counter from it, and persist every shard. The only
    /// O(entries) path left — taken at open/resync mismatches, never on
    /// the steady-state store/evict path.
    fn rebuild_index(&self) {
        let entries = self.scan();
        let generation = self.last_gen.load(Ordering::Relaxed);
        let mut state = IndexState::default();
        for e in &entries {
            let Some(stem) = e.path.file_stem().and_then(|s| s.to_str()) else {
                continue;
            };
            let Some(kind) = STORE_KINDS
                .into_iter()
                .find(|k| e.path.parent() == Some(&self.root.join(k.dir())))
            else {
                continue;
            };
            state.kind_mut(kind).insert(
                stem.to_string(),
                IdxEntry {
                    size: e.size,
                    touched_ms: millis_of(e.touched),
                },
            );
        }
        let total = state.total_bytes();
        for kind in STORE_KINDS {
            self.write_kind_index(kind, &state, generation);
        }
        *self.index_lock() = state;
        self.resident.store(total, Ordering::Relaxed);
        self.index_rebuilds.fetch_add(1, Ordering::Relaxed);
        self.tracer.instant("store", "store.index_rebuild", || {
            vec![("resident_bytes", ArgVal::U64(total))]
        });
    }

    /// Upsert one artifact into the index and persist its kind shard.
    fn index_record_store(&self, kind: StoreKind, key: ContentHash, size: u64) {
        let generation = self.last_gen.load(Ordering::Relaxed);
        let mut state = self.index_lock();
        state
            .kind_mut(kind)
            .insert(key.to_string(), IdxEntry { size, touched_ms: unix_millis() });
        self.write_kind_index(kind, &state, generation);
    }

    /// Bump an artifact's last-use clock (in-memory only — the durable
    /// recency signal is the `.lru` marker, which a rebuild scan reads).
    fn index_record_touch(&self, kind: StoreKind, key: ContentHash) {
        if let Some(e) = self.index_lock().kind_mut(kind).get_mut(&key.to_string()) {
            e.touched_ms = unix_millis();
        }
    }

    /// Drop one artifact from the index and persist its kind shard.
    fn index_record_remove(&self, kind: StoreKind, stem: &str) {
        let generation = self.last_gen.load(Ordering::Relaxed);
        let mut state = self.index_lock();
        if state.kind_mut(kind).remove(stem).is_some() {
            self.write_kind_index(kind, &state, generation);
        }
    }

    /// Load and verify an artifact's payload. Any malformed file is
    /// removed and counted; the caller recomputes.
    pub fn load(&self, kind: StoreKind, key: ContentHash) -> Option<Vec<u8>> {
        self.load_decoded(kind, key, |payload| Some(payload.to_vec()))
    }

    /// Load, verify *and decode* an artifact in one accounting unit: a
    /// file whose container checks out but whose payload fails the typed
    /// decoder (format drift within one `STORE_VERSION`) is treated
    /// exactly like a corrupt file — removed, counted, recomputed — and
    /// is never reported as a disk hit.
    pub fn load_decoded<T>(
        &self,
        kind: StoreKind,
        key: ContentHash,
        decode: impl FnOnce(&[u8]) -> Option<T>,
    ) -> Option<T> {
        let path = self.art_path(kind, key);
        let bytes = match self.vfs.read(&path) {
            Ok(b) => b,
            Err(_) => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                self.trace_op("store.load", kind, key, "miss");
                return None;
            }
        };
        match decode_container(&bytes, kind).and_then(decode) {
            Some(artifact) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                // bump the LRU clock; failure is harmless (falls back to
                // the artifact's own mtime)
                let _ = self.vfs.touch(&path.with_extension("lru"));
                self.index_record_touch(kind, key);
                self.trace_op("store.load", kind, key, "hit");
                Some(artifact)
            }
            None => {
                self.corrupt.fetch_add(1, Ordering::Relaxed);
                self.misses.fetch_add(1, Ordering::Relaxed);
                let _ = self.vfs.remove_file(&path);
                let _ = self.vfs.remove_file(&path.with_extension("lru"));
                self.index_record_remove(kind, &key.to_string());
                self.trace_op("store.load", kind, key, "corrupt");
                None
            }
        }
    }

    /// Persist an artifact payload, then evict down to the size bound.
    /// I/O failures are swallowed: the disk layer is an accelerator, never
    /// a correctness dependency.
    pub fn store(&self, kind: StoreKind, key: ContentHash, payload: &[u8]) {
        let path = self.art_path(kind, key);
        let mut bytes = Vec::with_capacity(payload.len() + 17);
        bytes.extend_from_slice(&MAGIC);
        bytes.extend_from_slice(&STORE_VERSION.to_le_bytes());
        bytes.push(kind.tag());
        bytes.extend_from_slice(payload);
        bytes.extend_from_slice(&fnv64(payload).to_le_bytes());

        // pid + global nonce: two stores in one process racing on the
        // same key must not interleave writes into one temp file
        static TMP_NONCE: AtomicU64 = AtomicU64::new(0);
        let tmp = path.with_extension(format!(
            "tmp{}-{}",
            std::process::id(),
            TMP_NONCE.fetch_add(1, Ordering::Relaxed)
        ));
        let old = self.vfs.metadata(&path).map(|m| m.len).unwrap_or(0);
        if self.vfs.write(&tmp, &bytes).is_ok() && self.vfs.rename(&tmp, &path).is_ok() {
            let n = self.stores.fetch_add(1, Ordering::Relaxed) + 1;
            let new = bytes.len() as u64;
            if new >= old {
                self.resident.fetch_add(new - old, Ordering::Relaxed);
            } else {
                self.resident.fetch_sub(old - new, Ordering::Relaxed);
            }
            self.index_record_store(kind, key, new);
            self.trace_op("store.store", kind, key, "stored");
            self.maybe_resync(n);
            self.evict_to_limit();
        } else {
            let _ = self.vfs.remove_file(&tmp);
            self.trace_op("store.store", kind, key, "failed");
        }
    }

    /// Resynchronize the resident counter *and the sharded index* from a
    /// directory scan when a foreign process bumped the manifest
    /// generation (its stores/evictions are invisible to our local
    /// increments) — or unconditionally every [`RESYNC_EVERY`]th store,
    /// catching drift even when evictors crash before publishing a
    /// generation. The steady-state store path (same generation, off the
    /// period) costs one manifest read and no scan.
    fn maybe_resync(&self, nth_store: u64) {
        let seen = self.read_manifest().map(|m| m.generation).unwrap_or(0);
        let last = self.last_gen.load(Ordering::Relaxed);
        if seen == last && nth_store % RESYNC_EVERY != 0 {
            return;
        }
        self.last_gen.store(seen, Ordering::Relaxed);
        self.rebuild_index();
        let total = self.resident.load(Ordering::Relaxed);
        self.resyncs.fetch_add(1, Ordering::Relaxed);
        self.tracer.instant("store", "store.resync", || {
            vec![
                ("generation", ArgVal::U64(seen)),
                ("resident_bytes", ArgVal::U64(total)),
            ]
        });
    }

    /// All resident artifacts with size and last-use time. Hardened
    /// against a dir being mutated underneath it: an unreadable kind dir
    /// is skipped, entries whose metadata cannot be read are dropped by
    /// the [`Vfs`] (a file deleted mid-scan was never resident), and a
    /// missing/corrupt `.lru` marker falls back to the artifact's own
    /// mtime. Nothing here is an error.
    fn scan(&self) -> Vec<Entry> {
        let mut out = Vec::new();
        for kind in STORE_KINDS {
            let dir = self.root.join(kind.dir());
            let Ok(entries) = self.vfs.read_dir(&dir) else { continue };
            for (path, meta) in entries {
                if path.extension().and_then(|x| x.to_str()) != Some("art") {
                    continue;
                }
                let touched = self
                    .vfs
                    .metadata(&path.with_extension("lru"))
                    .map(|m| m.modified)
                    .unwrap_or(meta.modified);
                out.push(Entry {
                    path,
                    size: meta.len,
                    touched,
                });
            }
        }
        out
    }

    /// Remove least-recently-used artifacts until the resident set fits
    /// `max_bytes`, overshooting down to a 90% low-water mark so a cache
    /// sitting at its bound does not evict on every subsequent write.
    /// Victims come from the **in-memory sharded index** — the whole round
    /// performs no directory scan, only the removals themselves, so
    /// eviction is O(changed). In-process evictors serialize on
    /// `evict_lock` (poison-tolerant: a panicking pipeline thread must not
    /// wedge eviction forever); cross-process evictors serialize on
    /// `.evict.lock` — when another live process holds it we *skip* this
    /// round (it is doing the work) rather than double-evict. The counter
    /// is only ever decremented by what this process actually removed;
    /// foreign evictions reach us through the manifest-generation resync
    /// in `store()`.
    pub fn evict_to_limit(&self) {
        if self.resident.load(Ordering::Relaxed) <= self.max_bytes {
            return;
        }
        let low_water = self.max_bytes - self.max_bytes / 10;
        let _guard = self
            .evict_lock
            .lock()
            .unwrap_or_else(|e| e.into_inner());
        if !self.acquire_process_lock() {
            self.lock_skips.fetch_add(1, Ordering::Relaxed);
            self.tracer.instant("store", "store.lock_skip", Vec::new);
            return;
        }
        let span = self.tracer.begin();
        let mut removed: u64 = 0;
        let mut victims: Vec<(StoreKind, String, IdxEntry)> = {
            let state = self.index_lock();
            STORE_KINDS
                .into_iter()
                .flat_map(|k| {
                    state
                        .kind(k)
                        .iter()
                        .map(move |(stem, &e)| (k, stem.clone(), e))
                })
                .collect()
        };
        let mut total: u64 = victims.iter().map(|v| v.2.size).sum();
        victims.sort_by(|a, b| {
            a.2.touched_ms
                .cmp(&b.2.touched_ms)
                .then_with(|| (a.0.dir(), &a.1).cmp(&(b.0.dir(), &b.1)))
        });
        for (kind, stem, entry) in victims {
            if total <= low_water {
                break;
            }
            let path = self.root.join(kind.dir()).join(format!("{stem}.art"));
            match self.vfs.remove_file(&path) {
                Ok(()) => {
                    let _ = self.vfs.remove_file(&path.with_extension("lru"));
                    self.evictions.fetch_add(1, Ordering::Relaxed);
                    removed += 1;
                }
                // a racing evictor got there first — the bytes are gone
                // either way, so account for them, but it was not *our*
                // eviction
                Err(err) if err.kind() == std::io::ErrorKind::NotFound => {}
                // transient failure (injected or real): leave the entry
                // for the next round
                Err(_) => continue,
            }
            total -= entry.size;
            self.index_lock().kind_mut(kind).remove(&stem);
            let _ = self
                .resident
                .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| {
                    Some(v.saturating_sub(entry.size))
                });
        }
        // publish the manifest from the index totals and persist every
        // shard at the new generation — O(kinds), no scan
        let totals = self.index_lock().totals();
        self.publish_manifest(totals);
        let generation = self.last_gen.load(Ordering::Relaxed);
        {
            let state = self.index_lock();
            for kind in STORE_KINDS {
                self.write_kind_index(kind, &state, generation);
            }
        }
        self.release_process_lock();
        self.tracer.span("store", "store.evict", span, || {
            vec![
                ("evicted", ArgVal::U64(removed)),
                ("resident_bytes", ArgVal::U64(total)),
            ]
        });
    }

    // -- cross-process coordination ----------------------------------------

    fn lock_path(&self) -> PathBuf {
        self.root.join(".evict.lock")
    }

    fn manifest_path(&self) -> PathBuf {
        self.root.join("manifest")
    }

    /// Try to take `.evict.lock` (exclusive create of `pid ∥ unix-millis`).
    /// An existing lock that is unparseable or older than [`STALE_LOCK_MS`]
    /// is presumed abandoned by a crashed holder: it is removed and the
    /// acquisition retried once. Returns `false` when another live process
    /// holds the lock (or IO keeps failing) — the caller skips eviction.
    fn acquire_process_lock(&self) -> bool {
        let mut payload = Vec::with_capacity(16);
        payload.extend_from_slice(&(std::process::id() as u64).to_le_bytes());
        payload.extend_from_slice(&unix_millis().to_le_bytes());
        for attempt in 0..2 {
            match self.vfs.create_new(&self.lock_path(), &payload) {
                Ok(()) => return true,
                Err(e) if e.kind() == std::io::ErrorKind::AlreadyExists && attempt == 0 => {
                    let stale = match self.vfs.read(&self.lock_path()) {
                        Ok(bytes) if bytes.len() == 16 => {
                            let ts =
                                u64::from_le_bytes(bytes[8..16].try_into().unwrap_or([0; 8]));
                            unix_millis().saturating_sub(ts) > STALE_LOCK_MS
                        }
                        // vanished → retry will race cleanly; garbage → stale
                        Err(_) => true,
                        Ok(_) => true,
                    };
                    if stale {
                        let _ = self.vfs.remove_file(&self.lock_path());
                        // fall through to the second create_new attempt
                    } else {
                        return false;
                    }
                }
                Err(_) => return false,
            }
        }
        false
    }

    fn release_process_lock(&self) {
        let _ = self.vfs.remove_file(&self.lock_path());
    }

    /// Write the generation manifest (tmp+rename, like artifacts): the
    /// incremented generation plus a per-kind count/bytes summary (from
    /// the sharded index — no directory walk). Racing bumps may coalesce
    /// generations — harmless, the number only needs to *change* for
    /// foreign processes to resync.
    fn publish_manifest(&self, kinds: [(u64, u64); STORE_KINDS.len()]) {
        let generation = self.read_manifest().map(|m| m.generation).unwrap_or(0) + 1;
        let mut e = Enc::default();
        e.u64(generation);
        for (count, bytes) in kinds {
            e.u64(count);
            e.u64(bytes);
        }
        let mut bytes = Vec::with_capacity(e.buf.len() + 12);
        bytes.extend_from_slice(&MANIFEST_MAGIC);
        bytes.extend_from_slice(&e.buf);
        bytes.extend_from_slice(&fnv64(&e.buf).to_le_bytes());
        let tmp = self.manifest_path().with_extension(format!(
            "tmp{}",
            std::process::id()
        ));
        if self.vfs.write(&tmp, &bytes).is_ok()
            && self.vfs.rename(&tmp, &self.manifest_path()).is_ok()
        {
            self.last_gen.store(generation, Ordering::Relaxed);
        } else {
            let _ = self.vfs.remove_file(&tmp);
        }
    }

    /// Read and verify the manifest; any corruption reads as "no
    /// manifest" (the store never trusts it for more than a resync hint).
    fn read_manifest(&self) -> Option<Manifest> {
        let bytes = self.vfs.read(&self.manifest_path()).ok()?;
        if bytes.len() < 12 || bytes[0..4] != MANIFEST_MAGIC {
            return None;
        }
        let payload = &bytes[4..bytes.len() - 8];
        let want = u64::from_le_bytes(bytes[bytes.len() - 8..].try_into().ok()?);
        if fnv64(payload) != want {
            return None;
        }
        let mut d = Dec::new(payload);
        let generation = d.u64()?;
        let mut kinds = [(0u64, 0u64); STORE_KINDS.len()];
        for slot in kinds.iter_mut() {
            *slot = (d.u64()?, d.u64()?);
        }
        d.done().then_some(Manifest { generation, kinds })
    }

    // -- verification ------------------------------------------------------

    /// Walk every resident artifact and check it end-to-end: container
    /// (magic, version, kind tag, checksum) *and* typed payload decode —
    /// the exact gauntlet a load would run. With `heal`, entries that
    /// fail are deleted (with their `.lru` markers and index entries) so
    /// the next run recomputes them. The walk also cross-checks the
    /// sharded index against what the directory actually holds
    /// ([`StoreCheck::index_mismatch`] — the CI gate that the O(changed)
    /// bookkeeping never drifts from the ground truth without being
    /// caught). The store's own counters are not touched: this is an
    /// audit, not a load path.
    pub fn verify(&self, heal: bool) -> StoreCheck {
        let mut check = StoreCheck::default();
        for kind in STORE_KINDS {
            let mut kc = KindCheck {
                kind,
                count: 0,
                bytes: 0,
                bad: 0,
            };
            let dir = self.root.join(kind.dir());
            let entries = self.vfs.read_dir(&dir).unwrap_or_default();
            for (path, meta) in entries {
                if path.extension().and_then(|x| x.to_str()) != Some("art") {
                    continue;
                }
                kc.count += 1;
                kc.bytes += meta.len;
                let ok = self
                    .vfs
                    .read(&path)
                    .ok()
                    .and_then(|bytes| {
                        decode_container(&bytes, kind).map(|p| p.to_vec())
                    })
                    .map(|payload| payload_decodes(kind, &payload))
                    .unwrap_or(false);
                if !ok {
                    kc.bad += 1;
                    check.bad_paths.push(path.clone());
                    if heal {
                        if self.vfs.remove_file(&path).is_ok() {
                            check.healed += 1;
                            kc.count -= 1;
                            kc.bytes -= meta.len;
                        }
                        let _ = self.vfs.remove_file(&path.with_extension("lru"));
                        if let Some(stem) = path.file_stem().and_then(|s| s.to_str()) {
                            self.index_record_remove(kind, stem);
                        }
                    }
                }
            }
            let (icount, ibytes) = {
                let state = self.index_lock();
                let m = state.kind(kind);
                (m.len() as u64, m.values().map(|e| e.size).sum::<u64>())
            };
            if (icount, ibytes) != (kc.count, kc.bytes) {
                check.index_mismatch.push(format!(
                    "{}: index says {} entries / {} bytes, directory holds {} / {}",
                    kind.dir(),
                    icount,
                    ibytes,
                    kc.count,
                    kc.bytes
                ));
            }
            check.total_bytes += kc.bytes;
            check.bad += kc.bad;
            check.kinds.push(kc);
        }
        check
    }
}

fn unix_millis() -> u64 {
    SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_millis() as u64)
        .unwrap_or(0)
}

/// Can this payload be decoded by its kind's typed codec? The emulated
/// image needs no kernel to *structurally* validate — it relocates into a
/// throwaway session.
fn payload_decodes(kind: StoreKind, payload: &[u8]) -> bool {
    match kind {
        StoreKind::Emulated => {
            // both image forms share the kind: complete results and
            // resumable frontier images (tight-budget partials)
            let mut d = Dec::new(payload);
            let Some(_elapsed) = d.u64() else { return false };
            let image = &payload[d.pos()..];
            let session = Arc::new(SessionInterner::new());
            crate::sym::decode_emulation(image, &session).is_some()
                || crate::sym::decode_partial_emulation(image, &session, None).is_some()
        }
        StoreKind::Decoded => decode_decoded(payload).is_some(),
        StoreKind::Detected => decode_detected(payload).is_some(),
        StoreKind::Synthesized => decode_synthesized(payload).is_some(),
        StoreKind::Validated => decode_validated(payload).is_some(),
        StoreKind::Scored => decode_scored(payload).is_some(),
    }
}

/// Result of a [`DiskStore::verify`] audit.
#[derive(Debug, Default, Clone)]
pub struct StoreCheck {
    pub kinds: Vec<KindCheck>,
    pub total_bytes: u64,
    pub bad: u64,
    pub healed: u64,
    pub bad_paths: Vec<PathBuf>,
    /// Kinds whose sharded index disagrees with the directory walk
    /// (human-readable descriptions; empty = the index is coherent).
    pub index_mismatch: Vec<String>,
}

/// Per-kind slice of a [`StoreCheck`].
#[derive(Debug, Clone, Copy)]
pub struct KindCheck {
    pub kind: StoreKind,
    pub count: u64,
    pub bytes: u64,
    pub bad: u64,
}

/// Decoded generation manifest.
#[derive(Debug, Clone, Copy)]
pub struct Manifest {
    pub generation: u64,
    /// Per-kind `(count, bytes)` in [`STORE_KINDS`] order.
    pub kinds: [(u64, u64); STORE_KINDS.len()],
}

struct Entry {
    path: PathBuf,
    size: u64,
    touched: SystemTime,
}

// ---------------------------------------------------------------------------
// Disk keys
// ---------------------------------------------------------------------------

/// Stable 128-bit key builder for disk filenames over the shared
/// [`crate::util::Fnv128`] scheme (the `kernel_fingerprint` scheme —
/// never the process-seeded `DefaultHasher`, keys must be identical
/// run-to-run).
#[derive(Debug)]
pub struct KeyBuilder(crate::util::Fnv128);

impl KeyBuilder {
    pub fn new(tag: &str) -> KeyBuilder {
        let mut h = crate::util::Fnv128::new();
        h.write(tag.as_bytes());
        KeyBuilder(h)
    }

    pub fn bytes(&mut self, bs: &[u8]) -> &mut KeyBuilder {
        self.0.write(bs);
        self
    }

    pub fn u64(&mut self, v: u64) -> &mut KeyBuilder {
        self.0.write_u64(v);
        self
    }

    pub fn hash(&mut self, h: ContentHash) -> &mut KeyBuilder {
        self.u64(h.0).u64(h.1)
    }

    /// Key the full detection-options struct (exhaustive, see
    /// [`DetectOpts::key_into`]).
    pub fn opts(&mut self, o: DetectOpts) -> &mut KeyBuilder {
        o.key_into(&mut self.0);
        self
    }

    /// Key the full elimination-options struct (exhaustive, see
    /// [`ElimOpts::key_into`]).
    pub fn elim(&mut self, o: ElimOpts) -> &mut KeyBuilder {
        o.key_into(&mut self.0);
        self
    }

    /// Key the full emulation-limits struct (exhaustive, see
    /// [`crate::emu::Limits::key_into`]).
    pub fn limits(&mut self, l: crate::emu::Limits) -> &mut KeyBuilder {
        l.key_into(&mut self.0);
        self
    }

    pub fn finish(&self) -> ContentHash {
        let (k0, k1) = self.0.finish();
        ContentHash(k0, k1)
    }
}

fn decode_container(bytes: &[u8], kind: StoreKind) -> Option<&[u8]> {
    if bytes.len() < 17 || bytes[0..4] != MAGIC {
        return None;
    }
    let version = u32::from_le_bytes(bytes[4..8].try_into().ok()?);
    if version != STORE_VERSION || bytes[8] != kind.tag() {
        return None;
    }
    let payload = &bytes[9..bytes.len() - 8];
    let want = u64::from_le_bytes(bytes[bytes.len() - 8..].try_into().ok()?);
    (fnv64(payload) == want).then_some(payload)
}

// ---------------------------------------------------------------------------
// Typed artifact codecs (on the shared `util::codec` primitives)
// ---------------------------------------------------------------------------

fn variant_tag(v: Variant) -> u8 {
    match v {
        Variant::Full => 0,
        Variant::NoLoad => 1,
        Variant::NoCorner => 2,
        Variant::UniformBranch => 3,
    }
}

fn variant_from(tag: u8) -> Option<Variant> {
    Some(match tag {
        0 => Variant::Full,
        1 => Variant::NoLoad,
        2 => Variant::NoCorner,
        3 => Variant::UniformBranch,
        _ => return None,
    })
}

/// Stable byte encoding of a `Variant` for disk keys.
pub fn variant_key_byte(v: Variant) -> u64 {
    variant_tag(v) as u64
}

fn enc_emu_stats(e: &mut Enc, s: &EmuStats) {
    for v in s.to_words() {
        e.u64(v);
    }
}

fn dec_emu_stats(d: &mut Dec) -> Option<EmuStats> {
    let mut w = [0u64; 12];
    for v in w.iter_mut() {
        *v = d.u64()?;
    }
    Some(EmuStats::from_words(w))
}

/// `emulated/` payload: elapsed wall time of the original emulation,
/// followed by the relocatable term-graph image.
pub(crate) fn encode_emulated(a: &Emulated) -> Vec<u8> {
    let mut e = Enc::default();
    e.u64(a.elapsed.as_nanos() as u64);
    e.buf.extend_from_slice(&crate::sym::encode_emulation(&a.result));
    e.buf
}

/// Decode an `emulated/` payload, relocating the term graph into
/// `session`. The caller supplies the kernel and hash the key was built
/// from (the image itself carries no kernel).
pub(crate) fn decode_emulated(
    bytes: &[u8],
    kernel: &Arc<Kernel>,
    hash: ContentHash,
    session: &Arc<SessionInterner>,
) -> Option<Emulated> {
    let mut d = Dec::new(bytes);
    let elapsed = Duration::from_nanos(d.u64()?);
    let result = crate::sym::decode_emulation(&bytes[d.pos()..], session)?;
    Some(Emulated {
        kernel: kernel.clone(),
        hash,
        result,
        elapsed,
    })
}

/// Frontier payload (`emulated/` kind, `emulated.frontier` key family):
/// the nanoseconds the tight run spent before tripping its budget,
/// followed by the resumable partial-emulation image. The elapsed prefix
/// keeps the container shape identical to a complete emulated artifact.
pub(crate) fn encode_frontier(elapsed: Duration, part: &crate::emu::PartialEmulation) -> Vec<u8> {
    let mut e = Enc::default();
    e.u64(elapsed.as_nanos() as u64);
    e.buf
        .extend_from_slice(&crate::sym::encode_partial_emulation(part));
    e.buf
}

/// Decode a frontier payload into `session`, validating the register
/// environment width against `nregs` (pass `None` for a structural-only
/// check with no kernel in hand). Returns the tight run's elapsed time
/// and the resumable image.
pub(crate) fn decode_frontier(
    bytes: &[u8],
    session: &Arc<SessionInterner>,
    nregs: Option<usize>,
) -> Option<(Duration, crate::emu::PartialEmulation)> {
    let mut d = Dec::new(bytes);
    let elapsed = Duration::from_nanos(d.u64()?);
    let part = crate::sym::decode_partial_emulation(&bytes[d.pos()..], session, nregs)?;
    Some((elapsed, part))
}

/// `decoded/` payload: the micro-op kernel's own field image.
pub(crate) fn encode_decoded(dk: &DecodedKernel) -> Vec<u8> {
    dk.to_bytes()
}

pub(crate) fn decode_decoded(bytes: &[u8]) -> Option<DecodedKernel> {
    DecodedKernel::from_bytes(bytes)
}

pub(crate) fn encode_detected(a: &Detected) -> Vec<u8> {
    let mut e = Enc::default();
    e.u64(a.detection.chosen.len() as u64);
    for c in &a.detection.chosen {
        e.u64(c.dst_stmt as u64);
        e.u64(c.src_stmt as u64);
        e.i64(c.delta);
    }
    e.u64(a.detection.total_global_loads as u64);
    match &a.detection.emu_stats {
        None => e.u8(0),
        Some(s) => {
            e.u8(1);
            enc_emu_stats(&mut e, s);
        }
    }
    e.u64(a.elapsed.as_nanos() as u64);
    e.u64(a.emu_elapsed.as_nanos() as u64);
    e.buf
}

pub(crate) fn decode_detected(bytes: &[u8]) -> Option<Detected> {
    let mut d = Dec::new(bytes);
    let n = d.len()?;
    let mut chosen = Vec::with_capacity(n);
    for _ in 0..n {
        chosen.push(Candidate {
            dst_stmt: d.u64()? as usize,
            src_stmt: d.u64()? as usize,
            delta: d.i64()?,
        });
    }
    let total_global_loads = d.u64()? as usize;
    let emu_stats = match d.u8()? {
        0 => None,
        1 => Some(dec_emu_stats(&mut d)?),
        _ => return None,
    };
    let elapsed = Duration::from_nanos(d.u64()?);
    let emu_elapsed = Duration::from_nanos(d.u64()?);
    d.done().then_some(Detected {
        detection: Detection {
            chosen,
            total_global_loads,
            emu_stats,
        },
        elapsed,
        emu_elapsed,
    })
}

pub(crate) fn encode_synthesized(a: &Synthesized) -> Vec<u8> {
    let mut e = Enc::default();
    e.u8(variant_tag(a.variant));
    e.u64(a.source.0);
    e.u64(a.source.1);
    e.u64(a.hash.0);
    e.u64(a.hash.1);
    e.str(&print_kernel(&a.kernel));
    a.elim.encode(&mut e);
    e.buf
}

pub(crate) fn decode_synthesized(bytes: &[u8]) -> Option<Synthesized> {
    let mut d = Dec::new(bytes);
    let variant = variant_from(d.u8()?)?;
    let source = ContentHash(d.u64()?, d.u64()?);
    let hash = ContentHash(d.u64()?, d.u64()?);
    let kernel = parse_kernel(d.str()?).ok()?;
    let elim = ElimReport::decode(&mut d)?;
    d.done().then_some(Synthesized {
        kernel: Arc::new(kernel),
        variant,
        source,
        hash,
        elim,
    })
}

pub(crate) fn encode_validated(a: &Validated) -> Vec<u8> {
    let mut e = Enc::default();
    e.u8(match a.valid {
        None => 0,
        Some(false) => 1,
        Some(true) => 2,
    });
    e.u64(a.out.len() as u64);
    for &x in &a.out {
        e.u32(x.to_bits());
    }
    let s = &a.stats;
    for v in [
        s.warp_instructions,
        s.thread_instructions,
        s.global_loads,
        s.nc_loads,
        s.shared_loads,
        s.stores,
        s.shfls,
        s.branches,
        s.divergent_branches,
        s.uninit_reads,
        s.cross_block_write_conflicts,
        s.barriers,
        s.barrier_phases,
        s.superblocks_entered,
        s.vector_warp_steps,
    ] {
        e.u64(v);
    }
    e.u64(a.trace.len() as u64);
    for warp in &a.trace {
        e.u64(warp.len() as u64);
        for ev in warp {
            e.u32(ev.stmt);
            e.u32(ev.active);
            e.u32(ev.exec);
            e.u64(ev.addr);
        }
    }
    e.buf
}

pub(crate) fn decode_validated(bytes: &[u8]) -> Option<Validated> {
    let mut d = Dec::new(bytes);
    let valid = match d.u8()? {
        0 => None,
        1 => Some(false),
        2 => Some(true),
        _ => return None,
    };
    let n = d.len()?;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        out.push(f32::from_bits(d.u32()?));
    }
    let stats = SimStats {
        warp_instructions: d.u64()?,
        thread_instructions: d.u64()?,
        global_loads: d.u64()?,
        nc_loads: d.u64()?,
        shared_loads: d.u64()?,
        stores: d.u64()?,
        shfls: d.u64()?,
        branches: d.u64()?,
        divergent_branches: d.u64()?,
        uninit_reads: d.u64()?,
        cross_block_write_conflicts: d.u64()?,
        barriers: d.u64()?,
        barrier_phases: d.u64()?,
        superblocks_entered: d.u64()?,
        vector_warp_steps: d.u64()?,
    };
    let nwarps = d.len()?;
    let mut trace = Vec::with_capacity(nwarps);
    for _ in 0..nwarps {
        let nev = d.len()?;
        let mut warp = Vec::with_capacity(nev);
        for _ in 0..nev {
            warp.push(WarpEvent {
                stmt: d.u32()?,
                active: d.u32()?,
                exec: d.u32()?,
                addr: d.u64()?,
            });
        }
        trace.push(warp);
    }
    d.done().then_some(Validated {
        out,
        stats,
        trace,
        valid,
    })
}

pub(crate) fn encode_scored(a: &Scored) -> Vec<u8> {
    let mut e = Enc::default();
    let r = &a.report;
    e.str(r.arch);
    e.f64(r.serial_cycles);
    e.f64(r.issue_cycles);
    for s in r.stalls {
        e.f64(s);
    }
    e.f64(r.occupancy);
    e.u32(r.regs_per_thread);
    e.f64(r.mem_cycles);
    e.f64(r.dram_cycles);
    e.f64(r.effective_cycles);
    e.buf
}

pub(crate) fn decode_scored(bytes: &[u8]) -> Option<Scored> {
    let mut d = Dec::new(bytes);
    // resolve through the arch table so `arch` stays a &'static str
    let arch = crate::perf::by_name(d.str()?)?.name;
    let serial_cycles = d.f64()?;
    let issue_cycles = d.f64()?;
    let mut stalls = [0f64; 8];
    for s in &mut stalls {
        *s = d.f64()?;
    }
    let occupancy = d.f64()?;
    let regs_per_thread = d.u32()?;
    let mem_cycles = d.f64()?;
    let dram_cycles = d.f64()?;
    let effective_cycles = d.f64()?;
    d.done().then_some(Scored {
        report: PerfReport {
            arch,
            serial_cycles,
            issue_cycles,
            stalls,
            occupancy,
            regs_per_thread,
            mem_cycles,
            dram_cycles,
            effective_cycles,
        },
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "ptxasw-store-unit-{tag}-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn roundtrip_and_counters() {
        let dir = tmp("roundtrip");
        let s = DiskStore::open(&dir, 1 << 20).unwrap();
        let key = ContentHash(1, 2);
        assert!(s.load(StoreKind::Validated, key).is_none());
        s.store(StoreKind::Validated, key, b"hello artifact");
        assert_eq!(s.load(StoreKind::Validated, key).unwrap(), b"hello artifact");
        // a second store instance over the same dir sees the artifact
        let s2 = DiskStore::open(&dir, 1 << 20).unwrap();
        assert_eq!(s2.load(StoreKind::Validated, key).unwrap(), b"hello artifact");
        let snap = s.snapshot();
        assert_eq!((snap.hits, snap.misses, snap.stores), (1, 1, 1));
        assert!(snap.resident_bytes > 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn kind_and_key_isolation() {
        let dir = tmp("isolation");
        let s = DiskStore::open(&dir, 1 << 20).unwrap();
        s.store(StoreKind::Detected, ContentHash(7, 7), b"det");
        assert!(s.load(StoreKind::Scored, ContentHash(7, 7)).is_none());
        assert!(s.load(StoreKind::Detected, ContentHash(7, 8)).is_none());
        assert_eq!(s.load(StoreKind::Detected, ContentHash(7, 7)).unwrap(), b"det");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_files_are_discarded() {
        let dir = tmp("corrupt");
        let s = DiskStore::open(&dir, 1 << 20).unwrap();
        let key = ContentHash(3, 4);
        s.store(StoreKind::Scored, key, b"payload-bytes");
        let path = s.art_path(StoreKind::Scored, key);

        // flip a payload byte → checksum mismatch
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[10] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        assert!(s.load(StoreKind::Scored, key).is_none());
        assert!(!path.exists(), "corrupt file must be removed");

        // truncated file
        s.store(StoreKind::Scored, key, b"payload-bytes");
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..7]).unwrap();
        assert!(s.load(StoreKind::Scored, key).is_none());
        assert!(s.snapshot().corrupt >= 2);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn lru_eviction_respects_bound_and_recency() {
        let dir = tmp("evict");
        // payloads of 1000 bytes + 17 header per artifact; the bound
        // fits two of them above the 90% low-water mark (2160), so
        // storing a third evicts exactly the least-recently-used one
        let s = DiskStore::open(&dir, 2400).unwrap();
        let payload = vec![0u8; 1000];
        s.store(StoreKind::Validated, ContentHash(1, 0), &payload);
        std::thread::sleep(std::time::Duration::from_millis(20));
        s.store(StoreKind::Validated, ContentHash(2, 0), &payload);
        std::thread::sleep(std::time::Duration::from_millis(20));
        // touch the older artifact so it becomes most-recently-used
        assert!(s.load(StoreKind::Validated, ContentHash(1, 0)).is_some());
        std::thread::sleep(std::time::Duration::from_millis(20));
        s.store(StoreKind::Validated, ContentHash(3, 0), &payload);

        // bound respected…
        let total: u64 = s.scan().iter().map(|e| e.size).sum();
        assert!(total <= 2400, "resident {total} exceeds the bound");
        assert!(s.snapshot().evictions >= 1);
        // …and the least-recently-used artifact (2) was the one evicted
        assert!(s.load(StoreKind::Validated, ContentHash(1, 0)).is_some());
        assert!(s.load(StoreKind::Validated, ContentHash(2, 0)).is_none());
        assert!(s.load(StoreKind::Validated, ContentHash(3, 0)).is_some());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn eviction_publishes_a_manifest_generation_and_foreign_stores_resync() {
        let dir = tmp("manifest");
        let s = DiskStore::open(&dir, 2400).unwrap();
        assert!(s.read_manifest().is_none(), "fresh store has no manifest");
        let payload = vec![0u8; 1000];
        for i in 0..4 {
            s.store(StoreKind::Validated, ContentHash(i, 0), &payload);
        }
        let m = s.read_manifest().expect("eviction must publish a manifest");
        assert!(m.generation >= 1);
        assert_eq!(s.snapshot().generation, m.generation);

        // a second store over the same dir opens at that generation and
        // its resident counter matches a fresh scan
        let s2 = DiskStore::open(&dir, 2400).unwrap();
        assert_eq!(s2.snapshot().generation, m.generation);
        let total: u64 = s2.scan().iter().map(|e| e.size).sum();
        assert_eq!(s2.snapshot().resident_bytes, total);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn store_and_evict_perform_no_directory_rescan() {
        use crate::util::vfs::{FaultFs, FaultOp};
        let dir = tmp("ochanged");
        let fs = FaultFs::real();
        let s = DiskStore::open_on(fs.clone(), &dir, 2400).unwrap();
        let payload = vec![0u8; 1000];
        s.store(StoreKind::Validated, ContentHash(1, 0), &payload);
        std::thread::sleep(std::time::Duration::from_millis(5));
        s.store(StoreKind::Validated, ContentHash(2, 0), &payload);
        std::thread::sleep(std::time::Duration::from_millis(5));

        // acceptance pin: a store that trips the bound must pick its
        // victims from the sharded index — zero `read_dir` calls across
        // the store *and* the eviction round it triggers (O(changed),
        // not O(entries))
        let before = fs.seen(FaultOp::ReadDir);
        s.store(StoreKind::Validated, ContentHash(3, 0), &payload);
        assert!(s.snapshot().evictions >= 1, "bound must force an eviction");
        assert_eq!(
            fs.seen(FaultOp::ReadDir) - before,
            0,
            "store+evict must not rescan any directory"
        );
        assert_eq!(s.snapshot().index_rebuilds, 1, "only the cold open scans");

        // and the index the eviction maintained agrees with the ground
        // truth a full verify walk computes
        let check = s.verify(false);
        assert!(check.index_mismatch.is_empty(), "{:?}", check.index_mismatch);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn warm_open_seeds_from_index_shards_and_drift_forces_rebuild() {
        let dir = tmp("seed");
        let resident = {
            let s = DiskStore::open(&dir, 1 << 20).unwrap();
            s.store(StoreKind::Validated, ContentHash(1, 0), b"alpha");
            s.store(StoreKind::Scored, ContentHash(2, 0), b"beta-beta");
            s.snapshot().resident_bytes
        };

        // clean reopen: the shards carry the whole picture — no rescan
        let s = DiskStore::open(&dir, 1 << 20).unwrap();
        assert_eq!(s.snapshot().index_rebuilds, 0, "seeded open must not scan");
        assert_eq!(s.snapshot().resident_bytes, resident);
        assert!(s.verify(false).index_mismatch.is_empty());

        // a corrupt shard invalidates the lot: the next open rebuilds
        let shard = s.index_path(StoreKind::Scored);
        let mut bytes = std::fs::read(&shard).unwrap();
        let n = bytes.len();
        bytes[n - 3] ^= 0xFF;
        std::fs::write(&shard, &bytes).unwrap();
        let s2 = DiskStore::open(&dir, 1 << 20).unwrap();
        assert_eq!(s2.snapshot().index_rebuilds, 1, "drifted shard must rescan");
        assert_eq!(s2.snapshot().resident_bytes, resident);
        assert!(s2.verify(false).index_mismatch.is_empty());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn verify_reports_index_drift_and_resync_heals_it() {
        let dir = tmp("drift");
        let s = DiskStore::open(&dir, 1 << 20).unwrap();
        s.store(StoreKind::Validated, ContentHash(1, 0), b"payload");
        assert!(s.verify(false).index_mismatch.is_empty());

        // simulate a foreign process deleting an artifact behind our back
        let path = s.art_path(StoreKind::Validated, ContentHash(1, 0));
        std::fs::remove_file(&path).unwrap();
        let check = s.verify(false);
        assert_eq!(check.index_mismatch.len(), 1, "{:?}", check.index_mismatch);
        assert!(check.index_mismatch[0].contains("validated"));

        // a rebuild (the resync path) restores coherence
        s.rebuild_index();
        assert!(s.verify(false).index_mismatch.is_empty());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn frontier_payloads_coexist_with_complete_images_in_the_emulated_kind() {
        use crate::emu::{emulate_outcome, EmuOutcome, Limits};
        use crate::ptx::parser::parse_kernel;
        let src = r#"
.visible .entry fr(.param .u64 out){
.reg .b32 %r<8>; .reg .b64 %rd<4>; .reg .pred %p<4>;
ld.param.u64 %rd1, [out];
cvta.to.global.u64 %rd2, %rd1;
mov.u32 %r1, %tid.x;
and.b32 %r2, %r1, 1;
setp.eq.s32 %p1, %r2, 0;
@%p1 bra $A;
add.s32 %r1, %r1, 7;
$A:
and.b32 %r3, %r1, 2;
setp.eq.s32 %p2, %r3, 0;
@%p2 bra $B;
add.s32 %r1, %r1, 9;
$B:
st.global.u32 [%rd2], %r1;
ret;
}
"#;
        let kernel = Arc::new(parse_kernel(src).unwrap());
        let session = Arc::new(SessionInterner::new());
        let tight = Limits {
            max_flows: 2,
            ..Limits::default()
        };
        let EmuOutcome::Partial(part) =
            emulate_outcome(&kernel, tight, session.clone(), None)
        else {
            panic!("tight budget must trip mid-exploration");
        };
        let elapsed = Duration::from_nanos(12345);
        let payload = encode_frontier(elapsed, &part);

        // the store accepts it under the emulated kind and verify's typed
        // audit recognizes it as well-formed
        let dir = tmp("frontier");
        let s = DiskStore::open(&dir, 1 << 20).unwrap();
        let key = KeyBuilder::new("emulated.frontier")
            .hash(ContentHash(5, 6))
            .limits(tight)
            .finish();
        s.store(StoreKind::Emulated, key, &payload);
        let check = s.verify(false);
        assert_eq!(check.bad, 0, "frontier image must pass the typed audit");

        // and it round-trips through the frontier decoder
        let loaded = s.load(StoreKind::Emulated, key).unwrap();
        let fresh = Arc::new(SessionInterner::new());
        let (got_elapsed, got) = decode_frontier(&loaded, &fresh, None).unwrap();
        assert_eq!(got_elapsed, elapsed);
        assert_eq!(got.pending.len(), part.pending.len());
        assert!(decode_frontier(&loaded, &fresh, Some(999)).is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn live_foreign_lock_skips_eviction_and_stale_lock_is_taken_over() {
        let dir = tmp("lock");
        let s = DiskStore::open(&dir, 2400).unwrap();
        let payload = vec![0u8; 1000];
        s.store(StoreKind::Validated, ContentHash(1, 0), &payload);
        s.store(StoreKind::Validated, ContentHash(2, 0), &payload);

        // a live foreign holder: fresh timestamp, different pid
        let mut lock = Vec::new();
        lock.extend_from_slice(&999_999u64.to_le_bytes());
        lock.extend_from_slice(&super::unix_millis().to_le_bytes());
        std::fs::write(s.lock_path(), &lock).unwrap();
        s.store(StoreKind::Validated, ContentHash(3, 0), &payload);
        assert!(s.snapshot().lock_skips >= 1, "live lock must skip eviction");
        assert_eq!(s.snapshot().evictions, 0);

        // age the lock past the stale bound: the next evictor takes over
        let mut stale = Vec::new();
        stale.extend_from_slice(&999_999u64.to_le_bytes());
        stale.extend_from_slice(
            &super::unix_millis()
                .saturating_sub(super::STALE_LOCK_MS + 1000)
                .to_le_bytes(),
        );
        std::fs::write(s.lock_path(), &stale).unwrap();
        s.store(StoreKind::Validated, ContentHash(4, 0), &payload);
        assert!(s.snapshot().evictions >= 1, "stale lock must be taken over");
        assert!(!s.lock_path().exists(), "lock released after eviction");

        // garbage lock contents are treated as stale, not trusted
        std::fs::write(s.lock_path(), b"not-a-lock").unwrap();
        assert!(s.acquire_process_lock());
        s.release_process_lock();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn open_sweeps_abandoned_temp_files() {
        let dir = tmp("sweep");
        {
            let s = DiskStore::open(&dir, 1 << 20).unwrap();
            s.store(StoreKind::Scored, ContentHash(1, 1), b"keep");
        }
        let kind_dir = dir.join(format!("v{STORE_VERSION}")).join("scored");
        std::fs::write(kind_dir.join("deadbeef.tmp123-0"), b"debris").unwrap();
        let s = DiskStore::open(&dir, 1 << 20).unwrap();
        assert_eq!(s.snapshot().swept_tmp, 1);
        assert!(!kind_dir.join("deadbeef.tmp123-0").exists());
        assert_eq!(s.load(StoreKind::Scored, ContentHash(1, 1)).unwrap(), b"keep");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn verify_flags_and_heals_undecodable_payloads() {
        let dir = tmp("verify");
        let s = DiskStore::open(&dir, 1 << 20).unwrap();
        // a container-valid but typed-undecodable payload (random bytes
        // are not a Scored image)
        s.store(StoreKind::Scored, ContentHash(1, 0), b"not a scored image");
        let check = s.verify(false);
        assert_eq!(check.bad, 1);
        assert_eq!(check.healed, 0);
        assert!(s.load(StoreKind::Scored, ContentHash(1, 0)).is_some(), "audit must not delete");

        let check = s.verify(true);
        assert_eq!((check.bad, check.healed), (1, 1));
        assert!(s.verify(false).bad == 0, "healed store is coherent");
        assert!(
            !s.art_path(StoreKind::Scored, ContentHash(1, 0)).exists(),
            "healing removes the entry"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn key_builder_is_stable() {
        let a = KeyBuilder::new("t").u64(1).hash(ContentHash(2, 3)).finish();
        let b = KeyBuilder::new("t").u64(1).hash(ContentHash(2, 3)).finish();
        assert_eq!(a, b);
        let c = KeyBuilder::new("t").u64(2).hash(ContentHash(2, 3)).finish();
        assert_ne!(a, c);
        let d = KeyBuilder::new("u").u64(1).hash(ContentHash(2, 3)).finish();
        assert_ne!(a, d);
    }

    #[test]
    fn key_builder_separates_elim_opts() {
        let base = KeyBuilder::new("s").elim(ElimOpts::default()).finish();
        let off = KeyBuilder::new("s")
            .elim(ElimOpts {
                enabled: false,
                block: 32,
            })
            .finish();
        let wider = KeyBuilder::new("s")
            .elim(ElimOpts {
                enabled: true,
                block: 64,
            })
            .finish();
        assert_ne!(base, off);
        assert_ne!(base, wider);
        assert_ne!(off, wider);
    }

    #[test]
    fn synthesized_payload_roundtrips_and_rejects_corruption() {
        use crate::ptx::printer::kernel_fingerprint;
        use crate::shuffle::phase_liveness::{BarrierElim, StoreElim};

        let kernel = parse_kernel(
            r#"
.visible .entry rt(.param .u64 out){
.reg .f32 %f<2>;
mov.f32 %f1, 0f3F800000;
ret;
}
"#,
        )
        .unwrap();
        let art = Synthesized {
            hash: kernel_fingerprint(&kernel),
            kernel: Arc::new(kernel),
            variant: Variant::Full,
            source: ContentHash(11, 13),
            elim: ElimReport {
                bail: None,
                stores: vec![StoreElim {
                    stmt: 3,
                    deleted: true,
                    reason: "all readers forwarded".into(),
                }],
                barriers: vec![BarrierElim {
                    stmt: 5,
                    elided: false,
                    reason: "unproven cross-lane traffic".into(),
                }],
                forwarded_loads: 2,
                dce_stmts: 4,
            },
        };
        let bytes = encode_synthesized(&art);
        let back = decode_synthesized(&bytes).unwrap();
        assert_eq!(back.variant, art.variant);
        assert_eq!(back.source, art.source);
        assert_eq!(back.hash, art.hash);
        assert_eq!(back.elim, art.elim);

        // every strict prefix must decode to None, never panic
        for cut in 0..bytes.len() {
            assert!(decode_synthesized(&bytes[..cut]).is_none(), "prefix {cut}");
        }
        // trailing garbage is rejected by the `done()` gate
        let mut padded = bytes.clone();
        padded.push(0);
        assert!(decode_synthesized(&padded).is_none());
        // randomized single-byte flips: decode may reject or may yield a
        // structurally different artifact, but must never panic
        let mut rng = crate::util::Rng::new(0x5eed);
        for _ in 0..64 {
            let mut evil = bytes.clone();
            let i = rng.below(evil.len() as u64) as usize;
            evil[i] ^= (rng.below(255) + 1) as u8;
            let _ = decode_synthesized(&evil);
        }
    }
}
