//! On-disk persistence for pipeline artifacts.
//!
//! The in-memory [`crate::pipeline::ArtifactCache`] makes repeated lookups
//! free *within* a process; this module extends the content addressing
//! across processes, so a recompile-heavy workflow (the ACC-Saturator /
//! JACC use case: the same kernels re-analyzed on every build) pays for
//! emulation, simulation and scoring once per machine instead of once per
//! run.
//!
//! Layout (one file per artifact, under a format-version directory):
//!
//! ```text
//! <cache-dir>/v1/<kind>/<32-hex-key>.art   artifact (header + payload)
//! <cache-dir>/v1/<kind>/<32-hex-key>.lru   empty touch marker (last use)
//! ```
//!
//! `<kind>` is one of `emulated`, `decoded`, `detected`, `synthesized`,
//! `validated`, `scored`. Emulations persist through the relocatable
//! term-graph codec ([`crate::sym::persist`]) — the image spells symbol/UF
//! names out and the loader re-interns them through its own session, so
//! the interner-relative ids never touch disk. Decoded micro-op kernels
//! persist as plain field images ([`crate::sim::DecodedKernel::to_bytes`]).
//! Workloads are *not* persisted: they are cheap to regenerate from their
//! fingerprint inputs.
//!
//! Every file is `MAGIC ∥ version ∥ kind ∥ payload ∥ fnv64(payload)`.
//! Loads are corruption-tolerant: any header/checksum/decode mismatch
//! deletes the file, counts it, and falls back to recompute. Writes go
//! through a temp file + rename so readers never observe a torn artifact.
//! The store is LRU size-bounded: after each write the store evicts
//! least-recently-used artifacts (by touch-marker mtime) until the
//! resident set fits `max_bytes`.

use crate::emu::EmuStats;
use crate::perf::PerfReport;
use crate::pipeline::artifact::{Detected, Emulated, Synthesized};
use crate::pipeline::stages::{Scored, Validated};
use crate::ptx::ast::Kernel;
use crate::ptx::parser::parse_kernel;
use crate::ptx::printer::{print_kernel, ContentHash};
use crate::shuffle::{Candidate, DetectOpts, Detection, ElimOpts, ElimReport, Variant};
use crate::sim::{DecodedKernel, SimStats, WarpEvent};
use crate::sym::SessionInterner;
use crate::util::{fnv64, Dec, Enc};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, SystemTime};

/// Bump when the artifact encoding changes; old `v<N>` trees are simply
/// ignored (and eventually reclaimed by the user, not by us).
/// v2: `SimStats` grew `cross_block_write_conflicts`.
/// v3: new `emulated/` (relocatable term-graph images) and `decoded/`
/// (micro-op kernel) artifact kinds.
/// v4: `SimStats` grew the barrier counters, memory-trace records carry
/// the barrier phase, and the decoded form carries branch statement
/// positions (`nstmts`, `Bra::target_stmt`, `BarSync` id/cnt).
/// v5: `SimStats` grew the engine telemetry counters
/// (`superblocks_entered`, `vector_warp_steps`) and the decoded form
/// carries the superblock table (`sb_end`).
/// v6: `synthesized/` artifacts carry the phase-liveness [`ElimReport`]
/// (dead-store / barrier-elision verdicts) and their disk key includes
/// the [`ElimOpts`] fingerprint.
pub const STORE_VERSION: u32 = 6;
const MAGIC: [u8; 4] = *b"RPST";
/// Default resident-set bound: 256 MiB.
pub const DEFAULT_MAX_BYTES: u64 = 256 * 1024 * 1024;

/// Artifact families the store persists.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StoreKind {
    Emulated,
    Decoded,
    Detected,
    Synthesized,
    Validated,
    Scored,
}

pub const STORE_KINDS: [StoreKind; 6] = [
    StoreKind::Emulated,
    StoreKind::Decoded,
    StoreKind::Detected,
    StoreKind::Synthesized,
    StoreKind::Validated,
    StoreKind::Scored,
];

impl StoreKind {
    pub fn dir(self) -> &'static str {
        match self {
            StoreKind::Emulated => "emulated",
            StoreKind::Decoded => "decoded",
            StoreKind::Detected => "detected",
            StoreKind::Synthesized => "synthesized",
            StoreKind::Validated => "validated",
            StoreKind::Scored => "scored",
        }
    }

    fn tag(self) -> u8 {
        match self {
            StoreKind::Detected => 1,
            StoreKind::Synthesized => 2,
            StoreKind::Validated => 3,
            StoreKind::Scored => 4,
            StoreKind::Emulated => 5,
            StoreKind::Decoded => 6,
        }
    }
}

/// Point-in-time view of the store's counters (all zero when no store is
/// attached to the pipeline).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct DiskSnapshot {
    pub enabled: bool,
    pub hits: u64,
    pub misses: u64,
    pub stores: u64,
    pub evictions: u64,
    /// Corrupt / truncated / undecodable files discarded on load.
    pub corrupt: u64,
    pub resident_bytes: u64,
}

/// The persistent artifact store. One per cache directory; safe to share
/// across threads (and, best-effort, across processes: writes are atomic
/// renames, and a file evicted under a concurrent reader just recomputes).
#[derive(Debug)]
pub struct DiskStore {
    root: PathBuf,
    max_bytes: u64,
    evict_lock: Mutex<()>,
    hits: AtomicU64,
    misses: AtomicU64,
    stores: AtomicU64,
    evictions: AtomicU64,
    corrupt: AtomicU64,
    resident: AtomicU64,
}

/// The default cache directory: `$RUST_PALLAS_CACHE_DIR`, else
/// `$HOME/.cache/rust_pallas`, else `None` (disk cache disabled).
pub fn default_dir() -> Option<PathBuf> {
    if let Some(d) = std::env::var_os("RUST_PALLAS_CACHE_DIR") {
        return Some(PathBuf::from(d));
    }
    std::env::var_os("HOME").map(|h| PathBuf::from(h).join(".cache").join("rust_pallas"))
}

impl DiskStore {
    /// Open (creating if needed) a store rooted at `dir`, bounded to
    /// `max_bytes` of resident artifacts.
    pub fn open(dir: &Path, max_bytes: u64) -> std::io::Result<DiskStore> {
        let root = dir.join(format!("v{STORE_VERSION}"));
        for kind in STORE_KINDS {
            std::fs::create_dir_all(root.join(kind.dir()))?;
        }
        let store = DiskStore {
            root,
            max_bytes,
            evict_lock: Mutex::new(()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            stores: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            corrupt: AtomicU64::new(0),
            resident: AtomicU64::new(0),
        };
        store.resident.store(store.scan().iter().map(|e| e.size).sum(), Ordering::Relaxed);
        Ok(store)
    }

    /// Open with the default size bound.
    pub fn open_default(dir: &Path) -> std::io::Result<DiskStore> {
        DiskStore::open(dir, DEFAULT_MAX_BYTES)
    }

    pub fn snapshot(&self) -> DiskSnapshot {
        DiskSnapshot {
            enabled: true,
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            stores: self.stores.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            corrupt: self.corrupt.load(Ordering::Relaxed),
            resident_bytes: self.resident.load(Ordering::Relaxed),
        }
    }

    fn art_path(&self, kind: StoreKind, key: ContentHash) -> PathBuf {
        self.root.join(kind.dir()).join(format!("{key}.art"))
    }

    /// Load and verify an artifact's payload. Any malformed file is
    /// removed and counted; the caller recomputes.
    pub fn load(&self, kind: StoreKind, key: ContentHash) -> Option<Vec<u8>> {
        self.load_decoded(kind, key, |payload| Some(payload.to_vec()))
    }

    /// Load, verify *and decode* an artifact in one accounting unit: a
    /// file whose container checks out but whose payload fails the typed
    /// decoder (format drift within one `STORE_VERSION`) is treated
    /// exactly like a corrupt file — removed, counted, recomputed — and
    /// is never reported as a disk hit.
    pub fn load_decoded<T>(
        &self,
        kind: StoreKind,
        key: ContentHash,
        decode: impl FnOnce(&[u8]) -> Option<T>,
    ) -> Option<T> {
        let path = self.art_path(kind, key);
        let bytes = match std::fs::read(&path) {
            Ok(b) => b,
            Err(_) => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                return None;
            }
        };
        match decode_container(&bytes, kind).and_then(decode) {
            Some(artifact) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                // bump the LRU clock; failure is harmless (falls back to
                // the artifact's own mtime)
                let _ = std::fs::File::create(path.with_extension("lru"));
                Some(artifact)
            }
            None => {
                self.corrupt.fetch_add(1, Ordering::Relaxed);
                self.misses.fetch_add(1, Ordering::Relaxed);
                let _ = std::fs::remove_file(&path);
                let _ = std::fs::remove_file(path.with_extension("lru"));
                None
            }
        }
    }

    /// Persist an artifact payload, then evict down to the size bound.
    /// I/O failures are swallowed: the disk layer is an accelerator, never
    /// a correctness dependency.
    pub fn store(&self, kind: StoreKind, key: ContentHash, payload: &[u8]) {
        let path = self.art_path(kind, key);
        let mut bytes = Vec::with_capacity(payload.len() + 17);
        bytes.extend_from_slice(&MAGIC);
        bytes.extend_from_slice(&STORE_VERSION.to_le_bytes());
        bytes.push(kind.tag());
        bytes.extend_from_slice(payload);
        bytes.extend_from_slice(&fnv64(payload).to_le_bytes());

        // pid + global nonce: two stores in one process racing on the
        // same key must not interleave writes into one temp file
        static TMP_NONCE: AtomicU64 = AtomicU64::new(0);
        let tmp = path.with_extension(format!(
            "tmp{}-{}",
            std::process::id(),
            TMP_NONCE.fetch_add(1, Ordering::Relaxed)
        ));
        let old = std::fs::metadata(&path).map(|m| m.len()).unwrap_or(0);
        if std::fs::write(&tmp, &bytes).is_ok() && std::fs::rename(&tmp, &path).is_ok() {
            self.stores.fetch_add(1, Ordering::Relaxed);
            let new = bytes.len() as u64;
            if new >= old {
                self.resident.fetch_add(new - old, Ordering::Relaxed);
            } else {
                self.resident.fetch_sub(old - new, Ordering::Relaxed);
            }
            self.evict_to_limit();
        } else {
            let _ = std::fs::remove_file(&tmp);
        }
    }

    /// All resident artifacts with size and last-use time.
    fn scan(&self) -> Vec<Entry> {
        let mut out = Vec::new();
        for kind in STORE_KINDS {
            let dir = self.root.join(kind.dir());
            let Ok(rd) = std::fs::read_dir(&dir) else { continue };
            for e in rd.flatten() {
                let path = e.path();
                if path.extension().and_then(|x| x.to_str()) != Some("art") {
                    continue;
                }
                let Ok(meta) = e.metadata() else { continue };
                let touched = std::fs::metadata(path.with_extension("lru"))
                    .and_then(|m| m.modified())
                    .or_else(|_| meta.modified())
                    .unwrap_or(SystemTime::UNIX_EPOCH);
                out.push(Entry {
                    path,
                    size: meta.len(),
                    touched,
                });
            }
        }
        out
    }

    /// Remove least-recently-used artifacts until the resident set fits
    /// `max_bytes`, overshooting down to a 90% low-water mark so a cache
    /// sitting at its bound does not pay a full directory scan on every
    /// subsequent write. The counter is only ever *decremented* by what
    /// was actually removed — overwriting it with a scan total would
    /// clobber concurrent `store()` increments and leave the bound
    /// violated.
    fn evict_to_limit(&self) {
        if self.resident.load(Ordering::Relaxed) <= self.max_bytes {
            return;
        }
        let low_water = self.max_bytes - self.max_bytes / 10;
        let _guard = self.evict_lock.lock().unwrap();
        let mut entries = self.scan();
        let mut total: u64 = entries.iter().map(|e| e.size).sum();
        entries.sort_by(|a, b| a.touched.cmp(&b.touched).then(a.path.cmp(&b.path)));
        for e in entries {
            if total <= low_water {
                break;
            }
            if std::fs::remove_file(&e.path).is_ok() {
                let _ = std::fs::remove_file(e.path.with_extension("lru"));
                total -= e.size;
                let _ = self
                    .resident
                    .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| {
                        Some(v.saturating_sub(e.size))
                    });
                self.evictions.fetch_add(1, Ordering::Relaxed);
            }
        }
    }
}

struct Entry {
    path: PathBuf,
    size: u64,
    touched: SystemTime,
}

// ---------------------------------------------------------------------------
// Disk keys
// ---------------------------------------------------------------------------

/// Stable 128-bit key builder for disk filenames over the shared
/// [`crate::util::Fnv128`] scheme (the `kernel_fingerprint` scheme —
/// never the process-seeded `DefaultHasher`, keys must be identical
/// run-to-run).
#[derive(Debug)]
pub struct KeyBuilder(crate::util::Fnv128);

impl KeyBuilder {
    pub fn new(tag: &str) -> KeyBuilder {
        let mut h = crate::util::Fnv128::new();
        h.write(tag.as_bytes());
        KeyBuilder(h)
    }

    pub fn bytes(&mut self, bs: &[u8]) -> &mut KeyBuilder {
        self.0.write(bs);
        self
    }

    pub fn u64(&mut self, v: u64) -> &mut KeyBuilder {
        self.0.write_u64(v);
        self
    }

    pub fn hash(&mut self, h: ContentHash) -> &mut KeyBuilder {
        self.u64(h.0).u64(h.1)
    }

    /// Key the full detection-options struct (exhaustive, see
    /// [`DetectOpts::key_into`]).
    pub fn opts(&mut self, o: DetectOpts) -> &mut KeyBuilder {
        o.key_into(&mut self.0);
        self
    }

    /// Key the full elimination-options struct (exhaustive, see
    /// [`ElimOpts::key_into`]).
    pub fn elim(&mut self, o: ElimOpts) -> &mut KeyBuilder {
        o.key_into(&mut self.0);
        self
    }

    pub fn finish(&self) -> ContentHash {
        let (k0, k1) = self.0.finish();
        ContentHash(k0, k1)
    }
}

fn decode_container(bytes: &[u8], kind: StoreKind) -> Option<&[u8]> {
    if bytes.len() < 17 || bytes[0..4] != MAGIC {
        return None;
    }
    let version = u32::from_le_bytes(bytes[4..8].try_into().ok()?);
    if version != STORE_VERSION || bytes[8] != kind.tag() {
        return None;
    }
    let payload = &bytes[9..bytes.len() - 8];
    let want = u64::from_le_bytes(bytes[bytes.len() - 8..].try_into().ok()?);
    (fnv64(payload) == want).then_some(payload)
}

// ---------------------------------------------------------------------------
// Typed artifact codecs (on the shared `util::codec` primitives)
// ---------------------------------------------------------------------------

fn variant_tag(v: Variant) -> u8 {
    match v {
        Variant::Full => 0,
        Variant::NoLoad => 1,
        Variant::NoCorner => 2,
        Variant::UniformBranch => 3,
    }
}

fn variant_from(tag: u8) -> Option<Variant> {
    Some(match tag {
        0 => Variant::Full,
        1 => Variant::NoLoad,
        2 => Variant::NoCorner,
        3 => Variant::UniformBranch,
        _ => return None,
    })
}

/// Stable byte encoding of a `Variant` for disk keys.
pub fn variant_key_byte(v: Variant) -> u64 {
    variant_tag(v) as u64
}

fn enc_emu_stats(e: &mut Enc, s: &EmuStats) {
    for v in s.to_words() {
        e.u64(v);
    }
}

fn dec_emu_stats(d: &mut Dec) -> Option<EmuStats> {
    let mut w = [0u64; 12];
    for v in w.iter_mut() {
        *v = d.u64()?;
    }
    Some(EmuStats::from_words(w))
}

/// `emulated/` payload: elapsed wall time of the original emulation,
/// followed by the relocatable term-graph image.
pub(crate) fn encode_emulated(a: &Emulated) -> Vec<u8> {
    let mut e = Enc::default();
    e.u64(a.elapsed.as_nanos() as u64);
    e.buf.extend_from_slice(&crate::sym::encode_emulation(&a.result));
    e.buf
}

/// Decode an `emulated/` payload, relocating the term graph into
/// `session`. The caller supplies the kernel and hash the key was built
/// from (the image itself carries no kernel).
pub(crate) fn decode_emulated(
    bytes: &[u8],
    kernel: &Arc<Kernel>,
    hash: ContentHash,
    session: &Arc<SessionInterner>,
) -> Option<Emulated> {
    let mut d = Dec::new(bytes);
    let elapsed = Duration::from_nanos(d.u64()?);
    let result = crate::sym::decode_emulation(&bytes[d.pos()..], session)?;
    Some(Emulated {
        kernel: kernel.clone(),
        hash,
        result,
        elapsed,
    })
}

/// `decoded/` payload: the micro-op kernel's own field image.
pub(crate) fn encode_decoded(dk: &DecodedKernel) -> Vec<u8> {
    dk.to_bytes()
}

pub(crate) fn decode_decoded(bytes: &[u8]) -> Option<DecodedKernel> {
    DecodedKernel::from_bytes(bytes)
}

pub(crate) fn encode_detected(a: &Detected) -> Vec<u8> {
    let mut e = Enc::default();
    e.u64(a.detection.chosen.len() as u64);
    for c in &a.detection.chosen {
        e.u64(c.dst_stmt as u64);
        e.u64(c.src_stmt as u64);
        e.i64(c.delta);
    }
    e.u64(a.detection.total_global_loads as u64);
    match &a.detection.emu_stats {
        None => e.u8(0),
        Some(s) => {
            e.u8(1);
            enc_emu_stats(&mut e, s);
        }
    }
    e.u64(a.elapsed.as_nanos() as u64);
    e.u64(a.emu_elapsed.as_nanos() as u64);
    e.buf
}

pub(crate) fn decode_detected(bytes: &[u8]) -> Option<Detected> {
    let mut d = Dec::new(bytes);
    let n = d.len()?;
    let mut chosen = Vec::with_capacity(n);
    for _ in 0..n {
        chosen.push(Candidate {
            dst_stmt: d.u64()? as usize,
            src_stmt: d.u64()? as usize,
            delta: d.i64()?,
        });
    }
    let total_global_loads = d.u64()? as usize;
    let emu_stats = match d.u8()? {
        0 => None,
        1 => Some(dec_emu_stats(&mut d)?),
        _ => return None,
    };
    let elapsed = Duration::from_nanos(d.u64()?);
    let emu_elapsed = Duration::from_nanos(d.u64()?);
    d.done().then_some(Detected {
        detection: Detection {
            chosen,
            total_global_loads,
            emu_stats,
        },
        elapsed,
        emu_elapsed,
    })
}

pub(crate) fn encode_synthesized(a: &Synthesized) -> Vec<u8> {
    let mut e = Enc::default();
    e.u8(variant_tag(a.variant));
    e.u64(a.source.0);
    e.u64(a.source.1);
    e.u64(a.hash.0);
    e.u64(a.hash.1);
    e.str(&print_kernel(&a.kernel));
    a.elim.encode(&mut e);
    e.buf
}

pub(crate) fn decode_synthesized(bytes: &[u8]) -> Option<Synthesized> {
    let mut d = Dec::new(bytes);
    let variant = variant_from(d.u8()?)?;
    let source = ContentHash(d.u64()?, d.u64()?);
    let hash = ContentHash(d.u64()?, d.u64()?);
    let kernel = parse_kernel(d.str()?).ok()?;
    let elim = ElimReport::decode(&mut d)?;
    d.done().then_some(Synthesized {
        kernel: Arc::new(kernel),
        variant,
        source,
        hash,
        elim,
    })
}

pub(crate) fn encode_validated(a: &Validated) -> Vec<u8> {
    let mut e = Enc::default();
    e.u8(match a.valid {
        None => 0,
        Some(false) => 1,
        Some(true) => 2,
    });
    e.u64(a.out.len() as u64);
    for &x in &a.out {
        e.u32(x.to_bits());
    }
    let s = &a.stats;
    for v in [
        s.warp_instructions,
        s.thread_instructions,
        s.global_loads,
        s.nc_loads,
        s.shared_loads,
        s.stores,
        s.shfls,
        s.branches,
        s.divergent_branches,
        s.uninit_reads,
        s.cross_block_write_conflicts,
        s.barriers,
        s.barrier_phases,
        s.superblocks_entered,
        s.vector_warp_steps,
    ] {
        e.u64(v);
    }
    e.u64(a.trace.len() as u64);
    for warp in &a.trace {
        e.u64(warp.len() as u64);
        for ev in warp {
            e.u32(ev.stmt);
            e.u32(ev.active);
            e.u32(ev.exec);
            e.u64(ev.addr);
        }
    }
    e.buf
}

pub(crate) fn decode_validated(bytes: &[u8]) -> Option<Validated> {
    let mut d = Dec::new(bytes);
    let valid = match d.u8()? {
        0 => None,
        1 => Some(false),
        2 => Some(true),
        _ => return None,
    };
    let n = d.len()?;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        out.push(f32::from_bits(d.u32()?));
    }
    let stats = SimStats {
        warp_instructions: d.u64()?,
        thread_instructions: d.u64()?,
        global_loads: d.u64()?,
        nc_loads: d.u64()?,
        shared_loads: d.u64()?,
        stores: d.u64()?,
        shfls: d.u64()?,
        branches: d.u64()?,
        divergent_branches: d.u64()?,
        uninit_reads: d.u64()?,
        cross_block_write_conflicts: d.u64()?,
        barriers: d.u64()?,
        barrier_phases: d.u64()?,
        superblocks_entered: d.u64()?,
        vector_warp_steps: d.u64()?,
    };
    let nwarps = d.len()?;
    let mut trace = Vec::with_capacity(nwarps);
    for _ in 0..nwarps {
        let nev = d.len()?;
        let mut warp = Vec::with_capacity(nev);
        for _ in 0..nev {
            warp.push(WarpEvent {
                stmt: d.u32()?,
                active: d.u32()?,
                exec: d.u32()?,
                addr: d.u64()?,
            });
        }
        trace.push(warp);
    }
    d.done().then_some(Validated {
        out,
        stats,
        trace,
        valid,
    })
}

pub(crate) fn encode_scored(a: &Scored) -> Vec<u8> {
    let mut e = Enc::default();
    let r = &a.report;
    e.str(r.arch);
    e.f64(r.serial_cycles);
    e.f64(r.issue_cycles);
    for s in r.stalls {
        e.f64(s);
    }
    e.f64(r.occupancy);
    e.u32(r.regs_per_thread);
    e.f64(r.mem_cycles);
    e.f64(r.dram_cycles);
    e.f64(r.effective_cycles);
    e.buf
}

pub(crate) fn decode_scored(bytes: &[u8]) -> Option<Scored> {
    let mut d = Dec::new(bytes);
    // resolve through the arch table so `arch` stays a &'static str
    let arch = crate::perf::by_name(d.str()?)?.name;
    let serial_cycles = d.f64()?;
    let issue_cycles = d.f64()?;
    let mut stalls = [0f64; 8];
    for s in &mut stalls {
        *s = d.f64()?;
    }
    let occupancy = d.f64()?;
    let regs_per_thread = d.u32()?;
    let mem_cycles = d.f64()?;
    let dram_cycles = d.f64()?;
    let effective_cycles = d.f64()?;
    d.done().then_some(Scored {
        report: PerfReport {
            arch,
            serial_cycles,
            issue_cycles,
            stalls,
            occupancy,
            regs_per_thread,
            mem_cycles,
            dram_cycles,
            effective_cycles,
        },
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "ptxasw-store-unit-{tag}-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn roundtrip_and_counters() {
        let dir = tmp("roundtrip");
        let s = DiskStore::open(&dir, 1 << 20).unwrap();
        let key = ContentHash(1, 2);
        assert!(s.load(StoreKind::Validated, key).is_none());
        s.store(StoreKind::Validated, key, b"hello artifact");
        assert_eq!(s.load(StoreKind::Validated, key).unwrap(), b"hello artifact");
        // a second store instance over the same dir sees the artifact
        let s2 = DiskStore::open(&dir, 1 << 20).unwrap();
        assert_eq!(s2.load(StoreKind::Validated, key).unwrap(), b"hello artifact");
        let snap = s.snapshot();
        assert_eq!((snap.hits, snap.misses, snap.stores), (1, 1, 1));
        assert!(snap.resident_bytes > 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn kind_and_key_isolation() {
        let dir = tmp("isolation");
        let s = DiskStore::open(&dir, 1 << 20).unwrap();
        s.store(StoreKind::Detected, ContentHash(7, 7), b"det");
        assert!(s.load(StoreKind::Scored, ContentHash(7, 7)).is_none());
        assert!(s.load(StoreKind::Detected, ContentHash(7, 8)).is_none());
        assert_eq!(s.load(StoreKind::Detected, ContentHash(7, 7)).unwrap(), b"det");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_files_are_discarded() {
        let dir = tmp("corrupt");
        let s = DiskStore::open(&dir, 1 << 20).unwrap();
        let key = ContentHash(3, 4);
        s.store(StoreKind::Scored, key, b"payload-bytes");
        let path = s.art_path(StoreKind::Scored, key);

        // flip a payload byte → checksum mismatch
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[10] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        assert!(s.load(StoreKind::Scored, key).is_none());
        assert!(!path.exists(), "corrupt file must be removed");

        // truncated file
        s.store(StoreKind::Scored, key, b"payload-bytes");
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..7]).unwrap();
        assert!(s.load(StoreKind::Scored, key).is_none());
        assert!(s.snapshot().corrupt >= 2);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn lru_eviction_respects_bound_and_recency() {
        let dir = tmp("evict");
        // payloads of 1000 bytes + 17 header per artifact; the bound
        // fits two of them above the 90% low-water mark (2160), so
        // storing a third evicts exactly the least-recently-used one
        let s = DiskStore::open(&dir, 2400).unwrap();
        let payload = vec![0u8; 1000];
        s.store(StoreKind::Validated, ContentHash(1, 0), &payload);
        std::thread::sleep(std::time::Duration::from_millis(20));
        s.store(StoreKind::Validated, ContentHash(2, 0), &payload);
        std::thread::sleep(std::time::Duration::from_millis(20));
        // touch the older artifact so it becomes most-recently-used
        assert!(s.load(StoreKind::Validated, ContentHash(1, 0)).is_some());
        std::thread::sleep(std::time::Duration::from_millis(20));
        s.store(StoreKind::Validated, ContentHash(3, 0), &payload);

        // bound respected…
        let total: u64 = s.scan().iter().map(|e| e.size).sum();
        assert!(total <= 2400, "resident {total} exceeds the bound");
        assert!(s.snapshot().evictions >= 1);
        // …and the least-recently-used artifact (2) was the one evicted
        assert!(s.load(StoreKind::Validated, ContentHash(1, 0)).is_some());
        assert!(s.load(StoreKind::Validated, ContentHash(2, 0)).is_none());
        assert!(s.load(StoreKind::Validated, ContentHash(3, 0)).is_some());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn key_builder_is_stable() {
        let a = KeyBuilder::new("t").u64(1).hash(ContentHash(2, 3)).finish();
        let b = KeyBuilder::new("t").u64(1).hash(ContentHash(2, 3)).finish();
        assert_eq!(a, b);
        let c = KeyBuilder::new("t").u64(2).hash(ContentHash(2, 3)).finish();
        assert_ne!(a, c);
        let d = KeyBuilder::new("u").u64(1).hash(ContentHash(2, 3)).finish();
        assert_ne!(a, d);
    }

    #[test]
    fn key_builder_separates_elim_opts() {
        let base = KeyBuilder::new("s").elim(ElimOpts::default()).finish();
        let off = KeyBuilder::new("s")
            .elim(ElimOpts {
                enabled: false,
                block: 32,
            })
            .finish();
        let wider = KeyBuilder::new("s")
            .elim(ElimOpts {
                enabled: true,
                block: 64,
            })
            .finish();
        assert_ne!(base, off);
        assert_ne!(base, wider);
        assert_ne!(off, wider);
    }

    #[test]
    fn synthesized_payload_roundtrips_and_rejects_corruption() {
        use crate::ptx::printer::kernel_fingerprint;
        use crate::shuffle::phase_liveness::{BarrierElim, StoreElim};

        let kernel = parse_kernel(
            r#"
.visible .entry rt(.param .u64 out){
.reg .f32 %f<2>;
mov.f32 %f1, 0f3F800000;
ret;
}
"#,
        )
        .unwrap();
        let art = Synthesized {
            hash: kernel_fingerprint(&kernel),
            kernel: Arc::new(kernel),
            variant: Variant::Full,
            source: ContentHash(11, 13),
            elim: ElimReport {
                bail: None,
                stores: vec![StoreElim {
                    stmt: 3,
                    deleted: true,
                    reason: "all readers forwarded".into(),
                }],
                barriers: vec![BarrierElim {
                    stmt: 5,
                    elided: false,
                    reason: "unproven cross-lane traffic".into(),
                }],
                forwarded_loads: 2,
                dce_stmts: 4,
            },
        };
        let bytes = encode_synthesized(&art);
        let back = decode_synthesized(&bytes).unwrap();
        assert_eq!(back.variant, art.variant);
        assert_eq!(back.source, art.source);
        assert_eq!(back.hash, art.hash);
        assert_eq!(back.elim, art.elim);

        // every strict prefix must decode to None, never panic
        for cut in 0..bytes.len() {
            assert!(decode_synthesized(&bytes[..cut]).is_none(), "prefix {cut}");
        }
        // trailing garbage is rejected by the `done()` gate
        let mut padded = bytes.clone();
        padded.push(0);
        assert!(decode_synthesized(&padded).is_none());
        // randomized single-byte flips: decode may reject or may yield a
        // structurally different artifact, but must never panic
        let mut rng = crate::util::Rng::new(0x5eed);
        for _ in 0..64 {
            let mut evil = bytes.clone();
            let i = rng.below(evil.len() as u64) as usize;
            evil[i] ^= (rng.below(255) + 1) as u8;
            let _ = decode_synthesized(&evil);
        }
    }
}
