//! The symbolic emulator (paper §4): path exploration with SMT-pruned
//! branching, loop abstraction via uninterpreted iterator functions, and
//! memory-trace collection.
//!
//! `emulate(kernel)` walks every realizable control-flow path of the kernel
//! once: forward branches fork the flow (with the branch predicate recorded
//! as an assumption on each side), loops are abstracted at their header and
//! terminate the flow at re-entry, and identical register environments at a
//! label are memoized away.

pub mod env;
pub mod exec;
pub mod induction;
pub mod memtrace;

use crate::obs::{ArgVal, Tracer};
use crate::ptx::ast::{Kernel, Op, Statement};
use crate::sym::{Assumptions, SessionInterner, TermId, TermPool, Truth};
use env::{RegEnv, RegInterner};
use induction::{Abstraction, KernelIndex};
use memtrace::MemTrace;
use std::collections::{HashMap, HashSet};
use std::sync::Arc;

/// Safety limits for path exploration.
#[derive(Debug, Clone, Copy)]
pub struct Limits {
    pub max_flows: usize,
    pub max_steps_per_flow: u64,
    pub max_total_steps: u64,
}

impl Default for Limits {
    fn default() -> Limits {
        Limits {
            max_flows: 4096,
            max_steps_per_flow: 200_000,
            max_total_steps: 20_000_000,
        }
    }
}

impl Limits {
    /// Fold every field into a disk-key hash. Exhaustive destructuring on
    /// purpose: a new limit field must fail to compile here rather than
    /// silently let two budgets share a cache entry (a tighter budget can
    /// change which flows finish, so emulations — and everything derived
    /// from them — keyed without the limits would poison readers running
    /// under different limits on a shared cache dir).
    pub fn key_into(&self, h: &mut crate::util::Fnv128) {
        let Limits {
            max_flows,
            max_steps_per_flow,
            max_total_steps,
        } = *self;
        h.write_u64(max_flows as u64);
        h.write_u64(max_steps_per_flow);
        h.write_u64(max_total_steps);
    }
}

/// Diagnostic counters.
#[derive(Debug, Clone, Copy, Default)]
pub struct EmuStats {
    pub flows_started: u64,
    pub flows_finished: u64,
    pub flows_pruned: u64,
    pub flows_memoized: u64,
    pub steps: u64,
    pub loads: u64,
    pub stores: u64,
    pub invalidated_loads: u64,
    pub uninit_reads: u64,
    pub barriers: u64,
    pub forks: u64,
    pub branches_decided: u64,
}

/// Why a flow ended.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FlowEnd {
    Ret,
    LoopReentry,
    Memoized,
    StepLimit,
}

impl FlowEnd {
    /// Stable on-disk tag (the [`crate::sym::persist`] codec).
    pub fn tag(self) -> u8 {
        match self {
            FlowEnd::Ret => 0,
            FlowEnd::LoopReentry => 1,
            FlowEnd::Memoized => 2,
            FlowEnd::StepLimit => 3,
        }
    }

    pub fn from_tag(tag: u8) -> Option<FlowEnd> {
        Some(match tag {
            0 => FlowEnd::Ret,
            1 => FlowEnd::LoopReentry,
            2 => FlowEnd::Memoized,
            3 => FlowEnd::StepLimit,
            _ => return None,
        })
    }
}

impl EmuStats {
    /// The counters as a fixed word array (stable serialization order —
    /// shared by the disk store's `Detected` codec and the
    /// [`crate::sym::persist`] emulation codec).
    pub fn to_words(&self) -> [u64; 12] {
        [
            self.flows_started,
            self.flows_finished,
            self.flows_pruned,
            self.flows_memoized,
            self.steps,
            self.loads,
            self.stores,
            self.invalidated_loads,
            self.uninit_reads,
            self.barriers,
            self.forks,
            self.branches_decided,
        ]
    }

    pub fn from_words(w: [u64; 12]) -> EmuStats {
        EmuStats {
            flows_started: w[0],
            flows_finished: w[1],
            flows_pruned: w[2],
            flows_memoized: w[3],
            steps: w[4],
            loads: w[5],
            stores: w[6],
            invalidated_loads: w[7],
            uninit_reads: w[8],
            barriers: w[9],
            forks: w[10],
            branches_decided: w[11],
        }
    }
}

/// One in-progress execution flow.
#[derive(Debug, Clone)]
pub struct Flow {
    pub id: u32,
    pub env: RegEnv,
    pub assumptions: Assumptions,
    pub trace: MemTrace,
    pub pc: usize,
    /// Straight-line segment id; bumped at every label and branch so the
    /// detector only pairs loads that sit in the same straight-line region.
    pub segment: u32,
    /// Barrier phase id; bumped at every `bar.sync` so the detector only
    /// pairs loads separated by no block-wide barrier — a shuffle may not
    /// move a value across a barrier, the warps' values are exchanged
    /// through memory there.
    pub phase: u32,
    /// Loop headers this flow has entered (header stmt → entry count).
    pub entered_loops: HashMap<usize, u32>,
    pub steps: u64,
}

/// A finished flow: its trace and final assumption set.
#[derive(Debug)]
pub struct FlowResult {
    pub id: u32,
    pub trace: MemTrace,
    pub assumptions: Assumptions,
    pub end: FlowEnd,
}

/// Everything the shuffle detector needs.
#[derive(Debug)]
pub struct EmulationResult {
    pub pool: TermPool,
    pub flows: Vec<FlowResult>,
    /// The `%tid.x` atom addresses are affine in.
    pub tid_sym: TermId,
    pub stats: EmuStats,
}

#[derive(Debug, Clone)]
pub enum EmuError {
    UnknownLabel(String),
    FlowLimit(usize),
    StepLimit,
}

impl std::fmt::Display for EmuError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            EmuError::UnknownLabel(l) => write!(f, "unknown branch target `{l}`"),
            EmuError::FlowLimit(n) => write!(f, "flow limit exceeded ({n} flows)"),
            EmuError::StepLimit => write!(f, "total step limit exceeded"),
        }
    }
}

impl std::error::Error for EmuError {}

/// The frontier of an emulation stopped by a *global* budget trip (flow
/// limit or total-step limit): everything a wider retry needs to resume
/// exploration exactly where the tight run stopped, instead of
/// re-emulating flow zero. Because the limits only decide *when to stop*
/// — never *what to explore* — the tight run's prefix is bit-identical
/// to a wider run's prefix, so a resumed run reproduces the wider run's
/// result exactly. (A per-flow step truncation breaks that equivalence —
/// the tight run keeps going on *other* flows where the wide run would
/// have continued the truncated one — so truncated runs never capture a
/// frontier; see [`EmuOutcome::Failed`].)
#[derive(Debug)]
pub struct PartialEmulation {
    pub pool: TermPool,
    pub tid_sym: TermId,
    pub stats: EmuStats,
    /// The budgets the run stopped under. A resume must be wider-or-equal
    /// on every axis ([`resume_outcome`] verifies this).
    pub limits: Limits,
    /// Flows finished before the budget tripped.
    pub done: Vec<FlowResult>,
    /// The worklist at the stop point, in LIFO order (last entry is the
    /// next to execute — including the flow that was in hand when the
    /// budget tripped, pushed back at a statement boundary).
    pub pending: Vec<Flow>,
    /// Memo keys as (pc, structural env fingerprint), sorted. Structural
    /// ([`TermPool::fp`]) so the keys survive relocation into the
    /// resuming process's pool.
    pub memo: Vec<(usize, u64)>,
    pub next_flow_id: u32,
    /// The budget error the tight caller observes.
    pub error: EmuError,
}

/// Outcome of a frontier-capturing emulation.
#[derive(Debug)]
pub enum EmuOutcome {
    Complete(EmulationResult),
    /// Stopped by a global budget with a resumable frontier.
    Partial(Box<PartialEmulation>),
    /// Failed without a resumable frontier (unknown label, or a budget
    /// trip after a per-flow truncation already diverged the prefix).
    Failed(EmuError),
}

impl EmuOutcome {
    /// Collapse to the classic result shape (frontier dropped).
    pub fn into_result(self) -> Result<EmulationResult, EmuError> {
        match self {
            EmuOutcome::Complete(r) => Ok(r),
            EmuOutcome::Partial(p) => Err(p.error),
            EmuOutcome::Failed(e) => Err(e),
        }
    }
}

/// The emulator: owns the term pool and the per-kernel static index.
#[derive(Debug)]
pub struct Emu<'k> {
    pub pool: TermPool,
    pub kernel: &'k Kernel,
    pub regs: RegInterner,
    pub index: KernelIndex,
    pub tid_sym: TermId,
    pub stats: EmuStats,
    limits: Limits,
    memo: HashSet<(usize, u64)>,
    next_flow_id: u32,
    /// Fork instants (`emu.fork`) land here when present, so a budget
    /// blowup shows *where* the fork explosion started.
    tracer: Option<Arc<Tracer>>,
}

/// Emulate a kernel with default limits (private, single-use session).
pub fn emulate(kernel: &Kernel) -> Result<EmulationResult, EmuError> {
    emulate_with(kernel, Limits::default())
}

pub fn emulate_with(kernel: &Kernel, limits: Limits) -> Result<EmulationResult, EmuError> {
    emulate_in_session(kernel, limits, Arc::new(SessionInterner::new()))
}

/// Emulate a kernel whose symbol/UF names are interned in a shared
/// session — the pipeline's artifact cache passes one session for a whole
/// suite run so `%tid.x`, param names and UF names are interned once.
pub fn emulate_in_session(
    kernel: &Kernel,
    limits: Limits,
    session: Arc<SessionInterner>,
) -> Result<EmulationResult, EmuError> {
    emulate_outcome(kernel, limits, session, None).into_result()
}

/// Frontier-capturing emulation: like [`emulate_in_session`] but a global
/// budget trip yields the resumable [`PartialEmulation`] instead of a bare
/// error, and flow forks are recorded as `emu.fork` instants on `tracer`.
pub fn emulate_outcome(
    kernel: &Kernel,
    limits: Limits,
    session: Arc<SessionInterner>,
    tracer: Option<Arc<Tracer>>,
) -> EmuOutcome {
    let mut pool = TermPool::in_session(session);
    let mut regs = RegInterner::from_kernel(kernel);
    let index = KernelIndex::build(kernel, &mut regs);
    let tid_sym = pool.symbol("tid.x", 32);
    let nregs = regs.len();
    let mut emu = Emu {
        pool,
        kernel,
        regs,
        index,
        tid_sym,
        stats: EmuStats::default(),
        limits,
        memo: HashSet::new(),
        next_flow_id: 0,
        tracer,
    };
    let id = emu.new_flow_id();
    let first = Flow {
        id,
        env: RegEnv::new(nregs),
        assumptions: Assumptions::new(),
        trace: MemTrace::default(),
        pc: 0,
        segment: 0,
        phase: 0,
        entered_loops: HashMap::new(),
        steps: 0,
    };
    let out = emu.run_from(vec![first], Vec::new());
    outcome_of(emu, out)
}

/// Continue a budget-stopped exploration under wider limits. The seed's
/// pool/flows may come straight from a decoded disk image — every memo
/// key is structural, so relocation into a fresh pool is transparent.
/// Limits that are narrower than the seed's on any axis yield
/// `Failed(seed.error)` (the frontier state would be unreachable under
/// them); callers fall back to a cold emulation.
pub fn resume_outcome(
    kernel: &Kernel,
    limits: Limits,
    part: PartialEmulation,
    tracer: Option<Arc<Tracer>>,
) -> EmuOutcome {
    let old = part.limits;
    if limits.max_flows < old.max_flows
        || limits.max_steps_per_flow < old.max_steps_per_flow
        || limits.max_total_steps < old.max_total_steps
    {
        return EmuOutcome::Failed(part.error);
    }
    let mut pool = part.pool;
    let mut regs = RegInterner::from_kernel(kernel);
    let index = KernelIndex::build(kernel, &mut regs);
    // hash-conses onto the image's relocated tid symbol
    let tid_sym = pool.symbol("tid.x", 32);
    let mut emu = Emu {
        pool,
        kernel,
        regs,
        index,
        tid_sym,
        stats: part.stats,
        limits,
        memo: part.memo.into_iter().collect(),
        next_flow_id: part.next_flow_id,
        tracer,
    };
    let out = emu.run_from(part.pending, part.done);
    outcome_of(emu, out)
}

/// Stop state of [`Emu::run_from`].
enum RunOutcome {
    Done(Vec<FlowResult>),
    Budget {
        work: Vec<Flow>,
        done: Vec<FlowResult>,
        error: EmuError,
    },
    Fatal(EmuError),
}

fn outcome_of(emu: Emu, out: RunOutcome) -> EmuOutcome {
    match out {
        RunOutcome::Done(flows) => EmuOutcome::Complete(EmulationResult {
            pool: emu.pool,
            flows,
            tid_sym: emu.tid_sym,
            stats: emu.stats,
        }),
        RunOutcome::Fatal(e) => EmuOutcome::Failed(e),
        RunOutcome::Budget { work, done, error } => {
            if done.iter().any(|f| f.end == FlowEnd::StepLimit) {
                // a truncated flow means the tight prefix already diverged
                // from what a wider run would have executed: not resumable
                return EmuOutcome::Failed(error);
            }
            let mut memo: Vec<(usize, u64)> = emu.memo.into_iter().collect();
            memo.sort_unstable();
            EmuOutcome::Partial(Box::new(PartialEmulation {
                pool: emu.pool,
                tid_sym: emu.tid_sym,
                stats: emu.stats,
                limits: emu.limits,
                done,
                pending: work,
                memo,
                next_flow_id: emu.next_flow_id,
                error,
            }))
        }
    }
}

enum Step {
    Continue,
    Jump(usize),
    End(FlowEnd),
    Fork { pred: TermId, target: usize },
}

impl<'k> Emu<'k> {
    fn new_flow_id(&mut self) -> u32 {
        let id = self.next_flow_id;
        self.next_flow_id += 1;
        self.stats.flows_started += 1;
        id
    }

    /// Drive the worklist to completion or a budget stop. `work`/`done`
    /// may be freshly seeded (one initial flow) or a resumed frontier —
    /// the loop itself cannot tell the difference, which is exactly the
    /// resume-equivalence argument: budget checks happen only at
    /// statement boundaries, so the frontier state *is* the wider run's
    /// mid-state.
    fn run_from(&mut self, mut work: Vec<Flow>, mut done: Vec<FlowResult>) -> RunOutcome {
        while let Some(mut flow) = work.pop() {
            if work.len() + done.len() > self.limits.max_flows {
                let error = EmuError::FlowLimit(self.limits.max_flows);
                work.push(flow); // back at the LIFO top for the resume
                return RunOutcome::Budget { work, done, error };
            }
            let end = loop {
                if flow.steps >= self.limits.max_steps_per_flow {
                    break FlowEnd::StepLimit;
                }
                if self.stats.steps >= self.limits.max_total_steps {
                    work.push(flow); // statement boundary: state is clean
                    return RunOutcome::Budget {
                        work,
                        done,
                        error: EmuError::StepLimit,
                    };
                }
                flow.steps += 1;
                self.stats.steps += 1;
                match self.step(&mut flow) {
                    Err(e) => return RunOutcome::Fatal(e),
                    Ok(Step::Continue) => flow.pc += 1,
                    Ok(Step::Jump(t)) => {
                        flow.segment += 1;
                        flow.pc = t;
                    }
                    Ok(Step::End(e)) => break e,
                    Ok(Step::Fork { pred, target }) => {
                        self.stats.forks += 1;
                        if let Some(tr) = &self.tracer {
                            let (pc, fid) = (flow.pc as u64, flow.id as u64);
                            let (depth, forks) = (work.len() as u64, self.stats.forks);
                            tr.instant("emu", "emu.fork", || {
                                vec![
                                    ("pc", ArgVal::U64(pc)),
                                    ("flow", ArgVal::U64(fid)),
                                    ("work_depth", ArgVal::U64(depth)),
                                    ("forks", ArgVal::U64(forks)),
                                ]
                            });
                        }
                        // not-taken side continues in `flow`
                        let mut taken = flow.clone();
                        taken.id = self.new_flow_id();
                        let taken_ok = taken.assumptions.assume(&self.pool, pred, true).is_ok();
                        let fall_ok = flow.assumptions.assume(&self.pool, pred, false).is_ok();
                        if taken_ok {
                            taken.segment += 1;
                            if self.reenters_loop(&taken, target) {
                                done.push(FlowResult {
                                    id: taken.id,
                                    trace: taken.trace,
                                    assumptions: taken.assumptions,
                                    end: FlowEnd::LoopReentry,
                                });
                                self.stats.flows_finished += 1;
                            } else {
                                let mut t = taken;
                                t.pc = target;
                                work.push(t);
                            }
                        } else {
                            self.stats.flows_pruned += 1;
                        }
                        if fall_ok {
                            flow.segment += 1;
                            flow.pc += 1;
                            continue;
                        } else {
                            self.stats.flows_pruned += 1;
                            break FlowEnd::Ret; // infeasible fall-through; drop silently
                        }
                    }
                }
            };
            self.stats.flows_finished += 1;
            done.push(FlowResult {
                id: flow.id,
                trace: flow.trace,
                assumptions: flow.assumptions,
                end,
            });
        }
        RunOutcome::Done(done)
    }

    fn reenters_loop(&self, flow: &Flow, target: usize) -> bool {
        self.index.loops.contains_key(&target)
            && flow.entered_loops.get(&target).copied().unwrap_or(0) > 0
    }

    fn step(&mut self, flow: &mut Flow) -> Result<Step, EmuError> {
        let Some(stmt) = self.kernel.body.get(flow.pc) else {
            return Ok(Step::End(FlowEnd::Ret)); // fell off the end
        };
        match stmt {
            Statement::Label(_) => {
                flow.segment += 1;
                // loop abstraction at header entry (paper §4.2)
                if let Some(info) = self.index.loops.get(&flow.pc).cloned() {
                    let n = flow.entered_loops.entry(flow.pc).or_insert(0);
                    *n += 1;
                    let gen = *n;
                    self.abstract_loop(flow, &info, gen);
                }
                // memoization of identical environments at block entry
                // (structural fingerprint: stable across image relocation)
                let key = (flow.pc, flow.env.fingerprint(&self.pool));
                if !self.memo.insert(key) {
                    self.stats.flows_memoized += 1;
                    return Ok(Step::End(FlowEnd::Memoized));
                }
                Ok(Step::Continue)
            }
            Statement::Instr { guard, op } => {
                // resolve guard
                let guard_term = match guard {
                    None => None,
                    Some(g) => {
                        let t = self.term_of(flow, &crate::ptx::ast::Operand::Reg(g.reg.clone()), 1, false);
                        let eff = if g.negated { self.pool.not(t) } else { t };
                        match flow.assumptions.check(&self.pool, eff) {
                            Truth::True => None, // unconditionally executes
                            Truth::False => {
                                // instruction is a no-op on this path
                                return Ok(match op {
                                    Op::Bra { .. } => {
                                        self.stats.branches_decided += 1;
                                        Step::Continue
                                    }
                                    Op::Ret | Op::Exit => Step::Continue,
                                    _ => Step::Continue,
                                });
                            }
                            Truth::Unknown => Some(eff),
                        }
                    }
                };
                match op {
                    Op::Bra { target, .. } => {
                        let t = *self
                            .index
                            .labels
                            .get(target)
                            .ok_or_else(|| EmuError::UnknownLabel(target.clone()))?;
                        match guard_term {
                            None => {
                                self.stats.branches_decided += 1;
                                if self.reenters_loop(flow, t) {
                                    Ok(Step::End(FlowEnd::LoopReentry))
                                } else {
                                    Ok(Step::Jump(t))
                                }
                            }
                            Some(pred) => Ok(Step::Fork { pred, target: t }),
                        }
                    }
                    Op::Ret | Op::Exit => Ok(Step::End(FlowEnd::Ret)),
                    _ => {
                        self.exec_op(flow, flow.pc, guard_term, op);
                        Ok(Step::Continue)
                    }
                }
            }
        }
    }

    /// Abstract loop-variant registers at a header entry: induction
    /// variables get `init + step·k`, everything else a fresh opaque UF.
    fn abstract_loop(&mut self, flow: &mut Flow, info: &induction::LoopInfo, gen: u32) {
        let k_name = format!("loop.{}.{}", info.header, gen);
        for &(reg, abs) in &info.variants {
            match abs {
                Abstraction::Induction { step } => {
                    let init = flow.env.get(reg);
                    let w = init.map(|t| self.pool.width(t)).unwrap_or(32);
                    let k = self.pool.uf(&k_name, vec![], w);
                    let stepped = if step == 1 {
                        k
                    } else {
                        let c = self.pool.constant(step as u64, w);
                        self.pool.bin(crate::sym::BvOp::Mul, k, c)
                    };
                    let v = match init {
                        Some(i) => self.pool.bin(crate::sym::BvOp::Add, i, stepped),
                        None => stepped,
                    };
                    flow.env.set(reg, v);
                }
                Abstraction::InductionSym => {
                    // init + k, the UF absorbing the loop-invariant stride
                    let init = flow.env.get(reg);
                    let w = init.map(|t| self.pool.width(t)).unwrap_or(32);
                    let name = format!("{k_name}.r{reg}");
                    let k = self.pool.uf(&name, vec![], w);
                    let v = match init {
                        Some(i) => self.pool.bin(crate::sym::BvOp::Add, i, k),
                        None => k,
                    };
                    flow.env.set(reg, v);
                }
                Abstraction::Opaque => {
                    let w = flow.env.get(reg).map(|t| self.pool.width(t)).unwrap_or(32);
                    let name = format!("loopvar.{}.{}.{}", info.header, gen, reg);
                    let v = self.pool.uf(&name, vec![], w);
                    flow.env.set(reg, v);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ptx::parser::parse_kernel;
    use crate::sym::{split_on, Node};

    /// Paper Listing 1/2: guarded vector add. Two flows (guard true/false),
    /// three loads on the hot path.
    const ADD: &str = r#"
.visible .entry add(.param .u64 c, .param .u64 a, .param .u64 b, .param .u64 f){
.reg .pred %p<2>; .reg .f32 %f<4>; .reg .b32 %r<6>; .reg .b64 %rd<15>;
ld.param.u64 %rd1, [c];
ld.param.u64 %rd2, [a];
ld.param.u64 %rd3, [b];
ld.param.u64 %rd4, [f];
cvta.to.global.u64 %rd5, %rd4;
mov.u32 %r2, %ntid.x;
mov.u32 %r3, %ctaid.x;
mov.u32 %r4, %tid.x;
mad.lo.s32 %r1, %r3, %r2, %r4;
mul.wide.s32 %rd6, %r1, 4;
add.s64 %rd7, %rd5, %rd6;
ld.global.u32 %r5, [%rd7];
setp.eq.s32 %p1, %r5, 0;
@%p1 bra $LABEL_EXIT;
cvta.u64 %rd8, %rd2;
add.s64 %rd10, %rd8, %rd6;
cvta.u64 %rd11, %rd3;
add.s64 %rd12, %rd11, %rd6;
ld.global.f32 %f1, [%rd12];
ld.global.f32 %f2, [%rd10];
add.f32 %f3, %f2, %f1;
cvta.u64 %rd13, %rd1;
add.s64 %rd14, %rd13, %rd6;
st.global.f32 [%rd14], %f3;
$LABEL_EXIT:
ret;
}
"#;

    #[test]
    fn add_kernel_two_flows() {
        let k = parse_kernel(ADD).unwrap();
        let r = emulate(&k).unwrap();
        assert_eq!(r.flows.len(), 2);
        let loads: Vec<usize> = r.flows.iter().map(|f| f.trace.loads.len()).collect();
        let mut sorted = loads.clone();
        sorted.sort();
        assert_eq!(sorted, vec![1, 3]); // guard-false path: 1 load; hot path: 3
        assert_eq!(r.stats.forks, 1);
        // no uninitialized register reads in well-formed code
        assert_eq!(r.stats.uninit_reads, 0);
    }

    #[test]
    fn addresses_are_affine_in_tid() {
        let k = parse_kernel(ADD).unwrap();
        let r = emulate(&k).unwrap();
        let hot = r
            .flows
            .iter()
            .find(|f| f.trace.loads.len() == 3)
            .unwrap();
        for l in &hot.trace.loads {
            let (stride, _) = split_on(&r.pool, l.addr, r.tid_sym);
            assert_eq!(stride, 4, "every load strides 4 bytes per thread");
        }
    }

    #[test]
    fn store_invalidation_respects_nc() {
        let k = parse_kernel(
            r#"
.visible .entry k(.param .u64 a, .param .u64 b){
.reg .f32 %f<4>; .reg .b64 %rd<6>; .reg .b32 %r<4>;
ld.param.u64 %rd1, [a];
ld.param.u64 %rd2, [b];
cvta.to.global.u64 %rd3, %rd1;
cvta.to.global.u64 %rd4, %rd2;
mov.u32 %r1, %tid.x;
mul.wide.s32 %rd5, %r1, 4;
add.s64 %rd3, %rd3, %rd5;
add.s64 %rd4, %rd4, %rd5;
ld.global.nc.f32 %f1, [%rd3];
ld.global.f32 %f2, [%rd3+4];
st.global.f32 [%rd4], %f1;
ret;
}
"#,
        )
        .unwrap();
        let r = emulate(&k).unwrap();
        assert_eq!(r.flows.len(), 1);
        let t = &r.flows[0].trace;
        assert_eq!(t.loads.len(), 2);
        // nc load survives the may-aliasing store; plain load does not
        let nc = t.loads.iter().find(|l| l.nc).unwrap();
        let plain = t.loads.iter().find(|l| !l.nc).unwrap();
        assert!(nc.valid);
        assert!(!plain.valid);
    }

    #[test]
    fn loop_terminates_with_abstraction() {
        let k = parse_kernel(
            r#"
.visible .entry k(.param .u64 a, .param .u64 n){
.reg .b32 %r<6>; .reg .b64 %rd<5>; .reg .pred %p<2>; .reg .f32 %f<3>;
ld.param.u64 %rd1, [a];
cvta.to.global.u64 %rd2, %rd1;
mov.u32 %r1, 0;
$LOOP:
mul.wide.s32 %rd3, %r1, 4;
add.s64 %rd4, %rd2, %rd3;
ld.global.f32 %f1, [%rd4];
add.s32 %r1, %r1, 1;
setp.lt.s32 %p1, %r1, 128;
@%p1 bra $LOOP;
ret;
}
"#,
        )
        .unwrap();
        let r = emulate(&k).unwrap();
        // loop body emulated once; flows: back-edge (LoopReentry) + exit (Ret)
        assert!(r.flows.len() >= 2);
        assert!(r.flows.iter().any(|f| f.end == FlowEnd::LoopReentry));
        assert!(r.flows.iter().any(|f| f.end == FlowEnd::Ret));
        // the loop load's address contains the iteration UF
        let f = r.flows.iter().find(|f| !f.trace.loads.is_empty()).unwrap();
        let addr = f.trace.loads[0].addr;
        let mut ufs = Vec::new();
        r.pool.collect_ufs(addr, &mut ufs);
        assert!(
            ufs.iter().any(|&u| {
                matches!(r.pool.node(u), Node::Uf { func, .. }
                    if r.pool.uf_name(*func).starts_with("loop."))
            }),
            "loop iterator UF should appear in the address"
        );
    }

    #[test]
    fn infeasible_paths_pruned() {
        // both branches test the same predicate: only 2 of 4 paths realizable
        let k = parse_kernel(
            r#"
.visible .entry k(.param .u64 a){
.reg .b32 %r<4>; .reg .pred %p<2>; .reg .b64 %rd<4>; .reg .f32 %f<4>;
ld.param.u64 %rd1, [a];
cvta.to.global.u64 %rd2, %rd1;
mov.u32 %r1, %tid.x;
setp.lt.s32 %p1, %r1, 16;
@%p1 bra $A;
ld.global.f32 %f1, [%rd2];
$A:
setp.lt.s32 %p1, %r1, 16;
@%p1 bra $B;
ld.global.f32 %f2, [%rd2+4];
$B:
ret;
}
"#,
        )
        .unwrap();
        let r = emulate(&k).unwrap();
        // flows that executed the first load must have also executed the second
        // (p1 false on both) — i.e. no flow loads only [%rd2] or only [%rd2+4]...
        // realizable: {both loads} and {no loads} (possibly memoized variants).
        for f in &r.flows {
            let n = f.trace.loads.len();
            assert!(n == 0 || n == 2, "unrealizable path with {n} loads survived");
        }
    }

    #[test]
    fn predicated_instruction_issues_conditional_value() {
        let k = parse_kernel(
            r#"
.visible .entry k(.param .u64 a){
.reg .b32 %r<4>; .reg .pred %p<2>;
mov.u32 %r1, %tid.x;
setp.lt.s32 %p1, %r1, 16;
mov.u32 %r2, 0;
@%p1 mov.u32 %r2, 1;
ret;
}
"#,
        )
        .unwrap();
        let r = emulate(&k).unwrap();
        assert_eq!(r.flows.len(), 1, "predication must not fork");
    }

    /// 2^bits realizable flows: each bit forks on a distinct predicate
    /// and accumulates a distinct constant, defeating memoization.
    fn forky(bits: usize) -> String {
        let mut body = String::new();
        for i in 0..bits {
            body.push_str(&format!(
                "and.b32 %r10, %r1, {};\nsetp.eq.s32 %p{p}, %r10, 0;\n\
                 @%p{p} bra $S{i};\nadd.s32 %r2, %r2, {};\n$S{i}:\n",
                1u32 << i,
                100 + i,
                p = i + 1,
            ));
        }
        format!(
            ".visible .entry forky(.param .u64 out){{\n\
             .reg .pred %p<{}>; .reg .b32 %r<12>; .reg .b64 %rd<3>;\n\
             ld.param.u64 %rd1, [out];\ncvta.to.global.u64 %rd2, %rd1;\n\
             mov.u32 %r1, %tid.x;\nmov.u32 %r2, 0;\n{body}\
             st.global.u32 [%rd2], %r2;\nret;\n}}\n",
            bits + 2,
        )
    }

    fn wide_limits() -> Limits {
        Limits {
            max_flows: 4096,
            max_steps_per_flow: 200_000,
            max_total_steps: 20_000_000,
        }
    }

    /// The tentpole resume contract: a frontier captured at the tight
    /// flow limit, resumed under wide limits, reproduces the cold wide
    /// run *exactly* (same flows, same order, same stats) while
    /// re-exploring strictly fewer flows than the cold retry does.
    #[test]
    fn resume_from_flow_limit_matches_cold_wide_exactly() {
        let k = parse_kernel(&forky(6)).unwrap(); // 64 flows
        let tight = Limits {
            max_flows: 8,
            ..wide_limits()
        };
        let part = match emulate_outcome(&k, tight, Arc::new(SessionInterner::new()), None) {
            EmuOutcome::Partial(p) => *p,
            other => panic!("expected a resumable frontier, got {other:?}"),
        };
        assert!(matches!(part.error, EmuError::FlowLimit(8)));
        assert!(!part.pending.is_empty(), "frontier carries pending flows");
        let seeded_started = part.stats.flows_started;
        assert!(seeded_started > 0);

        let resumed = match resume_outcome(&k, wide_limits(), part, None) {
            EmuOutcome::Complete(r) => r,
            other => panic!("resume should complete, got {other:?}"),
        };
        let cold = emulate_in_session(&k, wide_limits(), Arc::new(SessionInterner::new()))
            .unwrap();

        assert_eq!(resumed.stats.to_words(), cold.stats.to_words());
        assert_eq!(resumed.flows.len(), cold.flows.len());
        assert_eq!(resumed.flows.len(), 64);
        for (a, b) in resumed.flows.iter().zip(&cold.flows) {
            assert_eq!(a.id, b.id, "flow order/ids must match the cold run");
            assert_eq!(a.end, b.end);
            assert_eq!(a.trace.loads.len(), b.trace.loads.len());
            assert_eq!(a.trace.stores.len(), b.trace.stores.len());
            assert_eq!(a.assumptions.fact_count(), b.assumptions.fact_count());
        }
        // the acceptance criterion: the resumed retry re-emulates strictly
        // fewer flows than a cold retry starts from scratch
        let re_emulated = resumed.stats.flows_started - seeded_started;
        assert!(
            re_emulated < cold.stats.flows_started,
            "resume must re-explore fewer flows ({re_emulated} vs {})",
            cold.stats.flows_started
        );
    }

    /// Narrower-than-seed limits refuse to resume (the frontier state is
    /// unreachable under them); a step-budget stop is resumable too.
    #[test]
    fn resume_guards_and_step_budget_frontier() {
        let k = parse_kernel(&forky(5)).unwrap();
        let tight = Limits {
            max_flows: 4,
            ..wide_limits()
        };
        let part = match emulate_outcome(&k, tight, Arc::new(SessionInterner::new()), None) {
            EmuOutcome::Partial(p) => *p,
            other => panic!("expected partial, got {other:?}"),
        };
        let narrower = Limits {
            max_flows: 2,
            ..wide_limits()
        };
        assert!(matches!(
            resume_outcome(&k, narrower, part, None),
            EmuOutcome::Failed(EmuError::FlowLimit(4))
        ));

        // total-step budget stop also captures a frontier
        let steppy = Limits {
            max_total_steps: 40,
            ..wide_limits()
        };
        let part = match emulate_outcome(&k, steppy, Arc::new(SessionInterner::new()), None) {
            EmuOutcome::Partial(p) => *p,
            other => panic!("expected step-budget partial, got {other:?}"),
        };
        assert!(matches!(part.error, EmuError::StepLimit));
        let resumed = resume_outcome(&k, wide_limits(), part, None);
        let cold = emulate_in_session(&k, wide_limits(), Arc::new(SessionInterner::new()))
            .unwrap();
        match resumed {
            EmuOutcome::Complete(r) => {
                assert_eq!(r.stats.to_words(), cold.stats.to_words());
                assert_eq!(r.flows.len(), cold.flows.len());
            }
            other => panic!("resume should complete, got {other:?}"),
        }
    }

    #[test]
    fn fell_off_end_is_ret() {
        let k = parse_kernel(
            ".visible .entry k(.param .u64 a){ .reg .b32 %r<2>; mov.u32 %r1, 0; }",
        )
        .unwrap();
        let r = emulate(&k).unwrap();
        assert_eq!(r.flows.len(), 1);
        assert_eq!(r.flows[0].end, FlowEnd::Ret);
    }
}
