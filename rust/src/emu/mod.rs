//! The symbolic emulator (paper §4): path exploration with SMT-pruned
//! branching, loop abstraction via uninterpreted iterator functions, and
//! memory-trace collection.
//!
//! `emulate(kernel)` walks every realizable control-flow path of the kernel
//! once: forward branches fork the flow (with the branch predicate recorded
//! as an assumption on each side), loops are abstracted at their header and
//! terminate the flow at re-entry, and identical register environments at a
//! label are memoized away.

pub mod env;
pub mod exec;
pub mod induction;
pub mod memtrace;

use crate::ptx::ast::{Kernel, Op, Statement};
use crate::sym::{Assumptions, SessionInterner, TermId, TermPool, Truth};
use env::{RegEnv, RegInterner};
use induction::{Abstraction, KernelIndex};
use memtrace::MemTrace;
use std::collections::{HashMap, HashSet};
use std::sync::Arc;

/// Safety limits for path exploration.
#[derive(Debug, Clone, Copy)]
pub struct Limits {
    pub max_flows: usize,
    pub max_steps_per_flow: u64,
    pub max_total_steps: u64,
}

impl Default for Limits {
    fn default() -> Limits {
        Limits {
            max_flows: 4096,
            max_steps_per_flow: 200_000,
            max_total_steps: 20_000_000,
        }
    }
}

impl Limits {
    /// Fold every field into a disk-key hash. Exhaustive destructuring on
    /// purpose: a new limit field must fail to compile here rather than
    /// silently let two budgets share a cache entry (a tighter budget can
    /// change which flows finish, so emulations — and everything derived
    /// from them — keyed without the limits would poison readers running
    /// under different limits on a shared cache dir).
    pub fn key_into(&self, h: &mut crate::util::Fnv128) {
        let Limits {
            max_flows,
            max_steps_per_flow,
            max_total_steps,
        } = *self;
        h.write_u64(max_flows as u64);
        h.write_u64(max_steps_per_flow);
        h.write_u64(max_total_steps);
    }
}

/// Diagnostic counters.
#[derive(Debug, Clone, Copy, Default)]
pub struct EmuStats {
    pub flows_started: u64,
    pub flows_finished: u64,
    pub flows_pruned: u64,
    pub flows_memoized: u64,
    pub steps: u64,
    pub loads: u64,
    pub stores: u64,
    pub invalidated_loads: u64,
    pub uninit_reads: u64,
    pub barriers: u64,
    pub forks: u64,
    pub branches_decided: u64,
}

/// Why a flow ended.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FlowEnd {
    Ret,
    LoopReentry,
    Memoized,
    StepLimit,
}

impl FlowEnd {
    /// Stable on-disk tag (the [`crate::sym::persist`] codec).
    pub fn tag(self) -> u8 {
        match self {
            FlowEnd::Ret => 0,
            FlowEnd::LoopReentry => 1,
            FlowEnd::Memoized => 2,
            FlowEnd::StepLimit => 3,
        }
    }

    pub fn from_tag(tag: u8) -> Option<FlowEnd> {
        Some(match tag {
            0 => FlowEnd::Ret,
            1 => FlowEnd::LoopReentry,
            2 => FlowEnd::Memoized,
            3 => FlowEnd::StepLimit,
            _ => return None,
        })
    }
}

impl EmuStats {
    /// The counters as a fixed word array (stable serialization order —
    /// shared by the disk store's `Detected` codec and the
    /// [`crate::sym::persist`] emulation codec).
    pub fn to_words(&self) -> [u64; 12] {
        [
            self.flows_started,
            self.flows_finished,
            self.flows_pruned,
            self.flows_memoized,
            self.steps,
            self.loads,
            self.stores,
            self.invalidated_loads,
            self.uninit_reads,
            self.barriers,
            self.forks,
            self.branches_decided,
        ]
    }

    pub fn from_words(w: [u64; 12]) -> EmuStats {
        EmuStats {
            flows_started: w[0],
            flows_finished: w[1],
            flows_pruned: w[2],
            flows_memoized: w[3],
            steps: w[4],
            loads: w[5],
            stores: w[6],
            invalidated_loads: w[7],
            uninit_reads: w[8],
            barriers: w[9],
            forks: w[10],
            branches_decided: w[11],
        }
    }
}

/// One in-progress execution flow.
#[derive(Debug, Clone)]
pub struct Flow {
    pub id: u32,
    pub env: RegEnv,
    pub assumptions: Assumptions,
    pub trace: MemTrace,
    pub pc: usize,
    /// Straight-line segment id; bumped at every label and branch so the
    /// detector only pairs loads that sit in the same straight-line region.
    pub segment: u32,
    /// Barrier phase id; bumped at every `bar.sync` so the detector only
    /// pairs loads separated by no block-wide barrier — a shuffle may not
    /// move a value across a barrier, the warps' values are exchanged
    /// through memory there.
    pub phase: u32,
    /// Loop headers this flow has entered (header stmt → entry count).
    pub entered_loops: HashMap<usize, u32>,
    pub steps: u64,
}

/// A finished flow: its trace and final assumption set.
#[derive(Debug)]
pub struct FlowResult {
    pub id: u32,
    pub trace: MemTrace,
    pub assumptions: Assumptions,
    pub end: FlowEnd,
}

/// Everything the shuffle detector needs.
#[derive(Debug)]
pub struct EmulationResult {
    pub pool: TermPool,
    pub flows: Vec<FlowResult>,
    /// The `%tid.x` atom addresses are affine in.
    pub tid_sym: TermId,
    pub stats: EmuStats,
}

#[derive(Debug, Clone)]
pub enum EmuError {
    UnknownLabel(String),
    FlowLimit(usize),
    StepLimit,
}

impl std::fmt::Display for EmuError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            EmuError::UnknownLabel(l) => write!(f, "unknown branch target `{l}`"),
            EmuError::FlowLimit(n) => write!(f, "flow limit exceeded ({n} flows)"),
            EmuError::StepLimit => write!(f, "total step limit exceeded"),
        }
    }
}

impl std::error::Error for EmuError {}

/// The emulator: owns the term pool and the per-kernel static index.
#[derive(Debug)]
pub struct Emu<'k> {
    pub pool: TermPool,
    pub kernel: &'k Kernel,
    pub regs: RegInterner,
    pub index: KernelIndex,
    pub tid_sym: TermId,
    pub stats: EmuStats,
    limits: Limits,
    memo: HashSet<(usize, u64)>,
    next_flow_id: u32,
}

/// Emulate a kernel with default limits (private, single-use session).
pub fn emulate(kernel: &Kernel) -> Result<EmulationResult, EmuError> {
    emulate_with(kernel, Limits::default())
}

pub fn emulate_with(kernel: &Kernel, limits: Limits) -> Result<EmulationResult, EmuError> {
    emulate_in_session(kernel, limits, Arc::new(SessionInterner::new()))
}

/// Emulate a kernel whose symbol/UF names are interned in a shared
/// session — the pipeline's artifact cache passes one session for a whole
/// suite run so `%tid.x`, param names and UF names are interned once.
pub fn emulate_in_session(
    kernel: &Kernel,
    limits: Limits,
    session: Arc<SessionInterner>,
) -> Result<EmulationResult, EmuError> {
    let mut pool = TermPool::in_session(session);
    let mut regs = RegInterner::from_kernel(kernel);
    let index = KernelIndex::build(kernel, &mut regs);
    let tid_sym = pool.symbol("tid.x", 32);
    let mut emu = Emu {
        pool,
        kernel,
        regs,
        index,
        tid_sym,
        stats: EmuStats::default(),
        limits,
        memo: HashSet::new(),
        next_flow_id: 0,
    };
    let flows = emu.run()?;
    Ok(EmulationResult {
        pool: emu.pool,
        flows,
        tid_sym,
        stats: emu.stats,
    })
}

enum Step {
    Continue,
    Jump(usize),
    End(FlowEnd),
    Fork { pred: TermId, target: usize },
}

impl<'k> Emu<'k> {
    fn new_flow_id(&mut self) -> u32 {
        let id = self.next_flow_id;
        self.next_flow_id += 1;
        self.stats.flows_started += 1;
        id
    }

    fn run(&mut self) -> Result<Vec<FlowResult>, EmuError> {
        let id = self.new_flow_id();
        let first = Flow {
            id,
            env: RegEnv::new(self.regs.len()),
            assumptions: Assumptions::new(),
            trace: MemTrace::default(),
            pc: 0,
            segment: 0,
            phase: 0,
            entered_loops: HashMap::new(),
            steps: 0,
        };
        let mut work = vec![first];
        let mut done = Vec::new();

        while let Some(mut flow) = work.pop() {
            if work.len() + done.len() > self.limits.max_flows {
                return Err(EmuError::FlowLimit(self.limits.max_flows));
            }
            let end = loop {
                if flow.steps >= self.limits.max_steps_per_flow {
                    break FlowEnd::StepLimit;
                }
                if self.stats.steps >= self.limits.max_total_steps {
                    return Err(EmuError::StepLimit);
                }
                flow.steps += 1;
                self.stats.steps += 1;
                match self.step(&mut flow)? {
                    Step::Continue => flow.pc += 1,
                    Step::Jump(t) => {
                        flow.segment += 1;
                        flow.pc = t;
                    }
                    Step::End(e) => break e,
                    Step::Fork { pred, target } => {
                        self.stats.forks += 1;
                        // not-taken side continues in `flow`
                        let mut taken = flow.clone();
                        taken.id = self.new_flow_id();
                        let taken_ok = taken.assumptions.assume(&self.pool, pred, true).is_ok();
                        let fall_ok = flow.assumptions.assume(&self.pool, pred, false).is_ok();
                        if taken_ok {
                            taken.segment += 1;
                            if self.reenters_loop(&taken, target) {
                                done.push(FlowResult {
                                    id: taken.id,
                                    trace: taken.trace,
                                    assumptions: taken.assumptions,
                                    end: FlowEnd::LoopReentry,
                                });
                                self.stats.flows_finished += 1;
                            } else {
                                let mut t = taken;
                                t.pc = target;
                                work.push(t);
                            }
                        } else {
                            self.stats.flows_pruned += 1;
                        }
                        if fall_ok {
                            flow.segment += 1;
                            flow.pc += 1;
                            continue;
                        } else {
                            self.stats.flows_pruned += 1;
                            break FlowEnd::Ret; // infeasible fall-through; drop silently
                        }
                    }
                }
            };
            self.stats.flows_finished += 1;
            done.push(FlowResult {
                id: flow.id,
                trace: flow.trace,
                assumptions: flow.assumptions,
                end,
            });
        }
        Ok(done)
    }

    fn reenters_loop(&self, flow: &Flow, target: usize) -> bool {
        self.index.loops.contains_key(&target)
            && flow.entered_loops.get(&target).copied().unwrap_or(0) > 0
    }

    fn step(&mut self, flow: &mut Flow) -> Result<Step, EmuError> {
        let Some(stmt) = self.kernel.body.get(flow.pc) else {
            return Ok(Step::End(FlowEnd::Ret)); // fell off the end
        };
        match stmt {
            Statement::Label(_) => {
                flow.segment += 1;
                // loop abstraction at header entry (paper §4.2)
                if let Some(info) = self.index.loops.get(&flow.pc).cloned() {
                    let n = flow.entered_loops.entry(flow.pc).or_insert(0);
                    *n += 1;
                    let gen = *n;
                    self.abstract_loop(flow, &info, gen);
                }
                // memoization of identical environments at block entry
                let key = (flow.pc, flow.env.fingerprint());
                if !self.memo.insert(key) {
                    self.stats.flows_memoized += 1;
                    return Ok(Step::End(FlowEnd::Memoized));
                }
                Ok(Step::Continue)
            }
            Statement::Instr { guard, op } => {
                // resolve guard
                let guard_term = match guard {
                    None => None,
                    Some(g) => {
                        let t = self.term_of(flow, &crate::ptx::ast::Operand::Reg(g.reg.clone()), 1, false);
                        let eff = if g.negated { self.pool.not(t) } else { t };
                        match flow.assumptions.check(&self.pool, eff) {
                            Truth::True => None, // unconditionally executes
                            Truth::False => {
                                // instruction is a no-op on this path
                                return Ok(match op {
                                    Op::Bra { .. } => {
                                        self.stats.branches_decided += 1;
                                        Step::Continue
                                    }
                                    Op::Ret | Op::Exit => Step::Continue,
                                    _ => Step::Continue,
                                });
                            }
                            Truth::Unknown => Some(eff),
                        }
                    }
                };
                match op {
                    Op::Bra { target, .. } => {
                        let t = *self
                            .index
                            .labels
                            .get(target)
                            .ok_or_else(|| EmuError::UnknownLabel(target.clone()))?;
                        match guard_term {
                            None => {
                                self.stats.branches_decided += 1;
                                if self.reenters_loop(flow, t) {
                                    Ok(Step::End(FlowEnd::LoopReentry))
                                } else {
                                    Ok(Step::Jump(t))
                                }
                            }
                            Some(pred) => Ok(Step::Fork { pred, target: t }),
                        }
                    }
                    Op::Ret | Op::Exit => Ok(Step::End(FlowEnd::Ret)),
                    _ => {
                        self.exec_op(flow, flow.pc, guard_term, op);
                        Ok(Step::Continue)
                    }
                }
            }
        }
    }

    /// Abstract loop-variant registers at a header entry: induction
    /// variables get `init + step·k`, everything else a fresh opaque UF.
    fn abstract_loop(&mut self, flow: &mut Flow, info: &induction::LoopInfo, gen: u32) {
        let k_name = format!("loop.{}.{}", info.header, gen);
        for &(reg, abs) in &info.variants {
            match abs {
                Abstraction::Induction { step } => {
                    let init = flow.env.get(reg);
                    let w = init.map(|t| self.pool.width(t)).unwrap_or(32);
                    let k = self.pool.uf(&k_name, vec![], w);
                    let stepped = if step == 1 {
                        k
                    } else {
                        let c = self.pool.constant(step as u64, w);
                        self.pool.bin(crate::sym::BvOp::Mul, k, c)
                    };
                    let v = match init {
                        Some(i) => self.pool.bin(crate::sym::BvOp::Add, i, stepped),
                        None => stepped,
                    };
                    flow.env.set(reg, v);
                }
                Abstraction::InductionSym => {
                    // init + k, the UF absorbing the loop-invariant stride
                    let init = flow.env.get(reg);
                    let w = init.map(|t| self.pool.width(t)).unwrap_or(32);
                    let name = format!("{k_name}.r{reg}");
                    let k = self.pool.uf(&name, vec![], w);
                    let v = match init {
                        Some(i) => self.pool.bin(crate::sym::BvOp::Add, i, k),
                        None => k,
                    };
                    flow.env.set(reg, v);
                }
                Abstraction::Opaque => {
                    let w = flow.env.get(reg).map(|t| self.pool.width(t)).unwrap_or(32);
                    let name = format!("loopvar.{}.{}.{}", info.header, gen, reg);
                    let v = self.pool.uf(&name, vec![], w);
                    flow.env.set(reg, v);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ptx::parser::parse_kernel;
    use crate::sym::{split_on, Node};

    /// Paper Listing 1/2: guarded vector add. Two flows (guard true/false),
    /// three loads on the hot path.
    const ADD: &str = r#"
.visible .entry add(.param .u64 c, .param .u64 a, .param .u64 b, .param .u64 f){
.reg .pred %p<2>; .reg .f32 %f<4>; .reg .b32 %r<6>; .reg .b64 %rd<15>;
ld.param.u64 %rd1, [c];
ld.param.u64 %rd2, [a];
ld.param.u64 %rd3, [b];
ld.param.u64 %rd4, [f];
cvta.to.global.u64 %rd5, %rd4;
mov.u32 %r2, %ntid.x;
mov.u32 %r3, %ctaid.x;
mov.u32 %r4, %tid.x;
mad.lo.s32 %r1, %r3, %r2, %r4;
mul.wide.s32 %rd6, %r1, 4;
add.s64 %rd7, %rd5, %rd6;
ld.global.u32 %r5, [%rd7];
setp.eq.s32 %p1, %r5, 0;
@%p1 bra $LABEL_EXIT;
cvta.u64 %rd8, %rd2;
add.s64 %rd10, %rd8, %rd6;
cvta.u64 %rd11, %rd3;
add.s64 %rd12, %rd11, %rd6;
ld.global.f32 %f1, [%rd12];
ld.global.f32 %f2, [%rd10];
add.f32 %f3, %f2, %f1;
cvta.u64 %rd13, %rd1;
add.s64 %rd14, %rd13, %rd6;
st.global.f32 [%rd14], %f3;
$LABEL_EXIT:
ret;
}
"#;

    #[test]
    fn add_kernel_two_flows() {
        let k = parse_kernel(ADD).unwrap();
        let r = emulate(&k).unwrap();
        assert_eq!(r.flows.len(), 2);
        let loads: Vec<usize> = r.flows.iter().map(|f| f.trace.loads.len()).collect();
        let mut sorted = loads.clone();
        sorted.sort();
        assert_eq!(sorted, vec![1, 3]); // guard-false path: 1 load; hot path: 3
        assert_eq!(r.stats.forks, 1);
        // no uninitialized register reads in well-formed code
        assert_eq!(r.stats.uninit_reads, 0);
    }

    #[test]
    fn addresses_are_affine_in_tid() {
        let k = parse_kernel(ADD).unwrap();
        let r = emulate(&k).unwrap();
        let hot = r
            .flows
            .iter()
            .find(|f| f.trace.loads.len() == 3)
            .unwrap();
        for l in &hot.trace.loads {
            let (stride, _) = split_on(&r.pool, l.addr, r.tid_sym);
            assert_eq!(stride, 4, "every load strides 4 bytes per thread");
        }
    }

    #[test]
    fn store_invalidation_respects_nc() {
        let k = parse_kernel(
            r#"
.visible .entry k(.param .u64 a, .param .u64 b){
.reg .f32 %f<4>; .reg .b64 %rd<6>; .reg .b32 %r<4>;
ld.param.u64 %rd1, [a];
ld.param.u64 %rd2, [b];
cvta.to.global.u64 %rd3, %rd1;
cvta.to.global.u64 %rd4, %rd2;
mov.u32 %r1, %tid.x;
mul.wide.s32 %rd5, %r1, 4;
add.s64 %rd3, %rd3, %rd5;
add.s64 %rd4, %rd4, %rd5;
ld.global.nc.f32 %f1, [%rd3];
ld.global.f32 %f2, [%rd3+4];
st.global.f32 [%rd4], %f1;
ret;
}
"#,
        )
        .unwrap();
        let r = emulate(&k).unwrap();
        assert_eq!(r.flows.len(), 1);
        let t = &r.flows[0].trace;
        assert_eq!(t.loads.len(), 2);
        // nc load survives the may-aliasing store; plain load does not
        let nc = t.loads.iter().find(|l| l.nc).unwrap();
        let plain = t.loads.iter().find(|l| !l.nc).unwrap();
        assert!(nc.valid);
        assert!(!plain.valid);
    }

    #[test]
    fn loop_terminates_with_abstraction() {
        let k = parse_kernel(
            r#"
.visible .entry k(.param .u64 a, .param .u64 n){
.reg .b32 %r<6>; .reg .b64 %rd<5>; .reg .pred %p<2>; .reg .f32 %f<3>;
ld.param.u64 %rd1, [a];
cvta.to.global.u64 %rd2, %rd1;
mov.u32 %r1, 0;
$LOOP:
mul.wide.s32 %rd3, %r1, 4;
add.s64 %rd4, %rd2, %rd3;
ld.global.f32 %f1, [%rd4];
add.s32 %r1, %r1, 1;
setp.lt.s32 %p1, %r1, 128;
@%p1 bra $LOOP;
ret;
}
"#,
        )
        .unwrap();
        let r = emulate(&k).unwrap();
        // loop body emulated once; flows: back-edge (LoopReentry) + exit (Ret)
        assert!(r.flows.len() >= 2);
        assert!(r.flows.iter().any(|f| f.end == FlowEnd::LoopReentry));
        assert!(r.flows.iter().any(|f| f.end == FlowEnd::Ret));
        // the loop load's address contains the iteration UF
        let f = r.flows.iter().find(|f| !f.trace.loads.is_empty()).unwrap();
        let addr = f.trace.loads[0].addr;
        let mut ufs = Vec::new();
        r.pool.collect_ufs(addr, &mut ufs);
        assert!(
            ufs.iter().any(|&u| {
                matches!(r.pool.node(u), Node::Uf { func, .. }
                    if r.pool.uf_name(*func).starts_with("loop."))
            }),
            "loop iterator UF should appear in the address"
        );
    }

    #[test]
    fn infeasible_paths_pruned() {
        // both branches test the same predicate: only 2 of 4 paths realizable
        let k = parse_kernel(
            r#"
.visible .entry k(.param .u64 a){
.reg .b32 %r<4>; .reg .pred %p<2>; .reg .b64 %rd<4>; .reg .f32 %f<4>;
ld.param.u64 %rd1, [a];
cvta.to.global.u64 %rd2, %rd1;
mov.u32 %r1, %tid.x;
setp.lt.s32 %p1, %r1, 16;
@%p1 bra $A;
ld.global.f32 %f1, [%rd2];
$A:
setp.lt.s32 %p1, %r1, 16;
@%p1 bra $B;
ld.global.f32 %f2, [%rd2+4];
$B:
ret;
}
"#,
        )
        .unwrap();
        let r = emulate(&k).unwrap();
        // flows that executed the first load must have also executed the second
        // (p1 false on both) — i.e. no flow loads only [%rd2] or only [%rd2+4]...
        // realizable: {both loads} and {no loads} (possibly memoized variants).
        for f in &r.flows {
            let n = f.trace.loads.len();
            assert!(n == 0 || n == 2, "unrealizable path with {n} loads survived");
        }
    }

    #[test]
    fn predicated_instruction_issues_conditional_value() {
        let k = parse_kernel(
            r#"
.visible .entry k(.param .u64 a){
.reg .b32 %r<4>; .reg .pred %p<2>;
mov.u32 %r1, %tid.x;
setp.lt.s32 %p1, %r1, 16;
mov.u32 %r2, 0;
@%p1 mov.u32 %r2, 1;
ret;
}
"#,
        )
        .unwrap();
        let r = emulate(&k).unwrap();
        assert_eq!(r.flows.len(), 1, "predication must not fork");
    }

    #[test]
    fn fell_off_end_is_ret() {
        let k = parse_kernel(
            ".visible .entry k(.param .u64 a){ .reg .b32 %r<2>; mov.u32 %r1, 0; }",
        )
        .unwrap();
        let r = emulate(&k).unwrap();
        assert_eq!(r.flows.len(), 1);
        assert_eq!(r.flows[0].end, FlowEnd::Ret);
    }
}
