//! Register environment: name interning + per-flow value vectors.
//!
//! Registers are interned to dense indices once per kernel so that a flow's
//! environment is a flat `Vec<Option<TermId>>` — forking a flow at a branch
//! (paper §4.2 "duplicating the register environment") is a memcpy.

use crate::ptx::ast::{Address, Kernel, Op, Operand, Reg, Statement};
use crate::sym::{TermId, TermPool};
use crate::util::FnvMap;

/// Dense register index for one kernel.
#[derive(Default)]
pub struct RegInterner {
    ids: FnvMap<String, u32>,
    names: Vec<String>,
}

impl std::fmt::Debug for RegInterner {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "RegInterner({} regs)", self.names.len())
    }
}

impl RegInterner {
    pub fn intern(&mut self, r: &Reg) -> u32 {
        if let Some(&i) = self.ids.get(&r.0) {
            return i;
        }
        let i = self.names.len() as u32;
        self.ids.insert(r.0.clone(), i);
        self.names.push(r.0.clone());
        i
    }

    pub fn get(&self, r: &Reg) -> Option<u32> {
        self.ids.get(&r.0).copied()
    }

    pub fn name(&self, i: u32) -> &str {
        &self.names[i as usize]
    }

    pub fn len(&self) -> usize {
        self.names.len()
    }

    pub fn is_empty(&self) -> bool {
        self.names.is_empty()
    }

    /// Intern every register mentioned anywhere in a kernel body.
    pub fn from_kernel(k: &Kernel) -> RegInterner {
        let mut it = RegInterner::default();
        let op_reg = |o: &Operand, it: &mut RegInterner| {
            if let Operand::Reg(r) = o {
                it.intern(r);
            }
        };
        let addr_reg = |a: &Address, it: &mut RegInterner| {
            if let Operand::Reg(r) = &a.base {
                it.intern(r);
            }
        };
        for st in &k.body {
            let Statement::Instr { guard, op } = st else {
                continue;
            };
            if let Some(g) = guard {
                it.intern(&g.reg);
            }
            match op {
                Op::Ld { dst, addr, .. } => {
                    it.intern(dst);
                    addr_reg(addr, &mut it);
                }
                Op::St { addr, src, .. } => {
                    addr_reg(addr, &mut it);
                    op_reg(src, &mut it);
                }
                Op::Mov { dst, src, .. } => {
                    it.intern(dst);
                    op_reg(src, &mut it);
                }
                Op::Cvta { dst, src, .. } => {
                    it.intern(dst);
                    op_reg(src, &mut it);
                }
                Op::IntBin { dst, a, b, .. } | Op::FltBin { dst, a, b, .. } => {
                    it.intern(dst);
                    op_reg(a, &mut it);
                    op_reg(b, &mut it);
                }
                Op::Mad { dst, a, b, c, .. } | Op::Fma { dst, a, b, c, .. } => {
                    it.intern(dst);
                    op_reg(a, &mut it);
                    op_reg(b, &mut it);
                    op_reg(c, &mut it);
                }
                Op::Not { dst, a, .. } | Op::Neg { dst, a, .. } | Op::FltUn { dst, a, .. } => {
                    it.intern(dst);
                    op_reg(a, &mut it);
                }
                Op::Setp { dst, a, b, .. } => {
                    it.intern(dst);
                    op_reg(a, &mut it);
                    op_reg(b, &mut it);
                }
                Op::Selp { dst, a, b, p, .. } => {
                    it.intern(dst);
                    op_reg(a, &mut it);
                    op_reg(b, &mut it);
                    op_reg(p, &mut it);
                }
                Op::Cvt { dst, src, .. } => {
                    it.intern(dst);
                    op_reg(src, &mut it);
                }
                Op::Shfl {
                    dst,
                    pred_out,
                    src,
                    b,
                    c,
                    mask,
                    ..
                } => {
                    it.intern(dst);
                    if let Some(p) = pred_out {
                        it.intern(p);
                    }
                    op_reg(src, &mut it);
                    op_reg(b, &mut it);
                    op_reg(c, &mut it);
                    op_reg(mask, &mut it);
                }
                Op::Activemask { dst } => {
                    it.intern(dst);
                }
                Op::Bra { .. } | Op::BarSync { .. } | Op::Ret | Op::Exit => {}
            }
        }
        it
    }
}

/// One flow's register values. `None` = never written (paper: registers are
/// always initialized before use in well-formed PTX; a `None` read gets a
/// fresh uninterpreted value and bumps a diagnostic counter).
#[derive(Debug, Clone, PartialEq)]
pub struct RegEnv {
    pub vals: Vec<Option<TermId>>,
}

impl RegEnv {
    pub fn new(n: usize) -> RegEnv {
        RegEnv {
            vals: vec![None; n],
        }
    }

    pub fn get(&self, i: u32) -> Option<TermId> {
        self.vals[i as usize]
    }

    pub fn set(&mut self, i: u32, v: TermId) {
        self.vals[i as usize] = Some(v);
    }

    /// FNV-1a over the values' *structural* fingerprints ([`TermPool::fp`])
    /// — used for path memoization (§4.2). Structural rather than id-based
    /// so the memo table survives the persistence codec's relocation: a
    /// resumed emulation in a fresh pool computes the same keys the tight
    /// run computed, whatever the local `TermId`s are.
    pub fn fingerprint(&self, pool: &TermPool) -> u64 {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for v in &self.vals {
            let x = match v {
                Some(t) => pool.fp(*t) | 1,
                None => 0,
            };
            h ^= x;
            h = h.wrapping_mul(0x1000_0000_01b3);
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ptx::parser::parse_kernel;

    #[test]
    fn interns_all_registers() {
        let k = parse_kernel(
            r#"
.visible .entry k(.param .u64 a){
.reg .b32 %r<4>; .reg .b64 %rd<3>; .reg .pred %p<2>;
ld.param.u64 %rd1, [a];
cvta.to.global.u64 %rd2, %rd1;
mov.u32 %r1, %tid.x;
setp.lt.s32 %p1, %r1, 32;
@%p1 bra $DONE;
ld.global.f32 %f1, [%rd2+4];
$DONE: ret;
}
"#,
        )
        .unwrap();
        let it = RegInterner::from_kernel(&k);
        assert!(it.get(&Reg::new("%rd1")).is_some());
        assert!(it.get(&Reg::new("%rd2")).is_some());
        assert!(it.get(&Reg::new("%r1")).is_some());
        assert!(it.get(&Reg::new("%p1")).is_some());
        assert!(it.get(&Reg::new("%f1")).is_some());
        assert!(it.get(&Reg::new("%nope")).is_none());
    }

    #[test]
    fn env_fingerprint_changes_with_values() {
        let mut p = TermPool::new();
        let t = p.symbol("x", 32);
        let mut e = RegEnv::new(4);
        let f0 = e.fingerprint(&p);
        e.set(2, t);
        let f1 = e.fingerprint(&p);
        assert_ne!(f0, f1);
        let mut e2 = RegEnv::new(4);
        e2.set(2, t);
        assert_eq!(e2.fingerprint(&p), f1);
    }

    #[test]
    fn env_fingerprint_is_pool_relocation_stable() {
        // the same environment built in two pools with shifted ids must
        // fingerprint identically — the property the resumable emulation
        // image's memo table relies on
        let mut p1 = TermPool::new();
        let a1 = p1.symbol("a", 32);
        let c1 = p1.constant(3, 32);
        let v1 = p1.bin(crate::sym::BvOp::Add, a1, c1);
        let mut e1 = RegEnv::new(3);
        e1.set(0, a1);
        e1.set(2, v1);

        let mut p2 = TermPool::new();
        p2.symbol("noise", 64); // shift ids
        let a2 = p2.symbol("a", 32);
        let c2 = p2.constant(3, 32);
        let v2 = p2.bin(crate::sym::BvOp::Add, a2, c2);
        let mut e2 = RegEnv::new(3);
        e2.set(0, a2);
        e2.set(2, v2);

        assert_ne!(a1, a2, "ids should actually differ across the pools");
        assert_eq!(e1.fingerprint(&p1), e2.fingerprint(&p2));
    }
}
