//! Per-flow memory traces (paper §4.3).
//!
//! Loads are recorded forwardly through the emulation as uninterpreted
//! functions of their symbolic address. Stores invalidate earlier loads
//! that may alias (unless the load went through the non-coherent/read-only
//! path, which the OpenACC `independent` contract makes store-proof).

use crate::ptx::ast::{Space, Type};
use crate::sym::{may_alias, TermId, TermPool};
use crate::util::{Dec, Enc};

/// Stable on-disk tag of a [`Type`] (the [`crate::sym::persist`] codec —
/// exhaustive match so adding a variant without a tag fails to compile).
pub(crate) fn type_tag(t: Type) -> u8 {
    match t {
        Type::U8 => 0,
        Type::U16 => 1,
        Type::U32 => 2,
        Type::U64 => 3,
        Type::S8 => 4,
        Type::S16 => 5,
        Type::S32 => 6,
        Type::S64 => 7,
        Type::B8 => 8,
        Type::B16 => 9,
        Type::B32 => 10,
        Type::B64 => 11,
        Type::F32 => 12,
        Type::F64 => 13,
        Type::Pred => 14,
    }
}

pub(crate) fn type_from_tag(tag: u8) -> Option<Type> {
    Some(match tag {
        0 => Type::U8,
        1 => Type::U16,
        2 => Type::U32,
        3 => Type::U64,
        4 => Type::S8,
        5 => Type::S16,
        6 => Type::S32,
        7 => Type::S64,
        8 => Type::B8,
        9 => Type::B16,
        10 => Type::B32,
        11 => Type::B64,
        12 => Type::F32,
        13 => Type::F64,
        14 => Type::Pred,
        _ => return None,
    })
}

/// Stable on-disk tag of a [`Space`].
pub(crate) fn space_tag(s: Space) -> u8 {
    match s {
        Space::Param => 0,
        Space::Global => 1,
        Space::Shared => 2,
        Space::Local => 3,
        Space::Const => 4,
    }
}

pub(crate) fn space_from_tag(tag: u8) -> Option<Space> {
    Some(match tag {
        0 => Space::Param,
        1 => Space::Global,
        2 => Space::Shared,
        3 => Space::Local,
        4 => Space::Const,
        _ => return None,
    })
}

/// One recorded load.
#[derive(Debug, Clone)]
pub struct LoadRec {
    /// Statement index of the `ld` in the kernel body.
    pub stmt: usize,
    /// Symbolic byte address.
    pub addr: TermId,
    /// The UF application term holding the loaded value.
    pub value: TermId,
    pub ty: Type,
    pub space: Space,
    /// Non-coherent (`ld.global.nc`) — read-only data, never invalidated.
    pub nc: bool,
    /// Straight-line segment id within the flow (§5.1: shuffles are only
    /// detected between loads of the same straight-line region).
    pub segment: u32,
    /// Barrier phase id within the flow: loads separated by a `bar.sync`
    /// must never be paired (the exchange happens through memory at the
    /// barrier, so a shuffle across it is illegal).
    pub phase: u32,
    /// Guard was symbolic (predicated load) — excluded from shuffle pairing.
    pub guarded: bool,
    /// Still valid (not overwritten by a later may-aliasing store).
    pub valid: bool,
}

/// One recorded store.
#[derive(Debug, Clone)]
pub struct StoreRec {
    pub stmt: usize,
    pub addr: TermId,
    pub value: TermId,
    pub ty: Type,
    pub space: Space,
    pub segment: u32,
    /// Barrier phase id (see [`LoadRec::phase`]).
    pub phase: u32,
}

/// The memory trace of a single execution flow.
#[derive(Debug, Clone, Default)]
pub struct MemTrace {
    pub loads: Vec<LoadRec>,
    pub stores: Vec<StoreRec>,
}

impl MemTrace {
    pub fn record_load(&mut self, rec: LoadRec) {
        self.loads.push(rec);
    }

    /// Record a store and invalidate may-aliasing earlier loads. Returns the
    /// UF value-terms of the loads that were invalidated so the caller can
    /// also drop assumptions mentioning them (paper: "both loads and
    /// assumptions are invalidated by stores that possibly overwrite them").
    pub fn record_store(&mut self, pool: &TermPool, rec: StoreRec) -> Vec<TermId> {
        let mut killed = Vec::new();
        let bytes = rec.ty.bytes();
        for l in self.loads.iter_mut().filter(|l| l.valid) {
            if l.nc {
                continue; // read-only data path
            }
            if l.space != rec.space {
                continue; // disjoint state spaces
            }
            if may_alias(pool, l.addr, l.ty.bytes(), rec.addr, bytes) {
                l.valid = false;
                killed.push(l.value);
            }
        }
        self.stores.push(rec);
        killed
    }

    /// Valid (non-invalidated, unguarded) global loads of a given segment.
    pub fn valid_global_loads(&self) -> impl Iterator<Item = &LoadRec> {
        self.loads
            .iter()
            .filter(|l| l.valid && !l.guarded && l.space == Space::Global)
    }

    /// Every `.shared` load, in record order — including invalidated ones.
    /// `valid` tracks store-forwarding validity for the *detector*; the
    /// phase-liveness pass must see every shared read the kernel performs
    /// (an invalidated load still reads the bytes a staging store wrote).
    pub fn shared_loads(&self) -> impl Iterator<Item = &LoadRec> {
        self.loads.iter().filter(|l| l.space == Space::Shared)
    }

    /// Every `.shared` store, in record order.
    pub fn shared_stores(&self) -> impl Iterator<Item = &StoreRec> {
        self.stores.iter().filter(|s| s.space == Space::Shared)
    }

    /// Highest barrier-phase id any record carries (0 for an empty trace).
    /// The flow crossed exactly this many `bar.sync`s before its last
    /// recorded memory access.
    pub fn max_phase(&self) -> u32 {
        let l = self.loads.iter().map(|l| l.phase).max().unwrap_or(0);
        let s = self.stores.iter().map(|s| s.phase).max().unwrap_or(0);
        l.max(s)
    }

    /// Every term the trace references (serialization roots for the
    /// [`crate::sym::persist`] codec).
    pub fn term_roots(&self, out: &mut Vec<TermId>) {
        for l in &self.loads {
            out.push(l.addr);
            out.push(l.value);
        }
        for s in &self.stores {
            out.push(s.addr);
            out.push(s.value);
        }
    }

    /// Serialize the trace shape; `local` maps a pool `TermId` to its
    /// local index in the term-graph image being written.
    pub(crate) fn encode(&self, e: &mut Enc, local: &mut dyn FnMut(TermId) -> u32) {
        e.u64(self.loads.len() as u64);
        for l in &self.loads {
            e.u64(l.stmt as u64);
            e.u32(local(l.addr));
            e.u32(local(l.value));
            e.u8(type_tag(l.ty));
            e.u8(space_tag(l.space));
            e.bool(l.nc);
            e.u32(l.segment);
            e.u32(l.phase);
            e.bool(l.guarded);
            e.bool(l.valid);
        }
        e.u64(self.stores.len() as u64);
        for s in &self.stores {
            e.u64(s.stmt as u64);
            e.u32(local(s.addr));
            e.u32(local(s.value));
            e.u8(type_tag(s.ty));
            e.u8(space_tag(s.space));
            e.u32(s.segment);
            e.u32(s.phase);
        }
    }

    /// Decode a trace; `term` maps a local index back to a relocated
    /// `TermId` (bounds-checked — `None` fails the whole decode).
    pub(crate) fn decode(
        d: &mut Dec,
        term: &dyn Fn(u32) -> Option<TermId>,
    ) -> Option<MemTrace> {
        let nloads = d.len()?;
        let mut loads = Vec::with_capacity(nloads);
        for _ in 0..nloads {
            loads.push(LoadRec {
                stmt: d.u64()? as usize,
                addr: term(d.u32()?)?,
                value: term(d.u32()?)?,
                ty: type_from_tag(d.u8()?)?,
                space: space_from_tag(d.u8()?)?,
                nc: d.bool()?,
                segment: d.u32()?,
                phase: d.u32()?,
                guarded: d.bool()?,
                valid: d.bool()?,
            });
        }
        let nstores = d.len()?;
        let mut stores = Vec::with_capacity(nstores);
        for _ in 0..nstores {
            stores.push(StoreRec {
                stmt: d.u64()? as usize,
                addr: term(d.u32()?)?,
                value: term(d.u32()?)?,
                ty: type_from_tag(d.u8()?)?,
                space: space_from_tag(d.u8()?)?,
                segment: d.u32()?,
                phase: d.u32()?,
            });
        }
        Some(MemTrace { loads, stores })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sym::{BvOp, TermPool};

    fn mk_addr(p: &mut TermPool, base: &str, off: i64) -> TermId {
        let b = p.symbol(base, 64);
        let tid = p.symbol("tid.x", 32);
        let tw = p.sext(tid, 64);
        let c4 = p.constant(4, 64);
        let s = p.bin(BvOp::Mul, tw, c4);
        let t = p.bin(BvOp::Add, b, s);
        let o = p.constant(off as u64, 64);
        p.bin(BvOp::Add, t, o)
    }

    fn load(p: &mut TermPool, stmt: usize, addr: TermId, nc: bool) -> LoadRec {
        let value = p.uf("load.g32", vec![addr], 32);
        LoadRec {
            stmt,
            addr,
            value,
            ty: Type::F32,
            space: Space::Global,
            nc,
            segment: 0,
            phase: 0,
            guarded: false,
            valid: true,
        }
    }

    #[test]
    fn store_invalidates_aliasing_load() {
        let mut p = TermPool::new();
        let mut t = MemTrace::default();
        let a = mk_addr(&mut p, "w0", 0);
        let l = load(&mut p, 0, a, false);
        let lv = l.value;
        t.record_load(l);
        // store to the exact same address
        let sv = p.constant(0, 32);
        let killed = t.record_store(
            &p,
            StoreRec {
                stmt: 1,
                addr: a,
                value: sv,
                ty: Type::F32,
                space: Space::Global,
                segment: 0,
                phase: 0,
            },
        );
        assert_eq!(killed, vec![lv]);
        assert_eq!(t.valid_global_loads().count(), 0);
    }

    #[test]
    fn store_keeps_provably_distinct_load() {
        let mut p = TermPool::new();
        let mut t = MemTrace::default();
        let a = mk_addr(&mut p, "w0", 0);
        let b = mk_addr(&mut p, "w0", 8); // two words away
        t.record_load(load(&mut p, 0, a, false));
        let sv = p.constant(0, 32);
        let killed = t.record_store(
            &p,
            StoreRec {
                stmt: 1,
                addr: b,
                value: sv,
                ty: Type::F32,
                space: Space::Global,
                segment: 0,
                phase: 0,
            },
        );
        assert!(killed.is_empty());
        assert_eq!(t.valid_global_loads().count(), 1);
    }

    #[test]
    fn nc_load_survives_unknown_store() {
        let mut p = TermPool::new();
        let mut t = MemTrace::default();
        let a = mk_addr(&mut p, "w0", 0);
        let unk = mk_addr(&mut p, "w1", 0); // symbolic distance from a
        t.record_load(load(&mut p, 0, a, true));
        let sv = p.constant(0, 32);
        let killed = t.record_store(
            &p,
            StoreRec {
                stmt: 1,
                addr: unk,
                value: sv,
                ty: Type::F32,
                space: Space::Global,
                segment: 0,
                phase: 0,
            },
        );
        assert!(killed.is_empty());
        assert_eq!(t.valid_global_loads().count(), 1);
    }

    #[test]
    fn non_nc_load_killed_by_unknown_store() {
        let mut p = TermPool::new();
        let mut t = MemTrace::default();
        let a = mk_addr(&mut p, "w0", 0);
        let unk = mk_addr(&mut p, "w1", 0);
        t.record_load(load(&mut p, 0, a, false));
        let sv = p.constant(0, 32);
        let killed = t.record_store(
            &p,
            StoreRec {
                stmt: 1,
                addr: unk,
                value: sv,
                ty: Type::F32,
                space: Space::Global,
                segment: 0,
                phase: 0,
            },
        );
        assert_eq!(killed.len(), 1);
    }

    #[test]
    fn shared_queries_see_invalidated_records_and_phases() {
        let mut p = TermPool::new();
        let mut t = MemTrace::default();
        let a = mk_addr(&mut p, "sdata", 0);
        let mut l = load(&mut p, 0, a, false);
        l.space = Space::Shared;
        t.record_load(l);
        let sv = p.constant(0, 32);
        // aliasing shared store in a later phase invalidates the load …
        t.record_store(
            &p,
            StoreRec {
                stmt: 2,
                addr: a,
                value: sv,
                ty: Type::F32,
                space: Space::Shared,
                segment: 0,
                phase: 3,
            },
        );
        assert!(!t.loads[0].valid);
        // … but the phase-liveness queries still see it, plus the store.
        assert_eq!(t.shared_loads().count(), 1);
        assert_eq!(t.shared_stores().count(), 1);
        assert_eq!(t.max_phase(), 3);
    }

    #[test]
    fn shared_store_does_not_touch_global_loads() {
        let mut p = TermPool::new();
        let mut t = MemTrace::default();
        let a = mk_addr(&mut p, "w0", 0);
        t.record_load(load(&mut p, 0, a, false));
        let sv = p.constant(0, 32);
        let killed = t.record_store(
            &p,
            StoreRec {
                stmt: 1,
                addr: a,
                value: sv,
                ty: Type::F32,
                space: Space::Shared,
                segment: 0,
                phase: 0,
            },
        );
        assert!(killed.is_empty());
    }
}
