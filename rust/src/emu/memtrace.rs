//! Per-flow memory traces (paper §4.3).
//!
//! Loads are recorded forwardly through the emulation as uninterpreted
//! functions of their symbolic address. Stores invalidate earlier loads
//! that may alias (unless the load went through the non-coherent/read-only
//! path, which the OpenACC `independent` contract makes store-proof).

use crate::ptx::ast::{Space, Type};
use crate::sym::{may_alias, TermId, TermPool};

/// One recorded load.
#[derive(Debug, Clone)]
pub struct LoadRec {
    /// Statement index of the `ld` in the kernel body.
    pub stmt: usize,
    /// Symbolic byte address.
    pub addr: TermId,
    /// The UF application term holding the loaded value.
    pub value: TermId,
    pub ty: Type,
    pub space: Space,
    /// Non-coherent (`ld.global.nc`) — read-only data, never invalidated.
    pub nc: bool,
    /// Straight-line segment id within the flow (§5.1: shuffles are only
    /// detected between loads of the same straight-line region).
    pub segment: u32,
    /// Guard was symbolic (predicated load) — excluded from shuffle pairing.
    pub guarded: bool,
    /// Still valid (not overwritten by a later may-aliasing store).
    pub valid: bool,
}

/// One recorded store.
#[derive(Debug, Clone)]
pub struct StoreRec {
    pub stmt: usize,
    pub addr: TermId,
    pub value: TermId,
    pub ty: Type,
    pub space: Space,
    pub segment: u32,
}

/// The memory trace of a single execution flow.
#[derive(Debug, Clone, Default)]
pub struct MemTrace {
    pub loads: Vec<LoadRec>,
    pub stores: Vec<StoreRec>,
}

impl MemTrace {
    pub fn record_load(&mut self, rec: LoadRec) {
        self.loads.push(rec);
    }

    /// Record a store and invalidate may-aliasing earlier loads. Returns the
    /// UF value-terms of the loads that were invalidated so the caller can
    /// also drop assumptions mentioning them (paper: "both loads and
    /// assumptions are invalidated by stores that possibly overwrite them").
    pub fn record_store(&mut self, pool: &TermPool, rec: StoreRec) -> Vec<TermId> {
        let mut killed = Vec::new();
        let bytes = rec.ty.bytes();
        for l in self.loads.iter_mut().filter(|l| l.valid) {
            if l.nc {
                continue; // read-only data path
            }
            if l.space != rec.space {
                continue; // disjoint state spaces
            }
            if may_alias(pool, l.addr, l.ty.bytes(), rec.addr, bytes) {
                l.valid = false;
                killed.push(l.value);
            }
        }
        self.stores.push(rec);
        killed
    }

    /// Valid (non-invalidated, unguarded) global loads of a given segment.
    pub fn valid_global_loads(&self) -> impl Iterator<Item = &LoadRec> {
        self.loads
            .iter()
            .filter(|l| l.valid && !l.guarded && l.space == Space::Global)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sym::{BvOp, TermPool};

    fn mk_addr(p: &mut TermPool, base: &str, off: i64) -> TermId {
        let b = p.symbol(base, 64);
        let tid = p.symbol("tid.x", 32);
        let tw = p.sext(tid, 64);
        let c4 = p.constant(4, 64);
        let s = p.bin(BvOp::Mul, tw, c4);
        let t = p.bin(BvOp::Add, b, s);
        let o = p.constant(off as u64, 64);
        p.bin(BvOp::Add, t, o)
    }

    fn load(p: &mut TermPool, stmt: usize, addr: TermId, nc: bool) -> LoadRec {
        let value = p.uf("load.g32", vec![addr], 32);
        LoadRec {
            stmt,
            addr,
            value,
            ty: Type::F32,
            space: Space::Global,
            nc,
            segment: 0,
            guarded: false,
            valid: true,
        }
    }

    #[test]
    fn store_invalidates_aliasing_load() {
        let mut p = TermPool::new();
        let mut t = MemTrace::default();
        let a = mk_addr(&mut p, "w0", 0);
        let l = load(&mut p, 0, a, false);
        let lv = l.value;
        t.record_load(l);
        // store to the exact same address
        let sv = p.constant(0, 32);
        let killed = t.record_store(
            &p,
            StoreRec {
                stmt: 1,
                addr: a,
                value: sv,
                ty: Type::F32,
                space: Space::Global,
                segment: 0,
            },
        );
        assert_eq!(killed, vec![lv]);
        assert_eq!(t.valid_global_loads().count(), 0);
    }

    #[test]
    fn store_keeps_provably_distinct_load() {
        let mut p = TermPool::new();
        let mut t = MemTrace::default();
        let a = mk_addr(&mut p, "w0", 0);
        let b = mk_addr(&mut p, "w0", 8); // two words away
        t.record_load(load(&mut p, 0, a, false));
        let sv = p.constant(0, 32);
        let killed = t.record_store(
            &p,
            StoreRec {
                stmt: 1,
                addr: b,
                value: sv,
                ty: Type::F32,
                space: Space::Global,
                segment: 0,
            },
        );
        assert!(killed.is_empty());
        assert_eq!(t.valid_global_loads().count(), 1);
    }

    #[test]
    fn nc_load_survives_unknown_store() {
        let mut p = TermPool::new();
        let mut t = MemTrace::default();
        let a = mk_addr(&mut p, "w0", 0);
        let unk = mk_addr(&mut p, "w1", 0); // symbolic distance from a
        t.record_load(load(&mut p, 0, a, true));
        let sv = p.constant(0, 32);
        let killed = t.record_store(
            &p,
            StoreRec {
                stmt: 1,
                addr: unk,
                value: sv,
                ty: Type::F32,
                space: Space::Global,
                segment: 0,
            },
        );
        assert!(killed.is_empty());
        assert_eq!(t.valid_global_loads().count(), 1);
    }

    #[test]
    fn non_nc_load_killed_by_unknown_store() {
        let mut p = TermPool::new();
        let mut t = MemTrace::default();
        let a = mk_addr(&mut p, "w0", 0);
        let unk = mk_addr(&mut p, "w1", 0);
        t.record_load(load(&mut p, 0, a, false));
        let sv = p.constant(0, 32);
        let killed = t.record_store(
            &p,
            StoreRec {
                stmt: 1,
                addr: unk,
                value: sv,
                ty: Type::F32,
                space: Space::Global,
                segment: 0,
            },
        );
        assert_eq!(killed.len(), 1);
    }

    #[test]
    fn shared_store_does_not_touch_global_loads() {
        let mut p = TermPool::new();
        let mut t = MemTrace::default();
        let a = mk_addr(&mut p, "w0", 0);
        t.record_load(load(&mut p, 0, a, false));
        let sv = p.constant(0, 32);
        let killed = t.record_store(
            &p,
            StoreRec {
                stmt: 1,
                addr: a,
                value: sv,
                ty: Type::F32,
                space: Space::Shared,
                segment: 0,
            },
        );
        assert!(killed.is_empty());
    }
}
