//! Symbolic semantics of each PTX instruction (paper §4.1).
//!
//! Every instruction updates the destination register with a concolic term:
//! fully-concrete inputs fold to constants, runtime unknowns stay symbolic.
//! Floating-point arithmetic becomes uninterpreted functions ("we insert
//! the conversion by uninterpreted functions at loading and storing
//! bitvectors to and from floating-point data"), which hash-consing turns
//! into free common-subexpression detection: two loads of the same address
//! yield the *same* term.

use super::memtrace::{LoadRec, StoreRec};
use super::Emu;
use super::Flow;
use crate::ptx::ast::*;
use crate::sym::{BvOp, CmpKind, TermId};

impl<'k> Emu<'k> {
    /// Value of an operand coerced to `width` bits (`signed` controls how a
    /// narrower register value widens).
    pub(super) fn term_of(
        &mut self,
        flow: &mut Flow,
        o: &Operand,
        width: u32,
        signed: bool,
    ) -> TermId {
        match o {
            Operand::Reg(r) => {
                let id = self.regs.intern(r);
                let v = match flow.env.get(id) {
                    Some(v) => v,
                    None => {
                        self.stats.uninit_reads += 1;
                        let name = format!("uninit.{}", r.0);
                        let t = self.pool.symbol(&name, width);
                        flow.env.set(id, t);
                        t
                    }
                };
                self.coerce(v, width, signed)
            }
            Operand::ImmInt(v) => self.pool.constant(*v as u64, width),
            Operand::ImmF32(b) => self.pool.constant(*b as u64, 32),
            Operand::ImmF64(b) => self.pool.constant(*b, 64),
            Operand::Special(sp) => {
                let t = self.special(*sp);
                self.coerce(t, width, false)
            }
            Operand::Var(v) => {
                let name = format!("var.{v}");
                self.pool.symbol(&name, width)
            }
        }
    }

    pub(super) fn special(&mut self, sp: Special) -> TermId {
        match sp {
            Special::WarpSize => self.pool.constant(32, 32),
            _ => self.pool.symbol(sp.name().trim_start_matches('%'), 32),
        }
    }

    fn coerce(&mut self, t: TermId, width: u32, signed: bool) -> TermId {
        let w = self.pool.width(t);
        if w == width {
            t
        } else if w > width {
            self.pool.trunc(t, width)
        } else if signed {
            self.pool.sext(t, width)
        } else {
            self.pool.zext(t, width)
        }
    }

    /// Byte address of a memory operand as a 64-bit term.
    pub(super) fn addr_term(&mut self, flow: &mut Flow, addr: &Address) -> TermId {
        let base = match &addr.base {
            Operand::Var(name) => {
                let n = format!("addr.{name}");
                self.pool.symbol(&n, 64)
            }
            other => self.term_of(flow, other, 64, false),
        };
        if addr.offset == 0 {
            base
        } else {
            let off = self.pool.constant(addr.offset as u64, 64);
            self.pool.bin(BvOp::Add, base, off)
        }
    }

    /// Write `val` to `dst`, respecting a symbolic guard (predicated
    /// instructions issue conditional values, paper §4.1).
    fn write(&mut self, flow: &mut Flow, dst: &Reg, val: TermId, guard: Option<TermId>) {
        let id = self.regs.intern(dst);
        let v = match guard {
            None => val,
            Some(g) => {
                let old = match flow.env.get(id) {
                    Some(o) if self.pool.width(o) == self.pool.width(val) => o,
                    _ => {
                        let name = format!("uninit.{}", dst.0);
                        let w = self.pool.width(val);
                        self.pool.symbol(&name, w)
                    }
                };
                self.pool.ite(g, val, old)
            }
        };
        flow.env.set(id, v);
    }

    /// Execute one non-control-flow instruction.
    pub(super) fn exec_op(
        &mut self,
        flow: &mut Flow,
        stmt: usize,
        guard: Option<TermId>,
        op: &Op,
    ) {
        match op {
            Op::Ld {
                space,
                nc,
                ty,
                dst,
                addr,
            } => {
                let w = ty.bits().max(8);
                let val = if *space == Space::Param {
                    // parameter loads: named symbols (a UF of the static address)
                    let base = match &addr.base {
                        Operand::Var(n) => n.clone(),
                        Operand::Reg(r) => r.0.clone(),
                        _ => "?".into(),
                    };
                    let name = if addr.offset == 0 {
                        format!("param.{base}")
                    } else {
                        format!("param.{base}.{}", addr.offset)
                    };
                    self.pool.symbol(&name, w)
                } else {
                    let a = self.addr_term(flow, addr);
                    let uf = format!("load.{}.{}", space.suffix(), w);
                    let val = self.pool.uf(&uf, vec![a], w);
                    flow.trace.record_load(LoadRec {
                        stmt,
                        addr: a,
                        value: val,
                        ty: *ty,
                        space: *space,
                        nc: *nc,
                        segment: flow.segment,
                        phase: flow.phase,
                        guarded: guard.is_some(),
                        valid: true,
                    });
                    self.stats.loads += 1;
                    val
                };
                self.write(flow, dst, val, guard);
            }
            Op::St { space, ty, addr, src } => {
                if *space == Space::Param {
                    return;
                }
                let a = self.addr_term(flow, addr);
                let v = self.term_of(flow, src, ty.bits().max(8), ty.is_signed());
                let killed = flow.trace.record_store(
                    &self.pool,
                    StoreRec {
                        stmt,
                        addr: a,
                        value: v,
                        ty: *ty,
                        space: *space,
                        segment: flow.segment,
                        phase: flow.phase,
                    },
                );
                if !killed.is_empty() {
                    flow.assumptions.invalidate_atoms(&killed);
                    self.stats.invalidated_loads += killed.len() as u64;
                }
                self.stats.stores += 1;
            }
            Op::Mov { ty, dst, src } => {
                let v = self.term_of(flow, src, ty.bits().max(8), ty.is_signed());
                self.write(flow, dst, v, guard);
            }
            Op::Cvta { dst, src, .. } => {
                // address-space cast is the identity on the byte address
                let v = self.term_of(flow, src, 64, false);
                self.write(flow, dst, v, guard);
            }
            Op::IntBin { op: bop, ty, dst, a, b } => {
                let w = ty.bits().max(1);
                let signed = ty.is_signed();
                let ta = self.term_of(flow, a, w, signed);
                let v = match bop {
                    IntBinOp::MulWide => {
                        let tb = self.term_of(flow, b, w, signed);
                        let (wa, wb) = if signed {
                            (self.pool.sext(ta, w * 2), self.pool.sext(tb, w * 2))
                        } else {
                            (self.pool.zext(ta, w * 2), self.pool.zext(tb, w * 2))
                        };
                        self.pool.bin(BvOp::Mul, wa, wb)
                    }
                    _ => {
                        let tb = self.term_of(flow, b, w, signed);
                        let bv = match bop {
                            IntBinOp::Add => BvOp::Add,
                            IntBinOp::Sub => BvOp::Sub,
                            IntBinOp::MulLo => BvOp::Mul,
                            IntBinOp::MulHi => {
                                // (ext(a)*ext(b)) >> w
                                let (wa, wb) = if signed {
                                    (self.pool.sext(ta, w * 2), self.pool.sext(tb, w * 2))
                                } else {
                                    (self.pool.zext(ta, w * 2), self.pool.zext(tb, w * 2))
                                };
                                let m = self.pool.bin(BvOp::Mul, wa, wb);
                                let sh = self.pool.constant(w as u64, w * 2);
                                let hi = self.pool.bin(BvOp::LShr, m, sh);
                                let v = self.pool.trunc(hi, w);
                                self.write(flow, dst, v, guard);
                                return;
                            }
                            IntBinOp::Div => {
                                if signed {
                                    BvOp::SDiv
                                } else {
                                    BvOp::UDiv
                                }
                            }
                            IntBinOp::Rem => {
                                if signed {
                                    BvOp::SRem
                                } else {
                                    BvOp::URem
                                }
                            }
                            IntBinOp::Min => {
                                if signed {
                                    BvOp::SMin
                                } else {
                                    BvOp::UMin
                                }
                            }
                            IntBinOp::Max => {
                                if signed {
                                    BvOp::SMax
                                } else {
                                    BvOp::UMax
                                }
                            }
                            IntBinOp::And => BvOp::And,
                            IntBinOp::Or => BvOp::Or,
                            IntBinOp::Xor => BvOp::Xor,
                            IntBinOp::Shl => BvOp::Shl,
                            IntBinOp::Shr => {
                                if signed {
                                    BvOp::AShr
                                } else {
                                    BvOp::LShr
                                }
                            }
                            IntBinOp::MulWide => unreachable!(),
                        };
                        self.pool.bin(bv, ta, tb)
                    }
                };
                self.write(flow, dst, v, guard);
            }
            Op::Mad { wide, ty, dst, a, b, c } => {
                let w = ty.bits();
                let signed = ty.is_signed();
                let ta = self.term_of(flow, a, w, signed);
                let tb = self.term_of(flow, b, w, signed);
                let v = if *wide {
                    let tc = self.term_of(flow, c, w * 2, signed);
                    let (wa, wb) = if signed {
                        (self.pool.sext(ta, w * 2), self.pool.sext(tb, w * 2))
                    } else {
                        (self.pool.zext(ta, w * 2), self.pool.zext(tb, w * 2))
                    };
                    let m = self.pool.bin(BvOp::Mul, wa, wb);
                    self.pool.bin(BvOp::Add, m, tc)
                } else {
                    let tc = self.term_of(flow, c, w, signed);
                    let m = self.pool.bin(BvOp::Mul, ta, tb);
                    self.pool.bin(BvOp::Add, m, tc)
                };
                self.write(flow, dst, v, guard);
            }
            Op::Not { ty, dst, a } => {
                let w = ty.bits().max(1);
                let ta = self.term_of(flow, a, w, false);
                let v = self.pool.not(ta);
                self.write(flow, dst, v, guard);
            }
            Op::Neg { ty, dst, a } => {
                let w = ty.bits();
                let ta = self.term_of(flow, a, w, ty.is_signed());
                let z = self.pool.constant(0, w);
                let v = self.pool.bin(BvOp::Sub, z, ta);
                self.write(flow, dst, v, guard);
            }
            Op::FltBin { op: fop, ty, dst, a, b } => {
                let w = ty.bits();
                let ta = self.term_of(flow, a, w, false);
                let tb = self.term_of(flow, b, w, false);
                let name = format!("f{}.{}", fop.mnemonic().replace('.', "_"), ty.suffix());
                let v = self.pool.uf(&name, vec![ta, tb], w);
                self.write(flow, dst, v, guard);
            }
            Op::Fma { ty, dst, a, b, c } => {
                let w = ty.bits();
                let ta = self.term_of(flow, a, w, false);
                let tb = self.term_of(flow, b, w, false);
                let tc = self.term_of(flow, c, w, false);
                let name = format!("ffma.{}", ty.suffix());
                let v = self.pool.uf(&name, vec![ta, tb, tc], w);
                self.write(flow, dst, v, guard);
            }
            Op::FltUn { op: fop, ty, dst, a } => {
                let w = ty.bits();
                let ta = self.term_of(flow, a, w, false);
                let name = format!("f{}.{}", fop.mnemonic().replace('.', "_"), ty.suffix());
                let v = self.pool.uf(&name, vec![ta], w);
                self.write(flow, dst, v, guard);
            }
            Op::Setp { cmp, ty, dst, a, b } => {
                let w = ty.bits();
                let v = if ty.is_float() {
                    let ta = self.term_of(flow, a, w, false);
                    let tb = self.term_of(flow, b, w, false);
                    let name = format!("fcmp.{}.{}", cmp.mnemonic(), ty.suffix());
                    self.pool.uf(&name, vec![ta, tb], 1)
                } else {
                    let signed = !matches!(ty, Type::U8 | Type::U16 | Type::U32 | Type::U64);
                    let ta = self.term_of(flow, a, w, signed);
                    let tb = self.term_of(flow, b, w, signed);
                    let kind = match (cmp, signed) {
                        (CmpOp::Eq, _) => CmpKind::Eq,
                        (CmpOp::Ne, _) => CmpKind::Ne,
                        (CmpOp::Lt, true) => CmpKind::Slt,
                        (CmpOp::Le, true) => CmpKind::Sle,
                        (CmpOp::Gt, true) => CmpKind::Sgt,
                        (CmpOp::Ge, true) => CmpKind::Sge,
                        (CmpOp::Lt, false) => CmpKind::Ult,
                        (CmpOp::Le, false) => CmpKind::Ule,
                        (CmpOp::Gt, false) => CmpKind::Ugt,
                        (CmpOp::Ge, false) => CmpKind::Uge,
                    };
                    self.pool.cmp(kind, ta, tb)
                };
                self.write(flow, dst, v, guard);
            }
            Op::Selp { ty, dst, a, b, p } => {
                let w = ty.bits();
                let ta = self.term_of(flow, a, w, ty.is_signed());
                let tb = self.term_of(flow, b, w, ty.is_signed());
                let tp = self.term_of(flow, p, 1, false);
                let v = self.pool.ite(tp, ta, tb);
                self.write(flow, dst, v, guard);
            }
            Op::Cvt { dty, sty, dst, src } => {
                let sw = sty.bits();
                let dw = dty.bits();
                let ts = self.term_of(flow, src, sw, sty.is_signed());
                let v = if dty.is_float() || sty.is_float() {
                    let name = format!("cvt.{}.{}", dty.suffix(), sty.suffix());
                    self.pool.uf(&name, vec![ts], dw)
                } else if dw == sw {
                    ts
                } else if dw < sw {
                    self.pool.trunc(ts, dw)
                } else if sty.is_signed() {
                    self.pool.sext(ts, dw)
                } else {
                    self.pool.zext(ts, dw)
                };
                self.write(flow, dst, v, guard);
            }
            Op::Shfl {
                mode,
                dst,
                pred_out,
                src,
                b,
                c,
                mask,
            } => {
                let ts = self.term_of(flow, src, 32, false);
                let tb = self.term_of(flow, b, 32, false);
                let tc = self.term_of(flow, c, 32, false);
                let tm = self.term_of(flow, mask, 32, false);
                let tid = self.tid_sym;
                let tid32 = self.coerce(tid, 32, false);
                let name = format!("shfl.{}", mode.suffix());
                let v = self
                    .pool
                    .uf(&name, vec![ts, tb, tc, tm, tid32], 32);
                self.write(flow, dst, v, guard);
                if let Some(p) = pred_out {
                    let pname = format!("shfl.{}.p", mode.suffix());
                    let pv = self.pool.uf(&pname, vec![tb, tc, tm, tid32], 1);
                    self.write(flow, p, pv, guard);
                }
            }
            Op::Activemask { dst } => {
                let v = self.pool.symbol("activemask", 32);
                self.write(flow, dst, v, guard);
            }
            Op::BarSync { .. } => {
                // phase boundary: loads/stores on the two sides must never
                // be paired by the detector. A symbolically-guarded barrier
                // still bumps the phase — splitting a legal pair is safe,
                // merging across a possible barrier is not.
                self.stats.barriers += 1;
                flow.phase += 1;
            }
            Op::Bra { .. } | Op::Ret | Op::Exit => {
                unreachable!("control flow handled by the driver")
            }
        }
    }
}
