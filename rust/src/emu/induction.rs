//! Loop structure + induction-variable recognition (paper §4.2).
//!
//! Before emulation we index the kernel: label → statement position, and
//! for every backward branch the loop region `[header, back_edge]`. At loop
//! entry the emulator abstracts each register written inside the region:
//! recognized induction variables `r += step` become
//! `init + step · loopᵢ()` (the "clip the initial value out and add it"
//! trick), everything else becomes an opaque per-loop uninterpreted value.

use super::env::RegInterner;
use crate::ptx::ast::{Kernel, Op, Operand, Statement};
use std::collections::HashMap;

/// How a loop-variant register is abstracted at loop entry.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Abstraction {
    /// `value = init + step * k` where `k` is the loop's iteration UF.
    Induction { step: i128 },
    /// `r += inv` with a loop-invariant register step (NVHPC strip-mine
    /// loops step by `%ntid.x`): `value = init + k`, the UF absorbing the
    /// unknown-but-constant stride. Keeps the thread-id term of the initial
    /// value alive in derived addresses — the paper's "clip the initial
    /// values out and add them" trick.
    InductionSym,
    /// `value = fresh UF` (unknown recurrence).
    Opaque,
}

/// One natural loop discovered from a backward branch.
#[derive(Debug, Clone)]
pub struct LoopInfo {
    /// Statement index of the header label.
    pub header: usize,
    /// Statement index of the backward `bra`.
    pub back_edge: usize,
    /// Registers written inside `[header, back_edge]` and their abstraction.
    pub variants: Vec<(u32, Abstraction)>,
}

/// Static index of a kernel: labels, loops, statement count.
#[derive(Debug, Default)]
pub struct KernelIndex {
    pub labels: HashMap<String, usize>,
    /// Keyed by header statement index.
    pub loops: HashMap<usize, LoopInfo>,
}

/// The destination register (if any) written by an instruction.
pub fn written_reg(op: &Op) -> Option<&crate::ptx::ast::Reg> {
    match op {
        Op::Ld { dst, .. }
        | Op::Mov { dst, .. }
        | Op::Cvta { dst, .. }
        | Op::IntBin { dst, .. }
        | Op::Mad { dst, .. }
        | Op::Not { dst, .. }
        | Op::Neg { dst, .. }
        | Op::FltBin { dst, .. }
        | Op::Fma { dst, .. }
        | Op::FltUn { dst, .. }
        | Op::Setp { dst, .. }
        | Op::Selp { dst, .. }
        | Op::Cvt { dst, .. }
        | Op::Shfl { dst, .. }
        | Op::Activemask { dst } => Some(dst),
        Op::St { .. } | Op::Bra { .. } | Op::BarSync { .. } | Op::Ret | Op::Exit => None,
    }
}

impl KernelIndex {
    pub fn build(k: &Kernel, regs: &mut RegInterner) -> KernelIndex {
        let mut labels = HashMap::new();
        for (i, st) in k.body.iter().enumerate() {
            if let Statement::Label(l) = st {
                labels.insert(l.clone(), i);
            }
        }

        let mut loops: HashMap<usize, LoopInfo> = HashMap::new();
        for (i, st) in k.body.iter().enumerate() {
            let Statement::Instr {
                op: Op::Bra { target, .. },
                ..
            } = st
            else {
                continue;
            };
            let Some(&h) = labels.get(target) else {
                continue;
            };
            if h > i {
                continue; // forward branch
            }
            let entry = loops.entry(h).or_insert(LoopInfo {
                header: h,
                back_edge: i,
                variants: Vec::new(),
            });
            entry.back_edge = entry.back_edge.max(i);
        }

        // classify loop-variant registers per loop
        let headers: Vec<usize> = loops.keys().copied().collect();
        for h in headers {
            let back = loops[&h].back_edge;
            // first pass: which registers are written in the region at all
            let mut write_counts: HashMap<u32, u32> = HashMap::new();
            for st in &k.body[h..=back] {
                if let Statement::Instr { op, .. } = st {
                    if let Some(r) = written_reg(op) {
                        *write_counts.entry(regs.intern(r)).or_insert(0) += 1;
                    }
                }
            }
            // second pass: classify (loop-invariant = not written in region)
            let mut variants: HashMap<u32, Abstraction> = HashMap::new();
            for st in &k.body[h..=back] {
                let Statement::Instr { op, .. } = st else {
                    continue;
                };
                let Some(r) = written_reg(op) else { continue };
                let id = regs.intern(r);
                let abs = if write_counts[&id] > 1 {
                    Abstraction::Opaque
                } else {
                    match op {
                        // r = r ± c
                        Op::IntBin {
                            op: bop,
                            dst,
                            a: Operand::Reg(ra),
                            b: Operand::ImmInt(c),
                            ..
                        } if ra == dst => match bop {
                            crate::ptx::ast::IntBinOp::Add => {
                                Abstraction::Induction { step: *c }
                            }
                            crate::ptx::ast::IntBinOp::Sub => {
                                Abstraction::Induction { step: -*c }
                            }
                            _ => Abstraction::Opaque,
                        },
                        // r = r + inv (loop-invariant register step)
                        Op::IntBin {
                            op: crate::ptx::ast::IntBinOp::Add,
                            dst,
                            a: Operand::Reg(ra),
                            b: Operand::Reg(rb),
                            ..
                        } if ra == dst
                            && write_counts
                                .get(&regs.intern(rb))
                                .copied()
                                .unwrap_or(0)
                                == 0 =>
                        {
                            Abstraction::InductionSym
                        }
                        _ => Abstraction::Opaque,
                    }
                };
                variants.insert(id, abs);
            }
            let mut v: Vec<(u32, Abstraction)> = variants.into_iter().collect();
            v.sort_by_key(|&(id, _)| id);
            loops.get_mut(&h).unwrap().variants = v;
        }

        KernelIndex { labels, loops }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ptx::parser::parse_kernel;

    const LOOP_KERNEL: &str = r#"
.visible .entry k(.param .u64 a, .param .u64 n){
.reg .b32 %r<6>; .reg .b64 %rd<4>; .reg .pred %p<2>; .reg .f32 %f<3>;
ld.param.u64 %rd1, [a];
cvta.to.global.u64 %rd2, %rd1;
mov.u32 %r1, 0;
mov.f32 %f1, 0f00000000;
$LOOP:
mul.wide.s32 %rd3, %r1, 4;
add.s64 %rd3, %rd2, %rd3;
ld.global.f32 %f2, [%rd3];
add.f32 %f1, %f1, %f2;
add.s32 %r1, %r1, 1;
setp.lt.s32 %p1, %r1, 128;
@%p1 bra $LOOP;
st.global.f32 [%rd2], %f1;
ret;
}
"#;

    #[test]
    fn finds_loop_and_induction() {
        let k = parse_kernel(LOOP_KERNEL).unwrap();
        let mut regs = RegInterner::from_kernel(&k);
        let idx = KernelIndex::build(&k, &mut regs);
        assert_eq!(idx.loops.len(), 1);
        let (&h, info) = idx.loops.iter().next().unwrap();
        assert_eq!(h, idx.labels["$LOOP"]);
        assert!(info.back_edge > h);

        let r1 = regs.get(&crate::ptx::ast::Reg::new("%r1")).unwrap();
        let f1 = regs.get(&crate::ptx::ast::Reg::new("%f1")).unwrap();
        let rd3 = regs.get(&crate::ptx::ast::Reg::new("%rd3")).unwrap();
        let find = |id: u32| info.variants.iter().find(|&&(v, _)| v == id).map(|&(_, a)| a);
        assert_eq!(find(r1), Some(Abstraction::Induction { step: 1 }));
        assert_eq!(find(f1), Some(Abstraction::Opaque)); // float accumulator
        assert_eq!(find(rd3), Some(Abstraction::Opaque)); // written twice
    }

    #[test]
    fn forward_branches_make_no_loops() {
        let k = parse_kernel(
            r#"
.visible .entry k(.param .u64 a){
.reg .pred %p<2>; .reg .b32 %r<3>;
setp.lt.s32 %p1, %r1, 0;
@%p1 bra $SKIP;
mov.u32 %r2, 1;
$SKIP: ret;
}
"#,
        )
        .unwrap();
        let mut regs = RegInterner::from_kernel(&k);
        let idx = KernelIndex::build(&k, &mut regs);
        assert!(idx.loops.is_empty());
        assert_eq!(idx.labels.len(), 1);
    }

    #[test]
    fn decrementing_loop_negative_step() {
        let k = parse_kernel(
            r#"
.visible .entry k(.param .u64 a){
.reg .b32 %r<3>; .reg .pred %p<2>;
mov.u32 %r1, 128;
$L:
sub.s32 %r1, %r1, 2;
setp.gt.s32 %p1, %r1, 0;
@%p1 bra $L;
ret;
}
"#,
        )
        .unwrap();
        let mut regs = RegInterner::from_kernel(&k);
        let idx = KernelIndex::build(&k, &mut regs);
        let info = idx.loops.values().next().unwrap();
        let r1 = regs.get(&crate::ptx::ast::Reg::new("%r1")).unwrap();
        let abs = info.variants.iter().find(|&&(v, _)| v == r1).unwrap().1;
        assert_eq!(abs, Abstraction::Induction { step: -2 });
    }
}
