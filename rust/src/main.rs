//! `ptxasw` — wrapper of the PTX optimizing assembler (CC '23 reproduction).
//!
//! Subcommands:
//!   asm <in.ptx> [--out FILE]  assembler-wrapper mode: read PTX, synthesize
//!                              shuffles, print the rewritten PTX (the
//!                              paper's drop-in `ptxas` hook)
//!   suite [names...]           run the KernelGen pipeline → Table 2 + Fig 2/3
//!   apps                       §8.5 application kernels (|N| ≤ 1)
//!   serve                      long-running JSON-lines analysis daemon on a
//!                              persistent pipeline (stdin or --socket)
//!   metrics [--json]           the unified metrics registry (counters +
//!                              latency histograms) as a table or JSON
//!   store                      inspect / verify / heal the on-disk artifact
//!                              store shared by every mode
//!   artifacts [--run name]     list or execute AOT artifacts via PJRT
//!   help
//!
//! Every analysis path goes through the staged pass manager
//! (`ptxasw::pipeline`); `--stats` prints its cache hit rates and
//! per-stage wall time.

use ptxasw::cli::Args;
use ptxasw::coordinator::{report, run_suite_on, PipelineConfig};
use ptxasw::obs::Tracer;
use ptxasw::perf::by_name as arch_by_name;
use ptxasw::pipeline::{DiskStore, Pipeline, ServeOpts, ServeSession};
use ptxasw::ptx::{parse, print_module};
use ptxasw::shuffle::{DetectOpts, ElimOpts, ElimReport, Variant};
use ptxasw::suite;
use std::path::PathBuf;
use std::sync::Arc;

const HELP: &str = "\
ptxasw — symbolic emulator + shuffle synthesis for NVIDIA PTX

USAGE:
  ptxasw asm <in.ptx> [--out FILE] [--variant full|noload|nocorner|uniform]
             [--max-delta N] [--block N] [--no-elim] [--report] [--stats]
             [--trace-out FILE] [cache flags]
  ptxasw suite [bench...] [--shared] [--arch NAME] [--threads N]
             [--sim-threads N] [--max-delta N] [--no-elim] [--fig3 bench]
             [--stats] [--trace-out FILE] [cache flags]
  ptxasw apps [--threads N] [--sim-threads N] [--stats] [--trace-out FILE]
             [cache flags]
  ptxasw serve [--socket PATH] [--serve-threads N] [--deadline-ms N]
             [--sim-threads N] [--trace-sample N] [--test-faults] [--stats]
             [--trace-out FILE] [cache flags]
  ptxasw metrics [--json] [cache flags]
  ptxasw store [--verify] [--heal] [cache flags]
  ptxasw artifacts [--dir DIR] [--run NAME]
  ptxasw help

  --block N         asm: launched blockDim.x the elimination pass may
                    assume (default 32). The pass itself only fires for a
                    single warp (1..=32) — a larger N makes it bail with
                    an explicit per-kernel reason under --report, because
                    its store→load forwarding is warp-synchronous
  serve flags:
  --socket PATH     listen on a Unix socket instead of stdin/stdout; each
                    connection gets its own worker session (own pipelines,
                    own trace session id) over the shared disk store, so
                    clients are served concurrently
  --serve-threads N stdin batch mode: fan the request batch across N
                    worker sessions on a work-stealing queue. Responses
                    come back in input order and healthy output is
                    byte-identical to a serial run (default 1)
  --deadline-ms N   default per-request deadline (a request's own
                    `deadline_ms` field overrides it; 0 = immediate
                    timeout, used by the tests)
  --trace-sample N  record spans for every Nth request into the session
                    ring (exported via --trace-out) without attaching
                    them to responses; 0 = off
  --test-faults     honor the `__panic` test command so the per-request
                    isolation path can be exercised end-to-end
  store flags:
  --verify          decode every artifact on disk; exit nonzero if any
                    entry is corrupt (unless --heal removes it)
  --heal            with --verify: delete undecodable artifacts (they are
                    recomputed on demand — never served)

  --stats           print pipeline cache hit rates (memory + disk),
                    per-stage wall time and the unified metrics table
  --trace-out FILE  record structured spans for every pipeline stage, cache
                    event, store op and elimination verdict, and write them
                    as Chrome trace-event JSON (open in ui.perfetto.dev);
                    tracing never changes results. In serve mode a request
                    can instead ask per-request with `\"trace\": true`
  --json            metrics: print the versioned MetricsSnapshot as JSON
                    instead of the human table
  --shared          suite: also run the shared-memory/barrier benchmark
                    family (tiledreduce, sharedstencil) — kernels that
                    stage data through .shared and synchronize warps with
                    bar.sync on the cooperative scheduler; both are also
                    addressable by name
  --no-elim         skip the phase-liveness elimination pass that deletes
                    dead .shared staging stores and elides bar.syncs the
                    synthesized shuffles made redundant (the pass is on by
                    default and proves every rewrite per-lane; --report
                    explains each store/barrier verdict)
  --sim-threads N   worker threads inside each simulation (blocks of the
                    grid run in parallel; results are bit-identical for
                    any N). Default 1 — the suite already parallelizes
                    across benchmarks with --threads
  --detect-races    opt-in diagnostic: run every simulation on the serial
                    engine with a load-side shadow and fail hard when a
                    block reads global bytes an earlier block wrote
                    (cross-block read-after-write is scheduling-dependent
                    on real hardware). Diagnostic runs are never cached
                    on disk
  --engine E        decoded-engine execution paths: fused (default; superblock
                    fast path + lane-vectorized kernels), superblock,
                    vector, or scalar (per-uop per-lane). Results are
                    bit-identical for every choice; `vector` takes effect
                    only in builds with the `simd` cargo feature
  cache flags:
  --cache-dir DIR   persist pipeline artifacts under DIR (default:
                    $RUST_PALLAS_CACHE_DIR, else ~/.cache/rust_pallas);
                    warm re-runs skip emulation, decoding and simulation
  --no-disk-cache   in-memory caching only (no files written)
";

/// Build the session pipeline, attaching the on-disk artifact store
/// unless `--no-disk-cache` is given. A missing default cache location is
/// not an error (the disk layer is an accelerator, not a dependency); an
/// explicit `--cache-dir` that cannot be opened is.
fn engine_of(s: Option<&str>) -> Result<(bool, bool), String> {
    Ok(match s.unwrap_or("fused") {
        "fused" | "both" => (true, true),
        "superblock" => (true, false),
        "vector" => (false, true),
        "scalar" => (false, false),
        other => return Err(format!(
            "unknown engine `{other}` (expected fused|superblock|vector|scalar)"
        )),
    })
}

fn open_store(args: &Args, tracer: &Arc<Tracer>) -> Result<Option<Arc<DiskStore>>, String> {
    if args.flag("no-disk-cache") {
        return Ok(None);
    }
    let explicit = args.opt("cache-dir").map(PathBuf::from);
    let dir = match explicit.clone().or_else(ptxasw::pipeline::default_dir) {
        Some(d) => d,
        None => return Ok(None),
    };
    match DiskStore::open_default(&dir) {
        Ok(mut store) => {
            store.set_tracer(tracer.clone());
            Ok(Some(Arc::new(store)))
        }
        Err(e) if explicit.is_some() => Err(format!("--cache-dir {}: {e}", dir.display())),
        Err(e) => {
            eprintln!(
                "warning: disk cache disabled ({}: {e})",
                dir.display()
            );
            Ok(None)
        }
    }
}

/// The session span tracer: recording iff `--trace-out FILE` was given
/// (the Chrome-format export is written there when the command finishes).
fn make_tracer(args: &Args) -> Arc<Tracer> {
    Arc::new(if args.opt("trace-out").is_some() {
        Tracer::enabled()
    } else {
        Tracer::disabled()
    })
}

/// Export the recorded spans as a Chrome trace-event file (`--trace-out`).
fn write_trace(args: &Args, tracer: &Tracer) -> Result<(), String> {
    let Some(path) = args.opt("trace-out") else {
        return Ok(());
    };
    std::fs::write(path, tracer.export_chrome().render())
        .map_err(|e| format!("--trace-out {path}: {e}"))?;
    eprintln!(
        "ptxasw: wrote {} trace event(s) to {path} (open in ui.perfetto.dev)",
        tracer.len()
    );
    Ok(())
}

fn build_pipeline(args: &Args, tracer: &Arc<Tracer>) -> Result<Pipeline, String> {
    let (superblocks, vector) = engine_of(args.opt("engine"))?;
    let p = Pipeline::new()
        .with_sim_threads(args.opt_usize("sim-threads", 1)?)
        .with_detect_races(args.flag("detect-races"))
        .with_engine(superblocks, vector)
        .with_tracer(tracer.clone());
    match open_store(args, tracer)? {
        Some(store) => Ok(p.with_disk_shared(store)),
        None => Ok(p),
    }
}

fn main() {
    let args = match Args::parse(std::env::args().skip(1)) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n{HELP}");
            std::process::exit(2);
        }
    };
    let code = match args.command.as_str() {
        "asm" => cmd_asm(&args),
        "suite" => cmd_suite(&args),
        "apps" => cmd_apps(&args),
        "serve" => cmd_serve(&args),
        "metrics" => cmd_metrics(&args),
        "store" => cmd_store(&args),
        "artifacts" => cmd_artifacts(&args),
        "" | "help" => {
            println!("{HELP}");
            Ok(())
        }
        other => Err(format!("unknown command `{other}`\n{HELP}")),
    }
    .map(|_| 0)
    .unwrap_or_else(|e| {
        eprintln!("error: {e}");
        1
    });
    std::process::exit(code);
}

fn variant_of(s: Option<&str>) -> Result<Variant, String> {
    Ok(match s.unwrap_or("full") {
        "full" => Variant::Full,
        "noload" => Variant::NoLoad,
        "nocorner" => Variant::NoCorner,
        "uniform" => Variant::UniformBranch,
        other => return Err(format!("unknown variant `{other}`")),
    })
}

/// Render one kernel's elimination verdicts for `--report`.
fn print_elim_report(name: &str, r: &ElimReport) {
    if let Some(reason) = &r.bail {
        eprintln!("{name}: elim: skipped ({reason})");
        return;
    }
    eprintln!(
        "{name}: elim: {} of {} .shared store(s) deleted, {} of {} bar.sync(s) \
         elided, {} load(s) forwarded, {} dead stmt(s) swept",
        r.deleted_stores(),
        r.stores.len(),
        r.elided_barriers(),
        r.barriers.len(),
        r.forwarded_loads,
        r.dce_stmts,
    );
    for s in &r.stores {
        let verdict = if s.deleted { "deleted" } else { "kept" };
        eprintln!("{name}:   store @{}: {verdict} — {}", s.stmt, s.reason);
    }
    for b in &r.barriers {
        let verdict = if b.elided { "elided" } else { "kept" };
        eprintln!("{name}:   bar.sync @{}: {verdict} — {}", b.stmt, b.reason);
    }
}

fn cmd_asm(args: &Args) -> Result<(), String> {
    let input = args
        .positional
        .first()
        .ok_or("asm: missing input file")?;
    let src = std::fs::read_to_string(input).map_err(|e| format!("{input}: {e}"))?;
    let mut module = parse(&src).map_err(|e| e.to_string())?;
    let variant = variant_of(args.opt("variant"))?;
    let opts = DetectOpts {
        max_abs_delta: args.opt_usize("max-delta", 31)? as i64,
        ..DetectOpts::default()
    };
    // asm mode has no launch config; --block N states the caller's
    // blockDim.x assumption explicitly (default: the pass's own
    // single-warp domain). The pass only fires for 1..=32 — a wider
    // block makes it bail per-kernel with an explicit reason (visible
    // under --report) because its forwarding is warp-synchronous.
    let block = args.opt_usize("block", 32)?;
    if block == 0 || block > 1024 {
        return Err(format!("--block {block}: out of range (1..=1024)"));
    }
    let elim = ElimOpts {
        enabled: !args.flag("no-elim"),
        block: block as u32,
    };

    let tracer = make_tracer(args);
    let p = build_pipeline(args, &tracer)?;
    let mut total = 0;
    for k in module.kernels.iter_mut() {
        // identical kernels in one module share emulation via the cache
        let parsed = p.intake(k.clone());
        let det = p
            .detected_hashed(&parsed.kernel, parsed.hash, opts)
            .map_err(|e| format!("{}: {e}", k.name))?;
        if args.flag("report") {
            let (flows, steps) = det
                .detection
                .emu_stats
                .map(|s| (s.flows_finished, s.steps))
                .unwrap_or((0, 0));
            eprintln!(
                "{}: {} shuffles over {} global loads (avg delta {:?}; {} flows, {} steps)",
                k.name,
                det.detection.shuffle_count(),
                det.detection.total_global_loads,
                det.detection.avg_delta(),
                flows,
                steps,
            );
        }
        total += det.detection.shuffle_count();
        let synth = p
            .synthesized_hashed(&parsed.kernel, parsed.hash, opts, variant, elim)
            .map_err(|e| format!("{}: {e}", k.name))?;
        if args.flag("report") {
            print_elim_report(&k.name, &synth.elim);
        }
        *k = (*synth.kernel).clone();
    }
    let text = print_module(&module);
    match args.opt("out") {
        Some(path) => std::fs::write(path, text).map_err(|e| e.to_string())?,
        None => print!("{text}"),
    }
    eprintln!("ptxasw: synthesized {total} shuffle(s) [{}]", variant.name());
    if args.flag("stats") {
        eprintln!("{}", report::pipeline_stats(&p.stats()));
    }
    write_trace(args, &tracer)?;
    Ok(())
}

fn cmd_suite(args: &Args) -> Result<(), String> {
    let base = PipelineConfig::default();
    let archs = match args.opt("arch") {
        Some(a) => vec![arch_by_name(a).ok_or(format!("unknown arch `{a}`"))?],
        None => base.archs.clone(),
    };
    let cfg = PipelineConfig {
        threads: args.opt_usize("threads", base.threads)?,
        detect: DetectOpts {
            max_abs_delta: args.opt_usize("max-delta", 31)? as i64,
            ..base.detect
        },
        archs,
        elim: !args.flag("no-elim"),
        ..base
    };
    let mut benches: Vec<_> = if args.positional.is_empty() {
        suite::suite()
    } else {
        args.positional
            .iter()
            .map(|n| suite::by_name(n).ok_or(format!("unknown benchmark `{n}`")))
            .collect::<Result<_, _>>()?
    };
    if args.flag("shared") {
        // append only benchmarks not already named positionally
        for b in suite::shared_suite() {
            if !benches.iter().any(|x| x.name == b.name) {
                benches.push(b);
            }
        }
    }
    let tracer = make_tracer(args);
    let p = build_pipeline(args, &tracer)?;
    let results = run_suite_on(&p, &benches, &cfg);
    let ok: Vec<_> = results
        .iter()
        .map(|r| r.as_ref().map_err(|e| e.to_string()))
        .collect::<Result<_, _>>()?;

    println!("{}", report::table2(&ok));
    println!("{}", report::figure2(&ok, &cfg.archs, &cfg.variants));
    if let Some(name) = args.opt("fig3") {
        let r = ok
            .iter()
            .find(|r| r.name == name)
            .ok_or(format!("`{name}` not among the results"))?;
        println!("{}", report::figure3(r, &cfg.archs));
    }
    if args.flag("stats") {
        println!("{}", report::pipeline_stats(&p.stats()));
    }
    write_trace(args, &tracer)?;
    Ok(())
}

fn cmd_apps(args: &Args) -> Result<(), String> {
    let base = PipelineConfig::default();
    let cfg = PipelineConfig {
        detect: DetectOpts {
            max_abs_delta: 1, // §8.5 restriction
            ..base.detect
        },
        archs: vec![arch_by_name("Pascal").unwrap()],
        threads: args.opt_usize("threads", base.threads)?,
        ..base
    };
    let benches = suite::apps();
    let tracer = make_tracer(args);
    let p = build_pipeline(args, &tracer)?;
    let results = run_suite_on(&p, &benches, &cfg);
    let ok: Vec<_> = results
        .iter()
        .map(|r| r.as_ref().map_err(|e| e.to_string()))
        .collect::<Result<_, _>>()?;
    println!("{}", report::table2(&ok));
    println!("{}", report::figure2(&ok, &cfg.archs, &cfg.variants));
    if args.flag("stats") {
        println!("{}", report::pipeline_stats(&p.stats()));
    }
    write_trace(args, &tracer)?;
    Ok(())
}

/// Long-running analysis daemon: JSON-lines over stdin/stdout or a Unix
/// socket, one persistent warm session, per-request fault isolation.
fn cmd_serve(args: &Args) -> Result<(), String> {
    let (superblocks, vector) = engine_of(args.opt("engine"))?;
    let opts = ServeOpts {
        deadline_ms: match args.opt("deadline-ms") {
            None => None,
            Some(_) => Some(args.opt_usize("deadline-ms", 0)? as u64),
        },
        allow_test_faults: args.flag("test-faults"),
        sim_threads: args.opt_usize("sim-threads", 1)?,
        engine: (superblocks, vector),
        serve_threads: args.opt_usize("serve-threads", 1)?,
        trace_sample: args.opt_usize("trace-sample", 0)? as u64,
        ..ServeOpts::default()
    };
    let tracer = make_tracer(args);
    let mut session = ServeSession::with_tracer(opts, open_store(args, &tracer)?, tracer.clone());
    match args.opt("socket") {
        #[cfg(unix)]
        Some(path) => {
            ptxasw::pipeline::serve::serve_unix(&mut session, std::path::Path::new(path))
                .map_err(|e| format!("serve: {e}"))?;
        }
        #[cfg(not(unix))]
        Some(_) => return Err("serve: --socket requires a Unix platform".into()),
        None => {
            let stdin = std::io::stdin();
            let stdout = std::io::stdout();
            ptxasw::pipeline::serve_pooled(
                &mut session,
                stdin.lock(),
                stdout.lock(),
                opts.serve_threads,
            )
            .map_err(|e| format!("serve: {e}"))?;
        }
    }
    if args.flag("stats") {
        eprintln!("{}", report::pipeline_stats(&session.pipeline().stats()));
    }
    write_trace(args, &tracer)?;
    Ok(())
}

/// Print the unified metrics snapshot for a fresh session pipeline. Run
/// counters start at zero here — the live signal is the disk-store gauges
/// (residency, generation, coordination churn) shared across processes.
fn cmd_metrics(args: &Args) -> Result<(), String> {
    let tracer = make_tracer(args);
    let p = build_pipeline(args, &tracer)?;
    let m = p.metrics();
    if args.flag("json") {
        println!("{}", m.to_json().render());
    } else {
        print!("{}", m.render_table());
    }
    Ok(())
}

/// Inspect / verify / heal the shared on-disk artifact store.
fn cmd_store(args: &Args) -> Result<(), String> {
    let store = open_store(args, &make_tracer(args))?.ok_or(
        "store: no cache directory (give --cache-dir, or set RUST_PALLAS_CACHE_DIR; \
         --no-disk-cache is meaningless here)",
    )?;
    let snap = store.snapshot();
    println!(
        "store: generation {} · resident {} bytes (bound {}) · {} stale tmp file(s) swept",
        snap.generation,
        snap.resident_bytes,
        store.max_bytes(),
        snap.swept_tmp,
    );
    if !args.flag("verify") {
        return Ok(());
    }
    let heal = args.flag("heal");
    let check = store.verify(heal);
    for kc in &check.kinds {
        println!(
            "  {:<12} {:>5} artifact(s) {:>10} bytes  {} bad",
            kc.kind.dir(),
            kc.count,
            kc.bytes,
            kc.bad,
        );
    }
    println!(
        "store: verified {} bytes total · {} bad · {} healed",
        check.total_bytes, check.bad, check.healed
    );
    // CI gate: the O(changed) sharded index must agree with the ground
    // truth the full directory walk just computed
    if check.index_mismatch.is_empty() {
        println!("store: index agrees with the scan");
    } else {
        for m in &check.index_mismatch {
            eprintln!("store:   index drift: {m}");
        }
    }
    for p in &check.bad_paths {
        eprintln!("store:   bad: {}", p.display());
    }
    if check.bad > 0 && check.healed < check.bad {
        return Err(format!(
            "store: {} undecodable artifact(s) on disk (re-run with --heal to remove)",
            check.bad
        ));
    }
    if !check.index_mismatch.is_empty() {
        return Err(format!(
            "store: sharded index disagrees with the directory scan ({} kind(s))",
            check.index_mismatch.len()
        ));
    }
    Ok(())
}

fn cmd_artifacts(args: &Args) -> Result<(), String> {
    let dir = args.opt("dir").unwrap_or("artifacts");
    let mut rt = ptxasw::runtime::Runtime::open(dir).map_err(|e| e.to_string())?;
    println!("platform: {}", rt.platform());
    if let Some(name) = args.opt("run") {
        let spec = rt
            .spec(name)
            .ok_or(format!("unknown artifact `{name}`"))?
            .clone();
        let mut rng = ptxasw::util::Rng::new(7);
        let inputs: Vec<Vec<f32>> = spec
            .args
            .iter()
            .map(|a| (0..a.elements()).map(|_| rng.f32()).collect())
            .collect();
        let refs: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
        let t0 = std::time::Instant::now();
        let out = rt.run_f32(name, &refs).map_err(|e| e.to_string())?;
        let dt = t0.elapsed();
        let sum: f32 = out.iter().sum();
        println!(
            "{name}: {} inputs -> {} outputs in {dt:?} (checksum {sum:.6})",
            spec.args.len(),
            out.len()
        );
    } else {
        for n in rt.names() {
            let spec = rt.spec(n).unwrap();
            let dims: Vec<String> = spec.args.iter().map(|a| format!("{:?}", a.dims)).collect();
            println!("  {n}: f32 {}", dims.join(" × "));
        }
    }
    Ok(())
}
