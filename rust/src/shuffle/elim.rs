//! Driver for the phase-liveness elimination (`shuffle/phase_liveness.rs`):
//! applies a [`Plan`] to the kernel — rewriting covered `.shared` loads
//! into register forwarding (`mov` / `shfl.sync`), deleting dead staging
//! stores, eliding dead `bar.sync`s — then sweeps the address-computation
//! chains the deleted traffic alone kept alive (a backward dead-code pass
//! over the `shuffle/liveness.rs`-style use/def sets) and prunes `.shared`
//! windows nothing references anymore.
//!
//! Every decision lands in the [`ElimReport`]: one entry per store and per
//! barrier (indices into the *pre-elimination* body), plus rewrite counts,
//! so `--stats` can explain exactly why each was or wasn't removed.

use super::phase_liveness::{
    plan, seg_shape, CoverSeg, CoverSrc, ElimOpts, ElimReport, LoadPlan, Plan, SegShape,
};
use crate::emu::induction::written_reg;
use crate::emu::EmulationResult;
use crate::ptx::ast::{
    Address, CmpOp, IntBinOp, Kernel, Op, Operand, Reg, RegDecl, ShflMode, Statement, Type,
};
use std::collections::HashMap;

/// Run the elimination pass over a synthesized kernel. `source` is the
/// kernel the emulation (`emu`) actually ran — trace statement indices are
/// only meaningful if synthesis left the body unchanged, so any divergence
/// is a clean bail (kernel returned as-is, reason in the report).
pub fn eliminate(
    kernel: &Kernel,
    source: &Kernel,
    emu: &EmulationResult,
    opts: ElimOpts,
) -> (Kernel, ElimReport) {
    if !opts.enabled {
        return (kernel.clone(), ElimReport::disabled());
    }
    if kernel.body != source.body || kernel.shared != source.shared {
        return (
            kernel.clone(),
            ElimReport::bailed(
                "synthesis rewrote the body; trace indices no longer align with it",
            ),
        );
    }
    let p = match plan(kernel, emu, opts) {
        Ok(p) => p,
        Err(reason) => return (kernel.clone(), ElimReport::bailed(reason)),
    };
    let mut report = report_from(&p);
    if p.covered.is_empty() && p.dead_stores.is_empty() && p.elide_bars.is_empty() {
        return (kernel.clone(), report);
    }
    let (mut out, forwarded) = apply(kernel, &p);
    report.forwarded_loads = forwarded;
    report.dce_stmts = sweep_dead_code(&mut out.body);
    prune_shared_decls(&mut out);
    (out, report)
}

/// Emit one trace instant per store / barrier verdict plus a summary,
/// lifted from an [`ElimReport`] (`elim.store` / `elim.barrier` /
/// `elim.summary` in the span taxonomy). `source` is the content hash of
/// the kernel the report is about. A disabled tracer costs one atomic
/// load here.
pub fn trace_report(
    tracer: &crate::obs::Tracer,
    source: crate::ptx::printer::ContentHash,
    r: &ElimReport,
) {
    use crate::obs::ArgVal;
    if !tracer.is_enabled() {
        return;
    }
    for s in &r.stores {
        tracer.instant("elim", "elim.store", || {
            vec![
                ("key", ArgVal::Str(source.to_string())),
                ("stmt", ArgVal::U64(s.stmt as u64)),
                (
                    "verdict",
                    ArgVal::Str(if s.deleted { "deleted" } else { "kept" }.to_string()),
                ),
                ("reason", ArgVal::Str(s.reason.clone())),
            ]
        });
    }
    for b in &r.barriers {
        tracer.instant("elim", "elim.barrier", || {
            vec![
                ("key", ArgVal::Str(source.to_string())),
                ("stmt", ArgVal::U64(b.stmt as u64)),
                (
                    "verdict",
                    ArgVal::Str(if b.elided { "elided" } else { "kept" }.to_string()),
                ),
                ("reason", ArgVal::Str(b.reason.clone())),
            ]
        });
    }
    tracer.instant("elim", "elim.summary", || {
        vec![
            ("key", ArgVal::Str(source.to_string())),
            ("forwarded_loads", ArgVal::U64(u64::from(r.forwarded_loads))),
            ("dce_stmts", ArgVal::U64(u64::from(r.dce_stmts))),
            (
                "bail",
                ArgVal::Str(r.bail.clone().unwrap_or_else(|| "none".to_string())),
            ),
        ]
    });
}

/// Fold the plan's per-store / per-barrier verdicts into the report.
fn report_from(p: &Plan) -> ElimReport {
    use super::phase_liveness::{BarrierElim, StoreElim};
    let mut stores: Vec<StoreElim> = p
        .dead_stores
        .iter()
        .map(|(stmt, reason)| StoreElim {
            stmt: *stmt,
            deleted: true,
            reason: reason.clone(),
        })
        .chain(p.kept_stores.iter().map(|(stmt, reason)| StoreElim {
            stmt: *stmt,
            deleted: false,
            reason: reason.clone(),
        }))
        .collect();
    stores.sort_by_key(|s| s.stmt);
    let mut barriers: Vec<BarrierElim> = p
        .elide_bars
        .iter()
        .map(|(stmt, reason)| BarrierElim {
            stmt: *stmt,
            elided: true,
            reason: reason.clone(),
        })
        .chain(p.kept_bars.iter().map(|(stmt, reason)| BarrierElim {
            stmt: *stmt,
            elided: false,
            reason: reason.clone(),
        }))
        .collect();
    barriers.sort_by_key(|b| b.stmt);
    ElimReport {
        bail: None,
        stores,
        barriers,
        forwarded_loads: 0,
        dce_stmts: 0,
    }
}

/// Fresh-register + statement emission state for the rewrites.
struct Emit {
    tid: Reg,
    need_tid: bool,
    nq: u32, // %zeq — predicates
    nb: u32, // %zeb — b32 temporaries
    nm: u32, // %zem — activemask results
}

impl Emit {
    fn new() -> Emit {
        Emit {
            tid: Reg::new("%zet0"),
            need_tid: false,
            nq: 0,
            nb: 0,
            nm: 0,
        }
    }
    fn pred(&mut self) -> Reg {
        let r = Reg::new(format!("%zeq{}", self.nq));
        self.nq += 1;
        r
    }
    fn b32(&mut self) -> Reg {
        let r = Reg::new(format!("%zeb{}", self.nb));
        self.nb += 1;
        r
    }
    fn mask(&mut self) -> Reg {
        let r = Reg::new(format!("%zem{}", self.nm));
        self.nm += 1;
        r
    }
}

/// `mov` flavour for committing a value of the load's type: float regs
/// move as `.f32`, everything else as raw `.b32` bits.
fn mov_ty(ty: Type) -> Type {
    if ty == Type::F32 {
        Type::F32
    } else {
        Type::B32
    }
}

/// Predicate computation for one reader segment, or `None` when the
/// segment needs no guard / reuses the load's own.
enum SegPred {
    /// Commit unguarded.
    Open,
    /// Commit under `@[!]reg` (the load's own guard, already computed).
    Reuse(Reg, bool),
    /// Commit under a fresh predicate; the given statements compute it.
    Fresh(Reg, Vec<Statement>),
}

fn seg_pred(e: &mut Emit, lp: &LoadPlan, seg: &CoverSeg, block: u32) -> SegPred {
    if seg.readers == lp.exec {
        // one segment spans every executing lane: reuse the load's own
        // guard (an unguarded load's exec is the whole block → no guard)
        return match &lp.guard {
            Some(g) => SegPred::Reuse(g.reg.clone(), g.negated),
            None => SegPred::Open,
        };
    }
    let shape = seg_shape(seg.readers, block).expect("plan only emits encodable segments");
    if shape == SegShape::Full {
        return SegPred::Open;
    }
    e.need_tid = true;
    let q = e.pred();
    let mut setup = Vec::new();
    match shape {
        SegShape::Full => unreachable!(),
        SegShape::Single(k) => setup.push(Statement::instr(Op::Setp {
            cmp: CmpOp::Eq,
            ty: Type::S32,
            dst: q.clone(),
            a: Operand::Reg(e.tid.clone()),
            b: Operand::ImmInt(k as i128),
        })),
        SegShape::Prefix(k) => setup.push(Statement::instr(Op::Setp {
            cmp: CmpOp::Lt,
            ty: Type::S32,
            dst: q.clone(),
            a: Operand::Reg(e.tid.clone()),
            b: Operand::ImmInt(k as i128),
        })),
        SegShape::Suffix(a) => setup.push(Statement::instr(Op::Setp {
            cmp: CmpOp::Ge,
            ty: Type::S32,
            dst: q.clone(),
            a: Operand::Reg(e.tid.clone()),
            b: Operand::ImmInt(a as i128),
        })),
        SegShape::Range(a, b) => {
            // a <= t <= b  ⇔  (t - a) <u (b - a + 1)
            let t = e.b32();
            setup.push(Statement::instr(Op::IntBin {
                op: IntBinOp::Sub,
                ty: Type::S32,
                dst: t.clone(),
                a: Operand::Reg(e.tid.clone()),
                b: Operand::ImmInt(a as i128),
            }));
            setup.push(Statement::instr(Op::Setp {
                cmp: CmpOp::Lt,
                ty: Type::U32,
                dst: q.clone(),
                a: Operand::Reg(t),
                b: Operand::ImmInt((b - a + 1) as i128),
            }));
        }
    }
    SegPred::Fresh(q, setup)
}

/// The `shfl.sync` realizing one cross-lane segment source. Emitted
/// *unguarded*: the simulator requires a shuffle's source lane to execute
/// the instruction, so the shuffle runs on every lane and a guarded `mov`
/// commits the value only where the plan proved it correct.
fn shfl_op(src: &CoverSrc, dst: Reg, mask: Reg, block: u32) -> Op {
    let top = block as i128 - 1;
    let (mode, b, c) = match src {
        CoverSrc::Shift { n, .. } if *n > 0 => (ShflMode::Down, *n as i128, top),
        CoverSrc::Shift { n, .. } => (ShflMode::Up, -*n as i128, 0),
        CoverSrc::Bcast { lane, .. } => (ShflMode::Idx, *lane as i128, top),
        _ => unreachable!("register sources don't shuffle"),
    };
    let reg = match src {
        CoverSrc::Shift { reg, .. } | CoverSrc::Bcast { reg, .. } => reg.clone(),
        _ => unreachable!(),
    };
    Op::Shfl {
        mode,
        dst,
        pred_out: None,
        src: Operand::Reg(reg),
        b: Operand::ImmInt(b),
        c: Operand::ImmInt(c),
        mask: Operand::Reg(mask),
    }
}

/// Statements replacing one covered load.
fn rewrite_load(e: &mut Emit, lp: &LoadPlan, block: u32) -> Vec<Statement> {
    let mut out = Vec::new();
    for seg in &lp.segs {
        let pred = seg_pred(e, lp, seg, block);
        match (&seg.src, pred) {
            // register / immediate sources commit directly
            (CoverSrc::Same(r), p) => {
                let mv = Op::Mov {
                    ty: mov_ty(lp.ty),
                    dst: lp.dst.clone(),
                    src: Operand::Reg(r.clone()),
                };
                push_committed(&mut out, mv, p);
            }
            (CoverSrc::Imm(v), p) => {
                let ty = match v {
                    Operand::ImmF32(_) => Type::F32,
                    _ => Type::B32,
                };
                let mv = Op::Mov {
                    ty,
                    dst: lp.dst.clone(),
                    src: v.clone(),
                };
                push_committed(&mut out, mv, p);
            }
            // cross-lane sources: open segments shuffle straight into the
            // destination (every reader has a proven-valid source lane)
            (src, SegPred::Open) => {
                let m = e.mask();
                out.push(Statement::instr(Op::Activemask { dst: m.clone() }));
                out.push(Statement::instr(shfl_op(src, lp.dst.clone(), m, block)));
            }
            // guarded segments: unguarded shuffle into a temp, then a
            // guarded b32 mov commits it on the reader lanes only
            (src, p) => {
                let m = e.mask();
                out.push(Statement::instr(Op::Activemask { dst: m.clone() }));
                let tmp = e.b32();
                out.push(Statement::instr(shfl_op(src, tmp.clone(), m, block)));
                let mv = Op::Mov {
                    ty: Type::B32,
                    dst: lp.dst.clone(),
                    src: Operand::Reg(tmp),
                };
                push_committed(&mut out, mv, p);
            }
        }
    }
    out
}

fn push_committed(out: &mut Vec<Statement>, op: Op, pred: SegPred) {
    match pred {
        SegPred::Open => out.push(Statement::instr(op)),
        SegPred::Reuse(r, neg) => out.push(Statement::guarded(&r.0, neg, op)),
        SegPred::Fresh(r, setup) => {
            out.extend(setup);
            out.push(Statement::guarded(&r.0, false, op));
        }
    }
}

/// Rebuild the body: covered loads → forwarding sequences, dead stores and
/// elided barriers dropped, everything else copied. Returns the rewritten
/// kernel (with extended register declarations) and the forwarded count.
fn apply(kernel: &Kernel, p: &Plan) -> (Kernel, u32) {
    let mut e = Emit::new();
    let mut repl: HashMap<usize, Vec<Statement>> = HashMap::new();
    for lp in &p.covered {
        repl.insert(lp.stmt, rewrite_load(&mut e, lp, p.block));
    }
    let drop: Vec<usize> = p
        .dead_stores
        .iter()
        .chain(p.elide_bars.iter())
        .map(|(i, _)| *i)
        .collect();

    let mut body = Vec::with_capacity(kernel.body.len() + 8);
    for (i, s) in kernel.body.iter().enumerate() {
        if drop.contains(&i) {
            continue;
        }
        match repl.remove(&i) {
            Some(seq) => body.extend(seq),
            None => body.push(s.clone()),
        }
    }
    if e.need_tid {
        body.insert(
            0,
            Statement::instr(Op::Mov {
                ty: Type::U32,
                dst: e.tid.clone(),
                src: Operand::Special(crate::ptx::ast::Special::TidX),
            }),
        );
    }

    let mut regs = kernel.regs.clone();
    if e.need_tid {
        regs.push(RegDecl {
            ty: Type::B32,
            prefix: "%zet".into(),
            count: 1,
        });
    }
    if e.nq > 0 {
        regs.push(RegDecl {
            ty: Type::Pred,
            prefix: "%zeq".into(),
            count: e.nq,
        });
    }
    if e.nb > 0 {
        regs.push(RegDecl {
            ty: Type::B32,
            prefix: "%zeb".into(),
            count: e.nb,
        });
    }
    if e.nm > 0 {
        regs.push(RegDecl {
            ty: Type::B32,
            prefix: "%zem".into(),
            count: e.nm,
        });
    }

    (
        Kernel {
            name: kernel.name.clone(),
            params: kernel.params.clone(),
            regs,
            shared: kernel.shared.clone(),
            body,
        },
        p.covered.len() as u32,
    )
}

// ---------------------------------------------------------------------------
// Backward dead-code sweep + shared-window pruning
// ---------------------------------------------------------------------------

/// Register names an operand reads.
fn operand_use<'a>(o: &'a Operand, out: &mut Vec<&'a str>) {
    if let Operand::Reg(r) = o {
        out.push(&r.0);
    }
}

/// Every register a statement *reads*: operands, address bases, store
/// sources, guard predicates — and, for a guarded write, the destination
/// itself (inactive lanes keep its old value, so the old value is live).
fn stmt_uses<'a>(s: &'a Statement, out: &mut Vec<&'a str>) {
    let Statement::Instr { guard, op } = s else {
        return;
    };
    if let Some(g) = guard {
        out.push(&g.reg.0);
        if let Some(d) = written_reg(op) {
            out.push(&d.0);
        }
    }
    match op {
        Op::Ld { addr, .. } => operand_use(&addr.base, out),
        Op::St { addr, src, .. } => {
            operand_use(&addr.base, out);
            operand_use(src, out);
        }
        Op::Mov { src, .. } | Op::Cvta { src, .. } | Op::Cvt { src, .. } => {
            operand_use(src, out)
        }
        Op::IntBin { a, b, .. } | Op::FltBin { a, b, .. } | Op::Setp { a, b, .. } => {
            operand_use(a, out);
            operand_use(b, out);
        }
        Op::Mad { a, b, c, .. } | Op::Fma { a, b, c, .. } => {
            operand_use(a, out);
            operand_use(b, out);
            operand_use(c, out);
        }
        Op::Not { a, .. } | Op::Neg { a, .. } | Op::FltUn { a, .. } => operand_use(a, out),
        Op::Selp { a, b, p, .. } => {
            operand_use(a, out);
            operand_use(b, out);
            operand_use(p, out);
        }
        Op::Shfl {
            src, b, c, mask, ..
        } => {
            operand_use(src, out);
            operand_use(b, out);
            operand_use(c, out);
            operand_use(mask, out);
        }
        Op::Bra { .. }
        | Op::Activemask { .. }
        | Op::BarSync { .. }
        | Op::Ret
        | Op::Exit => {}
    }
}

/// Is this statement a pure register definition — removable once nothing
/// reads its destination? Memory writes, barriers and control flow are
/// never removable here (the plan handles stores/barriers itself); loads
/// are pure in this machine model (no faults, no side effects).
fn removable(s: &Statement) -> bool {
    let Statement::Instr { op, .. } = s else {
        return false;
    };
    written_reg(op).is_some()
}

/// Iteratively delete pure definitions whose destination no other
/// statement reads, until a fixpoint. Returns how many went.
fn sweep_dead_code(body: &mut Vec<Statement>) -> u32 {
    let mut removed = 0u32;
    loop {
        // use-count per register name, over the whole body
        let mut uses: HashMap<&str, u32> = HashMap::new();
        let mut names: Vec<&str> = Vec::new();
        for s in body.iter() {
            stmt_uses(s, &mut names);
        }
        for n in &names {
            *uses.entry(n).or_insert(0) += 1;
        }
        // a statement's own reads of its destination (e.g. a guarded
        // write's merge) must not keep it alive
        let mut dead: Vec<usize> = Vec::new();
        let mut own: Vec<&str> = Vec::new();
        for (i, s) in body.iter().enumerate() {
            if !removable(s) {
                continue;
            }
            let Statement::Instr { op, .. } = s else {
                continue;
            };
            let Some(d) = written_reg(op) else {
                continue;
            };
            let total = uses.get(d.0.as_str()).copied().unwrap_or(0);
            own.clear();
            stmt_uses(s, &mut own);
            let self_reads = own.iter().filter(|n| **n == d.0.as_str()).count() as u32;
            if total == self_reads {
                dead.push(i);
            }
        }
        if dead.is_empty() {
            return removed;
        }
        removed += dead.len() as u32;
        let mut i = 0usize;
        body.retain(|_| {
            let keep = !dead.contains(&i);
            i += 1;
            keep
        });
    }
}

/// Drop `.shared` declarations no remaining operand names.
fn prune_shared_decls(k: &mut Kernel) {
    let mut names = Vec::new();
    for s in &k.body {
        let Statement::Instr { op, .. } = s else {
            continue;
        };
        let mut check = |o: &Operand| {
            if let Operand::Var(v) = o {
                names.push(v.clone());
            }
        };
        match op {
            Op::Ld { addr, .. } => check(&addr.base),
            Op::St { addr, src, .. } => {
                check(&addr.base);
                check(src);
            }
            Op::Mov { src, .. } | Op::Cvta { src, .. } | Op::Cvt { src, .. } => check(src),
            Op::IntBin { a, b, .. } | Op::FltBin { a, b, .. } | Op::Setp { a, b, .. } => {
                check(a);
                check(b);
            }
            Op::Mad { a, b, c, .. } | Op::Fma { a, b, c, .. } => {
                check(a);
                check(b);
                check(c);
            }
            Op::Not { a, .. } | Op::Neg { a, .. } | Op::FltUn { a, .. } => check(a),
            Op::Selp { a, b, p, .. } => {
                check(a);
                check(b);
                check(p);
            }
            Op::Shfl {
                src, b, c, mask, ..
            } => {
                check(src);
                check(b);
                check(c);
                check(mask);
            }
            _ => {}
        }
    }
    k.shared.retain(|d| names.iter().any(|n| *n == d.name));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::emu::emulate;
    use crate::ptx::parser::parse;
    use crate::ptx::printer::print_kernel;
    use crate::sim::{run_reference, SimConfig};
    use crate::suite::{by_name, workload, Pattern};

    fn eliminated(name: &str) -> (crate::suite::Workload, Kernel, ElimReport) {
        let b = by_name(name).unwrap();
        let w = workload(&b, 4, 1, 1, 42);
        let emu = emulate(&w.kernel).unwrap();
        let block = match &b.pattern {
            Pattern::TiledReduce { block } => *block,
            Pattern::SharedStencil { block, .. } => *block,
            Pattern::SharedGather { block } => *block,
            _ => panic!("not shared"),
        };
        let (k, r) = eliminate(
            &w.kernel,
            &w.kernel,
            &emu,
            ElimOpts {
                enabled: true,
                block,
            },
        );
        (w, k, r)
    }

    fn count_op(k: &Kernel, f: impl Fn(&Op) -> bool) -> usize {
        k.body
            .iter()
            .filter(|s| matches!(s, Statement::Instr { op, .. } if f(op)))
            .count()
    }

    fn shared_stores(k: &Kernel) -> usize {
        count_op(k, |o| {
            matches!(
                o,
                Op::St {
                    space: crate::ptx::ast::Space::Shared,
                    ..
                }
            )
        })
    }

    fn shared_loads(k: &Kernel) -> usize {
        count_op(k, |o| {
            matches!(
                o,
                Op::Ld {
                    space: crate::ptx::ast::Space::Shared,
                    ..
                }
            )
        })
    }

    fn barriers(k: &Kernel) -> usize {
        count_op(k, |o| matches!(o, Op::BarSync { .. }))
    }

    /// Run both the original and eliminated kernels through the reference
    /// simulator on the same workload; outputs must agree bit-exactly.
    fn validate_bit_exact(w: &crate::suite::Workload, k: &Kernel) {
        let r0 = run_reference(&w.kernel, &w.cfg, w.mem.clone()).expect("original runs");
        let r1 = run_reference(k, &w.cfg, w.mem.clone()).expect("eliminated runs");
        let a = r0.mem.read_f32s(w.out_ptr, w.out_len).unwrap();
        let b = r1.mem.read_f32s(w.out_ptr, w.out_len).unwrap();
        for (i, (x, y)) in a.iter().zip(&b).enumerate() {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "out[{i}] diverged: {x} vs {y}"
            );
        }
        // and both must still match the CPU reference
        for (i, (x, y)) in a.iter().zip(&w.expected).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "out[{i}] vs CPU: {x} vs {y}");
        }
    }

    #[test]
    fn tiledreduce_loses_all_stores_and_barriers() {
        let (w, k, r) = eliminated("tiledreduce");
        assert!(r.bail.is_none(), "{:?}", r.bail);
        assert_eq!(shared_stores(&k), 0);
        assert_eq!(shared_loads(&k), 0);
        assert_eq!(barriers(&k), 0);
        assert!(r.deleted_stores() >= 6, "stores: {:?}", r.stores);
        assert!(r.elided_barriers() >= 5, "barriers: {:?}", r.barriers);
        assert!(r.forwarded_loads >= 11, "forwarded: {}", r.forwarded_loads);
        assert!(r.dce_stmts > 0, "the staging address chain must die");
        assert!(k.shared.is_empty(), "sdata window must be pruned");
        // still a valid, reparsable kernel
        let text =
            crate::ptx::printer::print_module(&crate::ptx::ast::Module::single(k.clone()));
        parse(&text).expect("eliminated kernel reparses");
        validate_bit_exact(&w, &k);
    }

    #[test]
    fn sharedstencil_loses_stores_and_barrier() {
        let (w, k, r) = eliminated("sharedstencil");
        assert!(r.bail.is_none(), "{:?}", r.bail);
        assert_eq!(shared_stores(&k), 0);
        assert_eq!(shared_loads(&k), 0);
        assert_eq!(barriers(&k), 0);
        assert_eq!(r.deleted_stores(), 3);
        assert_eq!(r.elided_barriers(), 1);
        assert_eq!(r.forwarded_loads, 3);
        assert!(k.shared.is_empty());
        // the shifted taps become shuffles
        assert!(count_op(&k, |o| matches!(o, Op::Shfl { .. })) >= 2);
        validate_bit_exact(&w, &k);
    }

    #[test]
    fn sharedgather_keeps_staging_conservatively() {
        let (w, k, r) = eliminated("sharedgather");
        assert!(r.bail.is_none(), "{:?}", r.bail);
        // the data-dependent tap pins the store and the barrier
        assert_eq!(shared_stores(&k), 1);
        assert_eq!(barriers(&k), 1);
        assert_eq!(shared_loads(&k), 1, "only the tid tap forwards");
        assert_eq!(r.deleted_stores(), 0);
        assert_eq!(r.elided_barriers(), 0);
        assert_eq!(r.forwarded_loads, 1);
        assert!(!k.shared.is_empty());
        // the kept store's reason names the blocking load
        let kept = r.stores.iter().find(|s| !s.deleted).unwrap();
        assert!(kept.reason.contains("kept load"), "{}", kept.reason);
        validate_bit_exact(&w, &k);
    }

    #[test]
    fn disabled_pass_changes_nothing() {
        let b = by_name("tiledreduce").unwrap();
        let w = workload(&b, 2, 1, 1, 7);
        let emu = emulate(&w.kernel).unwrap();
        let (k, r) = eliminate(
            &w.kernel,
            &w.kernel,
            &emu,
            ElimOpts {
                enabled: false,
                block: 32,
            },
        );
        assert_eq!(print_kernel(&k), print_kernel(&w.kernel));
        assert!(r.bail.is_some());
        assert!(!r.changed());
    }

    #[test]
    fn synth_divergence_bails_cleanly() {
        let b = by_name("tiledreduce").unwrap();
        let w = workload(&b, 2, 1, 1, 7);
        let emu = emulate(&w.kernel).unwrap();
        let mut other = w.kernel.clone();
        other.body.push(Statement::instr(Op::Ret));
        let (k, r) = eliminate(
            &other,
            &w.kernel,
            &emu,
            ElimOpts {
                enabled: true,
                block: 32,
            },
        );
        assert_eq!(print_kernel(&k), print_kernel(&other));
        assert!(r.bail.as_deref().unwrap_or("").contains("rewrote"), "{:?}", r.bail);
    }

    #[test]
    fn dce_sweeps_chains_but_keeps_partial_writes() {
        let mut body = vec![
            // dead chain: %r1 -> %rd1, nothing reads %rd1
            Statement::instr(Op::Mov {
                ty: Type::U32,
                dst: Reg::new("%r1"),
                src: Operand::ImmInt(3),
            }),
            Statement::instr(Op::IntBin {
                op: IntBinOp::MulWide,
                ty: Type::S32,
                dst: Reg::new("%rd1"),
                a: Operand::Reg(Reg::new("%r1")),
                b: Operand::ImmInt(4),
            }),
            // live: %f1 written unguarded, partially overwritten, stored
            Statement::instr(Op::Mov {
                ty: Type::F32,
                dst: Reg::new("%f1"),
                src: Operand::ImmF32(0),
            }),
            Statement::guarded(
                "%p1",
                false,
                Op::Mov {
                    ty: Type::F32,
                    dst: Reg::new("%f1"),
                    src: Operand::ImmF32(0x3F80_0000),
                },
            ),
            Statement::instr(Op::St {
                space: crate::ptx::ast::Space::Global,
                ty: Type::F32,
                addr: Address {
                    base: Operand::Reg(Reg::new("%rd2")),
                    offset: 0,
                },
                src: Operand::Reg(Reg::new("%f1")),
            }),
            Statement::instr(Op::Ret),
        ];
        let n = sweep_dead_code(&mut body);
        assert_eq!(n, 2, "the %r1/%rd1 chain dies");
        assert_eq!(body.len(), 4);
        // the guarded partial write stays: the store reads %f1
        assert!(body.iter().any(|s| matches!(
            s,
            Statement::Instr {
                guard: Some(_),
                ..
            }
        )));
    }
}
