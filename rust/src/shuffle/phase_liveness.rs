//! Phase-level liveness of `.shared` staging traffic (ROADMAP item 1).
//!
//! Shuffle synthesis substitutes loads; this pass reasons about the other
//! half of the round trip: the `.shared` stores that staged the data and
//! the `bar.sync`s that published it. Over the barrier-segmented symbolic
//! memory trace it proves, per shared load and per lane, *which store's
//! register* holds the loaded value — the store→load analogue of the
//! paper's shuffle-delta solving (`sym::solve_forward`). Loads whose every
//! executing lane is reached by a proven writer become register traffic
//! (`mov` / `shfl.sync`); stores no remaining load can read become dead;
//! barriers no cross-lane memory traffic crosses become no-ops. This is
//! the elimination ACC Saturator performs on directive-generated code
//! (PAPERS.md, arXiv:2306.13002), rebuilt on the symbolic emulator.
//!
//! Soundness rules (each conservative default is "keep the code"):
//!
//! - The pass only runs on single-warp launches (`block ≤ 32`) of
//!   straight-line bodies that produced exactly one symbolic flow —
//!   everything the forwarding proof assumes (lockstep statement order,
//!   total thread enumeration) holds by construction there.
//! - A store or load whose lane set can't be derived from its guard
//!   (a unique unguarded `setp` over `%tid.x` and an immediate) is
//!   *unknown*: unknown stores poison every load they may reach, unknown
//!   loads keep every store they may read.
//! - An address `sym::solve_forward` can't relate is unknown unless the
//!   accesses are provably disjoint for **every** pair of lanes.
//! - Cross-lane forwarding is only accepted across a barrier (strictly
//!   earlier phase). Same-phase forwarding must be same-lane and in
//!   program order; same-phase cross-lane traffic is a race — poison.
//! - Two different lanes writing a reader's bytes in the same last phase
//!   is a write-write race — poison.
//! - The staged register must reach the load untouched: any redefinition
//!   between store and load that may execute on the source lane cancels
//!   the match.
//! - A barrier stays unless *no* kept store ↝ kept load pair (same state
//!   space, possibly-overlapping, possibly-cross-lane, non-`.nc`) spans
//!   it. Lockstep per-lane program order makes same-lane and store↔store
//!   pairs barrier-free within one warp; anything unknown keeps the
//!   barrier — on independent-thread-scheduling hardware cross-lane
//!   memory traffic needs the sync even inside a warp.

use crate::emu::induction::written_reg;
use crate::emu::EmulationResult;
use crate::ptx::ast::{
    CmpOp, Guard, Kernel, Op, Operand, Reg, Space, Statement, Type,
};
use crate::sym::{solve_forward, split_on, ForwardRel, TermId, TermPool};
use crate::util::{Dec, Enc, Fnv128};

/// Elimination configuration. Part of the `Synthesized` cache key — see
/// [`ElimOpts::key_into`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ElimOpts {
    /// Master switch (`--no-elim` clears it).
    pub enabled: bool,
    /// Launched `blockDim.x`. The pass only fires for `1..=32` — one warp —
    /// because the store→load forwarding it emits is warp-synchronous.
    pub block: u32,
}

impl Default for ElimOpts {
    fn default() -> ElimOpts {
        ElimOpts {
            enabled: true,
            block: 32,
        }
    }
}

impl ElimOpts {
    /// Feed every field into a stable 128-bit key (mirrors
    /// [`crate::shuffle::DetectOpts::key_into`]) — exhaustive destructuring
    /// so a future field cannot silently stay out of the disk-cache key.
    pub fn key_into(&self, h: &mut Fnv128) {
        let ElimOpts { enabled, block } = *self;
        h.write_u64(enabled as u64);
        h.write_u64(block as u64);
    }
}

/// Why one `.shared` store was or wasn't deleted.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StoreElim {
    /// Statement index in the *pre-elimination* body.
    pub stmt: usize,
    pub deleted: bool,
    pub reason: String,
}

/// Why one `bar.sync` was or wasn't elided.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BarrierElim {
    /// Statement index in the *pre-elimination* body.
    pub stmt: usize,
    pub elided: bool,
    pub reason: String,
}

/// Machine-readable elimination record carried by the `Synthesized`
/// artifact: one verdict per `.shared` store and per `bar.sync`, plus the
/// rewrite counts. `--stats`/`--report` render it; the disk store encodes
/// it with the total `util::codec` readers.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct ElimReport {
    /// `Some(reason)` — the pass did not run; the kernel is unchanged.
    pub bail: Option<String>,
    /// One entry per `.shared` store statement, in body order.
    pub stores: Vec<StoreElim>,
    /// One entry per `bar.sync` statement, in body order.
    pub barriers: Vec<BarrierElim>,
    /// Shared loads rewritten into register forwarding (`mov`/`shfl.sync`).
    pub forwarded_loads: u32,
    /// Dead address-chain statements swept after the rewrites.
    pub dce_stmts: u32,
}

impl ElimReport {
    pub fn disabled() -> ElimReport {
        ElimReport {
            bail: Some("elimination disabled".into()),
            ..ElimReport::default()
        }
    }

    pub fn bailed(reason: impl Into<String>) -> ElimReport {
        ElimReport {
            bail: Some(reason.into()),
            ..ElimReport::default()
        }
    }

    pub fn deleted_stores(&self) -> usize {
        self.stores.iter().filter(|s| s.deleted).count()
    }

    pub fn elided_barriers(&self) -> usize {
        self.barriers.iter().filter(|b| b.elided).count()
    }

    /// Did the pass change the kernel at all?
    pub fn changed(&self) -> bool {
        self.forwarded_loads > 0
            || self.dce_stmts > 0
            || self.deleted_stores() > 0
            || self.elided_barriers() > 0
    }

    pub(crate) fn encode(&self, e: &mut Enc) {
        match &self.bail {
            None => e.bool(false),
            Some(r) => {
                e.bool(true);
                e.str(r);
            }
        }
        e.u64(self.stores.len() as u64);
        for s in &self.stores {
            e.u64(s.stmt as u64);
            e.bool(s.deleted);
            e.str(&s.reason);
        }
        e.u64(self.barriers.len() as u64);
        for b in &self.barriers {
            e.u64(b.stmt as u64);
            e.bool(b.elided);
            e.str(&b.reason);
        }
        e.u32(self.forwarded_loads);
        e.u32(self.dce_stmts);
    }

    /// Total decode: any malformed byte yields `None`, never a panic — the
    /// disk store recomputes on `None` (same contract as `sym::persist`).
    pub(crate) fn decode(d: &mut Dec) -> Option<ElimReport> {
        let bail = if d.bool()? {
            Some(d.str()?.to_string())
        } else {
            None
        };
        let nstores = d.len()?;
        let mut stores = Vec::with_capacity(nstores);
        for _ in 0..nstores {
            stores.push(StoreElim {
                stmt: d.u64()? as usize,
                deleted: d.bool()?,
                reason: d.str()?.to_string(),
            });
        }
        let nbars = d.len()?;
        let mut barriers = Vec::with_capacity(nbars);
        for _ in 0..nbars {
            barriers.push(BarrierElim {
                stmt: d.u64()? as usize,
                elided: d.bool()?,
                reason: d.str()?.to_string(),
            });
        }
        Some(ElimReport {
            bail,
            stores,
            barriers,
            forwarded_loads: d.u32()?,
            dce_stmts: d.u32()?,
        })
    }
}

// ---------------------------------------------------------------------------
// The rewrite plan the analysis produces and `shuffle::elim` applies
// ---------------------------------------------------------------------------

/// Where one reader segment of a covered load gets its value from.
#[derive(Debug, Clone, PartialEq)]
pub enum CoverSrc {
    /// The staged value was an immediate — materialize it directly.
    Imm(Operand),
    /// Same-lane forwarding: plain register reuse.
    Same(Reg),
    /// `shfl.sync.{up|down}` by `|n|` lanes from the staging register.
    Shift { reg: Reg, n: i64 },
    /// `shfl.sync.idx` from one fixed lane.
    Bcast { reg: Reg, lane: i64 },
}

/// One lane segment of a covered load: these readers all take their value
/// from the same store via the same lane relation.
#[derive(Debug, Clone, PartialEq)]
pub struct CoverSeg {
    /// Bitmask over lanes `0..block`.
    pub readers: u32,
    pub src: CoverSrc,
}

/// A shared load the pass will rewrite into register traffic.
#[derive(Debug, Clone, PartialEq)]
pub struct LoadPlan {
    /// Statement index of the `ld.shared` in the body.
    pub stmt: usize,
    pub dst: Reg,
    pub ty: Type,
    /// The load's own guard, if any (reused to commit full-set segments).
    pub guard: Option<Guard>,
    /// Executing-lane mask of the load.
    pub exec: u32,
    /// Disjoint segments whose union is exactly `exec`.
    pub segs: Vec<CoverSeg>,
}

/// Everything the analysis decided; `shuffle::elim` turns it into code.
#[derive(Debug, Clone, Default)]
pub struct Plan {
    pub block: u32,
    /// Loads to rewrite, body order.
    pub covered: Vec<LoadPlan>,
    /// Shared loads kept, with the reason (body order).
    pub kept_loads: Vec<(usize, String)>,
    /// Shared stores to delete, with the reason (body order).
    pub dead_stores: Vec<(usize, String)>,
    /// Shared stores kept, with the reason (body order).
    pub kept_stores: Vec<(usize, String)>,
    /// `bar.sync` statements to elide, with the reason (body order).
    pub elide_bars: Vec<(usize, String)>,
    /// `bar.sync` statements kept, with the reason (body order).
    pub kept_bars: Vec<(usize, String)>,
}

// ---------------------------------------------------------------------------
// Static lane-set derivation
// ---------------------------------------------------------------------------

/// All-lanes mask for a block of `block` threads.
pub(crate) fn block_mask(block: u32) -> u32 {
    if block >= 32 {
        u32::MAX
    } else {
        (1u32 << block) - 1
    }
}

/// Register(s) a statement defines (guard-independent).
fn stmt_defs(stmt: &Statement) -> Vec<&Reg> {
    let Statement::Instr { op, .. } = stmt else {
        return Vec::new();
    };
    let mut v = Vec::new();
    if let Some(d) = written_reg(op) {
        v.push(d);
    }
    if let Op::Shfl {
        pred_out: Some(p), ..
    } = op
    {
        v.push(p);
    }
    v
}

/// The unique statement index `< upto` defining `r`, if there is exactly
/// one such definition. More than one (or zero) ⇒ `None`.
fn unique_def_before(body: &[Statement], r: &Reg, upto: usize) -> Option<usize> {
    let mut found = None;
    for (i, s) in body.iter().enumerate().take(upto) {
        if stmt_defs(s).contains(&r) {
            if found.is_some() {
                return None;
            }
            found = Some(i);
        }
    }
    found
}

/// What an operand evaluates to per-lane, after chasing unguarded moves.
#[derive(Debug, Clone, Copy)]
enum TidVal {
    Tid,
    Const(i128),
}

fn resolve_tid_val(body: &[Statement], upto: usize, o: &Operand, depth: u32) -> Option<TidVal> {
    if depth == 0 {
        return None;
    }
    match o {
        Operand::ImmInt(k) => Some(TidVal::Const(*k)),
        Operand::Special(s) if s.name() == "%tid.x" => Some(TidVal::Tid),
        Operand::Reg(r) => {
            let j = unique_def_before(body, r, upto)?;
            match &body[j] {
                Statement::Instr {
                    guard: None,
                    op: Op::Mov { src, .. },
                } => resolve_tid_val(body, j, src, depth - 1),
                Statement::Instr {
                    guard: None,
                    op: Op::Cvt { dty, sty, src, .. },
                } if dty.bits() == 32 && sty.bits() == 32 && !dty.is_float() && !sty.is_float() => {
                    resolve_tid_val(body, j, src, depth - 1)
                }
                _ => None,
            }
        }
        _ => None,
    }
}

fn cmp_holds(cmp: CmpOp, ty: Type, x: i128, y: i128) -> bool {
    if ty.is_signed() {
        let (a, b) = (x as u32 as i32, y as u32 as i32);
        match cmp {
            CmpOp::Eq => a == b,
            CmpOp::Ne => a != b,
            CmpOp::Lt => a < b,
            CmpOp::Le => a <= b,
            CmpOp::Gt => a > b,
            CmpOp::Ge => a >= b,
        }
    } else {
        let (a, b) = (x as u32, y as u32);
        match cmp {
            CmpOp::Eq => a == b,
            CmpOp::Ne => a != b,
            CmpOp::Lt => a < b,
            CmpOp::Le => a <= b,
            CmpOp::Gt => a > b,
            CmpOp::Ge => a >= b,
        }
    }
}

/// Which lanes `0..block` execute statement `i`: the full block when it is
/// unguarded, otherwise the lane set of the guard predicate — derivable
/// only when the guard's unique unguarded `setp` compares a value traceable
/// to `%tid.x` (through `mov`/integer `cvt`) against a constant.
pub(crate) fn exec_lanes(body: &[Statement], i: usize, block: u32) -> Option<u32> {
    let Statement::Instr { guard, .. } = &body[i] else {
        return None;
    };
    let Some(g) = guard else {
        return Some(block_mask(block));
    };
    let j = unique_def_before(body, &g.reg, i)?;
    let Statement::Instr {
        guard: None,
        op: Op::Setp { cmp, ty, a, b, .. },
    } = &body[j]
    else {
        return None;
    };
    if ty.bits() != 32 {
        return None;
    }
    let av = resolve_tid_val(body, j, a, 8)?;
    let bv = resolve_tid_val(body, j, b, 8)?;
    let mut m = 0u32;
    for t in 0..block.min(32) {
        let xv = match av {
            TidVal::Tid => t as i128,
            TidVal::Const(k) => k,
        };
        let yv = match bv {
            TidVal::Tid => t as i128,
            TidVal::Const(k) => k,
        };
        if cmp_holds(*cmp, *ty, xv, yv) {
            m |= 1 << t;
        }
    }
    Some(if g.negated {
        !m & block_mask(block)
    } else {
        m
    })
}

// ---------------------------------------------------------------------------
// Lane-wise store↝load relation
// ---------------------------------------------------------------------------

/// How a store's bytes relate to a load's bytes, lane-wise.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Rel {
    /// `L(t) = S(t + n)` exactly, and no partial overlap is possible.
    Shift(i64),
    /// `L(·) = S(lane)` exactly, and no partial overlap is possible.
    Bcast(i64),
    /// No lane of the load ever touches bytes any lane of the store wrote.
    Disjoint,
    /// Anything else — treat as "may interfere".
    Unknown,
}

struct Access {
    addr: TermId,
    bytes: u64,
}

/// Can any (load-lane, store-lane) pair produce overlapping byte ranges?
/// Decidable only when both strides and the rest difference are constant.
fn provably_disjoint(pool: &TermPool, st: &Access, ld: &Access, tid: TermId, block: u32) -> bool {
    let (ss, rs) = split_on(pool, st.addr, tid);
    let (sl, rl) = split_on(pool, ld.addr, tid);
    let d = rl.sub(&rs);
    if !d.is_constant() {
        return false;
    }
    let c = d.constant;
    for t_ld in 0..block as i128 {
        for t_st in 0..block as i128 {
            let diff = sl * t_ld - ss * t_st + c; // load byte − store byte
            if diff > -(ld.bytes as i128) && diff < st.bytes as i128 {
                return false;
            }
        }
    }
    true
}

/// Relate store and load addresses. A `Shift`/`Bcast` result guarantees
/// exact byte-range equality per matched lane pair *and* that no other
/// lane pair partially overlaps (stride at least the access size, equal
/// access sizes).
fn relate(pool: &TermPool, st: &Access, ld: &Access, tid: TermId, block: u32) -> Rel {
    let exact = st.bytes == ld.bytes;
    if exact {
        if let Some(rel) = solve_forward(pool, st.addr, ld.addr, tid) {
            let (ss, _) = split_on(pool, st.addr, tid);
            if ss.unsigned_abs() >= st.bytes as u128 {
                return match rel {
                    ForwardRel::Shift(n) => Rel::Shift(n),
                    ForwardRel::Broadcast(l) => Rel::Bcast(l),
                };
            }
        }
    }
    if provably_disjoint(pool, st, ld, tid, block) {
        Rel::Disjoint
    } else {
        Rel::Unknown
    }
}

// ---------------------------------------------------------------------------
// The analysis
// ---------------------------------------------------------------------------

struct SharedSt {
    stmt: usize,
    addr: TermId,
    bytes: u64,
    phase: u32,
    /// `None` = underivable guard: poisons everything it may reach.
    exec: Option<u32>,
    src: Operand,
}

struct SharedLd {
    stmt: usize,
    addr: TermId,
    bytes: u64,
    phase: u32,
    exec: Option<u32>,
    dst: Reg,
    ty: Type,
    guard: Option<Guard>,
}

/// A memory access that matters for barrier-crossing traffic.
struct CommAccess {
    addr: TermId,
    bytes: u64,
    phase: u32,
    space: Space,
    exec: Option<u32>,
    stmt: usize,
}

/// Run the phase-liveness analysis. `Err(reason)` is a whole-pass bail:
/// the kernel must be left untouched.
pub fn plan(kernel: &Kernel, emu: &EmulationResult, opts: ElimOpts) -> Result<Plan, String> {
    let block = opts.block;
    if block == 0 || block > 32 {
        return Err(format!(
            "block size {block} is not a single warp (1..=32); forwarding is warp-synchronous"
        ));
    }
    for s in &kernel.body {
        match s {
            Statement::Label(_) => {
                return Err("body has labels; the pass needs a straight-line body".into())
            }
            Statement::Instr {
                op: Op::Bra { .. }, ..
            } => return Err("body has branches; the pass needs a straight-line body".into()),
            _ => {}
        }
    }
    if emu.flows.len() != 1 {
        return Err(format!(
            "{} symbolic flows; the pass needs exactly one",
            emu.flows.len()
        ));
    }
    let flow = &emu.flows[0];
    let pool = &emu.pool;
    let tid = emu.tid_sym;
    let body = &kernel.body;

    // -- collect shared accesses, one trace record per statement ----------
    let mut st_recs: Vec<SharedSt> = Vec::new();
    for r in flow.trace.shared_stores() {
        let Some(Statement::Instr {
            guard,
            op: Op::St { src, .. },
        }) = body.get(r.stmt)
        else {
            return Err(format!("trace store at stmt {} has no matching st", r.stmt));
        };
        if st_recs.iter().any(|s| s.stmt == r.stmt) {
            return Err(format!("stmt {} recorded twice; not straight-line", r.stmt));
        }
        let exec = if guard.is_some() {
            exec_lanes(body, r.stmt, block)
        } else {
            Some(block_mask(block))
        };
        st_recs.push(SharedSt {
            stmt: r.stmt,
            addr: r.addr,
            bytes: r.ty.bytes(),
            phase: r.phase,
            exec,
            src: src.clone(),
        });
    }
    let mut ld_recs: Vec<SharedLd> = Vec::new();
    for r in flow.trace.shared_loads() {
        let Some(Statement::Instr {
            guard,
            op: Op::Ld { dst, ty, .. },
        }) = body.get(r.stmt)
        else {
            return Err(format!("trace load at stmt {} has no matching ld", r.stmt));
        };
        if ld_recs.iter().any(|l| l.stmt == r.stmt) {
            return Err(format!("stmt {} recorded twice; not straight-line", r.stmt));
        }
        let exec = if guard.is_some() {
            exec_lanes(body, r.stmt, block)
        } else {
            Some(block_mask(block))
        };
        ld_recs.push(SharedLd {
            stmt: r.stmt,
            addr: r.addr,
            bytes: r.ty.bytes(),
            phase: r.phase,
            exec,
            dst: dst.clone(),
            ty: *ty,
            guard: guard.clone(),
        });
    }
    st_recs.sort_by_key(|s| s.stmt);
    ld_recs.sort_by_key(|l| l.stmt);

    // -- per-load coverage ------------------------------------------------
    let rels: Vec<Vec<Rel>> = ld_recs
        .iter()
        .map(|l| {
            let la = Access {
                addr: l.addr,
                bytes: l.bytes,
            };
            st_recs
                .iter()
                .map(|s| {
                    let sa = Access {
                        addr: s.addr,
                        bytes: s.bytes,
                    };
                    relate(pool, &sa, &la, tid, block)
                })
                .collect()
        })
        .collect();

    let mut out = Plan {
        block,
        ..Plan::default()
    };
    // covered[i] = true → load i rewritten, no longer reads memory
    let mut covered = vec![false; ld_recs.len()];

    'loads: for (li, l) in ld_recs.iter().enumerate() {
        if l.ty.bits() != 32 {
            out.kept_loads
                .push((l.stmt, format!("{}-bit load; only 32-bit forwards", l.ty.bits())));
            continue;
        }
        let Some(exec) = l.exec else {
            out.kept_loads
                .push((l.stmt, "guard lane set underivable".into()));
            continue;
        };
        if exec == 0 {
            out.kept_loads
                .push((l.stmt, "statically no lane executes it".into()));
            continue;
        }
        // lane → (store index, source lane) of the last proven writer
        let mut assign: Vec<Option<(usize, u32, u32, usize)>> = vec![None; 32];
        for t in 0..block {
            if exec >> t & 1 == 0 {
                continue;
            }
            // best = (store idx, source lane, phase, stmt)
            let mut best: Option<(usize, u32, u32, usize)> = None;
            for (si, s) in st_recs.iter().enumerate() {
                let src = match rels[li][si] {
                    Rel::Disjoint => continue,
                    Rel::Unknown => {
                        out.kept_loads.push((
                            l.stmt,
                            format!("store at stmt {} may alias it unprovably", s.stmt),
                        ));
                        continue 'loads;
                    }
                    Rel::Shift(n) => {
                        let src = t as i64 + n;
                        if src < 0 || src >= block as i64 {
                            continue;
                        }
                        src as u32
                    }
                    Rel::Bcast(lane) => {
                        if lane < 0 || lane >= block as i64 {
                            continue;
                        }
                        lane as u32
                    }
                };
                let Some(es) = s.exec else {
                    out.kept_loads.push((
                        l.stmt,
                        format!("store at stmt {} has an underivable lane set", s.stmt),
                    ));
                    continue 'loads;
                };
                if es >> src & 1 == 0 {
                    continue; // that lane never executes the store
                }
                // temporal filter: the write must happen before the read
                if s.phase > l.phase || (s.phase == l.phase && s.stmt >= l.stmt) {
                    continue;
                }
                if s.phase == l.phase && src != t {
                    out.kept_loads.push((
                        l.stmt,
                        format!(
                            "cross-lane read of stmt {} within one phase (race)",
                            s.stmt
                        ),
                    ));
                    continue 'loads;
                }
                best = match best {
                    None => Some((si, src, s.phase, s.stmt)),
                    Some(b) => {
                        if s.phase > b.2 {
                            Some((si, src, s.phase, s.stmt))
                        } else if s.phase == b.2 {
                            if src != b.1 {
                                out.kept_loads.push((
                                    l.stmt,
                                    format!(
                                        "lanes {} and {} both write lane {t}'s bytes in phase {} (race)",
                                        b.1, src, s.phase
                                    ),
                                ));
                                continue 'loads;
                            }
                            if s.stmt > b.3 {
                                Some((si, src, s.phase, s.stmt))
                            } else {
                                Some(b)
                            }
                        } else {
                            Some(b)
                        }
                    }
                };
            }
            let Some(b) = best else {
                out.kept_loads.push((
                    l.stmt,
                    format!("lane {t} reads bytes no proven store wrote"),
                ));
                continue 'loads;
            };
            assign[t as usize] = Some(b);
        }

        // -- register intactness + segment grouping -----------------------
        let mut segs: Vec<CoverSeg> = Vec::new();
        for (si, s) in st_recs.iter().enumerate() {
            let mut readers = 0u32;
            let mut src_lanes = 0u32;
            for t in 0..block {
                if let Some((bsi, src, _, _)) = assign[t as usize] {
                    if bsi == si {
                        readers |= 1 << t;
                        src_lanes |= 1 << src;
                    }
                }
            }
            if readers == 0 {
                continue;
            }
            let src = match &s.src {
                Operand::Reg(r) => {
                    // the staged register must survive from store to load on
                    // every source lane
                    for (j, stj) in body
                        .iter()
                        .enumerate()
                        .take(l.stmt)
                        .skip(s.stmt + 1)
                    {
                        if !stmt_defs(stj).contains(&r) {
                            continue;
                        }
                        let harmless = exec_lanes(body, j, block)
                            .is_some_and(|m| m & src_lanes == 0);
                        if !harmless {
                            out.kept_loads.push((
                                l.stmt,
                                format!(
                                    "staged register {r} is redefined at stmt {j} before the load"
                                ),
                            ));
                            continue 'loads;
                        }
                    }
                    match rels[li][si] {
                        Rel::Shift(0) => CoverSrc::Same(r.clone()),
                        Rel::Shift(n) => CoverSrc::Shift { reg: r.clone(), n },
                        // a broadcast whose only reader is the source lane
                        // itself is plain same-lane reuse
                        Rel::Bcast(_) if readers == src_lanes && readers.count_ones() == 1 => {
                            CoverSrc::Same(r.clone())
                        }
                        Rel::Bcast(lane) => CoverSrc::Bcast {
                            reg: r.clone(),
                            lane,
                        },
                        _ => unreachable!("matched stores have a lane relation"),
                    }
                }
                Operand::ImmInt(_) | Operand::ImmF32(_) | Operand::ImmF64(_) => {
                    CoverSrc::Imm(s.src.clone())
                }
                _ => {
                    out.kept_loads.push((
                        l.stmt,
                        format!("store at stmt {} stages a non-register value", s.stmt),
                    ));
                    continue 'loads;
                }
            };
            segs.push(CoverSeg { readers, src });
        }
        // every segment's lane set must be encodable as a predicate
        for seg in &segs {
            if seg_shape(seg.readers, block).is_none() {
                out.kept_loads.push((
                    l.stmt,
                    "reader lanes form no contiguous range; not encodable".into(),
                ));
                continue 'loads;
            }
        }
        covered[li] = true;
        out.covered.push(LoadPlan {
            stmt: l.stmt,
            dst: l.dst.clone(),
            ty: l.ty,
            guard: l.guard.clone(),
            exec,
            segs,
        });
    }

    // -- store deletion ---------------------------------------------------
    for (si, s) in st_recs.iter().enumerate() {
        let mut blocker: Option<String> = None;
        if s.exec.is_none() {
            blocker = Some("its lane set is underivable".into());
        }
        for (li, l) in ld_recs.iter().enumerate() {
            if covered[li] || blocker.is_some() {
                continue;
            }
            let reaches = match rels[li][si] {
                Rel::Disjoint => false,
                Rel::Unknown => true,
                Rel::Shift(n) => {
                    let el = l.exec.unwrap_or(block_mask(block));
                    let es = s.exec.unwrap_or(block_mask(block));
                    (0..block).any(|t| {
                        el >> t & 1 == 1 && {
                            let src = t as i64 + n;
                            (0..block as i64).contains(&src) && es >> (src as u32) & 1 == 1
                        }
                    })
                }
                Rel::Bcast(lane) => {
                    let el = l.exec.unwrap_or(block_mask(block));
                    let es = s.exec.unwrap_or(block_mask(block));
                    el != 0
                        && (0..block as i64).contains(&lane)
                        && es >> (lane as u32) & 1 == 1
                }
            };
            if reaches {
                blocker = Some(format!("still read by the kept load at stmt {}", l.stmt));
            }
        }
        match blocker {
            Some(why) => out.kept_stores.push((s.stmt, why)),
            None => out.dead_stores.push((
                s.stmt,
                "every load of its bytes was forwarded to registers".into(),
            )),
        }
    }

    // -- barrier elision --------------------------------------------------
    // Traffic that still goes through memory after the rewrite: kept
    // shared accesses plus all global ones (`.nc` loads are read-only by
    // contract and cannot observe any store).
    let dead: Vec<usize> = out.dead_stores.iter().map(|(i, _)| *i).collect();
    let mut comm_st: Vec<CommAccess> = Vec::new();
    let mut comm_ld: Vec<CommAccess> = Vec::new();
    for s in flow.trace.stores.iter() {
        match s.space {
            Space::Shared if dead.contains(&s.stmt) => continue,
            Space::Shared | Space::Global => {}
            _ => continue, // local is lane-private; param/const are read-only
        }
        comm_st.push(CommAccess {
            addr: s.addr,
            bytes: s.ty.bytes(),
            phase: s.phase,
            space: s.space,
            exec: exec_lanes(body, s.stmt, block),
            stmt: s.stmt,
        });
    }
    for (li, l) in ld_recs.iter().enumerate() {
        if covered[li] {
            continue;
        }
        comm_ld.push(CommAccess {
            addr: l.addr,
            bytes: l.bytes,
            phase: l.phase,
            space: Space::Shared,
            exec: l.exec,
            stmt: l.stmt,
        });
    }
    for l in flow.trace.loads.iter() {
        if l.space != Space::Global || l.nc {
            continue;
        }
        comm_ld.push(CommAccess {
            addr: l.addr,
            bytes: l.ty.bytes(),
            phase: l.phase,
            space: Space::Global,
            exec: exec_lanes(body, l.stmt, block),
            stmt: l.stmt,
        });
    }

    let mut bar_k = 0u32;
    for (i, stmt) in body.iter().enumerate() {
        let Statement::Instr {
            op: Op::BarSync { .. },
            ..
        } = stmt
        else {
            continue;
        };
        // the k-th barrier separates phase k from phase k+1
        let k = bar_k;
        bar_k += 1;
        let mut crossing: Option<String> = None;
        'pairs: for s in &comm_st {
            if s.phase > k {
                continue;
            }
            for l in &comm_ld {
                if l.phase <= k || l.space != s.space {
                    continue;
                }
                if cross_lane_traffic(pool, s, l, tid, block) {
                    crossing = Some(format!(
                        "store at stmt {} still publishes to the load at stmt {}",
                        s.stmt, l.stmt
                    ));
                    break 'pairs;
                }
            }
        }
        match crossing {
            Some(why) => out.kept_bars.push((i, why)),
            None => out.elide_bars.push((
                i,
                "no cross-lane memory traffic crosses it".into(),
            )),
        }
    }

    Ok(out)
}

/// Does any lane read bytes a *different* lane stored, across this
/// store/load pair? Same-lane traffic is ordered by lockstep program order
/// within the single warp and needs no barrier.
fn cross_lane_traffic(
    pool: &TermPool,
    s: &CommAccess,
    l: &CommAccess,
    tid: TermId,
    block: u32,
) -> bool {
    let sa = Access {
        addr: s.addr,
        bytes: s.bytes,
    };
    let la = Access {
        addr: l.addr,
        bytes: l.bytes,
    };
    match relate(pool, &sa, &la, tid, block) {
        Rel::Disjoint => false,
        Rel::Unknown => true,
        Rel::Shift(0) => false,
        Rel::Shift(n) => {
            let el = l.exec.unwrap_or(block_mask(block));
            let es = s.exec.unwrap_or(block_mask(block));
            (0..block).any(|t| {
                el >> t & 1 == 1 && {
                    let src = t as i64 + n;
                    (0..block as i64).contains(&src) && es >> (src as u32) & 1 == 1
                }
            })
        }
        Rel::Bcast(lane) => {
            if !(0..block as i64).contains(&lane) {
                return false;
            }
            let el = l.exec.unwrap_or(block_mask(block));
            let es = s.exec.unwrap_or(block_mask(block));
            es >> (lane as u32) & 1 == 1 && el & !(1 << lane as u32) != 0
        }
    }
}

/// How a reader lane set can be turned into a predicate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum SegShape {
    /// Every lane of the block.
    Full,
    /// Exactly one lane.
    Single(u32),
    /// Lanes `0..k`.
    Prefix(u32),
    /// Lanes `k..block`.
    Suffix(u32),
    /// Lanes `a..=b`, `0 < a`, `b < block-1`.
    Range(u32, u32),
}

/// Classify a non-empty lane mask; `None` for non-contiguous sets.
pub(crate) fn seg_shape(mask: u32, block: u32) -> Option<SegShape> {
    if mask == 0 {
        return None;
    }
    let a = mask.trailing_zeros();
    let b = 31 - mask.leading_zeros();
    let width = b - a + 1;
    let contiguous = if width == 32 {
        mask == u32::MAX
    } else {
        mask == ((1u32 << width) - 1) << a
    };
    if !contiguous {
        return None;
    }
    Some(if a == 0 && b == block - 1 {
        SegShape::Full
    } else if a == b {
        SegShape::Single(a)
    } else if a == 0 {
        SegShape::Prefix(b + 1)
    } else if b == block - 1 {
        SegShape::Suffix(a)
    } else {
        SegShape::Range(a, b)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::emu::emulate;
    use crate::ptx::parser::parse_kernel;
    use crate::suite::codegen::generate;
    use crate::suite::kernelgen::by_name;

    #[test]
    fn seg_shapes() {
        assert_eq!(seg_shape(0, 32), None);
        assert_eq!(seg_shape(u32::MAX, 32), Some(SegShape::Full));
        assert_eq!(seg_shape(0xFF, 8), Some(SegShape::Full));
        assert_eq!(seg_shape(1, 32), Some(SegShape::Single(0)));
        assert_eq!(seg_shape(0b111, 32), Some(SegShape::Prefix(3)));
        assert_eq!(seg_shape(u32::MAX << 4, 32), Some(SegShape::Suffix(4)));
        assert_eq!(seg_shape(0b0110, 32), Some(SegShape::Range(1, 2)));
        assert_eq!(seg_shape(0b0101, 32), None);
    }

    #[test]
    fn exec_lanes_from_setp_guards() {
        let k = parse_kernel(
            r#"
.visible .entry g(.param .u64 out){
.reg .b32 %r<3>; .reg .pred %p<3>; .reg .f32 %f<2>; .reg .b64 %rd<2>;
mov.u32 %r1, %tid.x;
setp.lt.s32 %p1, %r1, 4;
setp.eq.s32 %p2, %r1, 7;
@%p1 mov.f32 %f1, 0f00000000;
@!%p2 mov.f32 %f1, 0f00000000;
mov.f32 %f1, 0f3F800000;
ret;
}
"#,
        )
        .unwrap();
        assert_eq!(exec_lanes(&k.body, 3, 8), Some(0b1111));
        assert_eq!(exec_lanes(&k.body, 4, 8), Some(0xFF & !(1 << 7)));
        // unguarded statement: full block
        assert_eq!(exec_lanes(&k.body, 5, 8), Some(0xFF));
        // %f1 has several defs → a guard naming it would be underivable,
        // but guards name predicates; check the derivation bails on a
        // multiply-defined *predicate* instead:
        let k2 = parse_kernel(
            r#"
.visible .entry g2(.param .u64 out){
.reg .b32 %r<3>; .reg .pred %p<2>; .reg .f32 %f<2>;
mov.u32 %r1, %tid.x;
setp.lt.s32 %p1, %r1, 4;
setp.gt.s32 %p1, %r1, 2;
@%p1 mov.f32 %f1, 0f00000000;
ret;
}
"#,
        )
        .unwrap();
        assert_eq!(exec_lanes(&k2.body, 3, 8), None);
    }

    fn planned(name: &str) -> (crate::ptx::ast::Kernel, Plan) {
        let b = by_name(name).unwrap();
        let block = match &b.pattern {
            crate::suite::Pattern::TiledReduce { block } => *block,
            crate::suite::Pattern::SharedStencil { block, .. } => *block,
            crate::suite::Pattern::SharedGather { block } => *block,
            _ => panic!("not a shared benchmark"),
        };
        let k = generate(&b);
        let emu = emulate(&k).unwrap();
        let plan = plan(
            &k,
            &emu,
            ElimOpts {
                enabled: true,
                block,
            },
        )
        .unwrap();
        (k, plan)
    }

    #[test]
    fn tiledreduce_fully_forwards() {
        let (k, p) = planned("tiledreduce");
        // every shared load covered, every staging store dead
        assert!(p.kept_loads.is_empty(), "kept: {:?}", p.kept_loads);
        assert!(p.kept_stores.is_empty(), "kept: {:?}", p.kept_stores);
        assert!(!p.covered.is_empty());
        assert!(!p.dead_stores.is_empty());
        // all barriers elide once the staging traffic is register traffic
        assert!(p.kept_bars.is_empty(), "kept: {:?}", p.kept_bars);
        let bars = k
            .body
            .iter()
            .filter(|s| {
                matches!(
                    s,
                    Statement::Instr {
                        op: Op::BarSync { .. },
                        ..
                    }
                )
            })
            .count();
        assert_eq!(p.elide_bars.len(), bars);
        assert!(bars >= 1);
    }

    #[test]
    fn sharedstencil_covers_taps_with_halo_segments() {
        let (_k, p) = planned("sharedstencil");
        assert!(p.kept_loads.is_empty(), "kept: {:?}", p.kept_loads);
        assert_eq!(p.covered.len(), 3, "three taps");
        assert!(p.kept_stores.is_empty(), "kept: {:?}", p.kept_stores);
        assert_eq!(p.dead_stores.len(), 3, "tile + two halo stores");
        assert!(p.kept_bars.is_empty());
        assert_eq!(p.elide_bars.len(), 1);
        // the off-center taps need two segments (halo lane + shifted rest)
        let multi = p.covered.iter().filter(|c| c.segs.len() == 2).count();
        assert_eq!(multi, 2);
    }

    #[test]
    fn sharedgather_keeps_store_and_barrier() {
        let (_k, p) = planned("sharedgather");
        // the data-dependent tap is unknown → kept, and it pins the store
        // and the barrier; the tid tap still forwards
        assert_eq!(p.covered.len(), 1);
        assert_eq!(p.kept_loads.len(), 1);
        assert!(p.dead_stores.is_empty());
        assert_eq!(p.kept_stores.len(), 1);
        assert!(p.elide_bars.is_empty());
        assert_eq!(p.kept_bars.len(), 1);
    }

    #[test]
    fn oversized_block_bails() {
        let b = by_name("tiledreduce").unwrap();
        let k = generate(&b);
        let emu = emulate(&k).unwrap();
        let err = plan(
            &k,
            &emu,
            ElimOpts {
                enabled: true,
                block: 64,
            },
        )
        .unwrap_err();
        assert!(err.contains("warp"), "{err}");
    }

    #[test]
    fn report_roundtrips_and_rejects_corruption() {
        let r = ElimReport {
            bail: None,
            stores: vec![
                StoreElim {
                    stmt: 4,
                    deleted: true,
                    reason: "every load of its bytes was forwarded".into(),
                },
                StoreElim {
                    stmt: 9,
                    deleted: false,
                    reason: "still read by the kept load at stmt 12".into(),
                },
            ],
            barriers: vec![BarrierElim {
                stmt: 5,
                elided: true,
                reason: "no cross-lane memory traffic crosses it".into(),
            }],
            forwarded_loads: 3,
            dce_stmts: 7,
        };
        let mut e = Enc::default();
        r.encode(&mut e);
        let bytes = e.buf;
        let mut d = Dec::new(&bytes);
        let back = ElimReport::decode(&mut d).unwrap();
        assert!(d.done());
        assert_eq!(back, r);
        // truncation at every prefix must fail cleanly, never panic
        for cut in 0..bytes.len() {
            let mut d = Dec::new(&bytes[..cut]);
            assert!(ElimReport::decode(&mut d).is_none(), "cut at {cut}");
        }
        // single-byte corruption must never panic (it may still decode —
        // flipping a count or a reason byte can yield another valid image)
        crate::util::check_cases("elim_report_corruption", 64, |rng| {
            let mut evil = bytes.clone();
            let at = rng.below(evil.len() as u64) as usize;
            evil[at] ^= (rng.below(255) + 1) as u8;
            let mut d = Dec::new(&evil);
            let _ = ElimReport::decode(&mut d);
        });
    }
}
