//! Shuffle code generation (paper §5.2, Listing 6).
//!
//! For each chosen candidate the source load is extended with a `mov` into
//! a dedicated source register; the destination load is replaced by a
//! `shfl.sync` plus a corner-case checker: lanes with no in-warp source
//! (`%wid < N` for `.up`) or lanes of an incomplete warp re-issue the
//! original load under a predicate. The warp-lane id is computed once at
//! kernel entry and shared among all shuffles.
//!
//! Besides the paper's default synthesis, the evaluation variants are
//! generated here too: NO LOAD (covered loads deleted — invalid results,
//! measures the pure memory saving), NO CORNER (shuffle only, no checker),
//! and the §8.3 uniform-branch alternative that guards the whole shuffle
//! with `@%incomplete bra` (kills register-bank-conflict latency on Pascal
//! at the cost of an extra branch).

use super::detect::{Candidate, Detection};
use crate::ptx::ast::*;
use std::collections::BTreeMap;

/// Which synthesis flavour to emit.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Variant {
    /// Paper default: shuffle + corner-case predicate (valid results).
    Full,
    /// Delete covered loads, insert nothing (invalid results).
    NoLoad,
    /// Shuffle without corner handling (invalid results).
    NoCorner,
    /// §8.3: uniform branch around the shuffle (valid results).
    UniformBranch,
}

impl Variant {
    pub fn name(self) -> &'static str {
        match self {
            Variant::Full => "PTXASW",
            Variant::NoLoad => "NO LOAD",
            Variant::NoCorner => "NO CORNER",
            Variant::UniformBranch => "UNIFORM",
        }
    }

    pub const ALL: [Variant; 4] = [
        Variant::Full,
        Variant::NoLoad,
        Variant::NoCorner,
        Variant::UniformBranch,
    ];
}

/// Synthesize a new kernel with the chosen shuffles applied.
pub fn synthesize(kernel: &Kernel, det: &Detection, variant: Variant) -> Kernel {
    if det.chosen.is_empty() {
        return kernel.clone();
    }
    let dst_map: BTreeMap<usize, Candidate> =
        det.chosen.iter().map(|c| (c.dst_stmt, *c)).collect();

    // unique source statements → source register
    let mut src_regs: BTreeMap<usize, (Reg, Type)> = BTreeMap::new();
    let mut nf = 0u32;
    let mut nb = 0u32;
    for c in &det.chosen {
        src_regs.entry(c.src_stmt).or_insert_with(|| {
            let ty = load_type(kernel, c.src_stmt);
            let r = if ty == Type::F32 {
                let r = Reg::new(format!("%zsf{nf}"));
                nf += 1;
                r
            } else {
                let r = Reg::new(format!("%zsb{nb}"));
                nb += 1;
                r
            };
            (r, ty)
        });
    }

    let shuffling = !matches!(variant, Variant::NoLoad);
    let needs_wid = shuffling
        && matches!(variant, Variant::Full | Variant::UniformBranch)
        && det.chosen.iter().any(|c| c.delta != 0);

    let mut nm = 0u32; // mask regs
    let mut np = 0u32; // pred regs
    let mut nlabel = 0u32;
    let mut body: Vec<Statement> = Vec::with_capacity(kernel.body.len() + det.chosen.len() * 6);

    // shared %wid = %tid.x % 32 at kernel entry
    let wid = Reg::new("%zw0");
    if needs_wid {
        body.push(Statement::instr(Op::Mov {
            ty: Type::U32,
            dst: wid.clone(),
            src: Operand::Special(Special::TidX),
        }));
        body.push(Statement::instr(Op::IntBin {
            op: IntBinOp::Rem,
            ty: Type::U32,
            dst: wid.clone(),
            a: Operand::Reg(wid.clone()),
            b: Operand::ImmInt(32),
        }));
    }

    for (i, stmt) in kernel.body.iter().enumerate() {
        if let Some(c) = dst_map.get(&i) {
            let Statement::Instr {
                guard: None,
                op: op @ Op::Ld { .. },
            } = stmt
            else {
                // detection only proposes unguarded loads; be safe
                body.push(stmt.clone());
                continue;
            };
            let (src_reg, _src_ty) = &src_regs[&c.src_stmt];
            let Op::Ld { ty, dst, .. } = op else { unreachable!() };
            emit_covered_load(
                &mut body,
                variant,
                c,
                op,
                *ty,
                dst,
                src_reg,
                &wid,
                &mut nm,
                &mut np,
                &mut nlabel,
            );
            continue;
        }
        body.push(stmt.clone());
        if shuffling || true {
            // source mov is emitted for all variants that keep the loads;
            // NO LOAD deletes destinations but sources still execute.
        }
        if let Some((r, ty)) = src_regs.get(&i) {
            if shuffling {
                let Statement::Instr {
                    op: Op::Ld { dst, .. },
                    ..
                } = stmt
                else {
                    continue;
                };
                body.push(Statement::instr(Op::Mov {
                    ty: mov_ty(*ty),
                    dst: r.clone(),
                    src: Operand::Reg(dst.clone()),
                }));
            }
        }
    }

    // extend register declarations
    let mut regs = kernel.regs.clone();
    if needs_wid {
        regs.push(RegDecl {
            ty: Type::B32,
            prefix: "%zw".into(),
            count: 1,
        });
    }
    if shuffling {
        if nf > 0 {
            regs.push(RegDecl {
                ty: Type::F32,
                prefix: "%zsf".into(),
                count: nf,
            });
        }
        if nb > 0 {
            regs.push(RegDecl {
                ty: Type::B32,
                prefix: "%zsb".into(),
                count: nb,
            });
        }
        if nm > 0 {
            regs.push(RegDecl {
                ty: Type::B32,
                prefix: "%zm".into(),
                count: nm,
            });
        }
        if np > 0 {
            regs.push(RegDecl {
                ty: Type::Pred,
                prefix: "%zp".into(),
                count: np,
            });
        }
    }

    Kernel {
        name: kernel.name.clone(),
        params: kernel.params.clone(),
        regs,
        shared: kernel.shared.clone(),
        body,
    }
}

fn mov_ty(t: Type) -> Type {
    match t {
        Type::F32 => Type::F32,
        _ => Type::B32,
    }
}

fn load_type(kernel: &Kernel, stmt: usize) -> Type {
    match &kernel.body[stmt] {
        Statement::Instr {
            op: Op::Ld { ty, .. },
            ..
        } => *ty,
        _ => Type::B32,
    }
}

#[allow(clippy::too_many_arguments)]
fn emit_covered_load(
    body: &mut Vec<Statement>,
    variant: Variant,
    c: &Candidate,
    orig_ld: &Op,
    ty: Type,
    dst: &Reg,
    src_reg: &Reg,
    wid: &Reg,
    nm: &mut u32,
    np: &mut u32,
    nlabel: &mut u32,
) {
    if variant == Variant::NoLoad {
        return; // load deleted, nothing synthesized
    }
    if c.delta == 0 {
        // pure register reuse
        body.push(Statement::instr(Op::Mov {
            ty: mov_ty(ty),
            dst: dst.clone(),
            src: Operand::Reg(src_reg.clone()),
        }));
        return;
    }

    let n = c.delta.unsigned_abs() as i128;
    let (mode, clamp, oor_cmp, oor_val) = if c.delta < 0 {
        // value comes from lane (wid - |N|): shift up
        (ShflMode::Up, 0i128, CmpOp::Lt, n)
    } else {
        // value comes from lane (wid + N): shift down
        (ShflMode::Down, 31i128, CmpOp::Gt, 31 - n)
    };

    let mask = Reg::new(format!("%zm{}", *nm));
    *nm += 1;
    body.push(Statement::instr(Op::Activemask { dst: mask.clone() }));

    let shfl = Op::Shfl {
        mode,
        dst: dst.clone(),
        pred_out: None,
        src: Operand::Reg(src_reg.clone()),
        b: Operand::ImmInt(n),
        c: Operand::ImmInt(clamp),
        mask: Operand::Reg(mask.clone()),
    };

    match variant {
        Variant::NoCorner => {
            body.push(Statement::instr(shfl));
        }
        Variant::Full => {
            let incomplete = Reg::new(format!("%zp{}", *np));
            let oor = Reg::new(format!("%zp{}", *np + 1));
            let pred = Reg::new(format!("%zp{}", *np + 2));
            *np += 3;
            body.push(Statement::instr(Op::Setp {
                cmp: CmpOp::Ne,
                ty: Type::S32,
                dst: incomplete.clone(),
                a: Operand::Reg(mask.clone()),
                b: Operand::ImmInt(-1),
            }));
            body.push(Statement::instr(Op::Setp {
                cmp: oor_cmp,
                ty: Type::U32,
                dst: oor.clone(),
                a: Operand::Reg(wid.clone()),
                b: Operand::ImmInt(oor_val),
            }));
            body.push(Statement::instr(Op::IntBin {
                op: IntBinOp::Or,
                ty: Type::Pred,
                dst: pred.clone(),
                a: Operand::Reg(incomplete),
                b: Operand::Reg(oor),
            }));
            body.push(Statement::instr(shfl));
            body.push(Statement::guarded(&pred.0, false, orig_ld.clone()));
        }
        Variant::UniformBranch => {
            let incomplete = Reg::new(format!("%zp{}", *np));
            let oor = Reg::new(format!("%zp{}", *np + 1));
            *np += 2;
            let corner = format!("$ZC_{}", *nlabel);
            let done = format!("$ZD_{}", *nlabel);
            *nlabel += 1;
            body.push(Statement::instr(Op::Setp {
                cmp: CmpOp::Ne,
                ty: Type::S32,
                dst: incomplete.clone(),
                a: Operand::Reg(mask.clone()),
                b: Operand::ImmInt(-1),
            }));
            body.push(Statement::guarded(
                &incomplete.0,
                false,
                Op::Bra {
                    uni: false,
                    target: corner.clone(),
                },
            ));
            body.push(Statement::instr(Op::Setp {
                cmp: oor_cmp,
                ty: Type::U32,
                dst: oor.clone(),
                a: Operand::Reg(wid.clone()),
                b: Operand::ImmInt(oor_val),
            }));
            body.push(Statement::instr(shfl));
            body.push(Statement::guarded(&oor.0, false, orig_ld.clone()));
            body.push(Statement::instr(Op::Bra {
                uni: true,
                target: done.clone(),
            }));
            body.push(Statement::Label(corner));
            body.push(Statement::instr(orig_ld.clone()));
            body.push(Statement::Label(done));
        }
        Variant::NoLoad => unreachable!("handled above"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ptx::parser::{parse_kernel, parse};
    use crate::ptx::printer::print_kernel;
    use crate::shuffle::detect::analyze;

    const STENCIL3: &str = r#"
.visible .entry s3(.param .u64 out, .param .u64 a){
.reg .b32 %r<6>; .reg .b64 %rd<8>; .reg .f32 %f<6>; .reg .pred %p<2>;
ld.param.u64 %rd1, [out];
ld.param.u64 %rd2, [a];
cvta.to.global.u64 %rd3, %rd2;
cvta.to.global.u64 %rd4, %rd1;
mov.u32 %r2, %ntid.x;
mov.u32 %r3, %ctaid.x;
mov.u32 %r4, %tid.x;
mad.lo.s32 %r1, %r3, %r2, %r4;
mul.wide.s32 %rd5, %r1, 4;
add.s64 %rd6, %rd3, %rd5;
ld.global.nc.f32 %f1, [%rd6];
ld.global.nc.f32 %f2, [%rd6+4];
ld.global.nc.f32 %f3, [%rd6+8];
add.f32 %f4, %f1, %f2;
add.f32 %f5, %f4, %f3;
add.s64 %rd7, %rd4, %rd5;
st.global.f32 [%rd7], %f5;
ret;
}
"#;

    fn stencil_detection() -> (Kernel, Detection) {
        let k = parse_kernel(STENCIL3).unwrap();
        let det = analyze(&k).unwrap();
        assert_eq!(det.shuffle_count(), 2);
        (k, det)
    }

    #[test]
    fn full_variant_structure() {
        let (k, det) = stencil_detection();
        let s = synthesize(&k, &det, Variant::Full);
        assert_eq!(s.shuffles(), 2);
        // corner-case loads are guarded; original 3 loads: 1 unshuffled + 2 guarded
        let guarded_loads = s
            .body
            .iter()
            .filter(|st| {
                matches!(
                    st,
                    Statement::Instr {
                        guard: Some(_),
                        op: Op::Ld { .. }
                    }
                )
            })
            .count();
        assert_eq!(guarded_loads, 2);
        assert_eq!(s.global_loads(), 3);
        // both deltas positive here → shfl.sync.down with clamp 31
        for st in &s.body {
            if let Statement::Instr {
                op: Op::Shfl { mode, c, .. },
                ..
            } = st
            {
                assert_eq!(*mode, ShflMode::Down);
                assert_eq!(*c, Operand::ImmInt(31));
            }
        }
        // wid computed once
        let rems = s
            .body
            .iter()
            .filter(|st| {
                matches!(
                    st,
                    Statement::Instr {
                        op: Op::IntBin {
                            op: IntBinOp::Rem,
                            ..
                        },
                        ..
                    }
                )
            })
            .count();
        assert_eq!(rems, 1);
    }

    #[test]
    fn full_variant_reparses() {
        let (k, det) = stencil_detection();
        let s = synthesize(&k, &det, Variant::Full);
        let text = print_kernel(&s);
        let re = parse(&format!(".version 7.6\n.target sm_70\n.address_size 64\n{text}"))
            .unwrap_or_else(|e| panic!("reparse failed: {e}\n{text}"));
        assert_eq!(re.kernels[0], s);
    }

    #[test]
    fn noload_deletes_covered_loads() {
        let (k, det) = stencil_detection();
        let s = synthesize(&k, &det, Variant::NoLoad);
        assert_eq!(s.global_loads(), 1);
        assert_eq!(s.shuffles(), 0);
        // fewer instructions than original
        assert!(s.body.len() < k.body.len());
        // no new registers
        assert_eq!(s.declared_regs(), k.declared_regs());
    }

    #[test]
    fn nocorner_shuffles_without_checker() {
        let (k, det) = stencil_detection();
        let s = synthesize(&k, &det, Variant::NoCorner);
        assert_eq!(s.shuffles(), 2);
        assert_eq!(s.global_loads(), 1);
        // no predicates added
        assert!(!s.regs.iter().any(|r| r.prefix == "%zp"));
    }

    #[test]
    fn uniform_branch_adds_labels() {
        let (k, det) = stencil_detection();
        let s = synthesize(&k, &det, Variant::UniformBranch);
        assert_eq!(s.shuffles(), 2);
        let labels = s
            .body
            .iter()
            .filter(|st| matches!(st, Statement::Label(l) if l.starts_with("$Z")))
            .count();
        assert_eq!(labels, 4); // corner + done per shuffle
        let unis = s
            .body
            .iter()
            .filter(|st| {
                matches!(
                    st,
                    Statement::Instr {
                        op: Op::Bra { uni: true, .. },
                        ..
                    }
                )
            })
            .count();
        assert_eq!(unis, 2);
        // corner path re-issues the load: 1 unshuffled + 2 predicated + 2 corner
        assert_eq!(s.global_loads(), 5);
    }

    #[test]
    fn empty_detection_is_identity() {
        let k = parse_kernel(STENCIL3).unwrap();
        let det = Detection::default();
        let s = synthesize(&k, &det, Variant::Full);
        assert_eq!(s, k);
    }

    #[test]
    fn zero_delta_emits_mov_only() {
        let k = parse_kernel(
            r#"
.visible .entry dup(.param .u64 out, .param .u64 a){
.reg .b32 %r<6>; .reg .b64 %rd<8>; .reg .f32 %f<4>;
ld.param.u64 %rd1, [out];
ld.param.u64 %rd2, [a];
cvta.to.global.u64 %rd3, %rd2;
mov.u32 %r4, %tid.x;
mul.wide.s32 %rd5, %r4, 4;
add.s64 %rd6, %rd3, %rd5;
ld.global.nc.f32 %f1, [%rd6];
ld.global.nc.f32 %f2, [%rd6];
add.f32 %f3, %f1, %f2;
cvta.to.global.u64 %rd4, %rd1;
st.global.f32 [%rd4], %f3;
ret;
}
"#,
        )
        .unwrap();
        let det = analyze(&k).unwrap();
        assert_eq!(det.chosen[0].delta, 0);
        let s = synthesize(&k, &det, Variant::Full);
        assert_eq!(s.shuffles(), 0);
        assert_eq!(s.global_loads(), 1);
        // no wid computation for delta-0-only synthesis
        assert!(!s.regs.iter().any(|r| r.prefix == "%zw"));
    }
}
