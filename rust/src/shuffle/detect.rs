//! Shuffle-candidate detection from symbolic memory traces (paper §5.1).
//!
//! For every pair of valid global loads in the same straight-line segment of
//! the same flow, the solver looks for the integer `N` with
//! `A(%tid.x + N) = B(%tid.x)`, `|N| ≤ 31`. A candidate survives only if it
//! has the *same* delta in every execution flow its destination appears in,
//! and only direct loads serve as sources (no shuffles over shuffled
//! elements). Per destination, the smallest |N| wins — fewest corner cases.

use crate::emu::EmulationResult;
use crate::ptx::ast::{Kernel, Op, Space, Statement};
use crate::sym::solve_delta;
use std::collections::{BTreeMap, BTreeSet, HashSet};

/// One chosen shuffle: cover the load at `dst_stmt` with the value loaded at
/// `src_stmt`, shifted across the warp by `delta` lanes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Candidate {
    pub dst_stmt: usize,
    pub src_stmt: usize,
    /// `N`: negative → `shfl.sync.up`, positive → `shfl.sync.down`,
    /// zero → plain register reuse (`mov`).
    pub delta: i64,
}

/// Detection configuration. `Eq + Hash` so the pipeline's artifact cache
/// can key on the whole struct — a future field automatically becomes
/// part of the cache key.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct DetectOpts {
    /// Reject candidates with `|N|` above this bound (paper §8.5 uses 1).
    pub max_abs_delta: i64,
    /// Also cover shared-memory loads (paper §6: the synthesis "works on
    /// shared memory", though the latency win is nil — see Table 1).
    pub include_shared: bool,
}

impl Default for DetectOpts {
    fn default() -> DetectOpts {
        DetectOpts {
            max_abs_delta: 31,
            include_shared: false,
        }
    }
}

impl DetectOpts {
    /// Feed every field into a stable 128-bit key (the disk cache's
    /// counterpart of the in-memory `(ContentHash, DetectOpts)` key).
    /// Exhaustive destructuring on purpose: adding a field refuses to
    /// compile here until it is made part of the key, keeping the
    /// "a future field automatically becomes part of the cache key"
    /// guarantee true on disk as well.
    pub fn key_into(&self, h: &mut crate::util::Fnv128) {
        let DetectOpts {
            max_abs_delta,
            include_shared,
        } = *self;
        h.write_u64(max_abs_delta as u64);
        h.write_u64(include_shared as u64);
    }
}

/// Detection result (the numbers Table 2 reports).
#[derive(Debug, Clone, Default)]
pub struct Detection {
    pub chosen: Vec<Candidate>,
    /// Static count of global-load statements in the kernel.
    pub total_global_loads: usize,
    pub emu_stats: Option<crate::emu::EmuStats>,
}

impl Detection {
    /// Average |N| over the chosen shuffles (Table 2 "Delta").
    pub fn avg_delta(&self) -> Option<f64> {
        if self.chosen.is_empty() {
            return None;
        }
        let s: i64 = self.chosen.iter().map(|c| c.delta.abs()).sum();
        Some(s as f64 / self.chosen.len() as f64)
    }

    pub fn shuffle_count(&self) -> usize {
        self.chosen.len()
    }
}

/// Run detection on an emulation result.
pub fn detect(kernel: &Kernel, res: &EmulationResult, opts: DetectOpts) -> Detection {
    let total_global_loads = kernel
        .body
        .iter()
        .filter(|s| {
            matches!(
                s,
                Statement::Instr {
                    op: Op::Ld {
                        space: Space::Global,
                        ..
                    },
                    ..
                }
            )
        })
        .count();

    // per destination stmt: candidate (src, delta) sets per flow
    // dst -> (flows seen, intersection of candidate sets)
    let mut per_dst: BTreeMap<usize, (u32, BTreeSet<(usize, i64)>)> = BTreeMap::new();
    // all flows a dst load appears in (even with zero candidates) must agree
    let mut dst_appearances: BTreeMap<usize, u32> = BTreeMap::new();

    for flow in &res.flows {
        let loads: Vec<_> = flow
            .trace
            .loads
            .iter()
            .filter(|l| l.valid && !l.guarded && l.ty.bits() == 32)
            .filter(|l| {
                l.space == Space::Global || (opts.include_shared && l.space == Space::Shared)
            })
            .collect();
        let mut flow_dsts: BTreeMap<usize, BTreeSet<(usize, i64)>> = BTreeMap::new();
        for l in &loads {
            flow_dsts.entry(l.stmt).or_default();
        }
        for (i, b) in loads.iter().enumerate() {
            for a in &loads[..i] {
                if a.stmt == b.stmt || a.segment != b.segment {
                    continue;
                }
                if a.phase != b.phase {
                    // a `bar.sync` separates the two loads: the values are
                    // exchanged through memory at the barrier, so covering
                    // one load with a shuffle of the other is illegal
                    continue;
                }
                if a.ty != b.ty || a.space != b.space {
                    continue;
                }
                if let Some(n) = solve_delta(&res.pool, a.addr, b.addr, res.tid_sym) {
                    if n.abs() <= opts.max_abs_delta {
                        flow_dsts.entry(b.stmt).or_default().insert((a.stmt, n));
                    }
                }
            }
        }
        for (dst, set) in flow_dsts {
            *dst_appearances.entry(dst).or_insert(0) += 1;
            per_dst
                .entry(dst)
                .and_modify(|(n, acc)| {
                    *n += 1;
                    // intersection across flows: same source and same N
                    acc.retain(|c| set.contains(c));
                })
                .or_insert_with(|| (1, set));
        }
    }

    // greedy selection in program order; shuffled loads cannot be sources
    let mut shuffled: HashSet<usize> = HashSet::new();
    let mut chosen = Vec::new();
    for (dst, (flows_with_cands, cands)) in &per_dst {
        // consistency: candidate set must have survived every appearance
        if *flows_with_cands != dst_appearances[dst] {
            continue;
        }
        let best = cands
            .iter()
            .filter(|(src, _)| !shuffled.contains(src))
            .min_by_key(|(src, n)| (n.abs(), *src));
        if let Some(&(src, delta)) = best {
            chosen.push(Candidate {
                dst_stmt: *dst,
                src_stmt: src,
                delta,
            });
            shuffled.insert(*dst);
        }
    }

    Detection {
        chosen,
        total_global_loads,
        emu_stats: Some(res.stats),
    }
}

/// Convenience: analyze a kernel with default options through a one-shot
/// [`crate::pipeline::Pipeline`]. The emulation happens exactly once, as a
/// cached artifact the detection pass consumes — `detect` itself never
/// emulates (it is a pure function of an [`EmulationResult`]).
pub fn analyze(kernel: &Kernel) -> Result<Detection, crate::emu::EmuError> {
    let p = crate::pipeline::Pipeline::new();
    let det = p.detected(&std::sync::Arc::new(kernel.clone()), DetectOpts::default())?;
    Ok(det.detection.clone())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::emu::emulate;
    use crate::ptx::parser::parse_kernel;

    /// 1D 3-point stencil: out[i] = a[i-1] + a[i] + a[i+1].
    /// Expect 2 shuffles: a[i] ← a[i-1] (N=1), a[i+1] ← a[i-1] (N=2).
    const STENCIL3: &str = r#"
.visible .entry s3(.param .u64 out, .param .u64 a){
.reg .b32 %r<6>; .reg .b64 %rd<8>; .reg .f32 %f<6>; .reg .pred %p<2>;
ld.param.u64 %rd1, [out];
ld.param.u64 %rd2, [a];
cvta.to.global.u64 %rd3, %rd2;
cvta.to.global.u64 %rd4, %rd1;
mov.u32 %r2, %ntid.x;
mov.u32 %r3, %ctaid.x;
mov.u32 %r4, %tid.x;
mad.lo.s32 %r1, %r3, %r2, %r4;
mul.wide.s32 %rd5, %r1, 4;
add.s64 %rd6, %rd3, %rd5;
ld.global.nc.f32 %f1, [%rd6];
ld.global.nc.f32 %f2, [%rd6+4];
ld.global.nc.f32 %f3, [%rd6+8];
add.f32 %f4, %f1, %f2;
add.f32 %f5, %f4, %f3;
add.s64 %rd7, %rd4, %rd5;
st.global.f32 [%rd7], %f5;
ret;
}
"#;

    #[test]
    fn detects_stencil_shuffles() {
        let k = parse_kernel(STENCIL3).unwrap();
        let res = emulate(&k).unwrap();
        let det = detect(&k, &res, DetectOpts::default());
        assert_eq!(det.total_global_loads, 3);
        assert_eq!(det.shuffle_count(), 2);
        // loads at stmts 10,11,12: dst 11 ← src 10 (N=1); dst 12 ← src 10 (N=2)
        let mut deltas: Vec<i64> = det.chosen.iter().map(|c| c.delta).collect();
        deltas.sort();
        assert_eq!(deltas, vec![1, 2]);
        assert_eq!(det.avg_delta(), Some(1.5));
        // both shuffles source from the first (unshuffled) load
        let srcs: Vec<usize> = det.chosen.iter().map(|c| c.src_stmt).collect();
        assert!(srcs.iter().all(|&s| s == det.chosen[0].src_stmt));
    }

    #[test]
    fn detect_consumes_cached_emulation_and_never_emulates() {
        use crate::pipeline::{Pipeline, Stage};
        use std::sync::Arc;

        let k = Arc::new(parse_kernel(STENCIL3).unwrap());
        let p = Pipeline::new();
        let emu = p.emulated(&k).unwrap();
        let before = p.stats();
        // `detect` is a pure function of the emulation artifact: running it
        // must not emulate (no new stage run, no new cache traffic)
        let det = detect(&k, &emu.result, DetectOpts::default());
        assert_eq!(det.shuffle_count(), 2);
        let after = p.stats();
        assert_eq!(after.stage_count(Stage::Emulate), 1);
        assert_eq!(
            before.cache.emulate_misses,
            after.cache.emulate_misses
        );
        // detection through the pass manager reuses the same artifact
        let d2 = p.detected(&k, DetectOpts::default()).unwrap();
        assert_eq!(d2.detection.chosen, det.chosen);
        let s = p.stats();
        assert_eq!(s.stage_count(Stage::Emulate), 1, "still exactly one emulation");
        assert_eq!(s.cache.emulate_misses, 1);
        assert!(s.cache.emulate_hits >= 1);
    }

    #[test]
    fn max_abs_delta_limits_selection() {
        let k = parse_kernel(STENCIL3).unwrap();
        let res = emulate(&k).unwrap();
        let det = detect(&k, &res, DetectOpts { max_abs_delta: 1, ..Default::default() });
        // only the N=1 candidate fits; N=2 via the middle load is rejected
        // because the middle load became a shuffle destination itself —
        // but its candidate (src=middle, N=1) is still admissible.
        for c in &det.chosen {
            assert!(c.delta.abs() <= 1);
        }
        assert_eq!(det.shuffle_count(), 1);
    }

    #[test]
    fn no_shared_array_no_shuffles() {
        // vecadd: c[i] = a[i] + b[i] — no two loads share an array
        let k = parse_kernel(
            r#"
.visible .entry vadd(.param .u64 c, .param .u64 a, .param .u64 b){
.reg .b32 %r<6>; .reg .b64 %rd<10>; .reg .f32 %f<4>;
ld.param.u64 %rd1, [c];
ld.param.u64 %rd2, [a];
ld.param.u64 %rd3, [b];
cvta.to.global.u64 %rd4, %rd2;
cvta.to.global.u64 %rd5, %rd3;
cvta.to.global.u64 %rd6, %rd1;
mov.u32 %r2, %ntid.x;
mov.u32 %r3, %ctaid.x;
mov.u32 %r4, %tid.x;
mad.lo.s32 %r1, %r3, %r2, %r4;
mul.wide.s32 %rd7, %r1, 4;
add.s64 %rd8, %rd4, %rd7;
add.s64 %rd9, %rd5, %rd7;
ld.global.nc.f32 %f1, [%rd8];
ld.global.nc.f32 %f2, [%rd9];
add.f32 %f3, %f1, %f2;
add.s64 %rd8, %rd6, %rd7;
st.global.f32 [%rd8], %f3;
ret;
}
"#,
        )
        .unwrap();
        let det = analyze(&k).unwrap();
        assert_eq!(det.total_global_loads, 2);
        assert_eq!(det.shuffle_count(), 0);
        assert_eq!(det.avg_delta(), None);
    }

    #[test]
    fn non_tid_dimension_not_shuffled() {
        // loads differ along a non-leading (row) dimension: addresses differ
        // by nx*4 bytes where nx is symbolic — no constant delta exists
        let k = parse_kernel(
            r#"
.visible .entry rows(.param .u64 out, .param .u64 a, .param .u32 nx){
.reg .b32 %r<8>; .reg .b64 %rd<8>; .reg .f32 %f<4>;
ld.param.u64 %rd1, [out];
ld.param.u64 %rd2, [a];
ld.param.u32 %r5, [nx];
cvta.to.global.u64 %rd3, %rd2;
mov.u32 %r4, %tid.x;
mad.lo.s32 %r1, %r5, 1, %r4;
mul.wide.s32 %rd5, %r1, 4;
add.s64 %rd6, %rd3, %rd5;
mul.wide.s32 %rd7, %r5, 4;
ld.global.nc.f32 %f1, [%rd6];
add.s64 %rd6, %rd6, %rd7;
ld.global.nc.f32 %f2, [%rd6];
add.f32 %f3, %f1, %f2;
cvta.to.global.u64 %rd4, %rd1;
st.global.f32 [%rd4], %f3;
ret;
}
"#,
        )
        .unwrap();
        let det = analyze(&k).unwrap();
        assert_eq!(det.shuffle_count(), 0);
    }

    #[test]
    fn same_address_gives_zero_delta() {
        let k = parse_kernel(
            r#"
.visible .entry dup(.param .u64 out, .param .u64 a){
.reg .b32 %r<6>; .reg .b64 %rd<8>; .reg .f32 %f<4>;
ld.param.u64 %rd1, [out];
ld.param.u64 %rd2, [a];
cvta.to.global.u64 %rd3, %rd2;
mov.u32 %r4, %tid.x;
mul.wide.s32 %rd5, %r4, 4;
add.s64 %rd6, %rd3, %rd5;
ld.global.nc.f32 %f1, [%rd6];
ld.global.nc.f32 %f2, [%rd6];
add.f32 %f3, %f1, %f2;
cvta.to.global.u64 %rd4, %rd1;
st.global.f32 [%rd4], %f3;
ret;
}
"#,
        )
        .unwrap();
        let det = analyze(&k).unwrap();
        assert_eq!(det.shuffle_count(), 1);
        assert_eq!(det.chosen[0].delta, 0);
    }

    #[test]
    fn guard_divergence_keeps_consistent_candidates() {
        // the two loads sit behind a guard — both flows must agree
        let k = parse_kernel(
            r#"
.visible .entry g(.param .u64 out, .param .u64 a, .param .u32 n){
.reg .b32 %r<8>; .reg .b64 %rd<8>; .reg .f32 %f<4>; .reg .pred %p<2>;
ld.param.u64 %rd1, [out];
ld.param.u64 %rd2, [a];
ld.param.u32 %r5, [n];
cvta.to.global.u64 %rd3, %rd2;
mov.u32 %r4, %tid.x;
setp.ge.s32 %p1, %r4, %r5;
@%p1 bra $EXIT;
mul.wide.s32 %rd5, %r4, 4;
add.s64 %rd6, %rd3, %rd5;
ld.global.nc.f32 %f1, [%rd6];
ld.global.nc.f32 %f2, [%rd6+4];
add.f32 %f3, %f1, %f2;
cvta.to.global.u64 %rd4, %rd1;
add.s64 %rd7, %rd4, %rd5;
st.global.f32 [%rd7], %f3;
$EXIT: ret;
}
"#,
        )
        .unwrap();
        let det = analyze(&k).unwrap();
        assert_eq!(det.shuffle_count(), 1);
        assert_eq!(det.chosen[0].delta, 1);
    }

    #[test]
    fn barrier_after_both_loads_does_not_veto() {
        // boundary case for the cross-phase veto: the veto fires only when
        // a `bar.sync` sits *between* the two loads. Here the barrier comes
        // after both, so they share a phase and the candidate must survive.
        let k = parse_kernel(
            r#"
.visible .entry bafter(.param .u64 out, .param .u64 a){
.reg .b32 %r<6>; .reg .b64 %rd<8>; .reg .f32 %f<4>;
ld.param.u64 %rd1, [out];
ld.param.u64 %rd2, [a];
cvta.to.global.u64 %rd3, %rd2;
cvta.to.global.u64 %rd4, %rd1;
mov.u32 %r4, %tid.x;
mul.wide.s32 %rd5, %r4, 4;
add.s64 %rd6, %rd3, %rd5;
ld.global.nc.f32 %f1, [%rd6];
ld.global.nc.f32 %f2, [%rd6+4];
bar.sync 0;
add.f32 %f3, %f1, %f2;
add.s64 %rd7, %rd4, %rd5;
st.global.f32 [%rd7], %f3;
ret;
}
"#,
        )
        .unwrap();
        let res = emulate(&k).unwrap();
        let det = detect(&k, &res, DetectOpts::default());
        assert_eq!(det.total_global_loads, 2);
        assert_eq!(det.shuffle_count(), 1, "same-phase loads must not be vetoed");
        assert_eq!(det.chosen[0].delta, 1);
    }

    #[test]
    fn f64_loads_not_shuffled() {
        // 32-bit shuffles only (paper §2.3)
        let k = parse_kernel(
            r#"
.visible .entry d(.param .u64 out, .param .u64 a){
.reg .b32 %r<6>; .reg .b64 %rd<8>; .reg .f64 %fd<4>;
ld.param.u64 %rd2, [a];
cvta.to.global.u64 %rd3, %rd2;
mov.u32 %r4, %tid.x;
mul.wide.s32 %rd5, %r4, 8;
add.s64 %rd6, %rd3, %rd5;
ld.global.nc.f64 %fd1, [%rd6];
ld.global.nc.f64 %fd2, [%rd6+8];
add.f64 %fd3, %fd1, %fd2;
ld.param.u64 %rd1, [out];
cvta.to.global.u64 %rd4, %rd1;
st.global.f64 [%rd4], %fd3;
ret;
}
"#,
        )
        .unwrap();
        let det = analyze(&k).unwrap();
        assert_eq!(det.shuffle_count(), 0);
    }
}
