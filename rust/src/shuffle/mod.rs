//! Shuffle synthesis: detection over memory traces + PTX rewriting (§5).

pub mod cfg;
pub mod detect;
pub mod liveness;
pub mod synth;

pub use cfg::Cfg;
pub use detect::{analyze, detect, Candidate, DetectOpts, Detection};
pub use liveness::Liveness;
pub use synth::{synthesize, Variant};
