//! Shuffle synthesis: detection over memory traces + PTX rewriting (§5).

pub mod cfg;
pub mod detect;
pub mod elim;
pub mod liveness;
pub mod phase_liveness;
pub mod synth;

pub use cfg::Cfg;
pub use detect::{analyze, detect, Candidate, DetectOpts, Detection};
pub use elim::eliminate;
pub use liveness::Liveness;
pub use phase_liveness::{plan, ElimOpts, ElimReport};
pub use synth::{synthesize, Variant};
