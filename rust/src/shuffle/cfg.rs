//! Control-flow graph over kernel statements.
//!
//! Built before shuffle detection (paper §5.1 "for faster analysis, we
//! construct control-flow graphs before shuffle detection"); also consumed
//! by the liveness analysis and the performance model's block-level walk.

use crate::ptx::ast::{Kernel, Op, Statement};
use std::collections::HashMap;

/// A basic block: a maximal straight-line statement range.
#[derive(Debug, Clone, PartialEq)]
pub struct Block {
    /// Index into `Cfg::blocks`.
    pub id: usize,
    /// Statement range `[start, end)` in the kernel body.
    pub start: usize,
    pub end: usize,
    /// Successor block ids.
    pub succs: Vec<usize>,
    /// Predecessor block ids.
    pub preds: Vec<usize>,
}

#[derive(Debug)]
pub struct Cfg {
    pub blocks: Vec<Block>,
    /// Statement index → owning block id.
    pub block_of: Vec<usize>,
}

impl Cfg {
    pub fn build(k: &Kernel) -> Cfg {
        let n = k.body.len();
        let mut labels: HashMap<&str, usize> = HashMap::new();
        for (i, st) in k.body.iter().enumerate() {
            if let Statement::Label(l) = st {
                labels.insert(l.as_str(), i);
            }
        }

        // leaders: stmt 0, every label, every stmt after a branch/ret
        let mut leader = vec![false; n.max(1)];
        if n > 0 {
            leader[0] = true;
        }
        for (i, st) in k.body.iter().enumerate() {
            match st {
                Statement::Label(_) => leader[i] = true,
                Statement::Instr { op, .. } => {
                    if matches!(op, Op::Bra { .. } | Op::Ret | Op::Exit) && i + 1 < n {
                        leader[i + 1] = true;
                    }
                }
            }
        }

        let mut blocks = Vec::new();
        let mut block_of = vec![0usize; n];
        let mut start = 0;
        for i in 0..n {
            if i > start && leader[i] {
                blocks.push(Block {
                    id: blocks.len(),
                    start,
                    end: i,
                    succs: Vec::new(),
                    preds: Vec::new(),
                });
                start = i;
            }
        }
        if n > 0 {
            blocks.push(Block {
                id: blocks.len(),
                start,
                end: n,
                succs: Vec::new(),
                preds: Vec::new(),
            });
        }
        for b in &blocks {
            for i in b.start..b.end {
                block_of[i] = b.id;
            }
        }

        // edges
        let mut edges: Vec<(usize, usize)> = Vec::new();
        for b in &blocks {
            let last = b.end - 1;
            match &k.body[last] {
                Statement::Instr { guard, op } => match op {
                    Op::Bra { target, .. } => {
                        if let Some(&t) = labels.get(target.as_str()) {
                            edges.push((b.id, block_of[t]));
                        }
                        if guard.is_some() && b.end < n {
                            edges.push((b.id, block_of[b.end]));
                        }
                    }
                    Op::Ret | Op::Exit => {}
                    _ => {
                        if b.end < n {
                            edges.push((b.id, block_of[b.end]));
                        }
                    }
                },
                Statement::Label(_) => {
                    if b.end < n {
                        edges.push((b.id, block_of[b.end]));
                    }
                }
            }
        }
        for (f, t) in edges {
            blocks[f].succs.push(t);
            blocks[t].preds.push(f);
        }

        Cfg { blocks, block_of }
    }

    /// Are `a` and `b` (statement indices) in the same basic block?
    pub fn same_block(&self, a: usize, b: usize) -> bool {
        self.block_of[a] == self.block_of[b]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ptx::parser::parse_kernel;

    const K: &str = r#"
.visible .entry k(.param .u64 a){
.reg .b32 %r<4>; .reg .pred %p<2>; .reg .b64 %rd<4>; .reg .f32 %f<4>;
mov.u32 %r1, %tid.x;
setp.lt.s32 %p1, %r1, 16;
@%p1 bra $SKIP;
ld.global.f32 %f1, [%rd1];
ld.global.f32 %f2, [%rd1+4];
$SKIP:
st.global.f32 [%rd1], %f1;
ret;
}
"#;

    #[test]
    fn builds_blocks_and_edges() {
        let k = parse_kernel(K).unwrap();
        let cfg = Cfg::build(&k);
        // blocks: [entry..bra], [two loads], [label..ret]
        assert_eq!(cfg.blocks.len(), 3);
        assert_eq!(cfg.blocks[0].succs.len(), 2); // taken + fallthrough
        assert_eq!(cfg.blocks[1].succs, vec![2]);
        assert!(cfg.blocks[2].succs.is_empty());
        assert_eq!(cfg.blocks[2].preds.len(), 2);
        // the two loads share a block; the store does not
        assert!(cfg.same_block(3, 4));
        assert!(!cfg.same_block(4, 6));
    }

    #[test]
    fn loop_back_edge() {
        let k = parse_kernel(
            r#"
.visible .entry k(.param .u64 a){
.reg .b32 %r<3>; .reg .pred %p<2>;
mov.u32 %r1, 0;
$L:
add.s32 %r1, %r1, 1;
setp.lt.s32 %p1, %r1, 10;
@%p1 bra $L;
ret;
}
"#,
        )
        .unwrap();
        let cfg = Cfg::build(&k);
        // find the block ending in the conditional bra; it must point at the
        // block containing the label $L and at the ret block
        let bra_block = cfg
            .blocks
            .iter()
            .find(|b| b.succs.len() == 2)
            .expect("conditional branch block");
        let label_block = cfg.block_of[1]; // stmt 1 is $L
        assert!(bra_block.succs.contains(&label_block));
    }

    #[test]
    fn empty_kernel() {
        let k = parse_kernel(".visible .entry k(){ ret; }").unwrap();
        let cfg = Cfg::build(&k);
        assert_eq!(cfg.blocks.len(), 1);
    }
}
