//! Live-variable analysis over the CFG.
//!
//! Used two ways: (1) paper §5.1 — exclude shuffle sources whose value may
//! reflect a different loop iteration than the destination (the source
//! register must stay live and unclobbered from source load to destination
//! load); (2) the performance model uses max-live as its SASS register-count
//! estimate, since the declared virtual registers overstate real pressure.

use super::cfg::Cfg;
use crate::emu::env::RegInterner;
use crate::emu::induction::written_reg;
use crate::ptx::ast::{Address, Kernel, Op, Operand, Statement};

/// Per-statement use/def sets (register ids).
#[derive(Debug, Clone, Default)]
pub struct UseDef {
    pub uses: Vec<u32>,
    pub defs: Vec<u32>,
}

fn add_operand(uses: &mut Vec<u32>, regs: &mut RegInterner, o: &Operand) {
    if let Operand::Reg(r) = o {
        uses.push(regs.intern(r));
    }
}

fn add_addr(uses: &mut Vec<u32>, regs: &mut RegInterner, a: &Address) {
    if let Operand::Reg(r) = &a.base {
        uses.push(regs.intern(r));
    }
}

/// Use/def sets for every statement.
pub fn use_defs(k: &Kernel, regs: &mut RegInterner) -> Vec<UseDef> {
    k.body
        .iter()
        .map(|st| {
            let mut ud = UseDef::default();
            let Statement::Instr { guard, op } = st else {
                return ud;
            };
            if let Some(g) = guard {
                ud.uses.push(regs.intern(&g.reg));
                // a guarded write is also a read of the old value
                if let Some(d) = written_reg(op) {
                    ud.uses.push(regs.intern(d));
                }
            }
            match op {
                Op::Ld { addr, .. } => add_addr(&mut ud.uses, regs, addr),
                Op::St { addr, src, .. } => {
                    add_addr(&mut ud.uses, regs, addr);
                    add_operand(&mut ud.uses, regs, src);
                }
                Op::Mov { src, .. } | Op::Cvta { src, .. } | Op::Cvt { src, .. } => {
                    add_operand(&mut ud.uses, regs, src)
                }
                Op::IntBin { a, b, .. } | Op::FltBin { a, b, .. } | Op::Setp { a, b, .. } => {
                    add_operand(&mut ud.uses, regs, a);
                    add_operand(&mut ud.uses, regs, b);
                }
                Op::Mad { a, b, c, .. } | Op::Fma { a, b, c, .. } => {
                    add_operand(&mut ud.uses, regs, a);
                    add_operand(&mut ud.uses, regs, b);
                    add_operand(&mut ud.uses, regs, c);
                }
                Op::Selp { a, b, p, .. } => {
                    add_operand(&mut ud.uses, regs, a);
                    add_operand(&mut ud.uses, regs, b);
                    add_operand(&mut ud.uses, regs, p);
                }
                Op::Not { a, .. } | Op::Neg { a, .. } | Op::FltUn { a, .. } => {
                    add_operand(&mut ud.uses, regs, a)
                }
                Op::Shfl { src, b, c, mask, .. } => {
                    add_operand(&mut ud.uses, regs, src);
                    add_operand(&mut ud.uses, regs, b);
                    add_operand(&mut ud.uses, regs, c);
                    add_operand(&mut ud.uses, regs, mask);
                }
                Op::Activemask { .. }
                | Op::Bra { .. }
                | Op::BarSync { .. }
                | Op::Ret
                | Op::Exit => {}
            }
            if let Some(d) = written_reg(op) {
                ud.defs.push(regs.intern(d));
            }
            if let Op::Shfl { pred_out: Some(p), .. } = op {
                ud.defs.push(regs.intern(p));
            }
            ud
        })
        .collect()
}

/// Result of the backward dataflow.
#[derive(Debug)]
pub struct Liveness {
    /// live-in set per statement (bitset over register ids).
    pub live_in: Vec<Vec<u64>>,
    pub nregs: usize,
}

fn set(bits: &mut [u64], i: u32) {
    bits[(i / 64) as usize] |= 1 << (i % 64);
}
fn get(bits: &[u64], i: u32) -> bool {
    bits[(i / 64) as usize] >> (i % 64) & 1 == 1
}
fn clear(bits: &mut [u64], i: u32) {
    bits[(i / 64) as usize] &= !(1 << (i % 64));
}
fn or_into(dst: &mut [u64], src: &[u64]) -> bool {
    let mut changed = false;
    for (d, s) in dst.iter_mut().zip(src) {
        let n = *d | *s;
        changed |= n != *d;
        *d = n;
    }
    changed
}

impl Liveness {
    pub fn compute(k: &Kernel, cfg: &Cfg, regs: &mut RegInterner) -> Liveness {
        let uds = use_defs(k, regs);
        let nregs = regs.len();
        let words = nregs.div_ceil(64).max(1);
        let nstmt = k.body.len();
        let mut live_in = vec![vec![0u64; words]; nstmt.max(1)];

        // iterate to fixpoint over blocks in reverse
        let mut changed = true;
        while changed {
            changed = false;
            for b in cfg.blocks.iter().rev() {
                // live-out of block = union of successors' live-in
                let mut live: Vec<u64> = vec![0; words];
                for &s in &b.succs {
                    let first = cfg.blocks[s].start;
                    let snapshot = live_in[first].clone();
                    or_into(&mut live, &snapshot);
                }
                // walk statements backwards
                for i in (b.start..b.end).rev() {
                    for &d in &uds[i].defs {
                        clear(&mut live, d);
                    }
                    for &u in &uds[i].uses {
                        set(&mut live, u);
                    }
                    changed |= or_into(&mut live_in[i], &live);
                    live.copy_from_slice(&live_in[i]);
                }
            }
        }

        Liveness { live_in, nregs }
    }

    pub fn is_live_in(&self, stmt: usize, reg: u32) -> bool {
        get(&self.live_in[stmt], reg)
    }

    /// Maximum number of simultaneously-live registers — the SASS register
    /// estimate the perf model feeds into the occupancy calculation.
    pub fn max_live(&self) -> u32 {
        self.live_in
            .iter()
            .map(|bits| bits.iter().map(|w| w.count_ones()).sum::<u32>())
            .max()
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ptx::ast::Reg;
    use crate::ptx::parser::parse_kernel;

    #[test]
    fn straight_line_liveness() {
        let k = parse_kernel(
            r#"
.visible .entry k(.param .u64 a){
.reg .b32 %r<4>; .reg .b64 %rd<3>; .reg .f32 %f<4>;
ld.param.u64 %rd1, [a];
cvta.to.global.u64 %rd2, %rd1;
ld.global.f32 %f1, [%rd2];
ld.global.f32 %f2, [%rd2+4];
add.f32 %f3, %f1, %f2;
st.global.f32 [%rd2], %f3;
ret;
}
"#,
        )
        .unwrap();
        let cfg = Cfg::build(&k);
        let mut regs = RegInterner::from_kernel(&k);
        let lv = Liveness::compute(&k, &cfg, &mut regs);
        let f1 = regs.get(&Reg::new("%f1")).unwrap();
        let f3 = regs.get(&Reg::new("%f3")).unwrap();
        // %f1 live between its def (stmt 2) and use (stmt 4)
        assert!(lv.is_live_in(3, f1));
        assert!(lv.is_live_in(4, f1));
        assert!(!lv.is_live_in(5, f1));
        // %f3 live into the store
        assert!(lv.is_live_in(5, f3));
        assert!(lv.max_live() >= 3);
    }

    #[test]
    fn loop_keeps_accumulator_live() {
        let k = parse_kernel(
            r#"
.visible .entry k(.param .u64 a){
.reg .b32 %r<3>; .reg .pred %p<2>; .reg .f32 %f<3>; .reg .b64 %rd<3>;
mov.u32 %r1, 0;
mov.f32 %f1, 0f00000000;
$L:
add.f32 %f1, %f1, %f1;
add.s32 %r1, %r1, 1;
setp.lt.s32 %p1, %r1, 10;
@%p1 bra $L;
st.global.f32 [%rd1], %f1;
ret;
}
"#,
        )
        .unwrap();
        let cfg = Cfg::build(&k);
        let mut regs = RegInterner::from_kernel(&k);
        let lv = Liveness::compute(&k, &cfg, &mut regs);
        let f1 = regs.get(&Reg::new("%f1")).unwrap();
        let r1 = regs.get(&Reg::new("%r1")).unwrap();
        // both live around the back edge (live-in at the label statement)
        assert!(lv.is_live_in(2, f1));
        assert!(lv.is_live_in(2, r1));
    }

    #[test]
    fn diamond_cfg_liveness_hand_computed() {
        // Diamond: both arms redefine %r3 from %r2, the join reads %r3.
        //
        //   stmt  instruction              live-in (hand-computed)
        //   0     mov %r1, %tid.x          {rd1}
        //   1     mov %r2, 7               {rd1, r1}
        //   2     setp %p1, %r1 < 4        {rd1, r2, r1}
        //   3     @%p1 bra $THEN           {rd1, r2, p1}
        //   4     add %r3, %r2, 1          {rd1, r2}
        //   5     bra $JOIN                {rd1, r3}
        //   6     $THEN:                   {rd1, r2}
        //   7     add %r3, %r2, 2          {rd1, r2}
        //   8     $JOIN:                   {rd1, r3}
        //   9     st [%rd1], %r3           {rd1, r3}
        //   10    ret                      {}
        //
        // (%rd1 is never defined, so it is live-in everywhere it can reach.)
        let k = parse_kernel(
            r#"
.visible .entry d(.param .u64 a){
.reg .b32 %r<6>; .reg .pred %p<2>; .reg .b64 %rd<3>;
mov.u32 %r1, %tid.x;
mov.u32 %r2, 7;
setp.lt.s32 %p1, %r1, 4;
@%p1 bra $THEN;
add.s32 %r3, %r2, 1;
bra $JOIN;
$THEN:
add.s32 %r3, %r2, 2;
$JOIN:
st.global.b32 [%rd1], %r3;
ret;
}
"#,
        )
        .unwrap();
        let cfg = Cfg::build(&k);
        let mut regs = RegInterner::from_kernel(&k);
        let lv = Liveness::compute(&k, &cfg, &mut regs);
        let r2 = regs.get(&Reg::new("%r2")).unwrap();
        let r3 = regs.get(&Reg::new("%r3")).unwrap();
        let p1 = regs.get(&Reg::new("%p1")).unwrap();
        // %r2 flows down BOTH arms but dies at the join
        assert!(lv.is_live_in(4, r2));
        assert!(lv.is_live_in(7, r2));
        assert!(!lv.is_live_in(9, r2));
        // %r3 is born in each arm and live only from there to the store
        assert!(!lv.is_live_in(4, r3));
        assert!(!lv.is_live_in(7, r3));
        assert!(lv.is_live_in(5, r3));
        assert!(lv.is_live_in(9, r3));
        // the branch predicate dies at the branch
        assert!(lv.is_live_in(3, p1));
        assert!(!lv.is_live_in(4, p1));
        // peak pressure: {rd1, r2, r1} at stmt 2 / {rd1, r2, p1} at stmt 3
        assert_eq!(lv.max_live(), 3);
    }

    #[test]
    fn loop_max_live_matches_hand_computed_table() {
        //   stmt  instruction              live-in (hand-computed fixpoint)
        //   0     mov %r1, 0               {rd1}
        //   1     mov %f1, 0.0             {rd1, r1}
        //   2     $L:                      {rd1, f1, r1}
        //   3     add %f1, %f1, %f1        {rd1, f1, r1}
        //   4     add %r1, %r1, 1          {rd1, f1, r1}
        //   5     setp %p1, %r1 < 10       {rd1, f1, r1}
        //   6     @%p1 bra $L              {rd1, f1, r1, p1}
        //   7     st [%rd1], %f1           {rd1, f1}
        //   8     ret                      {}
        let k = parse_kernel(
            r#"
.visible .entry l(.param .u64 a){
.reg .b32 %r<4>; .reg .pred %p<2>; .reg .f32 %f<3>; .reg .b64 %rd<3>;
mov.u32 %r1, 0;
mov.f32 %f1, 0f00000000;
$L:
add.f32 %f1, %f1, %f1;
add.s32 %r1, %r1, 1;
setp.lt.s32 %p1, %r1, 10;
@%p1 bra $L;
st.global.f32 [%rd1], %f1;
ret;
}
"#,
        )
        .unwrap();
        let cfg = Cfg::build(&k);
        let mut regs = RegInterner::from_kernel(&k);
        let lv = Liveness::compute(&k, &cfg, &mut regs);
        let r1 = regs.get(&Reg::new("%r1")).unwrap();
        let f1 = regs.get(&Reg::new("%f1")).unwrap();
        let p1 = regs.get(&Reg::new("%p1")).unwrap();
        // the back edge keeps both accumulator and counter live at the header
        assert!(lv.is_live_in(2, f1));
        assert!(lv.is_live_in(2, r1));
        assert!(!lv.is_live_in(2, p1));
        // the counter dies after the branch, the accumulator reaches the store
        assert!(!lv.is_live_in(7, r1));
        assert!(lv.is_live_in(7, f1));
        // peak pressure: {rd1, f1, r1, p1} flowing into the guarded branch
        assert!(lv.is_live_in(6, p1));
        assert_eq!(lv.max_live(), 4);
    }

    #[test]
    fn guarded_write_reads_old_value() {
        let k = parse_kernel(
            r#"
.visible .entry k(.param .u64 a){
.reg .b32 %r<4>; .reg .pred %p<2>;
mov.u32 %r1, 0;
mov.u32 %r2, %tid.x;
setp.lt.s32 %p1, %r2, 4;
@%p1 mov.u32 %r1, 1;
st.global.b32 [%rd1], %r1;
ret;
}
"#,
        )
        .unwrap();
        let cfg = Cfg::build(&k);
        let mut regs = RegInterner::from_kernel(&k);
        let lv = Liveness::compute(&k, &cfg, &mut regs);
        let r1 = regs.get(&Reg::new("%r1")).unwrap();
        // %r1 must be live INTO the guarded mov (its old value may survive)
        assert!(lv.is_live_in(3, r1));
    }
}
