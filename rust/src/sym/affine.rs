//! Affine normal forms over symbolic atoms.
//!
//! The two solver queries the paper sends to Z3 — branch-feasibility under
//! recorded assumptions (§4.2) and the shuffle-delta equation
//! `A(%tid.x + N) = B(%tid.x)` (§5.1) — both reduce, for compiler-generated
//! address arithmetic, to reasoning about *affine* combinations of a small
//! set of atoms (parameters, loop-iterator UFs, loaded values). This module
//! extracts `Σ cᵢ·atomᵢ + k` from a term, looking through sign/zero
//! extensions and widening multiplies the way NVHPC emits them.
//!
//! Looking through `sext` is the integer relaxation of the bitvector
//! problem: it is exact as long as index arithmetic does not overflow 32
//! bits, which the paper's own induction-variable treatment also assumes
//! (DESIGN.md lists this as the Z3 substitution).

use super::term::{BvOp, Node, TermId, TermPool};
use std::collections::BTreeMap;

/// An affine form `Σ coeff·atom + constant` where atoms are irreducible
/// term ids (symbols, UF applications, or opaque subtrees).
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct Affine {
    pub coeffs: BTreeMap<TermId, i128>,
    pub constant: i128,
}

impl Affine {
    pub fn constant(k: i128) -> Affine {
        Affine {
            coeffs: BTreeMap::new(),
            constant: k,
        }
    }

    pub fn atom(t: TermId) -> Affine {
        let mut coeffs = BTreeMap::new();
        coeffs.insert(t, 1);
        Affine {
            coeffs,
            constant: 0,
        }
    }

    pub fn is_constant(&self) -> bool {
        self.coeffs.is_empty()
    }

    pub fn add(&self, other: &Affine) -> Affine {
        let mut out = self.clone();
        out.constant += other.constant;
        for (&t, &c) in &other.coeffs {
            let e = out.coeffs.entry(t).or_insert(0);
            *e += c;
            if *e == 0 {
                out.coeffs.remove(&t);
            }
        }
        out
    }

    pub fn sub(&self, other: &Affine) -> Affine {
        self.add(&other.scale(-1))
    }

    pub fn scale(&self, k: i128) -> Affine {
        if k == 0 {
            return Affine::constant(0);
        }
        Affine {
            coeffs: self.coeffs.iter().map(|(&t, &c)| (t, c * k)).collect(),
            constant: self.constant * k,
        }
    }

    /// Coefficient of `atom` (0 if absent).
    pub fn coeff(&self, atom: TermId) -> i128 {
        self.coeffs.get(&atom).copied().unwrap_or(0)
    }

    /// The form with `atom` removed.
    pub fn without(&self, atom: TermId) -> Affine {
        let mut out = self.clone();
        out.coeffs.remove(&atom);
        out
    }
}

/// Extract the affine form of `t`, treating non-affine subtrees as atoms.
///
/// `deep_atoms`: when extracting fails inside an extension, the whole
/// extension node becomes the atom, so two addresses that share the
/// extension subtree still cancel in a difference.
pub fn extract(pool: &TermPool, t: TermId) -> Affine {
    match pool.node(t) {
        Node::Const { bits, width } => Affine::constant(super::term::to_signed(*bits, *width)),
        Node::Sym { .. } | Node::Uf { .. } => Affine::atom(t),
        Node::Bin { op, a, b, .. } => {
            let (a, b) = (*a, *b);
            match op {
                BvOp::Add => extract(pool, a).add(&extract(pool, b)),
                BvOp::Sub => extract(pool, a).sub(&extract(pool, b)),
                BvOp::Mul => {
                    let fa = extract(pool, a);
                    let fb = extract(pool, b);
                    if fb.is_constant() {
                        fa.scale(fb.constant)
                    } else if fa.is_constant() {
                        fb.scale(fa.constant)
                    } else {
                        Affine::atom(t)
                    }
                }
                BvOp::Shl => {
                    let fb = extract(pool, b);
                    if fb.is_constant() && (0..63).contains(&fb.constant) {
                        extract(pool, a).scale(1i128 << fb.constant)
                    } else {
                        Affine::atom(t)
                    }
                }
                _ => Affine::atom(t),
            }
        }
        // `sext` preserves the signed value exactly, so looking through it is
        // sound in the signed affine domain (the relaxation is only the
        // assume-no-overflow of the inner arithmetic). `zext` does NOT
        // preserve signed values (bit pattern 0x8000_0000 zexts to a large
        // positive), so it stays atomic — which also lets the solver's
        // `form_nonneg` recognize provably non-negative forms.
        Node::SExt { a, .. } => extract(pool, *a),
        _ => Affine::atom(t),
    }
}

/// `d(addr)/d(tid)` and the residue: write `extract(t)` as
/// `stride·tid + rest`, where `tid` may occur either as a direct atom or
/// inside atoms we can linearize. Returns `(stride, rest)`.
pub fn split_on(pool: &TermPool, t: TermId, tid_atom: TermId) -> (i128, Affine) {
    let f = linearize_atom_through(pool, extract(pool, t), tid_atom);
    let stride = f.coeff(tid_atom);
    (stride, f.without(tid_atom))
}

/// Re-expand atoms that *contain* `target` linearly (e.g. an opaque
/// `Mul(nonconst, nonconst)` will stay opaque, but `UF` atoms never expand;
/// only recursion through extract already handled extensions). Since
/// `extract` already looks through extensions, the only remaining hidden
/// occurrences are inside genuinely non-linear atoms — leave those opaque.
fn linearize_atom_through(_pool: &TermPool, f: Affine, _target: TermId) -> Affine {
    f
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sym::term::{BvOp, TermPool};

    #[test]
    fn extracts_simple_affine() {
        let mut p = TermPool::new();
        let x = p.symbol("x", 64);
        let c4 = p.constant(4, 64);
        let c8 = p.constant(8, 64);
        let t1 = p.bin(BvOp::Mul, x, c4);
        let t = p.bin(BvOp::Add, t1, c8);
        let f = extract(&p, t);
        assert_eq!(f.coeff(x), 4);
        assert_eq!(f.constant, 8);
    }

    #[test]
    fn looks_through_sext_and_mulwide() {
        // NVHPC address pattern: base + sext32→64(idx) * 4 + off
        let mut p = TermPool::new();
        let base = p.symbol("base", 64);
        let idx = p.symbol("idx", 32);
        let widened = p.sext(idx, 64);
        let c4 = p.constant(4, 64);
        let scaled = p.bin(BvOp::Mul, widened, c4);
        let t0 = p.bin(BvOp::Add, base, scaled);
        let c12 = p.constant(12, 64);
        let addr = p.bin(BvOp::Add, t0, c12);
        let f = extract(&p, addr);
        assert_eq!(f.coeff(base), 1);
        assert_eq!(f.coeff(idx), 4);
        assert_eq!(f.constant, 12);
    }

    #[test]
    fn shl_becomes_scale() {
        let mut p = TermPool::new();
        let x = p.symbol("x", 64);
        let c3 = p.constant(3, 64);
        let t = p.bin(BvOp::Shl, x, c3);
        assert_eq!(extract(&p, t).coeff(x), 8);
    }

    #[test]
    fn difference_cancels_shared_atoms() {
        let mut p = TermPool::new();
        let base = p.symbol("base", 64);
        let i = p.symbol("i", 32);
        let iw = p.sext(i, 64);
        let c4 = p.constant(4, 64);
        let si = p.bin(BvOp::Mul, iw, c4);
        let a0 = p.bin(BvOp::Add, base, si);
        let c12 = p.constant(12, 64);
        let c4b = p.constant(4, 64);
        let a = p.bin(BvOp::Add, a0, c12);
        let b = p.bin(BvOp::Add, a0, c4b);
        let d = extract(&p, b).sub(&extract(&p, a));
        assert!(d.is_constant());
        assert_eq!(d.constant, -8);
    }

    #[test]
    fn nonlinear_stays_atomic() {
        let mut p = TermPool::new();
        let x = p.symbol("x", 64);
        let y = p.symbol("y", 64);
        let t = p.bin(BvOp::Mul, x, y);
        let f = extract(&p, t);
        assert_eq!(f.coeff(t), 1);
        assert_eq!(f.constant, 0);
        // difference of the same nonlinear atom cancels
        let d = f.sub(&extract(&p, t));
        assert_eq!(d, Affine::constant(0));
    }

    #[test]
    fn split_on_tid() {
        let mut p = TermPool::new();
        let tid = p.symbol("tid.x", 32);
        let base = p.symbol("base", 64);
        let tw = p.sext(tid, 64);
        let c4 = p.constant(4, 64);
        let scaled = p.bin(BvOp::Mul, tw, c4);
        let addr = p.bin(BvOp::Add, base, scaled);
        let (stride, rest) = split_on(&p, addr, tid);
        assert_eq!(stride, 4);
        assert_eq!(rest.coeff(base), 1);
        assert_eq!(rest.coeff(tid), 0);
    }

    #[test]
    fn negative_constants() {
        let mut p = TermPool::new();
        let x = p.symbol("x", 32);
        let c = p.constant((-4i64) as u64, 32);
        let t = p.bin(BvOp::Add, x, c);
        let f = extract(&p, t);
        assert_eq!(f.constant, -4);
    }
}
