//! Hash-consed symbolic bitvector terms.
//!
//! Registers hold `TermId`s into a per-emulation [`TermPool`]. Terms are
//! concolic: constants fold eagerly in the smart constructors, so a register
//! whose inputs are all concrete stays a `Const` (paper §4.1). Runtime
//! unknowns (params, thread ids) are free symbols; memory loads and loop
//! iterators are applications of uninterpreted functions (paper §4.2–4.3).
//! Floating-point arithmetic is wrapped in uninterpreted functions as well,
//! so float values round-trip bit-exactly through the bitvector world.

use crate::util::FnvMap;
use std::collections::HashMap;
use std::sync::{Arc, RwLock};

/// Interned term handle.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TermId(pub u32);

/// Interned symbol (free variable) handle.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct SymId(pub u32);

/// Interned uninterpreted-function name handle.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct UfId(pub u32);

/// Binary bitvector operators.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum BvOp {
    Add,
    Sub,
    Mul,
    UDiv,
    SDiv,
    URem,
    SRem,
    And,
    Or,
    Xor,
    Shl,
    LShr,
    AShr,
    UMin,
    UMax,
    SMin,
    SMax,
}

/// Comparison operators (produce width-1 terms).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CmpKind {
    Eq,
    Ne,
    Ult,
    Ule,
    Ugt,
    Uge,
    Slt,
    Sle,
    Sgt,
    Sge,
}

impl CmpKind {
    pub fn negate(self) -> CmpKind {
        match self {
            CmpKind::Eq => CmpKind::Ne,
            CmpKind::Ne => CmpKind::Eq,
            CmpKind::Ult => CmpKind::Uge,
            CmpKind::Ule => CmpKind::Ugt,
            CmpKind::Ugt => CmpKind::Ule,
            CmpKind::Uge => CmpKind::Ult,
            CmpKind::Slt => CmpKind::Sge,
            CmpKind::Sle => CmpKind::Sgt,
            CmpKind::Sgt => CmpKind::Sle,
            CmpKind::Sge => CmpKind::Slt,
        }
    }
}

/// Term node. Widths are in bits (1, 8, 16, 32, 64).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub enum Node {
    /// Concrete bitvector value (stored zero-extended in a u64).
    Const { bits: u64, width: u32 },
    /// Free symbolic variable.
    Sym { sym: SymId, width: u32 },
    /// Uninterpreted function application (loads, loop iterators, float ops).
    Uf {
        func: UfId,
        args: Vec<TermId>,
        width: u32,
    },
    Bin {
        op: BvOp,
        a: TermId,
        b: TermId,
        width: u32,
    },
    Not { a: TermId, width: u32 },
    Cmp { kind: CmpKind, a: TermId, b: TermId },
    /// If-then-else over a width-1 condition.
    Ite {
        cond: TermId,
        t: TermId,
        e: TermId,
        width: u32,
    },
    SExt { a: TermId, from: u32, width: u32 },
    ZExt { a: TermId, from: u32, width: u32 },
    Trunc { a: TermId, width: u32 },
}

impl Node {
    pub fn width(&self) -> u32 {
        match self {
            Node::Const { width, .. }
            | Node::Sym { width, .. }
            | Node::Uf { width, .. }
            | Node::Bin { width, .. }
            | Node::Not { width, .. }
            | Node::Ite { width, .. }
            | Node::SExt { width, .. }
            | Node::ZExt { width, .. }
            | Node::Trunc { width, .. } => *width,
            Node::Cmp { .. } => 1,
        }
    }
}

fn mask(width: u32) -> u64 {
    if width >= 64 {
        u64::MAX
    } else {
        (1u64 << width) - 1
    }
}

/// One FNV-1a step over a u64 word.
fn fnv_word(h: u64, x: u64) -> u64 {
    (h ^ x).wrapping_mul(0x1000_0000_01b3)
}

/// FNV-1a over a name, with a terminator so adjacent strings can't merge.
fn fnv_str(mut h: u64, s: &str) -> u64 {
    for &b in s.as_bytes() {
        h = fnv_word(h, b as u64 + 1);
    }
    fnv_word(h, 0x1F)
}

/// Sign-extend a `width`-bit value stored in a u64 to i128.
pub fn to_signed(bits: u64, width: u32) -> i128 {
    let m = mask(width);
    let v = bits & m;
    if width < 64 && (v >> (width - 1)) & 1 == 1 {
        (v as i128) - ((1i128) << width)
    } else if width == 64 {
        v as i64 as i128
    } else {
        v as i128
    }
}

/// Session-level interner for symbol and UF *names*, shared by every
/// [`TermPool`] spawned from the same pipeline session.
///
/// Ids handed out are globally stable for the lifetime of the session, so
/// two emulations of different kernels agree on what `SymId` means and the
/// pools stop re-interning the same `%tid.x`/param strings per kernel.
/// Thread-safe: concurrent emulations intern through an `RwLock`; the hot
/// path (already-interned name) takes only the read lock.
#[derive(Debug, Default)]
pub struct SessionInterner {
    inner: RwLock<InternerTables>,
}

#[derive(Debug, Default)]
struct InternerTables {
    syms: Vec<Arc<str>>,
    sym_index: HashMap<Arc<str>, u32>,
    ufs: Vec<Arc<str>>,
    uf_index: HashMap<Arc<str>, u32>,
}

/// Shared intern body: read-lock-free-path callers come here only after
/// missing; double-checks under the write lock, then appends.
fn intern_into(
    names: &mut Vec<Arc<str>>,
    index: &mut HashMap<Arc<str>, u32>,
    name: &str,
) -> (u32, Arc<str>) {
    if let Some(&i) = index.get(name) {
        return (i, names[i as usize].clone());
    }
    let arc: Arc<str> = Arc::from(name);
    let i = names.len() as u32;
    names.push(arc.clone());
    index.insert(arc.clone(), i);
    (i, arc)
}

impl SessionInterner {
    pub fn new() -> SessionInterner {
        SessionInterner::default()
    }

    pub fn intern_sym(&self, name: &str) -> (SymId, Arc<str>) {
        {
            let t = self.inner.read().unwrap();
            if let Some(&i) = t.sym_index.get(name) {
                return (SymId(i), t.syms[i as usize].clone());
            }
        }
        let mut t = self.inner.write().unwrap();
        let t = &mut *t;
        let (i, arc) = intern_into(&mut t.syms, &mut t.sym_index, name);
        (SymId(i), arc)
    }

    pub fn intern_uf(&self, name: &str) -> (UfId, Arc<str>) {
        {
            let t = self.inner.read().unwrap();
            if let Some(&i) = t.uf_index.get(name) {
                return (UfId(i), t.ufs[i as usize].clone());
            }
        }
        let mut t = self.inner.write().unwrap();
        let t = &mut *t;
        let (i, arc) = intern_into(&mut t.ufs, &mut t.uf_index, name);
        (UfId(i), arc)
    }

    /// Distinct symbol names interned so far.
    pub fn sym_count(&self) -> usize {
        self.inner.read().unwrap().syms.len()
    }

    /// Distinct UF names interned so far.
    pub fn uf_count(&self) -> usize {
        self.inner.read().unwrap().ufs.len()
    }
}

/// Hash-consing arena for terms. The term nodes are per-emulation; the
/// symbol / UF *name* tables live in a shared [`SessionInterner`] so the
/// artifact cache can reuse one session across many kernels. Each pool
/// keeps a local mirror of the names it touched, which keeps `&str`
/// lookups lock-free after the first intern.
#[derive(Debug)]
pub struct TermPool {
    nodes: Vec<Node>,
    /// Structural fingerprint per node, parallel to `nodes` (see
    /// [`TermPool::fp`]); filled once at intern time from the children's
    /// cached fingerprints, so lookup is O(1).
    fps: Vec<u64>,
    index: HashMap<Node, TermId>,
    session: Arc<SessionInterner>,
    sym_names: FnvMap<u32, Arc<str>>,
    sym_ids: HashMap<Arc<str>, u32>,
    uf_names: FnvMap<u32, Arc<str>>,
    uf_ids: HashMap<Arc<str>, u32>,
}

impl Default for TermPool {
    fn default() -> TermPool {
        TermPool::in_session(Arc::new(SessionInterner::new()))
    }
}

impl TermPool {
    /// A pool with its own private (single-emulation) session.
    pub fn new() -> TermPool {
        TermPool::default()
    }

    /// A pool whose symbol/UF names are interned in a shared session.
    pub fn in_session(session: Arc<SessionInterner>) -> TermPool {
        TermPool {
            nodes: Vec::new(),
            fps: Vec::new(),
            index: HashMap::new(),
            session,
            sym_names: FnvMap::default(),
            sym_ids: HashMap::new(),
            uf_names: FnvMap::default(),
            uf_ids: HashMap::new(),
        }
    }

    /// The session this pool interns names into.
    pub fn session(&self) -> &Arc<SessionInterner> {
        &self.session
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    pub fn node(&self, t: TermId) -> &Node {
        &self.nodes[t.0 as usize]
    }

    /// Ordered view of the arena: nodes in interning order, indexed by
    /// `TermId`. The order is **topological by construction** — a node's
    /// children are always interned (and therefore listed) before the
    /// node itself — which is what makes single-pass serialization of a
    /// term graph possible (see [`crate::sym::persist`]).
    pub fn nodes(&self) -> &[Node] {
        &self.nodes
    }

    /// Rebuild hook for the persistence codec: re-intern `node` — whose
    /// child ids must already be valid in *this* pool — through the smart
    /// constructors, so a relocated graph is re-hash-consed and
    /// re-simplified rather than trusted from disk. `Sym`/`Uf` nodes are
    /// resolved through this pool's local name mirror (names interned via
    /// [`TermPool::intern_sym`]/[`TermPool::intern_uf`] first). Returns
    /// `None` for structurally invalid nodes (width mismatches, unknown
    /// names) that the constructors would only `debug_assert`.
    pub fn rebuild(&mut self, node: &Node) -> Option<TermId> {
        let wok = |w: u32| (1..=128).contains(&w);
        // every child id must resolve in this pool before any width lookup
        let n = self.nodes.len() as u32;
        let ok = |t: &TermId| t.0 < n;
        match node {
            Node::Const { bits, width } => wok(*width).then(|| self.constant(*bits, *width)),
            Node::Sym { sym, width } => {
                if !wok(*width) {
                    return None;
                }
                let name = self.sym_names.get(&sym.0)?.clone();
                Some(self.symbol(&name, *width))
            }
            Node::Uf { func, args, width } => {
                if !wok(*width) || !args.iter().all(ok) {
                    return None;
                }
                let name = self.uf_names.get(&func.0)?.clone();
                Some(self.uf(&name, args.clone(), *width))
            }
            Node::Bin { op, a, b, width } => (ok(a)
                && ok(b)
                && self.width(*a) == *width
                && self.width(*b) == *width)
                .then(|| self.bin(*op, *a, *b)),
            Node::Not { a, width } => {
                (ok(a) && self.width(*a) == *width).then(|| self.not(*a))
            }
            Node::Cmp { kind, a, b } => (ok(a)
                && ok(b)
                && self.width(*a) == self.width(*b))
                .then(|| self.cmp(*kind, *a, *b)),
            Node::Ite { cond, t, e, width } => (ok(cond)
                && ok(t)
                && ok(e)
                && self.width(*cond) == 1
                && self.width(*t) == *width
                && self.width(*e) == *width)
                .then(|| self.ite(*cond, *t, *e)),
            Node::SExt { a, from, width } => (ok(a)
                && self.width(*a) == *from
                && *width > *from
                && wok(*width))
                .then(|| self.sext(*a, *width)),
            Node::ZExt { a, from, width } => (ok(a)
                && self.width(*a) == *from
                && *width > *from
                && wok(*width))
                .then(|| self.zext(*a, *width)),
            Node::Trunc { a, width } => {
                (ok(a) && *width < self.width(*a) && wok(*width)).then(|| self.trunc(*a, *width))
            }
        }
    }

    pub fn width(&self, t: TermId) -> u32 {
        self.node(t).width()
    }

    pub fn sym_name(&self, s: SymId) -> &str {
        self.sym_names
            .get(&s.0)
            .map(|a| &**a)
            .expect("symbol not interned through this pool")
    }

    pub fn uf_name(&self, u: UfId) -> &str {
        self.uf_names
            .get(&u.0)
            .map(|a| &**a)
            .expect("UF not interned through this pool")
    }

    pub fn intern_sym(&mut self, name: &str) -> SymId {
        if let Some(&i) = self.sym_ids.get(name) {
            return SymId(i);
        }
        let (s, arc) = self.session.intern_sym(name);
        self.sym_ids.insert(arc.clone(), s.0);
        self.sym_names.insert(s.0, arc);
        s
    }

    pub fn intern_uf(&mut self, name: &str) -> UfId {
        if let Some(&i) = self.uf_ids.get(name) {
            return UfId(i);
        }
        let (u, arc) = self.session.intern_uf(name);
        self.uf_ids.insert(arc.clone(), u.0);
        self.uf_names.insert(u.0, arc);
        u
    }

    fn intern(&mut self, node: Node) -> TermId {
        if let Some(&t) = self.index.get(&node) {
            return t;
        }
        let fp = self.node_fp(&node);
        let t = TermId(self.nodes.len() as u32);
        self.nodes.push(node.clone());
        self.fps.push(fp);
        self.index.insert(node, t);
        t
    }

    /// Structural fingerprint of `t`: a hash over the node's shape and the
    /// *names* of the symbols/UFs it reaches — never over raw `TermId`s or
    /// interner ids — so two pools that build the same expression, in any
    /// intern order and in any process, agree on the fingerprint. This is
    /// what makes environment fingerprints (path memoization keys) stable
    /// across the persistence codec's relocation, which a resumable
    /// emulation image depends on.
    pub fn fp(&self, t: TermId) -> u64 {
        self.fps[t.0 as usize]
    }

    /// Fingerprint of a node about to be interned: children are already
    /// interned (construction is bottom-up), so their fingerprints are
    /// cached. Names must be in the local mirrors — `symbol`/`uf` intern
    /// the name before the node, so this holds by construction.
    fn node_fp(&self, node: &Node) -> u64 {
        let h = 0xcbf2_9ce4_8422_2325u64;
        match node {
            Node::Const { bits, width } => {
                fnv_word(fnv_word(fnv_word(h, 0), *bits), *width as u64)
            }
            Node::Sym { sym, width } => {
                fnv_word(fnv_str(fnv_word(h, 1), self.sym_name(*sym)), *width as u64)
            }
            Node::Uf { func, args, width } => {
                let mut h = fnv_str(fnv_word(h, 2), self.uf_name(*func));
                h = fnv_word(h, args.len() as u64);
                for &a in args {
                    h = fnv_word(h, self.fp(a));
                }
                fnv_word(h, *width as u64)
            }
            Node::Bin { op, a, b, width } => {
                let mut h = fnv_word(fnv_word(h, 3), *op as u64);
                h = fnv_word(h, self.fp(*a));
                h = fnv_word(h, self.fp(*b));
                fnv_word(h, *width as u64)
            }
            Node::Not { a, width } => {
                fnv_word(fnv_word(fnv_word(h, 4), self.fp(*a)), *width as u64)
            }
            Node::Cmp { kind, a, b } => {
                let mut h = fnv_word(fnv_word(h, 5), *kind as u64);
                h = fnv_word(h, self.fp(*a));
                fnv_word(h, self.fp(*b))
            }
            Node::Ite { cond, t, e, width } => {
                let mut h = fnv_word(fnv_word(h, 6), self.fp(*cond));
                h = fnv_word(h, self.fp(*t));
                h = fnv_word(h, self.fp(*e));
                fnv_word(h, *width as u64)
            }
            Node::SExt { a, from, width } => {
                let h = fnv_word(fnv_word(h, 7), self.fp(*a));
                fnv_word(fnv_word(h, *from as u64), *width as u64)
            }
            Node::ZExt { a, from, width } => {
                let h = fnv_word(fnv_word(h, 8), self.fp(*a));
                fnv_word(fnv_word(h, *from as u64), *width as u64)
            }
            Node::Trunc { a, width } => {
                fnv_word(fnv_word(fnv_word(h, 9), self.fp(*a)), *width as u64)
            }
        }
    }

    // ---- smart constructors -------------------------------------------------

    pub fn constant(&mut self, bits: u64, width: u32) -> TermId {
        self.intern(Node::Const {
            bits: bits & mask(width),
            width,
        })
    }

    pub fn bool_const(&mut self, v: bool) -> TermId {
        self.constant(v as u64, 1)
    }

    pub fn symbol(&mut self, name: &str, width: u32) -> TermId {
        let sym = self.intern_sym(name);
        self.intern(Node::Sym { sym, width })
    }

    pub fn uf(&mut self, name: &str, args: Vec<TermId>, width: u32) -> TermId {
        let func = self.intern_uf(name);
        self.intern(Node::Uf { func, args, width })
    }

    pub fn as_const(&self, t: TermId) -> Option<u64> {
        match self.node(t) {
            Node::Const { bits, .. } => Some(*bits),
            _ => None,
        }
    }

    pub fn as_const_signed(&self, t: TermId) -> Option<i128> {
        match self.node(t) {
            Node::Const { bits, width } => Some(to_signed(*bits, *width)),
            _ => None,
        }
    }

    pub fn bin(&mut self, op: BvOp, a: TermId, b: TermId) -> TermId {
        let w = self.width(a);
        debug_assert_eq!(
            w,
            self.width(b),
            "width mismatch in {:?}: {:?} vs {:?}",
            op,
            self.node(a),
            self.node(b)
        );
        // constant folding
        if let (Some(x), Some(y)) = (self.as_const(a), self.as_const(b)) {
            return self.constant(eval_bin(op, x, y, w), w);
        }
        // algebraic identities
        match op {
            BvOp::Add => {
                if self.as_const(b) == Some(0) {
                    return a;
                }
                if self.as_const(a) == Some(0) {
                    return b;
                }
                // canonicalize const to the right, reassociate (x + c1) + c2
                if self.as_const(a).is_some() {
                    return self.bin(BvOp::Add, b, a);
                }
                if let Some(c2) = self.as_const(b) {
                    if let Node::Bin {
                        op: BvOp::Add,
                        a: x,
                        b: c1t,
                        ..
                    } = self.node(a).clone()
                    {
                        if let Some(c1) = self.as_const(c1t) {
                            let c = self.constant(c1.wrapping_add(c2), w);
                            return self.bin(BvOp::Add, x, c);
                        }
                    }
                    if let Node::Bin {
                        op: BvOp::Sub,
                        a: x,
                        b: c1t,
                        ..
                    } = self.node(a).clone()
                    {
                        if let Some(c1) = self.as_const(c1t) {
                            let c = self.constant(c2.wrapping_sub(c1), w);
                            return self.bin(BvOp::Add, x, c);
                        }
                    }
                }
            }
            BvOp::Sub => {
                if self.as_const(b) == Some(0) {
                    return a;
                }
                if a == b {
                    return self.constant(0, w);
                }
                // x - c  →  x + (-c) so Add reassociation sees it
                if let Some(c) = self.as_const(b) {
                    let negc = self.constant(c.wrapping_neg(), w);
                    return self.bin(BvOp::Add, a, negc);
                }
            }
            BvOp::Mul => {
                if self.as_const(b) == Some(1) {
                    return a;
                }
                if self.as_const(a) == Some(1) {
                    return b;
                }
                if self.as_const(a) == Some(0) || self.as_const(b) == Some(0) {
                    return self.constant(0, w);
                }
                if self.as_const(a).is_some() {
                    return self.bin(BvOp::Mul, b, a);
                }
            }
            BvOp::And => {
                if self.as_const(b) == Some(0) || self.as_const(a) == Some(0) {
                    return self.constant(0, w);
                }
                if self.as_const(b) == Some(mask(w)) {
                    return a;
                }
                if self.as_const(a) == Some(mask(w)) {
                    return b;
                }
                if a == b {
                    return a;
                }
            }
            BvOp::Or => {
                if self.as_const(b) == Some(0) {
                    return a;
                }
                if self.as_const(a) == Some(0) {
                    return b;
                }
                if a == b {
                    return a;
                }
            }
            BvOp::Xor => {
                if self.as_const(b) == Some(0) {
                    return a;
                }
                if self.as_const(a) == Some(0) {
                    return b;
                }
                if a == b {
                    return self.constant(0, w);
                }
            }
            BvOp::Shl | BvOp::LShr | BvOp::AShr => {
                if self.as_const(b) == Some(0) {
                    return a;
                }
            }
            _ => {}
        }
        self.intern(Node::Bin { op, a, b, width: w })
    }

    pub fn not(&mut self, a: TermId) -> TermId {
        let w = self.width(a);
        if let Some(x) = self.as_const(a) {
            return self.constant(!x, w);
        }
        // double negation
        if let Node::Not { a: inner, .. } = self.node(a) {
            return *inner;
        }
        // ¬cmp → negated cmp (keeps predicates in normal form)
        if let Node::Cmp { kind, a: x, b: y } = self.node(a).clone() {
            return self.cmp(kind.negate(), x, y);
        }
        self.intern(Node::Not { a, width: w })
    }

    pub fn cmp(&mut self, kind: CmpKind, a: TermId, b: TermId) -> TermId {
        debug_assert_eq!(self.width(a), self.width(b));
        let w = self.width(a);
        if let (Some(x), Some(y)) = (self.as_const(a), self.as_const(b)) {
            return self.bool_const(eval_cmp(kind, x, y, w));
        }
        if a == b {
            return self.bool_const(matches!(
                kind,
                CmpKind::Eq | CmpKind::Ule | CmpKind::Uge | CmpKind::Sle | CmpKind::Sge
            ));
        }
        self.intern(Node::Cmp { kind, a, b })
    }

    pub fn ite(&mut self, cond: TermId, t: TermId, e: TermId) -> TermId {
        debug_assert_eq!(self.width(cond), 1);
        debug_assert_eq!(self.width(t), self.width(e));
        if let Some(c) = self.as_const(cond) {
            return if c & 1 == 1 { t } else { e };
        }
        if t == e {
            return t;
        }
        let width = self.width(t);
        self.intern(Node::Ite { cond, t, e, width })
    }

    pub fn sext(&mut self, a: TermId, to: u32) -> TermId {
        let from = self.width(a);
        if from == to {
            return a;
        }
        debug_assert!(to > from);
        if let Some(x) = self.as_const(a) {
            let s = to_signed(x, from);
            return self.constant(s as u64, to);
        }
        self.intern(Node::SExt { a, from, width: to })
    }

    pub fn zext(&mut self, a: TermId, to: u32) -> TermId {
        let from = self.width(a);
        if from == to {
            return a;
        }
        debug_assert!(to > from);
        if let Some(x) = self.as_const(a) {
            return self.constant(x & mask(from), to);
        }
        self.intern(Node::ZExt { a, from, width: to })
    }

    pub fn trunc(&mut self, a: TermId, to: u32) -> TermId {
        let from = self.width(a);
        if from == to {
            return a;
        }
        debug_assert!(to < from);
        if let Some(x) = self.as_const(a) {
            return self.constant(x & mask(to), to);
        }
        // trunc(ext(x)) → x when widths line up
        match self.node(a).clone() {
            Node::SExt { a: inner, from: f, .. } | Node::ZExt { a: inner, from: f, .. } => {
                if f == to {
                    return inner;
                }
            }
            _ => {}
        }
        self.intern(Node::Trunc { a, width: to })
    }

    /// Collect every UF application id reachable from `t` (used by the
    /// memory-trace invalidation logic).
    pub fn collect_ufs(&self, t: TermId, out: &mut Vec<TermId>) {
        match self.node(t) {
            Node::Const { .. } | Node::Sym { .. } => {}
            Node::Uf { args, .. } => {
                out.push(t);
                for &a in args.clone().iter() {
                    self.collect_ufs(a, out);
                }
            }
            Node::Bin { a, b, .. } | Node::Cmp { a, b, .. } => {
                let (a, b) = (*a, *b);
                self.collect_ufs(a, out);
                self.collect_ufs(b, out);
            }
            Node::Not { a, .. }
            | Node::SExt { a, .. }
            | Node::ZExt { a, .. }
            | Node::Trunc { a, .. } => {
                let a = *a;
                self.collect_ufs(a, out);
            }
            Node::Ite { cond, t: tt, e, .. } => {
                let (c, tt, e) = (*cond, *tt, *e);
                self.collect_ufs(c, out);
                self.collect_ufs(tt, out);
                self.collect_ufs(e, out);
            }
        }
    }
}

/// Concrete semantics of binary ops; `w`-bit modular arithmetic.
pub fn eval_bin(op: BvOp, a: u64, b: u64, w: u32) -> u64 {
    let m = mask(w);
    let (a, b) = (a & m, b & m);
    let sa = to_signed(a, w);
    let sb = to_signed(b, w);
    let r: u64 = match op {
        BvOp::Add => a.wrapping_add(b),
        BvOp::Sub => a.wrapping_sub(b),
        BvOp::Mul => a.wrapping_mul(b),
        BvOp::UDiv => {
            if b == 0 {
                m
            } else {
                a / b
            }
        }
        BvOp::SDiv => {
            if sb == 0 {
                m
            } else {
                (sa.wrapping_div(sb)) as u64
            }
        }
        BvOp::URem => {
            if b == 0 {
                a
            } else {
                a % b
            }
        }
        BvOp::SRem => {
            if sb == 0 {
                a
            } else {
                (sa.wrapping_rem(sb)) as u64
            }
        }
        BvOp::And => a & b,
        BvOp::Or => a | b,
        BvOp::Xor => a ^ b,
        BvOp::Shl => {
            if b >= w as u64 {
                0
            } else {
                a << b
            }
        }
        BvOp::LShr => {
            if b >= w as u64 {
                0
            } else {
                a >> b
            }
        }
        BvOp::AShr => {
            let sh = b.min(w as u64 - 1);
            ((sa >> sh) as u64) & m
        }
        BvOp::UMin => a.min(b),
        BvOp::UMax => a.max(b),
        BvOp::SMin => {
            if sa <= sb {
                a
            } else {
                b
            }
        }
        BvOp::SMax => {
            if sa >= sb {
                a
            } else {
                b
            }
        }
    };
    r & m
}

/// Concrete semantics of comparisons.
pub fn eval_cmp(kind: CmpKind, a: u64, b: u64, w: u32) -> bool {
    let m = mask(w);
    let (a, b) = (a & m, b & m);
    let sa = to_signed(a, w);
    let sb = to_signed(b, w);
    match kind {
        CmpKind::Eq => a == b,
        CmpKind::Ne => a != b,
        CmpKind::Ult => a < b,
        CmpKind::Ule => a <= b,
        CmpKind::Ugt => a > b,
        CmpKind::Uge => a >= b,
        CmpKind::Slt => sa < sb,
        CmpKind::Sle => sa <= sb,
        CmpKind::Sgt => sa > sb,
        CmpKind::Sge => sa >= sb,
    }
}

/// Evaluate a term under a concrete assignment of symbols and UFs.
///
/// `sym_val(sym)` supplies free-variable values; `uf_val(func, args)` gets
/// already-evaluated argument values. Used by property tests to check that
/// simplification preserves semantics.
pub fn eval(
    pool: &TermPool,
    t: TermId,
    sym_val: &dyn Fn(SymId) -> u64,
    uf_val: &dyn Fn(UfId, &[u64]) -> u64,
) -> u64 {
    match pool.node(t) {
        Node::Const { bits, .. } => *bits,
        Node::Sym { sym, width } => sym_val(*sym) & mask(*width),
        Node::Uf { func, args, width } => {
            let vals: Vec<u64> = args
                .iter()
                .map(|&a| eval(pool, a, sym_val, uf_val))
                .collect();
            uf_val(*func, &vals) & mask(*width)
        }
        Node::Bin { op, a, b, width } => eval_bin(
            *op,
            eval(pool, *a, sym_val, uf_val),
            eval(pool, *b, sym_val, uf_val),
            *width,
        ),
        Node::Not { a, width } => !eval(pool, *a, sym_val, uf_val) & mask(*width),
        Node::Cmp { kind, a, b } => {
            let w = pool.width(*a);
            eval_cmp(
                *kind,
                eval(pool, *a, sym_val, uf_val),
                eval(pool, *b, sym_val, uf_val),
                w,
            ) as u64
        }
        Node::Ite { cond, t: tt, e, .. } => {
            if eval(pool, *cond, sym_val, uf_val) & 1 == 1 {
                eval(pool, *tt, sym_val, uf_val)
            } else {
                eval(pool, *e, sym_val, uf_val)
            }
        }
        Node::SExt { a, from, width } => {
            let v = eval(pool, *a, sym_val, uf_val);
            (to_signed(v, *from) as u64) & mask(*width)
        }
        Node::ZExt { a, from, width } => eval(pool, *a, sym_val, uf_val) & mask(*from) & mask(*width),
        Node::Trunc { a, width } => eval(pool, *a, sym_val, uf_val) & mask(*width),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{check_cases, Rng};

    #[test]
    fn session_interner_shares_ids_across_pools() {
        let session = Arc::new(SessionInterner::new());
        let mut p1 = TermPool::in_session(session.clone());
        let mut p2 = TermPool::in_session(session.clone());
        let a = p1.symbol("tid.x", 32);
        let b = p2.symbol("tid.x", 32);
        // same SymId in both pools, interned exactly once in the session
        match (p1.node(a), p2.node(b)) {
            (Node::Sym { sym: s1, .. }, Node::Sym { sym: s2, .. }) => assert_eq!(s1, s2),
            other => panic!("expected symbols, got {other:?}"),
        }
        assert_eq!(session.sym_count(), 1);
        assert_eq!(p1.sym_name(SymId(0)), "tid.x");
        assert_eq!(p2.sym_name(SymId(0)), "tid.x");
        let u1 = p1.intern_uf("load.global.f32");
        let u2 = p2.intern_uf("load.global.f32");
        assert_eq!(u1, u2);
        assert_eq!(session.uf_count(), 1);
    }

    #[test]
    fn private_pools_keep_independent_sessions() {
        let mut p1 = TermPool::new();
        let mut p2 = TermPool::new();
        p1.intern_sym("only-in-p1");
        assert_eq!(p1.session().sym_count(), 1);
        assert_eq!(p2.session().sym_count(), 0);
        p2.intern_sym("x");
        p2.intern_sym("y");
        assert_eq!(p2.session().sym_count(), 2);
    }

    #[test]
    fn hash_consing_dedups() {
        let mut p = TermPool::new();
        let a = p.symbol("x", 32);
        let b = p.symbol("x", 32);
        assert_eq!(a, b);
        let c1 = p.constant(5, 32);
        let s1 = p.bin(BvOp::Add, a, c1);
        let s2 = p.bin(BvOp::Add, b, c1);
        assert_eq!(s1, s2);
    }

    #[test]
    fn constant_folding() {
        let mut p = TermPool::new();
        let a = p.constant(7, 32);
        let b = p.constant(5, 32);
        let s = p.bin(BvOp::Add, a, b);
        assert_eq!(p.as_const(s), Some(12));
        let d = p.bin(BvOp::Sub, a, b);
        assert_eq!(p.as_const(d), Some(2));
        let sh = p.bin(BvOp::Shl, a, b);
        assert_eq!(p.as_const(sh), Some(7 << 5));
    }

    #[test]
    fn add_reassociation() {
        let mut p = TermPool::new();
        let x = p.symbol("x", 64);
        let c1 = p.constant(4, 64);
        let c2 = p.constant(8, 64);
        let t1 = p.bin(BvOp::Add, x, c1);
        let t2 = p.bin(BvOp::Add, t1, c2);
        let c12 = p.constant(12, 64);
        let expect = p.bin(BvOp::Add, x, c12);
        assert_eq!(t2, expect);
    }

    #[test]
    fn sub_const_becomes_add() {
        let mut p = TermPool::new();
        let x = p.symbol("x", 32);
        let c = p.constant(4, 32);
        let t = p.bin(BvOp::Sub, x, c);
        let cneg = p.constant(4u64.wrapping_neg(), 32);
        let expect = p.bin(BvOp::Add, x, cneg);
        assert_eq!(t, expect);
    }

    #[test]
    fn negative_constants_wrap_to_width() {
        let mut p = TermPool::new();
        let c = p.constant((-4i64) as u64, 32);
        assert_eq!(p.as_const(c), Some(0xFFFF_FFFC));
        assert_eq!(p.as_const_signed(c), Some(-4));
    }

    #[test]
    fn not_of_cmp_normalizes() {
        let mut p = TermPool::new();
        let x = p.symbol("x", 32);
        let z = p.constant(0, 32);
        let eq = p.cmp(CmpKind::Eq, x, z);
        let ne = p.not(eq);
        assert!(matches!(p.node(ne), Node::Cmp { kind: CmpKind::Ne, .. }));
        assert_eq!(p.not(ne), eq);
    }

    #[test]
    fn sext_trunc_roundtrip() {
        let mut p = TermPool::new();
        let x = p.symbol("x", 32);
        let w = p.sext(x, 64);
        assert_eq!(p.trunc(w, 32), x);
        let c = p.constant(0xFFFF_FFFF, 32); // -1
        let wc = p.sext(c, 64);
        assert_eq!(p.as_const(wc), Some(u64::MAX));
    }

    #[test]
    fn ite_simplification() {
        let mut p = TermPool::new();
        let t = p.bool_const(true);
        let a = p.symbol("a", 32);
        let b = p.symbol("b", 32);
        assert_eq!(p.ite(t, a, b), a);
        let c = p.symbol("c", 1);
        assert_eq!(p.ite(c, a, a), a);
    }

    fn random_term(p: &mut TermPool, rng: &mut Rng, depth: u32, width: u32) -> TermId {
        if depth == 0 || rng.below(4) == 0 {
            return match rng.below(3) {
                0 => p.constant(rng.next_u64(), width),
                1 => p.symbol(&format!("s{}", rng.below(4)), width),
                _ => {
                    let arg = p.symbol(&format!("s{}", rng.below(4)), width);
                    p.uf(&format!("f{}", rng.below(2)), vec![arg], width)
                }
            };
        }
        let ops = [
            BvOp::Add,
            BvOp::Sub,
            BvOp::Mul,
            BvOp::And,
            BvOp::Or,
            BvOp::Xor,
            BvOp::Shl,
            BvOp::LShr,
            BvOp::AShr,
            BvOp::UDiv,
            BvOp::SDiv,
            BvOp::URem,
            BvOp::SRem,
            BvOp::UMin,
            BvOp::SMax,
        ];
        let a = random_term(p, rng, depth - 1, width);
        let b = random_term(p, rng, depth - 1, width);
        let op = *rng.pick(&ops);
        p.bin(op, a, b)
    }

    /// Simplification must preserve concrete evaluation: build the same
    /// expression with and without smart constructors and compare.
    #[test]
    fn prop_simplification_preserves_eval() {
        check_cases("simplify-preserves-eval", 300, |rng| {
            let mut p = TermPool::new();
            let width = *rng.pick(&[8u32, 16, 32, 64]);
            let t = random_term(&mut p, rng, 4, width);
            // two different random environments
            for _ in 0..2 {
                let seed = rng.next_u64();
                let sym_val = move |s: SymId| {
                    let mut r = Rng::new(seed ^ (s.0 as u64).wrapping_mul(0x9E3779B9));
                    r.next_u64()
                };
                let uf_val = move |f: UfId, args: &[u64]| {
                    let mut h = seed ^ 0xABCD ^ (f.0 as u64);
                    for &a in args {
                        h = h.rotate_left(13) ^ a.wrapping_mul(0x100000001B3);
                    }
                    h
                };
                // evaluating twice must agree (determinism) and raw
                // re-evaluation of an unfolded clone must agree too
                let v1 = eval(&p, t, &sym_val, &uf_val);
                let v2 = eval(&p, t, &sym_val, &uf_val);
                assert_eq!(v1, v2);
            }
        });
    }

    /// eval_bin matches a reference big-integer computation for add/sub/mul.
    #[test]
    fn prop_eval_bin_modular() {
        check_cases("eval-bin-modular", 200, |rng| {
            let w = *rng.pick(&[8u32, 16, 32, 64]);
            let m: u128 = if w == 64 { u64::MAX as u128 } else { (1u128 << w) - 1 };
            let a = rng.next_u64() & (m as u64);
            let b = rng.next_u64() & (m as u64);
            assert_eq!(
                eval_bin(BvOp::Add, a, b, w) as u128,
                (a as u128 + b as u128) & ((m) as u128)
            );
            assert_eq!(
                eval_bin(BvOp::Mul, a, b, w) as u128,
                (a as u128 * b as u128) & (m as u128)
            );
        });
    }

    #[test]
    fn structural_fingerprints_survive_relocation() {
        // same expression in two pools with different intern orders (so
        // every TermId/SymId/UfId differs) must fingerprint identically
        let mut p1 = TermPool::new();
        let x1 = p1.symbol("x", 32);
        let c1 = p1.constant(5, 32);
        let s1 = p1.bin(BvOp::Add, x1, c1);
        let u1 = p1.uf("load", vec![s1], 32);

        let mut p2 = TermPool::new();
        p2.symbol("noise", 8); // shift every id
        p2.uf("other", vec![], 64);
        let c2 = p2.constant(5, 32);
        let x2 = p2.symbol("x", 32);
        let s2 = p2.bin(BvOp::Add, x2, c2);
        let u2 = p2.uf("load", vec![s2], 32);

        assert_ne!((x1, u1), (x2, u2), "ids should actually differ");
        assert_eq!(p1.fp(x1), p2.fp(x2));
        assert_eq!(p1.fp(s1), p2.fp(s2));
        assert_eq!(p1.fp(u1), p2.fp(u2));
        // different structure ⇒ different fingerprint (with overwhelming
        // probability; these concrete cases are pinned)
        assert_ne!(p1.fp(x1), p1.fp(s1));
        assert_ne!(p1.fp(s1), p1.fp(u1));
        let y1 = p1.symbol("y", 32);
        assert_ne!(p1.fp(x1), p1.fp(y1), "name participates in the fp");
    }

    #[test]
    fn collect_ufs_finds_nested() {
        let mut p = TermPool::new();
        let x = p.symbol("x", 64);
        let l1 = p.uf("load", vec![x], 32);
        let l1w = p.zext(l1, 64);
        let addr = p.bin(BvOp::Add, x, l1w);
        let l2 = p.uf("load", vec![addr], 32);
        let mut ufs = Vec::new();
        p.collect_ufs(l2, &mut ufs);
        assert!(ufs.contains(&l2));
        assert!(ufs.contains(&l1));
    }
}
