//! Relocatable, versioned binary codec for hash-consed term graphs — the
//! subsystem that lets [`crate::emu::EmulationResult`]s persist across
//! processes.
//!
//! Term graphs are interner-relative: a `SymId`/`UfId` is an index into
//! the *session* interner and a `TermId` an index into the emulation's
//! arena, so raw ids written by one process are meaningless in another.
//! This codec emits a **self-contained image** instead:
//!
//! * a local name table — every symbol / UF name the reachable graph
//!   uses, spelled out as strings;
//! * the reachable term nodes in topological order (the arena's
//!   interning order is topological by construction), children referenced
//!   by *local* indices that must precede the node — acyclicity is a
//!   construction invariant of the format, not a post-hoc check;
//! * every root the result references: register values are not needed
//!   (flows are finished), but memory-trace addresses/values, path
//!   conditions (assumption atoms) and the `tid` symbol are;
//! * an [`crate::util::Fnv128`] checksum over the whole payload.
//!
//! Decoding **relocates** into the loading session: names are re-interned
//! through the current [`SessionInterner`], nodes re-hash-consed into a
//! fresh [`TermPool`] via the smart constructors
//! ([`TermPool::rebuild`]), so structural sharing and term identities are
//! rebuilt — never trusted from disk. Every index is bounds-checked and
//! any malformed byte yields `None`, which the pipeline's disk store
//! treats exactly like a corrupt artifact: delete, count, recompute.

use crate::emu::{EmuStats, EmulationResult, FlowEnd, FlowResult};
use crate::sym::solver::{Assumptions, AssumptionsImage, FormImage};
use crate::sym::term::{BvOp, CmpKind, Node, SessionInterner, TermId, TermPool};
use crate::util::{Dec, Enc, Fnv128, FnvBuild, FnvMap};
use std::collections::HashSet;
use std::sync::Arc;

/// Bump when the image layout changes. The pipeline store's own version
/// guards the container; this one guards the term-graph encoding proper,
/// so a future store-format bump that leaves the graph codec untouched
/// can keep old images readable.
/// v2: memory-trace records carry the barrier `phase` id.
pub const PERSIST_VERSION: u32 = 2;

// ---------------------------------------------------------------------------
// Stable operator tags (shared with the simulator's DecodedKernel codec)
// ---------------------------------------------------------------------------

pub(crate) fn bvop_tag(op: BvOp) -> u8 {
    match op {
        BvOp::Add => 0,
        BvOp::Sub => 1,
        BvOp::Mul => 2,
        BvOp::UDiv => 3,
        BvOp::SDiv => 4,
        BvOp::URem => 5,
        BvOp::SRem => 6,
        BvOp::And => 7,
        BvOp::Or => 8,
        BvOp::Xor => 9,
        BvOp::Shl => 10,
        BvOp::LShr => 11,
        BvOp::AShr => 12,
        BvOp::UMin => 13,
        BvOp::UMax => 14,
        BvOp::SMin => 15,
        BvOp::SMax => 16,
    }
}

pub(crate) fn bvop_from_tag(tag: u8) -> Option<BvOp> {
    Some(match tag {
        0 => BvOp::Add,
        1 => BvOp::Sub,
        2 => BvOp::Mul,
        3 => BvOp::UDiv,
        4 => BvOp::SDiv,
        5 => BvOp::URem,
        6 => BvOp::SRem,
        7 => BvOp::And,
        8 => BvOp::Or,
        9 => BvOp::Xor,
        10 => BvOp::Shl,
        11 => BvOp::LShr,
        12 => BvOp::AShr,
        13 => BvOp::UMin,
        14 => BvOp::UMax,
        15 => BvOp::SMin,
        16 => BvOp::SMax,
        _ => return None,
    })
}

pub(crate) fn cmp_tag(k: CmpKind) -> u8 {
    match k {
        CmpKind::Eq => 0,
        CmpKind::Ne => 1,
        CmpKind::Ult => 2,
        CmpKind::Ule => 3,
        CmpKind::Ugt => 4,
        CmpKind::Uge => 5,
        CmpKind::Slt => 6,
        CmpKind::Sle => 7,
        CmpKind::Sgt => 8,
        CmpKind::Sge => 9,
    }
}

pub(crate) fn cmp_from_tag(tag: u8) -> Option<CmpKind> {
    Some(match tag {
        0 => CmpKind::Eq,
        1 => CmpKind::Ne,
        2 => CmpKind::Ult,
        3 => CmpKind::Ule,
        4 => CmpKind::Ugt,
        5 => CmpKind::Uge,
        6 => CmpKind::Slt,
        7 => CmpKind::Sle,
        8 => CmpKind::Sgt,
        9 => CmpKind::Sge,
        _ => return None,
    })
}

// ---------------------------------------------------------------------------
// Encoding: reachability + image writer
// ---------------------------------------------------------------------------

/// Collects the set of terms reachable from the registered roots.
#[derive(Debug)]
pub struct GraphBuilder<'p> {
    pool: &'p TermPool,
    /// FNV-hashed (the ids are small integers; this runs once per
    /// reachable node on every cache-miss emulation).
    seen: HashSet<u32, FnvBuild>,
}

impl<'p> GraphBuilder<'p> {
    pub fn new(pool: &'p TermPool) -> GraphBuilder<'p> {
        GraphBuilder {
            pool,
            seen: HashSet::default(),
        }
    }

    /// Mark `t` and everything it references (iterative DFS — address
    /// chains in unrolled kernels can be deep).
    pub fn add_root(&mut self, t: TermId) {
        let mut stack = vec![t];
        while let Some(t) = stack.pop() {
            if !self.seen.insert(t.0) {
                continue;
            }
            match self.pool.node(t) {
                Node::Const { .. } | Node::Sym { .. } => {}
                Node::Uf { args, .. } => stack.extend(args.iter().copied()),
                Node::Bin { a, b, .. } | Node::Cmp { a, b, .. } => {
                    stack.push(*a);
                    stack.push(*b);
                }
                Node::Not { a, .. }
                | Node::SExt { a, .. }
                | Node::ZExt { a, .. }
                | Node::Trunc { a, .. } => stack.push(*a),
                Node::Ite { cond, t: tt, e, .. } => {
                    stack.push(*cond);
                    stack.push(*tt);
                    stack.push(*e);
                }
            }
        }
    }

    /// Freeze the reachable set into an encodable image: nodes in
    /// ascending arena order (topological), local indices assigned.
    pub fn seal(self) -> GraphImage<'p> {
        let mut order: Vec<u32> = self.seen.into_iter().collect();
        order.sort_unstable();
        let index: FnvMap<u32, u32> = order
            .iter()
            .enumerate()
            .map(|(i, &t)| (t, i as u32))
            .collect();
        GraphImage {
            pool: self.pool,
            order,
            index,
        }
    }
}

/// A sealed, encodable view of a reachable term subgraph.
#[derive(Debug)]
pub struct GraphImage<'p> {
    pool: &'p TermPool,
    order: Vec<u32>,
    index: FnvMap<u32, u32>,
}

impl GraphImage<'_> {
    /// Local index of a registered root (panics on an unregistered term —
    /// an internal invariant violation, not an input condition).
    pub fn local(&self, t: TermId) -> u32 {
        *self
            .index
            .get(&t.0)
            .expect("term was not registered as a graph root")
    }

    /// Number of nodes in the image.
    pub fn len(&self) -> usize {
        self.order.len()
    }

    pub fn is_empty(&self) -> bool {
        self.order.is_empty()
    }

    /// Write the name tables and the topologically ordered node list.
    pub fn encode(&self, e: &mut Enc) {
        // local name tables, in first-use order
        let mut sym_local: FnvMap<u32, u32> = FnvMap::default();
        let mut sym_names: Vec<&str> = Vec::new();
        let mut uf_local: FnvMap<u32, u32> = FnvMap::default();
        let mut uf_names: Vec<&str> = Vec::new();
        for &t in &self.order {
            match self.pool.node(TermId(t)) {
                Node::Sym { sym, .. } => {
                    sym_local.entry(sym.0).or_insert_with(|| {
                        sym_names.push(self.pool.sym_name(*sym));
                        (sym_names.len() - 1) as u32
                    });
                }
                Node::Uf { func, .. } => {
                    uf_local.entry(func.0).or_insert_with(|| {
                        uf_names.push(self.pool.uf_name(*func));
                        (uf_names.len() - 1) as u32
                    });
                }
                _ => {}
            }
        }
        e.u64(sym_names.len() as u64);
        for n in &sym_names {
            e.str(n);
        }
        e.u64(uf_names.len() as u64);
        for n in &uf_names {
            e.str(n);
        }

        e.u64(self.order.len() as u64);
        for &t in &self.order {
            match self.pool.node(TermId(t)) {
                Node::Const { bits, width } => {
                    e.u8(0);
                    e.u64(*bits);
                    e.u32(*width);
                }
                Node::Sym { sym, width } => {
                    e.u8(1);
                    e.u32(sym_local[&sym.0]);
                    e.u32(*width);
                }
                Node::Uf { func, args, width } => {
                    e.u8(2);
                    e.u32(uf_local[&func.0]);
                    e.u32(*width);
                    e.u64(args.len() as u64);
                    for a in args {
                        e.u32(self.local(*a));
                    }
                }
                Node::Bin { op, a, b, width } => {
                    e.u8(3);
                    e.u8(bvop_tag(*op));
                    e.u32(self.local(*a));
                    e.u32(self.local(*b));
                    e.u32(*width);
                }
                Node::Not { a, width } => {
                    e.u8(4);
                    e.u32(self.local(*a));
                    e.u32(*width);
                }
                Node::Cmp { kind, a, b } => {
                    e.u8(5);
                    e.u8(cmp_tag(*kind));
                    e.u32(self.local(*a));
                    e.u32(self.local(*b));
                }
                Node::Ite { cond, t: tt, e: el, width } => {
                    e.u8(6);
                    e.u32(self.local(*cond));
                    e.u32(self.local(*tt));
                    e.u32(self.local(*el));
                    e.u32(*width);
                }
                Node::SExt { a, from, width } => {
                    e.u8(7);
                    e.u32(self.local(*a));
                    e.u32(*from);
                    e.u32(*width);
                }
                Node::ZExt { a, from, width } => {
                    e.u8(8);
                    e.u32(self.local(*a));
                    e.u32(*from);
                    e.u32(*width);
                }
                Node::Trunc { a, width } => {
                    e.u8(9);
                    e.u32(self.local(*a));
                    e.u32(*width);
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Decoding: relocation into the loading session
// ---------------------------------------------------------------------------

/// Local-index → relocated-`TermId` map produced by [`decode_graph`].
#[derive(Debug)]
pub struct GraphReader {
    map: Vec<TermId>,
}

impl GraphReader {
    /// Relocated id of local node `i` (bounds-checked).
    pub fn term(&self, i: u32) -> Option<TermId> {
        self.map.get(i as usize).copied()
    }
}

/// Read one graph image, re-interning names through `pool`'s session and
/// re-hash-consing every node into `pool`. Returns `None` on any
/// malformed byte (unknown tag, forward/out-of-range child reference,
/// width mismatch, bad UTF-8).
pub fn decode_graph(d: &mut Dec, pool: &mut TermPool) -> Option<GraphReader> {
    let nsyms = d.len()?;
    let mut sym_names = Vec::with_capacity(nsyms);
    for _ in 0..nsyms {
        sym_names.push(d.str()?);
    }
    let nufs = d.len()?;
    let mut uf_names = Vec::with_capacity(nufs);
    for _ in 0..nufs {
        uf_names.push(d.str()?);
    }

    let nnodes = d.len()?;
    let mut map: Vec<TermId> = Vec::with_capacity(nnodes);
    // children must precede their parent: only already-decoded locals
    // resolve, which makes the graph acyclic by construction
    for _ in 0..nnodes {
        let child = |i: u32, map: &[TermId]| -> Option<TermId> { map.get(i as usize).copied() };
        let wok = |w: u32| (1..=128).contains(&w);
        let id = match d.u8()? {
            0 => {
                let bits = d.u64()?;
                let width = d.u32()?;
                wok(width).then(|| pool.constant(bits, width))?
            }
            1 => {
                let name = *sym_names.get(d.u32()? as usize)?;
                let width = d.u32()?;
                wok(width).then(|| pool.symbol(name, width))?
            }
            2 => {
                let name = *uf_names.get(d.u32()? as usize)?;
                let width = d.u32()?;
                let nargs = d.len()?;
                let mut args = Vec::with_capacity(nargs);
                for _ in 0..nargs {
                    args.push(child(d.u32()?, &map)?);
                }
                wok(width).then(|| pool.uf(name, args, width))?
            }
            3 => {
                let op = bvop_from_tag(d.u8()?)?;
                let a = child(d.u32()?, &map)?;
                let b = child(d.u32()?, &map)?;
                let width = d.u32()?;
                pool.rebuild(&Node::Bin { op, a, b, width })?
            }
            4 => {
                let a = child(d.u32()?, &map)?;
                let width = d.u32()?;
                pool.rebuild(&Node::Not { a, width })?
            }
            5 => {
                let kind = cmp_from_tag(d.u8()?)?;
                let a = child(d.u32()?, &map)?;
                let b = child(d.u32()?, &map)?;
                pool.rebuild(&Node::Cmp { kind, a, b })?
            }
            6 => {
                let cond = child(d.u32()?, &map)?;
                let t = child(d.u32()?, &map)?;
                let e = child(d.u32()?, &map)?;
                let width = d.u32()?;
                pool.rebuild(&Node::Ite { cond, t, e, width })?
            }
            7 => {
                let a = child(d.u32()?, &map)?;
                let from = d.u32()?;
                let width = d.u32()?;
                pool.rebuild(&Node::SExt { a, from, width })?
            }
            8 => {
                let a = child(d.u32()?, &map)?;
                let from = d.u32()?;
                let width = d.u32()?;
                pool.rebuild(&Node::ZExt { a, from, width })?
            }
            9 => {
                let a = child(d.u32()?, &map)?;
                let width = d.u32()?;
                pool.rebuild(&Node::Trunc { a, width })?
            }
            _ => return None,
        };
        map.push(id);
    }
    Some(GraphReader { map })
}

// ---------------------------------------------------------------------------
// EmulationResult codec
// ---------------------------------------------------------------------------

fn encode_assumptions(e: &mut Enc, img: &AssumptionsImage, g: &GraphImage) {
    e.u64(img.forms.len() as u64);
    for f in &img.forms {
        e.u64(f.atoms.len() as u64);
        for &(t, c) in &f.atoms {
            e.u32(g.local(t));
            e.i128(c);
        }
        for bound in [f.lo, f.hi] {
            match bound {
                None => e.u8(0),
                Some(v) => {
                    e.u8(1);
                    e.i128(v);
                }
            }
        }
        e.u64(f.ne.len() as u64);
        for &v in &f.ne {
            e.i128(v);
        }
        e.bool(f.nonneg);
    }
    e.u64(img.opaque.len() as u64);
    for &(t, v) in &img.opaque {
        e.u32(g.local(t));
        e.bool(v);
    }
}

fn decode_assumptions(d: &mut Dec, g: &GraphReader) -> Option<Assumptions> {
    let nforms = d.len()?;
    let mut forms = Vec::with_capacity(nforms);
    for _ in 0..nforms {
        let natoms = d.len()?;
        let mut atoms = Vec::with_capacity(natoms);
        for _ in 0..natoms {
            let t = g.term(d.u32()?)?;
            atoms.push((t, d.i128()?));
        }
        let mut bounds = [None, None];
        for b in bounds.iter_mut() {
            *b = match d.u8()? {
                0 => None,
                1 => Some(d.i128()?),
                _ => return None,
            };
        }
        let nne = d.len()?;
        let mut ne = Vec::with_capacity(nne);
        for _ in 0..nne {
            ne.push(d.i128()?);
        }
        forms.push(FormImage {
            atoms,
            lo: bounds[0],
            hi: bounds[1],
            ne,
            nonneg: d.bool()?,
        });
    }
    let nopaque = d.len()?;
    let mut opaque = Vec::with_capacity(nopaque);
    for _ in 0..nopaque {
        let t = g.term(d.u32()?)?;
        opaque.push((t, d.bool()?));
    }
    Some(Assumptions::from_image(AssumptionsImage { forms, opaque }))
}

/// Serialize a whole emulation result as a self-contained, relocatable
/// image (version ∥ graph ∥ result shape ∥ `Fnv128` checksum).
pub fn encode_emulation(r: &EmulationResult) -> Vec<u8> {
    // snapshot the assumption sets once: the images both supply the
    // graph roots and get encoded verbatim afterwards
    let images: Vec<AssumptionsImage> = r.flows.iter().map(|f| f.assumptions.export()).collect();

    let mut b = GraphBuilder::new(&r.pool);
    b.add_root(r.tid_sym);
    let mut roots = Vec::new();
    for f in &r.flows {
        f.trace.term_roots(&mut roots);
    }
    for img in &images {
        for form in &img.forms {
            roots.extend(form.atoms.iter().map(|&(t, _)| t));
        }
        roots.extend(img.opaque.iter().map(|&(t, _)| t));
    }
    for t in roots {
        b.add_root(t);
    }
    let g = b.seal();

    let mut e = Enc::default();
    e.u32(PERSIST_VERSION);
    g.encode(&mut e);
    e.u32(g.local(r.tid_sym));
    for w in r.stats.to_words() {
        e.u64(w);
    }
    e.u64(r.flows.len() as u64);
    for (f, img) in r.flows.iter().zip(&images) {
        e.u32(f.id);
        e.u8(f.end.tag());
        f.trace.encode(&mut e, &mut |t| g.local(t));
        encode_assumptions(&mut e, img, &g);
    }

    let (c0, c1) = {
        let mut h = Fnv128::new();
        h.write(&e.buf);
        h.finish()
    };
    e.u64(c0);
    e.u64(c1);
    e.buf
}

/// Decode an emulation image into the *loading* session: a fresh
/// [`TermPool`] is grown in `session`, every name re-interned, every node
/// re-hash-consed. Any checksum/bounds/shape violation returns `None`
/// (the caller recomputes, exactly like other corrupt artifacts).
pub fn decode_emulation(
    bytes: &[u8],
    session: &Arc<SessionInterner>,
) -> Option<EmulationResult> {
    if bytes.len() < 16 {
        return None;
    }
    let (body, tail) = bytes.split_at(bytes.len() - 16);
    let want = {
        let mut h = Fnv128::new();
        h.write(body);
        h.finish()
    };
    let mut td = Dec::new(tail);
    if (td.u64()?, td.u64()?) != want {
        return None;
    }

    let mut d = Dec::new(body);
    if d.u32()? != PERSIST_VERSION {
        return None;
    }
    let mut pool = TermPool::in_session(session.clone());
    let g = decode_graph(&mut d, &mut pool)?;
    let tid_sym = g.term(d.u32()?)?;
    let mut words = [0u64; 12];
    for w in words.iter_mut() {
        *w = d.u64()?;
    }
    let stats = EmuStats::from_words(words);
    let nflows = d.len()?;
    let mut flows = Vec::with_capacity(nflows);
    for _ in 0..nflows {
        let id = d.u32()?;
        let end = FlowEnd::from_tag(d.u8()?)?;
        let trace = crate::emu::memtrace::MemTrace::decode(&mut d, &|i| g.term(i))?;
        let assumptions = decode_assumptions(&mut d, &g)?;
        flows.push(FlowResult {
            id,
            trace,
            assumptions,
            end,
        });
    }
    d.done().then_some(EmulationResult {
        pool,
        flows,
        tid_sym,
        stats,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::emu::emulate_in_session;
    use crate::emu::Limits;
    use crate::ptx::parser::parse_kernel;
    use crate::sym::term::{eval, SymId, UfId};
    use crate::util::{check_cases, fnv64, Rng};

    /// Name-keyed evaluation environment: identical values in any pool
    /// that spells the same names, whatever the local ids are.
    fn eval_by_name(pool: &TermPool, t: TermId, seed: u64) -> u64 {
        let sym_val = |s: SymId| {
            let mut r = Rng::new(seed ^ fnv64(pool.sym_name(s).as_bytes()));
            r.next_u64()
        };
        let uf_val = |f: UfId, args: &[u64]| {
            let mut h = seed ^ fnv64(pool.uf_name(f).as_bytes());
            for &a in args {
                h = h.rotate_left(13) ^ a.wrapping_mul(0x100000001B3);
            }
            h
        };
        eval(pool, t, &sym_val, &uf_val)
    }

    fn random_term(p: &mut TermPool, rng: &mut Rng, depth: u32, width: u32) -> TermId {
        if depth == 0 || rng.below(5) == 0 {
            return match rng.below(4) {
                0 => p.constant(rng.next_u64(), width),
                1 => p.symbol(&format!("s{}", rng.below(5)), width),
                _ => {
                    // UFs of arity 0..=2 over mixed-width args
                    let arity = rng.below(3) as usize;
                    let args = (0..arity)
                        .map(|_| {
                            let w = *rng.pick(&[8u32, 16, 32, 64]);
                            p.symbol(&format!("a{}", rng.below(3)), w)
                        })
                        .collect();
                    p.uf(&format!("f{}", rng.below(3)), args, width)
                }
            };
        }
        match rng.below(8) {
            0 => {
                let from = match width {
                    64 => 32,
                    32 => 16,
                    _ => 8,
                };
                if from < width {
                    let a = random_term(p, rng, depth - 1, from);
                    return if rng.below(2) == 0 {
                        p.sext(a, width)
                    } else {
                        p.zext(a, width)
                    };
                }
            }
            1 => {
                let wider = if width < 64 { 64 } else { 128 };
                let a = random_term(p, rng, depth - 1, wider);
                return p.trunc(a, width);
            }
            2 => {
                let w = *rng.pick(&[8u32, 16, 32, 64]);
                let a = random_term(p, rng, depth - 1, w);
                let b = random_term(p, rng, depth - 1, w);
                let kind = cmp_from_tag(rng.below(10) as u8).unwrap();
                let c = p.cmp(kind, a, b);
                let t = random_term(p, rng, depth - 1, width);
                let e = random_term(p, rng, depth - 1, width);
                return p.ite(c, t, e);
            }
            3 => {
                let a = random_term(p, rng, depth - 1, width);
                return p.not(a);
            }
            _ => {}
        }
        let a = random_term(p, rng, depth - 1, width);
        let b = random_term(p, rng, depth - 1, width);
        let op = bvop_from_tag(rng.below(17) as u8).unwrap();
        p.bin(op, a, b)
    }

    fn roundtrip_graph(src: &TermPool, roots: &[TermId], dst: &mut TermPool) -> Vec<TermId> {
        let mut b = GraphBuilder::new(src);
        for &r in roots {
            b.add_root(r);
        }
        let g = b.seal();
        let mut e = Enc::default();
        g.encode(&mut e);
        let locals: Vec<u32> = roots.iter().map(|&r| g.local(r)).collect();
        let mut d = Dec::new(&e.buf);
        let r = decode_graph(&mut d, dst).expect("decode of a fresh encoding");
        assert!(d.done(), "trailing bytes after graph");
        locals.iter().map(|&l| r.term(l).unwrap()).collect()
    }

    /// Round-trip over randomized graphs: eval agreement on every root,
    /// across sessions, with the destination interner polluted so every
    /// `SymId`/`UfId`/`TermId` is numerically different.
    #[test]
    fn prop_roundtrip_eval_agreement() {
        check_cases("persist-roundtrip-eval", 200, |rng| {
            let mut src = TermPool::new();
            let width = *rng.pick(&[8u32, 16, 32, 64]);
            let roots: Vec<TermId> = (0..1 + rng.below(4))
                .map(|_| random_term(&mut src, rng, 4, width))
                .collect();

            // destination session polluted with unrelated names
            let session = Arc::new(SessionInterner::new());
            let mut dst = TermPool::in_session(session);
            for i in 0..10 {
                dst.symbol(&format!("noise{i}"), 32);
                dst.uf(&format!("nf{i}"), vec![], 32);
            }

            let relocated = roundtrip_graph(&src, &roots, &mut dst);
            let seed = rng.next_u64();
            for (&r, &n) in roots.iter().zip(&relocated) {
                assert_eq!(
                    eval_by_name(&src, r, seed),
                    eval_by_name(&dst, n, seed),
                    "relocated root evaluates differently"
                );
                assert_eq!(src.width(r), dst.width(n), "width changed in relocation");
            }
        });
    }

    /// Structural sharing is rebuilt: the same root decoded twice into one
    /// pool lands on the same `TermId`.
    #[test]
    fn relocation_rehashconses() {
        let mut src = TermPool::new();
        let x = src.symbol("x", 32);
        let c = src.constant(7, 32);
        let t = src.bin(BvOp::Add, x, c);
        let u = src.uf("load", vec![t], 32);

        let mut dst = TermPool::new();
        let first = roundtrip_graph(&src, &[u, t], &mut dst);
        let len_after_first = dst.len();
        let second = roundtrip_graph(&src, &[u, t], &mut dst);
        assert_eq!(first, second, "re-decoding must re-hash-cons to the same ids");
        assert_eq!(dst.len(), len_after_first, "no duplicate nodes interned");
    }

    /// A full emulation survives the codec: encode in one session, decode
    /// into a *different* polluted session, and compare the result shape
    /// plus eval agreement on every memory-trace root.
    #[test]
    fn emulation_roundtrip_cross_session() {
        const K: &str = r#"
.visible .entry rt(.param .u64 out, .param .u64 a, .param .u32 n){
.reg .b32 %r<6>; .reg .b64 %rd<8>; .reg .f32 %f<4>; .reg .pred %p<2>;
ld.param.u64 %rd1, [out];
ld.param.u64 %rd2, [a];
ld.param.u32 %r5, [n];
cvta.to.global.u64 %rd3, %rd2;
cvta.to.global.u64 %rd4, %rd1;
mov.u32 %r1, %tid.x;
setp.ge.s32 %p1, %r1, %r5;
@%p1 bra $EXIT;
mul.wide.s32 %rd5, %r1, 4;
add.s64 %rd6, %rd3, %rd5;
ld.global.f32 %f1, [%rd6];
ld.global.f32 %f2, [%rd6+4];
add.f32 %f3, %f1, %f2;
add.s64 %rd7, %rd4, %rd5;
st.global.f32 [%rd7], %f3;
$EXIT: ret;
}
"#;
        let k = parse_kernel(K).unwrap();
        let fresh = emulate_in_session(
            &k,
            Limits::default(),
            Arc::new(SessionInterner::new()),
        )
        .unwrap();
        let bytes = encode_emulation(&fresh);

        // polluted loading session: every id is shifted
        let session = Arc::new(SessionInterner::new());
        {
            let mut warm = TermPool::in_session(session.clone());
            for i in 0..20 {
                warm.symbol(&format!("other{i}"), 32);
                warm.uf(&format!("of{i}"), vec![], 64);
            }
        }
        let loaded = decode_emulation(&bytes, &session).expect("image decodes");

        assert_eq!(loaded.flows.len(), fresh.flows.len());
        assert_eq!(loaded.stats.to_words(), fresh.stats.to_words());
        let seed = 0xC0FF_EE00_D15E_A5E5u64;
        assert_eq!(
            eval_by_name(&fresh.pool, fresh.tid_sym, seed),
            eval_by_name(&loaded.pool, loaded.tid_sym, seed)
        );
        for (a, b) in fresh.flows.iter().zip(&loaded.flows) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.end, b.end);
            assert_eq!(a.trace.loads.len(), b.trace.loads.len());
            assert_eq!(a.trace.stores.len(), b.trace.stores.len());
            assert_eq!(a.assumptions.fact_count(), b.assumptions.fact_count());
            for (la, lb) in a.trace.loads.iter().zip(&b.trace.loads) {
                assert_eq!((la.stmt, la.ty, la.space), (lb.stmt, lb.ty, lb.space));
                assert_eq!(
                    (la.nc, la.segment, la.guarded, la.valid),
                    (lb.nc, lb.segment, lb.guarded, lb.valid)
                );
                assert_eq!(
                    eval_by_name(&fresh.pool, la.addr, seed),
                    eval_by_name(&loaded.pool, lb.addr, seed),
                    "load address diverged"
                );
                assert_eq!(
                    eval_by_name(&fresh.pool, la.value, seed),
                    eval_by_name(&loaded.pool, lb.value, seed),
                    "load value diverged"
                );
            }
        }

        // the downstream consumer agrees: detection over the relocated
        // result is identical to detection over the fresh one
        let opts = crate::shuffle::DetectOpts::default();
        let d1 = crate::shuffle::detect(&k, &fresh, opts);
        let d2 = crate::shuffle::detect(&k, &loaded, opts);
        assert_eq!(d1.chosen, d2.chosen, "relocation changed detection");
        assert_eq!(d1.total_global_loads, d2.total_global_loads);
    }

    /// Relocated assumptions answer `check` like the originals even
    /// though every `TermId` was renumbered (key re-canonicalization).
    #[test]
    fn relocated_assumptions_still_decide() {
        use crate::sym::solver::Truth;
        let mut src = TermPool::new();
        let x = src.symbol("x", 32);
        let y = src.symbol("y", 32);
        let c100 = src.constant(100, 32);
        let lt = src.cmp(CmpKind::Slt, x, c100); // x < 100
        let xy = src.cmp(CmpKind::Slt, x, y); // x < y
        let mut a = Assumptions::new();
        a.assume(&src, lt, true).unwrap();
        a.assume(&src, xy, true).unwrap();

        // relocate the atoms and the image into a pool where y interns
        // *before* x, flipping the canonical atom order of `x - y`
        let session = Arc::new(SessionInterner::new());
        let mut dst = TermPool::in_session(session);
        dst.symbol("y", 32);
        dst.symbol("noise", 8);
        let mut b = GraphBuilder::new(&src);
        let img = a.export();
        for f in &img.forms {
            for &(t, _) in &f.atoms {
                b.add_root(t);
            }
        }
        for &(t, _) in &img.opaque {
            b.add_root(t);
        }
        let g = b.seal();
        let mut e = Enc::default();
        g.encode(&mut e);
        let mut enc2 = Enc::default();
        encode_assumptions(&mut enc2, &img, &g);
        let mut d = Dec::new(&e.buf);
        let r = decode_graph(&mut d, &mut dst).unwrap();
        let mut d2 = Dec::new(&enc2.buf);
        let reloc = decode_assumptions(&mut d2, &r).unwrap();

        let nx = dst.symbol("x", 32);
        let ny = dst.symbol("y", 32);
        let nc200 = dst.constant(200, 32);
        let nlt200 = dst.cmp(CmpKind::Slt, nx, nc200);
        assert_eq!(reloc.check(&dst, nlt200), Truth::True, "x < 100 ⇒ x < 200");
        let nyx = dst.cmp(CmpKind::Sgt, ny, nx);
        assert_eq!(reloc.check(&dst, nyx), Truth::True, "x < y ⇒ y > x");
    }

    /// Corrupt and truncated images must fail decode, never panic.
    #[test]
    fn corrupt_and_truncated_images_are_rejected() {
        let k = parse_kernel(
            r#"
.visible .entry c(.param .u64 a){
.reg .b32 %r<4>; .reg .b64 %rd<4>; .reg .f32 %f<2>;
ld.param.u64 %rd1, [a];
cvta.to.global.u64 %rd2, %rd1;
mov.u32 %r1, %tid.x;
mul.wide.s32 %rd3, %r1, 4;
add.s64 %rd2, %rd2, %rd3;
ld.global.f32 %f1, [%rd2];
st.global.f32 [%rd2], %f1;
ret;
}
"#,
        )
        .unwrap();
        let r = emulate_in_session(&k, Limits::default(), Arc::new(SessionInterner::new()))
            .unwrap();
        let bytes = encode_emulation(&r);
        let session = Arc::new(SessionInterner::new());
        assert!(decode_emulation(&bytes, &session).is_some());

        // every truncation fails cleanly
        for cut in 0..bytes.len() {
            assert!(
                decode_emulation(&bytes[..cut], &session).is_none(),
                "truncation at {cut} must be rejected"
            );
        }
        // every single-byte flip fails cleanly (checksum) — sample to
        // keep the test fast
        for i in (0..bytes.len()).step_by(7) {
            let mut bad = bytes.clone();
            bad[i] ^= 0xFF;
            assert!(
                decode_emulation(&bad, &session).is_none(),
                "bit flip at {i} must be rejected"
            );
        }
    }
}
